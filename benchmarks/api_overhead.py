"""API-overhead benchmark: pnp/PositArray dispatch vs raw functional calls.

The PositArray wrapper and the pnp namespace are pure trace-time sugar: the
config is static pytree metadata and every operator lowers to exactly the
same XLA computation as the functional `core.ops` call.  After `jax.jit`
tracing, dispatch overhead must therefore be ~= 0 (both paths execute the
same compiled executable; only the pytree flatten/unflatten differs, which
is nanoseconds per call).

Reports us/call for both paths and their ratio for add / fma / matmul.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time_call(fn, *args, iters: int = 100, repeats: int = 7,
               warmup: int = 5) -> float:
    """us/call, median over `repeats` samples (single means on ~1ms CPU
    dispatches are noise-dominated; the median keeps scheduler blips from
    reading as dispatch 'overhead')."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    samples = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        samples.append((time.perf_counter() - t0) / iters * 1e6)
    samples.sort()
    return samples[len(samples) // 2]


def run(report) -> None:
    import repro.pnp as pnp
    from repro.core import P16_2
    from repro.core.ops import padd, pfma
    from repro.core.quire import quire_matmul

    cfg = P16_2
    rng = np.random.default_rng(0)
    shape = (256, 256)
    ab = jnp.asarray(rng.integers(-(1 << 15) + 1, 1 << 15, shape), jnp.int16)
    bb = jnp.asarray(rng.integers(-(1 << 15) + 1, 1 << 15, shape), jnp.int16)
    a, b = pnp.frombits(ab, cfg), pnp.frombits(bb, cfg)

    cases = {
        "add": (jax.jit(lambda x, y: (x + y).bits), (a, b),
                jax.jit(lambda x, y: padd(x, y, cfg)), (ab, bb)),
        "fma": (jax.jit(lambda x, y: pnp.fma(x, y, x).bits), (a, b),
                jax.jit(lambda x, y: pfma(x, y, x, cfg)), (ab, bb)),
        "matmul": (jax.jit(lambda x, y: (x @ y).bits), (a, b),
                   jax.jit(lambda x, y: quire_matmul(x, y, cfg)), (ab, bb)),
    }

    derived = {}
    total_us = 0.0
    for name, (new_fn, new_args, old_fn, old_args) in cases.items():
        # same bits out is a precondition for a fair comparison
        assert (np.asarray(new_fn(*new_args))
                == np.asarray(old_fn(*old_args))).all(), name
        us_new = _time_call(new_fn, *new_args)
        us_old = _time_call(old_fn, *old_args)
        derived[name] = {
            "pnp_us": round(us_new, 2),
            "functional_us": round(us_old, 2),
            "overhead_ratio": round(us_new / us_old, 3),
        }
        total_us += us_new
    report("api_overhead", total_us / len(cases), derived)


if __name__ == "__main__":
    run(lambda name, us, d: print(name, us, d))
