"""Paper Table II: % of inexact division results, PACoGen LUT vs proposed.

Exhaustive over all operand pairs for posit8 (es 0..4), sampled (10^6 pairs)
for posit16 (es 0..3).  "wrong %" = fraction of results differing from the
exact golden division (core.golden.pdiv), exactly the paper's metric.

Also re-derives the optimized reciprocal constants (eq. 12-13) and checks
the claimed 36.4% error-integral improvement over [19].
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import golden as G
from repro.core import ops as O
from repro.core import recip
from repro.core.types import PositConfig, table2_grid

# paper Table II: NR rounds per mode
PACOGEN_NR = {8: 0, 16: 1}
PROPOSED_NR = 1


def wrong_pct(cfg: PositConfig, mode: str, nr: int, n_sample: int = 1_000_000,
              seed: int = 0) -> float:
    if cfg.n <= 8:
        bits = np.arange(1 << cfg.n)
        A, B = np.meshgrid(bits, bits)
        A, B = A.ravel(), B.ravel()
    else:
        rng = np.random.default_rng(seed)
        A = rng.integers(0, 1 << cfg.n, n_sample)
        B = rng.integers(0, 1 << cfg.n, n_sample)
    want = G.pdiv(A, B, cfg)
    got = np.asarray(
        O.pdiv(jnp.asarray(A, jnp.int32), jnp.asarray(B, jnp.int32), cfg,
               mode=mode, nr_rounds=nr)).astype(np.int64) & cfg.mask
    # exclude trivial specials (0/x, x/0, NaR) like a divider testbench would?
    # The paper counts all pairs; we do too.
    return 100.0 * float((got != want).mean())


def table2() -> list[dict]:
    rows = []
    for cfg in table2_grid():
        rows.append({
            "N": cfg.n, "ES": cfg.es,
            "pacogen_NR": PACOGEN_NR[cfg.n],
            "pacogen_wrong_pct": round(
                wrong_pct(cfg, "pacogen", PACOGEN_NR[cfg.n]), 2),
            "proposed_NR": PROPOSED_NR,
            "proposed_wrong_pct": round(
                wrong_pct(cfg, "poly", PROPOSED_NR), 2),
            "corrected_wrong_pct": round(
                wrong_pct(cfg, "poly_corrected", PROPOSED_NR), 2),
        })
    return rows


def constants_check() -> dict:
    k1, k2, e2_opt = recip.optimize_k1_k2()
    e2_ref19 = recip.squared_rel_err(recip.K1_REF19, recip.K2_REF19)
    improvement = 100.0 * (1 - e2_opt / e2_ref19)
    return {
        "k1_opt": k1, "k2_opt": k2,
        "k1_paper": recip.K1_OPT, "k2_paper": recip.K2_OPT,
        "k1_abs_err": abs(k1 - recip.K1_OPT),
        "k2_abs_err": abs(k2 - recip.K2_OPT),
        "e2_opt": e2_opt, "e2_ref19": e2_ref19,
        "improvement_vs_ref19_pct": round(improvement, 1),
        "paper_claim_pct": 36.4,
    }


def run(report):
    import time
    t0 = time.time()
    rows = table2()
    report("table2_division_accuracy", (time.time() - t0) * 1e6 / max(len(rows), 1),
           rows)
    t0 = time.time()
    cc = constants_check()
    report("k1k2_optimization", (time.time() - t0) * 1e6, cc)
