"""Paper Fig. 7/8: DNN inference accuracy — posit8 / posit16 / bfloat16 vs
binary32 on a LeNet-5-class CNN.

No datasets ship offline, so the model trains on a deterministic synthetic
MNIST-stand-in (10 gaussian digit prototypes + noise, 32x32, the paper's
image size); the *comparison* between number formats on identical weights
and inputs is the reproduced artifact: the paper's claim is that p16
matches binary32 and p8 degrades only slightly.

Inference modes:
  f32        binary32 reference
  bf16       bfloat16 weights+activations (Fig. 8 comparison format)
  p16 / p8   posit-quantized weights & activations, GEMMs through the quire
             path (decode -> exact f32 products -> one posit rounding per
             dot product — the FPPU PFMADD/quire semantics)
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.convert import f32_to_posit
from repro.core.decode import decode_to_f32
from repro.core.types import P8_2, P16_2, PositConfig
from repro.configs.lenet5_posit import init_lenet, lenet_forward

N_CLASS = 10


_PROTO_KEY = jax.random.PRNGKey(1234)            # dataset identity, fixed


def _prototypes():
    protos = jax.random.normal(_PROTO_KEY, (N_CLASS, 32, 32, 1))
    # cheap blur: average shifted copies (keeps everything deterministic)
    for _ in range(2):
        protos = (protos + jnp.roll(protos, 1, 1) + jnp.roll(protos, 1, 2)
                  + jnp.roll(protos, -1, 1) + jnp.roll(protos, -1, 2)) / 5.0
    protos = protos / jnp.std(protos, axis=(1, 2, 3), keepdims=True)
    return protos


def synth_batch(key, n: int):
    """10 fixed class prototypes (blurred blobs) + per-sample noise."""
    kn, kl = jax.random.split(key, 2)
    protos = _prototypes()
    labels = jax.random.randint(kl, (n,), 0, N_CLASS)
    # noise tuned so accuracy sits just below saturation — format
    # differences (p8 vs p16 vs f32) are visible, as in the paper's Fig. 7
    noise = 2.6 * jax.random.normal(kn, (n, 32, 32, 1))
    return protos[labels] + noise, labels


def train_f32(steps: int = 250, batch: int = 128, lr: float = 0.02, seed=0):
    params = init_lenet(jax.random.PRNGKey(seed))
    mom = jax.tree_util.tree_map(jnp.zeros_like, params)

    def loss_fn(p, x, y):
        logits = lenet_forward(p, x)
        lp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(lp, y[:, None], axis=-1).mean()

    @jax.jit
    def step(p, m, k):
        x, y = synth_batch(k, batch)
        l, g = jax.value_and_grad(loss_fn)(p, x, y)
        m = jax.tree_util.tree_map(lambda mm, gg: 0.9 * mm + gg, m, g)
        p = jax.tree_util.tree_map(lambda w, mm: w - lr * mm, p, m)
        return p, m, l

    key = jax.random.PRNGKey(seed + 1)
    for i in range(steps):
        key, sub = jax.random.split(key)
        params, mom, l = step(params, mom, sub)
    return params


def posit_matmul(cfg: PositConfig):
    """Quire-mode GEMM: posit-quantized operands, one rounding per dot."""
    def mm(a, b):
        pa = f32_to_posit(a.astype(jnp.float32), cfg)
        pb = f32_to_posit(b.astype(jnp.float32), cfg)
        af = decode_to_f32(pa, cfg)
        bf = decode_to_f32(pb, cfg)
        acc = jnp.dot(af, bf, preferred_element_type=jnp.float32)
        return decode_to_f32(f32_to_posit(acc, cfg), cfg)
    return mm


def evaluate(params, mode: str, n_eval: int = 2048, seed=42) -> float:
    x, y = synth_batch(jax.random.PRNGKey(seed), n_eval)
    if mode == "f32":
        logits = lenet_forward(params, x)
    elif mode == "bf16":
        pb = jax.tree_util.tree_map(lambda w: w.astype(jnp.bfloat16), params)
        logits = lenet_forward(pb, x.astype(jnp.bfloat16),
                               matmul=lambda a, b: (a @ b))
    elif mode in ("p8", "p16"):
        cfg = {"p8": P8_2, "p16": P16_2}[mode]
        logits = lenet_forward(params, x, matmul=posit_matmul(cfg))
    else:
        raise ValueError(mode)
    return float((jnp.argmax(logits, -1) == y).mean())


def fig7() -> dict:
    params = train_f32()
    out = {m: round(evaluate(params, m), 4)
           for m in ("f32", "bf16", "p16", "p8")}
    out["p16_drop_pp"] = round(100 * (out["f32"] - out["p16"]), 2)
    out["p8_drop_pp"] = round(100 * (out["f32"] - out["p8"]), 2)
    return out


def run(report):
    import time
    t0 = time.time()
    res = fig7()
    report("fig7_lenet_accuracy", (time.time() - t0) * 1e6, res)
