"""Paper Table IV: normalized mean error of posit ops vs binary32 in DNN
linear-algebra kernels on 32x32 matrices (GEMM, 3x3 conv, 4x4 avg pooling).

Replays the paper's trace-parser methodology: run each kernel through the
posit datapath (p<8,0> and p<16,2>), record every executed p.mul / p.add /
p.div next to the binary32 result of the same operation, and report
  e_op = mean(|r_posit - r_f32| / |r_f32|)
per operation type per kernel — the exact Table IV layout.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import ops as O
from repro.core.convert import f32_to_posit
from repro.core.decode import decode_to_f32
from repro.core.types import P8_0, P16_2, PositConfig

SIZE = 32


class _Tracer:
    """Accumulates per-op normalized errors (posit vs f32 twin)."""

    def __init__(self, cfg: PositConfig):
        self.cfg = cfg
        self.errs = {"mul": [], "add": [], "div": []}

    def _record(self, op, pres, fres):
        pv = np.asarray(decode_to_f32(pres, self.cfg), np.float64)
        fv = np.asarray(fres, np.float64)
        mask = fv != 0
        if mask.any():
            self.errs[op].append(
                np.abs((pv[mask] - fv[mask]) / fv[mask]))

    def mul(self, pa, pb, fa, fb):
        out = O.pmul(pa, pb, self.cfg)
        self._record("mul", out, fa * fb)
        return out

    def add(self, pa, pb, fa, fb):
        out = O.padd(pa, pb, self.cfg)
        self._record("add", out, fa + fb)
        return out

    def div_scalar(self, pa, scalar: float, fa):
        pb = f32_to_posit(jnp.full(np.shape(pa), scalar, jnp.float32), self.cfg)
        out = O.pdiv(jnp.asarray(pa), pb, self.cfg, mode="poly")
        self._record("div", out, fa / scalar)
        return out

    def nme(self):
        return {op: (float(np.concatenate(v).mean()) if v else None)
                for op, v in self.errs.items()}


def _quant(x, cfg):
    return f32_to_posit(jnp.asarray(x, jnp.float32), cfg)


def gemm_trace(cfg: PositConfig, seed=0):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(SIZE, SIZE)).astype(np.float32)
    Bm = rng.normal(size=(SIZE, SIZE)).astype(np.float32)
    tr = _Tracer(cfg)
    pA, pB = _quant(A, cfg), _quant(Bm, cfg)
    fA = np.asarray(decode_to_f32(pA, cfg))      # f32 twin starts from the
    fB = np.asarray(decode_to_f32(pB, cfg))      # same representable values
    psum = _quant(np.zeros((SIZE, SIZE)), cfg)
    fsum = np.zeros((SIZE, SIZE), np.float32)
    for k in range(SIZE):
        pm = tr.mul(pA[:, k:k+1], pB[k:k+1, :], fA[:, k:k+1], fB[k:k+1, :])
        fm = fA[:, k:k+1] * fB[k:k+1, :]
        psum = tr.add(psum, pm, fsum, fm)
        fsum = fsum + fm
    return tr.nme()


def conv3x3_trace(cfg: PositConfig, seed=1):
    rng = np.random.default_rng(seed)
    img = rng.normal(size=(SIZE + 2, SIZE + 2)).astype(np.float32)
    filt = rng.normal(size=(3, 3)).astype(np.float32)
    tr = _Tracer(cfg)
    pI, pF = _quant(img, cfg), _quant(filt, cfg)
    fI = np.asarray(decode_to_f32(pI, cfg))
    fF = np.asarray(decode_to_f32(pF, cfg))
    psum = _quant(np.zeros((SIZE, SIZE)), cfg)
    fsum = np.zeros((SIZE, SIZE), np.float32)
    for di in range(3):
        for dj in range(3):
            tile_p = pI[di:di+SIZE, dj:dj+SIZE]
            tile_f = fI[di:di+SIZE, dj:dj+SIZE]
            pm = tr.mul(tile_p, pF[di, dj], tile_f, fF[di, dj])
            fm = tile_f * fF[di, dj]
            psum = tr.add(psum, pm, fsum, fm)
            fsum = fsum + fm
    return tr.nme()


def avgpool4x4_trace(cfg: PositConfig, seed=2):
    rng = np.random.default_rng(seed)
    img = rng.normal(size=(SIZE, SIZE)).astype(np.float32)
    tr = _Tracer(cfg)
    pI = _quant(img, cfg)
    fI = np.asarray(decode_to_f32(pI, cfg))
    o = SIZE // 4
    pview = jnp.asarray(pI).reshape(o, 4, o, 4).transpose(0, 2, 1, 3).reshape(o, o, 16)
    fview = fI.reshape(o, 4, o, 4).transpose(0, 2, 1, 3).reshape(o, o, 16)
    psum, fsum = pview[..., 0], fview[..., 0]
    for t in range(1, 16):
        psum = tr.add(psum, pview[..., t], fsum, fview[..., t])
        fsum = fsum + fview[..., t]
    tr.div_scalar(psum, 16.0, fsum)
    return tr.nme()


def table4() -> dict:
    out = {}
    for task, fn in (("conv3x3", conv3x3_trace), ("gemm", gemm_trace),
                     ("avgpool4x4", avgpool4x4_trace)):
        out[task] = {}
        for cfg in (P8_0, P16_2):
            out[task][str(cfg)] = fn(cfg)
    return out


def run(report):
    import time
    t0 = time.time()
    t4 = table4()
    report("table4_linear_algebra_nme", (time.time() - t0) * 1e6, t4)
