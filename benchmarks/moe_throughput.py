"""Grouped posit MoE serving vs the dense one-shot GShard baseline.

The ISSUE-5 perf claim: a MoE decode step should stream **only the active
experts'** posit-packed weights (grouped GEMM, kernels/grouped_gemm.py),
not materialize all E experts' [d_model, d_ff] blocks as f32 the way the
one-hot dispatch does.  This bench drains the paged serving engine over an
olmoe-1b-7b-smoke-shaped model twice per posit format — once with the
dense one-shot path pinned (models.moe.FORCE_DENSE, the GShard baseline,
with the *pre-PR* serving capacity_factor restored so the baseline drops
tokens exactly as the replaced path did) and once with sort-based grouped
routing pinned (FORCE_GROUPED, no drops — the shipped serving semantics)
— and reports measured tok/s plus modeled per-step expert-weight traffic.

On the CPU backend both legs execute jnp (the grouped leg runs the routing
scheme with the dense reference matmul behind it), so the measured ratio
is near 1.0 and the modeled roofline columns carry the signal; on TPU the
grouped leg takes the Pallas kernel.  Modeled columns per MoE layer and
decode step of B tokens:

    dense one-shot:  E * glu * d * ff * 4            (full f32 decode)
    grouped posit:   min(E, B*top_k) * glu * d * ff * w   (active tiles)

so at B=1 the ratio is (top_k / E) * (w / 4) — the acceptance row's
(top_k/E + eps) bound holds with the posit width giving another 2x (p16)
or 4x (p8) on top.

    PYTHONPATH=src python -m benchmarks.moe_throughput [--smoke]

Writes experiments/BENCH_moe.json (nightly CI artifact).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "experiments",
                            "BENCH_moe.json")

_STORAGE_BYTES = {"off": 4, "p8": 1, "p16": 2}


def _model(posit: str, leg: str):
    import jax
    from repro import configs
    from repro.core.types import P8_2, P16_2
    from repro.models.transformer import ModelConfig, init_params
    from repro.quant.policy import PositPolicy, quantize_tree
    pcfg = {"p8": P8_2, "p16": P16_2, "off": None}[posit]
    base = configs.get_smoke("olmoe-1b-7b")
    # distinct names: the per-config jitted step caches one trace per name,
    # and the two legs trace different dispatch paths
    cfg = ModelConfig(**{**base.__dict__,
                         "name": f"bench-moe-{posit}-{leg}",
                         "policy": PositPolicy(kv_cache=pcfg)})
    params = init_params(jax.random.PRNGKey(0), cfg)
    if pcfg is not None:
        params = quantize_tree(params, pcfg)
    return params, cfg


def _drain(params, cfg, reqs, batch, page_size, table_width, chunk) -> float:
    from repro.serving.engine import PagedServingEngine
    eng = PagedServingEngine(params, cfg, max_seqs=batch,
                             page_size=page_size, table_width=table_width,
                             prefill_chunk=chunk)
    t0 = time.time()
    eng.run(list(reqs))
    return time.time() - t0


def _weight_bytes_per_step(cfg, n_tokens: int, posit: str):
    """Modeled expert-weight HBM traffic for one decode step of n_tokens,
    summed over the MoE layers."""
    moe = cfg.moe
    glu = 3 if cfg.act in ("geglu", "swiglu") else 2
    per_expert = glu * cfg.d_model * cfg.d_ff
    dense = cfg.n_layers * moe.n_experts * per_expert * 4
    active = min(moe.n_experts, n_tokens * moe.top_k)
    grouped = cfg.n_layers * active * per_expert * _STORAGE_BYTES[posit]
    return dense, grouped


def bench(smoke: bool = False, posits=("off", "p8", "p16")) -> dict:
    import jax
    from repro.models import moe as MOE
    from repro.serving.engine import PagedServingEngine  # noqa: F401
    from benchmarks.serving_decode import make_workload

    if smoke:
        n_req, min_len, max_len, max_new, batch = 8, 16, 64, 8, 4
        page_size, chunk = 16, 32
    else:
        n_req, min_len, max_len, max_new, batch = 16, 32, 256, 24, 8
        page_size, chunk = 32, 64

    rows = []
    for posit in posits:
        legs = {}
        cfg = None
        for leg in ("dense", "grouped"):
            params, cfg = _model(posit, leg)
            reqs = make_workload(n_req, min_len, max_len, max_new, max_new,
                                 cfg.vocab)
            table_width = -(-(max_len + max_new) // page_size)
            n_tok = sum(m for _, m in reqs)
            prev = (MOE.FORCE_DENSE, MOE.FORCE_GROUPED, MOE.moe_block)
            try:
                MOE.FORCE_DENSE = leg == "dense"
                MOE.FORCE_GROUPED = leg == "grouped"
                if leg == "dense":
                    # the baseline is the *pre-PR* GShard serving path,
                    # which dropped with the config's capacity_factor —
                    # serving now passes None (no drops), which would hand
                    # the dense leg gs-wide capacity slots and ~6x the
                    # dispatch-einsum work the replaced path actually did
                    orig = prev[2]

                    def capped(x, p, **kw):
                        if kw.get("capacity_factor") is None:
                            kw["capacity_factor"] = cfg.moe.capacity_factor
                        return orig(x, p, **kw)

                    MOE.moe_block = capped
                # warmup compiles every bucket width; then interleaved
                # best-of-2 (shared-machine timing noise)
                _drain(params, cfg, reqs, batch, page_size, table_width,
                       chunk)
                t = min(_drain(params, cfg, reqs, batch, page_size,
                               table_width, chunk) for _ in range(2))
            finally:
                MOE.FORCE_DENSE, MOE.FORCE_GROUPED, MOE.moe_block = prev
            legs[leg] = {"tok_s": round(n_tok / t, 2)}
        # both legs share identical shape fields; reuse the last leg's cfg
        dense_b1, grouped_b1 = _weight_bytes_per_step(cfg, 1, posit)
        dense_bB, grouped_bB = _weight_bytes_per_step(cfg, batch, posit)
        moe = cfg.moe
        rows.append({
            "posit": posit,
            "dense": legs["dense"], "grouped": legs["grouped"],
            "tok_s_ratio_measured": round(
                legs["grouped"]["tok_s"] / legs["dense"]["tok_s"], 3),
            "weight_bytes_step_dense_f32": dense_b1,
            "weight_bytes_step_grouped_b1": grouped_b1,
            "weight_bytes_step_grouped_bB": grouped_bB,
            "bytes_ratio_modeled_b1": round(grouped_b1 / dense_b1, 4),
            "bytes_ratio_modeled_bB": round(grouped_bB / dense_bB, 4),
            "top_k_over_E": round(moe.top_k / moe.n_experts, 4),
        })
    import jax as _jax
    res = {"smoke": smoke, "backend": _jax.default_backend(),
           "arch": "olmoe-1b-7b-smoke", "batch": batch,
           "n_req": n_req, "prompt_lens": [min_len, max_len],
           "max_new": max_new,
           "note": ("legs only diverge into the grouped Pallas kernel on "
                    "TPU; on cpu both execute jnp (grouped = sort routing "
                    "+ dense reference matmul) and the modeled "
                    "weight-bytes columns carry the signal"),
           "rows": rows}
    os.makedirs(os.path.dirname(RESULTS_PATH), exist_ok=True)
    with open(RESULTS_PATH, "w") as f:
        json.dump(res, f, indent=1)
    print(f"wrote {os.path.normpath(RESULTS_PATH)}")
    return res


def run(report):
    """benchmarks.run entry point."""
    t0 = time.time()
    res = bench(smoke=True)
    report("moe_throughput", (time.time() - t0) * 1e6, res)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    print(json.dumps(bench(smoke=args.smoke), indent=1))


if __name__ == "__main__":
    main()
