"""Roofline table assembler: reads experiments/dryrun/*.json (produced by
launch/dryrun.py) and emits the EXPERIMENTS.md §Roofline table.

Per (arch x shape) single-pod cell:
  compute/memory/collective terms (s), dominant bottleneck,
  MODEL_FLOPS (6ND / 6 N_active D) vs HLO FLOPs ratio, fit-in-HBM check.
"""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")
HBM_BYTES = 16e9   # v5e per chip


def load_cells(mesh: str = "pod"):
    rows = []
    for fn in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        rec = json.load(open(fn))
        if rec.get("mesh") != mesh or rec.get("posit") is False:
            continue
        rows.append(rec)
    return rows


def table(mesh: str = "pod"):
    rows = []
    for rec in load_cells(mesh):
        row = {"arch": rec["arch"], "shape": rec["shape"],
               "status": rec["status"]}
        if rec["status"] == "skip":
            row["note"] = rec.get("reason")
        elif rec["status"] == "ok":
            row.update({
                "strategy": rec.get("strategy"),
                "t_compute_s": rec.get("t_compute_s"),
                "t_memory_s": rec.get("t_memory_s"),
                "t_collective_s": rec.get("t_collective_s"),
                "bottleneck": rec.get("bottleneck"),
                "hbm_per_dev_gb": round(
                    (rec.get("mem_argument_size_in_bytes", 0)
                     + rec.get("mem_temp_size_in_bytes", 0)) / 1e9, 2),
                "fits_hbm": (rec.get("mem_argument_size_in_bytes", 0)
                             + rec.get("mem_temp_size_in_bytes", 0)) < HBM_BYTES,
            })
            mf = rec.get("model_flops_analytic")
            hf = rec.get("flops_per_device")
            nd = rec.get("n_devices", 256)
            if mf and hf:
                row["model_hlo_flops_ratio"] = round(mf / nd / hf, 3)
                # roofline fraction: useful-FLOPs time over the dominant term
                t_dom = max(rec.get("t_compute_s", 0),
                            rec.get("t_memory_s", 0),
                            rec.get("t_collective_s", 0))
                from repro.launch.analysis import PEAK_FLOPS_BF16
                t_useful = mf / nd / PEAK_FLOPS_BF16
                row["roofline_fraction"] = round(t_useful / t_dom, 4) if t_dom else None
        else:
            row["note"] = rec.get("error", "")[:160]
        rows.append(row)
    return rows


def markdown(mesh: str = "pod") -> str:
    rows = table(mesh)
    hdr = ("| arch | shape | strat | t_comp | t_mem | t_coll | bottleneck | "
           "HBM/dev GB | MODEL/HLO | roofline frac | note |")
    sep = "|" + "---|" * 11
    lines = [hdr, sep]
    for r in rows:
        if r["status"] == "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r.get('strategy','')} | "
                f"{r['t_compute_s']:.3g} | {r['t_memory_s']:.3g} | "
                f"{r['t_collective_s']:.3g} | {r['bottleneck']} | "
                f"{r['hbm_per_dev_gb']} | "
                f"{r.get('model_hlo_flops_ratio','')} | "
                f"{r.get('roofline_fraction','')} | |")
        else:
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | "
                         f"{r['status']} | - | - | - | {r.get('note','')} |")
    return "\n".join(lines)


def run(report):
    import time
    t0 = time.time()
    rows = table("pod")
    ok = sum(1 for r in rows if r["status"] == "ok")
    skip = sum(1 for r in rows if r["status"] == "skip")
    fail = len(rows) - ok - skip
    report("roofline_table", (time.time() - t0) * 1e6,
           {"cells_ok": ok, "cells_skip": skip, "cells_fail": fail})
