"""Benchmark driver — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only <name>]

Prints ``name,us_per_call,derived`` CSV rows and dumps the full structured
results to experiments/bench_results.json.

Modules <-> paper artifacts:
    division_accuracy    Table II  (+ eq. 12-13 constants re-derivation)
    linear_algebra_error Table IV
    dnn_accuracy         Fig. 7/8 (synthetic-data proxy; see module docstring)
    throughput           Table V / §VIII-A (TPU-transferable parts)
    roofline             EXPERIMENTS.md §Roofline assembler (from dry-run)
    api_overhead         pnp/PositArray dispatch vs raw functional calls
                         (beyond-paper; must be ~1.0x after jit tracing)
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

RESULTS = {}


def _report(name: str, us_per_call: float, derived):
    RESULTS[name] = derived
    compact = json.dumps(derived, default=str)
    if len(compact) > 160:
        compact = compact[:157] + "..."
    print(f"{name},{us_per_call:.1f},{compact}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (api_overhead, division_accuracy, dnn_accuracy,
                            linear_algebra_error, roofline, throughput)
    modules = {
        "division_accuracy": division_accuracy,
        "linear_algebra_error": linear_algebra_error,
        "dnn_accuracy": dnn_accuracy,
        "throughput": throughput,
        "roofline": roofline,
        "api_overhead": api_overhead,
    }
    if args.only:
        modules = {args.only: modules[args.only]}

    print("name,us_per_call,derived")
    for name, mod in modules.items():
        try:
            mod.run(_report)
        except Exception as e:  # keep the suite running; record the failure
            _report(name + "_ERROR", 0.0, f"{type(e).__name__}: {e}")

    out = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "bench_results.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(RESULTS, f, indent=1, default=str)
    print(f"# full results -> {out}")


if __name__ == "__main__":
    main()
