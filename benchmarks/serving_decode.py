"""Paged continuous-batching vs dense synchronized serving throughput.

The serving claim of the paper's C4/C6 (posit KV halves/quarters HBM bytes)
only turns into tokens/sec if the engine keeps slots busy: the dense engine
pads every prompt in a batch to the batch max and holds every slot until
the whole batch drains, so mixed-length traffic wastes most of its FLOPs on
padding.  The paged engine (serving.engine.PagedServingEngine) chunk-
prefills each prompt at its true length, buckets the page-table width to
the active maximum, and backfills freed slots immediately.

Workload: `n_req` requests, prompt lengths log-uniform in [min_len,
max_len], fixed max_new, greedy sampling, identical model/PTQ weights for
both engines.  Reported: end-to-end generated tokens/sec (excluding
compile, via a warmup pass) and the paged/dense speedup.

    PYTHONPATH=src python -m benchmarks.serving_decode [--smoke]

Writes experiments/BENCH_serving.json (the nightly CI artifact tracking
the perf trajectory PR-over-PR).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "experiments",
                            "BENCH_serving.json")
SHARDED_RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..",
                                    "experiments",
                                    "BENCH_serving_sharded.json")
PREFILL_RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..",
                                    "experiments", "BENCH_prefill.json")
ROBUSTNESS_RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..",
                                       "experiments",
                                       "BENCH_robustness.json")


def make_workload(n_req: int, min_len: int, max_len: int, min_new: int,
                  max_new: int, vocab: int, seed: int = 0):
    """Mixed traffic: prompt lengths log-uniform in [min_len, max_len] AND
    per-request output budgets uniform in [min_new, max_new] — real requests
    finish at different times, which is the load continuous batching
    exists for (a synchronized batch decodes until its slowest request)."""
    import numpy as np
    rng = np.random.default_rng(seed)
    lo, hi = np.log(min_len), np.log(max_len)
    reqs = []
    for i in range(n_req):
        plen = int(round(np.exp(rng.uniform(lo, hi))))
        plen = max(min_len, min(max_len, plen))
        new = int(rng.integers(min_new, max_new + 1))
        reqs.append((rng.integers(0, vocab, plen).astype(np.int32), new))
    return reqs


def _bench_model(d_model=64, n_layers=2, vocab=256, posit="p16"):
    import jax
    from repro.core.types import P8_2, P16_2
    from repro.models.transformer import ModelConfig, init_params
    from repro.quant.policy import PositPolicy
    pcfg = {"p8": P8_2, "p16": P16_2, "off": None}[posit]
    cfg = ModelConfig(name=f"bench-serve-{posit}", n_layers=n_layers,
                      d_model=d_model, n_heads=4, n_kv=2, d_ff=2 * d_model,
                      vocab=vocab, policy=PositPolicy(kv_cache=pcfg))
    params = init_params(jax.random.PRNGKey(0), cfg)
    return params, cfg


def run_dense(params, cfg, reqs, batch: int, max_new: int, cap: int,
              snug: bool = False) -> float:
    """The synchronized dense engine, two flavors:

    snug=False: fixed rectangular [batch, cap] prompts and a max-capacity
        KV buffer (a dense cache is sized for the longest request before
        lengths are known; one compiled step for the whole run) — the
        deployed dense engine.
    snug=True: pad each FIFO batch only to *its* max prompt and size the
        cache to match (one retrace per distinct batch shape) — a stronger
        baseline that gives the dense engine per-batch length knowledge.

    Prompts are left-padded so the last position is real.  Returns seconds.
    """
    import numpy as np
    import jax.numpy as jnp
    from repro.serving.engine import generate
    t0 = time.time()
    for lo in range(0, len(reqs), batch):
        chunk = reqs[lo:lo + batch]
        width = max(len(p) for p, _ in chunk) if snug else cap
        # synchronized batch: every slot decodes until the batch's slowest
        # request is done (per-request budgets can't stop a dense batch)
        new = max(m for _, m in chunk)
        toks = np.zeros((batch, width), np.int32)
        for i, (p, _) in enumerate(chunk):
            toks[i, width - len(p):] = p
        out = generate(params, cfg, jnp.asarray(toks), new,
                       max_len=width + max_new)
        out.block_until_ready()
    return time.time() - t0


def run_paged(params, cfg, reqs, batch: int, page_size: int,
              table_width: int, prefill_chunk: int) -> float:
    from repro.serving.engine import PagedServingEngine
    eng = PagedServingEngine(params, cfg, max_seqs=batch,
                             page_size=page_size, table_width=table_width,
                             prefill_chunk=prefill_chunk)
    t0 = time.time()
    eng.run(list(reqs))
    return time.time() - t0


def bench(smoke: bool = False, posit: str = "p16",
          uniform_new: bool = False) -> dict:
    """One workload measurement.  uniform_new=True fixes every request's
    output budget (the ISSUE-2 acceptance row: only *prompt lengths* are
    mixed); False also mixes per-request budgets, which lets the
    synchronized baselines finish early batches and is the harder
    comparison."""
    if smoke:
        n_req, min_len, max_len, batch = 12, 64, 512, 8
        min_new, max_new = (12, 12) if uniform_new else (4, 16)
        page_size, prefill_chunk = 32, 128
    else:
        n_req, min_len, max_len, batch = 24, 128, 4096, 8
        min_new, max_new = (32, 32) if uniform_new else (8, 64)
        page_size, prefill_chunk = 64, 512
    params, cfg = _bench_model(posit=posit)
    reqs = make_workload(n_req, min_len, max_len, min_new, max_new,
                         cfg.vocab)
    table_width = -(-(max_len + max_new) // page_size)
    # tokens/sec counts *requested* tokens only: the synchronized engines
    # keep decoding finished slots until the batch's slowest request, and
    # that overhang is precisely the waste continuous batching removes
    n_tok = sum(m for _, m in reqs)

    # warmup with the full workload (hits every page-table bucket width and
    # snug batch shape the measured run will compile; the jitted steps are
    # shared per-config so the measured runs are pure steady state)
    run_dense(params, cfg, reqs, batch, max_new, max_len)
    run_dense(params, cfg, reqs, batch, max_new, max_len, snug=True)
    run_paged(params, cfg, reqs, batch, page_size, table_width,
              prefill_chunk)
    # interleaved best-of-N: shared-machine timing noise swings individual
    # runs by 2x, so alternate engines and keep each engine's best run
    t_dense = t_snug = t_paged = float("inf")
    for _ in range(2):
        t_dense = min(t_dense,
                      run_dense(params, cfg, reqs, batch, max_new, max_len))
        t_snug = min(t_snug,
                     run_dense(params, cfg, reqs, batch, max_new, max_len,
                               snug=True))
        t_paged = min(t_paged,
                      run_paged(params, cfg, reqs, batch, page_size,
                                table_width, prefill_chunk))
    return {
        "smoke": smoke, "posit": posit, "n_req": n_req,
        "prompt_lens": [min_len, max_len], "max_new": [min_new, max_new],
        "batch": batch, "page_size": page_size,
        "dense_tok_s": round(n_tok / t_dense, 2),
        "dense_snug_tok_s": round(n_tok / t_snug, 2),
        "paged_tok_s": round(n_tok / t_paged, 2),
        # headline: paged vs the *stronger* dense baseline
        "speedup": round(min(t_dense, t_snug) / t_paged, 3),
        "speedup_vs_fixed": round(t_dense / t_paged, 3),
    }


def bench_all(smoke: bool = False, posit: str = "p16") -> dict:
    """Both workload rows: uniform output budgets (the acceptance row —
    only prompt lengths mixed) and mixed budgets (the harder row)."""
    return {
        "uniform_new": bench(smoke=smoke, posit=posit, uniform_new=True),
        "mixed_new": bench(smoke=smoke, posit=posit, uniform_new=False),
    }


# --------------------------------------------------------------------------
# recurrent / hybrid serving lane (posit state pool vs paged KV)
# --------------------------------------------------------------------------
RECURRENT_ARCHS = ("rwkv6-3b", "recurrentgemma-9b")


def bench_recurrent(smoke: bool = True, posit: str = "p16") -> dict:
    """State-pool serving rows: paged-engine tok/s for the recurrent and
    hybrid archs vs a same-width full-attention comparator (identical stack
    with block_pattern=("attn",) — what serving these models cost before
    the state-pool backend), plus analytic per-seq cache bytes at
    4k/16k/64k contexts from the backends' memory descriptors.  The bytes
    columns are the headline: state slots are O(1) in context and windowed
    KV is O(window), vs the comparator's O(context) pool."""
    import dataclasses as dc
    import jax
    from repro import configs
    from repro.core.types import P8_2, P16_2
    from repro.models.transformer import init_params
    from repro.quant.policy import PositPolicy
    from repro.serving.backends import layout_for
    pcfg = {"p8": P8_2, "p16": P16_2, "off": None}[posit]
    policy = PositPolicy(kv_cache=pcfg)
    if smoke:
        n_req, min_len, max_len, batch = 8, 16, 96, 4
        page_size, prefill_chunk, max_new = 16, 32, 8
    else:
        n_req, min_len, max_len, batch = 16, 64, 512, 8
        page_size, prefill_chunk, max_new = 32, 128, 16
    table_width = -(-(max_len + max_new) // page_size)
    rows = []
    for arch in RECURRENT_ARCHS:
        cfg = configs.get_smoke(arch, policy=policy)
        cfg = dc.replace(cfg, name=f"{cfg.name}-bench-{posit}")
        comp = dc.replace(cfg, block_pattern=("attn",), window=None,
                          name=f"{cfg.name}-attn")
        reqs = make_workload(n_req, min_len, max_len, max_new, max_new,
                             cfg.vocab, seed=3)
        n_tok = sum(m for _, m in reqs)
        times = {}
        for key, c in (("state_pool", cfg), ("full_attn", comp)):
            params = init_params(jax.random.PRNGKey(0), c)
            run_paged(params, c, reqs, batch, page_size, table_width,
                      prefill_chunk)            # warmup: compile every bucket
            times[key] = min(run_paged(params, c, reqs, batch, page_size,
                                       table_width, prefill_chunk)
                             for _ in range(2))
        # memory columns use the *full-size* configs: the smoke stack is
        # too small for the O(1)-vs-O(context) gap to register
        full = configs.get_config(arch, policy=policy)
        comp_full = dc.replace(full, block_pattern=("attn",), window=None)
        mem = {
            str(ctx): {
                "bytes_per_seq": layout_for(full).cache_bytes_per_seq(
                    ctx, 64),
                "full_attn_bytes_per_seq":
                    layout_for(comp_full).cache_bytes_per_seq(ctx, 64),
            } for ctx in (4096, 16384, 65536)}
        rows.append({
            "arch": arch, "posit": posit,
            "tok_s": round(n_tok / times["state_pool"], 2),
            "full_attn_tok_s": round(n_tok / times["full_attn"], 2),
            "cache_bytes_per_seq_full_model": mem,
        })
        print(f"[recurrent] {arch}: {rows[-1]['tok_s']} tok/s "
              f"(full-attn comparator {rows[-1]['full_attn_tok_s']})")
    return {"smoke": smoke, "posit": posit, "n_req": n_req,
            "prompt_lens": [min_len, max_len], "rows": rows}


# --------------------------------------------------------------------------
# prefill / time-to-first-token lane (the fused paged prefill kernel vs the
# gather_kv dense-materialization baseline it replaced)
# --------------------------------------------------------------------------
_STORAGE_BYTES = {"off": 4, "p8": 1, "p16": 2}


def run_prefill_ttft(params, cfg, reqs, batch, page_size, table_width,
                     chunk):
    """Drain a max_new=1 workload, recording per-request TTFT (submit-all ->
    first sampled token) and the prefill token rate.  With n_req == batch
    every request prefills from step zero, so the drain is a pure prefill
    measurement."""
    import numpy as np
    from repro.serving.engine import PagedServingEngine
    eng = PagedServingEngine(params, cfg, max_seqs=batch,
                             page_size=page_size, table_width=table_width,
                             prefill_chunk=chunk, admit_threshold=0)
    for p, m in reqs:
        eng.submit(p, m)
    ttft = {}
    t0 = time.time()
    while eng.waiting or eng.active:
        pairs = eng.step()
        now = time.time()
        for rid, _ in pairs:
            ttft.setdefault(rid, now - t0)
    total = time.time() - t0
    lens = sorted(ttft.values())
    n_prompt_tok = sum(len(p) for p, _ in reqs)
    return {
        "ttft_mean_s": round(float(np.mean(lens)), 4),
        "ttft_p50_s": round(lens[len(lens) // 2], 4),
        "ttft_p95_s": round(lens[min(len(lens) - 1,
                                     int(0.95 * len(lens)))], 4),
        "prefill_tok_s": round(n_prompt_tok / total, 1),
    }


def _drain_ttft(eng, reqs):
    """Submit `reqs` and drain, returning (mean TTFT seconds, stats dict).
    Stats are reset first so each drain reports only its own counters."""
    import numpy as np
    eng.reset_stats()
    for p, m in reqs:
        eng.submit(p, m)
    ttft = {}
    t0 = time.time()
    while eng.waiting or eng.active:
        pairs = eng.step()
        now = time.time()
        for rid, _ in pairs:
            ttft.setdefault(rid, now - t0)
    return float(np.mean(list(ttft.values()))), eng.stats()


def bench_prefix(smoke: bool = False, posits=("off", "p8", "p16")) -> list:
    """Shared-prefix warm-vs-cold TTFT rows (the prefix-cache lane of
    BENCH_prefill.json).

    Workload: every request is one long common prefix plus a short unique
    suffix, max_new=1 — the system-prompt shape prefix caching targets.
    Three drains per posit format: cold (empty cache), warm (same prompts
    again: admission shares the cached prefix pages and prefill restarts at
    the first uncached token), and disjoint (fresh prompts against the warm
    cache: the chained digests must never false-share, hit rate exactly 0).
    cache_hit_rate = prefix_hit_tokens / submitted prompt tokens.  The
    disjoint drain also exercises LRU eviction under pool pressure: the
    warm cache's pages must be evicted (never preempting) to fit it."""
    import jax
    import numpy as np
    from repro.models.transformer import ModelConfig, init_params
    from repro.quant.policy import PositPolicy
    from repro.core.types import P8_2, P16_2
    from repro.serving.engine import PagedServingEngine
    if smoke:
        n_req = batch = 4
        prefix_len, suffix_len, page_size, chunk = 448, 32, 32, 128
    else:
        n_req = batch = 8
        prefix_len, suffix_len, page_size, chunk = 3584, 64, 64, 512
    plen = prefix_len + suffix_len
    table_width = -(-(plen + 1) // page_size)
    rows = []
    for posit in posits:
        pcfg = {"p8": P8_2, "p16": P16_2, "off": None}[posit]
        cfg = ModelConfig(name=f"bench-prefix-{posit}", n_layers=2,
                          d_model=64, n_heads=4, n_kv=2, d_ff=128,
                          vocab=256, policy=PositPolicy(kv_cache=pcfg))
        params = init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(0)
        prefix = rng.integers(0, cfg.vocab, prefix_len).astype(np.int32)
        shared = [(np.concatenate(
            [prefix, rng.integers(0, cfg.vocab, suffix_len).astype(np.int32)]
        ), 1) for _ in range(n_req)]
        disjoint = [(rng.integers(0, cfg.vocab, plen).astype(np.int32), 1)
                    for _ in range(n_req)]

        def mk():
            return PagedServingEngine(
                params, cfg, max_seqs=batch, page_size=page_size,
                table_width=table_width, prefill_chunk=chunk,
                admit_threshold=0)

        # warmup compiles both paths: the cold drain's chunk steps and the
        # warm drain's COW page-copy fn + full-width bucket
        weng = mk()
        _drain_ttft(weng, [(p.copy(), m) for p, m in shared])
        _drain_ttft(weng, [(p.copy(), m) for p, m in shared])
        # measured: cold once per fresh engine (best-of-2 engines), then
        # warm best-of-2 on the populated cache
        cold = min(_drain_ttft(mk(), [(p.copy(), m) for p, m in shared])[0]
                   for _ in range(2))
        eng = mk()
        _drain_ttft(eng, [(p.copy(), m) for p, m in shared])
        warm, st = _drain_ttft(eng, [(p.copy(), m) for p, m in shared])
        w2, _ = _drain_ttft(eng, [(p.copy(), m) for p, m in shared])
        warm = min(warm, w2)
        deng = mk()
        _drain_ttft(deng, [(p.copy(), m) for p, m in shared])
        dis, st_dis = _drain_ttft(deng, [(p.copy(), m) for p, m in disjoint])
        n_prompt = n_req * plen
        row = {
            "posit": posit, "prompt_len": plen, "prefix_len": prefix_len,
            "ttft_cold_s": round(cold, 4), "ttft_warm_s": round(warm, 4),
            "warm_speedup": round(cold / warm, 3),
            "cache_hit_rate": round(st["prefix_hit_tokens"] / n_prompt, 4),
            "disjoint_hit_rate": round(
                st_dis["prefix_hit_tokens"] / n_prompt, 4),
            "disjoint_evicted_pages": st_dis["evicted_pages"],
            "disjoint_preempted": st_dis["preempted"],
            "warm_stats": {k: st[k] for k in
                           ("prefix_hits", "prefix_misses",
                            "prefix_hit_tokens", "cow_copies",
                            "deduped_pages", "evicted_pages", "preempted",
                            "prefill_steps", "gather_fallbacks")},
        }
        print(f"[prefix] {posit}: cold={row['ttft_cold_s']}s "
              f"warm={row['ttft_warm_s']}s "
              f"speedup={row['warm_speedup']}x "
              f"hit_rate={row['cache_hit_rate']} "
              f"disjoint_hit_rate={row['disjoint_hit_rate']} "
              f"stats={st}")
        rows.append(row)
    return rows


def bench_prefill(smoke: bool = False, posits=("off", "p8", "p16"),
                  chunks=(128, 512)) -> dict:
    """TTFT + prefill tok/s for the fused-kernel route vs the forced
    gather_kv baseline (REPRO_FORCE_GATHER), float/p8/p16 pages, chunk
    sizes 128/512 — the nightly BENCH_prefill.json artifact.

    On TPU the two legs really diverge (fused paged_flash_prefill vs dense
    materialization); on the CPU jnp backend both legs execute the gather
    reference, so the measured ratio is ~1.0 and the modeled roofline ratio
    carries the signal: the fallback's dense f32 view costs an extra
    write+read of 4 bytes/elem on top of the posit pool read, so KV traffic
    is (w + 8) / w per element (w = storage width) — 5x at posit16, 9x at
    posit8, 3x float — of which the paper-level headline (f32 view read vs
    posit pool read) is 4/w: the 2x posit16 reduction the acceptance
    criterion quotes.
    """
    import jax
    from repro.models.transformer import ModelConfig, init_params
    from repro.quant.policy import PositPolicy
    from repro.core.types import P8_2, P16_2
    if smoke:
        n_req = batch = 4
        min_len, max_len, page_size = 64, 512, 32
        chunks = tuple(c for c in chunks if c <= 128) or (128,)
    else:
        n_req = batch = 8
        min_len, max_len, page_size = 128, 4096, 64
    rows = []
    for posit in posits:
        pcfg = {"p8": P8_2, "p16": P16_2, "off": None}[posit]
        for chunk in chunks:
            legs = {}
            for leg in ("fused", "gather"):
                # distinct cfg names: the per-config jitted step caches a
                # trace per name, and the two legs trace different paths
                cfg = ModelConfig(
                    name=f"bench-prefill-{posit}-{chunk}-{leg}",
                    n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
                    vocab=256, policy=PositPolicy(kv_cache=pcfg))
                params = init_params(jax.random.PRNGKey(0), cfg)
                reqs = make_workload(n_req, min_len, max_len, 1, 1,
                                     cfg.vocab)
                table_width = -(-(max_len + 1) // page_size)
                prev = os.environ.get("REPRO_FORCE_GATHER")
                try:
                    if leg == "gather":
                        os.environ["REPRO_FORCE_GATHER"] = "1"
                    # warmup compiles every bucket width, then best-of-2
                    run_prefill_ttft(params, cfg, reqs, batch, page_size,
                                     table_width, chunk)
                    best = min(
                        (run_prefill_ttft(params, cfg, reqs, batch,
                                          page_size, table_width, chunk)
                         for _ in range(2)),
                        key=lambda r: r["ttft_mean_s"])
                finally:
                    if prev is None:
                        os.environ.pop("REPRO_FORCE_GATHER", None)
                    else:
                        os.environ["REPRO_FORCE_GATHER"] = prev
                legs[leg] = best
            w = _STORAGE_BYTES[posit]
            rows.append({
                "posit": posit, "chunk": chunk,
                "fused": legs["fused"], "gather": legs["gather"],
                "ttft_speedup_measured": round(
                    legs["gather"]["ttft_mean_s"]
                    / legs["fused"]["ttft_mean_s"], 3),
                "kv_traffic_ratio_modeled": round((w + 8) / w, 2),
                "f32_view_vs_pool_read_modeled": round(4 / w, 2),
            })
    res = {"smoke": smoke, "backend": jax.default_backend(),
           "n_req": n_req, "prompt_lens": [min_len, max_len],
           "note": ("fused vs gather legs only diverge on the Pallas "
                    "backend; on cpu both execute the gather reference and "
                    "the modeled roofline columns carry the signal"),
           "rows": rows,
           "prefix_rows": bench_prefix(smoke=smoke, posits=posits)}
    os.makedirs(os.path.dirname(PREFILL_RESULTS_PATH), exist_ok=True)
    with open(PREFILL_RESULTS_PATH, "w") as f:
        json.dump(res, f, indent=1)
    print(f"wrote {os.path.normpath(PREFILL_RESULTS_PATH)}")
    return res


# --------------------------------------------------------------------------
# robustness / chaos lane: graceful degradation under injected faults
# --------------------------------------------------------------------------
def _drain_timed(eng, reqs) -> dict:
    """Submit `reqs` ((prompt, max_new, ttl_steps) triples) up front
    (2x-oversubscribed load: the queue is the point), drain, and record
    per-request completion latency (submit-all -> structured outcome).
    Returns the row the chaos bench reports."""
    eng.reset_stats()
    rids = [eng.submit(p, m, ttl_steps=ttl) for p, m, ttl in reqs]
    done_t = {r: 0.0 for r in rids if r in eng.outcomes}  # insta-rejects
    t0 = time.time()
    while eng.waiting or eng.active:
        eng.step()
        now = time.time()
        for rid in rids:
            if rid not in done_t and rid in eng.outcomes:
                done_t[rid] = now - t0
    total = time.time() - t0
    s = eng.stats()
    n_gen = sum(len(eng.outcomes[r].tokens) for r in rids)
    lat = sorted(done_t[r] for r in rids
                 if eng.outcomes[r].status == "completed")
    p99 = lat[min(len(lat) - 1, int(0.99 * len(lat)))] if lat else None
    return {
        "tok_s": round(n_gen / total, 2),
        "rejection_rate": round(s["rejected"] / max(s["submitted"], 1), 4),
        "completion_p50_s": (round(lat[len(lat) // 2], 4) if lat else None),
        "completion_p99_s": (round(p99, 4) if p99 is not None else None),
        "outcomes": {k: s[k] for k in
                     ("completed", "rejected", "expired", "failed_nar",
                      "failed_fault")},
        "degradation": {k: s[k] for k in
                        ("step_retries", "slots_quarantined",
                         "scrubbed_pages", "straggler_steps")},
        "injected": {k: s[k] for k in
                     ("injected_step_faults", "injected_nar_poisons",
                      "injected_page_poisons")},
    }


def bench_chaos(smoke: bool = False, posit: str = "p16") -> dict:
    """Serving under fault injection vs the fault-free baseline at the
    same 2x-oversubscribed load — the BENCH_robustness.json artifact.

    Both rows submit every request up front (twice the engine's slot
    count, bounded wait queue, per-request TTLs on a third of the
    traffic), so queueing latency is part of p99 by construction.  The
    chaos row layers the full seeded fault menu (serving/faults.py) on
    top: device step failures, NaR-poisoned activations, bit-flipped KV
    pages, stragglers.  The contract being measured: the drain terminates
    with every submission resolved to a structured outcome (the pre-ISSUE-9
    engine crashed the whole process instead), throughput degrades
    proportionally to the injected fault mass, and the rejection rate
    stays a queue-depth property rather than a failure mode."""
    from repro.serving.engine import PagedServingEngine
    from repro.serving.faults import ChaosConfig
    if smoke:
        n_req, batch, min_len, max_len = 8, 4, 16, 96
        min_new, max_new, page_size, prefill_chunk = 6, 10, 16, 32
    else:
        n_req, batch, min_len, max_len = 16, 8, 64, 512
        min_new, max_new, page_size, prefill_chunk = 8, 24, 32, 128
    params, cfg = _bench_model(posit=posit)
    reqs = make_workload(n_req, min_len, max_len, min_new, max_new,
                         cfg.vocab, seed=11)
    table_width = -(-(max_len + max_new) // page_size)
    chaos = ChaosConfig(seed=5, p_step_fault=0.02, p_nar_poison=0.02,
                        p_page_poison=0.03, p_straggle=0.1,
                        straggle_s=0.001, max_injections=6)

    def mk(inject):
        return PagedServingEngine(
            params, cfg, max_seqs=batch, page_size=page_size,
            table_width=table_width, prefill_chunk=prefill_chunk,
            max_waiting=2 * n_req, chaos=chaos if inject else None)

    def load():
        # every fourth request carries a TTL ~ the expected drain depth,
        # so expiry competes with completion exactly as in production
        ttl = 4 * (max_new + 2)
        return [(p.copy(), m, ttl if j % 4 == 3 else None)
                for j, (p, m) in enumerate(reqs)]

    def run_row(inject):
        eng = mk(inject)
        _drain_timed(eng, load())               # warmup: compile buckets
        eng2 = mk(inject)
        return _drain_timed(eng2, load())

    rows = {"baseline": run_row(False), "chaos": run_row(True)}
    res = {"smoke": smoke, "posit": posit, "n_req": n_req, "slots": batch,
           "oversubscription": round(n_req / batch, 1),
           "prompt_lens": [min_len, max_len],
           "chaos_config": {
               "p_step_fault": chaos.p_step_fault,
               "p_nar_poison": chaos.p_nar_poison,
               "p_page_poison": chaos.p_page_poison,
               "p_straggle": chaos.p_straggle,
               "max_injections": chaos.max_injections},
           "rows": rows}
    os.makedirs(os.path.dirname(ROBUSTNESS_RESULTS_PATH), exist_ok=True)
    with open(ROBUSTNESS_RESULTS_PATH, "w") as f:
        json.dump(res, f, indent=1)
    print(f"wrote {os.path.normpath(ROBUSTNESS_RESULTS_PATH)}")
    return res


# --------------------------------------------------------------------------
# sharded serving: tok/s vs device count (each count in its own subprocess —
# jax locks the host device count at first backend init)
# --------------------------------------------------------------------------
def _sharded_worker(devices: int, smoke: bool, posit: str) -> dict:
    """Runs inside a subprocess whose XLA_FLAGS already forced `devices`
    CPU host devices: one paged-engine drain on a (devices, 1) data-
    parallel mesh (TP over CPU psums is pure overhead; the DP axis is the
    throughput story), warmup pass excluded."""
    from repro.launch.mesh import make_serving_mesh
    params, cfg = _bench_model(posit=posit)
    if smoke:
        n_req, min_len, max_len, batch = 16, 64, 512, 8
        page_size, prefill_chunk, max_new = 32, 128, 12
    else:
        n_req, min_len, max_len, batch = 32, 128, 4096, 8
        page_size, prefill_chunk, max_new = 64, 512, 32
    reqs = make_workload(n_req, min_len, max_len, max_new, max_new,
                         cfg.vocab)
    table_width = -(-(max_len + max_new) // page_size)
    mesh = make_serving_mesh(devices, 1) if devices > 1 else None
    n_tok = sum(m for _, m in reqs)
    # warmup (compiles every bucket width), then interleaved best-of-2
    run_paged_mesh(params, cfg, reqs, batch, page_size, table_width,
                   prefill_chunk, mesh)
    t = min(run_paged_mesh(params, cfg, reqs, batch, page_size, table_width,
                           prefill_chunk, mesh) for _ in range(2))
    return {"devices": devices, "tok_s": round(n_tok / t, 2)}


def run_paged_mesh(params, cfg, reqs, batch, page_size, table_width,
                   prefill_chunk, mesh) -> float:
    from repro.serving.engine import PagedServingEngine
    eng = PagedServingEngine(params, cfg, max_seqs=batch,
                             page_size=page_size, table_width=table_width,
                             prefill_chunk=prefill_chunk, mesh=mesh)
    t0 = time.time()
    eng.run(list(reqs))
    return time.time() - t0


def bench_sharded(smoke: bool = False, posit: str = "p16",
                  device_counts=(1, 2, 4, 8)) -> dict:
    """tok/s vs device count for the mesh-sharded paged engine (the CI
    nightly artifact BENCH_serving_sharded.json).  On CPU the DP shards
    share physical cores, so this tracks scheduler/collective overhead
    rather than real speedup — the trend of interest is tok/s *not
    collapsing* as the mesh widens."""
    import subprocess
    rows = []
    for n in device_counts:
        env = dict(os.environ,
                   XLA_FLAGS=f"--xla_force_host_platform_device_count={n}",
                   PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                           "src"))
        cmd = [sys.executable, "-m", "benchmarks.serving_decode",
               "--sharded-worker", str(n), "--posit", posit]
        if smoke:
            cmd.append("--smoke")
        out = subprocess.run(cmd, env=env, capture_output=True, text=True,
                             cwd=os.path.join(os.path.dirname(__file__),
                                              ".."))
        if out.returncode != 0:
            raise RuntimeError(f"sharded worker ({n} devices) failed:\n"
                               f"{out.stderr[-2000:]}")
        rows.append(json.loads(out.stdout.strip().splitlines()[-1]))
    res = {"smoke": smoke, "posit": posit, "rows": rows}
    os.makedirs(os.path.dirname(SHARDED_RESULTS_PATH), exist_ok=True)
    with open(SHARDED_RESULTS_PATH, "w") as f:
        json.dump(res, f, indent=1)
    print(f"wrote {os.path.normpath(SHARDED_RESULTS_PATH)}")
    return res


def run(report):
    """benchmarks.run entry point."""
    t0 = time.time()
    res = bench_all(smoke=True)
    report("serving_decode", (time.time() - t0) * 1e6, res)
    _write(res)


def _write(res: dict):
    """Merge `res` into BENCH_serving.json (the dense-vs-paged rows and the
    --recurrent rows are separate CI steps writing disjoint keys)."""
    os.makedirs(os.path.dirname(RESULTS_PATH), exist_ok=True)
    merged = {}
    if os.path.exists(RESULTS_PATH):
        try:
            with open(RESULTS_PATH) as f:
                merged = json.load(f)
        except (OSError, json.JSONDecodeError):
            merged = {}
    merged.update(res)
    with open(RESULTS_PATH, "w") as f:
        json.dump(merged, f, indent=1)
    print(f"wrote {os.path.normpath(RESULTS_PATH)}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--posit", choices=["off", "p8", "p16"], default="p16")
    ap.add_argument("--sharded", action="store_true",
                    help="tok/s vs device count for the mesh-sharded "
                         "engine (subprocess per count)")
    ap.add_argument("--prefill", action="store_true",
                    help="TTFT + prefill tok/s: fused paged prefill kernel "
                         "vs the gather_kv baseline -> BENCH_prefill.json")
    ap.add_argument("--recurrent", action="store_true",
                    help="recurrent/hybrid state-pool serving vs a full-"
                         "attention comparator -> BENCH_serving.json "
                         "'recurrent' key")
    ap.add_argument("--chaos", action="store_true",
                    help="graceful degradation under seeded fault "
                         "injection at 2x-oversubscribed load vs the "
                         "fault-free baseline -> BENCH_robustness.json")
    ap.add_argument("--sharded-worker", type=int, default=None,
                    help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.sharded_worker is not None:
        print(json.dumps(_sharded_worker(args.sharded_worker, args.smoke,
                                         args.posit)))
        return
    if args.sharded:
        print(json.dumps(bench_sharded(smoke=args.smoke, posit=args.posit),
                         indent=1))
        return
    if args.prefill:
        print(json.dumps(bench_prefill(smoke=args.smoke), indent=1))
        return
    if args.chaos:
        print(json.dumps(bench_chaos(smoke=args.smoke, posit=args.posit),
                         indent=1))
        return
    if args.recurrent:
        res = bench_recurrent(smoke=args.smoke, posit=args.posit)
        print(json.dumps(res, indent=1))
        _write({"recurrent": res})
        return
    res = bench_all(smoke=args.smoke, posit=args.posit)
    print(json.dumps(res, indent=1))
    _write(res)


if __name__ == "__main__":
    main()
