"""Paper Table V + §VIII-A throughput — mapped to what a TPU target can show.

The FPGA numbers (mW, 33->132 MOps/s via SIMD) do not transfer to TPU
silicon; the transferable claims are measured instead:
  * SIMD lane scaling (C4): posit8 payloads are 4x denser than f32 — ops/s
    of the vectorized datapath on this host, p8 vs p16 vs f32 mul.
  * storage-bandwidth win (the serving roofline mover): bytes/element of
    weights+KV for each format.
  * kernel throughput of the posit GEMM dispatch path (CPU jnp; the Pallas
    kernel itself is TPU-target and validated in interpret mode by tests).
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ops as O
from repro.core.convert import f32_to_posit
from repro.core.types import P8_2, P16_2
from repro.kernels import ref as kref


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters


def elementwise_throughput(n: int = 1 << 20) -> dict:
    rng = np.random.default_rng(0)
    out = {}
    for cfg, dt in ((P8_2, jnp.int8), (P16_2, jnp.int16)):
        a = jnp.asarray(rng.integers(-100, 100, n), dt)
        b = jnp.asarray(rng.integers(-100, 100, n), dt)
        for op, fn in (("add", O.padd), ("mul", O.pmul)):
            f = jax.jit(lambda x, y, fn=fn, cfg=cfg: fn(x, y, cfg))
            dt_s = _time(f, a, b)
            out[f"{cfg}_{op}_mops"] = round(n / dt_s / 1e6, 1)
        f = jax.jit(lambda x, y, cfg=cfg: O.pdiv(x, y, cfg, mode="poly"))
        out[f"{cfg}_div_mops"] = round(n / _time(f, a, b) / 1e6, 1)
    af = jnp.asarray(rng.normal(size=n), jnp.float32)
    bf = jnp.asarray(rng.normal(size=n), jnp.float32)
    f = jax.jit(lambda x, y: x * y)
    out["f32_mul_mops"] = round(n / _time(f, af, bf) / 1e6, 1)
    return out


def gemm_throughput(m=512, k=512, n=512) -> dict:
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w16 = f32_to_posit(jnp.asarray(rng.normal(size=(k, n)), jnp.float32), P16_2)
    w8 = f32_to_posit(jnp.asarray(rng.normal(size=(k, n)), jnp.float32), P8_2)
    wf = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    flops = 2 * m * k * n
    out = {}
    f = jax.jit(lambda a, b: kref.posit_gemm_ref(a, b, cfg_a=None, cfg_b=P16_2))
    out["pw16_gemm_gflops"] = round(flops / _time(f, x, w16) / 1e9, 2)
    f = jax.jit(lambda a, b: kref.posit_gemm_ref(a, b, cfg_a=None, cfg_b=P8_2))
    out["pw8_gemm_gflops"] = round(flops / _time(f, x, w8) / 1e9, 2)
    f = jax.jit(lambda a, b: a @ b)
    out["f32_gemm_gflops"] = round(flops / _time(f, x, wf) / 1e9, 2)
    out["w16_bytes_per_elem"] = 2
    out["w8_bytes_per_elem"] = 1
    out["f32_bytes_per_elem"] = 4
    return out


def run(report):
    t0 = time.time()
    e = elementwise_throughput()
    report("elementwise_throughput", (time.time() - t0) * 1e6, e)
    t0 = time.time()
    g = gemm_throughput()
    report("gemm_throughput", (time.time() - t0) * 1e6, g)
