"""Forward+backward train-step time: Pallas training kernels vs the jnp
oracles, across posit weight formats.

Three legs per format, all through training.train_step.make_train_step:

    kernel:   REPRO_USE_PALLAS on — flash fwd/bwd, grouped MoE and
              posit GEMM custom_vjp backwards all dispatch Pallas
              (interpret mode on CPU; real kernels on TPU)
    bwd-ref:  kernels forward, REPRO_FORCE_BWD_REFERENCE pins the counted
              jnp reference backwards — isolates the backward kernels'
              contribution
    jnp:      REPRO_USE_PALLAS off — the pure-jnp einsum path end to end

On the CPU backend the kernel legs run the Pallas *interpreter*, so
absolute ratios are meaningless there (interpret mode is a correctness
tool); the jnp column is the CPU-meaningful number and the leg structure
is what the nightly TPU lane consumes.  BWD_FALLBACKS deltas are recorded
per leg — the kernel leg must report {} (the zero-fallback training
invariant, same as tier-1 asserts).

    PYTHONPATH=src python -m benchmarks.train_step [--smoke]

Writes experiments/BENCH_training.json (nightly CI artifact).

--elastic instead measures the fault-tolerance stack (nightly elastic
lane) and writes experiments/BENCH_elastic.json:

    ckpt_stall_ms:   per-checkpoint train-loop stall, sync store.save vs
                     AsyncCheckpointStore (the async number is just the
                     device->host snapshot + any backpressure block);
    kill_recovery:   a supervised 3-worker group with one worker
                     SIGKILLed mid-run — restart latency (group death ->
                     first post-restart heartbeat) and lost-work steps
                     (steps past the last checkpoint that the restarted
                     generation had to redo).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "experiments",
                            "BENCH_training.json")

_LEG_ENV = {
    "kernel": {"REPRO_USE_PALLAS": "1", "REPRO_FORCE_BWD_REFERENCE": None},
    "bwd-ref": {"REPRO_USE_PALLAS": "1", "REPRO_FORCE_BWD_REFERENCE": "1"},
    "jnp": {"REPRO_USE_PALLAS": None, "REPRO_FORCE_BWD_REFERENCE": None},
}


def _set_env(leg: str, backend: str):
    env = dict(_LEG_ENV[leg])
    if backend == "cpu" and env.get("REPRO_USE_PALLAS"):
        env["REPRO_PALLAS_INTERPRET"] = "1"
    else:
        env["REPRO_PALLAS_INTERPRET"] = None
    for k, v in env.items():
        if v is None:
            os.environ.pop(k, None)
        else:
            os.environ[k] = v


def _one_leg(posit: str, leg: str, smoke: bool, reps: int):
    import jax
    from repro.core.types import P8_2, P16_2
    from repro.kernels import ops as kops
    from repro.models.transformer import ModelConfig, init_params
    from repro.optim.adamw import OptConfig, init_state
    from repro.quant.policy import PositPolicy
    from repro.training.train_step import make_train_step

    pcfg = {"p8": P8_2, "p16": P16_2, "off": None}[posit]
    dims = (dict(n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
                 vocab=256) if smoke else
            dict(n_layers=4, d_model=256, n_heads=8, n_kv=4, d_ff=768,
                 vocab=2048))
    # distinct names per leg: each traces a different dispatch path
    cfg = ModelConfig(f"bench-train-{posit}-{leg}", **dims,
                      policy=PositPolicy(weights=pcfg))
    _set_env(leg, jax.default_backend())

    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = OptConfig(lr_peak=1e-3, warmup_steps=10, total_steps=100)
    opt = init_state(params, opt_cfg)
    step = make_train_step(cfg, opt_cfg, donate=False)
    B, S = (4, 33) if smoke else (8, 129)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                          cfg.vocab)}
    kops.BWD_FALLBACKS.clear()
    p, o, m = step(params, opt, batch)        # compile + fallback counting
    jax.block_until_ready(p)
    fallbacks = dict(kops.BWD_FALLBACKS)
    best = float("inf")
    for _ in range(reps):
        t0 = time.time()
        p, o, m = step(p, o, batch)
        jax.block_until_ready(p)
        best = min(best, time.time() - t0)
    tokens = B * (S - 1)
    return {"step_ms": round(best * 1e3, 2),
            "tok_s": round(tokens / best, 1),
            "bwd_fallbacks": {k: int(v) for k, v in fallbacks.items()}}


def bench(smoke: bool = False, posits=("off", "p8", "p16")) -> dict:
    import jax
    reps = 2 if smoke else 5
    saved = {k: os.environ.get(k) for k in
             ("REPRO_USE_PALLAS", "REPRO_PALLAS_INTERPRET",
              "REPRO_FORCE_BWD_REFERENCE")}
    rows = []
    try:
        for posit in posits:
            legs = {leg: _one_leg(posit, leg, smoke, reps)
                    for leg in ("kernel", "bwd-ref", "jnp")}
            assert not legs["kernel"]["bwd_fallbacks"], (
                "kernel leg fell back", legs["kernel"]["bwd_fallbacks"])
            rows.append({"posit": posit, **legs})
    finally:
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v

    res = {"smoke": smoke, "backend": jax.default_backend(),
           "note": ("cpu kernel legs run the Pallas interpreter "
                    "(correctness harness, not perf); jnp is the "
                    "CPU-meaningful column.  kernel leg must show "
                    "bwd_fallbacks == {}"),
           "rows": rows}
    os.makedirs(os.path.dirname(RESULTS_PATH), exist_ok=True)
    with open(RESULTS_PATH, "w") as f:
        json.dump(res, f, indent=1)
    print(f"wrote {os.path.normpath(RESULTS_PATH)}")
    return res


ELASTIC_PATH = os.path.join(os.path.dirname(__file__), "..", "experiments",
                            "BENCH_elastic.json")


def _elastic_stall_legs(smoke: bool):
    """Per-checkpoint stall: sync store.save vs async snapshot+enqueue,
    same model, same loop (training.elastic, num_hosts=1)."""
    import tempfile
    from repro.data.pipeline import DataConfig
    from repro.distributed.fault_tolerance import RestartPolicy
    from repro.models.transformer import ModelConfig
    from repro.optim.adamw import OptConfig
    from repro.training.elastic import elastic_train_loop

    dims = (dict(n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
                 vocab=128) if smoke else
            dict(n_layers=4, d_model=256, n_heads=8, n_kv=4, d_ff=768,
                 vocab=2048))
    cfg = ModelConfig("bench-elastic", **dims)
    steps = 8 if smoke else 20
    every = 2 if smoke else 4
    opt_cfg = OptConfig(lr_peak=1e-3, warmup_steps=2, total_steps=steps)
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=32 if smoke else 128,
                          global_batch=4)
    policy = RestartPolicy(ckpt_every=every, keep=2)

    legs = {}
    for leg, use_async in (("sync", False), ("async", True)):
        with tempfile.TemporaryDirectory() as ck:
            stalls_s = []
            elastic_train_loop(cfg, opt_cfg, data_cfg, steps,
                               ckpt_dir=ck, policy=policy,
                               async_ckpt=use_async, verbose=False,
                               ckpt_stalls_out=stalls_s)
        stalls = [s * 1e3 for s in stalls_s]
        legs[leg] = {"stall_ms_mean": round(sum(stalls) / len(stalls), 3),
                     "stall_ms_max": round(max(stalls), 3),
                     "n_ckpts": len(stalls)}
    return legs


def _elastic_kill_recovery(smoke: bool):
    """Supervised kill run: SIGKILL 1 of 3 workers mid-run, measure the
    restart latency and redone (lost-work) steps from the GenRecords."""
    import tempfile
    from repro.distributed.fault_tolerance import RestartPolicy
    from repro.launch.supervisor import supervise_training

    steps = 6 if smoke else 12
    with tempfile.TemporaryDirectory() as tmp:
        out = supervise_training(
            "tiny", steps, os.path.join(tmp, "ck"),
            os.path.join(tmp, "run"), workers=3,
            policy=RestartPolicy(ckpt_every=2, step_timeout_s=120,
                                 backoff_s=0.1),
            global_batch=4, seq_len=32, seed=0,
            chaos_kill=f"1:{steps // 2}", verbose=False)
    if out.status != "completed" or len(out.generations) < 2:
        return {"status": out.status, "error": out.error}
    g0, g1 = out.generations[0], out.generations[1]
    return {"status": out.status,
            "restarts": out.restarts,
            "workers": f"{g0.workers}->{g1.workers}",
            # group death -> restarted gen's first observed heartbeat
            "restart_latency_s": round(g1.started_t - g0.ended_t, 3)
            if g1.first_step is not None else None,
            # steps the restarted gen redid (past the resumed checkpoint)
            "lost_work_steps": (g0.last_step - g1.first_step
                                if None not in (g0.last_step, g1.first_step)
                                else None)}


def bench_elastic(smoke: bool = False) -> dict:
    import jax
    res = {"smoke": smoke, "backend": jax.default_backend(),
           "note": ("ckpt_stall_ms: caller-visible per-checkpoint stall; "
                    "async = device->host snapshot only (write+fsync on "
                    "the background thread).  kill_recovery: 3-worker "
                    "supervised group, 1 SIGKILLed mid-run"),
           "ckpt_stall_ms": _elastic_stall_legs(smoke),
           "kill_recovery": _elastic_kill_recovery(smoke)}
    os.makedirs(os.path.dirname(ELASTIC_PATH), exist_ok=True)
    with open(ELASTIC_PATH, "w") as f:
        json.dump(res, f, indent=1)
    print(f"wrote {os.path.normpath(ELASTIC_PATH)}")
    return res


def run(report):
    """benchmarks.run entry point."""
    t0 = time.time()
    res = bench(smoke=True)
    report("train_step", (time.time() - t0) * 1e6, res)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--elastic", action="store_true",
                    help="measure the fault-tolerance stack instead "
                         "(ckpt stalls sync vs async, kill recovery) -> "
                         "BENCH_elastic.json")
    args = ap.parse_args()
    if args.elastic:
        print(json.dumps(bench_elastic(smoke=args.smoke), indent=1))
    else:
        print(json.dumps(bench(smoke=args.smoke), indent=1))


if __name__ == "__main__":
    main()
