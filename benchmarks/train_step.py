"""Forward+backward train-step time: Pallas training kernels vs the jnp
oracles, across posit weight formats.

Three legs per format, all through training.train_step.make_train_step:

    kernel:   REPRO_USE_PALLAS on — flash fwd/bwd, grouped MoE and
              posit GEMM custom_vjp backwards all dispatch Pallas
              (interpret mode on CPU; real kernels on TPU)
    bwd-ref:  kernels forward, REPRO_FORCE_BWD_REFERENCE pins the counted
              jnp reference backwards — isolates the backward kernels'
              contribution
    jnp:      REPRO_USE_PALLAS off — the pure-jnp einsum path end to end

On the CPU backend the kernel legs run the Pallas *interpreter*, so
absolute ratios are meaningless there (interpret mode is a correctness
tool); the jnp column is the CPU-meaningful number and the leg structure
is what the nightly TPU lane consumes.  BWD_FALLBACKS deltas are recorded
per leg — the kernel leg must report {} (the zero-fallback training
invariant, same as tier-1 asserts).

    PYTHONPATH=src python -m benchmarks.train_step [--smoke]

Writes experiments/BENCH_training.json (nightly CI artifact).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "experiments",
                            "BENCH_training.json")

_LEG_ENV = {
    "kernel": {"REPRO_USE_PALLAS": "1", "REPRO_FORCE_BWD_REFERENCE": None},
    "bwd-ref": {"REPRO_USE_PALLAS": "1", "REPRO_FORCE_BWD_REFERENCE": "1"},
    "jnp": {"REPRO_USE_PALLAS": None, "REPRO_FORCE_BWD_REFERENCE": None},
}


def _set_env(leg: str, backend: str):
    env = dict(_LEG_ENV[leg])
    if backend == "cpu" and env.get("REPRO_USE_PALLAS"):
        env["REPRO_PALLAS_INTERPRET"] = "1"
    else:
        env["REPRO_PALLAS_INTERPRET"] = None
    for k, v in env.items():
        if v is None:
            os.environ.pop(k, None)
        else:
            os.environ[k] = v


def _one_leg(posit: str, leg: str, smoke: bool, reps: int):
    import jax
    from repro.core.types import P8_2, P16_2
    from repro.kernels import ops as kops
    from repro.models.transformer import ModelConfig, init_params
    from repro.optim.adamw import OptConfig, init_state
    from repro.quant.policy import PositPolicy
    from repro.training.train_step import make_train_step

    pcfg = {"p8": P8_2, "p16": P16_2, "off": None}[posit]
    dims = (dict(n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
                 vocab=256) if smoke else
            dict(n_layers=4, d_model=256, n_heads=8, n_kv=4, d_ff=768,
                 vocab=2048))
    # distinct names per leg: each traces a different dispatch path
    cfg = ModelConfig(f"bench-train-{posit}-{leg}", **dims,
                      policy=PositPolicy(weights=pcfg))
    _set_env(leg, jax.default_backend())

    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = OptConfig(lr_peak=1e-3, warmup_steps=10, total_steps=100)
    opt = init_state(params, opt_cfg)
    step = make_train_step(cfg, opt_cfg, donate=False)
    B, S = (4, 33) if smoke else (8, 129)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                          cfg.vocab)}
    kops.BWD_FALLBACKS.clear()
    p, o, m = step(params, opt, batch)        # compile + fallback counting
    jax.block_until_ready(p)
    fallbacks = dict(kops.BWD_FALLBACKS)
    best = float("inf")
    for _ in range(reps):
        t0 = time.time()
        p, o, m = step(p, o, batch)
        jax.block_until_ready(p)
        best = min(best, time.time() - t0)
    tokens = B * (S - 1)
    return {"step_ms": round(best * 1e3, 2),
            "tok_s": round(tokens / best, 1),
            "bwd_fallbacks": {k: int(v) for k, v in fallbacks.items()}}


def bench(smoke: bool = False, posits=("off", "p8", "p16")) -> dict:
    import jax
    reps = 2 if smoke else 5
    saved = {k: os.environ.get(k) for k in
             ("REPRO_USE_PALLAS", "REPRO_PALLAS_INTERPRET",
              "REPRO_FORCE_BWD_REFERENCE")}
    rows = []
    try:
        for posit in posits:
            legs = {leg: _one_leg(posit, leg, smoke, reps)
                    for leg in ("kernel", "bwd-ref", "jnp")}
            assert not legs["kernel"]["bwd_fallbacks"], (
                "kernel leg fell back", legs["kernel"]["bwd_fallbacks"])
            rows.append({"posit": posit, **legs})
    finally:
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v

    res = {"smoke": smoke, "backend": jax.default_backend(),
           "note": ("cpu kernel legs run the Pallas interpreter "
                    "(correctness harness, not perf); jnp is the "
                    "CPU-meaningful column.  kernel leg must show "
                    "bwd_fallbacks == {}"),
           "rows": rows}
    os.makedirs(os.path.dirname(RESULTS_PATH), exist_ok=True)
    with open(RESULTS_PATH, "w") as f:
        json.dump(res, f, indent=1)
    print(f"wrote {os.path.normpath(RESULTS_PATH)}")
    return res


def run(report):
    """benchmarks.run entry point."""
    t0 = time.time()
    res = bench(smoke=True)
    report("train_step", (time.time() - t0) * 1e6, res)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    print(json.dumps(bench(smoke=args.smoke), indent=1))


if __name__ == "__main__":
    main()
