"""Quickstart: posit arithmetic as a drop-in number format (paper §III-§VI).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (P8_2, P16_2, f32_to_posit, posit_to_f32, padd, pmul,
                        pdiv, pfma, quire_matmul)

# --- scalars through the FPPU datapath -----------------------------------
a = f32_to_posit(jnp.float32(1.25), P16_2)     # PFCVT.P
b = f32_to_posit(jnp.float32(-0.375), P16_2)
print("a bits:", hex(int(a) & 0xFFFF), "value:", float(posit_to_f32(a, P16_2)))

s = padd(a, b, P16_2)                          # PADD
p = pmul(a, b, P16_2)                          # PMUL
q = pdiv(a, b, P16_2, mode="poly")             # PDIV (paper's Alg.1 + NR)
f = pfma(a, b, s, P16_2)                       # PFMADD (fused, one rounding)
for name, x in (("a+b", s), ("a*b", p), ("a/b", q), ("fma", f)):
    print(f"{name:5s} = {float(posit_to_f32(x, P16_2)):+.6f}")

# --- the paper's intrinsic-style GEMM (Listing 2), vectorized -------------
rng = np.random.default_rng(0)
A = f32_to_posit(jnp.asarray(rng.normal(size=(8, 8)), jnp.float32), P8_2)
B = f32_to_posit(jnp.asarray(rng.normal(size=(8, 8)), jnp.float32), P8_2)
C = quire_matmul(A, B, P8_2)                   # decode -> MXU f32 quire -> round
Cf = posit_to_f32(C, P8_2)
ref = (posit_to_f32(A, P8_2) @ posit_to_f32(B, P8_2))
print("posit8 GEMM NME vs f32:",
      float(jnp.mean(jnp.abs((Cf - ref) / (jnp.abs(ref) + 1e-9)))))

# --- SIMD packing (paper §VIII-A): 4 posit8 lanes per 32-bit word ---------
from repro.core import pack_words, unpack_words, packed_map
w1 = pack_words(A.reshape(8, 8), P8_2)
w2 = pack_words(B.reshape(8, 8), P8_2)
lanes_sum = unpack_words(packed_map(padd, w1, w2, P8_2), P8_2)
print("packed word shape:", w1.shape, "->", lanes_sum.shape, "(4 lanes/word)")
