"""Quickstart: posit arithmetic as a drop-in number format (paper §III-§VI).

Run:  PYTHONPATH=src python examples/quickstart.py

The first-class API is `repro.pnp` + `PositArray`: the posit format is
bound to the array (like the FPPU register file binds it to the register),
so no call ever re-states a config.  The functional intrinsics
(`repro.core.padd` etc.) remain available as the low-level/legacy layer.
"""
import numpy as np
import jax.numpy as jnp

import repro.pnp as pnp
from repro.core import P8_2, P16_2

# --- scalars through the FPPU datapath -----------------------------------
a = pnp.asarray(1.25, P16_2)                   # PFCVT: f32 -> posit
b = pnp.asarray(-0.375, P16_2)
print("a bits:", hex(int(a.bits) & 0xFFFF), "value:", float(a.to_f32()))

s = a + b                                      # PADD
p = a * b                                      # PMUL
q = pnp.divide(a, b, mode="poly")              # PDIV (paper's Alg.1 + NR)
f = pnp.fma(a, b, s)                           # PFMADD (fused, one rounding)
r = pnp.reciprocal(b)                          # inversion
for name, x in (("a+b", s), ("a*b", p), ("a/b", q), ("fma", f), ("1/b", r)):
    print(f"{name:5s} = {float(x.to_f32()):+.6f}")

# comparisons are free (bit patterns order as 2's-complement ints, §VIII)
print("a > b:", bool(a > b), "| a == a:", bool(pnp.equal(a, a)))

# --- the paper's intrinsic-style GEMM (Listing 2), now just `@` -----------
rng = np.random.default_rng(0)
A = pnp.asarray(rng.normal(size=(8, 8)).astype(np.float32), P8_2)
B = pnp.asarray(rng.normal(size=(8, 8)).astype(np.float32), P8_2)
C = A @ B                                      # decode -> MXU f32 quire -> round
Cf = C.to_f32()
ref = A.to_f32() @ B.to_f32()
print("posit8 GEMM NME vs f32:",
      float(jnp.mean(jnp.abs((Cf - ref) / (jnp.abs(ref) + 1e-9)))))

# mixed formats never combine silently:
try:
    _ = A + pnp.ones((8, 8), P16_2)
except pnp.PositConfigMismatchError as e:
    print("mixed-format guard:", type(e).__name__)

# --- SIMD packing (paper §VIII-A): 4 posit8 lanes per 32-bit word ---------
w1, w2 = pnp.pack(A), pnp.pack(B)
lanes_sum = pnp.unpack(w1, P8_2) + pnp.unpack(w2, P8_2)
print("packed word shape:", w1.shape, "->", lanes_sum.shape,
      f"({pnp.lanes(P8_2)} lanes/word)")

# --- legacy functional layer (deprecated shims; bit-identical) ------------
from repro.core import padd
assert (np.asarray(padd(A.bits, B.bits, P8_2)) == np.asarray((A + B).bits)).all()
print("legacy padd(bits, bits, cfg) == PositArray __add__: OK")
