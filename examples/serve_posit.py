"""Serving example: PTQ a model to posit16, serve a batched request set with
a posit KV cache, and report the memory-footprint win (paper C4/C6 applied
to LM serving).

Run:  PYTHONPATH=src python examples/serve_posit.py
"""
import time

import jax
import jax.numpy as jnp

from repro.core.types import P16_2
from repro.models.transformer import ModelConfig, init_params
from repro.quant.policy import PositPolicy
from repro.quant.ptq import quantize_for_serving
from repro.serving.engine import generate


def tree_bytes(t):
    return sum(x.nbytes for x in jax.tree_util.tree_leaves(t))


def main():
    f32_cfg = ModelConfig("serve-demo", n_layers=4, d_model=256, n_heads=8,
                          n_kv=2, d_ff=768, vocab=2048)
    posit_cfg = ModelConfig("serve-demo-p16", n_layers=4, d_model=256,
                            n_heads=8, n_kv=2, d_ff=768, vocab=2048,
                            policy=PositPolicy(weights=P16_2, kv_cache=P16_2))

    params = init_params(jax.random.PRNGKey(0), f32_cfg)
    qparams = quantize_for_serving(params, P16_2)
    print(f"[serve] weights: f32 {tree_bytes(params)/1e6:.1f} MB -> "
          f"posit16 {tree_bytes(qparams)/1e6:.1f} MB")

    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, 2048)

    for name, cfg, p in (("binary32", f32_cfg, params),
                         ("posit16", posit_cfg, qparams)):
        t0 = time.time()
        out = generate(p, cfg, prompts, max_new=24, max_len=64)
        out.block_until_ready()
        print(f"[serve] {name:9s}: {out.shape} in {time.time()-t0:.2f}s; "
              f"first tokens {out[0, :8].tolist()}")


if __name__ == "__main__":
    main()
