"""End-to-end driver: train a ~100M-class LM for a few hundred steps with
posit16 QAT weights, checkpoint/resume, then compare against the binary32
baseline — the LM-scale version of the paper's Fig. 7 experiment.

Run:  PYTHONPATH=src python examples/train_smollm.py [--steps 300]
(CPU: a reduced-width smollm family config; the full config is exercised by
the production dry-run.)
"""
import argparse
import tempfile

import jax

from repro.core.types import P16_2
from repro.data.pipeline import DataConfig
from repro.distributed.fault_tolerance import RestartPolicy
from repro.models.transformer import ModelConfig
from repro.optim.adamw import OptConfig
from repro.quant.policy import PositPolicy
from repro.training.trainer import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--posit", action="store_true", default=True)
    ap.add_argument("--no-posit", dest="posit", action="store_false")
    args = ap.parse_args()

    # ~M-scale smollm-family config sized for a CPU example; same code path
    # as the 256-chip launch (launch/train.py)
    cfg = ModelConfig(
        "smollm-mini", n_layers=6, d_model=256, n_heads=8, n_kv=4,
        d_ff=768, vocab=2048,
        policy=PositPolicy(weights=P16_2) if args.posit else PositPolicy())
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(
        __import__("repro.models.transformer", fromlist=["init_params"])
        .init_params(jax.random.PRNGKey(0), cfg)))
    print(f"[example] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"posit={'p16 QAT' if args.posit else 'off (binary32)'}")

    opt = OptConfig(lr_peak=3e-3, warmup_steps=30, total_steps=args.steps)
    data = DataConfig(vocab=cfg.vocab, seq_len=128, global_batch=16)

    with tempfile.TemporaryDirectory() as ckpt:
        params, _, hist = train_loop(
            cfg, opt, data, args.steps, ckpt_dir=ckpt,
            policy=RestartPolicy(ckpt_every=100), log_every=25)
    print(f"[example] loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"over {args.steps} steps")


if __name__ == "__main__":
    main()
