"""End-to-end driver: train a smollm-family LM on the Pallas training
kernels twice — posit16 QAT weights vs the binary32 baseline — and emit a
loss-curve parity artifact (the LM-scale version of the paper's Fig. 7
"posits match binary32" experiment, now through the full kernel surface:
flash fwd/bwd, posit GEMM custom_vjp, donated train step).

Both legs run the *same* kernel path (REPRO_USE_PALLAS; interpret mode on
CPU), the same data stream and the same init seed, so the only difference
is the posit16 STE weight quantization.  The artifact records both loss
curves plus the gap statistics and the per-leg fallback counters (which
must stay empty — the zero-BWD_FALLBACKS training invariant).

Run:  PYTHONPATH=src python examples/train_smollm.py [--steps 80]
Writes experiments/smollm_p16_parity.json.
"""
import argparse
import json
import os

os.environ.setdefault("REPRO_USE_PALLAS", "1")
if not os.environ.get("JAX_PLATFORMS", "").startswith("tpu"):
    os.environ.setdefault("REPRO_PALLAS_INTERPRET", "1")

import jax

from repro.core.types import P16_2
from repro.data.pipeline import DataConfig
from repro.models.transformer import ModelConfig, init_params
from repro.optim.adamw import OptConfig
from repro.quant.policy import PositPolicy
from repro.training.trainer import train_loop

ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                        "smollm_p16_parity.json")


def run_leg(posit: bool, steps: int, log_every: int):
    # ~M-scale smollm-family config sized for interpret-mode CPU steps;
    # same code path as the 256-chip launch (launch/train.py).  Distinct
    # names: each leg jits its own step.
    cfg = ModelConfig(
        f"smollm-mini-{'p16' if posit else 'f32'}",
        n_layers=4, d_model=128, n_heads=8, n_kv=4, d_ff=384, vocab=1024,
        policy=PositPolicy(weights=P16_2) if posit else PositPolicy())
    opt = OptConfig(lr_peak=3e-3, warmup_steps=max(steps // 10, 5),
                    total_steps=steps)
    data = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8)
    _, _, hist = train_loop(cfg, opt, data, steps, log_every=log_every,
                            verbose=True)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(
        init_params(jax.random.PRNGKey(0), cfg)))
    return cfg, n_params, hist


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    legs = {}
    for name, posit in (("p16", True), ("f32", False)):
        print(f"[example] === {name} leg "
              f"({'posit16 QAT weights' if posit else 'binary32'}) ===")
        cfg, n_params, hist = run_leg(posit, args.steps, args.log_every)
        fallbacks = {}
        for row in hist:
            for k, v in row.get("fallbacks", {}).items():
                fallbacks[k] = fallbacks.get(k, 0) + v
        legs[name] = {
            "arch": cfg.name,
            "params_m": round(n_params / 1e6, 2),
            "curve": [{"step": r["step"], "loss": round(r["loss"], 4)}
                      for r in hist],
            "final_loss": round(hist[-1]["loss"], 4),
            "steps_per_s": round(hist[-1]["steps_per_s"], 3),
            "bwd_fallbacks": fallbacks,
        }
        print(f"[example] {name}: loss {hist[0]['loss']:.3f} -> "
              f"{hist[-1]['loss']:.3f} over {args.steps} steps")

    gaps = [abs(a["loss"] - b["loss"])
            for a, b in zip(legs["p16"]["curve"], legs["f32"]["curve"])]
    res = {
        "experiment": "posit16 QAT vs binary32 loss parity, kernel path "
                      "(flash fwd/bwd + posit GEMM custom_vjp + donated "
                      "train step)",
        "backend": jax.default_backend(),
        "interpret": bool(os.environ.get("REPRO_PALLAS_INTERPRET")),
        "steps": args.steps,
        "seq_len": 64, "global_batch": 8,
        "p16": legs["p16"], "f32": legs["f32"],
        "loss_gap_final": round(
            abs(legs["p16"]["final_loss"] - legs["f32"]["final_loss"]), 4),
        "loss_gap_max": round(max(gaps), 4),
    }
    os.makedirs(os.path.dirname(ARTIFACT), exist_ok=True)
    with open(ARTIFACT, "w") as f:
        json.dump(res, f, indent=1)
    print(f"[example] wrote {os.path.normpath(ARTIFACT)}: "
          f"final p16 {legs['p16']['final_loss']} vs "
          f"f32 {legs['f32']['final_loss']} "
          f"(gap {res['loss_gap_final']})")


if __name__ == "__main__":
    main()
