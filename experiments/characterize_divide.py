"""Characterize the (pre-fix) poly-divide kernel/ref divergence on posit16es1.

Root cause (ROADMAP "latent divide" item): `core.recip.approx_quotient` used
to evaluate Algorithm 1 + Newton-Raphson in f32.  XLA keeps the freedom to
contract `a*b +/- c` into an FMA, and exercises it differently per
compilation context: the eager per-op path (how `kernels.ref.divide_ref` is
usually called) rounds every multiply, while the jitted/Pallas-interpreted
kernel fuses `2 - x*y` (verified: the diverging bits match f64-emulated FMA
exactly).  The quotient estimate flips +/-1 on operands near a rounding
boundary, so `posit_elementwise.divide(mode="poly")` disagreed with
`divide_ref` for a ~1e-4 fraction of posit16es1 operand pairs.

The fix (this PR) re-evaluates the pipeline in int32 fixed point
(`core.recip.recip_poly_fx` / `nr_round_fx`) — integer ops leave the
compiler no contraction freedom, so kernel == ref by construction.

This script re-measures both implementations:

  * exhaustive q-divergence over all 4096 x 4096 realizable te=0 mantissa
    pairs (the root-cause space: q depends only on (Ma, Mb));
  * sampled full-operand output divergence (kernel interpret=True vs eager
    ref), collecting the exact diverging 16-bit operand pairs;

and writes experiments/divide_characterization.json.  The regression test
(tests/test_divide_regression.py) pins pairs enumerated by this script.

    PYTHONPATH=src python experiments/characterize_divide.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ops as pops
from repro.core import recip as _recip
from repro.core.decode import work_frac_bits
from repro.core.types import P16_1
from repro.kernels import posit_elementwise as KE
from repro.kernels import ref as R


def _legacy_approx_quotient(Ma, Mb, cfg, *, mode, nr_rounds, wq,
                            k1=_recip.K1_OPT, k2=_recip.K2_OPT):
    """The pre-fix f32 evaluation (verbatim), for re-measuring the bug."""
    Wd = work_frac_bits(cfg)
    ma = Ma.astype(jnp.float32)
    mb = Mb.astype(jnp.float32)
    if mode in ("poly", "poly_corrected"):
        x = mb * jnp.float32(2.0 ** -(Wd + 1))
        y = _recip.recip_poly_f32(x, k1, k2)
        for _ in range(nr_rounds):
            y = _recip.nr_round(y, x)
        q = ma * y * jnp.float32(2.0 ** (wq - Wd))
    elif mode == "pacogen":
        frac = Mb - (jnp.int32(1) << Wd)
        y = _recip.recip_pacogen_f32(frac, cfg)
        x = mb * jnp.float32(2.0 ** -Wd)
        for _ in range(nr_rounds):
            y = _recip.nr_round(y, x)
        q = ma * y * jnp.float32(2.0 ** (wq + 1 - Wd))
    else:
        raise ValueError(mode)
    return jnp.clip(q, 1.0, 2.0 ** (wq + 2)).astype(jnp.int32)


class _use_legacy:
    """Swap in the legacy f32 quotient; KE.divide is a jitted wrapper, so
    its trace cache must be dropped on both transitions or a stale trace of
    the other implementation would keep serving."""

    def __enter__(self):
        self._orig = _recip.approx_quotient
        _recip.approx_quotient = _legacy_approx_quotient
        KE.divide.clear_cache()

    def __exit__(self, *exc):
        _recip.approx_quotient = self._orig
        KE.divide.clear_cache()


def _te0_operand(frac12: np.ndarray) -> np.ndarray:
    """posit16es1 bit pattern with sign=0, k=0, e=0 and the given 12-bit
    fraction: covers every realizable mantissa exactly once at te=0."""
    return (0x4000 | frac12).astype(np.int64)


def q_divergence_exhaustive(batch: int = 1 << 16, quick: bool = False):
    """Old implementation: kernel-context q vs eager-ref q over ALL te=0
    mantissa pairs (4096^2).  Returns (n_total, n_diverging, sample pairs)."""
    cfg = P16_1
    fr = np.arange(4096 if not quick else 256, dtype=np.int64)
    A, B = np.meshgrid(fr, fr, indexing="ij")
    a_bits = _te0_operand(A.ravel())
    b_bits = _te0_operand(B.ravel())
    n = a_bits.size
    bad_pairs = []
    n_bad = 0
    with _use_legacy():
        for lo in range(0, n, batch):
            hi = min(lo + batch, n)
            a = jnp.asarray(a_bits[lo:hi].astype(np.uint16).astype(np.int16))
            b = jnp.asarray(b_bits[lo:hi].astype(np.uint16).astype(np.int16))
            got = np.asarray(KE.divide(a, b, cfg=cfg, mode="poly",
                                       interpret=True))
            want = np.asarray(R.divide_ref(a, b, cfg=cfg, mode="poly"))
            neq = np.nonzero(got != want)[0]
            n_bad += neq.size
            for i in neq[:4]:
                if len(bad_pairs) < 256:
                    bad_pairs.append([int(a_bits[lo + i]) & 0xFFFF,
                                      int(b_bits[lo + i]) & 0xFFFF])
    return n, n_bad, bad_pairs


def output_divergence_sampled(n_batches: int = 64, seed: int = 0):
    """Old implementation: full-operand kernel-vs-ref output divergence on
    random posit16es1 pairs; returns exact diverging pairs."""
    cfg = P16_1
    rng = np.random.default_rng(seed)
    pairs = []
    n_bad = n_tot = 0
    with _use_legacy():
        for _ in range(n_batches):
            a_bits = rng.integers(0, 1 << 16, size=(1 << 16,))
            b_bits = rng.integers(0, 1 << 16, size=(1 << 16,))
            a = jnp.asarray(a_bits.astype(np.uint16).astype(np.int16))
            b = jnp.asarray(b_bits.astype(np.uint16).astype(np.int16))
            got = np.asarray(KE.divide(a, b, cfg=cfg, mode="poly",
                                       interpret=True))
            want = np.asarray(R.divide_ref(a, b, cfg=cfg, mode="poly"))
            neq = np.nonzero(got != want)[0]
            n_tot += a.size
            n_bad += neq.size
            for i in neq:
                if len(pairs) < 256:
                    pairs.append([int(a_bits[i]), int(b_bits[i]),
                                  int(got[i]) & 0xFFFF,
                                  int(want[i]) & 0xFFFF])
    return n_tot, n_bad, pairs


def fixed_point_check(pairs, n_random_batches: int = 16, seed: int = 1):
    """New implementation: assert kernel == ref on the characterized pairs
    and on fresh random sweeps."""
    cfg = P16_1
    rng = np.random.default_rng(seed)
    if pairs:
        a = jnp.asarray(np.asarray([p[0] for p in pairs],
                                   np.uint16).astype(np.int16))
        b = jnp.asarray(np.asarray([p[1] for p in pairs],
                                   np.uint16).astype(np.int16))
        got = np.asarray(KE.divide(a, b, cfg=cfg, mode="poly", interpret=True))
        want = np.asarray(R.divide_ref(a, b, cfg=cfg, mode="poly"))
        assert (got == want).all(), "fixed-point path still diverges!"
    n_bad = 0
    for _ in range(n_random_batches):
        a = jnp.asarray(rng.integers(0, 1 << 16, size=(1 << 16,)).astype(np.uint16).astype(np.int16))
        b = jnp.asarray(rng.integers(0, 1 << 16, size=(1 << 16,)).astype(np.uint16).astype(np.int16))
        got = np.asarray(KE.divide(a, b, cfg=cfg, mode="poly", interpret=True))
        want = np.asarray(R.divide_ref(a, b, cfg=cfg, mode="poly"))
        n_bad += int((got != want).sum())
    return n_bad


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="256x256 mantissa grid + fewer random batches")
    args = ap.parse_args()

    nb = 8 if args.quick else 64
    n_tot, n_bad, pairs = output_divergence_sampled(n_batches=nb)
    print(f"[old f32 path] output divergence: {n_bad}/{n_tot} "
          f"({100.0 * n_bad / n_tot:.4f}%), {len(pairs)} pairs collected")

    nq, nq_bad, q_pairs = q_divergence_exhaustive(quick=args.quick)
    print(f"[old f32 path] te=0 mantissa-pair divergence: {nq_bad}/{nq} "
          f"({100.0 * nq_bad / nq:.4f}%)")

    new_bad = fixed_point_check(pairs, n_random_batches=4 if args.quick else 16)
    print(f"[fixed-point path] divergence on same + fresh sweeps: {new_bad}")

    out = {
        "config": "posit16es1",
        "mode": "poly",
        "quick": args.quick,
        "jax_version": jax.__version__,
        "old_output_divergence": {"checked": n_tot, "diverging": n_bad},
        "old_te0_mantissa_divergence": {"checked": nq, "diverging": nq_bad},
        "new_divergence": new_bad,
        "diverging_pairs_a_b_kernel_ref": pairs,
        "diverging_te0_pairs_a_b": q_pairs[:64],
    }
    # quick runs are labeled AND written elsewhere: the committed exhaustive
    # artifact backs the ROADMAP/test citations and must not be replaced by
    # reduced-grid numbers
    name = ("divide_characterization_quick.json" if args.quick
            else "divide_characterization.json")
    path = os.path.join(os.path.dirname(__file__), name)
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
