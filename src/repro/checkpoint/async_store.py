"""Asynchronous checkpointing: snapshot synchronously, publish in the
background — the train loop stalls for a device→host copy instead of a
full write+hash+fsync cycle.

Contract (the elastic-training acceptance row in ISSUE 10):

  * save(step, tree) snapshots device→host *synchronously* — an actual
    copy (np.array, copy=True semantics), never a view of the device
    buffer: the trainer's donated jit reuses those buffers on the very
    next step, and a zero-copy CPU-backend view would hand the writer
    thread garbage.  The caller-visible stall is this copy (+ a possible
    backpressure block), recorded per save in `stalls_s`.
  * the background thread runs checkpoint.store.save verbatim — write,
    fsync every leaf + manifest, atomic .tmp→final rename, fsync the
    parent dir, GC by valid steps.  A crash mid-async-write therefore
    leaves only a .tmp dir, which restore_latest already skips (the
    corrupted-tail fallback covers torn leaves).
  * the in-flight queue is bounded: a save() issued while `max_inflight`
    snapshots are still being written BLOCKS until a slot frees — memory
    stays bounded and no checkpoint is ever silently dropped.
  * wait() is the loop-exit barrier: it returns only when every enqueued
    snapshot is published (or re-raises the writer thread's failure).
    Background write errors never vanish — they surface on the next
    save()/wait()/close().

Used by training/trainer.py and training/elastic.py under
--async-ckpt; stall sync-vs-async is measured in BENCH_elastic.json
(benchmarks/train_step.py --elastic).
"""
from __future__ import annotations

import queue
import threading
import time

import jax
import numpy as np

from repro.checkpoint import store


class AsyncCheckpointStore:
    def __init__(self, ckpt_dir: str, *, keep: int = 3,
                 max_inflight: int = 2):
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._q: queue.Queue = queue.Queue(maxsize=max_inflight)
        self._exc: BaseException | None = None
        self._closed = False
        self.stalls_s: list[float] = []   # caller-visible stall per save()
        self.published: list[int] = []    # steps the writer thread finished
        self._thread = threading.Thread(target=self._drain,
                                        name="async-ckpt", daemon=True)
        self._thread.start()

    # -- trainer-facing API -------------------------------------------------
    def save(self, step: int, tree) -> float:
        """Snapshot `tree` to host memory and enqueue it for background
        publishing; returns the caller-visible stall in seconds."""
        if self._closed:
            raise RuntimeError("AsyncCheckpointStore is closed")
        self._raise_pending()
        t0 = time.perf_counter()
        snap = jax.tree_util.tree_map(lambda x: np.array(x), tree)
        self._q.put((int(step), snap))    # blocks on overflow — never drops
        stall = time.perf_counter() - t0
        self.stalls_s.append(stall)
        return stall

    def wait(self):
        """Barrier: block until every enqueued snapshot is on disk."""
        self._q.join()
        self._raise_pending()

    def close(self):
        """Drain, stop the writer thread, surface any pending error."""
        if not self._closed:
            self._closed = True
            self._q.put(None)
            self._thread.join()
        self._raise_pending()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- writer thread ------------------------------------------------------
    def _drain(self):
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                step, snap = item
                store.save(self.ckpt_dir, step, snap, keep=self.keep)
                self.published.append(step)
            except BaseException as e:   # kept; re-raised at the barrier
                self._exc = e
            finally:
                self._q.task_done()

    def _raise_pending(self):
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise RuntimeError(
                f"async checkpoint write failed: {exc!r}") from exc
