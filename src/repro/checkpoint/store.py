"""Atomic, manifest-verified checkpointing (numpy-backed).

Fault-tolerance contract (DESIGN.md §5):
  * writes go to  <dir>/step_<N>.tmp/  and are renamed to  step_<N>/  only
    after every leaf and the manifest hash are on disk — a killed writer
    leaves a .tmp dir that restore ignores;
  * restore scans for the newest *valid* step (manifest present, hash
    matches, all leaves load) and falls back to older steps on corruption;
  * the data pipeline is seekable (data/pipeline.py), so params+opt_state+
    step is the complete training state: restart is exact.

At fleet scale each host writes its own param shards (per-leaf files here —
process-local stand-in documented in DESIGN.md); the manifest carries the
pytree structure so the restore side rebuilds any sharding layout.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil

import jax
import numpy as np


def _leaf_files(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


_HASH_CHUNK = 1 << 20    # 1 MiB: bounded memory however large the leaf


def _leaf_digest(arr: np.ndarray) -> str:
    """Full-content sha256 of one leaf, streamed in chunks (no whole-leaf
    bytes copy: the digest walks a memoryview of the array buffer).  A
    prefix-only hash (the old `tobytes()[:4096]`) let any corruption past
    the first 4 KiB of a leaf pass validation silently."""
    h = hashlib.sha256()
    mv = memoryview(np.ascontiguousarray(arr)).cast("B")
    for off in range(0, len(mv), _HASH_CHUNK):
        h.update(mv[off:off + _HASH_CHUNK])
    return h.hexdigest()


def _fsync_dir(path: str):
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save(ckpt_dir: str, step: int, tree, keep: int = 3) -> str:
    """Durable atomic save: every leaf and the manifest are fsync'd before
    the .tmp -> final rename, and the parent dir is fsync'd after, so a
    published step survives a host crash, not just a process kill (the
    async store runs this exact function on its background thread)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(final):        # idempotent: step already published
        return final
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, treedef = _leaf_files(tree)
    manifest = {"step": step, "treedef": str(treedef), "leaves": []}
    h = hashlib.sha256()
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        fn = f"leaf_{i:05d}.npy"
        with open(os.path.join(tmp, fn), "wb") as f:
            np.save(f, arr)
            f.flush()
            os.fsync(f.fileno())
        digest = _leaf_digest(arr)
        h.update(digest.encode())               # combined hash over digests
        manifest["leaves"].append({"file": fn, "dtype": str(arr.dtype),
                                   "shape": list(arr.shape),
                                   "sha256": digest})
    manifest["hash"] = h.hexdigest()
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    _fsync_dir(tmp)
    os.replace(tmp, final)                       # atomic publish
    _fsync_dir(ckpt_dir)

    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    """Prune to the newest `keep` *valid* steps.

    Only directories that at least carry a manifest count toward `keep`
    (restore's full-hash validation stays too expensive to run per GC):
    a manifest-less partial dir — a hand-mangled or half-unpacked step —
    must neither consume a keep slot nor shadow older valid steps, and
    the newest valid step must never be deleted even when newer partial
    or .tmp dirs exist above it.  Partial/.tmp dirs themselves are left
    alone (save() reclaims its own .tmp; anything else is evidence worth
    keeping for a human)."""
    if keep <= 0:
        return
    valid = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")))
    for d in valid[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def _try_load(path: str, example_tree):
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    _, treedef = jax.tree_util.tree_flatten(example_tree)
    leaves = []
    h = hashlib.sha256()
    legacy = any("sha256" not in spec for spec in manifest["leaves"])
    for spec in manifest["leaves"]:
        arr = np.load(os.path.join(path, spec["file"]))
        if str(arr.dtype) != spec["dtype"] or list(arr.shape) != spec["shape"]:
            raise IOError(f"leaf mismatch in {path}: {spec}")
        if legacy:
            # pre-sha256 manifests: the old combined prefix hash is all
            # there is to check (full-digest validation needs a re-save)
            h.update(arr.tobytes()[:4096])
        else:
            digest = _leaf_digest(arr)
            if digest != spec["sha256"]:
                raise IOError(f"leaf hash mismatch in {path}: "
                              f"{spec['file']}")
            h.update(digest.encode())
        leaves.append(arr)
    if h.hexdigest() != manifest["hash"]:
        raise IOError(f"hash mismatch in {path}")
    return manifest["step"], jax.tree_util.tree_unflatten(treedef, leaves)


def restore_latest(ckpt_dir: str, example_tree):
    """Returns (step, tree) from the newest valid checkpoint, or (None, None)."""
    if not os.path.isdir(ckpt_dir):
        return None, None
    steps = sorted((d for d in os.listdir(ckpt_dir)
                    if d.startswith("step_") and not d.endswith(".tmp")),
                   reverse=True)
    for d in steps:
        try:
            return _try_load(os.path.join(ckpt_dir, d), example_tree)
        except Exception as e:  # corrupted/partial: fall back to older
            print(f"[checkpoint] skipping {d}: {e}")
    return None, None
