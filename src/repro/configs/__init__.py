"""Architecture registry: one module per assigned arch (+ the paper's own
LeNet-5 workload).  get_config(name) -> full ModelConfig;
get_smoke(name) -> reduced same-family config for CPU smoke tests.
"""
from repro.configs import (gemma_2b, hubert_xlarge, internlm2_20b,
                           olmoe_1b_7b, phi_3_vision_4_2b, qwen1_5_110b,
                           qwen3_moe_235b_a22b, recurrentgemma_9b, rwkv6_3b,
                           smollm_360m)
from repro.configs.shapes import SHAPES, ShapeSpec, cells, skip_reason

_MODULES = {
    "internlm2-20b": internlm2_20b,
    "gemma-2b": gemma_2b,
    "smollm-360m": smollm_360m,
    "qwen1.5-110b": qwen1_5_110b,
    "rwkv6-3b": rwkv6_3b,
    "hubert-xlarge": hubert_xlarge,
    "olmoe-1b-7b": olmoe_1b_7b,
    "qwen3-moe-235b-a22b": qwen3_moe_235b_a22b,
    "phi-3-vision-4.2b": phi_3_vision_4_2b,
    "recurrentgemma-9b": recurrentgemma_9b,
}

ARCHS = tuple(_MODULES)


def get_config(name: str, **overrides):
    return _MODULES[name].full(**overrides)


def get_smoke(name: str, **overrides):
    return _MODULES[name].smoke(**overrides)


def all_configs(**overrides):
    return {a: get_config(a, **overrides) for a in ARCHS}
