"""gemma-2b [arXiv:2403.08295; hf] — dense MQA decoder, GeGLU, head_dim=256.

18L d_model=2048 8H (kv=1, MQA) d_ff=16384 vocab=256000; embeddings scaled
by sqrt(d_model) (Gemma convention).
"""
from repro.models.transformer import ModelConfig


def full(**ov) -> ModelConfig:
    return ModelConfig(
        name="gemma-2b", n_layers=18, d_model=2048, n_heads=8, n_kv=1,
        d_ff=16384, vocab=256000, head_dim=256, act="geglu",
        embed_scale=True, **ov)


def smoke(**ov) -> ModelConfig:
    return ModelConfig(
        name="gemma-2b-smoke", n_layers=3, d_model=96, n_heads=4, n_kv=1,
        d_ff=192, vocab=512, head_dim=32, act="geglu", embed_scale=True, **ov)
