"""hubert-xlarge [arXiv:2106.07447; unverified] — audio encoder-only.

48L d_model=1280 16H d_ff=5120 vocab=504 (cluster targets), GELU, LayerNorm,
bidirectional.  The conv frame frontend is a STUB per the assignment:
input_specs provide precomputed frame embeddings [B, S, d_model].
No decode step (encoder-only): decode shapes skipped.
"""
from repro.models.transformer import ModelConfig


def full(**ov) -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge", n_layers=48, d_model=1280, n_heads=16, n_kv=16,
        d_ff=5120, vocab=504, act="gelu", norm="layernorm",
        encoder_only=True, input_mode="embeddings", tie_embeddings=False,
        **ov)


def smoke(**ov) -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge-smoke", n_layers=3, d_model=96, n_heads=4,
        n_kv=4, d_ff=192, vocab=64, act="gelu", norm="layernorm",
        encoder_only=True, input_mode="embeddings", tie_embeddings=False,
        **ov)
