"""internlm2-20b [arXiv:2403.17297; hf] — dense GQA decoder.

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92544, SwiGLU, RMSNorm.
"""
from repro.models.transformer import ModelConfig


def full(**ov) -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b", n_layers=48, d_model=6144, n_heads=48, n_kv=8,
        d_ff=16384, vocab=92544, act="swiglu", rope_theta=1e6, **ov)


def smoke(**ov) -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b-smoke", n_layers=4, d_model=128, n_heads=8,
        n_kv=2, d_ff=256, vocab=512, act="swiglu", **ov)
