"""LeNet-5-class CNN — the paper's own DNN benchmark (§VII-A, Fig. 7).

Not one of the ten assigned LM archs: this is the paper-native workload used
by benchmarks/dnn_accuracy.py to reproduce the posit-vs-binary32 accuracy
comparison on 32x32 images (MNIST/CIFAR10-sized, synthetic data offline).
Implemented directly in JAX (conv -> pool -> conv -> pool -> fc x3).
"""
import jax
import jax.numpy as jnp


def init_lenet(key, n_classes: int = 10, in_ch: int = 1):
    ks = jax.random.split(key, 5)
    he = lambda k, shape, fan: jax.random.normal(k, shape, jnp.float32) * (2.0 / fan) ** 0.5
    return {
        "c1": he(ks[0], (5, 5, in_ch, 6), 25 * in_ch),
        "c2": he(ks[1], (5, 5, 6, 16), 25 * 6),
        "f1": he(ks[2], (16 * 25, 120), 400),
        "f2": he(ks[3], (120, 84), 120),
        "f3": he(ks[4], (84, n_classes), 84),
    }


def lenet_forward(params, x, matmul=None):
    """x [B, 32, 32, C].  `matmul(a, b)` overrides dense/conv contractions
    (used to run the network through the posit datapath)."""
    mm = matmul or (lambda a, b: a @ b)

    def conv(x, w):
        # im2col so the conv goes through the same (posit) GEMM path
        B, H, W, Cin = x.shape
        kh, kw, _, Cout = w.shape
        Ho, Wo = H - kh + 1, W - kw + 1
        patches = jnp.stack([
            x[:, i:i + Ho, j:j + Wo, :] for i in range(kh) for j in range(kw)
        ], axis=3)                                  # [B,Ho,Wo,kh*kw,Cin]
        patches = patches.reshape(B * Ho * Wo, kh * kw * Cin)
        out = mm(patches, w.reshape(kh * kw * Cin, Cout))
        return out.reshape(B, Ho, Wo, Cout)

    def pool(x):  # 2x2 average pooling (the paper's pooling benchmark op)
        B, H, W, C = x.shape
        return x.reshape(B, H // 2, 2, W // 2, 2, C).mean(axis=(2, 4))

    x = jax.nn.relu(conv(x, params["c1"]))
    x = pool(x)
    x = jax.nn.relu(conv(x, params["c2"]))
    x = pool(x)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(mm(x, params["f1"]))
    x = jax.nn.relu(mm(x, params["f2"]))
    return mm(x, params["f3"])
