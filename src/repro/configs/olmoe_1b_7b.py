"""olmoe-1b-7b [arXiv:2409.02060; hf] — MoE, 64 experts top-8.

16L d_model=2048 16H d_ff=1024 (per expert) vocab=50304.
"""
from repro.models.transformer import ModelConfig, MoEConfig


def full(**ov) -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b", n_layers=16, d_model=2048, n_heads=16, n_kv=16,
        d_ff=1024, vocab=50304, act="swiglu", moe=MoEConfig(64, 8), **ov)


def smoke(**ov) -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv=4,
        d_ff=96, vocab=512, act="swiglu", moe=MoEConfig(8, 2), **ov)
