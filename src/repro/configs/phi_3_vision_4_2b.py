"""phi-3-vision-4.2b [hf:microsoft/Phi-3-vision-128k-instruct; hf] —
phi3-mini text backbone + CLIP vision frontend.

32L d_model=3072 32H (MHA kv=32) d_ff=8192 vocab=32064, SwiGLU.
The CLIP patch frontend is a STUB per the assignment: input_specs provide
precomputed patch embeddings [B, n_patches, d_model] prepended to tokens.
"""
from repro.models.transformer import ModelConfig

N_PATCHES = 576  # 24x24 CLIP-L grid @ 336px


def full(**ov) -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b", n_layers=32, d_model=3072, n_heads=32,
        n_kv=32, d_ff=8192, vocab=32064, act="swiglu",
        input_mode="tokens+image", **ov)


def smoke(**ov) -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b-smoke", n_layers=3, d_model=96, n_heads=4,
        n_kv=4, d_ff=192, vocab=512, act="swiglu",
        input_mode="tokens+image", **ov)
