"""qwen1.5-110b [hf:Qwen/Qwen1.5-110B; hf] — dense GQA decoder with QKV bias.

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064, SwiGLU.
"""
from repro.models.transformer import ModelConfig


def full(**ov) -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b", n_layers=80, d_model=8192, n_heads=64, n_kv=8,
        d_ff=49152, vocab=152064, act="swiglu", qkv_bias=True, **ov)


def smoke(**ov) -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b-smoke", n_layers=4, d_model=128, n_heads=8,
        n_kv=2, d_ff=384, vocab=512, act="swiglu", qkv_bias=True, **ov)
