"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3-235B-A22B; hf] — MoE, 128 experts
top-8, the largest assigned model (~235B total / ~22B active).

94L d_model=4096 64H (GQA kv=4) d_ff=1536 (per expert) vocab=151936;
head_dim=128 (so H*hd = 8192 != d_model, faithful to Qwen3).
"""
from repro.models.transformer import ModelConfig, MoEConfig


def full(**ov) -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b", n_layers=94, d_model=4096, n_heads=64,
        n_kv=4, d_ff=1536, vocab=151936, head_dim=128, act="swiglu",
        moe=MoEConfig(128, 8), **ov)


def smoke(**ov) -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b-smoke", n_layers=2, d_model=64, n_heads=8,
        n_kv=2, d_ff=64, vocab=512, head_dim=16, act="swiglu",
        moe=MoEConfig(8, 2), **ov)
