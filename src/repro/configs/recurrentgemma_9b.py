"""recurrentgemma-9b [arXiv:2402.19427; unverified] — Griffin hybrid:
RG-LRU recurrent blocks + local sliding-window attention, 1:2 ratio.

38L (= 12 x [rglru, rglru, attn_local] + 2 rglru) d_model=4096 16H
(kv=1, MQA) d_ff=12288 vocab=256000, GeGLU, window 2048.
Sub-quadratic: runs the long_500k shape.
"""
from repro.models.transformer import ModelConfig


def full(**ov) -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b", n_layers=38, d_model=4096, n_heads=16,
        n_kv=1, d_ff=12288, vocab=256000, head_dim=256, act="geglu",
        block_pattern=("rglru", "rglru", "attn_local"), window=2048,
        embed_scale=True, **ov)


def smoke(**ov) -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b-smoke", n_layers=5, d_model=64, n_heads=4,
        n_kv=1, d_ff=128, vocab=512, head_dim=16, act="geglu",
        block_pattern=("rglru", "rglru", "attn_local"), window=32,
        embed_scale=True, **ov)
