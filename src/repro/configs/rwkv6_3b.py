"""rwkv6-3b "Finch" [arXiv:2404.05892; hf] — attention-free SSM with
data-dependent decay.

32L d_model=2560 d_ff=8960 vocab=65536; head_dim 64 (40 heads).
Sub-quadratic: runs the long_500k shape.
"""
from repro.models.transformer import ModelConfig


def full(**ov) -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b", n_layers=32, d_model=2560, n_heads=40, n_kv=40,
        d_ff=8960, vocab=65536, block_pattern=("rwkv6",), rwkv_head_dim=64,
        **ov)


def smoke(**ov) -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv=4,
        d_ff=224, vocab=512, block_pattern=("rwkv6",), rwkv_head_dim=16, **ov)
