"""Assigned input shapes and the (arch x shape) cell grid.

LM shapes are seq_len x global_batch.  decode_* / long_* lower `serve_step`
(one new token against a KV cache of seq_len), not `train_step`.
Skip rules (recorded in DESIGN.md §4 / EXPERIMENTS.md §Dry-run):
  * encoder-only archs have no decode step -> decode shapes skipped
  * long_500k requires sub-quadratic attention -> full-attention archs skip
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def skip_reason(model_cfg, shape: ShapeSpec) -> str | None:
    """None if the cell runs; otherwise the documented skip reason."""
    if model_cfg.encoder_only and shape.kind == "decode":
        return "encoder-only arch: no decode step"
    subquadratic = all(k in ("rwkv6", "rglru", "attn_local")
                       for k in model_cfg.block_pattern)
    if shape.name == "long_500k" and not subquadratic:
        return "pure full-attention arch: long_500k needs sub-quadratic attention"
    return None


def cells(configs: dict):
    """Yield (arch_name, shape_name, model_cfg, shape, skip_reason)."""
    for arch, cfg in configs.items():
        for sname, shape in SHAPES.items():
            yield arch, sname, cfg, shape, skip_reason(cfg, shape)
