"""smollm-360m [hf:HuggingFaceTB/SmolLM-360M; hf] — llama-arch small dense.

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152, SwiGLU.
"""
from repro.models.transformer import ModelConfig


def full(**ov) -> ModelConfig:
    return ModelConfig(
        name="smollm-360m", n_layers=32, d_model=960, n_heads=15, n_kv=5,
        d_ff=2560, vocab=49152, act="swiglu", **ov)


def smoke(**ov) -> ModelConfig:
    return ModelConfig(
        name="smollm-360m-smoke", n_layers=4, d_model=120, n_heads=6, n_kv=2,
        d_ff=320, vocab=512, act="swiglu", **ov)
