"""Posit arithmetic core — the paper's contribution as a composable JAX module."""
from repro.core.array import (PositArray, PositConfigMismatchError, is_posit,
                              result_cfg)
from repro.core.types import (P8_0, P8_2, P16_1, P16_2, P32_2, STANDARD,
                              PositConfig, table2_grid)
from repro.core.decode import decode, decode_to_f32
from repro.core.encode import encode_fir, to_storage
from repro.core.ops import (pabs, padd, pdiv, peq, pfma, plt, pmul, pneg,
                            precip, psub)
from repro.core.convert import (bf16_to_posit, f32_to_posit, posit_to_bf16,
                                posit_to_f32)
from repro.core.packing import lanes, pack_words, packed_map, unpack_words
from repro.core.quire import quire_dot, quire_matmul

__all__ = [
    "PositArray", "PositConfigMismatchError", "is_posit", "result_cfg",
    "PositConfig", "P8_0", "P8_2", "P16_1", "P16_2", "P32_2", "STANDARD",
    "table2_grid", "decode", "decode_to_f32", "encode_fir", "to_storage",
    "padd", "psub", "pmul", "pdiv", "pfma", "pneg", "pabs", "precip",
    "plt", "peq", "f32_to_posit", "posit_to_f32", "bf16_to_posit",
    "posit_to_bf16", "pack_words", "unpack_words", "packed_map", "lanes",
    "quire_dot", "quire_matmul",
]
