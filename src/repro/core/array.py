"""First-class posit arrays — the software analogue of the FPPU register file.

The paper's ISA makes posits a machine type: once a value sits in the posit
register file, PADD/PMUL/PFMADD know its format without the programmer
re-stating it (§VI).  `PositArray` gives the JAX reproduction the same
property: it binds the payload bits (narrow storage ints) to their
`PositConfig`, so the format travels with the array instead of being
threaded as a `cfg` argument through every call site.

Design rules:
  * `PositArray` is a registered JAX pytree — the bits are the (single)
    traced child, the `PositConfig` is static aux data — so it passes
    transparently through `jax.jit`, `jax.vmap`, `lax.scan`, shardings and
    checkpoint flattening.
  * Operators dispatch through `repro.kernels.ops`, so the Pallas-vs-jnp
    routing (`use_pallas`) is invisible to callers and results are
    bit-identical to the functional `core.ops` intrinsics.
  * Mixed formats never silently reinterpret: combining two PositArrays
    with different configs raises `PositConfigMismatchError`; int arrays are
    never implicitly treated as posit payloads (use `frombits`).  Python
    scalars and float arrays are *values* and are correctly rounded into the
    array's own format before the op.
  * Gradients: the bits are integers and carry no tangents.  Training flows
    cross the posit boundary through the straight-through estimator
    (`repro.quant.policy.posit_cast_ste`, re-exported as `repro.pnp.ste`),
    exactly as the QAT path in `models/blocks.py` does.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.types import PositConfig


class PositConfigMismatchError(ValueError):
    """Two posit operands carry different formats; no silent reinterpretation."""


@jax.tree_util.register_pytree_with_keys_class
class PositArray:
    """Payload bits + format, behaving like a numpy array of posit values.

    Construct via `repro.pnp.asarray` (from float values) or
    `repro.pnp.frombits` (from existing payload ints); the raw constructor
    performs no conversion and only light validation so traced values,
    `ShapeDtypeStruct`s and numpy arrays all pass through (pytree
    unflattening must stay trivial).
    """

    __slots__ = ("bits", "cfg")

    # keep numpy from claiming `np_array <op> posit_array`: defer to our
    # reflected operators instead of ufunc broadcasting over the object
    __array_ufunc__ = None
    __array_priority__ = 100

    def __init__(self, bits: Any, cfg: PositConfig):
        if not isinstance(cfg, PositConfig):
            raise TypeError(f"cfg must be a PositConfig, got {type(cfg)!r}")
        self.bits = bits
        self.cfg = cfg

    # ---- pytree protocol: bits traced, cfg static --------------------------
    def tree_flatten_with_keys(self):
        return ((jax.tree_util.GetAttrKey("bits"), self.bits),), self.cfg

    @classmethod
    def tree_unflatten(cls, cfg, children):
        (bits,) = children
        return cls(bits, cfg)

    # ---- array metadata passthrough ---------------------------------------
    @property
    def shape(self):
        return self.bits.shape

    @property
    def ndim(self):
        return self.bits.ndim

    @property
    def size(self):
        return self.bits.size

    @property
    def dtype(self):
        """Storage dtype of the payload (int8/int16/int32)."""
        return self.bits.dtype

    @property
    def nbytes(self):
        return self.bits.nbytes

    def __len__(self):
        return len(self.bits)

    def __getitem__(self, idx):
        return PositArray(self.bits[idx], self.cfg)

    def reshape(self, *shape):
        return PositArray(self.bits.reshape(*shape), self.cfg)

    def transpose(self, *axes):
        return PositArray(self.bits.transpose(*axes), self.cfg)

    @property
    def T(self):
        return PositArray(self.bits.T, self.cfg)

    def ravel(self):
        return PositArray(self.bits.ravel(), self.cfg)

    def flatten(self):
        return self.ravel()

    def squeeze(self, axis=None):
        return PositArray(jnp.squeeze(self.bits, axis), self.cfg)

    def __repr__(self):
        return (f"PositArray({self.cfg}, shape={tuple(jnp.shape(self.bits))}, "
                f"dtype={getattr(self.bits, 'dtype', '?')})")

    # equality-as-elementwise makes the object unhashable, like numpy arrays
    __hash__ = None  # type: ignore[assignment]

    # ---- conversions -------------------------------------------------------
    def to_f32(self) -> jnp.ndarray:
        """Exact decode to float32 (PFCVT.S); NaR -> NaN."""
        from repro.kernels import ops as kops
        return kops.decode(self.bits, self.cfg)

    def to_bf16(self) -> jnp.ndarray:
        return self.to_f32().astype(jnp.bfloat16)

    def astype(self, cfg: PositConfig) -> "PositArray":
        """Re-round into another posit format (exact when widening, single
        correctly-rounded step when narrowing, for n <= 16)."""
        if not isinstance(cfg, PositConfig):
            raise TypeError("astype takes a PositConfig; use to_f32()/to_bf16()"
                            " for float outputs")
        if cfg == self.cfg:
            return self
        from repro.kernels import ops as kops
        return PositArray(kops.encode(self.to_f32(), cfg), cfg)

    # ---- operand coercion --------------------------------------------------
    def _coerce(self, other) -> "PositArray":
        """Bring `other` into this array's format, or fail loudly.

        PositArray: formats must match exactly.  Python scalars / float
        arrays: correctly rounded into self.cfg (they are *values*).  Int
        arrays are rejected — ambiguous between values and payload bits.
        """
        if isinstance(other, PositArray):
            if other.cfg != self.cfg:
                raise PositConfigMismatchError(
                    f"cannot combine {self.cfg} with {other.cfg}; cast "
                    f"explicitly with .astype()")
            return other
        if isinstance(other, (bool, int, float)):
            from repro.kernels import ops as kops
            bits = kops.encode(jnp.full((), float(other), jnp.float32),
                               self.cfg)
            return PositArray(bits, self.cfg)
        dt = getattr(other, "dtype", None)
        if dt is not None and jnp.issubdtype(dt, jnp.floating):
            from repro.kernels import ops as kops
            return PositArray(kops.encode(jnp.asarray(other, jnp.float32),
                                          self.cfg), self.cfg)
        raise TypeError(
            f"cannot mix PositArray with {type(other).__name__}: int arrays "
            f"are ambiguous (values vs payload bits) — wrap payloads with "
            f"pnp.frombits(x, cfg) or convert values with pnp.asarray")

    # ---- arithmetic: dispatches through kernels.ops ------------------------
    def _ew(self, other, op: str, reverse: bool = False) -> "PositArray":
        other = self._coerce(other)
        a, b = (other, self) if reverse else (self, other)
        from repro.kernels import ops as kops
        return PositArray(kops.elementwise(op, a.bits, b.bits, cfg=self.cfg),
                          self.cfg)

    def __add__(self, other):
        return self._ew(other, "add")

    def __radd__(self, other):
        return self._ew(other, "add", reverse=True)

    def __sub__(self, other):
        return self._ew(other, "sub")

    def __rsub__(self, other):
        return self._ew(other, "sub", reverse=True)

    def __mul__(self, other):
        return self._ew(other, "mul")

    def __rmul__(self, other):
        return self._ew(other, "mul", reverse=True)

    def __truediv__(self, other):
        other = self._coerce(other)
        from repro.kernels import ops as kops
        return PositArray(kops.divide(self.bits, other.bits, cfg=self.cfg),
                          self.cfg)

    def __rtruediv__(self, other):
        other = self._coerce(other)
        from repro.kernels import ops as kops
        return PositArray(kops.divide(other.bits, self.bits, cfg=self.cfg),
                          self.cfg)

    def __matmul__(self, other):
        other = self._coerce(other)
        from repro.kernels import ops as kops
        out = kops.gemm(self.bits, other.bits, cfg_a=self.cfg, cfg_b=self.cfg,
                        cfg_out=self.cfg, out_posit=True)
        return PositArray(out, self.cfg)

    def __neg__(self):
        from repro.core.ops import pneg
        return PositArray(pneg(self.bits, self.cfg), self.cfg)

    def __pos__(self):
        return self

    def __abs__(self):
        from repro.core.ops import pabs
        return PositArray(pabs(self.bits, self.cfg), self.cfg)

    # ---- comparisons: free on the bit patterns (paper §VIII) ---------------
    def __lt__(self, other):
        from repro.core.ops import plt
        return plt(self.bits, self._coerce(other).bits, self.cfg)

    def __gt__(self, other):
        from repro.core.ops import plt
        return plt(self._coerce(other).bits, self.bits, self.cfg)

    def __le__(self, other):
        from repro.core.ops import plt
        return ~plt(self._coerce(other).bits, self.bits, self.cfg)

    def __ge__(self, other):
        from repro.core.ops import plt
        return ~plt(self.bits, self._coerce(other).bits, self.cfg)

    def _coerce_or_foreign(self, other):
        """_coerce, but mapping only truly-foreign types to None (so ==/!=
        can fall back to identity).  Format mismatches and ambiguous int
        arrays stay loud — a silent scalar False against payload bits is
        exactly the wrong-predicate bug the guards exist to prevent."""
        try:
            return self._coerce(other)
        except PositConfigMismatchError:
            raise
        except TypeError:
            dt = getattr(other, "dtype", None)
            if dt is not None and jnp.issubdtype(dt, jnp.integer):
                raise               # ambiguous bits-vs-values: keep loud
            return None             # foreign type (None, str, ...): defer

    def __eq__(self, other):  # type: ignore[override]
        from repro.core.ops import peq
        other = self._coerce_or_foreign(other)
        if other is None:
            return NotImplemented
        return peq(self.bits, other.bits, self.cfg)

    def __ne__(self, other):  # type: ignore[override]
        from repro.core.ops import peq
        other = self._coerce_or_foreign(other)
        if other is None:
            return NotImplemented
        return ~peq(self.bits, other.bits, self.cfg)


def is_posit(x) -> bool:
    return isinstance(x, PositArray)


def unwrap_kv(k, v, cfg: PositConfig | None = None, q=None):
    """Shared attention-entry unwrap: (k, v[, explicit cfg]) -> raw buffers
    + resolved KV format.  k and v must be both PositArray or both raw —
    one operand's format is never applied to a float operand.  Pass `q` to
    also enforce that queries stay float (activations, never posit pages)."""
    if isinstance(q, PositArray):
        raise TypeError("q must be a float array (queries are activations); "
                        "only the KV pages may be posit")
    if isinstance(k, PositArray) or isinstance(v, PositArray):
        if not (isinstance(k, PositArray) and isinstance(v, PositArray)):
            raise TypeError("k and v must both be PositArray (or both raw): "
                            "one operand's format cannot be applied to a "
                            "float operand")
        cfg = result_cfg(k, v, cfg=cfg)
        return k.bits, v.bits, cfg
    return k, v, cfg


def result_cfg(*operands, cfg: PositConfig | None = None) -> PositConfig:
    """Resolve the common format of a mixed operand list.

    Every PositArray operand must agree; an explicit `cfg` must agree with
    all of them.  Raises if no format can be determined.
    """
    out = cfg
    for x in operands:
        if isinstance(x, PositArray):
            if out is not None and x.cfg != out:
                raise PositConfigMismatchError(
                    f"operand format {x.cfg} conflicts with {out}")
            out = x.cfg
    if out is None:
        raise TypeError("no PositArray operand and no cfg given: cannot "
                        "infer the posit format")
    return out
