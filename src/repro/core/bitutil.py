"""Portable integer bit tricks shared by the jnp datapath and Pallas kernels.

Pallas/Mosaic does not reliably lower `lax.clz`, so bit_length is computed
from the exponent field of an f32 conversion — exact, branch-free, and made
of ops every backend lowers (convert, bitcast, shift, compare, select).

f32 conversion is exact for ints < 2^24; above that, rounding could carry
into the next power of two and overstate bit_length by 1.  The two-step
split (high 24 bits first) keeps it exact for the full non-negative int32
range used by the posit datapath (values < 2^31).
"""
from __future__ import annotations

import jax.numpy as jnp


def _bl_small(y: jnp.ndarray) -> jnp.ndarray:
    """bit_length for 0 <= y < 2^24 (exact f32 conversion)."""
    f = y.astype(jnp.float32)
    exp = ((f.view(jnp.int32) >> 23) & 0xFF) - 127
    return jnp.where(y == 0, 0, exp + 1)


def bit_length32(y: jnp.ndarray) -> jnp.ndarray:
    """bit_length of non-negative int32 values (exact for y < 2^31)."""
    y = jnp.asarray(y, dtype=jnp.int32)
    hi = y >> 7
    return jnp.where(hi != 0, _bl_small(hi) + 7, _bl_small(y))
