"""float <-> posit conversions — the paper's PFCVT instructions (§VI).

These enable the paper's deployment model: "binary32 numbers as frontend
while maintaining posit computation as backend".  In the LM framework they
are the quantize/dequantize primitives of the posit dtype policy.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.decode import decode, decode_to_f32, work_frac_bits
from repro.core.encode import encode_fir, to_storage
from repro.core.types import PositConfig


def f32_to_posit(v, cfg: PositConfig) -> jnp.ndarray:
    """Correctly-rounded float32 -> posit (RNE; NaN/Inf -> NaR; +-0 -> 0).

    Single rounding: the f32 mantissa (24 bits) is wider than any posit<=16
    fraction, and we keep all 24 bits through the encode stage.
    """
    v = jnp.asarray(v, dtype=jnp.float32)
    i = v.view(jnp.int32)
    s = (i >> 31) & 1
    exp = (i >> 23) & 0xFF
    mant = i & 0x7FFFFF
    nar = exp == 0xFF                          # Inf/NaN -> NaR
    zero = (i & 0x7FFFFFFF) == 0
    # subnormals (exp==0, mant!=0) are below every posit<=16 minpos: map to a
    # tiny te so encode saturates to minpos (posit never rounds nonzero to 0).
    W = 23
    te = jnp.where(exp == 0, jnp.int32(-200), exp - 127)
    M = (jnp.int32(1) << W) | mant
    out = encode_fir(s, te, M, W, jnp.zeros_like(M), cfg)
    out = jnp.where(zero, 0, out)
    out = jnp.where(nar, cfg.nar, out)
    return to_storage(out, cfg)


def posit_to_f32(p, cfg: PositConfig) -> jnp.ndarray:
    """Exact posit -> float32 (PFCVT.S); NaR -> NaN."""
    return decode_to_f32(p, cfg)


def bf16_to_posit(v, cfg: PositConfig) -> jnp.ndarray:
    return f32_to_posit(jnp.asarray(v).astype(jnp.float32), cfg)


def posit_to_bf16(p, cfg: PositConfig) -> jnp.ndarray:
    """posit -> bfloat16 (double rounding is innocuous: 8-bit bf16 fraction,
    f32 intermediate is exact for n <= 16)."""
    return decode_to_f32(p, cfg).astype(jnp.bfloat16)
