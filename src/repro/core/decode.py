"""JAX posit decode — stage (i) of the FPPU pipeline (paper §IV, §V).

Branch-free uint/int32 bit manipulation; vectorizes on the TPU VPU.  The
decoded form is the paper's FIR: sign, total exponent te = 2^ES*k + e, and an
integer significand.

Significand convention (chosen so every downstream op fits int32):
    M is an integer with value = M / 2^W(cfg) in [1, 2),  W(cfg) = n - 3.
A posit<n,es> fraction has at most n-3-es significant bits, so the bottom
3+es bits of the n-bit left-aligned fraction are always zero: dropping 3 is
lossless.  For n=16: M has <= 14 bits, products <= 28 bits -> int32-safe.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.bitutil import bit_length32
from repro.core.types import PositConfig

KLASS_ZERO = 0
KLASS_NAR = 1
KLASS_NORMAL = 2


def work_frac_bits(cfg: PositConfig) -> int:
    """W: fraction bits of the decoded integer significand (lossless)."""
    return cfg.n - 3


def as_bits32(p, cfg: PositConfig) -> jnp.ndarray:
    """Any int array -> int32 N-bit patterns (zero-extended)."""
    return jnp.asarray(p).astype(jnp.int32) & jnp.int32(cfg.mask)


def classify(u: jnp.ndarray, cfg: PositConfig) -> jnp.ndarray:
    klass = jnp.full(u.shape, KLASS_NORMAL, dtype=jnp.int32)
    klass = jnp.where(u == 0, KLASS_ZERO, klass)
    klass = jnp.where(u == cfg.nar, KLASS_NAR, klass)
    return klass


def decode(p, cfg: PositConfig):
    """posit bits -> (klass, sign, te, M) int32 arrays.

    M = significand with hidden bit at position W(cfg); don't-care for
    ZERO/NAR lanes (callers mask via klass).
    """
    n, es = cfg.n, cfg.es
    u = as_bits32(p, cfg)
    klass = classify(u, cfg)

    s = (u >> (n - 1)) & 1
    absu = jnp.where(s == 1, (-u) & cfg.mask, u)
    absu = jnp.where(klass == KLASS_NORMAL, absu, 1)  # keep shifts well-defined

    x = (absu << 1) & cfg.mask                  # drop sign bit, regime at MSB
    b = (x >> (n - 1)) & 1
    y = jnp.where(b == 1, (~x) & cfg.mask, x)
    # count the regime run: leading-identical-bits within the n-bit window
    run = jnp.minimum(n - bit_length32(y), n - 1)
    k = jnp.where(b == 1, run - 1, -run)

    rem = (x << (run + 1)) & cfg.mask           # exponent+fraction, left-aligned
    if es > 0:
        e = rem >> (n - es)
        frac = (rem << es) & cfg.mask
    else:
        e = jnp.zeros_like(rem)
        frac = rem
    te = k * cfg.useed_exp + e

    W = work_frac_bits(cfg)
    M = (jnp.int32(1) << W) | (frac >> 3)       # bottom 3+es fraction bits are 0
    return klass, s, te, M


def decode_to_f32(p, cfg: PositConfig) -> jnp.ndarray:
    """Exact posit -> float32 (n <= 16: 14-bit significand, |te| <= 126).

    NaR -> NaN, zero -> 0.  This is the PFCVT.S direction of the paper's ISA
    extension and the in-kernel dequantization primitive for the GEMM path.
    The f32 is assembled bit-by-bit (no ldexp/frexp) so the same code lowers
    inside Pallas kernels.
    """
    if cfg.te_max > 126:
        raise ValueError(f"{cfg}: te range exceeds f32 normal exponents")
    klass, s, te, M = decode(p, cfg)
    W = work_frac_bits(cfg)
    mant23 = (M - (jnp.int32(1) << W)) << (23 - W)     # W <= 13 < 23
    fbits = (s << 31) | ((te + 127) << 23) | mant23
    v = fbits.view(jnp.float32)
    v = jnp.where(klass == KLASS_ZERO, 0.0, v)
    v = jnp.where(klass == KLASS_NAR, jnp.nan, v)
    return v
