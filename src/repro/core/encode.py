"""JAX posit encode — FPPU stage (iii): normalization + round-to-nearest-even.

Implements the paper's §IV-D: split te into regime k / exponent e, assemble
[sign | regime | exp | fraction], round with the (G, R, S) bits of Fig. 3,
and saturate (clip k per eq. (9)) to maxpos/minpos — a nonzero value never
rounds to zero or NaR (posit standard).

All arithmetic is int32 and branch-free.  The monotonicity of posit bit
patterns lets RNE act directly on the assembled pattern: increment iff
R & (S | G) — carries propagate through fraction/exponent/regime correctly.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.types import PositConfig


def encode_fir(s, te, M, W: int, sticky, cfg: PositConfig) -> jnp.ndarray:
    """RNE-encode (-1)^s * 2^te * (M / 2^W) to posit bits (int32, N-bit).

    M must be normalized: M in [2^W, 2^(W+1)).  W is a static python int
    (<= 29).  `sticky` is 0/1 per element: OR of all discarded value bits
    below M's LSB.  Callers handle ZERO/NAR lanes.
    """
    n, es = cfg.n, cfg.es
    s = jnp.asarray(s, dtype=jnp.int32)
    te = jnp.asarray(te, dtype=jnp.int32)
    M = jnp.asarray(M, dtype=jnp.int32)
    sticky = jnp.asarray(sticky, dtype=jnp.int32)

    # values beyond the representable exponent range saturate (paper eq. (9)
    # clip): > maxpos -> maxpos, < minpos -> minpos (never 0/NaR).  Record the
    # masks before clipping — the clipped assembly would otherwise round a
    # sub-minpos value up across the boundary.
    sat_hi = te > cfg.te_max
    sat_lo = te < cfg.te_min
    te = jnp.clip(te, cfg.te_min, cfg.te_max)
    k = te >> es
    e = te - (k << es)

    # regime field: k>=0 -> (k+1) ones + stop 0 ; k<0 -> (-k) zeros + stop 1
    k_pos = k >= 0
    rlen = jnp.where(k_pos, k + 2, 1 - k)            # <= n
    regime = jnp.where(k_pos, ((jnp.int32(1) << (jnp.minimum(k, n) + 1)) - 1) << 1, 1)

    frac = M - (jnp.int32(1) << W)
    nre = rlen + es
    body_bits = n - 1
    combined_re = (regime << es) | e                 # <= n + es + 1 bits

    # --- case A: some fraction bits survive (nre < n-1) ---
    ffield = jnp.maximum(body_bits - nre, 0)
    shiftA = jnp.clip(W - ffield, 1, 31)             # >= 4 in practice (W >= n-3+?')
    keptA = frac >> shiftA
    rA = (frac >> (shiftA - 1)) & 1
    sA = ((frac & ((jnp.int32(1) << (shiftA - 1)) - 1)) != 0).astype(jnp.int32) | sticky
    bodyA = (combined_re << ffield) | keptA

    # --- case B: regime+exponent fill the body (nre >= n-1) ---
    shiftB = jnp.clip(nre - body_bits, 0, 31)
    bodyB = combined_re >> shiftB
    shiftB1 = jnp.maximum(shiftB - 1, 0)
    rB = jnp.where(shiftB > 0, (combined_re >> shiftB1) & 1, (frac >> (W - 1)) & 1)
    low_re = (combined_re & ((jnp.int32(1) << shiftB1) - 1)) != 0
    low_fr_all = frac != 0
    low_fr_tail = (frac & ((jnp.int32(1) << (W - 1)) - 1)) != 0
    sB = jnp.where(shiftB > 0, low_re | low_fr_all, low_fr_tail).astype(jnp.int32) | sticky

    caseA = nre < body_bits
    body = jnp.where(caseA, bodyA, bodyB)
    r = jnp.where(caseA, rA, rB)
    st = jnp.where(caseA, sA, sB)

    g = body & 1
    body = body + (r & (st | g))                     # RNE on the monotone pattern

    body = jnp.minimum(body, cfg.maxpos_bits)        # round-up past maxpos
    body = jnp.maximum(body, cfg.minpos_bits)        # nonzero never rounds to 0
    body = jnp.where(sat_hi, cfg.maxpos_bits, body)
    body = jnp.where(sat_lo, cfg.minpos_bits, body)

    return jnp.where(s == 1, (-body) & cfg.mask, body)


def to_storage(p: jnp.ndarray, cfg: PositConfig) -> jnp.ndarray:
    """int32 N-bit patterns -> the format's storage dtype (sign-extended)."""
    bits = cfg.storage_bits
    shift = 32 - bits if cfg.n == bits else 32 - cfg.n
    # left-align then arithmetic shift right to sign-extend the N-bit pattern
    x = (p << (32 - cfg.n)) >> (32 - cfg.n)
    return x.astype(jnp.dtype(f"int{bits}"))
