"""Exact software golden model for posit arithmetic (numpy, int64 datapath).

This is the reproduction of the paper's "software golden model for posit
computation" (§V-A, §VII): every FPPU result — and every JAX/Pallas kernel in
this repo — is validated against it.

Exactness strategy
------------------
All operations are computed with *integer* mantissa arithmetic and a single
round-to-nearest-even at the end, i.e. the mathematically exact posit result:

* decode:   posit bits -> (sign, te, M) with M an (n+1)-bit integer
            significand, value = M / 2^n  in [1, 2).  Exact.
* add/sub:  operand alignment with sticky capture beyond n+3 bits.  Exact.
* mul:      M1*M2 <= 2*(n+1) bits: int64-exact for n <= 16; python-int
            fallback for wider formats.  Exact.
* div:      integer long division with remainder -> sticky.  Exact.
* fma:      exact product + aligned addend with sticky.  Exact.
* quire:    arbitrary-precision python-int fixed-point accumulator (the
            posit-standard quire semantics: no intermediate rounding).
* encode:   regime/exponent/fraction assembly with G/R/S round-to-nearest-even
            (paper Fig. 3) and saturation to maxpos/minpos (never to 0/NaR).

The vectorized int64 paths cover n <= 16 (the paper's DNN formats); wider
formats transparently fall back to an exact scalar path.
"""
from __future__ import annotations

import numpy as np

from repro.core.types import PositConfig

# Classification codes shared with the JAX implementation.
KLASS_ZERO = 0
KLASS_NAR = 1
KLASS_NORMAL = 2


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------
def _as_bits(p, cfg: PositConfig) -> np.ndarray:
    """Canonicalize any int array to int64 N-bit patterns (unsigned view)."""
    p = np.asarray(p)
    return p.astype(np.int64) & cfg.mask


def _bit_length(y: np.ndarray) -> np.ndarray:
    """Vectorized bit_length for int64 values in [0, 2^32)."""
    y = y.astype(np.int64)
    safe = np.maximum(y, 1).astype(np.float64)
    # exact for integers < 2^53; log2 never rounds across an integer boundary
    # for y < 2^32 (max true distance to the boundary ~3.4e-10 >> 1 ulp).
    bl = np.floor(np.log2(safe)).astype(np.int64) + 1
    return np.where(y == 0, 0, bl)


# --------------------------------------------------------------------------
# decode
# --------------------------------------------------------------------------
def decode(p, cfg: PositConfig):
    """posit bits -> (klass, sign, te, M).

    M is the integer significand with hidden bit: value = M / 2^n in [1, 2).
    For ZERO/NAR klass entries sign/te/M are don't-care (zeros).
    """
    u = _as_bits(p, cfg)
    n, es = cfg.n, cfg.es
    klass = np.full(u.shape, KLASS_NORMAL, dtype=np.int64)
    klass = np.where(u == 0, KLASS_ZERO, klass)
    klass = np.where(u == cfg.nar, KLASS_NAR, klass)

    s = (u >> (n - 1)) & 1
    absu = np.where(s == 1, (-u) & cfg.mask, u)
    # guard specials so shifts below stay well-defined
    absu = np.where(klass == KLASS_NORMAL, absu, 1)

    x = (absu << 1) & cfg.mask                      # drop sign, left-align regime
    b = (x >> (n - 1)) & 1
    y = np.where(b == 1, (~x) & cfg.mask, x)
    run = np.minimum(n - _bit_length(y), n - 1)      # regime run length l
    k = np.where(b == 1, run - 1, -run)

    rem = (x << (run + 1)) & cfg.mask                # exponent+fraction, left-aligned
    e = (rem >> (n - es)) if es > 0 else np.zeros_like(rem)
    frac = (rem << es) & cfg.mask                    # fraction left-aligned in n bits
    te = k * cfg.useed_exp + e
    M = (np.int64(1) << n) | frac                    # (n+1)-bit significand
    return klass, s, te, M


def decode_to_float64(p, cfg: PositConfig) -> np.ndarray:
    """Exact real value of each posit (NaR -> nan). Requires |te_max| < 1023."""
    if cfg.te_max >= 1023:
        raise ValueError(f"{cfg} exceeds float64 exponent range")
    klass, s, te, M = decode(p, cfg)
    sig = M.astype(np.float64) * np.ldexp(1.0, -cfg.n)   # exact: M has <= 33 bits
    v = np.ldexp(sig, te.astype(np.int32))
    v = np.where(s == 1, -v, v)
    v = np.where(klass == KLASS_ZERO, 0.0, v)
    v = np.where(klass == KLASS_NAR, np.nan, v)
    return v


# --------------------------------------------------------------------------
# encode (FIR -> posit), the paper's §IV-D normalization + Fig. 3 G/R/S RNE
# --------------------------------------------------------------------------
def _encode_fir(s, te, M, W, sticky, cfg: PositConfig) -> np.ndarray:
    """Round-to-nearest-even encode of (-1)^s * 2^te * (M / 2^W), M in [2^W, 2^(W+1)).

    All arrays int64; W is a python int (uniform working fraction width).
    Saturates to maxpos/minpos; never rounds a nonzero value to zero or NaR.
    """
    n, es = cfg.n, cfg.es
    s = np.asarray(s, dtype=np.int64)
    te = np.asarray(te, dtype=np.int64)
    M = np.asarray(M, dtype=np.int64)
    sticky = np.asarray(sticky, dtype=np.int64)

    k = te >> es                      # floor division (arithmetic shift)
    e = te - (k << es)                # in [0, 2^es)

    # regime field (paper eq. (2)): k>=0 -> (k+1) ones + stop 0; k<0 -> (-k) zeros + stop 1
    k_pos = k >= 0
    rlen = np.where(k_pos, k + 2, 1 - k)
    regime = np.where(k_pos, ((np.int64(1) << np.minimum(k + 1, 62)) - 1) << 1, 1)

    frac = M - (np.int64(1) << W)     # W-bit fraction (hidden bit removed)

    nre = rlen + es                   # regime+exponent width
    body_bits = n - 1

    # ---- case A: fraction (partly) survives:  nre < n-1 ----
    ffield = np.maximum(body_bits - nre, 0)
    shiftA = W - ffield               # fraction bits discarded
    shiftA_c = np.clip(shiftA, 1, 62)
    keptA = frac >> shiftA_c
    rA = (frac >> (shiftA_c - 1)) & 1
    sA = ((frac & ((np.int64(1) << (shiftA_c - 1)) - 1)) != 0).astype(np.int64) | sticky
    combined_re = (regime << es) | e
    bodyA = (combined_re << ffield) | keptA

    # ---- case B: regime+exp overflow the body:  nre >= n-1 ----
    shiftB = np.clip(nre - body_bits, 0, 62)
    bodyB = combined_re >> shiftB
    rB = np.where(
        shiftB > 0,
        (combined_re >> np.maximum(shiftB - 1, 0)) & 1,
        (frac >> (W - 1)) & 1,
    )
    lowmaskB = (np.int64(1) << np.maximum(shiftB - 1, 0)) - 1
    s_from_re = np.where(shiftB > 0, (combined_re & lowmaskB) != 0, False)
    s_from_frac = np.where(
        shiftB > 0,
        frac != 0,
        (frac & ((np.int64(1) << (W - 1)) - 1)) != 0,
    )
    sB = (s_from_re | s_from_frac).astype(np.int64) | sticky

    caseA = nre < body_bits
    body = np.where(caseA, bodyA, bodyB)
    r = np.where(caseA, rA, rB)
    st = np.where(caseA, sA, sB)

    # round-to-nearest-even on the monotone posit pattern: inc iff R & (S | G)
    g = body & 1
    body = body + (r & (st | g))

    # saturation: pattern overflow past maxpos, or te outside representable range
    body = np.minimum(body, cfg.maxpos_bits)
    body = np.where(te > cfg.te_max, cfg.maxpos_bits, body)
    body = np.where(te < cfg.te_min, cfg.minpos_bits, body)
    # nonzero never rounds to zero (posit standard): bump to minpos
    body = np.maximum(body, cfg.minpos_bits)

    out = np.where(s == 1, (-body) & cfg.mask, body)
    return out.astype(np.int64)


def encode_from_float64(v, cfg: PositConfig) -> np.ndarray:
    """Correctly-rounded float64 -> posit (paper's FCVT.P direction).

    Exact RNE for n <= 16 by Figueroa's innocuous-double-rounding bound
    (53 >= 2*max_frac+2); for n <= 32 the f64 mantissa is wider than any
    posit fraction so the conversion itself is single-rounding and exact.
    """
    v = np.asarray(v, dtype=np.float64)
    nar = ~np.isfinite(v)
    zero = v == 0.0
    s = (np.signbit(v)).astype(np.int64)
    av = np.abs(np.where(nar | zero, 1.0, v))
    m, ex = np.frexp(av)                       # av = m * 2^ex, m in [0.5, 1)
    te = ex.astype(np.int64) - 1
    W = 52
    M = np.ldexp(m, W + 1).astype(np.int64)    # exact 53-bit integer mantissa
    out = _encode_fir(s, te, M, W, np.zeros_like(M), cfg)
    out = np.where(zero, 0, out)
    out = np.where(nar, cfg.nar, out)
    return out


# --------------------------------------------------------------------------
# exact arithmetic (vectorized int64, n <= 16; scalar exact fallback otherwise)
# --------------------------------------------------------------------------
def _specials2(ka, kb):
    any_nar = (ka == KLASS_NAR) | (kb == KLASS_NAR)
    return any_nar


def padd(a, b, cfg: PositConfig) -> np.ndarray:
    """Exact posit addition with a single final rounding (paper §IV-A)."""
    if cfg.n > 16:
        return _scalar_op(a, b, cfg, "add")
    ka, sa, tea, Ma = decode(a, cfg)
    kb, sb, teb, Mb = decode(b, cfg)
    n = cfg.n

    # order so |p1| >= |p2|  (compare (te, M))
    swap = (teb > tea) | ((teb == tea) & (Mb > Ma))
    s1 = np.where(swap, sb, sa); s2 = np.where(swap, sa, sb)
    te1 = np.where(swap, teb, tea); te2 = np.where(swap, tea, teb)
    M1 = np.where(swap, Mb, Ma); M2 = np.where(swap, Ma, Mb)

    # working precision: mantissas at n bits + 3 guard bits
    G = 3
    M1w = M1 << G
    M2w = M2 << G
    d = te1 - te2
    dc = np.clip(d, 0, n + G + 1)
    M2s = M2w >> dc
    sticky = ((M2w & ((np.int64(1) << dc) - 1)) != 0).astype(np.int64)

    eff_sub = s1 != s2
    mag = np.where(eff_sub, M1w - M2s - sticky * 0, M1w + M2s)
    # subtraction: sticky bits reduce the magnitude below the truncated value;
    # represent by (mag - 1) with sticky kept when sticky and exact borrow matter.
    mag = np.where(eff_sub & (sticky == 1), mag - 1, mag)
    st = sticky

    W = n + G
    # normalize into [2^W, 2^(W+1))
    bl = _bit_length(np.maximum(mag, 1))
    shift_left = (W + 1) - bl
    sl = np.clip(shift_left, 0, 62)
    sr = np.clip(-shift_left, 0, 62)
    lost = (mag & ((np.int64(1) << sr) - 1)) != 0
    Mn = np.where(shift_left >= 0, mag << sl, mag >> sr)
    st = st | lost.astype(np.int64)
    ten = te1 - shift_left

    res = _encode_fir(s1, ten, np.maximum(Mn, np.int64(1) << W), W, st, cfg)

    # exact zero result
    res = np.where(mag == 0, 0, res)
    # specials
    res = np.where(ka == KLASS_ZERO, _as_bits(b, cfg), res)
    res = np.where(kb == KLASS_ZERO, _as_bits(a, cfg), res)
    res = np.where((ka == KLASS_ZERO) & (kb == KLASS_ZERO), 0, res)
    res = np.where(_specials2(ka, kb), cfg.nar, res)
    return res


def pneg(a, cfg: PositConfig) -> np.ndarray:
    u = _as_bits(a, cfg)
    return np.where(u == cfg.nar, cfg.nar, (-u) & cfg.mask)


def psub(a, b, cfg: PositConfig) -> np.ndarray:
    return padd(a, pneg(b, cfg), cfg)


def pmul(a, b, cfg: PositConfig) -> np.ndarray:
    """Exact posit multiplication (paper §IV-B)."""
    if cfg.n > 16:
        return _scalar_op(a, b, cfg, "mul")
    ka, sa, tea, Ma = decode(a, cfg)
    kb, sb, teb, Mb = decode(b, cfg)
    n = cfg.n
    s = sa ^ sb
    te = tea + teb
    P = Ma * Mb                          # (2n+2)-bit product, value in [1, 4)
    W = 2 * n
    top = P >> (W + 1)                   # 1 if P >= 2 * 2^W
    te = te + top
    M = np.where(top == 1, P >> 1, P)
    st = np.where(top == 1, (P & 1).astype(np.int64), 0)
    res = _encode_fir(s, te, M, W, st, cfg)
    res = np.where((ka == KLASS_ZERO) | (kb == KLASS_ZERO), 0, res)
    res = np.where(_specials2(ka, kb), cfg.nar, res)
    return res


def pdiv(a, b, cfg: PositConfig) -> np.ndarray:
    """Exact (correctly-rounded) posit division — the golden reference the
    paper's Table II 'wrong %' is measured against."""
    if cfg.n > 16:
        return _scalar_op(a, b, cfg, "div")
    ka, sa, tea, Ma = decode(a, cfg)
    kb, sb, teb, Mb = decode(b, cfg)
    n = cfg.n
    s = sa ^ sb
    te = tea - teb

    # quotient of mantissas in [0.5, 2): compute to n+3 fraction bits + sticky
    Wq = n + 3
    num = Ma << Wq                       # <= (n+1) + (n+3) <= 36 bits
    q = num // Mb
    # q in (2^(Wq-1), 2^(Wq+1)): if quotient < 1, recompute one bit deeper so
    # the pulled-in bit is a true quotient bit (not a zero fill).
    small = q < (np.int64(1) << Wq)
    num2 = np.where(small, num << 1, num)
    q2 = num2 // Mb
    rem2 = num2 - q2 * Mb
    st = (rem2 != 0).astype(np.int64)
    te = np.where(small, te - 1, te)

    res = _encode_fir(s, te, q2, Wq, st, cfg)
    res = np.where(ka == KLASS_ZERO, 0, res)
    res = np.where(kb == KLASS_ZERO, cfg.nar, res)   # x/0 = NaR (posit standard)
    res = np.where(_specials2(ka, kb), cfg.nar, res)
    return res


def precip(b, cfg: PositConfig) -> np.ndarray:
    """Exact reciprocal 1/b (the FPPU's inversion op)."""
    one = encode_from_float64(np.ones(np.shape(b)), cfg)
    return pdiv(one, b, cfg)


def pfma(a, b, c, cfg: PositConfig) -> np.ndarray:
    """Exact fused multiply-add round(a*b + c) — the PFMADD instruction."""
    if cfg.n > 16:
        return _scalar_fma(a, b, c, cfg)
    ka, sa, tea, Ma = decode(a, cfg)
    kb, sb, teb, Mb = decode(b, cfg)
    kc, sc, tec, Mc = decode(c, cfg)
    n = cfg.n

    sp = sa ^ sb
    tep = tea + teb
    P = Ma * Mb                          # value in [1,4) at scale 2^-2n
    Wp = 2 * n
    top = P >> (Wp + 1)
    tep = tep + top
    P = np.where(top == 1, P, P << 1)    # normalize to [2^(Wp+1), 2^(Wp+2)) scale Wp+1
    Wp = Wp + 1                          # now P in [2^Wp, 2^(Wp+1)), exact (bit kept)

    # addend at same fraction width
    Cw = Mc << (Wp - n)

    # align smaller operand to larger (by te), capture sticky
    p_big = (tep > tec) | ((tep == tec) & (P >= Cw))
    s1 = np.where(p_big, sp, sc); s2 = np.where(p_big, sc, sp)
    te1 = np.where(p_big, tep, tec); te2 = np.where(p_big, tec, tep)
    M1 = np.where(p_big, P, Cw); M2 = np.where(p_big, Cw, P)

    G = 3
    M1w = M1 << G
    M2w = M2 << G
    d = np.clip(te1 - te2, 0, Wp + G + 2)
    M2s = M2w >> d
    sticky = ((M2w & ((np.int64(1) << d) - 1)) != 0).astype(np.int64)

    eff_sub = s1 != s2
    mag = np.where(eff_sub, M1w - M2s - 0, M1w + M2s)
    mag = np.where(eff_sub & (sticky == 1), mag - 1, mag)

    W = Wp + G
    bl = _bit_length(np.maximum(mag, 1))
    shift_left = (W + 1) - bl
    sl = np.clip(shift_left, 0, 62)
    sr = np.clip(-shift_left, 0, 62)
    lost = (mag & ((np.int64(1) << sr) - 1)) != 0
    Mn = np.where(shift_left >= 0, mag << sl, mag >> sr)
    st = sticky | lost.astype(np.int64)
    ten = te1 - shift_left

    res = _encode_fir(s1, ten, np.maximum(Mn, np.int64(1) << W), W, st, cfg)
    res = np.where(mag == 0, 0, res)

    # specials: a*b zero -> result c; c zero -> result round(a*b)
    ab_zero = (ka == KLASS_ZERO) | (kb == KLASS_ZERO)
    c_zero = kc == KLASS_ZERO
    res = np.where(ab_zero, _as_bits(c, cfg), res)
    res = np.where(c_zero & ~ab_zero, pmul(a, b, cfg), res)
    res = np.where(ab_zero & c_zero, 0, res)
    nar = (ka == KLASS_NAR) | (kb == KLASS_NAR) | (kc == KLASS_NAR)
    res = np.where(nar, cfg.nar, res)
    return res


# --------------------------------------------------------------------------
# quire: exact fused dot product (posit-standard semantics)
# --------------------------------------------------------------------------
def quire_dot(a_vec, b_vec, cfg: PositConfig) -> int:
    """Exact sum_i a_i*b_i rounded once to posit — arbitrary-precision quire.

    Scalar (python-int) implementation; used as the oracle for the GEMM
    kernels' MXU-f32 'quire analogue' accumulation.
    """
    a_vec = np.asarray(a_vec).reshape(-1)
    b_vec = np.asarray(b_vec).reshape(-1)
    ka, sa, tea, Ma = decode(a_vec, cfg)
    kb, sb, teb, Mb = decode(b_vec, cfg)
    if np.any((ka == KLASS_NAR) | (kb == KLASS_NAR)):
        return cfg.nar
    acc = 0                                   # value = acc * 2^scale
    scale = 2 * (cfg.te_min - cfg.n) - 8      # below any product's LSB
    for i in range(a_vec.shape[0]):
        if ka[i] == KLASS_ZERO or kb[i] == KLASS_ZERO:
            continue
        m = int(Ma[i]) * int(Mb[i])           # scale 2^(te_a+te_b-2n)
        ex = int(tea[i] + teb[i]) - 2 * cfg.n
        acc += ((-1) ** int(sa[i] ^ sb[i])) * (m << (ex - scale))
    if acc == 0:
        return 0
    s = 1 if acc < 0 else 0
    mag = abs(acc)
    bl = mag.bit_length()
    te = bl - 1 + scale
    W = 60
    if bl - 1 >= W:
        sh = bl - 1 - W
        sticky = 1 if (mag & ((1 << sh) - 1)) != 0 else 0
        M = mag >> sh
    else:
        sticky = 0
        M = mag << (W - (bl - 1))
    return _encode_scalar_bigint(s, te, M, W, sticky, cfg)


def _encode_scalar_bigint(s, te, M, W, sticky, cfg: PositConfig) -> int:
    """Arbitrary-precision scalar version of _encode_fir (python ints)."""
    n, es = cfg.n, cfg.es
    if te > cfg.te_max:
        body = cfg.maxpos_bits
    elif te < cfg.te_min:
        body = cfg.minpos_bits
    else:
        k, e = te >> es, te - ((te >> es) << es)
        if k >= 0:
            rlen, regime = k + 2, (((1 << (k + 1)) - 1) << 1)
        else:
            rlen, regime = 1 - k, 1
        frac = M - (1 << W)
        nre = rlen + es
        combined = (regime << es) | e
        if nre < n - 1:
            ffield = (n - 1) - nre
            sh = W - ffield
            if sh <= 0:  # working fraction narrower than the field: exact fit
                body = (combined << ffield) | (frac << (-sh))
                r, st = 0, sticky
            else:
                kept = frac >> sh
                r = (frac >> (sh - 1)) & 1
                st = int((frac & ((1 << (sh - 1)) - 1)) != 0) | sticky
                body = (combined << ffield) | kept
        else:
            sh = nre - (n - 1)
            body = combined >> sh
            if sh > 0:
                r = (combined >> (sh - 1)) & 1
                st = int((combined & ((1 << (sh - 1)) - 1)) != 0) | int(frac != 0) | sticky
            else:
                r = (frac >> (W - 1)) & 1
                st = int((frac & ((1 << (W - 1)) - 1)) != 0) | sticky
        body += r & (st | (body & 1))
        body = min(body, cfg.maxpos_bits)
        body = max(body, cfg.minpos_bits)
    return ((-body) & cfg.mask) if s else body


# --------------------------------------------------------------------------
# exact scalar fallback for n > 16 (python ints; slow, test-scale only)
# --------------------------------------------------------------------------
def _decode_scalar(u: int, cfg: PositConfig):
    n, es = cfg.n, cfg.es
    u &= cfg.mask
    if u == 0:
        return KLASS_ZERO, 0, 0, 0
    if u == cfg.nar:
        return KLASS_NAR, 0, 0, 0
    s = (u >> (n - 1)) & 1
    absu = ((-u) & cfg.mask) if s else u
    x = (absu << 1) & cfg.mask
    b = (x >> (n - 1)) & 1
    y = ((~x) & cfg.mask) if b else x
    run = min(n - y.bit_length(), n - 1)
    k = (run - 1) if b else -run
    rem = (x << (run + 1)) & cfg.mask
    e = (rem >> (n - es)) if es > 0 else 0
    frac = (rem << es) & cfg.mask
    return KLASS_NORMAL, s, k * cfg.useed_exp + e, (1 << n) | frac


def _scalar_op(a, b, cfg: PositConfig, op: str) -> np.ndarray:
    a = np.atleast_1d(np.asarray(a)); b = np.atleast_1d(np.asarray(b))
    a, b = np.broadcast_arrays(a, b)
    out = np.zeros(a.shape, dtype=np.int64)
    it = np.nditer(a, flags=["multi_index"])
    n = cfg.n
    for _ in it:
        idx = it.multi_index
        ka, sa, tea, Ma = _decode_scalar(int(a[idx]), cfg)
        kb, sb, teb, Mb = _decode_scalar(int(b[idx]), cfg)
        if ka == KLASS_NAR or kb == KLASS_NAR or (op == "div" and kb == KLASS_ZERO):
            out[idx] = cfg.nar
            continue
        if op == "mul":
            if ka == KLASS_ZERO or kb == KLASS_ZERO:
                out[idx] = 0
                continue
            P, W, te = Ma * Mb, 2 * n, tea + teb
            if P >> (W + 1):
                te, st, P = te + 1, P & 1, P >> 1
            else:
                st = 0
            out[idx] = _encode_scalar_bigint(sa ^ sb, te, P, W, st, cfg)
        elif op == "div":
            if ka == KLASS_ZERO:
                out[idx] = 0
                continue
            Wq = n + 3
            num = Ma << (Wq + 1)
            q, r = divmod(num, Mb)
            te = tea - teb - 1
            if q >> (Wq + 1):
                r |= q & 1
                q >>= 1
                te += 1
            out[idx] = _encode_scalar_bigint(sa ^ sb, te, q, Wq, int(r != 0), cfg)
        elif op == "add":
            if ka == KLASS_ZERO:
                out[idx] = int(b[idx]) & cfg.mask
                continue
            if kb == KLASS_ZERO:
                out[idx] = int(a[idx]) & cfg.mask
                continue
            # exact via big ints at a common scale 2^(min(te)-n)
            acc = ((-1) ** sa) * (Ma << max(tea - teb, 0)) + (
                (-1) ** sb
            ) * (Mb << max(teb - tea, 0))
            if acc == 0:
                out[idx] = 0
                continue
            base = min(tea, teb) - n
            s = 1 if acc < 0 else 0
            mag = abs(acc)
            bl = mag.bit_length()
            te = bl - 1 + base
            W = max(bl - 1, 1)
            out[idx] = _encode_scalar_bigint(
                s, te, mag << (W - (bl - 1)), W, 0, cfg
            )
        else:
            raise ValueError(op)
    return out.reshape(np.shape(a))


def _scalar_fma(a, b, c, cfg: PositConfig) -> np.ndarray:
    a = np.atleast_1d(np.asarray(a)); b = np.atleast_1d(np.asarray(b)); c = np.atleast_1d(np.asarray(c))
    a, b, c = np.broadcast_arrays(a, b, c)
    out = np.zeros(a.shape, dtype=np.int64)
    it = np.nditer(a, flags=["multi_index"])
    for _ in it:
        idx = it.multi_index
        out[idx] = quire_dot(
            np.array([a[idx], c[idx]]),
            np.array([b[idx], encode_from_float64(np.array(1.0), cfg)]),
            cfg,
        )
    return out
