"""Posit arithmetic "intrinsics" — the JAX analogue of the paper's ISA
extension (§VI: PADD/PSUB/PMUL/PDIV/PFMADD + inversion).

Each op is the three-stage FPPU datapath (§V): decode -> integer-domain
compute -> RNE encode.  All integer arithmetic fits int32 by construction
(see decode.work_frac_bits); every op is bit-exact against core.golden for
n <= 16 (tested exhaustively for p8, sampled + property-based for p16).

Division (§V-A) has three modes:
  * "exact":   integer long division (digit-recurrence golden; correctly rounded)
  * "poly"     paper-faithful: Alg.1 reciprocal (optimized k1/k2) + NR rounds
  * "poly_corrected": poly + exact int32 remainder fix-up -> correctly rounded
                at approx-pipeline cost (beyond-paper; default for kernels)

Comparison needs no op: posit patterns compare as 2's-complement integers
(paper §VIII — "posits can be compared as signed integers").
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import recip as _recip
from repro.core.bitutil import bit_length32
from repro.core.decode import (KLASS_NAR, KLASS_NORMAL, KLASS_ZERO, as_bits32,
                               decode, work_frac_bits)
from repro.core.encode import encode_fir, to_storage
from repro.core.types import PositConfig


def _bit_length(x: jnp.ndarray) -> jnp.ndarray:
    return bit_length32(jnp.maximum(x, 1))


def _nar_mask(*klasses):
    m = klasses[0] == KLASS_NAR
    for k in klasses[1:]:
        m = m | (k == KLASS_NAR)
    return m


def pneg(a, cfg: PositConfig) -> jnp.ndarray:
    u = as_bits32(a, cfg)
    out = jnp.where(u == cfg.nar, cfg.nar, (-u) & cfg.mask)
    return to_storage(out, cfg)


def pabs(a, cfg: PositConfig) -> jnp.ndarray:
    u = as_bits32(a, cfg)
    neg = ((u >> (cfg.n - 1)) & 1) == 1
    out = jnp.where(neg & (u != cfg.nar), (-u) & cfg.mask, u)
    return to_storage(out, cfg)


# --------------------------------------------------------------------------
# addition / subtraction (paper §IV-A)
# --------------------------------------------------------------------------
def padd(a, b, cfg: PositConfig) -> jnp.ndarray:
    n = cfg.n
    Wd = work_frac_bits(cfg)
    ka, sa, tea, Ma = decode(a, cfg)
    kb, sb, teb, Mb = decode(b, cfg)

    # order |p1| >= |p2|
    swap = (teb > tea) | ((teb == tea) & (Mb > Ma))
    s1 = jnp.where(swap, sb, sa); s2 = jnp.where(swap, sa, sb)
    te1 = jnp.where(swap, teb, tea); te2 = jnp.where(swap, tea, teb)
    M1 = jnp.where(swap, Mb, Ma); M2 = jnp.where(swap, Ma, Mb)

    G = 3
    W = Wd + G                                    # = n
    M1w = M1 << G
    M2w = M2 << G
    d = jnp.clip(te1 - te2, 0, W + 2)
    M2s = M2w >> d
    sticky = ((M2w & ((jnp.int32(1) << d) - 1)) != 0).astype(jnp.int32)

    eff_sub = s1 != s2
    mag = jnp.where(eff_sub, M1w - M2s, M1w + M2s)
    mag = jnp.where(eff_sub & (sticky == 1), mag - 1, mag)

    shift_left = (W + 1) - _bit_length(mag)
    sl = jnp.clip(shift_left, 0, 31)
    sr = jnp.clip(-shift_left, 0, 31)
    lost = (mag & ((jnp.int32(1) << sr) - 1)) != 0
    Mn = jnp.where(shift_left >= 0, mag << sl, mag >> sr)
    st = sticky | lost.astype(jnp.int32)
    ten = te1 - shift_left

    res = encode_fir(s1, ten, jnp.maximum(Mn, jnp.int32(1) << W), W, st, cfg)
    res = jnp.where(mag == 0, 0, res)
    res = jnp.where(ka == KLASS_ZERO, as_bits32(b, cfg), res)
    res = jnp.where(kb == KLASS_ZERO, as_bits32(a, cfg), res)
    res = jnp.where((ka == KLASS_ZERO) & (kb == KLASS_ZERO), 0, res)
    res = jnp.where(_nar_mask(ka, kb), cfg.nar, res)
    return to_storage(res, cfg)


def psub(a, b, cfg: PositConfig) -> jnp.ndarray:
    return padd(a, pneg(b, cfg), cfg)


# --------------------------------------------------------------------------
# multiplication (paper §IV-B)
# --------------------------------------------------------------------------
def pmul(a, b, cfg: PositConfig) -> jnp.ndarray:
    Wd = work_frac_bits(cfg)
    ka, sa, tea, Ma = decode(a, cfg)
    kb, sb, teb, Mb = decode(b, cfg)

    s = sa ^ sb
    te = tea + teb
    P = Ma * Mb                                   # <= 2*(n-2) <= 28 bits
    W = 2 * Wd
    top = (P >> (W + 1)) & 1
    te = te + top
    M = jnp.where(top == 1, P >> 1, P)
    st = jnp.where(top == 1, P & 1, 0)

    res = encode_fir(s, te, M, W, st, cfg)
    res = jnp.where((ka == KLASS_ZERO) | (kb == KLASS_ZERO), 0, res)
    res = jnp.where(_nar_mask(ka, kb), cfg.nar, res)
    return to_storage(res, cfg)


# --------------------------------------------------------------------------
# division (paper §IV-C, §V-A)
# --------------------------------------------------------------------------
def pdiv(a, b, cfg: PositConfig, mode: str = "poly_corrected",
         nr_rounds: int = 1) -> jnp.ndarray:
    """Posit division.  mode in {"exact", "poly", "poly_corrected", "pacogen"}.

    "poly" is the paper's proposed pipeline (Alg. 1 with the optimized
    k1/k2 + `nr_rounds` Newton-Raphson); "pacogen" is the LUT baseline of
    Table II; both are *approximate* (nonzero wrong-%).  "poly_corrected"
    adds an exact integer remainder fix-up (correctly rounded; beyond-paper).
    """
    n = cfg.n
    ka, sa, tea, Ma = decode(a, cfg)
    kb, sb, teb, Mb = decode(b, cfg)
    s = sa ^ sb
    te = tea - teb

    Wq = n
    num = Ma << (Wq + 1)                          # <= (n-2)+(n+1) = 2n-1 bits

    if mode == "exact":
        q = num // Mb
        rem = num - q * Mb
    else:
        q = _recip.approx_quotient(Ma, Mb, cfg, mode=mode, nr_rounds=nr_rounds, wq=Wq)
        if mode == "poly_corrected":
            # exact remainder fix-up: for any integer estimate q,
            # q + floor((num - q*Mb)/Mb) == floor(num/Mb) exactly — one
            # multiply + one small division replaces the full long division.
            q = q + (num - q * Mb) // Mb
            rem = num - q * Mb                    # in [0, Mb)
        else:
            rem = jnp.zeros_like(q)

    te = te - 1
    # q in (2^Wq, 2^(Wq+2)): fold top bit
    big = (q >> (Wq + 1)) & 1
    stq = jnp.where(big == 1, q & 1, 0)
    q = jnp.where(big == 1, q >> 1, q)
    te = te + big
    if mode in ("exact", "poly_corrected"):
        st = (rem != 0).astype(jnp.int32) | stq
    else:
        # approximate pipeline: no remainder available; sticky unknown.
        # Treat the residual as inexact (matches the FPGA datapath which
        # rounds from a truncated fixed-point quotient).
        st = jnp.ones_like(q) | stq

    res = encode_fir(s, te, jnp.maximum(q, jnp.int32(1) << Wq), Wq, st, cfg)
    res = jnp.where(ka == KLASS_ZERO, 0, res)
    res = jnp.where(kb == KLASS_ZERO, cfg.nar, res)   # x/0 = NaR
    res = jnp.where(_nar_mask(ka, kb), cfg.nar, res)
    return to_storage(res, cfg)


def precip(b, cfg: PositConfig, mode: str = "poly_corrected") -> jnp.ndarray:
    """Reciprocal (the FPPU inversion op): 1/b."""
    one = jnp.asarray(cfg.one_bits, dtype=jnp.int32)
    ones = jnp.broadcast_to(one, jnp.shape(b))
    return pdiv(ones, b, cfg, mode=mode)


# --------------------------------------------------------------------------
# fused multiply-add (PFMADD): round(a*b + c) with a single rounding
# --------------------------------------------------------------------------
def pfma(a, b, c, cfg: PositConfig) -> jnp.ndarray:
    n = cfg.n
    Wd = work_frac_bits(cfg)
    ka, sa, tea, Ma = decode(a, cfg)
    kb, sb, teb, Mb = decode(b, cfg)
    kc, sc, tec, Mc = decode(c, cfg)

    sp = sa ^ sb
    tep = tea + teb
    P = Ma * Mb
    top = (P >> (2 * Wd + 1)) & 1
    tep = tep + top
    P = jnp.where(top == 1, P, P << 1)            # normalize, keep every bit
    Wp = 2 * Wd + 1                               # P in [2^Wp, 2^(Wp+1))

    Cw = Mc << (Wp - Wd)

    p_big = (tep > tec) | ((tep == tec) & (P >= Cw))
    s1 = jnp.where(p_big, sp, sc); s2 = jnp.where(p_big, sc, sp)
    te1 = jnp.where(p_big, tep, tec); te2 = jnp.where(p_big, tec, tep)
    M1 = jnp.where(p_big, P, Cw); M2 = jnp.where(p_big, Cw, P)

    G = 2
    W = Wp + G                                    # = 2n-3 <= 29
    M1w = M1 << G
    M2w = M2 << G
    d = jnp.clip(te1 - te2, 0, W + 2)
    M2s = M2w >> d
    sticky = ((M2w & ((jnp.int32(1) << d) - 1)) != 0).astype(jnp.int32)

    eff_sub = s1 != s2
    mag = jnp.where(eff_sub, M1w - M2s, M1w + M2s)
    mag = jnp.where(eff_sub & (sticky == 1), mag - 1, mag)

    shift_left = (W + 1) - _bit_length(mag)
    sl = jnp.clip(shift_left, 0, 31)
    sr = jnp.clip(-shift_left, 0, 31)
    lost = (mag & ((jnp.int32(1) << sr) - 1)) != 0
    Mn = jnp.where(shift_left >= 0, mag << sl, mag >> sr)
    st = sticky | lost.astype(jnp.int32)
    ten = te1 - shift_left

    res = encode_fir(s1, ten, jnp.maximum(Mn, jnp.int32(1) << W), W, st, cfg)
    res = jnp.where(mag == 0, 0, res)

    ab_zero = (ka == KLASS_ZERO) | (kb == KLASS_ZERO)
    c_zero = kc == KLASS_ZERO
    # a*b == 0 -> c ;  c == 0 -> round(a*b) (datapath already handles via Mc,
    # but the decode stub for zero lanes is garbage, so mask explicitly)
    mul_bits = as_bits32(pmul(a, b, cfg), cfg)
    res = jnp.where(ab_zero, as_bits32(c, cfg), res)
    res = jnp.where(c_zero & ~ab_zero, mul_bits, res)
    res = jnp.where(ab_zero & c_zero, 0, res)
    res = jnp.where(_nar_mask(ka, kb, kc), cfg.nar, res)
    return to_storage(res, cfg)


# --------------------------------------------------------------------------
# comparisons (free: patterns are monotone 2's-complement integers)
# --------------------------------------------------------------------------
def plt(a, b, cfg: PositConfig) -> jnp.ndarray:
    sa = (as_bits32(a, cfg) << (32 - cfg.n)) >> (32 - cfg.n)
    sb = (as_bits32(b, cfg) << (32 - cfg.n)) >> (32 - cfg.n)
    return sa < sb


def peq(a, b, cfg: PositConfig) -> jnp.ndarray:
    return as_bits32(a, cfg) == as_bits32(b, cfg)
