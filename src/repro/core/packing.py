"""SIMD lane packing — paper §VIII-A (C4).

The FPPU packs 4 posit8 (or 2 posit16) operands into one 32-bit register and
replicates the unit per lane, quadrupling/doubling throughput with the same
opcode.  On TPU the VPU already processes int8 arrays at full lane density —
the *storage layout* is the transferable part: these helpers provide the
ISA-faithful packed-word view (used by the serving KV-cache layout and the
gradient-compression collective, where payloads travel as int32 words).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.types import PositConfig


def lanes(cfg: PositConfig) -> int:
    """SIMD lanes per 32-bit word: 4 for posit8, 2 for posit16 (paper C4)."""
    return 32 // cfg.storage_bits


def pack_words(p: jnp.ndarray, cfg: PositConfig) -> jnp.ndarray:
    """[..., L*k] posit storage ints -> [..., k] int32 packed words.

    Lane 0 occupies the least-significant bits (matches the paper's register
    convention: a single posit goes in the LSBs).
    """
    L = lanes(cfg)
    b = cfg.storage_bits
    if p.shape[-1] % L:
        raise ValueError(f"last dim {p.shape[-1]} not divisible by {L} lanes")
    u = p.astype(jnp.int32) & ((1 << b) - 1)
    u = u.reshape(*p.shape[:-1], p.shape[-1] // L, L)
    shifts = jnp.arange(L, dtype=jnp.int32) * b
    return jnp.sum(u << shifts, axis=-1).astype(jnp.int32)


def unpack_words(w: jnp.ndarray, cfg: PositConfig) -> jnp.ndarray:
    """[..., k] int32 packed words -> [..., k*L] posit storage ints."""
    L = lanes(cfg)
    b = cfg.storage_bits
    shifts = jnp.arange(L, dtype=jnp.int32) * b
    u = (w[..., None] >> shifts) & ((1 << b) - 1)
    # sign-extend the N-bit pattern into the storage dtype
    u = (u << (32 - cfg.n)) >> (32 - cfg.n)
    return u.astype(jnp.dtype(f"int{b}")).reshape(*w.shape[:-1], w.shape[-1] * L)


def packed_map(op, w1: jnp.ndarray, w2: jnp.ndarray, cfg: PositConfig) -> jnp.ndarray:
    """Apply a two-operand posit op lane-wise on packed words (same opcode,
    L results per word — the paper's SIMD dispatch)."""
    a = unpack_words(w1, cfg)
    b = unpack_words(w2, cfg)
    return pack_words(op(a, b, cfg), cfg)
