"""Quire-style fused accumulation (paper Table I "Quire/Fused support").

The posit standard quire is an exact fixed-point accumulator; the FPPU
exposes it through PFMADD.  The TPU-native analogue: decode posits to exact
f32 (lossless for n <= 16), accumulate dot products in the MXU's f32
accumulator, round to posit once.  One rounding per reduction — the quire
semantics — with the accumulator precision being f32 instead of exact
fixed-point (deviation recorded in DESIGN.md §2).

`quire_dot_exact` in core.golden is the arbitrary-precision oracle.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.convert import f32_to_posit
from repro.core.decode import decode_to_f32
from repro.core.types import PositConfig


def quire_matmul(a_bits: jnp.ndarray, b_bits: jnp.ndarray, cfg: PositConfig,
                 out_posit: bool = True) -> jnp.ndarray:
    """[m,k] x [k,n] posit matmul with single-rounding (quire) semantics.

    Pure-jnp reference path; the Pallas kernel (kernels/posit_gemm.py) fuses
    the decode into the tile pipeline.  Products are exact in f32
    (<=14-bit mantissas); accumulation is f32 (MXU).
    """
    a = decode_to_f32(a_bits, cfg)
    b = decode_to_f32(b_bits, cfg)
    acc = jnp.dot(a, b, preferred_element_type=jnp.float32)
    return f32_to_posit(acc, cfg) if out_posit else acc


def quire_dot(a_bits: jnp.ndarray, b_bits: jnp.ndarray, cfg: PositConfig,
              out_posit: bool = True) -> jnp.ndarray:
    """Fused dot product over the last axis with quire semantics."""
    a = decode_to_f32(a_bits, cfg)
    b = decode_to_f32(b_bits, cfg)
    acc = jnp.sum(a * b, axis=-1, dtype=jnp.float32)
    return f32_to_posit(acc, cfg) if out_posit else acc
