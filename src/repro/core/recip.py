"""Reciprocal approximation — the paper's §V-A contribution (C2).

Implements:
  * Algorithm 1 (from [19]): y = 4*(k2 - x*(k1-x))*(k1-x), two multiplies
    (the *4 is a shift), with the paper's *optimized* constants obtained by
    minimizing the integral relative error over x in (0.5, 1):
        k1_opt = 1.4567844114901045,  k2_opt = 1.0009290026616422
    (36.4% better than [19]; re-derived numerically in
    benchmarks/division_accuracy.py).
  * Optional Newton-Raphson refinement rounds: y <- y*(2 - x*y).
  * The PACoGen baseline [11]: 2^IN-entry LUT (IN=8 fraction bits in,
    OUT=9 bits out) + NR rounds — the comparison row of Table II.

The FPGA datapath evaluates Alg. 1 in fixed point; the TPU-native
realisation here does the same — int32 fixed point with explicit split
multiplies — because it must be *bit-deterministic across backends*.  An
earlier f32 evaluation was not: XLA may (and does, depending on the
compilation context — eager vs jit vs Mosaic) contract `a*b + c` chains
into FMAs, which changes the final ulp of the quotient estimate and made
`kernels.posit_elementwise.divide(mode="poly")` disagree with
`kernels.ref.divide_ref` on ~0.01% of posit16es1 operand pairs (see
tests/test_divide_regression.py for the characterization).  Integer ops
have no contraction freedom, so kernel == ref by construction everywhere.

Fixed-point layout (everything fits int32 for n <= 16, the FPPU width
guarantee of core.decode):

    x  = m_b/2   in [0.5, 1)   14 frac bits (exact: X = Mb << (13 - Wd))
    b,c,d,e,y    intermediates 14/28/28/28/28 frac bits
    products     split hi/lo at 14 bits so every partial fits int32;
                 each split truncation loses < 2^-28 absolute.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.decode import work_frac_bits
from repro.core.types import PositConfig

# Paper §V-A optimized constants (eq. 13 solution).
K1_OPT = 1.4567844114901045
K2_OPT = 1.0009290026616422

# Constants of the original formulation [19] (for the ablation benchmark).
K1_REF19 = 1.466
K2_REF19 = 1.0012

PACOGEN_LUT_IN = 8    # fraction bits indexing the LUT (Table II "IN")
PACOGEN_LUT_OUT = 9   # reciprocal fraction bits produced (Table II "OUT")


def recip_poly_f32(x: jnp.ndarray, k1: float = K1_OPT, k2: float = K2_OPT) -> jnp.ndarray:
    """Algorithm 1 on x in (0.5, 1]: ~1/x with 2 multiplies + shift."""
    b = k1 - x
    c = x * b
    d = k2 - c
    e = d * b
    return 4.0 * e


def nr_round(y: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """One Newton-Raphson refinement of y ~= 1/x."""
    return y * (2.0 - x * y)


def _pacogen_table() -> np.ndarray:
    """PACoGen-style reciprocal LUT: IN fraction bits -> OUT-bit 1/m mantissa.

    m = 1.f in [1, 2) -> y = 1/m in (0.5, 1]; stored as round(y * 2^OUT),
    midpoint-sampled per entry (standard LUT construction).
    """
    idx = np.arange(1 << PACOGEN_LUT_IN, dtype=np.float64)
    m = 1.0 + (idx + 0.5) / (1 << PACOGEN_LUT_IN)
    y = 1.0 / m
    return np.round(y * (1 << PACOGEN_LUT_OUT)).astype(np.int32)


_PACOGEN_LUT = _pacogen_table()


def pacogen_lut_i32(mb_frac: jnp.ndarray, cfg: PositConfig) -> jnp.ndarray:
    """PACoGen LUT lookup: divisor fraction bits -> int 1/m mantissa with
    PACOGEN_LUT_OUT frac bits (m in [1, 2), entries in [2^(OUT-1), 2^OUT]).

    mb_frac: the Wd-bit fraction of the divisor mantissa (hidden bit removed).
    Pallas kernels patch this hook to read the LUT from a kernel input
    (Pallas forbids captured array constants).
    """
    Wd = work_frac_bits(cfg)
    if Wd >= PACOGEN_LUT_IN:
        idx = mb_frac >> (Wd - PACOGEN_LUT_IN)
    else:
        idx = mb_frac << (PACOGEN_LUT_IN - Wd)
    lut = jnp.asarray(_PACOGEN_LUT)
    return lut[idx].astype(jnp.int32)


def recip_pacogen_f32(mb_frac: jnp.ndarray, cfg: PositConfig) -> jnp.ndarray:
    """f32 view of the LUT reciprocal (ablation/benchmark convenience)."""
    return (pacogen_lut_i32(mb_frac, cfg).astype(jnp.float32)
            * jnp.float32(1.0 / (1 << PACOGEN_LUT_OUT)))


# ---- int32 fixed-point datapath (the deterministic TPU realisation) -------
_YF = 28          # frac bits of the reciprocal estimate y
_SPLIT = 14       # hi/lo split point of 28f operands in the split multiplies


def _mul_y(A: jnp.ndarray, Y: jnp.ndarray) -> jnp.ndarray:
    """(A * Y) >> 14 for A with <= 16 int bits and Y a 28f value <= ~2^30.

    Split Y at 14 bits so both partial products fit int32; the dropped
    low-product tail is < 2^-14 of one 28f ulp.  Works for negative A
    (arithmetic shifts are floor division; Y must be nonnegative).
    """
    Yh = Y >> _SPLIT
    Yl = Y & ((jnp.int32(1) << _SPLIT) - 1)
    return A * Yh + ((A * Yl) >> _SPLIT)


def recip_poly_fx(X: jnp.ndarray, k1: float = K1_OPT,
                  k2: float = K2_OPT) -> jnp.ndarray:
    """Algorithm 1 in int32 fixed point: X = x*2^14, x in [0.5, 1) ->
    y0 = 4*(k2 - x*(k1-x))*(k1-x) at 28 frac bits."""
    K1q = jnp.int32(round(k1 * (1 << 14)))        # 14f
    K2q = jnp.int32(round(k2 * (1 << _YF)))       # 28f
    B = K1q - X                                   # 14f, b in (0.457, 0.957]
    C = X * B                                     # 28f exact, c < 1
    D = K2q - C                                   # 28f, d in (0.044, 0.767]
    E = _mul_y(B, D)                              # 28f, e = d*b < 0.735
    return E << 2                                 # 28f, y0 = 4e in (0.17, 2.94]


def nr_round_fx(Y: jnp.ndarray, X: jnp.ndarray) -> jnp.ndarray:
    """One Newton-Raphson round y <- y*(2 - x*y) in fixed point.

    Y: 28f reciprocal estimate; X: x at 14 frac bits (poly: x in [0.5,1);
    pacogen: m_b in [1,2) via X2 = Mb << (14-Wd)).  u = 2-t can go negative
    on garbage lanes; arithmetic shifts keep that deterministic and the
    final clip in approx_quotient discards it.
    """
    mask = (jnp.int32(1) << _SPLIT) - 1
    T = _mul_y(X, Y)                              # 28f, t = x*y ~= 1
    U = (jnp.int32(2) << _YF) - T                 # 28f, u = 2 - t
    # y' = u*y, split U at 14 bits (Uh arithmetic-shifted, Ul nonnegative)
    return _mul_y(U >> _SPLIT, Y) + (((U & mask) * (Y >> _SPLIT)) >> _SPLIT)


def approx_quotient(Ma: jnp.ndarray, Mb: jnp.ndarray, cfg: PositConfig, *,
                    mode: str, nr_rounds: int, wq: int,
                    k1: float = K1_OPT, k2: float = K2_OPT) -> jnp.ndarray:
    """Integer quotient mantissa q ~= (Ma << (wq+1)) / Mb, in (2^wq, 2^(wq+2)).

    Ma, Mb: decoded significands in [2^Wd, 2^(Wd+1)).  The result feeds the
    shared posit rounding stage (ops.pdiv), optionally after an exact
    remainder fix-up.  All arithmetic is int32 fixed point, so the estimate
    is bit-identical in eager jnp, jit, Pallas interpret and Mosaic — no
    FP-contraction sensitivity (see module docstring).
    """
    Wd = work_frac_bits(cfg)

    if mode in ("poly", "poly_corrected"):
        # x = m_b / 2 in [0.5, 1); y ~= 1/x = 2/m_b in (1, 2]
        X = Mb << (13 - Wd)                       # 14f exact
        Y = recip_poly_fx(X, k1, k2)              # 28f
        for _ in range(nr_rounds):
            Y = nr_round_fx(Y, X)
        # q = m_a * y * 2^(wq - Wd) = Ma * Y * 2^(wq - Wd - 28); wq-Wd == 3
        q = _mul_y(Ma, Y) >> (_YF - _SPLIT - (wq - Wd))
    elif mode == "pacogen":
        frac = Mb - (jnp.int32(1) << Wd)
        Y = pacogen_lut_i32(frac, cfg) << (_YF - PACOGEN_LUT_OUT)  # 28f
        X2 = Mb << (14 - Wd)                      # m_b in [1, 2) at 14f
        for _ in range(nr_rounds):
            Y = nr_round_fx(Y, X2)
        # q = m_a * y * 2^(wq + 1 - Wd); wq+1-Wd == 4
        q = _mul_y(Ma, Y) >> (_YF - _SPLIT - (wq + 1 - Wd))
    else:
        raise ValueError(f"unknown division mode {mode!r}")

    return jnp.clip(q, jnp.int32(1), jnp.int32(1) << (wq + 2))


# --------------------------------------------------------------------------
# paper eq. (12)-(13): the k1/k2 optimization problem (used by benchmarks to
# re-derive the constants; numpy-only, runs in milliseconds)
# --------------------------------------------------------------------------
def squared_rel_err(k1: float, k2: float, num_pts: int = 20001) -> float:
    """e^2(k1,k2) = integral over (1/2, 1) of ((y - 1/x)*x)^2 dx  (eq. 12)."""
    x = np.linspace(0.5, 1.0, num_pts)
    y = 4.0 * (k2 - x * (k1 - x)) * (k1 - x)
    rerr = y * x - 1.0
    return float(np.trapezoid(rerr * rerr, x))


def optimize_k1_k2(iters: int = 200) -> tuple[float, float, float]:
    """Re-derive (k1_opt, k2_opt) by Newton descent on eq. (13)."""
    k = np.array([1.45, 1.0])
    h = 1e-6
    for _ in range(iters):
        def f(v):
            return squared_rel_err(v[0], v[1])
        g = np.array([
            (f(k + [h, 0]) - f(k - [h, 0])) / (2 * h),
            (f(k + [0, h]) - f(k - [0, h])) / (2 * h),
        ])
        H = np.zeros((2, 2))
        for i in range(2):
            for j in range(2):
                ei = np.eye(2)[i] * h
                ej = np.eye(2)[j] * h
                H[i, j] = (f(k + ei + ej) - f(k + ei - ej)
                           - f(k - ei + ej) + f(k - ei - ej)) / (4 * h * h)
        step = np.linalg.solve(H, g)
        k = k - step
        if np.max(np.abs(step)) < 1e-12:
            break
    return float(k[0]), float(k[1]), squared_rel_err(k[0], k[1])
