"""Reciprocal approximation — the paper's §V-A contribution (C2).

Implements:
  * Algorithm 1 (from [19]): y = 4*(k2 - x*(k1-x))*(k1-x), two multiplies
    (the *4 is a shift), with the paper's *optimized* constants obtained by
    minimizing the integral relative error over x in (0.5, 1):
        k1_opt = 1.4567844114901045,  k2_opt = 1.0009290026616422
    (36.4% better than [19]; re-derived numerically in
    benchmarks/division_accuracy.py).
  * Optional Newton-Raphson refinement rounds: y <- y*(2 - x*y).
  * The PACoGen baseline [11]: 2^IN-entry LUT (IN=8 fraction bits in,
    OUT=9 bits out) + NR rounds — the comparison row of Table II.

The FPGA datapath evaluates Alg. 1 in fixed point; the TPU-native
realisation here evaluates it in f32 on the VPU (exactly representable
inputs: mantissas have <= 14 bits) and converts the quotient back to an
integer mantissa for the posit rounding stage.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.decode import work_frac_bits
from repro.core.types import PositConfig

# Paper §V-A optimized constants (eq. 13 solution).
K1_OPT = 1.4567844114901045
K2_OPT = 1.0009290026616422

# Constants of the original formulation [19] (for the ablation benchmark).
K1_REF19 = 1.466
K2_REF19 = 1.0012

PACOGEN_LUT_IN = 8    # fraction bits indexing the LUT (Table II "IN")
PACOGEN_LUT_OUT = 9   # reciprocal fraction bits produced (Table II "OUT")


def recip_poly_f32(x: jnp.ndarray, k1: float = K1_OPT, k2: float = K2_OPT) -> jnp.ndarray:
    """Algorithm 1 on x in (0.5, 1]: ~1/x with 2 multiplies + shift."""
    b = k1 - x
    c = x * b
    d = k2 - c
    e = d * b
    return 4.0 * e


def nr_round(y: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """One Newton-Raphson refinement of y ~= 1/x."""
    return y * (2.0 - x * y)


def _pacogen_table() -> np.ndarray:
    """PACoGen-style reciprocal LUT: IN fraction bits -> OUT-bit 1/m mantissa.

    m = 1.f in [1, 2) -> y = 1/m in (0.5, 1]; stored as round(y * 2^OUT),
    midpoint-sampled per entry (standard LUT construction).
    """
    idx = np.arange(1 << PACOGEN_LUT_IN, dtype=np.float64)
    m = 1.0 + (idx + 0.5) / (1 << PACOGEN_LUT_IN)
    y = 1.0 / m
    return np.round(y * (1 << PACOGEN_LUT_OUT)).astype(np.int32)


_PACOGEN_LUT = _pacogen_table()


def recip_pacogen_f32(mb_frac: jnp.ndarray, cfg: PositConfig) -> jnp.ndarray:
    """PACoGen LUT lookup: divisor fraction bits -> f32 approx of 1/m, m in [1,2).

    mb_frac: the Wd-bit fraction of the divisor mantissa (hidden bit removed).
    """
    Wd = work_frac_bits(cfg)
    if Wd >= PACOGEN_LUT_IN:
        idx = mb_frac >> (Wd - PACOGEN_LUT_IN)
    else:
        idx = mb_frac << (PACOGEN_LUT_IN - Wd)
    lut = jnp.asarray(_PACOGEN_LUT)
    y = lut[idx].astype(jnp.float32) * jnp.float32(1.0 / (1 << PACOGEN_LUT_OUT))
    return y


def approx_quotient(Ma: jnp.ndarray, Mb: jnp.ndarray, cfg: PositConfig, *,
                    mode: str, nr_rounds: int, wq: int,
                    k1: float = K1_OPT, k2: float = K2_OPT) -> jnp.ndarray:
    """Integer quotient mantissa q ~= (Ma << (wq+1)) / Mb, in (2^wq, 2^(wq+2)).

    Ma, Mb: decoded significands in [2^Wd, 2^(Wd+1)).  The result feeds the
    shared posit rounding stage (ops.pdiv), optionally after an exact
    remainder fix-up.
    """
    Wd = work_frac_bits(cfg)
    ma = Ma.astype(jnp.float32)
    mb = Mb.astype(jnp.float32)

    if mode in ("poly", "poly_corrected"):
        # x = m_b / 2 in (0.5, 1]; y ~= 1/x = 2/m_b
        x = mb * jnp.float32(2.0 ** -(Wd + 1))
        y = recip_poly_f32(x, k1, k2)
        for _ in range(nr_rounds):
            y = nr_round(y, x)
        # q = m_a * (y/2) * 2^(wq+1) = Ma * y * 2^(wq - Wd)
        q = ma * y * jnp.float32(2.0 ** (wq - Wd))
    elif mode == "pacogen":
        frac = Mb - (jnp.int32(1) << Wd)
        y = recip_pacogen_f32(frac, cfg)          # ~ 1/m_b in (0.5, 1]
        x = mb * jnp.float32(2.0 ** -Wd)          # m_b in [1, 2)
        for _ in range(nr_rounds):
            y = nr_round(y, x)
        # q = m_a * y * 2^(wq+1) = Ma * y * 2^(wq + 1 - Wd)
        q = ma * y * jnp.float32(2.0 ** (wq + 1 - Wd))
    else:
        raise ValueError(f"unknown division mode {mode!r}")

    return jnp.clip(q, 1.0, 2.0 ** (wq + 2)).astype(jnp.int32)


# --------------------------------------------------------------------------
# paper eq. (12)-(13): the k1/k2 optimization problem (used by benchmarks to
# re-derive the constants; numpy-only, runs in milliseconds)
# --------------------------------------------------------------------------
def squared_rel_err(k1: float, k2: float, num_pts: int = 20001) -> float:
    """e^2(k1,k2) = integral over (1/2, 1) of ((y - 1/x)*x)^2 dx  (eq. 12)."""
    x = np.linspace(0.5, 1.0, num_pts)
    y = 4.0 * (k2 - x * (k1 - x)) * (k1 - x)
    rerr = y * x - 1.0
    return float(np.trapezoid(rerr * rerr, x))


def optimize_k1_k2(iters: int = 200) -> tuple[float, float, float]:
    """Re-derive (k1_opt, k2_opt) by Newton descent on eq. (13)."""
    k = np.array([1.45, 1.0])
    h = 1e-6
    for _ in range(iters):
        def f(v):
            return squared_rel_err(v[0], v[1])
        g = np.array([
            (f(k + [h, 0]) - f(k - [h, 0])) / (2 * h),
            (f(k + [0, h]) - f(k - [0, h])) / (2 * h),
        ])
        H = np.zeros((2, 2))
        for i in range(2):
            for j in range(2):
                ei = np.eye(2)[i] * h
                ej = np.eye(2)[j] * h
                H[i, j] = (f(k + ei + ej) - f(k + ei - ej)
                           - f(k - ei + ej) + f(k - ei - ej)) / (4 * h * h)
        step = np.linalg.solve(H, g)
        k = k - step
        if np.max(np.abs(step)) < 1e-12:
            break
    return float(k[0]), float(k[1]), squared_rel_err(k[0], k[1])
