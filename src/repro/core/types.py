"""Posit format descriptors and FIR (Floating-point Intermediate Representation).

The paper (§III) defines Posit<N, ES>: 1 sign bit, run-length-encoded regime,
up to ES exponent bits, remaining bits fraction.  Decoded posits are carried
through the datapath in the paper's FIR form  (s, te, 1.f)  where
``te = 2^ES * k + e`` is the unbiased total exponent (§IV).

Everything here is pure metadata — no jax import — so configs can be built
anywhere (including before device initialisation in launch scripts).
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache


@dataclasses.dataclass(frozen=True)
class PositConfig:
    """Static description of a Posit<N, ES> format.

    Attributes:
      n:  total width in bits (4..32 supported; 8/16 are the paper's DNN formats).
      es: maximum exponent field width in bits (0..4 swept in the paper's Table II).
    """

    n: int
    es: int

    def __post_init__(self) -> None:
        if not (2 <= self.n <= 32):
            raise ValueError(f"posit width must be in [2, 32], got {self.n}")
        if not (0 <= self.es <= 6):
            raise ValueError(f"posit es must be in [0, 6], got {self.es}")

    # ---- derived constants (all python ints; usable in traced code) ----
    @property
    def mask(self) -> int:
        """N-bit all-ones mask."""
        return (1 << self.n) - 1

    @property
    def sign_bit(self) -> int:
        return 1 << (self.n - 1)

    @property
    def nar(self) -> int:
        """Not-a-Real: 1000...0 (two's complement -2^(N-1)); eq. (4)."""
        return 1 << (self.n - 1)

    @property
    def useed_exp(self) -> int:
        """log2(useed) = 2^ES; eq. (3)."""
        return 1 << self.es

    @property
    def k_max(self) -> int:
        """Maximum regime value (regime of N-2 ones + stop bit fills the word)."""
        return self.n - 2

    @property
    def k_min(self) -> int:
        """Minimum regime value of a *nonzero* posit.

        Note: the paper (§IV-D) quotes -(N-1) as the clip bound for k'; the
        encodable minimum for a nonzero pattern is -(N-2) (l = N-2 zeros +
        stop bit; l = N-1 zeros is the zero word).  Clipping to either bound
        produces the same minpos after saturation; we use the tight bound,
        matching softposit and the 2022 standard (minpos = useed^(2-N)).
        """
        return -(self.n - 2)

    @property
    def te_max(self) -> int:
        """Largest representable total exponent: maxpos = useed^k_max."""
        return self.k_max * self.useed_exp

    @property
    def te_min(self) -> int:
        return self.k_min * self.useed_exp

    @property
    def max_frac_bits(self) -> int:
        """Fraction bits when the regime is shortest (len 2): N-1-2-ES, >= 0."""
        return max(0, self.n - 3 - self.es)

    @property
    def maxpos_bits(self) -> int:
        """Bit pattern of the largest positive posit: 0111...1."""
        return self.mask >> 1

    @property
    def one_bits(self) -> int:
        """Bit pattern of +1.0: 0b0100...0."""
        return 1 << (self.n - 2)

    @property
    def minpos_bits(self) -> int:
        """Bit pattern of the smallest positive posit: 000...01."""
        return 1

    @property
    def storage_bits(self) -> int:
        """Smallest power-of-two container width (the int dtype we store in)."""
        for w in (8, 16, 32):
            if self.n <= w:
                return w
        raise AssertionError

    @property
    def storage_dtype_name(self) -> str:
        return f"int{self.storage_bits}"

    def __str__(self) -> str:  # matches the paper's P<N,ES> notation
        return f"posit{self.n}es{self.es}"


# The paper's headline formats (§VII-A, Table IV, Figs 7-10).
P8_0 = PositConfig(8, 0)
P8_2 = PositConfig(8, 2)
P16_1 = PositConfig(16, 1)
P16_2 = PositConfig(16, 2)
P32_2 = PositConfig(32, 2)

# posit standard (2022) fixes ES=2 for all widths; the paper sweeps ES for
# Table II but uses <8,0>/<8,2>/<16,2> elsewhere.
STANDARD = {8: P8_2, 16: P16_2, 32: P32_2}


@lru_cache(maxsize=None)
def table2_grid() -> tuple[PositConfig, ...]:
    """The <N, ES> grid of the paper's Table II (division accuracy)."""
    grid = [PositConfig(8, es) for es in range(0, 5)]
    grid += [PositConfig(16, es) for es in range(0, 4)]
    return tuple(grid)
