"""Deterministic, seekable synthetic token pipeline.

Fault-tolerance contract: batch(step) is a pure function of (seed, step,
shape) — no iterator state.  A restarted trainer resumes from checkpoint
step s and regenerates exactly the batches it would have seen; elastic
resizes (different data-parallel degree) re-derive per-host slices from the
same global batch.  This is the property production pipelines get from
tfds/grain checkpointable iterators, implemented here without external deps.

The token distribution is a mixture of affine-recurrence sequences
(x_{t+1} = a*x_t + b mod V, per-sequence (a, b)) plus noise — structured
enough that a ~100M model visibly learns (examples/train_smollm.py), cheap
enough to generate on the fly.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.05


def global_batch_at(step: int, cfg: DataConfig):
    """Returns dict(tokens [B, S+1] int32) — inputs are [:, :-1], labels
    [:, 1:].  Pure function of (cfg.seed, step)."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    ka, kb, k0, kn, km = jax.random.split(key, 5)
    B, S, V = cfg.global_batch, cfg.seq_len + 1, cfg.vocab
    a = jax.random.randint(ka, (B, 1), 1, 64)
    b = jax.random.randint(kb, (B, 1), 0, V)
    x0 = jax.random.randint(k0, (B, 1), 0, V)

    t = jnp.arange(S)[None, :]
    # closed form of the affine recurrence would need modular powers; a short
    # scan keeps it exact and jit-friendly
    def step_fn(x, _):
        nxt = (a[:, 0] * x + b[:, 0]) % V
        return nxt, nxt
    _, xs = jax.lax.scan(step_fn, x0[:, 0], None, length=S)
    toks = xs.T                                        # [B, S]
    noise_mask = jax.random.bernoulli(kn, cfg.noise, toks.shape)
    noise_tok = jax.random.randint(km, toks.shape, 0, V)
    toks = jnp.where(noise_mask, noise_tok, toks).astype(jnp.int32)
    del t
    return {"tokens": toks}


def host_row_bounds(global_batch: int, host_id: int, num_hosts: int):
    """[lo, hi) rows of the global batch owned by `host_id`.

    Balanced partition: the first `global_batch % num_hosts` hosts take one
    extra row, so the host slices tile the *whole* global batch in host
    order for ANY host count — the elastic-shrink invariant.  (The old
    `global_batch // num_hosts` slicing silently dropped the remainder
    rows whenever the batch stopped dividing, so a 4→3 worker shrink
    would have trained on a different global batch sequence.)"""
    if not 1 <= num_hosts:
        raise ValueError(f"num_hosts must be >= 1, got {num_hosts}")
    if not 0 <= host_id < num_hosts:
        raise ValueError(f"host_id {host_id} outside [0, {num_hosts})")
    base, rem = divmod(global_batch, num_hosts)
    lo = host_id * base + min(host_id, rem)
    return lo, lo + base + (1 if host_id < rem else 0)


def host_batch_at(step: int, cfg: DataConfig, host_id: int, num_hosts: int):
    """Per-host slice of the global batch (elastic-safe: derived, not
    stored).  Concatenating the slices for hosts 0..num_hosts-1 always
    reproduces global_batch_at(step) exactly, for any num_hosts — so a
    run that shrinks 4→3 workers (or grows back 3→4) keeps consuming the
    bit-identical global batch sequence."""
    full = global_batch_at(step, cfg)
    lo, hi = host_row_bounds(cfg.global_batch, host_id, num_hosts)
    return jax.tree_util.tree_map(lambda x: x[lo:hi], full)
