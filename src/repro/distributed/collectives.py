"""Posit-compressed collectives — the paper's number format as a gradient
wire format (beyond-paper distributed-optimization trick, DESIGN.md §5).

A ring all-reduce is reduce-scatter + all-gather, each moving ~N bytes per
chip.  Summation must stay f32 (posit8/16 addition of many shards would
round pathologically), but the *all-gather half carries final values* and
tolerates posit quantization: encode the reduced shard to posit16/8, gather
ints, decode locally.

    allreduce_bytes(f32)            ~ 2 * 4N
    reduce_scatter f32 + gather p16 ~ 4N + 2N   (-25%)
    ... + gather p8                 ~ 4N + 1N   (-37.5%)

Across the pod axis (the slow inter-pod links) gradients are *pre-reduced*
in-pod in f32, so only the compressed cross-pod exchange touches DCN:
cross-pod bytes drop 2x/4x — visible in the dry-run HLO collective sizes
(EXPERIMENTS.md §Perf).

These run inside shard_map; gradient summation correctness is preserved
(quantization error enters once, after the exact f32 reduction, bounded by
the posit RNE half-ulp — measured in tests/test_collectives.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.convert import f32_to_posit
from repro.core.decode import decode_to_f32
from repro.core.types import PositConfig


def compressed_psum(x: jnp.ndarray, axis_name: str, cfg: PositConfig):
    """All-reduce of x over `axis_name` with a posit-compressed gather half.

    Call inside shard_map.  x: any float array, identical shape per member.
    Returns the (quantized) mean-preserving sum on every member.
    """
    n = jax.lax.psum(1, axis_name)
    size = x.size
    pad = (-size) % n
    flat = jnp.pad(x.astype(jnp.float32).reshape(-1), (0, pad))
    shards = flat.reshape(n, size // n if pad == 0 else (size + pad) // n)
    # exact f32 reduction of my shard (reduce-scatter half)
    idx = jax.lax.axis_index(axis_name)
    mine = jax.lax.psum_scatter(shards, axis_name, scatter_dimension=0,
                                tiled=False)
    # compressed all-gather half: posit wire format
    wire = f32_to_posit(mine, cfg)
    gathered = jax.lax.all_gather(wire, axis_name, axis=0, tiled=False)
    out = decode_to_f32(gathered, cfg).reshape(-1)[:size]
    del idx
    return out.reshape(x.shape).astype(x.dtype)


def compressed_grad_sync(grads, axis_name: str, cfg: PositConfig | None):
    """Apply compressed_psum leaf-wise to a gradient pytree (or plain psum
    when cfg is None — the f32 baseline)."""
    if cfg is None:
        return jax.lax.psum(grads, axis_name)
    return jax.tree_util.tree_map(
        lambda g: compressed_psum(g, axis_name, cfg), grads)


def cross_pod_grad_sync(grads, cfg: PositConfig | None, mesh,
                        in_specs, data_axis: str = "data",
                        pod_axis: str = "pod"):
    """Two-level gradient sync for the multi-pod mesh: exact f32 psum over
    the in-pod data axis, posit-compressed psum across pods (slow links).

    grads must already be laid out per `in_specs`; runs one shard_map.
    """
    from jax.experimental.shard_map import shard_map

    def sync(g):
        g = jax.lax.psum(g, data_axis)                  # fast in-pod links, f32
        return compressed_grad_sync(g, pod_axis, cfg)   # slow links, posit wire

    return shard_map(sync, mesh=mesh, in_specs=in_specs,
                     out_specs=in_specs, check_rep=False)(grads)
