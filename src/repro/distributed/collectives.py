"""Posit-compressed collectives — the paper's number format as a gradient
wire format (beyond-paper distributed-optimization trick, DESIGN.md §5).

A ring all-reduce is reduce-scatter + all-gather, each moving ~N bytes per
chip.  Summation must stay f32 (posit8/16 addition of many shards would
round pathologically), but the *all-gather half carries final values* and
tolerates posit quantization: encode the reduced shard to posit16/8, gather
ints, decode locally.

    allreduce_bytes(f32)            ~ 2 * 4N
    reduce_scatter f32 + gather p16 ~ 4N + 2N   (-25%)
    ... + gather p8                 ~ 4N + 1N   (-37.5%)

Across the pod axis (the slow inter-pod links) gradients are *pre-reduced*
in-pod in f32, so only the compressed cross-pod exchange touches DCN:
cross-pod bytes drop 2x/4x — visible in the dry-run HLO collective sizes
(EXPERIMENTS.md §Perf).

These run inside shard_map; gradient summation correctness is preserved
(quantization error enters once, after the exact f32 reduction, bounded by
the posit RNE half-ulp — measured in tests/test_collectives.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.convert import f32_to_posit
from repro.core.decode import decode_to_f32
from repro.core.types import PositConfig


def compressed_psum(x: jnp.ndarray, axis_name: str, cfg: PositConfig):
    """All-reduce of x over `axis_name` with a posit-compressed gather half.

    Call inside shard_map.  x: any float array, identical shape per member.
    Returns the (quantized) mean-preserving sum on every member.
    """
    n = jax.lax.psum(1, axis_name)
    size = x.size
    pad = (-size) % n
    flat = jnp.pad(x.astype(jnp.float32).reshape(-1), (0, pad))
    shards = flat.reshape(n, size // n if pad == 0 else (size + pad) // n)
    # exact f32 reduction of my shard (reduce-scatter half)
    idx = jax.lax.axis_index(axis_name)
    mine = jax.lax.psum_scatter(shards, axis_name, scatter_dimension=0,
                                tiled=False)
    # compressed all-gather half: posit wire format
    wire = f32_to_posit(mine, cfg)
    gathered = jax.lax.all_gather(wire, axis_name, axis=0, tiled=False)
    out = decode_to_f32(gathered, cfg).reshape(-1)[:size]
    del idx
    return out.reshape(x.shape).astype(x.dtype)


def compressed_grad_sync(grads, axis_name: str, cfg: PositConfig | None):
    """Apply compressed_psum leaf-wise to a gradient pytree (or plain psum
    when cfg is None — the f32 baseline)."""
    if cfg is None:
        return jax.lax.psum(grads, axis_name)
    return jax.tree_util.tree_map(
        lambda g: compressed_psum(g, axis_name, cfg), grads)


# --------------------------------------------------------------------------
# tensor-parallel serving context (used inside the sharded paged step)
# --------------------------------------------------------------------------
# The sharded serving step (serving.engine._sharded_paged_step) runs the
# whole forward inside one shard_map with Megatron column/row-parallel
# weights (distributed.sharding.serving_param_pspecs).  The model blocks
# need two pieces of information the param tree cannot carry: the TP axis
# name (for the one psum each block owes after its row-parallel output
# projection) and whether the vocab dimension is sharded (the embedding
# lookup becomes masked-local + psum, and sampling must reduce across vocab
# shards).  Both travel through this thread-local context, active only
# while the step body is being traced — training and single-device serving
# never see it.
import contextlib
import dataclasses
import threading

_TP = threading.local()


@dataclasses.dataclass(frozen=True)
class TPContext:
    axis: str                       # mesh axis name ("model")
    size: int                       # static axis size
    vocab_sharded: bool             # embed/unembed tables vocab-parallel?
    compress: PositConfig | None    # posit wire format for block psums


@contextlib.contextmanager
def tensor_parallel(axis: str, size: int, vocab_sharded: bool = False,
                    compress: PositConfig | None = None):
    prev = getattr(_TP, "ctx", None)
    _TP.ctx = TPContext(axis, size, vocab_sharded, compress) \
        if size > 1 else None
    try:
        yield
    finally:
        _TP.ctx = prev


def tp_ctx() -> TPContext | None:
    return getattr(_TP, "ctx", None)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _psum_g(x, axis: str, compress):
    if compress is not None:
        return compressed_psum(x, axis, compress)
    return jax.lax.psum(x, axis)


def _psum_g_fwd(x, axis: str, compress):
    return _psum_g(x, axis, compress), None


def _psum_g_bwd(axis: str, compress, _, g):
    return (g,)


_psum_g.defvjp(_psum_g_fwd, _psum_g_bwd)


def block_psum(x):
    """The one all-reduce a row-parallel block output owes under TP —
    Megatron's g-operator: psum forward, *identity* backward.

    The identity backward is load-bearing for training: under shard_map
    with check_rep=False, autodiff transposes a raw lax.psum to another
    psum, so a replicated cotangent flowing into the block output would
    multiply by the axis size at every block.  The block's output cotangent
    is already replicated (everything downstream of the psum is replicated
    compute), so the correct pullback is the identity — block_grad_sync at
    the block *entry* is where the one real backward psum happens.

    Identity outside a tensor_parallel context.  With a compress format the
    gather half of the psum moves posit ints instead of f32 (profitable on
    slow inter-chip links, at the cost of the half-ulp wire quantization —
    serving keeps it off by default to preserve single-device bit-parity).
    """
    ctx = tp_ctx()
    if ctx is None:
        return x
    return _psum_g(x, ctx.axis, ctx.compress)


# --------------------------------------------------------------------------
# Megatron f-operator: the training-side dual of block_psum
# --------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _grad_psum(x, axis: str):
    return x


def _grad_psum_fwd(x, axis: str):
    return x, None


def _grad_psum_bwd(axis: str, _, g):
    return (jax.lax.psum(g, axis),)


_grad_psum.defvjp(_grad_psum_fwd, _grad_psum_bwd)


def block_grad_sync(x):
    """Megatron's f-operator at a TP block *entry*: identity forward, psum
    over the TP axis backward.

    A column/row-parallel block consumes a replicated activation and its
    backward produces a partial d(input) per shard (each shard only saw its
    weight slice); the psum here restores the full gradient so everything
    upstream (embeddings, earlier blocks' row-parallel outputs) sees the
    same replicated cotangent on every member.  Identity outside a
    tensor_parallel context — serving never differentiates, so block_psum
    stays the only collective the forward pays.
    """
    ctx = tp_ctx()
    if ctx is None:
        return x
    return _grad_psum(x, ctx.axis)


def sharded_argmax(logits: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Global greedy token ids from vocab-sharded logits [B, V/ntp].

    Each member reduces its local shard to (max, argmax) and only the
    O(B) pairs cross the mesh — never the [B, vocab] logits.  Ties break
    to the lowest global index (vocab order == shard order, and argmax
    picks the first occurrence at both levels), so the result is exactly
    jnp.argmax of the unsharded logits.
    """
    local_v = logits.shape[-1]
    off = jax.lax.axis_index(axis_name) * local_v
    lmax = logits.max(axis=-1)                               # [B]
    larg = jnp.argmax(logits, axis=-1).astype(jnp.int32) + off
    gmax = jax.lax.all_gather(lmax, axis_name)               # [ntp, B]
    garg = jax.lax.all_gather(larg, axis_name)               # [ntp, B]
    shard = jnp.argmax(gmax, axis=0)                         # first max wins
    return jnp.take_along_axis(garg, shard[None, :], axis=0)[0]


def gather_vocab_shards(logits: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """[B, V/ntp] vocab-sharded logits -> full [B, V] on every member (the
    temperature-sampling path; greedy uses sharded_argmax and stays O(B))."""
    return jax.lax.all_gather(logits, axis_name, axis=1, tiled=True)


def cross_pod_grad_sync(grads, cfg: PositConfig | None, mesh,
                        in_specs, data_axis: str = "data",
                        pod_axis: str = "pod"):
    """Two-level gradient sync for the multi-pod mesh: exact f32 psum over
    the in-pod data axis, posit-compressed psum across pods (slow links).

    grads must already be laid out per `in_specs`; runs one shard_map.
    """
    from jax.experimental.shard_map import shard_map

    def sync(g):
        g = jax.lax.psum(g, data_axis)                  # fast in-pod links, f32
        return compressed_grad_sync(g, pod_axis, cfg)   # slow links, posit wire

    return shard_map(sync, mesh=mesh, in_specs=in_specs,
                     out_specs=in_specs, check_rep=False)(grads)
