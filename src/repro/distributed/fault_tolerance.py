"""Fault tolerance, straggler mitigation, elasticity — the runbook layer.

What is implemented and exercised in this repo (CPU container):
  * checkpoint/restart: atomic manifest-verified checkpoints with full
    per-leaf sha256 digests (checkpoint/store.py) + a seekable pipeline
    (data/pipeline.py) make the (params, opt_state, step) triple the full
    training state; the trainer (training/trainer.py) auto-resumes from
    the newest valid step, skipping corrupted/partial directories.
    tests/test_fault_tolerance.py kills a run mid-flight (subprocess
    SIGKILL) and asserts bit-identical continuation, fallback past a
    corrupted step dir, and that a flipped byte deep in a leaf (past the
    old 4 KiB prefix hash) is caught.
  * NaR/non-finite containment: a non-finite gradient norm skips the
    optimizer update and increments the checkpointed
    opt_state["nar_skips"] counter (optim/adamw.py, guard selected
    per-leaf so the happy path is bit-identical); the serving engine
    detects NaR in output logits on device and fails only the poisoned
    request (serving/engine.py, chaos harness in serving/faults.py,
    drains exercised by tests/test_chaos_serving.py).
  * elastic data-parallel resize: per-host batches are *derived*
    (host_batch_at(step, host_id, num_hosts)), so a restart with a different
    data-axis size resumes the same global batch sequence; param shardings
    are re-fit by sharding.param_pspecs against the new mesh (dims that no
    longer divide fall back to replication rather than failing).

What is designed-for and documented (needs real multi-host hardware):
  * failure detection: on TPU pods, jax.distributed heartbeats surface node
    loss as a NotFoundError on the next collective; the launcher
    (launch/train.py --restart-on-failure) re-execs the process group and
    resumes from the last checkpoint.  MTBF math: at 1000 nodes / 3-year
    node MTBF, expect ~1 failure/day -> checkpoint every K steps such that
    K * step_time << 1 day / overhead budget; default --ckpt-every covers
    <=2% lost work at 30 s steps.
  * straggler mitigation: synchronous SPMD cannot drop stragglers
    mid-collective; mitigation is (a) the launcher's per-step watchdog
    (--step-timeout) which treats a >p99.9 step as a failure and restarts
    without the slow host, shrinking the data axis (elastic resume), and
    (b) the pipeline's derived batches, which make that shrink consistent.
  * hierarchical sync: cross-pod gradient traffic is pre-reduced in-pod and
    posit-compressed (collectives.cross_pod_grad_sync), halving the bytes
    crossing the slowest links.
"""
from __future__ import annotations

import dataclasses
import os
import signal
import time


@dataclasses.dataclass
class RestartPolicy:
    ckpt_every: int = 50
    keep: int = 3
    max_restarts: int = 100
    step_timeout_s: float | None = None   # straggler watchdog (launcher-level)


class StepWatchdog:
    """Treat a stuck/straggling step as a failure (SIGALRM -> exception)."""

    def __init__(self, timeout_s: float | None):
        self.timeout_s = timeout_s

    def __enter__(self):
        if self.timeout_s:
            signal.signal(signal.SIGALRM, self._fire)
            signal.setitimer(signal.ITIMER_REAL, self.timeout_s)
        return self

    def _fire(self, signum, frame):
        raise TimeoutError("step exceeded straggler watchdog timeout")

    def __exit__(self, *exc):
        if self.timeout_s:
            signal.setitimer(signal.ITIMER_REAL, 0.0)
        return False
