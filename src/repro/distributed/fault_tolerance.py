"""Fault tolerance, straggler mitigation, elasticity — implemented and
exercised in this repo (CPU container), not just designed for hardware.

  * checkpoint/restart: atomic manifest-verified checkpoints with full
    per-leaf sha256 digests (checkpoint/store.py: fsync'd leaves/manifest,
    .tmp -> atomic rename publish, GC that counts only *valid* steps) + a
    seekable pipeline (data/pipeline.py) make the (params, opt_state, step)
    triple the full training state; the trainer auto-resumes from the
    newest valid step, skipping corrupted/partial directories.
    tests/test_fault_tolerance.py kills a run mid-flight (subprocess
    SIGKILL) and asserts bit-identical continuation, fallback past a
    corrupted step dir, and that a flipped byte deep in a leaf is caught.
  * async checkpointing: checkpoint/async_store.AsyncCheckpointStore
    snapshots device->host synchronously (a copy, so donated buffers can
    be reused immediately), then writes + fsyncs + atomically publishes on
    a background thread behind a bounded in-flight queue (block on
    overflow, never drop) with a wait() barrier at loop exit.  A crash
    mid-async-write leaves only a .tmp dir, which restore already skips.
    Exercised by tests/test_elastic.py and BENCH_elastic.json (per-ckpt
    train-loop stall, sync vs async).
  * failure detection + restart: launch/supervisor.py spawns the worker
    process group (jax.distributed over localhost TCP on this container),
    monitors per-worker heartbeat files (step + phase + timestamp,
    atomically renamed), and on a worker death (signal), straggler
    timeout, or startup hang kills the whole group and re-execs it with
    the data axis shrunk to the survivors — exponential backoff between
    restarts, RestartPolicy.max_restarts bounded, ending in a structured
    RunOutcome (completed | exhausted_restarts | failed) instead of a
    raised exception.  tests/test_supervisor.py SIGKILLs and straggles
    workers mid-run and asserts the shrunk resume is bit-identical.
  * straggler mitigation: synchronous SPMD cannot drop stragglers
    mid-collective, so the supervisor's heartbeat watchdog
    (--step-timeout) treats a stale heartbeat as a failure; among the
    timed-out workers the one stuck at the earliest (step, phase) is the
    straggler (its peers have already reached the exchange phase and are
    merely blocked on it), and the group restarts without it.  The
    in-process StepWatchdog below covers the single-process trainer.
  * elastic data-parallel resize: per-host batches are *derived*
    (host_batch_at(step, host_id, num_hosts), balanced partition), so any
    worker count consumes the bit-identical global batch sequence, and
    training/elastic.py computes gradients per-row and reduces them in
    canonical global row order — the update is bitwise invariant to how
    rows are grouped onto workers, which is what makes a 4→3 shrunk
    resume reproduce an uninterrupted run exactly.  Param shardings are
    re-fit by sharding.param_pspecs against the new mesh (dims that no
    longer divide fall back to replication rather than failing).

MTBF math (why --ckpt-every matters): at 1000 nodes / 3-year node MTBF,
expect ~1 failure/day; lost work per failure averages ckpt_every/2 steps,
so checkpoint every K steps with K * step_time << MTBF/overhead budget —
the default covers <=2% lost work at 30 s steps.  BENCH_elastic.json
measures the other side of the tradeoff (per-checkpoint stall), which the
async store collapses to the device->host snapshot time.

Hierarchical sync (real multi-pod hardware only): cross-pod gradient
traffic is pre-reduced in-pod and posit-compressed
(collectives.cross_pod_grad_sync), halving the bytes crossing the slowest
links.
"""
from __future__ import annotations

import dataclasses
import json
import os
import signal
import threading
import time


@dataclasses.dataclass
class RestartPolicy:
    ckpt_every: int = 50
    keep: int = 3
    max_restarts: int = 100
    step_timeout_s: float | None = None   # straggler watchdog (supervisor)
    # supervisor knobs (launch/supervisor.py)
    min_workers: int = 1          # shrink floor: fewer survivors -> failed
    startup_timeout_s: float = 300.0   # spawn -> first heartbeat deadline
    backoff_s: float = 0.5        # restart backoff: backoff_s * 2**(n-1)
    backoff_max_s: float = 30.0   # ... capped here


class StepWatchdog:
    """Treat a stuck/straggling step as a failure (SIGALRM -> exception).

    Context-manager hygiene: the previous SIGALRM handler AND any
    in-flight itimer are saved on entry and restored on exit (an enclosing
    watchdog/alarm keeps working; its clock is paused for the duration of
    this block).  SIGALRM can only be delivered to the main thread, so
    arming from any other thread raises a clear error up front instead of
    dying inside signal.signal.
    """

    def __init__(self, timeout_s: float | None):
        self.timeout_s = timeout_s
        self._prev_handler = None
        self._prev_timer = (0.0, 0.0)
        self._t0 = 0.0

    def __enter__(self):
        if self.timeout_s:
            if threading.current_thread() is not threading.main_thread():
                raise RuntimeError(
                    "StepWatchdog uses SIGALRM, which only the main thread "
                    "may arm; run the training loop on the main thread or "
                    "use the supervisor's process-level --step-timeout "
                    "heartbeat watchdog instead")
            self._prev_handler = signal.signal(signal.SIGALRM, self._fire)
            self._prev_timer = signal.setitimer(signal.ITIMER_REAL,
                                                self.timeout_s)
            self._t0 = time.monotonic()
        return self

    def _fire(self, signum, frame):
        raise TimeoutError("step exceeded straggler watchdog timeout")

    def __exit__(self, *exc):
        if self.timeout_s:
            signal.setitimer(signal.ITIMER_REAL, 0.0)
            signal.signal(signal.SIGALRM, self._prev_handler)
            remaining, interval = self._prev_timer
            if remaining > 0.0:
                # re-arm the enclosing timer with the time it had left when
                # we preempted it; if this block already overran that
                # budget, fire (almost) immediately under its own handler
                left = remaining - (time.monotonic() - self._t0)
                signal.setitimer(signal.ITIMER_REAL, max(left, 1e-6),
                                 interval)
        return False


# --------------------------------------------------------------------------
# heartbeats: the supervisor's failure/straggler detector input
# --------------------------------------------------------------------------
# phase order within a step; the straggler among a set of mutually-stale
# workers is the one stuck at the smallest (step, phase rank) — its peers
# have advanced to the exchange and are merely blocked waiting for it
PHASES = ("step", "sync", "done")
PHASE_RANK = {p: i for i, p in enumerate(PHASES)}


class Heartbeat:
    """Atomically-renamed per-worker heartbeat file: {host_id, step, phase,
    t}.  Readers (the supervisor) never observe a torn write — the json is
    written to <path>.tmp and os.replace'd over the live file."""

    def __init__(self, path: str, host_id: int):
        self.path = path
        self.host_id = host_id
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    def beat(self, step: int, phase: str = "step"):
        if phase not in PHASE_RANK:
            raise ValueError(f"unknown heartbeat phase {phase!r}")
        rec = {"host_id": self.host_id, "step": int(step), "phase": phase,
               "t": time.time()}
        tmp = f"{self.path}.tmp.{self.host_id}"
        with open(tmp, "w") as f:
            json.dump(rec, f)
        os.replace(tmp, self.path)

    def done(self, step: int):
        self.beat(step, "done")


def read_heartbeat(path: str):
    """The worker's latest heartbeat record, or None (not yet written)."""
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None
