"""Sharding rules: params, optimizer state, activations -> PartitionSpecs.

Layout (DESIGN.md §5):
  * FSDP: weight matrices shard their d_model/d_ff "reduction-side" dim over
    ("pod","data") — XLA GSPMD all-gathers per scanned layer, overlapping
    with compute (latency-hiding scheduler flags in launch scripts).
  * TP (Megatron): the "parallel" dim (heads*head_dim, d_ff, vocab) shards
    over "model"; column-parallel in, row-parallel out -> one psum per block.
  * EP: MoE expert dim shards over "model" (experts % 16 == 0 for both MoE
    archs).
  * Dims that do not divide the assigned axes are dropped to replication
    (guard below) — e.g. hubert's vocab=504.

Rules are path-regex -> trailing-dims spec; stacked scan dims get leading
None automatically.
"""
from __future__ import annotations

import re

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

FSDP = "__fsdp__"     # placeholder replaced by ("pod","data") or "data"


def strategy_for(cfg, mesh) -> str:
    """Per-arch parallelism strategy (DESIGN.md §5).

    "tp2d": Megatron TP over 'model' + FSDP over data axes.  Requires every
            TP-sharded dim to divide the model-axis size (heads, d_ff,
            d_model, experts).
    "fsdp": pure fully-sharded data parallel — batch and parameters shard
            over the flattened (data, model) axes; right for models whose
            per-layer weight gathers are cheaper than Megatron psums of
            (B*S, d) activations (everything below ~50B here), and for
            head-count-indivisible stacks (smollm, rwkv6).
    """
    tp = mesh.shape["model"]
    ok = cfg.d_model % tp == 0 and cfg.d_ff % tp == 0
    has_attn = any(k in ("attn", "attn_local") for k in cfg.block_pattern)
    if has_attn:
        ok = ok and cfg.n_heads % tp == 0
    else:
        ok = False                     # pure-recurrent stacks: FSDP
    if cfg.moe is not None:
        ok = ok and cfg.moe.n_experts % tp == 0
    # napkin math (EXPERIMENTS.md §Perf): TP psum bytes/layer ~ 8*B*S*d/dp
    # vs FSDP gather bytes/layer ~ 3*layer_params; at 1M-token batches the
    # crossover sits near ~50B params on a (16,16) v5e pod.
    ok = ok and cfg.param_count() > 5e10
    return "tp2d" if ok else "fsdp"


def _rules():
    return [
        # embeddings: vocab-parallel over the TP axis (Megatron): logits come
        # out vocab-sharded and the loss reduces them without a gather
        (r"embed/table$", ("model", None)),
        (r"unembed/w$", (None, "model")),
        (r"unembed/b$", ("model",)),
        # MoE: experts over model (EP), d_model over fsdp
        (r"moe/router$", (None, None)),
        (r"moe/w_(up|gate)$", ("model", FSDP, None)),
        (r"moe/w_down$", ("model", None, FSDP)),
        # rwkv channel-mix down projection (ff, d)
        (r"cmix/wv/w$", ("model", FSDP)),
        # row-parallel (output) projections
        (r"(wo|w_down|w_out)/w$", ("model", FSDP)),
        # column-parallel (input) projections
        (r"(wq|wk|wv|wg|w_up|w_gate|wr|w_x|w_gate_branch|w_input_gate|"
         r"w_rec_gate|wk)/w$", (FSDP, "model")),
        (r"w_lora_a$", (FSDP, None)),
        (r"w_lora_b$", (None, FSDP)),
        # everything small: replicate
        (r".*", ()),
    ]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    # PositArray leaves flatten to a trailing GetAttrKey('bits') child; the
    # rules name the parameter, so that key is transparent to the regexes
    # (a genuine dict entry named "bits" is a DictKey and is kept)
    if (path and isinstance(path[-1], jax.tree_util.GetAttrKey)
            and path[-1].name == "bits"):
        parts.pop()
    return "/".join(parts)


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def _fit(spec_trailing, shape, mesh):
    """Pad with leading None to ndim; drop axes whose size doesn't divide."""
    nd = len(shape)
    spec = (None,) * (nd - len(spec_trailing)) + tuple(spec_trailing)
    spec = spec[:nd] if len(spec) > nd else spec
    fixed = []
    for dim, ax in zip(shape, spec):
        if ax is None or dim % _axis_size(mesh, ax) != 0:
            fixed.append(None)
        else:
            fixed.append(ax)
    return P(*fixed)


def param_pspecs(params, mesh, multi_pod: bool, strategy: str = "tp2d"):
    """PartitionSpec pytree for a model param tree (also fits opt moments)."""
    if strategy == "fsdp":
        return _fsdp_param_pspecs(params, mesh)
    fsdp = ("pod", "data") if multi_pod else "data"
    rules = [(re.compile(pat), spec) for pat, spec in _rules()]

    def assign(path, leaf):
        ps = _path_str(path)
        for pat, trailing in rules:
            if pat.search(ps):
                tr = tuple(fsdp if a == FSDP else a for a in trailing)
                return _fit(tr, leaf.shape, mesh)
        return P()

    return jax.tree_util.tree_map_with_path(assign, params)


def _fsdp_param_pspecs(params, mesh):
    """Pure FSDP: shard one dim of every matrix over the flat (data, model)
    axes (replicated across 'pod'; cross-pod sync is plain DP, where the
    posit-compressed collective applies).  Prefers the reduction (-2) dim,
    falls back to any dim that divides."""
    dm = ("data", "model")
    n = _axis_size(mesh, dm)

    def assign(path, leaf):
        if leaf.ndim < 2:
            return P()
        order = [leaf.ndim - 2, leaf.ndim - 1] + list(range(leaf.ndim - 2))
        for d in order:
            if leaf.shape[d] >= n and leaf.shape[d] % n == 0:
                spec = [None] * leaf.ndim
                spec[d] = dm
                return P(*spec)
        # half-flat fallback: data axis only
        for d in order:
            nd = _axis_size(mesh, "data")
            if leaf.shape[d] >= nd and leaf.shape[d] % nd == 0:
                spec = [None] * leaf.ndim
                spec[d] = "data"
                return P(*spec)
        return P()

    return jax.tree_util.tree_map_with_path(assign, params)


def serving_param_pspecs(params, mesh):
    """Megatron inference-TP specs for the sharded paged serving step.

    Column/row-parallel weights over 'model' (one psum per block, applied
    by the blocks under distributed.collectives.tensor_parallel), vocab-
    parallel embed/unembed when the vocab divides.  Replicated over 'data':
    serving holds no optimizer state, so there is nothing to FSDP — every
    data-parallel replica reads the same (posit-narrow) weights.  Reuses
    the training rules with the FSDP placeholder dropped to replication,
    plus column-parallel qkv/gate bias sharding (training replicates
    biases; under TP a column-parallel output needs its bias shard-local).
    """
    # NOTE on MoE expert parallelism: the base moe/ rules already give the
    # serving layout once FSDP drops to replication — experts over the
    # model axis for w_up/w_gate/w_down (the shard-local grouped GEMM +
    # one block_psum combine in models/moe.py) and a replicated router, so
    # every shard routes identically.  No extra entries needed.
    extra = [(r"(wq|wk|wv|wg|w_up|w_gate|wr)/b$", ("model",))]
    rules = [(re.compile(pat), spec) for pat, spec in extra + _rules()]

    def assign(path, leaf):
        ps = _path_str(path)
        for pat, trailing in rules:
            if pat.search(ps):
                tr = tuple(None if a == FSDP else a for a in trailing)
                return _fit(tr, leaf.shape, mesh)
        return P()

    return jax.tree_util.tree_map_with_path(assign, params)


def train_param_pspecs(params, mesh):
    """Megatron TP specs for the shard_map training step.

    Serving's column/row-parallel layout minus vocab parallelism: embed
    and unembed tables stay replicated so the loss (softmax over the full
    vocab) and the embedding-table gradient need no vocab-shard psums —
    the unembed matmul is then replicated compute, which is exactly why
    the training step applies the f-operator (collectives.block_grad_sync)
    at TP block entries only and never at the final norm.  FSDP dims drop
    to replication ('data' carries pure DP with the posit-compressed
    gradient sync instead); column-parallel biases shard like serving.
    """
    extra = [
        (r"embed/table$", (None, None)),
        (r"unembed/w$", (None, None)),
        (r"unembed/b$", (None,)),
        (r"(wq|wk|wv|wg|w_up|w_gate|wr)/b$", ("model",)),
    ]
    rules = [(re.compile(pat), spec) for pat, spec in extra + _rules()]

    def assign(path, leaf):
        ps = _path_str(path)
        for pat, trailing in rules:
            if pat.search(ps):
                tr = tuple(None if a == FSDP else a for a in trailing)
                return _fit(tr, leaf.shape, mesh)
        return P()

    return jax.tree_util.tree_map_with_path(assign, params)


def paged_pool_pspecs(pages, mesh):
    """Serving pool specs, per backend (serving/backends.py):

    Paged KV leaves [.., num_pages, n_kv, page, D]: the page dim shards over
    'data' (each DP shard owns a private sub-pool with its own garbage page
    — the host scheduler in serving.engine allocates shard-locally), kv
    heads over 'model' when they divide (the TP attention heads live next
    to their pages).

    State-pool leaves (wkv/tshift/cshift/h/conv): the slot dim shards over
    'data' (slots are striped across DP shards exactly like the page-table
    rows), and the wkv head dim over 'model' when it divides (head-sharded
    state; the engine currently rejects TP for recurrent patterns, so this
    is layout support, not a dispatch path).

    Leaves may carry a leading stacked-reps dim for scanned layer groups.
    """
    from repro.core.array import PositArray
    from repro.serving.backends import _STATE_BASE_NDIM

    def kv_assign(leaf):
        spec = [None] * leaf.ndim
        spec[leaf.ndim - 4] = "data"
        if leaf.shape[leaf.ndim - 3] % _axis_size(mesh, "model") == 0:
            spec[leaf.ndim - 3] = "model"
        return P(*spec)

    def state_assign(name, leaf):
        slot = leaf.ndim - _STATE_BASE_NDIM[name]     # 0 unstacked, 1 stacked
        spec = [None] * leaf.ndim
        spec[slot] = "data"
        if (name == "wkv"
                and leaf.shape[slot + 1] % _axis_size(mesh, "model") == 0):
            spec[slot + 1] = "model"
        return P(*spec)

    def layer(p):
        if "k_pages" in p:
            # stop at PositArray (one spec covers its bits leaf): the spec
            # tree stays a plain-P prefix tree usable by shard_map and
            # device_put alike
            return jax.tree_util.tree_map(
                kv_assign, p, is_leaf=lambda x: isinstance(x, PositArray))
        return {k: state_assign(k, v) for k, v in p.items()}

    return {"scanned": tuple(layer(p) for p in pages["scanned"]),
            "rem": tuple(layer(p) for p in pages["rem"])}


def opt_state_pspecs(opt_state, param_specs, mesh):
    """Moments mirror parameter sharding; scalars (step, the NaR-guard
    skip counter) are replicated.  Keys mirror the opt_state actually
    passed so pre-nar_skips checkpoints still shard cleanly."""
    specs = {
        "step": P(),
        "m": param_specs,
        "v": param_specs,
    }
    for k in opt_state:
        if k not in specs:
            specs[k] = P()
    return specs


def dp_axes(mesh, multi_pod: bool, strategy: str):
    """Candidate batch axes, widest first."""
    base = ("pod", "data") if multi_pod else ("data",)
    if strategy == "fsdp":
        return [base + ("model",), ("data", "model"), base, ("data",)]
    return [base, ("data",)]


def batch_pspecs(batch, mesh, multi_pod: bool, shard_seq: bool = False,
                 strategy: str = "tp2d"):
    """Input batch: batch dim over the widest dividing DP axes; optionally
    sequence over data (sequence parallelism, e.g. long_500k)."""
    cands = dp_axes(mesh, multi_pod, strategy)

    def assign(leaf):
        if leaf.ndim == 0:
            return P()
        bdim = leaf.shape[0]
        for dp in cands:
            if bdim % _axis_size(mesh, tuple(dp)) == 0:
                return _fit((tuple(dp),) + (None,) * (leaf.ndim - 1),
                            leaf.shape, mesh)
        if shard_seq and leaf.ndim >= 2:
            return _fit((None, "data") + (None,) * (leaf.ndim - 2),
                        leaf.shape, mesh)
        return P()

    return jax.tree_util.tree_map(assign, batch)


def cache_pspecs(caches, mesh, multi_pod: bool, strategy: str = "tp2d"):
    """KV caches: batch over DP when divisible, else sequence over data;
    model axis on kv heads if they divide, else on head_dim."""
    dp = ("pod", "data") if multi_pod else ("data",)

    def assign(path, leaf):
        ps = _path_str(path)
        if leaf.ndim == 0:
            return P()
        shape = leaf.shape
        # stacked scan dim possible at axis 0: detect KV buffers by name
        if ps.endswith("/k") or ps.endswith("/v"):
            nd = leaf.ndim
            spec = [None] * nd
            b_ax, h_ax, s_ax, d_ax = nd - 4, nd - 3, nd - 2, nd - 1
            if shape[b_ax] % _axis_size(mesh, tuple(dp)) == 0:
                spec[b_ax] = tuple(dp)
            elif shape[s_ax] % mesh.shape["data"] == 0:
                spec[s_ax] = "data"
            # model axis: kv heads if they divide; else the sequence dim
            # (flash-decoding layout — softmax stats psum instead of KV
            # gathers); head_dim as the last resort
            if shape[h_ax] % mesh.shape["model"] == 0:
                spec[h_ax] = "model"
            elif spec[s_ax] is None and shape[s_ax] % mesh.shape["model"] == 0:
                spec[s_ax] = "model"
            elif shape[d_ax] % mesh.shape["model"] == 0:
                spec[d_ax] = "model"
            return P(*spec)
        # recurrent states (rwkv/rglru) and lengths: shard batch when it
        # divides, else replicate (states are small)
        for b_ax in (1, 0):
            if (leaf.ndim > b_ax
                    and shape[b_ax] % _axis_size(mesh, tuple(dp)) == 0
                    and shape[b_ax] >= _axis_size(mesh, tuple(dp))):
                spec = [None] * leaf.ndim
                spec[b_ax] = tuple(dp)
                return P(*spec)
        return P()

    return jax.tree_util.tree_map_with_path(assign, caches)


def to_shardings(pspecs, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P))


# --------------------------------------------------------------------------
# activation sharding constraints (set by launch-layer code; no-op without)
# --------------------------------------------------------------------------
import contextlib
import threading

_ACT = threading.local()


@contextlib.contextmanager
def activation_sharding(mesh, multi_pod: bool, strategy: str = "tp2d"):
    """While active, shard_activation() pins key activations to the mesh.
    Trainer/dryrun wrap tracing in this; single-device tests skip it."""
    prev = getattr(_ACT, "ctx", None)
    _ACT.ctx = (mesh, multi_pod, strategy)
    try:
        yield
    finally:
        _ACT.ctx = prev


def shard_activation(x, kind: str):
    """kind: 'tokens' | 'act' | 'logits'.  Identity when no context."""
    ctx = getattr(_ACT, "ctx", None)
    if ctx is None or x.ndim == 0:
        return x
    mesh, multi_pod, strategy = ctx
    spec = None
    for dp in dp_axes(mesh, multi_pod, strategy):
        if x.shape[0] % _axis_size(mesh, tuple(dp)) == 0:
            if kind == "logits" and strategy == "tp2d":
                trailing = ((tuple(dp),) + (None,) * (x.ndim - 2)
                            + ("model",))
            elif kind == "act" and strategy == "tp2d" and x.ndim >= 3:
                # Megatron sequence parallelism: the inter-block residual
                # stream shards its sequence dim over the TP axis — scan-
                # carry residuals shrink 16x and block-boundary psums become
                # reduce-scatter/all-gather pairs (§Perf iteration A)
                trailing = ((tuple(dp), "model") + (None,) * (x.ndim - 2))
            elif kind == "kv_seq":
                # flash-decoding layout: KV [B,H,S,D] sharded on sequence
                trailing = (tuple(dp), None, "model", None)[:x.ndim]
            elif kind == "batch_only":
                # small per-step tensors (decode q): batch-sharded only,
                # replicated over the TP axis so the S-sharded KV einsum
                # partitions on S without gathers
                trailing = (tuple(dp),) + (None,) * (x.ndim - 1)
            elif kind == "block_in" and strategy == "tp2d":
                # Megatron-SP block entry: gather the sequence (replicate on
                # the TP axis) so weight gradients contract an unsharded
                # token dim and materialize at TP-sharded shape instead of
                # full (d, ff) partials (§Perf iteration A4)
                trailing = (tuple(dp),) + (None,) * (x.ndim - 1)
            else:
                trailing = (tuple(dp),) + (None,) * (x.ndim - 1)
            spec = _fit(trailing, x.shape, mesh)
            break
    if spec is None or spec == P(*(None,) * x.ndim):
        # batch unshardable (e.g. B=1 long-context): shard sequence on data
        if x.ndim >= 2 and x.shape[1] % _axis_size(mesh, "data") == 0:
            tr = (None, "data") + (None,) * (x.ndim - 2)
            if (kind == "logits" and strategy == "tp2d"
                    and x.shape[-1] % _axis_size(mesh, "model") == 0):
                tr = tr[:-1] + ("model",)
            spec = _fit(tr, x.shape, mesh)
        else:
            return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))
