"""Pallas TPU kernels for the posit datapath hot spots.

Each kernel module pairs pl.pallas_call + explicit BlockSpec VMEM tiling
with a pure-jnp oracle in ref.py; ops.py is the jit'd dispatch layer.
"""
from repro.kernels.ops import (attention, decode, divide, elementwise,
                               encode, flash_prefill, gemm, grouped_matmul,
                               paged_prefill_attention, pallas_interpret,
                               pw_matmul, use_pallas)

__all__ = ["gemm", "pw_matmul", "grouped_matmul", "elementwise", "divide",
           "decode", "encode", "attention", "flash_prefill",
           "paged_prefill_attention", "use_pallas", "pallas_interpret"]
