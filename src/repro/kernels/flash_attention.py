"""Pallas TPU kernel: blockwise (flash) attention with posit KV-cache decode
fused into the score/value matmuls.

Serving is memory-bound on KV-cache reads; storing KV as posit16/posit8
halves/quarters those bytes (paper C4/C6 applied to LMs — the central
serving win measured in EXPERIMENTS.md §Perf).  The decode (stage (i) of
the FPPU) happens on VMEM tiles right before the MXU, so HBM only ever sees
the narrow ints.

Standard online-softmax across KV blocks; supports causal masking with a
query-position offset (decode steps: q_len << kv_len).

Three fused entry points share the decode-before-the-MXU structure:
  * flash_attention            — contiguous KV, rectangular batch (training)
  * paged_flash_decode         — Sq == 1 over the paged pool (serving decode)
  * paged_flash_prefill /      — Sq >= 1 over the paged pool / a contiguous
    flash_prefill_contiguous     cache: the chunked-prefill + TTFT hot path.
                                 One kernel body, two BlockSpec wirings; the
                                 page table (paged) or the block index
                                 (contiguous) picks each KV tile, and
                                 causal/q_offset/window/softcap are masked
                                 in-kernel, so no `gather_kv` dense
                                 materialization exists on the TPU path for
                                 any Sq.

Every grid is tagged with `dimension_semantics`: batch and q-tile axes are
"parallel" (no cross-iteration state), the KV axis is "arbitrary" (it
carries the online-softmax running max/sum/acc), which lets Mosaic
parallelize across cores without breaking the accumulation order.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.decode import decode_to_f32
from repro.core.types import PositConfig

_NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  cfg_kv, nkv, scale, causal, bq, bk, q_offset, kv_len):
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0]
    v = v_ref[0]
    if cfg_kv is not None:
        k = decode_to_f32(k, cfg_kv)
        v = decode_to_f32(v, cfg_kv)
    else:
        k = k.astype(jnp.float32)
        v = v.astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    qpos = q_offset + pl.program_id(1) * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0)
    kpos = kv_idx * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = kpos < kv_len                             # mask KV padding
    if causal:
        valid = valid & (qpos >= kpos)
    s = jnp.where(valid, s, _NEG)

    m_prev = m_ref[...][:, :1]                        # (bq, 1)
    m_cur = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_cur)
    alpha = jnp.exp(m_prev - m_cur)                   # (bq, 1)
    l_ref[...] = l_ref[...] * alpha + jnp.broadcast_to(
        p.sum(axis=1, keepdims=True), l_ref.shape)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_cur, m_ref.shape)

    @pl.when(kv_idx == nkv - 1)
    def _done():
        l = l_ref[...][:, :1]
        o_ref[0] = acc_ref[...] / jnp.where(l == 0, 1.0, l)


def _paged_decode_kernel(pt_ref, sl_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, cfg_kv, n_kv, groups,
                         page, n_pages, scale, window):
    """One (sequence, page) cell of the paged decode grid.

    The page index was resolved by the BlockSpec index_map from the
    prefetched page table, so k_ref/v_ref already hold this sequence's
    j-th KV page in VMEM; posit pages decode here, right before the dot —
    HBM only ever saw the narrow ints.
    """
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    d = q_ref.shape[-1]
    q = q_ref[0].astype(jnp.float32).reshape(n_kv, groups, d)
    k = k_ref[0]
    v = v_ref[0]
    if cfg_kv is not None:
        k = decode_to_f32(k, cfg_kv)
        v = decode_to_f32(v, cfg_kv)
    else:
        k = k.astype(jnp.float32)
        v = v.astype(jnp.float32)

    # s[kv, g, p] = q[kv, g, :] . k[kv, p, :]  (batched over the kv head)
    s = jax.lax.dot_general(q, k, (((2,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32) * scale
    kpos = j * page + jax.lax.broadcasted_iota(jnp.int32,
                                               (n_kv, groups, page), 2)
    valid = kpos < sl_ref[b]
    if window is not None:
        # local attention: the query sits at position sl-1 (the cache is
        # post-append), so it sees kpos in (sl-1-window, sl) — identical to
        # the blockwise decode path's `qpos - kpos < window` mask
        valid = valid & (kpos > sl_ref[b] - 1 - window)
    s = jnp.where(valid, s, _NEG)

    m_prev = m_ref[...][:, :, :1]                     # (n_kv, groups, 1)
    m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_cur)
    alpha = jnp.exp(m_prev - m_cur)
    l_ref[...] = l_ref[...] * alpha + jnp.broadcast_to(
        p.sum(axis=-1, keepdims=True), l_ref.shape)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_cur, m_ref.shape)

    @pl.when(j == n_pages - 1)
    def _done():
        l = l_ref[...][:, :, :1]
        out = acc_ref[...] / jnp.where(l == 0, 1.0, l)
        o_ref[0] = out.reshape(n_kv * groups, d)


def _prefill_body(sl_ref, qo_ref, q_ref, k_ref, v_ref, o_ref, *rest,
                  cfg_kv, n_kv, groups, bq, bk,
                  nkv_blocks, scale, causal, window, softcap,
                  with_lse=False):
    """One (sequence, q-tile, kv-tile) cell of the fused prefill grid.

    Shared by the paged entry (the BlockSpec index_map resolved the KV tile
    from the scalar-prefetched page table) and the contiguous entry (the
    tile is block j of the dense cache).  Posit KV tiles decode here, in
    VMEM, right before the dot — the dense f32 view the gather_kv fallback
    materialized never exists.  GQA keeps the group dim folded into the
    query rows: q is (n_kv, groups*bq, d) so one batched dot per kv head
    feeds the MXU without repeating K/V across groups.

    with_lse: also emit the log-sum-exp rows (m + log l), the residual the
    backward kernels need to rebuild p = exp(s - lse) without re-running the
    online softmax.
    """
    if with_lse:
        lse_ref, m_ref, l_ref, acc_ref = rest
    else:
        lse_ref = None
        m_ref, l_ref, acc_ref = rest
    b = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    d = q_ref.shape[-1]
    # (H, bq, d) -> (n_kv, groups*bq, d): heads are (kv, group)-major, so a
    # single reshape folds the group axis into the query-row axis
    q = q_ref[0].astype(jnp.float32).reshape(n_kv, groups * bq, d)
    k = k_ref[0]
    v = v_ref[0]
    if cfg_kv is not None:
        k = decode_to_f32(k, cfg_kv)
        v = decode_to_f32(v, cfg_kv)
    else:
        k = k.astype(jnp.float32)
        v = v.astype(jnp.float32)

    # s[kv, g*bq + qi, p] = q . k  (batched over the kv head)
    s = jax.lax.dot_general(q, k, (((2,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap
    # row r of the folded axis is query qi = r % bq of this tile
    qpos = qo_ref[b] + i * bq + jax.lax.broadcasted_iota(
        jnp.int32, (n_kv, groups * bq, bk), 1) % bq
    kpos = j * bk + jax.lax.broadcasted_iota(
        jnp.int32, (n_kv, groups * bq, bk), 2)
    valid = kpos < sl_ref[b]                          # KV padding / garbage
    if causal:
        valid = valid & (qpos >= kpos)
    if window is not None:
        valid = valid & (qpos - kpos < window)
    s = jnp.where(valid, s, _NEG)

    m_prev = m_ref[...][:, :, :1]                     # (n_kv, g*bq, 1)
    m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_cur)
    alpha = jnp.exp(m_prev - m_cur)
    l_ref[...] = l_ref[...] * alpha + jnp.broadcast_to(
        p.sum(axis=-1, keepdims=True), l_ref.shape)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_cur, m_ref.shape)

    @pl.when(j == nkv_blocks - 1)
    def _done():
        l = l_ref[...][:, :, :1]
        safe_l = jnp.where(l == 0, 1.0, l)
        out = acc_ref[...] / safe_l
        o_ref[0] = out.reshape(n_kv * groups, bq, d)
        if lse_ref is not None:
            # fully-masked rows (l == 0, m == -inf) get lse = 0: finite, and
            # their p = exp(_NEG - 0) underflows to exactly 0 in the backward
            m = m_ref[...][:, :, :1]
            lse = jnp.where(l == 0, 0.0, m + jnp.log(safe_l))
            lse_ref[0] = lse[..., 0].reshape(n_kv * groups, bq)


def _prefill_scratch(n_kv, groups, bq, d):
    return [
        pltpu.VMEM((n_kv, groups * bq, 128), jnp.float32),
        pltpu.VMEM((n_kv, groups * bq, 128), jnp.float32),
        pltpu.VMEM((n_kv, groups * bq, d), jnp.float32),
    ]


# batch and q-tile axes carry no state; the kv axis owns the online-softmax
# accumulators and must run in order
_PREFILL_SEMANTICS = ("parallel", "parallel", "arbitrary")


@functools.partial(
    jax.jit,
    static_argnames=("cfg_kv", "causal", "window", "softcap", "bq",
                     "interpret"),
)
def paged_flash_prefill(q: jnp.ndarray, k_pages: jnp.ndarray,
                        v_pages: jnp.ndarray, page_table: jnp.ndarray,
                        seq_lens: jnp.ndarray, q_offset: jnp.ndarray, *,
                        cfg_kv: PositConfig | None = None,
                        causal: bool = True, window: int | None = None,
                        softcap: float | None = None, bq: int = 128,
                        interpret: bool = False) -> jnp.ndarray:
    """Fused paged prefill attention (the chunked-prefill / TTFT hot path).

    q [B, H, Sq, D] x paged KV pool -> [B, H, Sq, D] f32.  The pool layout
    matches paged_flash_decode: k_pages/v_pages [num_pages, n_kv, page, D]
    (posit storage ints when cfg_kv is set), page_table [B, W] scalar-
    prefetched so the BlockSpec index map streams exactly the pages each
    sequence owns into VMEM.  seq_lens [B] is the *post-append* valid
    length (positions >= it are masked); q_offset [B] is the absolute
    position of each sequence's first query row (mid-prefill chunks:
    seq_lens - num_new).  Query rows beyond the caller's real chunk length
    compute garbage and must be ignored by the caller (the engine reads the
    last *valid* position only).  softcap/window/causal are masked
    in-kernel — the conditions that used to force the gather_kv dense
    fallback.
    """
    B, H, Sq, d = q.shape
    _, n_kv, page, _ = k_pages.shape
    _, W = page_table.shape
    groups = H // n_kv
    scale = 1.0 / (d ** 0.5)
    bq_ = min(bq, max(8, Sq))
    pq = (-Sq) % bq_
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    nq = (Sq + pq) // bq_
    grid = (B, nq, W)

    body = functools.partial(
        _prefill_body, cfg_kv=cfg_kv, n_kv=n_kv, groups=groups, bq=bq_,
        bk=page, nkv_blocks=W, scale=scale, causal=causal, window=window,
        softcap=softcap)

    def kernel(pt_ref, sl_ref, qo_ref, *rest):
        body(sl_ref, qo_ref, *rest)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, H, bq_, d),
                         lambda b, i, j, pt, sl, qo: (b, 0, i, 0)),
            pl.BlockSpec((1, n_kv, page, d),
                         lambda b, i, j, pt, sl, qo: (pt[b, j], 0, 0, 0)),
            pl.BlockSpec((1, n_kv, page, d),
                         lambda b, i, j, pt, sl, qo: (pt[b, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, bq_, d),
                               lambda b, i, j, pt, sl, qo: (b, 0, i, 0)),
        scratch_shapes=_prefill_scratch(n_kv, groups, bq_, d),
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, Sq + pq, d), jnp.float32),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=_PREFILL_SEMANTICS),
        interpret=interpret,
    )(page_table, seq_lens, q_offset, q, k_pages, v_pages)
    return out[:, :, :Sq, :]


@functools.partial(
    jax.jit,
    static_argnames=("cfg_kv", "causal", "window", "softcap", "bq", "bk",
                     "return_lse", "interpret"),
)
def flash_prefill_contiguous(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                             kv_len: jnp.ndarray, q_offset: jnp.ndarray, *,
                             cfg_kv: PositConfig | None = None,
                             causal: bool = True, window: int | None = None,
                             softcap: float | None = None, bq: int = 128,
                             bk: int = 256, return_lse: bool = False,
                             interpret: bool = False) -> jnp.ndarray:
    """The prefill kernel over a contiguous (dense-cache / training) KV.

    q [B, H, Sq, D] x k/v [B, n_kv, Skv, D] -> [B, H, Sq, D] f32.  Same
    kernel body as paged_flash_prefill; the KV tile index map is the block
    index instead of a page-table lookup, so the dense engine's prefill and
    the training forward stream the cache (posit ints or float) tile by
    tile without any dense f32 copy.  kv_len/q_offset [B] as in the paged
    entry (scalars must be broadcast by the caller).

    Default blocks: bq=128 query rows x bk=256 KV rows keeps the f32
    working set (decoded K+V tiles + acc + running stats) under ~2 MB for
    d=128 GQA shapes — small enough to double-buffer the posit tile
    fetches, large enough that every HBM byte feeds >= bq MXU MACs (well
    past the ~300 flops/byte ridge at posit16 width).

    return_lse: additionally return the row log-sum-exps [B, H, Sq] f32 —
    the residual the training backward saves so the dQ/dK/dV kernels can
    rebuild p = exp(s - lse) tile by tile.
    """
    B, H, Sq, d = q.shape
    _, n_kv, Skv, _ = k.shape
    groups = H // n_kv
    scale = 1.0 / (d ** 0.5)
    bq_ = min(bq, max(8, Sq))
    bk_ = min(bk, Skv)
    pq = (-Sq) % bq_
    pk = (-Skv) % bk_
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        # padded keys sit at kpos >= Skv >= kv_len and are masked in-kernel
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq, nk = (Sq + pq) // bq_, (Skv + pk) // bk_
    grid = (B, nq, nk)

    body = functools.partial(
        _prefill_body, cfg_kv=cfg_kv, n_kv=n_kv, groups=groups, bq=bq_,
        bk=bk_, nkv_blocks=nk, scale=scale, causal=causal, window=window,
        softcap=softcap, with_lse=return_lse)

    o_spec = pl.BlockSpec((1, H, bq_, d), lambda b, i, j, sl, qo: (b, 0, i, 0))
    o_shape = jax.ShapeDtypeStruct((B, H, Sq + pq, d), jnp.float32)
    if return_lse:
        out_specs = [o_spec,
                     pl.BlockSpec((1, H, bq_), lambda b, i, j, sl, qo: (b, 0, i))]
        out_shape = [o_shape,
                     jax.ShapeDtypeStruct((B, H, Sq + pq), jnp.float32)]
    else:
        out_specs, out_shape = o_spec, o_shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, H, bq_, d),
                         lambda b, i, j, sl, qo: (b, 0, i, 0)),
            pl.BlockSpec((1, n_kv, bk_, d),
                         lambda b, i, j, sl, qo: (b, 0, j, 0)),
            pl.BlockSpec((1, n_kv, bk_, d),
                         lambda b, i, j, sl, qo: (b, 0, j, 0)),
        ],
        out_specs=out_specs,
        scratch_shapes=_prefill_scratch(n_kv, groups, bq_, d),
    )
    res = pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=_PREFILL_SEMANTICS),
        interpret=interpret,
    )(kv_len, q_offset, q, k, v)
    if return_lse:
        out, lse = res
        return out[:, :, :Sq, :], lse[:, :, :Sq]
    return res[:, :, :Sq, :]


def _bwd_probs(q, k, lse, qo_b, sl_b, i, j, *, n_kv, groups, bq, bk, scale,
               causal, window, softcap):
    """Recompute p = exp(s - lse) for one (q-tile, kv-tile) pair with the
    forward's exact masking, plus the softcap chain factor d s_cap / d s.

    The chain factor is taken from the *unmasked* capped scores (bounded in
    [-softcap, softcap]); masked positions are killed through p alone, so no
    inf/NaN from (_NEG / softcap)**2 can leak into the products.
    """
    s = jax.lax.dot_general(q, k, (((2,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        t = jnp.tanh(s / softcap)
        s = t * softcap
        dcap = 1.0 - t * t
    else:
        dcap = None
    qpos = qo_b + i * bq + jax.lax.broadcasted_iota(
        jnp.int32, (n_kv, groups * bq, bk), 1) % bq
    kpos = j * bk + jax.lax.broadcasted_iota(
        jnp.int32, (n_kv, groups * bq, bk), 2)
    valid = kpos < sl_b
    if causal:
        valid = valid & (qpos >= kpos)
    if window is not None:
        valid = valid & (qpos - kpos < window)
    p = jnp.exp(jnp.where(valid, s, _NEG) - lse)
    return p, dcap


def _prefill_bwd_dq_body(sl_ref, qo_ref, q_ref, k_ref, v_ref, do_ref,
                         lse_ref, delta_ref, dq_ref, dq_acc, *, cfg_kv,
                         n_kv, groups, bq, bk, nkv_blocks, scale, causal,
                         window, softcap):
    """dQ tile: sweep the kv axis, accumulating ds @ K in an f32 VMEM
    scratch (the per-tile quire) and writing once at the last kv block.
    Posit KV decodes in VMEM exactly as in the forward — the backward
    never materializes an f32 cache either.
    """
    b = pl.program_id(0)
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    d = q_ref.shape[-1]
    q = q_ref[0].astype(jnp.float32).reshape(n_kv, groups * bq, d)
    k = k_ref[0]
    v = v_ref[0]
    if cfg_kv is not None:
        k = decode_to_f32(k, cfg_kv)
        v = decode_to_f32(v, cfg_kv)
    else:
        k = k.astype(jnp.float32)
        v = v.astype(jnp.float32)

    lse = lse_ref[0].reshape(n_kv, groups * bq, 1)
    p, dcap = _bwd_probs(q, k, lse, qo_ref[b], sl_ref[b], i, j, n_kv=n_kv,
                         groups=groups, bq=bq, bk=bk, scale=scale,
                         causal=causal, window=window, softcap=softcap)
    do = do_ref[0].astype(jnp.float32).reshape(n_kv, groups * bq, d)
    dp = jax.lax.dot_general(do, v, (((2,), (2,)), ((0,), (0,))),
                             preferred_element_type=jnp.float32)
    delta = delta_ref[0].reshape(n_kv, groups * bq, 1)
    ds = p * (dp - delta)
    if dcap is not None:
        ds = ds * dcap
    # ds is d loss / d (scaled scores): one scale chains back to q
    dq_acc[...] += jax.lax.dot_general(
        ds, k, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32) * scale

    @pl.when(j == nkv_blocks - 1)
    def _done():
        dq_ref[0] = dq_acc[...].reshape(n_kv * groups, bq, d)


def _prefill_bwd_dkv_body(sl_ref, qo_ref, q_ref, k_ref, v_ref, do_ref,
                          lse_ref, delta_ref, dk_ref, dv_ref, dk_acc,
                          dv_acc, *, n_kv, groups, bq, bk, nq_blocks, scale,
                          causal, window, softcap):
    """dK/dV tile: the kv tile is pinned (axis 1), the q axis sweeps (axis
    2) carrying the two f32 accumulators.  The folded (group, q-row) axis is
    the contraction, so the GQA group-sum falls out of the same reshape the
    forward uses.  Only called for float KV — posit caches carry no
    tangent.
    """
    b = pl.program_id(0)
    j = pl.program_id(1)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    d = q_ref.shape[-1]
    q = q_ref[0].astype(jnp.float32).reshape(n_kv, groups * bq, d)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)

    lse = lse_ref[0].reshape(n_kv, groups * bq, 1)
    p, dcap = _bwd_probs(q, k, lse, qo_ref[b], sl_ref[b], i, j, n_kv=n_kv,
                         groups=groups, bq=bq, bk=bk, scale=scale,
                         causal=causal, window=window, softcap=softcap)
    do = do_ref[0].astype(jnp.float32).reshape(n_kv, groups * bq, d)
    # padded / garbage q rows contribute nothing: their do is zero-padded,
    # so p^T do and ds^T q vanish row by row
    dv_acc[...] += jax.lax.dot_general(
        p, do, (((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do, v, (((2,), (2,)), ((0,), (0,))),
                             preferred_element_type=jnp.float32)
    delta = delta_ref[0].reshape(n_kv, groups * bq, 1)
    ds = p * (dp - delta)
    if dcap is not None:
        ds = ds * dcap
    dk_acc[...] += jax.lax.dot_general(
        ds, q, (((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32) * scale

    @pl.when(i == nq_blocks - 1)
    def _done():
        dk_ref[0] = dk_acc[...]
        dv_ref[0] = dv_acc[...]


@functools.partial(
    jax.jit,
    static_argnames=("cfg_kv", "causal", "window", "softcap", "bq", "bk",
                     "interpret"),
)
def flash_prefill_bwd_contiguous(q: jnp.ndarray, k: jnp.ndarray,
                                 v: jnp.ndarray, o: jnp.ndarray,
                                 lse: jnp.ndarray, do: jnp.ndarray,
                                 kv_len: jnp.ndarray, q_offset: jnp.ndarray,
                                 *, cfg_kv: PositConfig | None = None,
                                 causal: bool = True,
                                 window: int | None = None,
                                 softcap: float | None = None, bq: int = 128,
                                 bk: int = 256, interpret: bool = False):
    """Backward of flash_prefill_contiguous: (dQ, dK, dV).

    Two kernels over the same tiles as the forward: dQ pins the q tile and
    sweeps kv; dK/dV pin the kv tile and sweep q.  Both rebuild the scores
    from (q, k, lse) — classic flash backward, no [Sq, Skv] matrix ever
    exists — and accumulate in per-tile f32 VMEM scratch (the PERCIVAL
    quire analogue: narrow storage, wide accumulation).  delta = rowsum
    (dO * O) is the only host-side precompute.  Posit KV (cfg_kv set)
    decodes in VMEM for dQ and returns dK = dV = None: storage ints carry
    no tangent, matching the jnp-reference oracle.
    """
    B, H, Sq, d = q.shape
    _, n_kv, Skv, _ = k.shape
    groups = H // n_kv
    scale = 1.0 / (d ** 0.5)
    bq_ = min(bq, max(8, Sq))
    bk_ = min(bk, Skv)
    pq = (-Sq) % bq_
    pk = (-Skv) % bk_

    delta = (do.astype(jnp.float32) * o.astype(jnp.float32)).sum(-1)
    lse = lse.astype(jnp.float32)
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
        do = jnp.pad(do, ((0, 0), (0, 0), (0, pq), (0, 0)))
        lse = jnp.pad(lse, ((0, 0), (0, 0), (0, pq)))
        delta = jnp.pad(delta, ((0, 0), (0, 0), (0, pq)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq, nk = (Sq + pq) // bq_, (Skv + pk) // bk_

    qd_spec = pl.BlockSpec((1, H, bq_, d), lambda b, i, j, sl, qo: (b, 0, i, 0))
    kv_spec = pl.BlockSpec((1, n_kv, bk_, d),
                           lambda b, i, j, sl, qo: (b, 0, j, 0))
    row_spec = pl.BlockSpec((1, H, bq_), lambda b, i, j, sl, qo: (b, 0, i))

    dq = pl.pallas_call(
        functools.partial(
            _prefill_bwd_dq_body, cfg_kv=cfg_kv, n_kv=n_kv, groups=groups,
            bq=bq_, bk=bk_, nkv_blocks=nk, scale=scale, causal=causal,
            window=window, softcap=softcap),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, nq, nk),
            in_specs=[qd_spec, kv_spec, kv_spec, qd_spec, row_spec, row_spec],
            out_specs=qd_spec,
            scratch_shapes=[pltpu.VMEM((n_kv, groups * bq_, d), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq + pq, d), jnp.float32),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=_PREFILL_SEMANTICS),
        interpret=interpret,
    )(kv_len, q_offset, q, k, v, do, lse, delta)[:, :, :Sq, :]

    if cfg_kv is not None:
        return dq, None, None

    # kv tile on the parallel axis 1, q sweep (with the accumulators) on
    # the trailing "arbitrary" axis
    qd_spec2 = pl.BlockSpec((1, H, bq_, d),
                            lambda b, j, i, sl, qo: (b, 0, i, 0))
    kv_spec2 = pl.BlockSpec((1, n_kv, bk_, d),
                            lambda b, j, i, sl, qo: (b, 0, j, 0))
    row_spec2 = pl.BlockSpec((1, H, bq_), lambda b, j, i, sl, qo: (b, 0, i))
    dk, dv = pl.pallas_call(
        functools.partial(
            _prefill_bwd_dkv_body, n_kv=n_kv, groups=groups, bq=bq_, bk=bk_,
            nq_blocks=nq, scale=scale, causal=causal, window=window,
            softcap=softcap),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, nk, nq),
            in_specs=[qd_spec2, kv_spec2, kv_spec2, qd_spec2, row_spec2,
                      row_spec2],
            out_specs=[kv_spec2, kv_spec2],
            scratch_shapes=[pltpu.VMEM((n_kv, bk_, d), jnp.float32),
                            pltpu.VMEM((n_kv, bk_, d), jnp.float32)],
        ),
        out_shape=[jax.ShapeDtypeStruct((B, n_kv, Skv + pk, d), jnp.float32),
                   jax.ShapeDtypeStruct((B, n_kv, Skv + pk, d), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=_PREFILL_SEMANTICS),
        interpret=interpret,
    )(kv_len, q_offset, q, k, v, do, lse, delta)
    return dq, dk[:, :, :Skv, :], dv[:, :, :Skv, :]


@functools.partial(jax.jit, static_argnames=("cfg_kv", "window", "interpret"))
def paged_flash_decode(q: jnp.ndarray, k_pages: jnp.ndarray,
                       v_pages: jnp.ndarray, page_table: jnp.ndarray,
                       seq_lens: jnp.ndarray, *,
                       cfg_kv: PositConfig | None = None,
                       window: int | None = None,
                       interpret: bool = False) -> jnp.ndarray:
    """Fused paged-gather decode attention (the continuous-batching hot path).

    q [B, H, D] x paged KV pool -> [B, H, D].  k_pages/v_pages
    [num_pages, n_kv, page, D] hold posit storage ints when cfg_kv is set;
    page_table [B, W] names each sequence's pages in position order and is
    scalar-prefetched so the BlockSpec index_map can stream exactly the
    pages a sequence owns — the dense `materialize_kv` copy never exists.
    Positions >= seq_lens[b] (garbage-page tails, unallocated entries) are
    masked.  GQA: H = n_kv * groups, query head h reads kv head h // groups.
    window: sliding-window (local-attention) size — the decode query at
    position seq_lens[b]-1 attends only the last `window` tokens.  Pages
    entirely outside the window still stream (the grid is static over W);
    their scores are masked to -inf, matching the gathered reference.
    """
    bh, H, d = q.shape
    n_pages_total, n_kv, page, _ = k_pages.shape
    _, W = page_table.shape
    groups = H // n_kv
    scale = 1.0 / (d ** 0.5)
    grid = (bh, W)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, H, d), lambda b, j, pt, sl: (b, 0, 0)),
            pl.BlockSpec((1, n_kv, page, d),
                         lambda b, j, pt, sl: (pt[b, j], 0, 0, 0)),
            pl.BlockSpec((1, n_kv, page, d),
                         lambda b, j, pt, sl: (pt[b, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, d), lambda b, j, pt, sl: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((n_kv, groups, 128), jnp.float32),
            pltpu.VMEM((n_kv, groups, 128), jnp.float32),
            pltpu.VMEM((n_kv, groups, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_decode_kernel, cfg_kv=cfg_kv, n_kv=n_kv,
                          groups=groups, page=page, n_pages=W, scale=scale,
                          window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bh, H, d), jnp.float32),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(page_table, seq_lens, q, k_pages, v_pages)


@functools.partial(
    jax.jit,
    static_argnames=("cfg_kv", "causal", "bq", "bk", "interpret"),
)
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    cfg_kv: PositConfig | None = None, causal: bool = True,
                    bq: int = 128, bk: int = 512,
                    interpret: bool = False) -> jnp.ndarray:
    """q [BH, Sq, D] x k,v [BH, Skv, D] -> [BH, Sq, D].

    k/v are posit storage ints when cfg_kv is given, else float.  The causal
    mask assumes queries occupy the *last* Sq positions of the Skv context
    (prefill: Sq == Skv; decode: Sq == 1).
    """
    bh, sq, d = q.shape
    _, skv, _ = k.shape
    bq_ = min(bq, max(8, sq))
    bk_ = min(bk, skv)
    pq = (-sq) % bq_
    pk = (-skv) % bk_
    # pad keys with zeros and mask them off via position bounds below; padded
    # queries produce garbage rows that are sliced away.
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
    sqp, skvp = sq + pq, skv + pk
    grid = (bh, sqp // bq_, skvp // bk_)
    scale = 1.0 / (d ** 0.5)
    q_offset = skv - sq                       # causal alignment

    out = pl.pallas_call(
        functools.partial(_flash_kernel, cfg_kv=cfg_kv, nkv=grid[2],
                          scale=scale, causal=causal, bq=bq_, bk=bk_,
                          q_offset=q_offset, kv_len=skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq_, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk_, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk_, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sqp, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bq_, 128), jnp.float32),
            pltpu.VMEM((bq_, 128), jnp.float32),
            pltpu.VMEM((bq_, d), jnp.float32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=_PREFILL_SEMANTICS),
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq, :]
