"""Pallas TPU kernel: blockwise (flash) attention with posit KV-cache decode
fused into the score/value matmuls.

Serving is memory-bound on KV-cache reads; storing KV as posit16/posit8
halves/quarters those bytes (paper C4/C6 applied to LMs — the central
serving win measured in EXPERIMENTS.md §Perf).  The decode (stage (i) of
the FPPU) happens on VMEM tiles right before the MXU, so HBM only ever sees
the narrow ints.

Standard online-softmax across KV blocks; supports causal masking with a
query-position offset (decode steps: q_len << kv_len).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.decode import decode_to_f32
from repro.core.types import PositConfig

_NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  cfg_kv, nkv, scale, causal, bq, bk, q_offset, kv_len):
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0]
    v = v_ref[0]
    if cfg_kv is not None:
        k = decode_to_f32(k, cfg_kv)
        v = decode_to_f32(v, cfg_kv)
    else:
        k = k.astype(jnp.float32)
        v = v.astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    qpos = q_offset + pl.program_id(1) * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0)
    kpos = kv_idx * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = kpos < kv_len                             # mask KV padding
    if causal:
        valid = valid & (qpos >= kpos)
    s = jnp.where(valid, s, _NEG)

    m_prev = m_ref[...][:, :1]                        # (bq, 1)
    m_cur = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_cur)
    alpha = jnp.exp(m_prev - m_cur)                   # (bq, 1)
    l_ref[...] = l_ref[...] * alpha + jnp.broadcast_to(
        p.sum(axis=1, keepdims=True), l_ref.shape)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_cur, m_ref.shape)

    @pl.when(kv_idx == nkv - 1)
    def _done():
        l = l_ref[...][:, :1]
        o_ref[0] = acc_ref[...] / jnp.where(l == 0, 1.0, l)


def _paged_decode_kernel(pt_ref, sl_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, cfg_kv, n_kv, groups,
                         page, n_pages, scale, window):
    """One (sequence, page) cell of the paged decode grid.

    The page index was resolved by the BlockSpec index_map from the
    prefetched page table, so k_ref/v_ref already hold this sequence's
    j-th KV page in VMEM; posit pages decode here, right before the dot —
    HBM only ever saw the narrow ints.
    """
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    d = q_ref.shape[-1]
    q = q_ref[0].astype(jnp.float32).reshape(n_kv, groups, d)
    k = k_ref[0]
    v = v_ref[0]
    if cfg_kv is not None:
        k = decode_to_f32(k, cfg_kv)
        v = decode_to_f32(v, cfg_kv)
    else:
        k = k.astype(jnp.float32)
        v = v.astype(jnp.float32)

    # s[kv, g, p] = q[kv, g, :] . k[kv, p, :]  (batched over the kv head)
    s = jax.lax.dot_general(q, k, (((2,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32) * scale
    kpos = j * page + jax.lax.broadcasted_iota(jnp.int32,
                                               (n_kv, groups, page), 2)
    valid = kpos < sl_ref[b]
    if window is not None:
        # local attention: the query sits at position sl-1 (the cache is
        # post-append), so it sees kpos in (sl-1-window, sl) — identical to
        # the blockwise decode path's `qpos - kpos < window` mask
        valid = valid & (kpos > sl_ref[b] - 1 - window)
    s = jnp.where(valid, s, _NEG)

    m_prev = m_ref[...][:, :, :1]                     # (n_kv, groups, 1)
    m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_cur)
    alpha = jnp.exp(m_prev - m_cur)
    l_ref[...] = l_ref[...] * alpha + jnp.broadcast_to(
        p.sum(axis=-1, keepdims=True), l_ref.shape)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_cur, m_ref.shape)

    @pl.when(j == n_pages - 1)
    def _done():
        l = l_ref[...][:, :, :1]
        out = acc_ref[...] / jnp.where(l == 0, 1.0, l)
        o_ref[0] = out.reshape(n_kv * groups, d)


@functools.partial(jax.jit, static_argnames=("cfg_kv", "window", "interpret"))
def paged_flash_decode(q: jnp.ndarray, k_pages: jnp.ndarray,
                       v_pages: jnp.ndarray, page_table: jnp.ndarray,
                       seq_lens: jnp.ndarray, *,
                       cfg_kv: PositConfig | None = None,
                       window: int | None = None,
                       interpret: bool = False) -> jnp.ndarray:
    """Fused paged-gather decode attention (the continuous-batching hot path).

    q [B, H, D] x paged KV pool -> [B, H, D].  k_pages/v_pages
    [num_pages, n_kv, page, D] hold posit storage ints when cfg_kv is set;
    page_table [B, W] names each sequence's pages in position order and is
    scalar-prefetched so the BlockSpec index_map can stream exactly the
    pages a sequence owns — the dense `materialize_kv` copy never exists.
    Positions >= seq_lens[b] (garbage-page tails, unallocated entries) are
    masked.  GQA: H = n_kv * groups, query head h reads kv head h // groups.
    window: sliding-window (local-attention) size — the decode query at
    position seq_lens[b]-1 attends only the last `window` tokens.  Pages
    entirely outside the window still stream (the grid is static over W);
    their scores are masked to -inf, matching the gathered reference.
    """
    bh, H, d = q.shape
    n_pages_total, n_kv, page, _ = k_pages.shape
    _, W = page_table.shape
    groups = H // n_kv
    scale = 1.0 / (d ** 0.5)
    grid = (bh, W)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, H, d), lambda b, j, pt, sl: (b, 0, 0)),
            pl.BlockSpec((1, n_kv, page, d),
                         lambda b, j, pt, sl: (pt[b, j], 0, 0, 0)),
            pl.BlockSpec((1, n_kv, page, d),
                         lambda b, j, pt, sl: (pt[b, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, d), lambda b, j, pt, sl: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((n_kv, groups, 128), jnp.float32),
            pltpu.VMEM((n_kv, groups, 128), jnp.float32),
            pltpu.VMEM((n_kv, groups, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_decode_kernel, cfg_kv=cfg_kv, n_kv=n_kv,
                          groups=groups, page=page, n_pages=W, scale=scale,
                          window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bh, H, d), jnp.float32),
        interpret=interpret,
    )(page_table, seq_lens, q, k_pages, v_pages)


@functools.partial(
    jax.jit,
    static_argnames=("cfg_kv", "causal", "bq", "bk", "interpret"),
)
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    cfg_kv: PositConfig | None = None, causal: bool = True,
                    bq: int = 128, bk: int = 512,
                    interpret: bool = False) -> jnp.ndarray:
    """q [BH, Sq, D] x k,v [BH, Skv, D] -> [BH, Sq, D].

    k/v are posit storage ints when cfg_kv is given, else float.  The causal
    mask assumes queries occupy the *last* Sq positions of the Skv context
    (prefill: Sq == Skv; decode: Sq == 1).
    """
    bh, sq, d = q.shape
    _, skv, _ = k.shape
    bq_ = min(bq, max(8, sq))
    bk_ = min(bk, skv)
    pq = (-sq) % bq_
    pk = (-skv) % bk_
    # pad keys with zeros and mask them off via position bounds below; padded
    # queries produce garbage rows that are sliced away.
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
    sqp, skvp = sq + pq, skv + pk
    grid = (bh, sqp // bq_, skvp // bk_)
    scale = 1.0 / (d ** 0.5)
    q_offset = skv - sq                       # causal alignment

    out = pl.pallas_call(
        functools.partial(_flash_kernel, cfg_kv=cfg_kv, nkv=grid[2],
                          scale=scale, causal=causal, bq=bq_, bk=bk_,
                          q_offset=q_offset, kv_len=skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq_, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk_, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk_, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sqp, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bq_, 128), jnp.float32),
            pltpu.VMEM((bq_, 128), jnp.float32),
            pltpu.VMEM((bq_, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq, :]
