"""Pallas TPU kernel: grouped posit GEMM — the MoE expert hot path.

`posit_grouped_gemm(x_sorted, w_experts, group_offsets)` multiplies rows of
an expert-sorted activation matrix by *their own group's* weight matrix:

    out[r] = x_sorted[r] @ w_experts[g]   for group_offsets[g] <= r <
                                              group_offsets[g + 1]

This is the sort-based-routing replacement for the GShard one-hot dispatch
(models/moe.py): tokens are argsorted by expert, the per-expert segment
offsets come in as a scalar-prefetched table (the same idiom as the paged
page-table prefetch in kernels/flash_attention.py), and the BlockSpec index
maps stream **only the experts that own at least one row** from HBM — an
inactive expert's [d_model, d_ff] posit block never leaves HBM, and the
full [E, d_model, d_ff] f32 decode the one-hot path performs never exists.
Posit weight tiles decode to exact f32 in VMEM right in front of the MXU
(stage (i) of posit_gemm), and each group accumulates in a f32 scratch —
the PERCIVAL-style quire-per-accumulation analogue (arXiv:2111.15286)
mapped onto the MXU epilogue.

Ragged groups are native: group sizes are arbitrary (including zero), so
the capacity zero-padding of the GShard dispatch disappears.  Groups do
not need to align to tile boundaries — the grid iterates over (group,
m-tile) *incidences* and masks the rows of a shared tile that belong to a
different group, megablocks-style:

  * a physical m-tile fully inside one group is visited once;
  * a tile straddling a group boundary is visited once per group, each
    visit accumulating only its own rows (the other rows of the x tile are
    zeroed before the dot, so the f32 accumulator composes disjoint row
    sets across the consecutive visits);
  * the output tile is written exactly once, at the last visit of its run.

The incidence count is data-dependent but bounded by m_tiles + E - 1, so
the grid is static; trailing slack steps repeat the last incidence with an
all-false row mask (idempotent no-ops).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.decode import decode_to_f32
from repro.core.types import PositConfig


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _group_metadata(group_offsets: jnp.ndarray, n_m_tiles: int, bm: int,
                    n_groups: int):
    """(group, m-tile) incidence tables for the static grid.

    Returns (m_tile_ids [L], group_ids [L], valid [L]) with
    L = n_m_tiles + n_groups - 1 (the worst case: every interior group
    boundary lands strictly inside a tile).  Incidences are ordered by
    (group, tile), which — because groups are contiguous in row space —
    also visits each physical m-tile's incidences consecutively, the
    property the kernel's run-accumulation relies on.  Slack steps past the
    true incidence count repeat the last incidence and are flagged invalid
    (the kernel masks their rows off entirely).
    """
    offsets = group_offsets.astype(jnp.int32)
    starts, ends = offsets[:-1], offsets[1:]
    sizes = ends - starts
    tile_starts = starts // bm
    tile_ends = -(-ends // bm)
    tiles_pg = jnp.where(sizes > 0, tile_ends - tile_starts, 0)
    inc_cum = jnp.cumsum(tiles_pg)
    num_inc = inc_cum[-1]
    L = n_m_tiles + n_groups - 1
    t = jnp.arange(L, dtype=jnp.int32)
    valid = (t < num_inc).astype(jnp.int32)
    tc = jnp.clip(jnp.minimum(t, num_inc - 1), 0, None)
    g = jnp.clip(jnp.searchsorted(inc_cum, tc, side="right"),
                 0, n_groups - 1).astype(jnp.int32)
    pos = tc - (inc_cum - tiles_pg)[g]
    mt = jnp.clip(tile_starts[g] + pos, 0, n_m_tiles - 1).astype(jnp.int32)
    return mt, g, valid


def _grouped_kernel(off_ref, mt_ref, gid_ref, valid_ref, x_ref, w_ref, o_ref,
                    acc_ref, *, cfg_b, bm, nk, L, transpose_b=False):
    """One (n-tile, incidence, k-tile) cell.

    The BlockSpec index maps already resolved this incidence's x m-tile and
    its group's weight tile from the prefetched tables; posit weight tiles
    decode here, in VMEM, right before the dot.  Rows of the x tile outside
    [off[g], off[g+1]) are zeroed so the accumulator — shared across the
    consecutive incidences of one physical tile — composes disjoint row
    sets; it initializes at the first incidence of the run and the output
    tile is written once, at the run's last incidence's final k step.

    transpose_b contracts w on its *last* (storage) dim — the dX backward
    streams the same posit weight tiles at storage width instead of
    materializing a decoded f32 transpose.
    """
    t = pl.program_id(1)
    k = pl.program_id(2)
    mt = mt_ref[t]
    first = jnp.logical_or(t == 0, mt != mt_ref[jnp.maximum(t - 1, 0)])

    @pl.when(first & (k == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = gid_ref[t]
    rows = mt * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0)
    live = ((rows >= off_ref[g]) & (rows < off_ref[g + 1])
            & (valid_ref[t] > 0))
    x = jnp.where(live, x_ref[...].astype(jnp.float32), 0.0)
    w = w_ref[0]
    if cfg_b is not None:
        w = decode_to_f32(w, cfg_b)          # stage (i): posit tile -> f32
    else:
        w = w.astype(jnp.float32)
    if transpose_b:
        acc_ref[...] += jax.lax.dot_general(
            x, w, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
    else:
        acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    last = jnp.logical_or(t == L - 1, mt_ref[jnp.minimum(t + 1, L - 1)] != mt)

    @pl.when(last & (k == nk - 1))
    def _done():
        o_ref[...] = acc_ref[...]


# n-tiles own disjoint output columns; the incidence axis carries the
# per-run accumulator and the k axis the partial sums — both must stay
# ordered
_GROUPED_SEMANTICS = ("parallel", "arbitrary", "arbitrary")


@functools.partial(
    jax.jit,
    static_argnames=("cfg_b", "bm", "bn", "bk", "transpose_b", "interpret"),
)
def posit_grouped_gemm(x: jnp.ndarray, w: jnp.ndarray,
                       group_offsets: jnp.ndarray, *,
                       cfg_b: PositConfig | None,
                       bm: int = 128, bn: int = 512, bk: int = 512,
                       transpose_b: bool = False,
                       interpret: bool = False) -> jnp.ndarray:
    """x [S, k] (expert-sorted rows) x w [E, k, n] -> [S, n] f32.

    group_offsets [E + 1] int32, non-decreasing, with offsets[0] == 0 and
    offsets[E] <= S: rows [offsets[g], offsets[g+1]) belong to group g.
    Rows at or past offsets[E] (e.g. the non-local tail under expert-
    parallel sharding) belong to no group and come back as exact zeros.
    cfg_b None means float weights (still grouped — the one-hot dispatch
    einsums are gone either way); otherwise w holds posit storage ints that
    decode tile-by-tile in VMEM.

    transpose_b: x [S, n] x w [E, k, n] -> [S, k], contracting w on its
    last dim — the dX backward (dx = g @ w[g]^T) over the *same* storage
    layout, so posit experts stream at posit width in the backward too.

    Per-step HBM weight traffic is (incidences x k x n) storage bytes with
    incidences <= ceil(S/bm) + E_active — for a decode step (S = B*top_k
    rows) that is the active experts' posit blocks only, vs the one-hot
    path's full E x k x n f32 materialization (the roofline columns in
    benchmarks/moe_throughput.py).
    """
    S, C = x.shape
    if transpose_b:
        E, Nout, C2 = w.shape
    else:
        E, C2, Nout = w.shape
    assert C == C2, (x.shape, w.shape, transpose_b)
    bm_ = min(bm, _round_up(max(S, 1), 8))
    bk_ = min(bk, C)
    bn_ = min(bn, max(128, Nout))
    Sp, Cp, Np = (_round_up(S, bm_), _round_up(C, bk_), _round_up(Nout, bn_))
    if (Sp, Cp) != (S, C):
        x = jnp.pad(x, ((0, Sp - S), (0, Cp - C)))
    if (Cp, Np) != (C, Nout):
        # zero int padding is posit zero, so padded tiles decode to 0.0
        if transpose_b:
            w = jnp.pad(w, ((0, 0), (0, Np - Nout), (0, Cp - C)))
        else:
            w = jnp.pad(w, ((0, 0), (0, Cp - C), (0, Np - Nout)))
    nm, nk, nn = Sp // bm_, Cp // bk_, Np // bn_
    L = nm + E - 1
    mt, gid, valid = _group_metadata(group_offsets, nm, bm_, E)

    if transpose_b:
        w_spec = pl.BlockSpec((1, bn_, bk_),
                              lambda j, t, k, off, mt, gid, vl: (gid[t], j, k))
    else:
        w_spec = pl.BlockSpec((1, bk_, bn_),
                              lambda j, t, k, off, mt, gid, vl: (gid[t], k, j))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(nn, L, nk),
        in_specs=[
            pl.BlockSpec((bm_, bk_),
                         lambda j, t, k, off, mt, gid, vl: (mt[t], k)),
            w_spec,
        ],
        out_specs=pl.BlockSpec((bm_, bn_),
                               lambda j, t, k, off, mt, gid, vl: (mt[t], j)),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_grouped_kernel, cfg_b=cfg_b, bm=bm_, nk=nk, L=L,
                          transpose_b=transpose_b),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Sp, Np), jnp.float32),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=_GROUPED_SEMANTICS),
        interpret=interpret,
    )(group_offsets.astype(jnp.int32), mt, gid, valid, x, w)[:S, :Nout]
    # tiles that no group touches are never written (their buffer content
    # is undefined); rows outside [offsets[0], offsets[-1]) are defined to
    # be zero, so mask them rather than trust the unwritten buffer
    rows = jnp.arange(S)
    inb = (rows >= group_offsets[0]) & (rows < group_offsets[-1])
    return jnp.where(inb[:, None], out, 0.0)


def _grouped_dw_kernel(off_ref, mt_ref, gid_ref, valid_ref, x_ref, g_ref,
                       o_ref, acc_ref, *, bm, L):
    """One (k-tile, n-tile, incidence) cell of the dW grid.

    dw[g] = x[rows(g)]^T @ gout[rows(g)]: the incidence axis is innermost
    and a group's incidences are consecutive, so one f32 scratch (the
    per-group quire) accumulates the whole group's outer product across its
    m-tiles; it zeroes at the group's first incidence and the [k, n] output
    tile is written once at the group's last.  Rows of a straddling tile
    that belong to the neighbour group are zeroed on the x side — zero rows
    contribute nothing to the contraction.  Empty groups never appear in
    the incidence table; their (unwritten) output blocks are masked by the
    caller.
    """
    t = pl.program_id(2)
    g = gid_ref[t]
    first = jnp.logical_or(t == 0, gid_ref[jnp.maximum(t - 1, 0)] != g)

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    mt = mt_ref[t]
    rows = mt * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0)
    live = ((rows >= off_ref[g]) & (rows < off_ref[g + 1])
            & (valid_ref[t] > 0))
    x = jnp.where(live, x_ref[...].astype(jnp.float32), 0.0)
    gout = g_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        x, gout, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    last = jnp.logical_or(t == L - 1, gid_ref[jnp.minimum(t + 1, L - 1)] != g)

    @pl.when(last)
    def _done():
        o_ref[0] = acc_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "interpret"),
)
def posit_grouped_gemm_dw(x: jnp.ndarray, g: jnp.ndarray,
                          group_offsets: jnp.ndarray, *,
                          bm: int = 128, bn: int = 512, bk: int = 512,
                          interpret: bool = False) -> jnp.ndarray:
    """x [S, k] x g [S, n], both expert-sorted -> dw [E, k, n] f32.

    The grouped-GEMM weight gradient: dw[e] = x[rows(e)]^T @ g[rows(e)],
    accumulated per group in f32 VMEM scratch over the same (group, m-tile)
    incidence grid as the forward.  Only meaningful for float (QAT) expert
    weights — posit storage ints carry no tangent, so the dispatcher never
    calls this for them.
    """
    S, K = x.shape
    S2, N = g.shape
    assert S == S2, (x.shape, g.shape)
    E = group_offsets.shape[0] - 1
    bm_ = min(bm, _round_up(max(S, 1), 8))
    bk_ = min(bk, max(8, K))
    bn_ = min(bn, max(128, N))
    Sp, Kp, Np = (_round_up(S, bm_), _round_up(K, bk_), _round_up(N, bn_))
    if (Sp, Kp) != (S, K):
        x = jnp.pad(x, ((0, Sp - S), (0, Kp - K)))
    if (Sp, Np) != (S, N):
        g = jnp.pad(g, ((0, Sp - S), (0, Np - N)))
    nm, nk, nn = Sp // bm_, Kp // bk_, Np // bn_
    L = nm + E - 1
    mt, gid, valid = _group_metadata(group_offsets, nm, bm_, E)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(nk, nn, L),
        in_specs=[
            pl.BlockSpec((bm_, bk_),
                         lambda ki, ni, t, off, mt, gid, vl: (mt[t], ki)),
            pl.BlockSpec((bm_, bn_),
                         lambda ki, ni, t, off, mt, gid, vl: (mt[t], ni)),
        ],
        out_specs=pl.BlockSpec(
            (1, bk_, bn_),
            lambda ki, ni, t, off, mt, gid, vl: (gid[t], ki, ni)),
        scratch_shapes=[pltpu.VMEM((bk_, bn_), jnp.float32)],
    )
    dw = pl.pallas_call(
        functools.partial(_grouped_dw_kernel, bm=bm_, L=L),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((E, Kp, Np), jnp.float32),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(group_offsets.astype(jnp.int32), mt, gid, valid, x, g)[:, :K, :N]
    # empty groups own no incidence: their blocks were never written
    sizes = group_offsets[1:] - group_offsets[:-1]
    return jnp.where(sizes[:, None, None] > 0, dw, 0.0)
