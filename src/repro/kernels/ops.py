"""Kernel dispatch: Pallas on TPU, pure-jnp reference path elsewhere.

The model zoo calls these wrappers; the CPU dry-run/AOT compile lowers the
jnp path (Pallas-for-TPU cannot lower on the CPU backend), real TPU runs
take the fused kernels, and tests exercise both via interpret=True.

Operands may be `PositArray` (format travels with the array; the `cfg_*`
keywords stay unset) or raw storage-int arrays with an explicit config (the
original, now-deprecated calling convention — kept as a shim).  When a
posit-typed result is produced from PositArray inputs it comes back as a
PositArray; raw-bit inputs keep getting raw bits out.
"""
from __future__ import annotations

import collections
import functools
import os

import jax
import jax.numpy as jnp

from repro.core.array import (PositArray, PositConfigMismatchError,
                              result_cfg, unwrap_kv)
from repro.core.types import PositConfig
from repro.kernels import flash_attention as _fa
from repro.kernels import grouped_gemm as _ggemm
from repro.kernels import posit_codec as _codec
from repro.kernels import posit_elementwise as _ew
from repro.kernels import posit_gemm as _gemm
from repro.kernels import ref as _ref


def use_pallas() -> bool:
    env = os.environ.get("REPRO_USE_PALLAS")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() == "tpu"


def pallas_interpret() -> bool:
    """Run the Pallas kernels in interpret mode (REPRO_PALLAS_INTERPRET=1).

    With REPRO_USE_PALLAS=1 this executes the *kernel* code paths on the
    CPU backend — the tier-1 suite uses it to drive whole engines through
    the fused attention/gemm kernels (and to assert the gather_kv fallback
    is never taken) without TPU hardware."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    return env is not None and env not in ("0", "false", "False")


# in-process equivalent of REPRO_FORCE_GATHER=1 (tests/benches that cannot
# re-exec); both are consulted by every fused-attention dispatch site, so
# forcing the baseline forces the *whole* gather_kv + jnp blockwise path —
# a gather leg can never half-dispatch back into a fused kernel
FORCE_REFERENCE = False


def force_reference() -> bool:
    """Force the jnp reference paths even where `use_pallas()` would fuse
    (REPRO_FORCE_GATHER=1 or ops.FORCE_REFERENCE) — the baseline leg of the
    prefill/TTFT benchmarks, which measure the fused kernels against the
    gather_kv + blockwise dense-materialization path they replaced."""
    if FORCE_REFERENCE:
        return True
    env = os.environ.get("REPRO_FORCE_GATHER")
    return env is not None and env not in ("0", "false", "False")


# Pin the jnp-reference *backwards* while the forwards keep their kernels
# (REPRO_FORCE_BWD_REFERENCE=1 or ops.FORCE_BWD_REFERENCE): the baseline
# leg of benchmarks/train_step.py, and the oracle leg of the grad-parity
# tests — a forced backward is still counted in BWD_FALLBACKS.
FORCE_BWD_REFERENCE = False


def force_bwd_reference() -> bool:
    if FORCE_BWD_REFERENCE:
        return True
    env = os.environ.get("REPRO_FORCE_BWD_REFERENCE")
    return env is not None and env not in ("0", "false", "False")


# Every backward that does NOT take a Pallas kernel is counted here, keyed
# "<op>:forced" (a kernel was available but FORCE_* pinned the reference)
# or "<op>:jnp-reference" (no Pallas backend) — the training analogue of
# GATHER_FALLBACKS / DENSE_MOE_FALLBACKS / RECURRENT_FALLBACKS, asserted
# zero by the shard_map train-step test and logged by training.trainer.
BWD_FALLBACKS = collections.Counter()


def _count_bwd_fallback(op: str) -> None:
    BWD_FALLBACKS[f"{op}:" + ("forced" if use_pallas()
                              else "jnp-reference")] += 1


def _split(x, cfg: PositConfig | None):
    """(operand, explicit-cfg) -> (raw bits/array, cfg, was_posit_array)."""
    if isinstance(x, PositArray):
        if cfg is not None and cfg != x.cfg:
            raise PositConfigMismatchError(
                f"explicit cfg {cfg} contradicts operand format {x.cfg}")
        return x.bits, x.cfg, True
    return x, cfg, False


def _resolve_elementwise(op: str, inputs, cfg: PositConfig | None):
    """Shared PositArray resolution for the elementwise-shaped ops:
    returns (raw input tuple, cfg, any_posit).  Raw companions of
    PositArray operands must be payload ints — float values consumed as
    bit patterns are silent corruption."""
    any_posit = any(isinstance(x, PositArray) for x in inputs)
    if any_posit:
        cfg = result_cfg(*inputs, cfg=cfg)
        for x in inputs:
            if isinstance(x, PositArray):
                continue
            dt = getattr(x, "dtype", None)
            if (isinstance(x, (bool, int, float, complex))
                    or (dt is not None and jnp.issubdtype(dt, jnp.floating))):
                # python scalars are values, float arrays are values: both
                # would be consumed as bit patterns here.  Only raw *int
                # arrays* pass through (the documented payload-bits shim).
                raise TypeError(
                    f"{op}: cannot mix a PositArray with a python scalar or "
                    f"float array — encode values with pnp.asarray(x, cfg) "
                    f"or wrap payload bits with pnp.frombits")
    if cfg is None:
        raise TypeError(f"{op} needs PositArray inputs or an explicit cfg")
    raw = tuple(x.bits if isinstance(x, PositArray) else x for x in inputs)
    # broadcast to a common shape here, not in the kernels: the Pallas
    # elementwise path tiles each input independently and would silently
    # mis-align scalar/broadcast operands (the jnp ref path broadcasts
    # anyway, so this is free there)
    shape = jnp.broadcast_shapes(*(jnp.shape(x) for x in raw))
    raw = tuple(jnp.broadcast_to(x, shape) for x in raw)
    return raw, cfg, any_posit


def gemm(a, b, *, cfg_a: PositConfig | None = None,
         cfg_b: PositConfig | None = None,
         cfg_out: PositConfig | None = None, out_posit: bool = False,
         transpose_b: bool = False):
    a, cfg_a, a_posit = _split(a, cfg_a)
    b, cfg_b, b_posit = _split(b, cfg_b)
    # cfg-less *int* operands would be matmul'd as integer values: posit
    # payload bits always need their format (floats are activations and
    # legitimately skip the decode)
    for raw, raw_cfg in ((a, cfg_a), (b, cfg_b)):
        dt = getattr(raw, "dtype", None)
        if (raw_cfg is None and dt is not None
                and jnp.issubdtype(dt, jnp.integer)):
            raise TypeError(
                "gemm: int payload bits need their format — wrap them with "
                "pnp.frombits(bits, cfg) or pass cfg_a/cfg_b")
    if out_posit and cfg_out is None:
        if (cfg_a is not None and cfg_b is not None and cfg_a != cfg_b):
            raise PositConfigMismatchError(
                f"mixed-format gemm ({cfg_a} @ {cfg_b}) with out_posit needs "
                f"an explicit cfg_out")
        cfg_out = cfg_a if cfg_a is not None else cfg_b
    if out_posit:
        # posit bits out: no tangent through the rounding — direct dispatch
        if use_pallas():
            out = _gemm.posit_gemm(a, b, cfg_a=cfg_a, cfg_b=cfg_b,
                                   cfg_out=cfg_out, out_posit=True,
                                   transpose_b=transpose_b,
                                   interpret=pallas_interpret())
        else:
            out = _ref.posit_gemm_ref(a, b, cfg_a=cfg_a, cfg_b=cfg_b,
                                      cfg_out=cfg_out, out_posit=True,
                                      transpose_b=transpose_b)
        if a_posit or b_posit:
            return PositArray(out, cfg_out)
        return out
    static = (cfg_a, cfg_b, transpose_b, use_pallas(), pallas_interpret())
    return _gemm_mm(static, a, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _gemm_mm(static, a, b):
    cfg_a, cfg_b, transpose_b, use_kernel, interpret = static
    if use_kernel:
        return _gemm.posit_gemm(a, b, cfg_a=cfg_a, cfg_b=cfg_b,
                                transpose_b=transpose_b, interpret=interpret)
    return _ref.posit_gemm_ref(a, b, cfg_a=cfg_a, cfg_b=cfg_b,
                               transpose_b=transpose_b)


def _gemm_mm_fwd(static, a, b):
    return _gemm_mm(static, a, b), (a, b)


def _gemm_mm_bwd(static, res, g):
    """dA = G @ B^T and dB = A^T @ G through the same posit_gemm kernel the
    forward used: posit operands stream at storage width and decode in VMEM
    (transpose_a/transpose_b index the stored tiles, so no transposed copy
    exists), with f32 quire-style accumulation.  Posit operands carry no
    tangent — training crosses the posit boundary through the STE.  Off the
    kernel path the jnp reference runs and the miss is counted."""
    cfg_a, cfg_b, transpose_b, use_kernel, interpret = static
    a, b = res
    g = g.astype(jnp.float32)
    if use_kernel and not force_bwd_reference():
        if cfg_a is not None:
            da = None
        else:
            # forward b layout: [k,n] (or [n,k] when transpose_b) — dA
            # contracts g with the *other* storage axis
            da = _gemm.posit_gemm(g, b, cfg_a=None, cfg_b=cfg_b,
                                  transpose_b=not transpose_b,
                                  interpret=interpret).astype(a.dtype)
        if cfg_b is not None:
            db = None
        elif transpose_b:
            db = _gemm.posit_gemm(g, a, cfg_a=None, cfg_b=cfg_a,
                                  transpose_a=True,
                                  interpret=interpret).astype(b.dtype)
        else:
            db = _gemm.posit_gemm(a, g, cfg_a=cfg_a, cfg_b=None,
                                  transpose_a=True,
                                  interpret=interpret).astype(b.dtype)
        return da, db
    _count_bwd_fallback("gemm")
    from repro.core.decode import decode_to_f32
    af = (decode_to_f32(a, cfg_a) if cfg_a is not None
          else a.astype(jnp.float32))
    bf = (decode_to_f32(b, cfg_b) if cfg_b is not None
          else b.astype(jnp.float32))
    da = None
    if cfg_a is None:
        da = (g @ bf if transpose_b else g @ bf.T).astype(a.dtype)
    db = None
    if cfg_b is None:
        db = (g.T @ af if transpose_b else af.T @ g).astype(b.dtype)
    return da, db


_gemm_mm.defvjp(_gemm_mm_fwd, _gemm_mm_bwd)


def pw_matmul(x, w, cfg: PositConfig | None = None, *,
              transpose_b: bool = False):
    """[..., k] @ posit-weight [k, n] -> f32 (the LM linear-layer hot path).

    `w` is a PositArray (preferred) or raw storage ints + explicit `cfg`
    (deprecated shim).  transpose_b: `w` is stored [n, k] and contracted on
    its last dim — the unembedding path, where the tied [vocab, d] table
    must stream at posit width without materializing a transposed (or
    decoded) copy.
    """
    w, cfg, _ = _split(w, cfg)
    if cfg is None:
        raise TypeError("pw_matmul needs a PositArray weight or explicit cfg")
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    out = gemm(x2, w, cfg_a=None, cfg_b=cfg, transpose_b=transpose_b)
    return out.reshape(*lead, w.shape[0] if transpose_b else w.shape[-1])


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _grouped_mm(static, x, w, group_offsets):
    cfg, use_kernel, interpret = static
    if use_kernel:
        return _ggemm.posit_grouped_gemm(x, w, group_offsets, cfg_b=cfg,
                                         interpret=interpret)
    return _ref.grouped_matmul_ref(x, w, group_offsets, cfg_b=cfg)


def _grouped_mm_fwd(static, x, w, group_offsets):
    return _grouped_mm(static, x, w, group_offsets), (x, w, group_offsets)


def _grouped_mm_bwd(static, res, g):
    """Backward dispatch: the grouped Pallas kernels when the forward fused,
    the jnp reference (counted in BWD_FALLBACKS) otherwise.

    Kernel leg: dx = g @ w[gid]^T runs `posit_grouped_gemm(transpose_b=
    True)` over the *same* [E, k, n] storage layout — posit experts stream
    at posit width and decode in VMEM in the backward too, replacing the
    full `decode_to_f32(w)` this path used to materialize; dw accumulates
    each group's x^T g in f32 VMEM scratch (`posit_grouped_gemm_dw`, the
    per-group quire).  Reference leg: per-row weight gather + one-hot
    three-operand einsum (XLA picks an O(S*E*max(k,n)) contraction, never
    the [S, k, n] outer-product tensor).  Integer operands (posit weight
    bits, the offsets) carry no tangents — training crosses the posit
    boundary through the STE."""
    cfg, use_kernel, interpret = static
    x, w, off = res
    gid, inb = _ref.grouped_row_ids(off, x.shape[0])
    g = jnp.where(inb[:, None], g.astype(jnp.float32), 0.0)
    if use_kernel and not force_bwd_reference():
        dx = _ggemm.posit_grouped_gemm(g, w, off, cfg_b=cfg,
                                       transpose_b=True,
                                       interpret=interpret).astype(x.dtype)
        if cfg is not None:
            return dx, None, None
        dw = _ggemm.posit_grouped_gemm_dw(
            x.astype(jnp.float32), g, off,
            interpret=interpret).astype(w.dtype)
        return dx, dw, None
    _count_bwd_fallback("grouped")
    if cfg is not None:
        from repro.core.decode import decode_to_f32
        wf = decode_to_f32(w, cfg)
    else:
        wf = w.astype(jnp.float32)
    dx = jnp.einsum("sn,skn->sk", g, wf[gid],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    if cfg is not None:
        return dx, None, None
    oh = jnp.where(inb[:, None], jax.nn.one_hot(gid, w.shape[0]), 0.0)
    dw = jnp.einsum("se,sk,sn->ekn", oh, x.astype(jnp.float32), g,
                    preferred_element_type=jnp.float32).astype(w.dtype)
    return dx, dw, None


_grouped_mm.defvjp(_grouped_mm_fwd, _grouped_mm_bwd)


def grouped_matmul(x, w, group_offsets, *, cfg: PositConfig | None = None,
                   interpret: bool | None = None):
    """Expert-sorted rows x [S, k] @ per-group weights w [E, k, n] -> [S, n]
    f32 (the MoE grouped hot path; see models/moe.py).

    Rows [group_offsets[g], group_offsets[g+1]) contract against w[g]; rows
    at or past group_offsets[-1] come back as exact zeros.  `w` is a
    PositArray (preferred), raw storage ints + explicit `cfg`, or a float
    array (cfg None).  On the Pallas path the grouped kernel streams only
    the active groups' posit tiles and decodes them in VMEM; elsewhere the
    dense jnp reference runs.  Differentiable via jax.custom_vjp: on the
    kernel path both directions fuse (dx streams the storage-layout experts
    via transpose_b, dw accumulates per group in f32 scratch); elsewhere
    the jnp reference backward runs and is counted in BWD_FALLBACKS (posit
    weight bits carry no tangent — training crosses the posit boundary
    through the STE, exactly as pw_matmul does).
    """
    w, cfg, _ = _split(w, cfg)
    dt = getattr(w, "dtype", None)
    if (cfg is None and dt is not None and jnp.issubdtype(dt, jnp.integer)):
        raise TypeError(
            "grouped_matmul: int payload bits need their format — wrap them "
            "with pnp.frombits(bits, cfg) or pass cfg")
    use_kernel = use_pallas() and not force_reference()
    if interpret is None:
        interpret = pallas_interpret()
    static = (cfg, use_kernel, bool(interpret))
    return _grouped_mm(static, x, w,
                       jnp.asarray(group_offsets, jnp.int32))


def elementwise(op: str, *inputs, cfg: PositConfig | None = None):
    raw, cfg, any_posit = _resolve_elementwise(f"elementwise('{op}')",
                                               inputs, cfg)
    if use_pallas():
        out = _ew.elementwise(op, *raw, cfg=cfg, interpret=pallas_interpret())
    else:
        out = _ref.elementwise_ref(op, *raw, cfg=cfg)
    return PositArray(out, cfg) if any_posit else out


def divide(a, b, *, cfg: PositConfig | None = None,
           mode: str = "poly_corrected", nr_rounds: int = 1):
    (a, b), cfg, any_posit = _resolve_elementwise("divide", (a, b), cfg)
    if use_pallas():
        out = _ew.divide(a, b, cfg=cfg, mode=mode, nr_rounds=nr_rounds,
                         interpret=pallas_interpret())
    else:
        out = _ref.divide_ref(a, b, cfg=cfg, mode=mode, nr_rounds=nr_rounds)
    return PositArray(out, cfg) if any_posit else out


def decode(p, cfg: PositConfig | None = None):
    """Posit payload -> f32 values."""
    p, cfg, _ = _split(p, cfg)
    if cfg is None:
        raise TypeError("decode needs a PositArray or explicit cfg")
    if use_pallas():
        return _codec.decode_block(p, cfg, interpret=pallas_interpret())
    return _ref.decode_ref(p, cfg)


def encode(v, cfg: PositConfig):
    """f32 values -> posit payload bits (raw; wrap via pnp.asarray for a
    PositArray)."""
    if use_pallas():
        return _codec.encode_block(v, cfg, interpret=pallas_interpret())
    return _ref.encode_ref(v, cfg)


def attention(q, k, v, *, cfg_kv: PositConfig | None = None,
              causal: bool = True):
    """[BH, Sq, D] attention over (possibly posit) KV."""
    k, v, cfg_kv = unwrap_kv(k, v, cfg_kv, q=q)
    if use_pallas():
        return _fa.flash_attention(q, k, v, cfg_kv=cfg_kv, causal=causal,
                                   interpret=pallas_interpret())
    return _ref.flash_attention_ref(q, k, v, cfg_kv=cfg_kv, causal=causal)


def paged_prefill_attention(q, k_pages, v_pages, page_table, seq_lens,
                            q_offset, *, cfg_kv: PositConfig | None = None,
                            causal: bool = True, window: int | None = None,
                            softcap: float | None = None,
                            interpret: bool | None = None):
    """Fused paged prefill: q [B, H, Sq, D] x the paged KV pool.

    The TPU-only chunked-prefill hot path (serving.paged_kv.paged_attention
    routes here whenever `use_pallas()`); the pure-jnp oracle is
    gather_kv + models.blocks.blockwise_attention.  Pages may be PositArray
    (format travels with the pool) or raw ints + cfg_kv.
    """
    k_pages, v_pages, cfg_kv = unwrap_kv(k_pages, v_pages, cfg_kv, q=q)
    if interpret is None:
        interpret = pallas_interpret()
    return _fa.paged_flash_prefill(
        q, k_pages, v_pages, page_table, seq_lens, q_offset, cfg_kv=cfg_kv,
        causal=causal, window=window, softcap=softcap, interpret=interpret)


# Serving-path recurrent scans (RWKV6 WKV / rGLRU).  Every dispatch that
# does NOT take the fused Pallas kernel is counted here, keyed by why
# ("forced": REPRO_FORCE_GATHER overrode an available kernel;
# "jnp-reference": no Pallas backend) — the recurrent analogue of
# paged_kv.GATHER_FALLBACKS, asserted zero by the kernel-path serving tests.
RECURRENT_FALLBACKS = collections.Counter()


def wkv_scan(r, k, v, logw, u, s0, *, num_new=None,
             cfg_state: PositConfig | None = None):
    """RWKV6 WKV recurrence over a chunk (the serving scan core).

    r/k/v/logw [B, H, T, dh], u [H, dh].  s0 [B, H, dh, dh] is the carried
    state: a PositArray (the paged engine's posit state pool — decoded in
    VMEM, f32-accumulated, re-encoded in-kernel) or an f32 array (dense
    cache tuples / posit-off serving).  Under a posit state format
    (PositArray s0, or explicit `cfg_state` for f32 state under a posit KV
    policy) the state is round-tripped through the format after *every*
    token, so the scan is invariant to prefill chunking and the dense and
    pooled representations compute identical values.  num_new [B] masks
    per-slot ragged chunks (None = every row advances all T tokens).
    Returns (y [B, H, T, dh] f32, s_fin in s0's representation).
    """
    from repro.kernels import recurrent_scan as _rs
    s0_raw, cfg_state, posit_state = _split(s0, cfg_state)
    B, _, T, _ = r.shape
    nn = (jnp.full((B,), T, jnp.int32) if num_new is None
          else jnp.asarray(num_new, jnp.int32))
    if use_pallas() and not force_reference():
        y, sf = _rs.wkv_scan_pallas(r, k, v, logw, u, s0_raw, nn,
                                    cfg_state=cfg_state,
                                    posit_state=posit_state,
                                    interpret=pallas_interpret())
    else:
        RECURRENT_FALLBACKS["forced" if use_pallas()
                            else "jnp-reference"] += 1
        y, sf = _rs.wkv_scan_ref(r, k, v, logw, u, s0_raw, nn,
                                 cfg_state=cfg_state,
                                 posit_state=posit_state)
    return y, PositArray(sf, cfg_state) if posit_state else sf


def rglru_scan(a, b, h0, *, num_new=None,
               cfg_state: PositConfig | None = None):
    """rGLRU recurrence h_t = rt(a_t h + b_t) over a chunk (Griffin /
    RecurrentGemma serving core); a/b [B, T, d] are the batched gate
    projections.  h0 [B, d] follows the same PositArray-or-f32 state (and
    `cfg_state` round-trip) contract as `wkv_scan`.  Returns
    (h_seq [B, T, d] f32, h_fin in h0's representation)."""
    from repro.kernels import recurrent_scan as _rs
    h0_raw, cfg_state, posit_state = _split(h0, cfg_state)
    B, T, _ = a.shape
    nn = (jnp.full((B,), T, jnp.int32) if num_new is None
          else jnp.asarray(num_new, jnp.int32))
    if use_pallas() and not force_reference():
        h, hf = _rs.rglru_scan_pallas(a, b, h0_raw, nn,
                                      cfg_state=cfg_state,
                                      posit_state=posit_state,
                                      interpret=pallas_interpret())
    else:
        RECURRENT_FALLBACKS["forced" if use_pallas()
                            else "jnp-reference"] += 1
        h, hf = _rs.rglru_scan_ref(a, b, h0_raw, nn, cfg_state=cfg_state,
                                   posit_state=posit_state)
    return h, PositArray(hf, cfg_state) if posit_state else hf


def flash_prefill(q, k, v, kv_len, q_offset, *,
                  cfg_kv: PositConfig | None = None, causal: bool = True,
                  window: int | None = None, softcap: float | None = None,
                  return_lse: bool = False, interpret: bool | None = None):
    """Fused prefill over a contiguous KV cache (GQA layout).

    q [B, H, Sq, D] x k/v [B, n_kv, Skv, D]; kv_len/q_offset [B] int32.
    The TPU dispatch target of models.blocks.blockwise_attention (training
    forward and the dense engine's prefill), which remains the bit-parity
    reference; the dense cache streams tile-by-tile at storage width.
    return_lse: also return the row log-sum-exps — the residual the
    training backward (flash_prefill_bwd) consumes.
    """
    k, v, cfg_kv = unwrap_kv(k, v, cfg_kv, q=q)
    if interpret is None:
        interpret = pallas_interpret()
    return _fa.flash_prefill_contiguous(
        q, k, v, kv_len, q_offset, cfg_kv=cfg_kv, causal=causal,
        window=window, softcap=softcap, return_lse=return_lse,
        interpret=interpret)


def flash_prefill_bwd(q, k, v, o, lse, g, kv_len, q_offset, *, n_kv: int,
                      cfg_kv: PositConfig | None = None, causal: bool = True,
                      window: int | None = None, softcap: float | None = None,
                      interpret: bool | None = None):
    """(dQ, dK, dV) for the fused contiguous prefill.

    Kernel path: the flash backward kernels (dQ sweeps kv tiles, dK/dV
    sweep q tiles, scores rebuilt from the saved lse — no [Sq, Skv] matrix,
    posit KV decoded in VMEM).  Otherwise the jnp blockwise oracle is
    differentiated and the miss is counted in BWD_FALLBACKS.  Posit KV
    (cfg_kv set) returns dK = dV = None on both legs — storage ints carry
    no tangent.
    """
    if interpret is None:
        interpret = pallas_interpret()
    if use_pallas() and not force_reference() and not force_bwd_reference():
        dq, dk, dv = _fa.flash_prefill_bwd_contiguous(
            q, k, v, o, lse, g, kv_len, q_offset, cfg_kv=cfg_kv,
            causal=causal, window=window, softcap=softcap,
            interpret=interpret)
        dq = dq.astype(q.dtype)
        if dk is not None:
            dk, dv = dk.astype(k.dtype), dv.astype(v.dtype)
        return dq, dk, dv
    _count_bwd_fallback("flash")
    from repro.models.blocks import _blockwise_jnp

    def ref(qq, kk, vv):
        return _blockwise_jnp(qq, kk, vv, n_kv=n_kv, causal=causal,
                              q_off=q_offset, window=window, q_chunk=512,
                              kv_chunk=512, softcap=softcap, kv_len=kv_len,
                              cfg_kv=cfg_kv)

    if cfg_kv is not None:
        out, vjp = jax.vjp(lambda qq: ref(qq, k, v), q)
        (dq,) = vjp(g.astype(out.dtype))
        return dq, None, None
    out, vjp = jax.vjp(ref, q, k, v)
    dq, dk, dv = vjp(g.astype(out.dtype))
    return dq, dk, dv
