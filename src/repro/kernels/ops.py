"""Kernel dispatch: Pallas on TPU, pure-jnp reference path elsewhere.

The model zoo calls these wrappers; the CPU dry-run/AOT compile lowers the
jnp path (Pallas-for-TPU cannot lower on the CPU backend), real TPU runs
take the fused kernels, and tests exercise both via interpret=True.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core.types import PositConfig
from repro.kernels import flash_attention as _fa
from repro.kernels import posit_codec as _codec
from repro.kernels import posit_elementwise as _ew
from repro.kernels import posit_gemm as _gemm
from repro.kernels import ref as _ref


def use_pallas() -> bool:
    env = os.environ.get("REPRO_USE_PALLAS")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() == "tpu"


def gemm(a, b, *, cfg_a: PositConfig | None, cfg_b: PositConfig | None,
         cfg_out: PositConfig | None = None, out_posit: bool = False):
    if use_pallas():
        return _gemm.posit_gemm(a, b, cfg_a=cfg_a, cfg_b=cfg_b,
                                cfg_out=cfg_out, out_posit=out_posit)
    return _ref.posit_gemm_ref(a, b, cfg_a=cfg_a, cfg_b=cfg_b,
                               cfg_out=cfg_out, out_posit=out_posit)


def pw_matmul(x, w_bits, cfg: PositConfig):
    """[..., k] @ posit-weight [k, n] -> f32 (the LM linear-layer hot path)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    out = gemm(x2, w_bits, cfg_a=None, cfg_b=cfg)
    return out.reshape(*lead, w_bits.shape[-1])


def elementwise(op: str, *inputs, cfg: PositConfig):
    if use_pallas():
        return _ew.elementwise(op, *inputs, cfg=cfg)
    return _ref.elementwise_ref(op, *inputs, cfg=cfg)


def divide(a, b, *, cfg: PositConfig, mode: str = "poly_corrected",
           nr_rounds: int = 1):
    if use_pallas():
        return _ew.divide(a, b, cfg=cfg, mode=mode, nr_rounds=nr_rounds)
    return _ref.divide_ref(a, b, cfg=cfg, mode=mode, nr_rounds=nr_rounds)


def decode(p, cfg: PositConfig):
    if use_pallas():
        return _codec.decode_block(p, cfg)
    return _ref.decode_ref(p, cfg)


def encode(v, cfg: PositConfig):
    if use_pallas():
        return _codec.encode_block(v, cfg)
    return _ref.encode_ref(v, cfg)


def attention(q, k, v, *, cfg_kv: PositConfig | None = None,
              causal: bool = True):
    """[BH, Sq, D] attention over (possibly posit) KV."""
    if use_pallas():
        return _fa.flash_attention(q, k, v, cfg_kv=cfg_kv, causal=causal)
    return _ref.flash_attention_ref(q, k, v, cfg_kv=cfg_kv, causal=causal)
