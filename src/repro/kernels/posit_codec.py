"""Pallas TPU kernels: bulk posit <-> float codec (PFCVT, §VI).

HBM-bandwidth-bound kernels used wherever tensors cross the posit/float
boundary in bulk: weight dematerialisation, KV-cache (de)quantization and
the posit-compressed gradient collective.  Reading int8 and writing f32
moves 5 bytes/element instead of 8 for an f32->f32 copy — the paper's
storage-density benefit (C4) on the memory roofline term.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.convert import f32_to_posit
from repro.core.decode import decode_to_f32
from repro.core.types import PositConfig

_WIDTH = 8 * 128


def _reshape_tiles(x: jnp.ndarray, block_rows: int):
    flat = x.reshape(-1)
    rows = max(1, -(-flat.shape[0] // _WIDTH))
    rows = -(-rows // block_rows) * block_rows
    flat = jnp.pad(flat, (0, rows * _WIDTH - flat.shape[0]))
    return flat.reshape(rows, _WIDTH)


def _decode_kernel(p_ref, o_ref, *, cfg):
    o_ref[...] = decode_to_f32(p_ref[...], cfg)


def _encode_kernel(v_ref, o_ref, *, cfg):
    o_ref[...] = f32_to_posit(v_ref[...], cfg)


@functools.partial(jax.jit, static_argnames=("cfg", "block_rows", "interpret"))
def decode_block(p: jnp.ndarray, cfg: PositConfig, *, block_rows: int = 128,
                 interpret: bool = False) -> jnp.ndarray:
    """Bulk posit -> f32 (exact)."""
    shape, size = p.shape, p.size
    t = _reshape_tiles(jnp.asarray(p), block_rows)
    grid = (t.shape[0] // block_rows,)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, cfg=cfg),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, _WIDTH), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, _WIDTH), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(t.shape, jnp.float32),
        interpret=interpret,
    )(t)
    return out.reshape(-1)[:size].reshape(shape)


@functools.partial(jax.jit, static_argnames=("cfg", "block_rows", "interpret"))
def encode_block(v: jnp.ndarray, cfg: PositConfig, *, block_rows: int = 128,
                 interpret: bool = False) -> jnp.ndarray:
    """Bulk f32 -> posit (RNE)."""
    shape, size = v.shape, v.size
    t = _reshape_tiles(jnp.asarray(v).astype(jnp.float32), block_rows)
    grid = (t.shape[0] // block_rows,)
    out = pl.pallas_call(
        functools.partial(_encode_kernel, cfg=cfg),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, _WIDTH), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, _WIDTH), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(t.shape, jnp.dtype(f"int{cfg.storage_bits}")),
        interpret=interpret,
    )(t)
    return out.reshape(-1)[:size].reshape(shape)
