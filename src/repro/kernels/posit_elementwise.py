"""Pallas TPU kernels: bit-exact elementwise posit ops on the VPU.

The SIMD configuration of the paper (§VIII-A) realised natively: int8/int16
posit payloads fill TPU vector lanes at 4x/2x the density of f32, and each
lane runs the integer FPPU datapath (decode -> int32 mantissa op -> RNE
encode) from repro.core.ops — the same code, so kernels are bit-exact
against the golden model by construction; the pallas_call adds the HBM->VMEM
tile pipeline (the paper's 4-stage pipelining analogue).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import ops as pops
from repro.core.types import PositConfig

# (name -> (n_inputs, core fn))
_OPS = {
    "add": (2, pops.padd),
    "sub": (2, pops.psub),
    "mul": (2, pops.pmul),
    "fma": (3, pops.pfma),
}

_LANES = 128
_SUBLANES = 8


def _ew_kernel(*refs, op_fn, cfg):
    ins = [r[...] for r in refs[:-1]]
    refs[-1][...] = op_fn(*ins, cfg)


def _tile_1d(x: jnp.ndarray, block_rows: int):
    """Flatten to (rows, 8*128) tiles; returns (tiled, orig_len, rows)."""
    flat = x.reshape(-1)
    width = _SUBLANES * _LANES
    rows = max(1, -(-flat.shape[0] // width))
    rows_pad = -(-rows // block_rows) * block_rows
    pad = rows_pad * width - flat.shape[0]
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(rows_pad, width), x.size


@functools.partial(jax.jit, static_argnames=("op", "cfg", "block_rows", "interpret"))
def elementwise(op: str, *inputs, cfg: PositConfig, block_rows: int = 64,
                interpret: bool = False) -> jnp.ndarray:
    """Apply a posit op elementwise via a Pallas VPU kernel.

    inputs: posit storage-int arrays of identical shape.  div uses the
    dedicated kernel in this module (extra mode arg).
    """
    n_in, fn = _OPS[op]
    assert len(inputs) == n_in, (op, len(inputs))
    shape = inputs[0].shape
    dt = inputs[0].dtype
    tiled = [_tile_1d(jnp.asarray(x), block_rows)[0] for x in inputs]
    size = inputs[0].size
    rows, width = tiled[0].shape
    grid = (rows // block_rows,)

    out = pl.pallas_call(
        functools.partial(_ew_kernel, op_fn=fn, cfg=cfg),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, width), lambda i: (i, 0))
                  for _ in range(n_in)],
        out_specs=pl.BlockSpec((block_rows, width), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, width), dt),
        interpret=interpret,
    )(*tiled)
    return out.reshape(-1)[:size].reshape(shape)


def _div_kernel(a_ref, b_ref, o_ref, *, cfg, mode, nr_rounds):
    o_ref[...] = pops.pdiv(a_ref[...], b_ref[...], cfg, mode=mode,
                           nr_rounds=nr_rounds)


def _div_kernel_lut(a_ref, b_ref, lut_ref, o_ref, *, cfg, mode, nr_rounds):
    # pacogen mode: the reciprocal LUT rides along as a kernel input
    # (Pallas forbids captured constants); patch it into the lookup fn
    from repro.core import recip as _recip
    lut = lut_ref[0]

    def lookup(mb_frac, cfg2):
        from repro.core.decode import work_frac_bits
        Wd = work_frac_bits(cfg2)
        if Wd >= _recip.PACOGEN_LUT_IN:
            idx = mb_frac >> (Wd - _recip.PACOGEN_LUT_IN)
        else:
            idx = mb_frac << (_recip.PACOGEN_LUT_IN - Wd)
        return (jnp.take(lut, idx.reshape(-1)).reshape(idx.shape)
                .astype(jnp.int32))

    orig = _recip.pacogen_lut_i32
    _recip.pacogen_lut_i32 = lookup
    try:
        o_ref[...] = pops.pdiv(a_ref[...], b_ref[...], cfg, mode=mode,
                               nr_rounds=nr_rounds)
    finally:
        _recip.pacogen_lut_i32 = orig


@functools.partial(jax.jit, static_argnames=("cfg", "mode", "nr_rounds",
                                             "block_rows", "interpret"))
def divide(a, b, *, cfg: PositConfig, mode: str = "poly_corrected",
           nr_rounds: int = 1, block_rows: int = 64,
           interpret: bool = False) -> jnp.ndarray:
    """Elementwise posit division kernel (paper §V-A datapath).

    mode: "poly" (paper-faithful approximate), "pacogen" (Table II baseline),
    "poly_corrected"/"exact" (correctly rounded).
    """
    shape, dt = a.shape, a.dtype
    ta, size = _tile_1d(jnp.asarray(a), block_rows)
    tb, _ = _tile_1d(jnp.asarray(b), block_rows)
    rows, width = ta.shape
    grid = (rows // block_rows,)
    if mode == "pacogen":
        from repro.core.recip import _PACOGEN_LUT
        lut = jnp.asarray(_PACOGEN_LUT)[None, :]
        out = pl.pallas_call(
            functools.partial(_div_kernel_lut, cfg=cfg, mode=mode,
                              nr_rounds=nr_rounds),
            grid=grid,
            in_specs=[pl.BlockSpec((block_rows, width), lambda i: (i, 0)),
                      pl.BlockSpec((block_rows, width), lambda i: (i, 0)),
                      pl.BlockSpec((1, lut.shape[1]), lambda i: (0, 0))],
            out_specs=pl.BlockSpec((block_rows, width), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((rows, width), dt),
            interpret=interpret,
        )(ta, tb, lut)
        return out.reshape(-1)[:size].reshape(shape)
    out = pl.pallas_call(
        functools.partial(_div_kernel, cfg=cfg, mode=mode, nr_rounds=nr_rounds),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, width), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows, width), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, width), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, width), dt),
        interpret=interpret,
    )(ta, tb)
    return out.reshape(-1)[:size].reshape(shape)
