"""Pallas TPU kernel: posit GEMM with in-kernel decode and quire-style
accumulation (the FPPU's PFMADD/quire datapath mapped onto the MXU).

TPU adaptation of the paper's compute pipeline (DESIGN.md §2):
  stage (i)  decode:      posit tiles (int8/int16) -> exact f32 in VMEM
  stage (ii) compute:     MXU matmul, f32 accumulator = the quire analogue
  stage (iii) normalize:  single RNE encode of the accumulator (optional)

The Pallas grid pipeline double-buffers HBM->VMEM tile fetches across grid
steps — the TPU realisation of the FPPU's 4-stage pipelining (§V).

Because operands travel as 8/16-bit integers, HBM traffic is 1/4 / 1/2 of
an f32 GEMM (the paper's SIMD-register-density argument, §VIII-A) — this is
what moves the memory roofline term in EXPERIMENTS.md §Perf.

Two kernels:
  * posit_gemm:  A[posit] @ B[posit] -> f32 or posit
  * pw_gemm:     A[f32/bf16] @ B[posit] -> f32   (posit-weight hot path)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.convert import f32_to_posit
from repro.core.decode import decode_to_f32
from repro.core.types import PositConfig


def _pad_to(x: jnp.ndarray, m0: int, m1: int, value=0) -> jnp.ndarray:
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)), constant_values=value)
    return x


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, cfg_a, cfg_b, nk, out_posit,
                 cfg_out, transpose_a, transpose_b):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    if cfg_a is not None:
        a = decode_to_f32(a, cfg_a)          # exact dequant, stage (i)
    else:
        a = a.astype(jnp.float32)
    b = b_ref[...]
    if cfg_b is not None:
        b = decode_to_f32(b, cfg_b)
    else:
        b = b.astype(jnp.float32)

    # transposed operands contract on their stored axis (a tile [bk, bm]:
    # dim 0; b tile [bn, bk]: dim 1) — the transposed layout never
    # materializes, in VMEM or HBM
    ca = 0 if transpose_a else 1
    cb = 1 if transpose_b else 0
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((ca,), (cb,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        acc = acc_ref[...]
        if out_posit:
            o_ref[...] = f32_to_posit(acc, cfg_out)   # stage (iii): one rounding
        else:
            o_ref[...] = acc


# i/j tiles own disjoint output blocks; only the k axis carries the
# accumulator and must stay ordered
_GEMM_SEMANTICS = ("parallel", "parallel", "arbitrary")


@functools.partial(
    jax.jit,
    static_argnames=("cfg_a", "cfg_b", "cfg_out", "out_posit", "bm", "bn",
                     "bk", "transpose_a", "transpose_b", "interpret"),
)
def posit_gemm(a: jnp.ndarray, b: jnp.ndarray, *,
               cfg_a: PositConfig | None, cfg_b: PositConfig | None,
               cfg_out: PositConfig | None = None, out_posit: bool = False,
               bm: int = 512, bn: int = 512, bk: int = 512,
               transpose_a: bool = False, transpose_b: bool = False,
               interpret: bool = False) -> jnp.ndarray:
    """[m,k] @ [k,n] (or [m,k] @ [n,k].T when transpose_b, or
    [k,m].T @ [k,n] when transpose_a) with posit operands decoded in-kernel.

    cfg_a/cfg_b None means that operand is already float.  Output is f32
    (quire-accumulated) or posit bits when out_posit (single final rounding).
    transpose_a is the dW leg of the training backward (dW = A^T @ G): the
    stored activation tile contracts on its leading dim, so no XLA
    transpose of the [m, k] operand ever materializes.
    Block shapes: MXU-aligned multiples of 128.  Roofline defaults: HBM
    traffic is m*k*(n/bn) + k*n*(m/bm) operand bytes, so square 512-blocks
    halve the re-read term vs the old 256x256 while the f32 working set
    (decoded a + b + acc = 3 MB, double-buffered narrow-int inputs on top)
    still fits VMEM with headroom; the k axis stays at 512 so one tile pair
    amortizes its fetch over >= 512 MACs/element — past the MXU ridge even
    at posit8 (1 byte/elem) width.
    """
    if transpose_a:
        k, m = a.shape
    else:
        m, k = a.shape
    if transpose_b:
        n, k2 = b.shape
    else:
        k2, n = b.shape
    assert k == k2, (a.shape, b.shape, transpose_a, transpose_b)
    bm_ = min(bm, max(8, m)); bn_ = min(bn, max(128, n)); bk_ = min(bk, k)
    a = _pad_to(a, bk_, bm_) if transpose_a else _pad_to(a, bm_, bk_)
    b = _pad_to(b, bn_, bk_) if transpose_b else _pad_to(b, bk_, bn_)
    mp = a.shape[1] if transpose_a else a.shape[0]
    kp = a.shape[0] if transpose_a else a.shape[1]
    np_ = b.shape[0] if transpose_b else b.shape[1]
    grid = (mp // bm_, np_ // bn_, kp // bk_)

    if out_posit:
        out_dtype = jnp.dtype(f"int{cfg_out.storage_bits}")
    else:
        out_dtype = jnp.float32

    if transpose_a:
        a_spec = pl.BlockSpec((bk_, bm_), lambda i, j, kk: (kk, i))
    else:
        a_spec = pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk))
    if transpose_b:
        b_spec = pl.BlockSpec((bn_, bk_), lambda i, j, kk: (j, kk))
    else:
        b_spec = pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j))
    out = pl.pallas_call(
        functools.partial(_gemm_kernel, cfg_a=cfg_a, cfg_b=cfg_b, nk=grid[2],
                          out_posit=out_posit, cfg_out=cfg_out,
                          transpose_a=transpose_a, transpose_b=transpose_b),
        grid=grid,
        in_specs=[
            a_spec,
            b_spec,
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=_GEMM_SEMANTICS),
        interpret=interpret,
    )(a, b)
    return out[:m, :n]


def pw_gemm(x: jnp.ndarray, w_bits: jnp.ndarray, cfg: PositConfig, *,
            bm: int = 512, bn: int = 512, bk: int = 512,
            transpose_b: bool = False,
            interpret: bool = False) -> jnp.ndarray:
    """Activations[f32/bf16, m x k] @ posit-weights[k x n] -> f32.

    The LM forward/serving hot path: weights stream from HBM at posit width
    and are decoded in VMEM right before the MXU.  transpose_b: the weight
    is stored [n, k] (the tied unembedding table) and contracted on its
    last dim in-kernel.
    """
    return posit_gemm(x, w_bits, cfg_a=None, cfg_b=cfg, out_posit=False,
                      bm=bm, bn=bn, bk=bk, transpose_b=transpose_b,
                      interpret=interpret)
