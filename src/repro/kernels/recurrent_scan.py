"""Pallas TPU kernel: fused recurrent-scan step for RWKV6 / rGLRU serving.

Recurrent layers cache O(1) state per sequence instead of O(context) KV —
the extreme case of the paper's C4/C6 memory story — and the serving engine
stores that state as posit8/posit16 in the state pool.  This kernel runs the
per-token recurrence with the posit state decoded in VMEM, accumulated in
f32, and re-encoded in-kernel after every token (same idiom as
`paged_flash_decode`: HBM only ever sees the narrow ints).

The per-token round-trip is the serving-path quantization contract: because
every value that crosses a token boundary is used at its round-tripped
value, the scan is invariant to where prefill chunks split the prompt, and
the paged engine's chunked prefill + single-token decode reproduces dense
`generate()` bit-for-bit.

Grid layout puts the time axis last as an "arbitrary" dimension and carries
the state in VMEM scratch across it (the online-softmax accumulator
pattern); batch (and head, for WKV) axes are "parallel".  `num_new` is
scalar-prefetched and masks per-token updates at `t >= num_new[b]`, so
inactive pool slots carry their state through unchanged (posit
encode(decode(bits)) is the identity on canonical bits).

The jnp `lax.scan` twins (`*_ref`) implement the identical per-token math
and serve as the counted CPU/interpret oracle under `kernels.ops`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.convert import f32_to_posit
from repro.core.decode import decode_to_f32
from repro.core.types import PositConfig


def _rt(x, cfg: PositConfig | None):
    """Posit round-trip (quantize state to its storage format); identity
    when no posit policy is in force."""
    if cfg is None:
        return x
    return decode_to_f32(f32_to_posit(x, cfg), cfg)


def _load_state(ref_val, cfg, posit_state):
    if posit_state:
        return decode_to_f32(ref_val, cfg)
    return ref_val.astype(jnp.float32)


def _store_state(val, cfg, posit_state):
    if posit_state:
        return f32_to_posit(val, cfg)
    return val


# --------------------------------------------------------------------------
# WKV (RWKV6 time-mix core):
#   y_t = r_t . S_{t-1}  +  (sum_d r_t u k_t) v_t
#   S_t = rt( diag(exp(logw_t)) S_{t-1} + k_t^T v_t )
# --------------------------------------------------------------------------
def _wkv_kernel(nn_ref, r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                y_ref, sf_ref, s_scr, *, cfg_state, posit_state, T):
    b = pl.program_id(0)
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        s_scr[...] = _load_state(s0_ref[0, 0], cfg_state, posit_state)

    S = s_scr[...]                                    # [dh, dh] f32
    r = r_ref[0, 0].astype(jnp.float32)               # [1, dh]
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)                # [1, dh]

    y = jax.lax.dot_general(r, S, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    su = jnp.sum(r * u * k, axis=-1, keepdims=True)   # [1, 1] bonus
    y = y + su * v

    # outer products via contract-the-unit-axis dot_general (no transposes:
    # Mosaic dislikes 1D relayouts); E[d, :] = exp(w[d]) scales row d of S
    def outer(col, row):
        return jax.lax.dot_general(col, row, (((0,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)

    S_new = outer(jnp.exp(w), jnp.ones_like(v)) * S + outer(k, v)
    S_new = _rt(S_new, cfg_state)

    live = t < nn_ref[b]
    S_new = jnp.where(live, S_new, S)
    s_scr[...] = S_new
    y_ref[0, 0] = jnp.where(live, y, 0.0)

    @pl.when(t == T - 1)
    def _done():
        sf_ref[0, 0] = _store_state(S_new, cfg_state, posit_state)


@functools.partial(jax.jit, static_argnames=("cfg_state", "posit_state",
                                             "interpret"))
def wkv_scan_pallas(r, k, v, logw, u, s0, num_new, *,
                    cfg_state: PositConfig | None, posit_state: bool,
                    interpret: bool = False):
    """r/k/v/logw [B, H, T, dh], u [H, dh], s0 [B, H, dh, dh] (posit storage
    ints when posit_state), num_new [B] int32 -> (y [B, H, T, dh] f32,
    s_fin same representation as s0)."""
    B, H, T, dh = r.shape
    grid = (B, H, T)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, dh), lambda b, h, t, nn: (b, h, t, 0)),
            pl.BlockSpec((1, 1, 1, dh), lambda b, h, t, nn: (b, h, t, 0)),
            pl.BlockSpec((1, 1, 1, dh), lambda b, h, t, nn: (b, h, t, 0)),
            pl.BlockSpec((1, 1, 1, dh), lambda b, h, t, nn: (b, h, t, 0)),
            pl.BlockSpec((1, dh), lambda b, h, t, nn: (h, 0)),
            pl.BlockSpec((1, 1, dh, dh), lambda b, h, t, nn: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, dh), lambda b, h, t, nn: (b, h, t, 0)),
            pl.BlockSpec((1, 1, dh, dh), lambda b, h, t, nn: (b, h, 0, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_wkv_kernel, cfg_state=cfg_state,
                          posit_state=posit_state, T=T),
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((B, H, T, dh), jnp.float32),
                   jax.ShapeDtypeStruct((B, H, dh, dh), s0.dtype)),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(num_new, r, k, v, logw, u, s0)


def wkv_scan_ref(r, k, v, logw, u, s0, num_new, *,
                 cfg_state: PositConfig | None, posit_state: bool):
    """jnp oracle: identical per-token math as `_wkv_kernel`."""
    S0 = (decode_to_f32(s0, cfg_state) if posit_state
          else s0.astype(jnp.float32))
    uf = u.astype(jnp.float32)
    rT = jnp.moveaxis(r.astype(jnp.float32), 2, 0)    # [T, B, H, dh]
    kT = jnp.moveaxis(k.astype(jnp.float32), 2, 0)
    vT = jnp.moveaxis(v.astype(jnp.float32), 2, 0)
    wT = jnp.moveaxis(logw.astype(jnp.float32), 2, 0)
    tt = jnp.arange(r.shape[2], dtype=jnp.int32)

    def body(S, inp):
        r_t, k_t, v_t, w_t, t = inp
        y = jnp.einsum("bhd,bhdv->bhv", r_t, S)
        su = jnp.einsum("bhd,hd,bhd->bh", r_t, uf, k_t)
        y = y + su[..., None] * v_t
        S_new = jnp.exp(w_t)[..., None] * S + k_t[..., None] * v_t[:, :, None, :]
        S_new = _rt(S_new, cfg_state)
        live = t < num_new                            # [B]
        S = jnp.where(live[:, None, None, None], S_new, S)
        y = jnp.where(live[:, None, None], y, 0.0)
        return S, y

    S_fin, ys = jax.lax.scan(body, S0, (rT, kT, vT, wT, tt))
    y = jnp.moveaxis(ys, 0, 2)
    return y, _store_state(S_fin, cfg_state, posit_state)


# --------------------------------------------------------------------------
# rGLRU (Griffin/RecurrentGemma core):  h_t = rt(a_t h_{t-1} + b_t), y = h_t
# (a/b are the batched gate projections, computed outside the scan)
# --------------------------------------------------------------------------
def _rglru_kernel(nn_ref, a_ref, b_ref, h0_ref, y_ref, hf_ref, h_scr, *,
                  cfg_state, posit_state, T):
    bb = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        h_scr[...] = _load_state(h0_ref[...], cfg_state, posit_state)

    h = h_scr[...]                                    # [1, d] f32
    a = a_ref[0].astype(jnp.float32)                  # [1, d]
    bt = b_ref[0].astype(jnp.float32)
    h_new = _rt(a * h + bt, cfg_state)

    live = t < nn_ref[bb]
    h_new = jnp.where(live, h_new, h)
    h_scr[...] = h_new
    y_ref[0] = jnp.where(live, h_new, 0.0)

    @pl.when(t == T - 1)
    def _done():
        hf_ref[...] = _store_state(h_new, cfg_state, posit_state)


@functools.partial(jax.jit, static_argnames=("cfg_state", "posit_state",
                                             "interpret"))
def rglru_scan_pallas(a, b, h0, num_new, *,
                      cfg_state: PositConfig | None, posit_state: bool,
                      interpret: bool = False):
    """a/b [B, T, d], h0 [B, d] (posit storage ints when posit_state),
    num_new [B] int32 -> (h_seq [B, T, d] f32, h_fin same rep as h0)."""
    B, T, d = a.shape
    grid = (B, T)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda bb, t, nn: (bb, t, 0)),
            pl.BlockSpec((1, 1, d), lambda bb, t, nn: (bb, t, 0)),
            pl.BlockSpec((1, d), lambda bb, t, nn: (bb, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, d), lambda bb, t, nn: (bb, t, 0)),
            pl.BlockSpec((1, d), lambda bb, t, nn: (bb, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_rglru_kernel, cfg_state=cfg_state,
                          posit_state=posit_state, T=T),
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((B, T, d), jnp.float32),
                   jax.ShapeDtypeStruct((B, d), h0.dtype)),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(num_new, a, b, h0)


def rglru_scan_ref(a, b, h0, num_new, *,
                   cfg_state: PositConfig | None, posit_state: bool):
    """jnp oracle: identical per-token math as `_rglru_kernel`."""
    H0 = (decode_to_f32(h0, cfg_state) if posit_state
          else h0.astype(jnp.float32))
    aT = jnp.moveaxis(a.astype(jnp.float32), 1, 0)    # [T, B, d]
    bT = jnp.moveaxis(b.astype(jnp.float32), 1, 0)
    tt = jnp.arange(a.shape[1], dtype=jnp.int32)

    def body(h, inp):
        a_t, b_t, t = inp
        h_new = _rt(a_t * h + b_t, cfg_state)
        live = (t < num_new)[:, None]                 # [B, 1]
        h = jnp.where(live, h_new, h)
        return h, jnp.where(live, h_new, 0.0)

    h_fin, ys = jax.lax.scan(body, H0, (aT, bT, tt))
    return jnp.moveaxis(ys, 0, 1), _store_state(h_fin, cfg_state, posit_state)
