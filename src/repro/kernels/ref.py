"""Pure-jnp oracles for every Pallas kernel (the per-kernel golden models).

Tests assert kernel(interpret=True) == ref to machine precision (bit-exact
for integer-domain kernels, allclose for f32 accumulation order effects).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import ops as pops
from repro.core.convert import f32_to_posit
from repro.core.decode import decode_to_f32
from repro.core.types import PositConfig


def posit_gemm_ref(a, b, *, cfg_a: PositConfig | None, cfg_b: PositConfig | None,
                   cfg_out: PositConfig | None = None,
                   out_posit: bool = False,
                   transpose_b: bool = False) -> jnp.ndarray:
    import jax
    af = decode_to_f32(a, cfg_a) if cfg_a is not None else a.astype(jnp.float32)
    bf = decode_to_f32(b, cfg_b) if cfg_b is not None else b.astype(jnp.float32)
    if transpose_b:
        # contract both on their last dim (b stored [n, k]) — the same
        # dot_general the old unembed einsum "...d,vd->...v" lowered to, so
        # the ref path stays bit-identical to the pre-pw_gemm unembedding
        acc = jax.lax.dot_general(af, bf, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    else:
        acc = jnp.dot(af, bf, preferred_element_type=jnp.float32)
    return f32_to_posit(acc, cfg_out) if out_posit else acc


def grouped_row_ids(group_offsets, n_rows: int):
    """Row -> group id under the sorted-segment layout ([E+1] offsets), plus
    the in-any-group mask (rows past offsets[-1] belong to no group)."""
    rows = jnp.arange(n_rows)
    gid = jnp.clip(jnp.searchsorted(group_offsets, rows, side="right") - 1,
                   0, group_offsets.shape[0] - 2)
    inb = (rows >= group_offsets[0]) & (rows < group_offsets[-1])
    return gid, inb


def grouped_matmul_ref(x, w, group_offsets, *,
                       cfg_b: PositConfig | None = None) -> jnp.ndarray:
    """Oracle for kernels.grouped_gemm.posit_grouped_gemm: rows of x hit
    their own group's weight matrix; rows outside every group come back 0.

    Deliberately dense on the weight side: the full w decodes to f32 —
    this is the CPU/interpret reference, never the TPU path (the kernel
    streams only the active groups' posit tiles).  The contraction itself
    goes through jax.lax.ragged_dot (contiguous ascending groups, our
    exact layout) so no [S, k, n] per-row weight gather materializes; the
    where-mask pins the rows past group_offsets[-1], whose ragged_dot
    values are formally undefined.
    """
    import jax
    wf = decode_to_f32(w, cfg_b) if cfg_b is not None \
        else w.astype(jnp.float32)
    gid, inb = grouped_row_ids(group_offsets, x.shape[0])
    sizes = (group_offsets[1:] - group_offsets[:-1]).astype(jnp.int32)
    if hasattr(jax.lax, "ragged_dot"):
        out = jax.lax.ragged_dot(x.astype(jnp.float32), wf, sizes)
    else:  # older jax: the gather formulation
        out = jnp.einsum("sk,skn->sn", x.astype(jnp.float32), wf[gid],
                         preferred_element_type=jnp.float32)
    return jnp.where(inb[:, None], out, 0.0)


def elementwise_ref(op: str, *inputs, cfg: PositConfig) -> jnp.ndarray:
    fn = {"add": pops.padd, "sub": pops.psub, "mul": pops.pmul,
          "fma": pops.pfma}[op]
    return fn(*inputs, cfg)


def divide_ref(a, b, *, cfg: PositConfig, mode: str = "poly_corrected",
               nr_rounds: int = 1) -> jnp.ndarray:
    return pops.pdiv(a, b, cfg, mode=mode, nr_rounds=nr_rounds)


def decode_ref(p, cfg: PositConfig) -> jnp.ndarray:
    return decode_to_f32(p, cfg)


def encode_ref(v, cfg: PositConfig) -> jnp.ndarray:
    return f32_to_posit(jnp.asarray(v).astype(jnp.float32), cfg)


def flash_attention_ref(q, k, v, *, cfg_kv: PositConfig | None = None,
                        causal: bool = True) -> jnp.ndarray:
    """Naive softmax attention oracle. q [BH,Sq,D], k/v [BH,Skv,D]."""
    qf = q.astype(jnp.float32)
    kf = decode_to_f32(k, cfg_kv) if cfg_kv is not None else k.astype(jnp.float32)
    vf = decode_to_f32(v, cfg_kv) if cfg_kv is not None else v.astype(jnp.float32)
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", qf, kf) / (d ** 0.5)
    if causal:
        sq, skv = q.shape[1], k.shape[1]
        qpos = jnp.arange(sq)[:, None] + (skv - sq)
        kpos = jnp.arange(skv)[None, :]
        s = jnp.where(qpos >= kpos, s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p, vf)
