"""Roofline-term extraction from AOT-compiled artifacts (EXPERIMENTS §Roofline).

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

cost_analysis() FLOPs/bytes are for the SPMD-partitioned per-device module.
Collective bytes are not in cost_analysis: we parse the optimized HLO text
and sum buffer sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops (shapes there are per-device), with
ring-algorithm byte factors.

Hardware constants: TPU v5e targets (the container is CPU; these terms are
*structural*, derived from the compiled module, not wall-clock).
"""
from __future__ import annotations

import dataclasses
import re

# --- TPU v5e hardware constants (per chip) ---
PEAK_FLOPS_BF16 = 197e12     # FLOP/s
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link (~bidirectional per-direction)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_OP_RE = re.compile(
    r"^(?P<res>[^=]*?)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<variant>-start|-done)?\(")

_TUPLE_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveStats:
    by_op: dict
    total_bytes: float       # ring-factored, per device

    @property
    def raw_bytes(self) -> float:
        return sum(v["bytes"] for v in self.by_op.values())


# ring-algorithm traffic factors (large-group limit), per device
_FACTORS = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    by_op: dict[str, dict] = {}
    for line in hlo_text.splitlines():
        if " = " not in line:
            continue
        _, rhs = line.split(" = ", 1)
        m = _OP_RE.match(rhs.strip())
        if not m or m.group("variant") == "-done":
            continue            # async start/done pairs: count the start only
        op = m.group("op")
        # sum the result buffer shapes (tuple for async starts)
        nbytes = sum(_shape_bytes(d, s)
                     for d, s in _TUPLE_SHAPE_RE.findall(m.group("res"))
                     if d in _DTYPE_BYTES)
        rec = by_op.setdefault(op, {"count": 0, "bytes": 0.0})
        rec["count"] += 1
        rec["bytes"] += nbytes
    total = sum(_FACTORS[op] * v["bytes"] for op, v in by_op.items())
    return CollectiveStats(by_op=by_op, total_bytes=total)


def roofline_terms(compiled, n_devices: int) -> dict:
    """Three roofline terms (seconds) + raw counters from a compiled exe."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    mem = compiled.memory_analysis()

    terms = {
        "flops_per_device": flops,
        "bytes_per_device": byts,
        "collective_bytes_per_device": coll.total_bytes,
        "collectives_by_op": coll.by_op,
        "t_compute_s": flops / PEAK_FLOPS_BF16,
        "t_memory_s": byts / HBM_BW,
        "t_collective_s": coll.total_bytes / ICI_BW,
        "n_devices": n_devices,
    }
    terms["bottleneck"] = max(
        ("compute", terms["t_compute_s"]),
        ("memory", terms["t_memory_s"]),
        ("collective", terms["t_collective_s"]),
        key=lambda kv: kv[1])[0]
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "generated_code_size_in_bytes"):
        if hasattr(mem, attr):
            terms[f"mem_{attr}"] = getattr(mem, attr)
    return terms


def model_flops(cfg, shape, decode: bool) -> float:
    """Analytic MODEL_FLOPS: 6*N*D train, 2*N_active per token decode/prefill."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.seq_len * shape.global_batch
    return 2.0 * n_active * 1 * shape.global_batch     # one decode token
