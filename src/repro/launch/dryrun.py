import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# The two lines above MUST run before any other import (jax locks the device
# count on first init).  Everything below is ordinary code.
"""Multi-pod dry-run: AOT-lower + compile every (arch x shape x mesh) cell.

For each cell this builds ShapeDtypeStruct stand-ins for params, optimizer
state, batches and KV caches (no allocation), jits the train/prefill/decode
step with explicit in_shardings on the production mesh, compiles, and dumps
memory_analysis / cost_analysis / collective-bytes to JSON for the roofline
table (EXPERIMENTS.md §Dry-run, §Roofline).

    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m \
        --shape train_4k --mesh pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Failures here (sharding mismatch, OOM at compile, unsupported collective)
are bugs in the system — fix the sharding rules, not the script.
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.shapes import SHAPES, skip_reason
from repro.core.types import P16_2
from repro.distributed import sharding as sh
from repro.launch import analysis
from repro.launch.mesh import make_production_mesh
from repro.models.transformer import init_caches, init_params
from repro.optim import adamw
from repro.quant.policy import PositPolicy
from repro.quant.ptq import serving_param_specs
from repro.serving.engine import decode_step, prefill_step
from repro.training.train_step import train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")

# paper-mode posit policies
from repro.core.types import P8_2
TRAIN_POLICY = PositPolicy(weights=P16_2)                  # QAT posit16 weights
SERVE_POLICY = PositPolicy(weights=P16_2, kv_cache=P16_2)  # PTQ + posit KV
SERVE_POLICY_P8 = PositPolicy(weights=P8_2, kv_cache=P8_2)

# --format axis for the posit-vs-float comparison (§Perf iteration C):
#   p16 (default) / p8: posit policy;  bf16: bf16 act+KV, f32 weights;
#   f32: everything float32 — the paper's binary32 reference
FORMATS = ("p16", "p8", "bf16", "f32")


def _sds(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def model_config(arch: str, shape, mode: str, fmt: str = "p16",
                 n_layers: int | None = None, scan_layers: bool = True):
    # production numerics: bf16 activations, f32 master weights (+posit
    # storage per policy); "f32" is the paper's binary32 reference
    over = {"dtype": "float32" if fmt == "f32" else "bfloat16",
            "scan_layers": scan_layers}
    if fmt == "p16":
        over["policy"] = SERVE_POLICY if mode != "train" else TRAIN_POLICY
    elif fmt == "p8":
        over["policy"] = (SERVE_POLICY_P8 if mode != "train"
                          else PositPolicy(weights=P8_2))
    cfg = configs.get_config(arch, **over)
    if n_layers is not None:
        import dataclasses
        cfg = dataclasses.replace(cfg, n_layers=n_layers)
    return cfg


def batch_specs(cfg, shape):
    B, S = shape.global_batch, shape.seq_len
    if cfg.encoder_only:
        return {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.float32),
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    batch = {"tokens": jax.ShapeDtypeStruct((B, S + 1), jnp.int32)}
    if cfg.input_mode == "tokens+image":
        from repro.configs.phi_3_vision_4_2b import N_PATCHES
        batch["tokens"] = jax.ShapeDtypeStruct((B, S + 1 - N_PATCHES), jnp.int32)
        batch["image_embeds"] = jax.ShapeDtypeStruct(
            (B, N_PATCHES, cfg.d_model), jnp.float32)
    return batch


def build_cell(arch: str, shape, mesh, multi_pod: bool, fmt: str = "p16",
               n_layers: int | None = None, scan_layers: bool = True):
    """Returns (jitted_fn, arg_specs) ready to .lower(*arg_specs)."""
    mode = shape.kind
    cfg = model_config(arch, shape, mode, fmt, n_layers, scan_layers)
    B, S = shape.global_batch, shape.seq_len
    # serving is weight-stationary: TP sharding keeps the (huge) weights put
    # and moves only (B, 1/S_chunk, d) activations through psums — FSDP
    # weight gathers per decoded token are the §Perf iteration-B pathology
    strategy = "tp2d" if mode != "train" else sh.strategy_for(cfg, mesh)

    param_shapes = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))
    pspec = sh.param_pspecs(param_shapes, mesh, multi_pod, strategy)
    psh = sh.to_shardings(pspec, mesh)

    if mode == "train":
        moment_dtype = ("bfloat16"
                        if cfg.param_count() > 5e10 else "float32")
        opt_cfg = adamw.OptConfig(moment_dtype=moment_dtype)
        opt_shapes = jax.eval_shape(
            lambda: adamw.init_state(param_shapes, opt_cfg))
        ospec = sh.opt_state_pspecs(opt_shapes, pspec, mesh)
        osh = sh.to_shardings(ospec, mesh)
        bspecs = batch_specs(cfg, shape)
        bspec = sh.batch_pspecs(bspecs, mesh, multi_pod,
                                shard_seq=(B < 16), strategy=strategy)
        bsh = sh.to_shardings(bspec, mesh)

        # >=50B models: 16-way gradient accumulation (activation temp /16,
        # same math — §Perf iteration A2)
        accum = 16 if cfg.param_count() > 5e10 else 1
        fn = jax.jit(
            lambda p, o, b: train_step(p, o, b, cfg, opt_cfg,
                                       accum_steps=accum),
            in_shardings=(psh, osh, bsh),
            donate_argnums=(0, 1))
        return fn, (param_shapes, opt_shapes, bspecs)

    # serving: PTQ posit weights
    if fmt in ("p16", "p8"):
        param_shapes = serving_param_specs(param_shapes,
                                           P16_2 if fmt == "p16" else P8_2)
        pspec = sh.param_pspecs(param_shapes, mesh, multi_pod, strategy)
        psh = sh.to_shardings(pspec, mesh)

    cache_shapes = jax.eval_shape(
        lambda: init_caches(cfg, B, S, dtype=jnp.dtype(cfg.dtype)))
    cspec = sh.cache_pspecs(cache_shapes, mesh, multi_pod, strategy)
    csh = sh.to_shardings(cspec, mesh)

    if mode == "prefill":
        tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if cfg.encoder_only:
            # encoder prefill == one full forward over embeddings
            from repro.models.transformer import forward
            emb = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.float32)
            espec = sh.batch_pspecs({"e": emb}, mesh, multi_pod,
                                    strategy=strategy)["e"]
            fn = jax.jit(
                lambda p, e: forward(p, cfg, inputs_embeds=e)[0],
                in_shardings=(psh, sh.to_shardings(espec, mesh)))
            return fn, (param_shapes, emb)
        if cfg.input_mode == "tokens+image":
            from repro.configs.phi_3_vision_4_2b import N_PATCHES
            tok = jax.ShapeDtypeStruct((B, S - N_PATCHES), jnp.int32)
        tspec = sh.batch_pspecs({"t": tok}, mesh, multi_pod,
                                strategy=strategy)["t"]
        fn = jax.jit(
            lambda p, t, c: prefill_step(p, cfg, t, c),
            in_shardings=(psh, sh.to_shardings(tspec, mesh), csh),
            donate_argnums=(2,))
        return fn, (param_shapes, tok, cache_shapes)

    # decode: cache filled to S-1, one new token
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tspec = sh.batch_pspecs({"t": tok}, mesh, multi_pod,
                            strategy=strategy)["t"]
    fn = jax.jit(
        lambda p, t, c: decode_step(p, cfg, t, c),
        in_shardings=(psh, sh.to_shardings(tspec, mesh), csh),
        donate_argnums=(2,))
    return fn, (param_shapes, tok, cache_shapes)


def _probe_counters(arch, shape, mesh, multi_pod, fmt, n_layers):
    """Compile an unrolled reduced-depth probe; return (flops, bytes, coll)."""
    fn, arg_specs = build_cell(arch, shape, mesh, multi_pod, fmt,
                               n_layers=n_layers, scan_layers=False)
    compiled = fn.lower(*arg_specs).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    coll = analysis.parse_collectives(compiled.as_text())
    return (float(ca.get("flops", 0.0)),
            float(ca.get("bytes accessed", 0.0)),
            coll.total_bytes, coll.by_op)


def probe_roofline(arch, shape, mesh, multi_pod, fmt) -> dict:
    """Trip-count-correct roofline counters via linear extrapolation.

    XLA cost_analysis counts a scanned body once; we compile UNROLLED probes
    at L=P and L=2P layers (P = block-pattern length), solve
    outside = 2*c1 - c2, per_pattern = c2 - c1.  A hybrid remainder
    (recurrentgemma's trailing rglru pair: n_layers % P != 0) gets its own
    probe at L = P + rem, whose delta over c1 is exactly the remainder
    layers' cost:  total(L) = outside + (L // P) * per_pattern + rem_cost.
    Exact for every stack, uniform or hybrid.
    """
    cfg_full = configs.get_config(arch)
    P = len(cfg_full.block_pattern)
    c1 = _probe_counters(arch, shape, mesh, multi_pod, fmt, P)
    c2 = _probe_counters(arch, shape, mesh, multi_pod, fmt, 2 * P)
    reps, rem = divmod(cfg_full.n_layers, P)
    c3 = (_probe_counters(arch, shape, mesh, multi_pod, fmt, P + rem)
          if rem else None)
    out = {}
    names = ("flops_per_device", "bytes_per_device",
             "collective_bytes_per_device")
    for i, name in enumerate(names):
        outside = 2 * c1[i] - c2[i]
        per_pattern = c2[i] - c1[i]
        rem_cost = (c3[i] - c1[i]) if c3 is not None else 0.0
        out[name] = max(outside, 0.0) + reps * per_pattern + rem_cost
    out["probe_collectives_by_op_2p"] = c2[3]
    out["t_compute_s"] = out["flops_per_device"] / analysis.PEAK_FLOPS_BF16
    out["t_memory_s"] = out["bytes_per_device"] / analysis.HBM_BW
    out["t_collective_s"] = (out["collective_bytes_per_device"]
                             / analysis.ICI_BW)
    out["bottleneck"] = max(
        ("compute", out["t_compute_s"]), ("memory", out["t_memory_s"]),
        ("collective", out["t_collective_s"]), key=lambda kv: kv[1])[0]
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             fmt: str = "p16", save: bool = True) -> dict:
    shape = SHAPES[shape_name]
    cfg_plain = configs.get_config(arch)
    reason = skip_reason(cfg_plain, shape)
    mesh_name = "multipod" if multi_pod else "pod"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "posit": fmt in ("p16", "p8"), "format": fmt, "status": None}
    if reason:
        rec["status"] = "skip"
        rec["reason"] = reason
        print(f"[dryrun] SKIP {arch} x {shape_name}: {reason}")
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
        t0 = time.time()
        try:
            strategy = ("tp2d" if shape.kind != "train"
                        else sh.strategy_for(configs.get_config(arch), mesh))
            rec["strategy"] = strategy
            with mesh, sh.activation_sharding(mesh, multi_pod, strategy):
                fn, arg_specs = build_cell(arch, shape, mesh, multi_pod, fmt)
                lowered = fn.lower(*arg_specs)
                t_lower = time.time() - t0
                compiled = lowered.compile()
                t_compile = time.time() - t0 - t_lower
                terms = analysis.roofline_terms(compiled, mesh.size)
                print(compiled.memory_analysis())
                # scan bodies are cost-counted once; probes fix trip counts
                terms.update(probe_roofline(arch, shape, mesh, multi_pod,
                                            fmt))
            rec.update(terms)
            rec["model_flops_analytic"] = analysis.model_flops(
                cfg_plain, shape, shape.kind == "decode")
            # per-layer serving-cache accounting from the backends' memory
            # descriptors: state layers are O(1)/seq, windowed KV
            # O(window), full KV O(context) — the exact bytes the paged
            # engine holds per sequence at this shape's context length
            from repro.serving.backends import layout_for
            layout = layout_for(cfg_plain)
            page = 64
            rec["serving_cache"] = {
                "page_size": page,
                "per_layer": [
                    {"kind": d.kind, "backend": d.backend,
                     "bytes_per_seq": d.bytes_per_seq(shape.seq_len, page)}
                    for d in layout.descs(page)],
                "bytes_per_seq": layout.cache_bytes_per_seq(shape.seq_len,
                                                            page),
            }
            rec["t_lower_s"] = round(t_lower, 1)
            rec["t_compile_s"] = round(t_compile, 1)
            rec["status"] = "ok"
            print(f"[dryrun] OK {arch} x {shape_name} x {mesh_name} "
                  f"(lower {t_lower:.0f}s compile {t_compile:.0f}s) "
                  f"bottleneck={terms['bottleneck']}")
        except Exception as e:
            rec["status"] = "fail"
            rec["error"] = f"{type(e).__name__}: {e}"
            rec["traceback"] = traceback.format_exc()[-4000:]
            print(f"[dryrun] FAIL {arch} x {shape_name} x {mesh_name}: "
                  f"{type(e).__name__}: {str(e)[:300]}")
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        fname = f"{arch}__{shape_name}__{mesh_name}" + \
            ("" if fmt == "p16" else f"__{fmt}") + ".json"
        with open(os.path.join(RESULTS_DIR, fname), "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-posit", action="store_true",
                    help="alias for --format bf16")
    ap.add_argument("--format", default="p16", choices=list(FORMATS))
    args = ap.parse_args()
    if args.no_posit:
        args.format = "bf16" 

    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]
    cells = []
    if args.all:
        for arch in configs.ARCHS:
            for sname in SHAPES:
                cells.append((arch, sname))
    else:
        cells.append((args.arch, args.shape))

    summary = []
    for arch, sname in cells:
        for mp in meshes:
            rec = run_cell(arch, sname, mp, fmt=args.format)
            summary.append((arch, sname, rec["status"]))
    n_ok = sum(1 for *_, s in summary if s == "ok")
    n_skip = sum(1 for *_, s in summary if s == "skip")
    n_fail = sum(1 for *_, s in summary if s == "fail")
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skip, {n_fail} fail")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
