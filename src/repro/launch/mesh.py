"""Production mesh definition.

Single pod: (data=16, model=16) = 256 chips (v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the pod axis composes
with data for gradient reduction (lowest-traffic axis over the slowest
links; cross-pod bytes further shrink via the posit-compressed collective).

A FUNCTION, not a module constant: importing this module never touches jax
device state (jax locks the device count on first backend init, and only
dryrun.py sets the 512-device XLA flag).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh for single-device runs (tests/examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_serving_mesh(data: int | None = None, model: int = 1):
    """("data", "model") mesh over the locally visible devices for the
    sharded paged serving step (serving.engine.PagedServingEngine(mesh=...)).

    data=None: all devices not claimed by `model` go to data parallelism.
    Unlike make_production_mesh this takes whatever jax.devices() offers
    (a TPU slice, or a forced-CPU host via
    XLA_FLAGS=--xla_force_host_platform_device_count=N), and may use a
    prefix subset of the devices.
    """
    import numpy as np

    devs = jax.devices()
    if model < 1:
        raise ValueError(f"model axis must be >= 1, got {model}")
    if data is None:
        data = len(devs) // model
    n = data * model
    if n < 1 or n > len(devs):
        raise ValueError(f"mesh ({data}, {model}) needs {n} devices, "
                         f"have {len(devs)}")
    return jax.sharding.Mesh(np.asarray(devs[:n]).reshape(data, model),
                             ("data", "model"))
