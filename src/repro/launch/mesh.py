"""Production mesh definition.

Single pod: (data=16, model=16) = 256 chips (v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the pod axis composes
with data for gradient reduction (lowest-traffic axis over the slowest
links; cross-pod bytes further shrink via the posit-compressed collective).

A FUNCTION, not a module constant: importing this module never touches jax
device state (jax locks the device count on first backend init, and only
dryrun.py sets the 512-device XLA flag).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh for single-device runs (tests/examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))
