"""Serving launcher: batched generation with posit-quantized weights/KV.

    # synchronized dense-cache batch (the original engine)
    python -m repro.launch.serve --arch smollm-360m --smoke \
        --batch 4 --prompt-len 32 --max-new 16 --posit p16

    # continuous batching over the paged posit KV pool
    python -m repro.launch.serve --arch smollm-360m --smoke --engine paged \
        --batch 4 --prompt-len 32 --max-new 16 --posit p16 --requests 16

    # mesh-sharded paged serving (data x model axes; here 8-way forced-CPU)
    python -m repro.launch.serve --arch smollm-360m --smoke --engine paged \
        --batch 8 --mesh 4x2 --host-devices 8

Runs PTQ (quant/ptq.py) on freshly-initialized (or checkpointed) weights,
then serves synthetic traffic.  The paged engine draws mixed prompt lengths
in [prompt-len/4, prompt-len] so admission/retirement actually interleave.
"""
from __future__ import annotations

import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", choices=["dense", "paged"], default="dense")
    ap.add_argument("--batch", type=int, default=4,
                    help="batch (dense) / sequence slots (paged)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--posit", choices=["off", "p8", "p16"], default="p16")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--temperature", type=float, default=0.0)
    # paged-engine knobs
    ap.add_argument("--requests", type=int, default=None,
                    help="paged: total requests to serve (default 2*batch)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=64)
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="paged: disable content-addressed prefix caching "
                         "of KV pages")
    ap.add_argument("--mesh", default=None, metavar="DATAxMODEL",
                    help="paged: shard the serving step over a "
                         "(data, model) mesh, e.g. 4x2")
    ap.add_argument("--host-devices", type=int, default=None,
                    help="force N CPU host devices (sets XLA_FLAGS; must "
                         "run before jax initializes)")
    # graceful-degradation / chaos knobs (paged engine)
    ap.add_argument("--max-waiting", type=int, default=None,
                    help="paged: bound the admission queue; overflow "
                         "submissions resolve `rejected` with a "
                         "retry-after hint instead of queueing forever")
    ap.add_argument("--ttl-steps", type=int, default=None,
                    help="paged: per-request TTL in engine steps; "
                         "exceeded -> `expired`, pages return to the pool")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="paged: per-request wall-clock deadline (s)")
    ap.add_argument("--chaos", default=None, metavar="KIND=P[,KIND=P...]",
                    help="paged: seeded fault injection, e.g. "
                         "'step_fault=0.05,nar_poison=0.02,"
                         "page_poison=0.02,straggle=0.1' "
                         "(see serving/faults.py)")
    ap.add_argument("--chaos-seed", type=int, default=0)
    args = ap.parse_args()

    if args.host_devices:
        # append (not prepend): XLA applies the *last* duplicate flag, so an
        # inherited force_host_platform_device_count must not win over the
        # explicit request
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={args.host_devices}")

    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.checkpoint import store
    from repro.core.types import P8_2, P16_2
    from repro.models.transformer import init_params
    from repro.quant.policy import PositPolicy
    from repro.quant.ptq import quantize_for_serving
    from repro.serving.engine import PagedServingEngine, generate

    pcfg = {"p8": P8_2, "p16": P16_2}.get(args.posit)
    policy = PositPolicy(weights=pcfg, kv_cache=pcfg) if pcfg else PositPolicy()
    get = configs.get_smoke if args.smoke else configs.get_config
    cfg = get(args.arch, policy=policy)

    params = init_params(jax.random.PRNGKey(0), cfg)
    if args.ckpt_dir:
        step, restored = store.restore_latest(args.ckpt_dir, {"params": params})
        if step is not None:
            params = restored["params"]
            print(f"[serve] loaded checkpoint step {step}")
    if pcfg is not None:
        params = quantize_for_serving(params, pcfg)
        nbytes = sum(x.nbytes for x in jax.tree_util.tree_leaves(params))
        print(f"[serve] PTQ {pcfg}: weights now {nbytes/1e6:.1f} MB")

    if args.engine == "dense":
        prompts = jax.random.randint(jax.random.PRNGKey(1),
                                     (args.batch, args.prompt_len), 0,
                                     cfg.vocab)
        t0 = time.time()
        out = generate(params, cfg, prompts, args.max_new,
                       temperature=args.temperature)
        out.block_until_ready()
        dt = time.time() - t0
        print(f"[serve] generated {out.shape} in {dt:.2f}s "
              f"({args.batch * args.max_new / dt:.1f} tok/s incl. compile)")
        print(out[:, :12])
        return

    # paged continuous batching: mixed-length synthetic traffic
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_serving_mesh
        d, m = (int(v) for v in args.mesh.lower().split("x"))
        mesh = make_serving_mesh(d, m)
        print(f"[serve] mesh: data={d} x model={m} over "
              f"{d * m} {jax.devices()[0].platform} devices")
    n_req = args.requests or 2 * args.batch
    rng = np.random.default_rng(1)
    cap = args.prompt_len + args.max_new
    width = max(2, -(-cap // args.page_size))
    from repro.serving.backends import layout_for
    layout = layout_for(cfg)
    kinds = ",".join(f"{b.kind}:{b.backend}" for b in layout.backends)
    print(f"[serve] cache backends: {kinds}; per-seq cache at "
          f"{cap} tokens = "
          f"{layout.cache_bytes_per_seq(cap, args.page_size) / 1e3:.1f} KB")
    chaos = None
    if args.chaos:
        from repro.serving.faults import ChaosConfig
        kv = dict(part.split("=") for part in args.chaos.split(","))
        chaos = ChaosConfig(seed=args.chaos_seed,
                            **{f"p_{k}": float(v) for k, v in kv.items()})
        print(f"[serve] chaos: {chaos}")
    eng = PagedServingEngine(
        params, cfg, max_seqs=args.batch, page_size=args.page_size,
        table_width=width, prefill_chunk=args.prefill_chunk,
        temperature=args.temperature,
        prefix_cache=not args.no_prefix_cache, mesh=mesh,
        max_waiting=args.max_waiting, default_ttl_steps=args.ttl_steps,
        default_deadline_s=args.deadline_s, chaos=chaos)
    reqs = []
    for _ in range(n_req):
        plen = int(rng.integers(max(1, args.prompt_len // 4),
                                args.prompt_len + 1))
        reqs.append((rng.integers(0, cfg.vocab, plen), args.max_new))
    t0 = time.time()
    results = eng.run(reqs)
    dt = time.time() - t0
    n_tok = sum(len(v) for v in results.values())
    stats = eng.stats()
    print(f"[serve] paged: {len(results)} requests, {n_tok} tokens in "
          f"{dt:.2f}s ({n_tok / dt:.1f} tok/s incl. compile); "
          f"stats={stats}")
    from repro.serving.engine import OUTCOMES
    outcome_line = " ".join(f"{k}={stats.get(k, 0)}" for k in OUTCOMES)
    print(f"[serve] outcomes: submitted={stats.get('submitted', 0)} "
          f"{outcome_line}")
    print(f"[serve] robustness: straggler_steps="
          f"{stats.get('straggler_steps', 0)} "
          f"step_latency_ms p50={stats.get('step_latency_p50_ms', 0.0):.1f} "
          f"p99={stats.get('step_latency_p99_ms', 0.0):.1f}")
    if results:
        first = results[min(results)]
        print(f"[serve] rid {min(results)}: {first[:12]}")


if __name__ == "__main__":
    main()
