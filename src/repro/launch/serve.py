"""Serving launcher: batched generation with posit-quantized weights/KV.

    python -m repro.launch.serve --arch smollm-360m --smoke \
        --batch 4 --prompt-len 32 --max-new 16 --posit p16

Runs PTQ (quant/ptq.py) on freshly-initialized (or checkpointed) weights,
then serves a synthetic batch through prefill+decode — the same
prefill_step/decode_step the dry-run lowers for the production mesh.
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--posit", choices=["off", "p8", "p16"], default="p16")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.checkpoint import store
    from repro.core.types import P8_2, P16_2
    from repro.models.transformer import init_params
    from repro.quant.policy import PositPolicy
    from repro.quant.ptq import quantize_for_serving
    from repro.serving.engine import generate

    pcfg = {"p8": P8_2, "p16": P16_2}.get(args.posit)
    policy = PositPolicy(weights=pcfg, kv_cache=pcfg) if pcfg else PositPolicy()
    get = configs.get_smoke if args.smoke else configs.get_config
    cfg = get(args.arch, policy=policy)

    params = init_params(jax.random.PRNGKey(0), cfg)
    if args.ckpt_dir:
        step, restored = store.restore_latest(args.ckpt_dir, {"params": params})
        if step is not None:
            params = restored["params"]
            print(f"[serve] loaded checkpoint step {step}")
    if pcfg is not None:
        params = quantize_for_serving(params, pcfg)
        nbytes = sum(x.nbytes for x in jax.tree_util.tree_leaves(params))
        print(f"[serve] PTQ {pcfg}: weights now {nbytes/1e6:.1f} MB")

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.time()
    out = generate(params, cfg, prompts, args.max_new,
                   temperature=args.temperature)
    out.block_until_ready()
    dt = time.time() - t0
    print(f"[serve] generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.max_new / dt:.1f} tok/s incl. compile)")
    print(out[:, :12])


if __name__ == "__main__":
    main()
