"""Elastic process-group supervisor: restart-on-failure that actually
re-execs processes, not a try/except around the train loop.

    python -m repro.launch.supervisor --arch smollm-360m --smoke \
        --workers 4 --steps 200 --ckpt-dir /tmp/ck --step-timeout 60

The supervisor spawns N worker processes (jax.distributed.initialize over
localhost TCP — gloo CPU collectives, the same subprocess pattern as
tests/test_serving_sharded.py), and watches two signals:

  * process exit codes — a worker killed by a signal (rc < 0) is a node
    death; rc == COLLATERAL_RC (75) is a worker that died *because a peer
    vanished mid-collective* and must not count as its own failure;
  * per-worker heartbeat files (fault_tolerance.Heartbeat: step + phase +
    timestamp, atomically renamed) — a heartbeat stale past
    --step-timeout is a straggler even though the process is alive, and
    no heartbeat within startup_timeout_s is a hung launch.

On any failure it kills the whole group (SIGTERM, then SIGKILL), backs
off exponentially (RestartPolicy.backoff_s * 2**n, capped), and re-execs
with the data axis shrunk to the survivors — crashed/straggling workers
are removed; collateral deaths and clean exits are not.  Restarts are
bounded by RestartPolicy.max_restarts and floored at min_workers; the
run ends in a structured RunOutcome (completed | exhausted_restarts |
failed), never an unhandled exception.

The shrunk group resumes from the newest valid checkpoint and — because
per-host batches are derived (data.pipeline.host_batch_at) and gradient
reduction is regroup-invariant (training/elastic.py) — produces
parameters bit-identical to an uninterrupted run.  tests/test_supervisor.py
pins exactly that: SIGKILL one of 4 workers mid-run, compare final
params against a same-seed single-process run.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import signal
import socket
import subprocess
import sys
import time

from repro.distributed.fault_tolerance import (PHASE_RANK, RestartPolicy,
                                               read_heartbeat)

# a worker that dies because a *peer* vanished mid-collective exits with
# this code; the supervisor restarts but does not shrink it away
COLLATERAL_RC = 75


@dataclasses.dataclass
class GenRecord:
    """One generation (spawn) of the worker group, for the bench/tests."""
    gen: int
    workers: int
    started_t: float
    ended_t: float = 0.0
    first_step: int | None = None   # min heartbeat step seen this gen
    last_step: int | None = None    # max heartbeat step seen this gen
    failure: str | None = None      # crash | straggler | startup_timeout |
                                    # collateral | error | None (completed)
    culprits: tuple[int, ...] = ()  # host_ids removed going into next gen


@dataclasses.dataclass
class RunOutcome:
    status: str                     # completed | exhausted_restarts | failed
    restarts: int
    final_workers: int
    generations: list[GenRecord]
    error: str | None = None

    @property
    def ok(self) -> bool:
        return self.status == "completed"


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("localhost", 0))
        return s.getsockname()[1]


def _kill_group(procs, grace_s: float = 5.0):
    for p in procs:
        if p.poll() is None:
            p.terminate()
    deadline = time.monotonic() + grace_s
    for p in procs:
        while p.poll() is None and time.monotonic() < deadline:
            time.sleep(0.05)
        if p.poll() is None:
            p.kill()
            p.wait()


def supervise(make_cmd, workers: int, policy: RestartPolicy, run_dir: str,
              *, env: dict | None = None, poll_s: float = 0.2,
              verbose: bool = True) -> RunOutcome:
    """Generic supervisor loop, decoupled from jax so tests can drive it
    with toy workers.

    make_cmd(gen, host_id, num_hosts, port, hb_path) -> argv for one
    worker.  host_id here is the *dense rank within the generation*; the
    worker itself decides what to do with it (the training worker derives
    its batch slice from it).  Heartbeats land in
    <run_dir>/gen<g>/hb_<rank>.json, worker output in
    <run_dir>/gen<g>/worker_<rank>.log.
    """
    os.makedirs(run_dir, exist_ok=True)
    outcome = RunOutcome("failed", 0, workers, [])
    gen = 0
    while True:
        if workers < policy.min_workers:
            outcome.status = "failed"
            outcome.error = (f"{workers} worker(s) left, below "
                             f"min_workers={policy.min_workers}")
            return outcome
        gen_dir = os.path.join(run_dir, f"gen{gen}")
        os.makedirs(gen_dir, exist_ok=True)
        port = _free_port()
        hb_paths = [os.path.join(gen_dir, f"hb_{r}.json")
                    for r in range(workers)]
        rec = GenRecord(gen, workers, time.time())
        outcome.generations.append(rec)
        outcome.final_workers = workers
        procs, logs = [], []
        for r in range(workers):
            log = open(os.path.join(gen_dir, f"worker_{r}.log"), "wb")
            logs.append(log)
            procs.append(subprocess.Popen(
                make_cmd(gen, r, workers, port, hb_paths[r]),
                stdout=log, stderr=subprocess.STDOUT, env=env))
        if verbose:
            print(f"[supervisor] gen {gen}: {workers} worker(s), "
                  f"port {port}", flush=True)

        failure, culprits = _monitor(procs, hb_paths, policy, poll_s, rec)
        _kill_group(procs)
        rec.ended_t = time.time()
        rec.failure = failure
        rec.culprits = tuple(culprits)
        for log in logs:
            log.close()

        if failure is None:
            outcome.status = "completed"
            return outcome
        if verbose:
            print(f"[supervisor] gen {gen} failed: {failure} "
                  f"(culprit ranks {sorted(culprits)}); "
                  f"last step {rec.last_step}", flush=True)
        outcome.restarts += 1
        if outcome.restarts > policy.max_restarts:
            outcome.status = "exhausted_restarts"
            outcome.error = f"gave up after {policy.max_restarts} restarts"
            return outcome
        # shrink only for failures attributable to specific workers; a
        # collateral-only generation (everyone exited 75 — e.g. the
        # coordinator hiccuped) restarts at the same size
        if failure in ("crash", "straggler", "startup_timeout"):
            workers -= len(culprits)
        backoff = min(policy.backoff_s * 2 ** (outcome.restarts - 1),
                      policy.backoff_max_s)
        time.sleep(backoff)
        gen += 1


def _monitor(procs, hb_paths, policy: RestartPolicy, poll_s: float,
             rec: GenRecord):
    """Watch one generation.  Returns (failure, culprit_ranks);
    failure None means every worker exited 0."""
    n = len(procs)
    start = time.monotonic()
    while True:
        time.sleep(poll_s)
        now = time.time()
        beats = [read_heartbeat(p) for p in hb_paths]
        steps = [b["step"] for b in beats if b]
        if steps:
            rec.first_step = (min(steps) if rec.first_step is None
                              else min(rec.first_step, min(steps)))
            rec.last_step = (max(steps) if rec.last_step is None
                             else max(rec.last_step, max(steps)))

        rcs = [p.poll() for p in procs]
        crashed = [r for r, rc in enumerate(rcs)
                   if rc is not None and rc < 0]
        if crashed:
            return "crash", crashed
        errored = [r for r, rc in enumerate(rcs)
                   if rc is not None and rc not in (0, COLLATERAL_RC)]
        if errored:
            # deterministic worker bug: removing it won't help, restart
            # same-size and let max_restarts bound the loop
            return "error", errored
        if all(rc is not None for rc in rcs):
            if all(rc == 0 for rc in rcs):
                return None, []
            return "collateral", []     # only rc==75 deaths: peer fallout

        # liveness: startup deadline before the first beat, straggler
        # deadline after.  A straggler stalls its peers inside the
        # exchange collective, so *all* heartbeats go stale — the
        # culprit is the worker stuck at the earliest (step, phase):
        # everyone else already advanced to the sync phase and is merely
        # blocked waiting for it.
        alive = [r for r, rc in enumerate(rcs) if rc is None]
        hung = [r for r in alive if beats[r] is None
                and time.monotonic() - start > policy.startup_timeout_s]
        if hung:
            return "startup_timeout", hung
        if policy.step_timeout_s:
            stale = [r for r in alive if beats[r]
                     and beats[r]["phase"] != "done"
                     and now - beats[r]["t"] > policy.step_timeout_s]
            if stale:
                key = lambda r: (beats[r]["step"],
                                 PHASE_RANK[beats[r]["phase"]])
                worst = min(key(r) for r in stale)
                return "straggler", [r for r in stale if key(r) == worst]


# --------------------------------------------------------------------------
# the training worker group
# --------------------------------------------------------------------------

def _worker_env():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)   # forced host-device counts break gloo init
    env["PYTHONUNBUFFERED"] = "1"
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


def supervise_training(arch: str, steps: int, ckpt_dir: str, run_dir: str, *,
                       workers: int = 1, policy: RestartPolicy | None = None,
                       global_batch: int = 8, seq_len: int = 128,
                       lr: float = 3e-4, seed: int = 0, smoke: bool = False,
                       async_ckpt: bool = False, posit: str = "p16",
                       chaos_kill: str | None = None,
                       chaos_straggle: str | None = None,
                       verbose: bool = True) -> RunOutcome:
    """Supervise an elastic training group (the CLI below and
    launch/train.py both land here).  chaos_kill="host:step" /
    chaos_straggle="host:step:seconds" inject a fault into generation 0
    only — restarted generations run clean, which is what lets the tests
    assert recovery."""
    policy = policy or RestartPolicy()
    env = _worker_env()

    def make_cmd(gen, host_id, num_hosts, port, hb_path):
        cmd = [sys.executable, "-m", "repro.launch.supervisor", "--worker",
               "--arch", arch, "--steps", str(steps),
               "--ckpt-dir", ckpt_dir, "--heartbeat", hb_path,
               "--host-id", str(host_id), "--num-hosts", str(num_hosts),
               "--port", str(port), "--gen", str(gen),
               "--global-batch", str(global_batch),
               "--seq-len", str(seq_len), "--lr", str(lr),
               "--seed", str(seed), "--posit", posit,
               "--ckpt-every", str(policy.ckpt_every),
               "--keep", str(policy.keep)]
        if smoke:
            cmd.append("--smoke")
        if async_ckpt:
            cmd.append("--async-ckpt")
        if gen == 0:
            if chaos_kill:
                cmd += ["--chaos-kill", chaos_kill]
            if chaos_straggle:
                cmd += ["--chaos-straggle", chaos_straggle]
        return cmd

    return supervise(make_cmd, workers, policy, run_dir, env=env,
                     verbose=verbose)


def _parse_chaos(spec: str | None, parts: int):
    if not spec:
        return None
    vals = spec.split(":")
    if len(vals) != parts:
        raise ValueError(f"bad chaos spec {spec!r}")
    return tuple(float(v) if i == 2 else int(v) for i, v in enumerate(vals))


def _resolve_cfg(arch: str, smoke: bool, posit: str):
    if arch == "tiny":    # the chaos-suite workload: seconds per generation
        from repro.models.transformer import ModelConfig
        return ModelConfig("tiny", n_layers=2, d_model=64, n_heads=4,
                           n_kv=2, d_ff=128, vocab=128)
    from repro import configs
    from repro.core.types import P8_2, P16_2
    from repro.quant.policy import PositPolicy
    pol = {"off": PositPolicy(), "p8": PositPolicy(weights=P8_2),
           "p16": PositPolicy(weights=P16_2)}[posit]
    get = configs.get_smoke if smoke else configs.get_config
    return get(arch, policy=pol)


def _worker_main(args):
    """One member of the elastic group (invoked with --worker)."""
    if args.num_hosts > 1:
        import jax
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
        jax.distributed.initialize(
            coordinator_address=f"localhost:{args.port}",
            num_processes=args.num_hosts, process_id=args.host_id)

    from repro.data.pipeline import DataConfig
    from repro.distributed.fault_tolerance import Heartbeat
    from repro.optim.adamw import OptConfig
    from repro.training.elastic import elastic_train_loop

    cfg = _resolve_cfg(args.arch, args.smoke, args.posit)
    opt_cfg = OptConfig(lr_peak=args.lr,
                        warmup_steps=min(100, args.steps // 10 + 1),
                        total_steps=args.steps)
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                          global_batch=args.global_batch, seed=args.seed)
    policy = RestartPolicy(ckpt_every=args.ckpt_every, keep=args.keep)
    hb = Heartbeat(args.heartbeat, args.host_id) if args.heartbeat else None

    kill = _parse_chaos(args.chaos_kill, 2)
    strag = _parse_chaos(args.chaos_straggle, 3)
    kwargs = {}
    if kill and kill[0] == args.host_id:
        kwargs["chaos_kill_at"] = int(kill[1])
    if strag and strag[0] == args.host_id:
        kwargs["chaos_straggle_at"] = int(strag[1])
        kwargs["chaos_straggle_s"] = strag[2]

    try:
        elastic_train_loop(cfg, opt_cfg, data_cfg, args.steps,
                           ckpt_dir=args.ckpt_dir, policy=policy,
                           host_id=args.host_id, num_hosts=args.num_hosts,
                           heartbeat=hb, async_ckpt=args.async_ckpt,
                           seed=args.seed, **kwargs)
    except Exception as e:
        # in a multi-host group, an exchange/collective error here is very
        # likely fallout from a dead peer — exit COLLATERAL_RC so the
        # supervisor restarts without shrinking this worker away
        print(f"[worker {args.host_id}] {type(e).__name__}: {e}",
              file=sys.stderr, flush=True)
        sys.exit(COLLATERAL_RC if args.num_hosts > 1 else 1)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--worker", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--run-dir", default=None,
                    help="heartbeats + worker logs (default <ckpt>/run)")
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--posit", choices=["off", "p8", "p16"], default="p16")
    ap.add_argument("--async-ckpt", action="store_true")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--max-restarts", type=int, default=10)
    ap.add_argument("--min-workers", type=int, default=1)
    ap.add_argument("--step-timeout", type=float, default=None)
    ap.add_argument("--startup-timeout", type=float, default=300.0)
    ap.add_argument("--chaos-kill", default=None, metavar="HOST:STEP")
    ap.add_argument("--chaos-straggle", default=None,
                    metavar="HOST:STEP:SECONDS")
    # worker-only plumbing
    ap.add_argument("--host-id", type=int, default=0)
    ap.add_argument("--num-hosts", type=int, default=1)
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--gen", type=int, default=0)
    ap.add_argument("--heartbeat", default=None)
    args = ap.parse_args(argv)

    if args.worker:
        _worker_main(args)
        return None

    policy = RestartPolicy(ckpt_every=args.ckpt_every, keep=args.keep,
                           max_restarts=args.max_restarts,
                           step_timeout_s=args.step_timeout,
                           min_workers=args.min_workers,
                           startup_timeout_s=args.startup_timeout)
    out = supervise_training(
        args.arch, args.steps, args.ckpt_dir,
        args.run_dir or os.path.join(args.ckpt_dir, "run"),
        workers=args.workers, policy=policy,
        global_batch=args.global_batch, seq_len=args.seq_len, lr=args.lr,
        seed=args.seed, smoke=args.smoke, async_ckpt=args.async_ckpt,
        posit=args.posit, chaos_kill=args.chaos_kill,
        chaos_straggle=args.chaos_straggle)
    print(f"[supervisor] {out.status}: {out.restarts} restart(s), "
          f"{out.final_workers} final worker(s), "
          f"{len(out.generations)} generation(s)"
          + (f" — {out.error}" if out.error else ""), flush=True)
    if not out.ok:
        sys.exit(1)
    return out


if __name__ == "__main__":
    main()
