"""Production training launcher.

On a real TPU pod slice this is executed once per host:

    python -m repro.launch.train --arch smollm-360m --steps 1000 \
        --ckpt-dir gs://.../ckpts --mesh pod --restart-on-failure

On this CPU container it drives the same code path on a 1x1 mesh (used by
examples/ and the integration tests).  The mesh/sharding configuration is
identical to what launch/dryrun.py proves compiles for the production mesh.

Fault tolerance: --restart-on-failure hands the run to the elastic
process-group supervisor (launch/supervisor.py) — the trainer runs in
child processes that are *re-execed* on crash or straggler timeout,
resuming from the newest valid checkpoint (checkpoints every --ckpt-every
steps; the data pipeline is seekable); --workers N spawns an N-process
elastic data-parallel group (jax.distributed over localhost TCP) that
shrinks to the survivors on a worker death; --step-timeout arms the
supervisor's heartbeat straggler watchdog (process-level), or the
in-process fault_tolerance.StepWatchdog on the plain single-process path;
--async-ckpt moves checkpoint writes off the training thread
(checkpoint/async_store.py).

XLA flags for real hardware (latency-hiding overlap of the FSDP gathers —
DESIGN.md §5) are exported here so runs inherit them:
    --xla_tpu_enable_async_collective_fusion=true
    --xla_tpu_enable_latency_hiding_scheduler=true
    --xla_tpu_overlap_compute_collective_tc=true
"""
from __future__ import annotations

import argparse
import os


TPU_XLA_FLAGS = (
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_latency_hiding_scheduler=true "
    "--xla_tpu_overlap_compute_collective_tc=true"
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-sized)")
    ap.add_argument("--posit", choices=["off", "p8", "p16"], default="p16")
    ap.add_argument("--dp", type=int, default=1,
                    help="data-parallel mesh axis (1 = single device)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel mesh axis (attention/MLP stacks)")
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--host-devices", type=int, default=None,
                    help="force N CPU host devices (sets XLA_FLAGS; must "
                         "run before jax initializes)")
    ap.add_argument("--restart-on-failure", action="store_true")
    ap.add_argument("--max-restarts", type=int, default=10)
    ap.add_argument("--step-timeout", type=float, default=None)
    ap.add_argument("--workers", type=int, default=1,
                    help="elastic data-parallel worker processes "
                         "(>1 implies the supervisor path)")
    ap.add_argument("--min-workers", type=int, default=1)
    ap.add_argument("--async-ckpt", action="store_true",
                    help="background checkpoint writes (bounded queue)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.workers > 1 or args.restart_on_failure:
        # elastic supervisor path: the trainer runs in child processes
        # that are re-execed (and the group shrunk) on failure
        if not args.ckpt_dir:
            ap.error("--restart-on-failure/--workers>1 need --ckpt-dir "
                     "(restarts resume from it)")
        from repro.distributed.fault_tolerance import RestartPolicy
        from repro.launch.supervisor import supervise_training
        policy = RestartPolicy(ckpt_every=args.ckpt_every,
                               max_restarts=args.max_restarts,
                               step_timeout_s=args.step_timeout,
                               min_workers=args.min_workers)
        out = supervise_training(
            args.arch, args.steps, args.ckpt_dir,
            os.path.join(args.ckpt_dir, "run"), workers=args.workers,
            policy=policy, global_batch=args.global_batch,
            seq_len=args.seq_len, lr=args.lr, seed=args.seed,
            smoke=args.smoke, async_ckpt=args.async_ckpt, posit=args.posit)
        print(f"[launch] supervisor outcome: {out.status} "
              f"({out.restarts} restart(s), {out.final_workers} final "
              f"worker(s))" + (f" — {out.error}" if out.error else ""))
        raise SystemExit(0 if out.ok else 1)

    if args.host_devices:
        # append (not prepend): XLA applies the *last* duplicate flag, so an
        # inherited force_host_platform_device_count must not win over the
        # explicit request
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={args.host_devices}")

    if os.environ.get("JAX_PLATFORMS", "") not in ("", "cpu"):
        os.environ["XLA_FLAGS"] = (TPU_XLA_FLAGS + " "
                                   + os.environ.get("XLA_FLAGS", ""))

    from repro import configs
    from repro.core.types import P8_2, P16_2
    from repro.data.pipeline import DataConfig
    from repro.distributed.fault_tolerance import RestartPolicy
    from repro.optim.adamw import OptConfig
    from repro.quant.policy import PositPolicy
    from repro.training.trainer import train_loop

    policy = {"off": PositPolicy(),
              "p8": PositPolicy(weights=P8_2),
              "p16": PositPolicy(weights=P16_2)}[args.posit]
    get = configs.get_smoke if args.smoke else configs.get_config
    cfg = get(args.arch, policy=policy)

    opt_cfg = OptConfig(lr_peak=args.lr, warmup_steps=min(100, args.steps // 10 + 1),
                        total_steps=args.steps)
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                          global_batch=args.global_batch)
    rp = RestartPolicy(ckpt_every=args.ckpt_every,
                       step_timeout_s=args.step_timeout)

    mesh = None
    if args.dp > 1 or args.tp > 1:
        # same builder as sharded serving: whatever jax.devices() offers (a
        # TPU slice, or XLA_FLAGS=--xla_force_host_platform_device_count=N)
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh(data=args.dp, model=args.tp)

    train_loop(cfg, opt_cfg, data_cfg, args.steps,
               ckpt_dir=args.ckpt_dir, policy=rp, mesh=mesh,
               accum_steps=args.accum_steps, seed=args.seed,
               async_ckpt=args.async_ckpt)


if __name__ == "__main__":
    main()
