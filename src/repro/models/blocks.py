"""Transformer building blocks — pure functional JAX (params are pytrees).

Conventions:
  * params: nested dicts of jnp arrays; init_* functions build them from a
    PRNG key; apply functions are pure.
  * activations f32 (dry-run/CPU) or bf16 via ModelConfig.dtype; matmuls
    accumulate f32.
  * posit weight policy: when cfg.policy.weights is set, weight matrices go
    through posit_cast_ste (training, QAT semantics) so the forward sees
    exactly the deployed posit values.  Serving uses pre-quantized int
    weights via kernels.pw_matmul.
  * attention is blockwise (flash-style online softmax) in pure jnp —
    O(S) memory, scan-based — so 32k prefill lowers without an S x S buffer;
    the Pallas kernel path replaces it on real TPUs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.array import PositArray
from repro.core.types import PositConfig
from repro.quant.policy import PositPolicy, posit_cast_ste

Params = dict[str, Any]


# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------
def _dense_init(key, shape, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def init_rmsnorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(x, p: Params, eps: float = 1e-6):
    h = x.astype(jnp.float32)
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    h = h * jax.lax.rsqrt(var + eps)
    return (h * p["scale"]).astype(x.dtype)


def init_layernorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layer_norm(x, p: Params, eps: float = 1e-6):
    h = x.astype(jnp.float32)
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.mean((h - mu) ** 2, axis=-1, keepdims=True)
    h = (h - mu) * jax.lax.rsqrt(var + eps)
    return (h * p["scale"] + p["bias"]).astype(x.dtype)


# --------------------------------------------------------------------------
# linear with posit weight policy
# --------------------------------------------------------------------------
def init_linear(key, d_in: int, d_out: int, bias: bool = False) -> Params:
    p = {"w": _dense_init(key, (d_in, d_out))}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def linear(x, p: Params, policy: PositPolicy | None = None):
    w = p["w"]
    if isinstance(w, PositArray):
        # serving path: pre-quantized posit weights carry their own format;
        # decode is fused in the kernel
        from repro.kernels import ops as kops
        y = kops.pw_matmul(x, w).astype(x.dtype)
    elif w.dtype in (jnp.int8, jnp.int16):
        # deprecated shim: raw posit bits, format threaded via the policy
        from repro.kernels import ops as kops
        assert policy is not None and policy.weights is not None, (
            "int posit weights require policy.weights")
        y = kops.pw_matmul(x, w, policy.weights).astype(x.dtype)
    else:
        if policy is not None and policy.weights is not None:
            w = posit_cast_ste(w, policy.weights)
        from repro.kernels import ops as kops
        if kops.use_pallas() and not kops.force_reference():
            # training / float-weight kernel path: same posit_gemm kernel,
            # differentiable end to end (gemm's custom_vjp runs the dX/dW
            # kernels), so QAT training engages the MXU pipeline too
            lead = x.shape[:-1]
            y = kops.gemm(x.reshape(-1, x.shape[-1]), w)
            y = y.reshape(*lead, w.shape[-1]).astype(x.dtype)
        else:
            y = jnp.einsum("...i,io->...o", x, w,
                           preferred_element_type=jnp.float32).astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# --------------------------------------------------------------------------
# stateful single-step serving helpers (recurrent blocks; serving/backends)
# --------------------------------------------------------------------------
def rt_values(x, pcfg):
    """Posit round-trip decode(encode(x)) — identity when pcfg is None.

    The serving-side state quantization rule: every value that crosses a
    step boundary (carried state, token shifts, conv tails) is *used* at
    its round-tripped value, so the computation is independent of where
    prefill chunks split the sequence and of whether the state was stored
    as raw floats (dense cache tuples) or posit bits (the state pool) —
    both decode to the same values.  Round-tripping is idempotent, so
    applying it at use as well as at store costs nothing numerically."""
    if pcfg is None:
        return x
    from repro.core.convert import f32_to_posit
    from repro.core.decode import decode_to_f32
    return decode_to_f32(f32_to_posit(x.astype(jnp.float32), pcfg), pcfg)


def select_last(x, num_new):
    """x [B, S, ...] -> the last *valid* position per row: x[b, num_new[b]-1]
    (clipped into range; rows with num_new == 0 return position 0, which the
    caller masks).  num_new None means every row is fully valid: x[:, -1]."""
    if num_new is None:
        return x[:, -1]
    idx = jnp.clip(num_new - 1, 0, x.shape[1] - 1)
    idx = idx.reshape((-1,) + (1,) * (x.ndim - 1))
    return jnp.take_along_axis(x, idx, axis=1)[:, 0]


# --------------------------------------------------------------------------
# rotary position embedding
# --------------------------------------------------------------------------
def rope(x, positions, theta: float = 10000.0):
    """x [..., S, D] with D even; positions [..., S] (int)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs        # [..., S, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# blockwise (flash-style) attention in pure jnp
# --------------------------------------------------------------------------
_NEG = -1e30


def blockwise_attention(q, k, v, *, n_kv: int, causal: bool, q_offset=0,
                        window: int | None = None, q_chunk: int = 512,
                        kv_chunk: int = 512, softcap: float | None = None,
                        kv_len=None, cfg_kv=None):
    """GQA-aware flash-style attention, O(chunk^2) memory.

    q [B,H,Sq,D]; k/v [B,KV,Skv,D] with H = KV*G — the group dim is kept
    explicit (no jnp.repeat materialization).  k/v may be `PositArray` (the
    format travels with the pages; `cfg_kv` stays unset) or raw posit
    storage ints with the deprecated explicit `cfg_kv`: each KV chunk is
    decoded to f32 right before its matmul, mirroring the Pallas kernel's
    fused dequant — HBM traffic stays at posit width and no full-cache
    float copy ever exists.

    On the Pallas path, Sq > 1 dispatches to the fused prefill kernel
    (kernels.ops.flash_prefill): the training forward and the dense
    engine's chunked prefill run the same kernel serving prefill uses, with
    this function's jnp scan as the bit-parity reference — and as the
    backward (jax.custom_vjp recomputes the reference VJP, flash-attention
    style, so nothing score-shaped is ever saved).

    q_offset: absolute position of q[0] (decode: cache length; may be traced;
        scalar or per-sequence [B] for the paged engine's ragged batches).
    kv_len: number of valid KV positions (dynamic; default Skv; scalar or
        per-sequence [B]).
    window: sliding-window size (local attention, recurrentgemma).
    """
    from repro.core.array import unwrap_kv
    k, v, cfg_kv = unwrap_kv(k, v, cfg_kv, q=q)
    B, H, Sq, D = q.shape
    Skv = k.shape[2]
    if kv_len is None:
        kv_len = Skv
    # normalize to a [B]-or-[1] vector: per-sequence lengths/offsets (paged
    # continuous batching) and scalars share one code path; broadcasting a
    # [1]-vector is bit-identical to the old scalar math
    kv_len = jnp.asarray(kv_len)
    kv_len = kv_len[None] if kv_len.ndim == 0 else kv_len
    q_off = jnp.asarray(q_offset)
    q_off = q_off[None] if q_off.ndim == 0 else q_off

    from repro.kernels import ops as kops
    if Sq > 1 and kops.use_pallas() and not kops.force_reference():
        static = (cfg_kv, n_kv, causal, window, softcap)
        qo = jnp.broadcast_to(q_off.astype(jnp.int32), (B,))
        kl = jnp.broadcast_to(kv_len.astype(jnp.int32), (B,))
        return _fused_prefill(static, q, k, v, kl, qo).astype(q.dtype)
    return _blockwise_jnp(q, k, v, n_kv=n_kv, causal=causal, q_off=q_off,
                          window=window, q_chunk=q_chunk, kv_chunk=kv_chunk,
                          softcap=softcap, kv_len=kv_len, cfg_kv=cfg_kv)


def _blockwise_jnp(q, k, v, *, n_kv: int, causal: bool, q_off, window,
                   q_chunk: int, kv_chunk: int, softcap, kv_len, cfg_kv):
    """The pure-jnp scan (k/v raw, q_off/kv_len already [B]-or-[1]): the
    reference/oracle body and the non-Pallas execution path."""
    B, H, Sq, D = q.shape
    KV = n_kv
    G = H // KV
    Skv = k.shape[2]
    scale = D ** -0.5

    if Sq == 1:
        # decode fast path (flash-decoding layout): no scan — S-contraction
        # einsums let GSPMD keep the KV cache fully sharded on its sequence
        # dim; the only cross-device traffic is the softmax stats and the
        # (B,H,1,D) output psum (§Perf iteration B2)
        def _dec1(t):
            if cfg_kv is not None:
                from repro.core.decode import decode_to_f32
                return decode_to_f32(t, cfg_kv)
            return t.astype(jnp.float32)

        from repro.distributed.sharding import shard_activation
        kf, vf = _dec1(k), _dec1(v)
        if G > 1:
            kf = jnp.repeat(kf, G, axis=1)
            vf = jnp.repeat(vf, G, axis=1)
        # pin the flash-decoding layout: tiny q replicated over the TP axis,
        # KV stays sequence-sharded -> only stats/output psums cross chips
        kf = shard_activation(kf, "kv_seq")
        vf = shard_activation(vf, "kv_seq")
        q = shard_activation(q, "batch_only")
        s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf,
                       preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = jnp.tanh(s / softcap) * softcap
        kpos = jnp.arange(Skv)
        valid = kpos[None, :] < kv_len[:, None]
        if window is not None:
            valid = valid & (kpos[None, :] > kv_len[:, None] - 1 - window)
        s = jnp.where(valid[:, None, None, :], s, _NEG)
        m = s.max(axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        out = jnp.einsum("bhqk,bhkd->bhqd", p, vf,
                         preferred_element_type=jnp.float32)
        out = out / p.sum(axis=-1, keepdims=True)
        return out.astype(q.dtype)

    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, Skv)
    pq = (-Sq) % qc
    pk = (-Skv) % kc
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0))) if pk else v
    nq, nk = (Sq + pq) // qc, (Skv + pk) // kc

    kb = kp.reshape(B, KV, nk, kc, D).transpose(2, 0, 1, 3, 4)
    vb = vp.reshape(B, KV, nk, kc, D).transpose(2, 0, 1, 3, 4)
    qb = qp.reshape(B, H, nq, qc, D).transpose(2, 0, 1, 3, 4)

    def _dec(t):
        if cfg_kv is not None:
            from repro.core.decode import decode_to_f32
            return decode_to_f32(t, cfg_kv)
        return t.astype(jnp.float32)

    def q_block(qi, q_tile):                     # q_tile [B,H,qc,D]
        qpos = q_off[:, None] + qi * qc + jnp.arange(qc)[None, :]  # [B|1, qc]

        def kv_step(carry, inputs):
            m, l, acc = carry
            ki, k_tile, v_tile = inputs          # [B,KV,kc,D] (posit/float)
            # per-chunk decode + GQA head expansion: transient, chunk-sized —
            # the q-side head sharding propagates through the einsum while
            # the kv source stays narrow in HBM
            k_tile = _dec(k_tile)
            v_tile = _dec(v_tile)
            if G > 1:
                k_tile = jnp.repeat(k_tile, G, axis=1)
                v_tile = jnp.repeat(v_tile, G, axis=1)
            kpos = ki * kc + jnp.arange(kc)
            s = jnp.einsum("bhqd,bhkd->bhqk",
                           q_tile.astype(jnp.float32), k_tile,
                           preferred_element_type=jnp.float32) * scale
            if softcap is not None:
                s = jnp.tanh(s / softcap) * softcap
            valid = kpos[None, None, :] < kv_len[:, None, None]  # [B|1,1,kc]
            if causal:
                valid = valid & (qpos[:, :, None] >= kpos[None, None, :])
            if window is not None:
                valid = valid & (qpos[:, :, None] - kpos[None, None, :]
                                 < window)
            s = jnp.where(valid[:, None], s, _NEG)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p, v_tile,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, qc), _NEG, jnp.float32)
        l0 = jnp.zeros((B, H, qc), jnp.float32)
        a0 = jnp.zeros((B, H, qc, D), jnp.float32)
        # remat each kv step: score/prob blocks are recomputed in the backward
        # (flash-attention memory behaviour), never saved per block pair
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step,
                           policy=jax.checkpoint_policies.nothing_saveable),
            (m0, l0, a0), (jnp.arange(nk), kb, vb))
        return acc / jnp.where(l == 0, 1.0, l)[..., None]

    # checkpoint per q-block: lax.map saves only block inputs; one block's
    # kv-scan carry chain is live at a time in the backward
    outs = jax.lax.map(
        jax.checkpoint(lambda args: q_block(*args),
                       policy=jax.checkpoint_policies.nothing_saveable),
        (jnp.arange(nq), qb))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(B, H, Sq + pq, D)[:, :, :Sq]
    return out.astype(q.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _fused_prefill(static, q, k, v, kv_len, q_off):
    """Fused prefill forward with a kernel (or counted-oracle) VJP.

    static = (cfg_kv, n_kv, causal, window, softcap) — hashable, so one
    custom_vjp covers every arch.  The forward runs the Pallas kernel
    (posit KV decodes in VMEM, no dense copy); when differentiated it also
    saves (o, lse) so the backward can rebuild the scores tile by tile —
    `kernels.ops.flash_prefill_bwd` dispatches the flash dQ/dK/dV kernels,
    falling back to differentiating `_blockwise_jnp` (counted in
    `ops.BWD_FALLBACKS`) off the kernel path.  Integer operands (posit KV
    bits, lengths/offsets) carry no tangents and get None cotangents.
    """
    cfg_kv, n_kv, causal, window, softcap = static
    from repro.kernels import ops as kops
    return kops.flash_prefill(q, k, v, kv_len, q_off, cfg_kv=cfg_kv,
                              causal=causal, window=window, softcap=softcap)


def _fused_prefill_fwd(static, q, k, v, kv_len, q_off):
    cfg_kv, n_kv, causal, window, softcap = static
    from repro.kernels import ops as kops
    out, lse = kops.flash_prefill(q, k, v, kv_len, q_off, cfg_kv=cfg_kv,
                                  causal=causal, window=window,
                                  softcap=softcap, return_lse=True)
    return out, (q, k, v, out, lse, kv_len, q_off)


def _fused_prefill_bwd(static, res, g):
    cfg_kv, n_kv, causal, window, softcap = static
    q, k, v, o, lse, kv_len, q_off = res
    from repro.kernels import ops as kops
    dq, dk, dv = kops.flash_prefill_bwd(
        q, k, v, o, lse, g, kv_len, q_off, n_kv=n_kv, cfg_kv=cfg_kv,
        causal=causal, window=window, softcap=softcap)
    if jnp.issubdtype(k.dtype, jnp.floating):
        return dq, dk, dv, None, None
    # posit KV (serving): bits are integers, only q carries a tangent
    return dq, None, None, None, None


_fused_prefill.defvjp(_fused_prefill_fwd, _fused_prefill_bwd)


# --------------------------------------------------------------------------
# GQA attention block
# --------------------------------------------------------------------------
def init_attention(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
                   qkv_bias: bool = False) -> Params:
    ks = jax.random.split(key, 4)
    return {
        "wq": init_linear(ks[0], d_model, n_heads * head_dim, qkv_bias),
        "wk": init_linear(ks[1], d_model, n_kv * head_dim, qkv_bias),
        "wv": init_linear(ks[2], d_model, n_kv * head_dim, qkv_bias),
        "wo": init_linear(ks[3], n_heads * head_dim, d_model, False),
    }


def attention_block(x, p: Params, *, n_heads: int, n_kv: int, head_dim: int,
                    positions, policy: PositPolicy, causal: bool = True,
                    window: int | None = None, rope_theta: float = 10000.0,
                    kv_cache=None, softcap: float | None = None):
    """Returns (out, new_kv_cache).  kv_cache: dict(k, v, length) or None.

    k/v cache tensors are [B, n_kv, S_max, head_dim]; PositArray pages when
    the cache was initialized with a posit format (decoded for compute here,
    fused in the Pallas kernel on TPU) — the format rides with the pages, so
    nothing here re-states it.
    """
    from repro.distributed.collectives import (block_grad_sync, block_psum,
                                               tp_ctx)
    ctx = tp_ctx()
    if ctx is not None:
        # Megatron TP (sharded serving or training step): wq/wk/wv are
        # column-parallel, so this member computes its n_heads/ntp heads
        # (and n_kv/ntp kv heads, whose pages live on the same member); wo
        # is row-parallel and owes the block's one psum below.  The
        # f-operator makes the block's d(input) whole again when training
        # differentiates through the weight shards (identity forward).
        n_heads //= ctx.size
        n_kv //= ctx.size
        x = block_grad_sync(x)
    B, S, _ = x.shape
    q = linear(x, p["wq"], policy).reshape(B, S, n_heads, head_dim)
    k = linear(x, p["wk"], policy).reshape(B, S, n_kv, head_dim)
    v = linear(x, p["wv"], policy).reshape(B, S, n_kv, head_dim)

    q = rope(q.transpose(0, 2, 1, 3), positions[:, None, :], rope_theta)
    k = rope(k.transpose(0, 2, 1, 3), positions[:, None, :], rope_theta)
    v = v.transpose(0, 2, 1, 3)

    new_cache = None
    kv_len = None
    legacy_cfg = None
    if kv_cache is not None and "page_table" in kv_cache:
        # paged pool (continuous batching): scatter-append the new tokens
        # into this layer's pages, then attend through the paged path —
        # fused Pallas paged-gather decode on TPU, gather+blockwise on CPU
        from repro.serving.paged_kv import paged_append_kv, paged_attention
        q_offset = kv_cache["seq_lens"]             # per-sequence, traced
        new_cache = paged_append_kv(kv_cache, k, v)
        out = paged_attention(q, new_cache, n_kv=n_kv, causal=causal,
                              q_offset=q_offset, window=window,
                              softcap=softcap)
        out = out.transpose(0, 2, 1, 3).reshape(B, S, n_heads * head_dim)
        return block_psum(linear(out, p["wo"], policy)), new_cache
    if kv_cache is not None:
        from repro.serving.kv_cache import append_kv
        q_offset = kv_cache["length"]               # traced scalar
        # legacy raw-int posit caches (pre-PositArray convention) still need
        # the policy-threaded format; PositArray pages carry their own
        if (not isinstance(kv_cache["k"], PositArray)
                and jnp.issubdtype(kv_cache["k"].dtype, jnp.integer)):
            legacy_cfg = policy.kv_cache
        new_cache = append_kv(kv_cache, k, v, legacy_cfg)
        # pass the buffers as-is (PositArray pages stay posit): chunks
        # decode in-scan, with the format read off the pages themselves
        k, v = new_cache["k"], new_cache["v"]
        kv_len = new_cache["length"]
    else:
        q_offset = k.shape[2] - S

    out = blockwise_attention(q, k, v, n_kv=n_kv, causal=causal,
                              q_offset=q_offset, window=window,
                              softcap=softcap, kv_len=kv_len,
                              cfg_kv=legacy_cfg)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, n_heads * head_dim)
    return block_psum(linear(out, p["wo"], policy)), new_cache


# --------------------------------------------------------------------------
# MLP (dense) block
# --------------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, act: str) -> Params:
    ks = jax.random.split(key, 3)
    p = {"w_up": init_linear(ks[0], d_model, d_ff),
         "w_down": init_linear(ks[1], d_ff, d_model)}
    if act in ("geglu", "swiglu"):
        p["w_gate"] = init_linear(ks[2], d_model, d_ff)
    return p


def mlp_block(x, p: Params, *, act: str, policy: PositPolicy):
    # f-operator (identity fwd / TP-psum bwd): w_up/w_gate shards each see
    # only their d_ff slice, so d(x) comes back partial per member
    from repro.distributed.collectives import block_grad_sync
    x = block_grad_sync(x)
    up = linear(x, p["w_up"], policy)
    if act == "geglu":
        h = jax.nn.gelu(linear(x, p["w_gate"], policy)) * up
    elif act == "swiglu":
        h = jax.nn.silu(linear(x, p["w_gate"], policy)) * up
    elif act == "gelu":
        h = jax.nn.gelu(up)
    elif act == "relu":
        h = jax.nn.relu(up)
    else:
        raise ValueError(act)
    # under TP (sharded serving) w_up/w_gate are column-parallel over d_ff
    # and w_down row-parallel: the partial product owes the block's one psum
    from repro.distributed.collectives import block_psum
    return block_psum(linear(h, p["w_down"], policy))


# --------------------------------------------------------------------------
# embedding with posit storage option
# --------------------------------------------------------------------------
def init_embedding(key, vocab: int, d_model: int) -> Params:
    return {"table": jax.random.normal(key, (vocab, d_model),
                                       dtype=jnp.float32) * (d_model ** -0.5)}


def embed(tokens, p: Params, policy: PositPolicy):
    t = p["table"]
    from repro.distributed.collectives import tp_ctx
    ctx = tp_ctx()
    if ctx is not None and ctx.vocab_sharded:
        # Megatron vocab-parallel embedding: this member holds rows
        # [off, off + v_local); out-of-range tokens gather a masked zero row
        # and the psum assembles each embedding from exactly one nonzero
        # member — 0 + x is exact, so logits stay bit-identical to the
        # unsharded lookup.
        v_local = t.shape[0]
        local = tokens - jax.lax.axis_index(ctx.axis) * v_local
        ok = (local >= 0) & (local < v_local)
        idx = jnp.clip(local, 0, v_local - 1)
        if isinstance(t, PositArray):
            rows = t[idx].to_f32()
        elif t.dtype in (jnp.int8, jnp.int16):
            from repro.core.decode import decode_to_f32
            rows = decode_to_f32(jnp.take(t, idx, axis=0), policy.weights)
        else:
            if policy is not None and policy.weights is not None:
                t = posit_cast_ste(t, policy.weights)
            rows = jnp.take(t, idx, axis=0)
        rows = jnp.where(ok[..., None], rows, 0.0)
        return jax.lax.psum(rows, ctx.axis)
    if isinstance(t, PositArray):
        # Light-PPU use case [9]: posit storage of tables, decode after
        # gather — the table knows its own format
        return t[tokens].to_f32()
    if t.dtype in (jnp.int8, jnp.int16):
        # deprecated shim: raw posit bits + policy-threaded format
        from repro.core.decode import decode_to_f32
        rows = jnp.take(t, tokens, axis=0)
        return decode_to_f32(rows, policy.weights)
    if policy is not None and policy.weights is not None:
        t = posit_cast_ste(t, policy.weights)
    return jnp.take(t, tokens, axis=0)


def unembed(h, p: Params, policy: PositPolicy):
    """h [..., d] @ tied-table [V, d].T -> logits [..., V].

    Posit tables route through pw_gemm with transpose_b: the [V, d] table —
    the decode step's largest single tensor — streams at posit width and
    decodes tile-by-tile in VMEM, instead of materializing the full f32
    table every step.  Under vocab-parallel TP the local [V/ntp, d] shard
    takes the same path.  The jnp reference contracts the identical
    dot_general dims, so logits stay bit-identical across backends.
    """
    t = p["table"]
    if isinstance(t, PositArray) or jnp.issubdtype(t.dtype, jnp.integer):
        from repro.kernels import ops as kops
        cfg = None if isinstance(t, PositArray) else policy.weights
        return kops.pw_matmul(h, t, cfg, transpose_b=True)
    if policy is not None and policy.weights is not None:
        t = posit_cast_ste(t, policy.weights)
    from repro.kernels import ops as kops
    if kops.use_pallas() and not kops.force_reference():
        # float/QAT table on the kernel path: same transpose_b stream, and
        # gemm's custom_vjp gives the dH/dTable kernels for training
        lead = h.shape[:-1]
        out = kops.gemm(h.reshape(-1, h.shape[-1]), t, transpose_b=True)
        return out.reshape(*lead, t.shape[0])
    return jnp.einsum("...d,vd->...v", h, t,
                      preferred_element_type=jnp.float32)
