"""Griffin / RecurrentGemma blocks (arXiv:2402.19427): RG-LRU gated linear
recurrence + temporal conv, interleaved 1:2 with local sliding-window
attention.

The RG-LRU recurrence is per-channel (diagonal), so it maps exactly onto
jax.lax.associative_scan — O(log T) depth, O(T d) memory, no custom kernel
needed (the TPU-native form of the paper's GPU linear-scan kernel).  The
O(1) recurrent state + windowed attention is what lets recurrentgemma-9b
run the long_500k decode shape.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.blocks import init_linear, linear
from repro.quant.policy import PositPolicy

Params = dict[str, Any]

CONV_WIDTH = 4
LRU_C = 8.0


def init_rglru_block(key, d_model: int, d_rnn: int | None = None) -> Params:
    d_rnn = d_rnn or d_model
    ks = jax.random.split(key, 7)
    return {
        "w_x": init_linear(ks[0], d_model, d_rnn),
        "w_gate_branch": init_linear(ks[1], d_model, d_rnn),
        "conv_w": jax.random.normal(ks[2], (CONV_WIDTH, d_rnn),
                                    dtype=jnp.float32) * 0.1,
        "conv_b": jnp.zeros((d_rnn,), jnp.float32),
        "w_input_gate": init_linear(ks[3], d_rnn, d_rnn),
        "w_rec_gate": init_linear(ks[4], d_rnn, d_rnn),
        # Lambda init so a = sigmoid(lam)^c spreads over (0.9, 0.999)
        "lam": jnp.linspace(2.0, 6.0, d_rnn).astype(jnp.float32),
        "w_out": init_linear(ks[5], d_rnn, d_model),
    }


def _causal_conv1d(x, w, b, state=None):
    """x [B,S,d], w [K,d] depthwise causal conv.  state: last K-1 inputs."""
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K)) + b
    return out.astype(x.dtype), xp[:, -(K - 1):]


def rglru(x, gates_in, p: Params, h0=None, policy=None):
    """RG-LRU: h_t = a_t h_{t-1} + sqrt(1-a_t^2) (i_t o x_t).

    a_t = exp(c * log(sigmoid(lam)) * r_t), r_t = sigmoid(W_r g),
    i_t = sigmoid(W_i g).  x, gates_in: [B,S,d].
    """
    r = jax.nn.sigmoid(linear(gates_in, p["w_rec_gate"], policy))
    i = jax.nn.sigmoid(linear(gates_in, p["w_input_gate"], policy))
    log_a = LRU_C * r.astype(jnp.float32) * jax.nn.log_sigmoid(p["lam"])
    a = jnp.exp(log_a)
    gated = (i * x).astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated

    if x.shape[1] == 1 and h0 is not None:     # decode fast path
        h = a[:, 0] * h0 + b[:, 0]
        return h[:, None].astype(x.dtype), h

    if h0 is not None:
        # fold initial state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_block(x, p: Params, *, policy: PositPolicy, state=None):
    """Full recurrent block: (linear -> conv -> RG-LRU) * gelu(linear) -> out.

    state: (h [B,d], conv_state [B,K-1,d]) or None.
    Returns (out, new_state).
    """
    h0, conv_state = state if state is not None else (None, None)
    branch = linear(x, p["w_x"], policy)
    branch, new_conv = _causal_conv1d(branch, p["conv_w"], p["conv_b"],
                                      conv_state)
    rec, h_last = rglru(branch, branch, p, h0, policy=policy)
    gate = jax.nn.gelu(linear(x, p["w_gate_branch"], policy))
    out = linear(rec * gate, p["w_out"], policy)
    return out, (h_last, new_conv)


def rglru_block_serving(x, p: Params, *, policy: PositPolicy, state,
                        num_new=None):
    """Stateful serving-path recurrent block: same projections/gates as
    rglru_block, but the diagonal recurrence runs through the kernels.ops
    recurrent-scan dispatch (Pallas fused kernel on TPU, counted jnp oracle
    elsewhere) with the hidden state posit-round-tripped after every token
    under policy.kv_cache.

    state = (h0 [B,d], conv_state [B,K-1,d]): f32 arrays (dense cache
    tuples) or PositArray pool slots (the paged engine's state pool) — h0
    is returned in the same representation; the conv tail comes back as raw
    f32 values of the last K-1 valid inputs (callers re-encode for the pool
    via backends.store_state).  num_new [B] masks ragged chunks; every
    cross-token value is used round-tripped (blocks.rt_values), so the scan
    is invariant to prefill chunking.
    """
    from repro.kernels import ops as kops
    from repro.models.blocks import rt_values
    from repro.serving.backends import state_f32
    h0, conv_state = state
    pcfg = policy.kv_cache
    S = x.shape[1]
    K = p["conv_w"].shape[0]
    branch = linear(x, p["w_x"], policy)
    xp = rt_values(jnp.concatenate(
        [state_f32(conv_state).astype(branch.dtype), branch],
        axis=1), pcfg).astype(branch.dtype)
    conv = sum(xp[:, i:i + S] * p["conv_w"][i] for i in range(K)) + p["conv_b"]
    conv = conv.astype(x.dtype)

    # gates read the conv output (rglru_block's rglru(branch, branch)); a/b
    # are batched projections — only the h recurrence itself is sequential
    r = jax.nn.sigmoid(linear(conv, p["w_rec_gate"], policy))
    i = jax.nn.sigmoid(linear(conv, p["w_input_gate"], policy))
    log_a = LRU_C * r.astype(jnp.float32) * jax.nn.log_sigmoid(p["lam"])
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i * conv).astype(jnp.float32)

    h_seq, h_fin = kops.rglru_scan(a, b, h0, num_new=num_new, cfg_state=pcfg)
    rec = h_seq.astype(x.dtype)
    gate = jax.nn.gelu(linear(x, p["w_gate_branch"], policy))
    out = linear(rec * gate, p["w_out"], policy)

    if num_new is None:
        new_conv = xp[:, -(K - 1):]
    else:
        # row b's last K-1 valid conv inputs sit at xp[b, nn : nn+K-1]
        # (valid branch tokens occupy xp[b, K-1 : K-1+nn])
        idx = num_new[:, None] + jnp.arange(K - 1)[None, :]
        new_conv = jnp.take_along_axis(xp, idx[:, :, None], axis=1)
    return out, (h_fin, new_conv.astype(jnp.float32))
