"""Mixture-of-Experts block: sort-based routing + grouped posit GEMM on the
Pallas path, with the GShard one-hot capacity dispatch as the jnp oracle.

The GShard formulation (dispatch/combine one-hots, per-expert capacity
slots) moves O(G*Tg*E*C) dense one-hot traffic per layer and — worse for
serving — materializes the **full** [E, d_model, d_ff] expert tensors as
f32 every step even though only top_k of E experts are active (for
qwen3-moe-235b-a22b that is all 128 experts' weights decoded for a top-8
step).  Serving steps on the Pallas path now route by sorting instead:
each token's (token, k) pairs are argsorted by expert id, per-expert
segment offsets feed `kernels.ops.grouped_matmul`
(kernels/grouped_gemm.py), and the
grouped kernel streams only the active experts' posit-packed weight tiles
into VMEM, decoding them in front of the MXU with one f32 accumulator per
group (the PERCIVAL-style quire analogue).  Ragged expert groups are
native, so the capacity zero-padding slots of the one-hot dispatch
disappear; tokens scatter back with their combine weights instead of a
[G,Tg,E,C] comb einsum.

Routing semantics are identical on both paths (and replicated under
expert-parallel TP): top-k over the router softmax, per-dispatch-group
arrival-order capacity positions, overflow drops, and combine weights
renormalized over the *kept* experts only — a token whose sibling expert
overflowed redistributes its mix instead of keeping a stale under-weighted
sum.  The one-hot implementation survives as the CPU/interpret oracle and
the benchmark baseline.  Training uses the grouped path too: the grouped
custom_vjp supplies dX/dW Pallas kernels, and the shard_map train step
(training/train_step.py) makes partitioning manual, so the old GSPMD
carve-out (one-hot einsums for training) is gone.  DENSE_MOE_FALLBACKS
counts every dense dispatch plus the one-hot path's full-expert posit
decodes; tier-1 asserts neither an engine drain nor a kernel-path train
step adds one.

Under a `tensor_parallel` context (the mesh-sharded serving step) experts
are split over the model axis: routing is computed globally on every
shard, non-local (token, k) pairs drop their combine weight to zero, the
grouped GEMM runs over the shard-local expert slice, and the block's one
`collectives.block_psum` assembles the full mixture.

Used by olmoe-1b-7b (64e top-8) and qwen3-moe-235b-a22b (128e top-8).
Expert tables are the biggest posit-storage win (DESIGN.md §4).
"""
from __future__ import annotations

import collections
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.blocks import _dense_init
from repro.quant.policy import PositPolicy, posit_cast_ste

Params = dict[str, Any]

# trace-time executions of the dense one-shot expert path, keyed by reason.
# "expert-decode" entries mean the full [E, d_model, d_ff] posit expert
# tensors were materialized as f32 — the HBM blow-up the grouped kernel
# exists to kill.  On the Pallas path this must stay untouched (tests
# assert an engine drain adds nothing here); the one-hot path survives as
# the CPU/interpret oracle and the FORCE_DENSE benchmark baseline.
DENSE_MOE_FALLBACKS: collections.Counter = collections.Counter()

# in-process switches for the benchmark legs and tests (mirroring
# ops.FORCE_REFERENCE): FORCE_DENSE pins the GShard one-hot oracle even for
# serving steps on the Pallas path; FORCE_GROUPED pins sort-based routing +
# grouped matmul everywhere — including training-shaped calls, which
# normally keep the one-hot path (see moe_block), and the jnp backend,
# where the matmul itself still dispatches kernel-vs-reference via
# use_pallas (on CPU this measures the routing scheme with the dense
# reference matmul behind it).
FORCE_DENSE = False
FORCE_GROUPED = False


def init_moe(key, d_model: int, d_ff: int, n_experts: int, act: str) -> Params:
    ks = jax.random.split(key, 4)
    glu = act in ("geglu", "swiglu")
    p = {
        "router": _dense_init(ks[0], (d_model, n_experts)),
        "w_up": _dense_init(ks[1], (n_experts, d_model, d_ff)),
        "w_down": _dense_init(ks[2], (n_experts, d_ff, d_model), d_ff ** -0.5),
    }
    if glu:
        p["w_gate"] = _dense_init(ks[3], (n_experts, d_model, d_ff))
    return p


def _maybe_decode(w, policy: PositPolicy, count: str | None = None):
    """Full-tensor f32 view of a (possibly posit) weight — the dense path.
    `count` tags posit materializations in DENSE_MOE_FALLBACKS."""
    from repro.core.array import PositArray
    if isinstance(w, PositArray):
        if count is not None:
            DENSE_MOE_FALLBACKS[count] += 1
        return w.to_f32()
    if w.dtype in (jnp.int8, jnp.int16):
        if count is not None:
            DENSE_MOE_FALLBACKS[count] += 1
        from repro.core.decode import decode_to_f32
        return decode_to_f32(w, policy.weights)
    if policy is not None and policy.weights is not None:
        return posit_cast_ste(w, policy.weights)
    return w


def _grouped_weight(w, policy: PositPolicy):
    """(operand, cfg) for grouped_matmul: posit storage passes through at
    storage width (the kernel decodes tiles in VMEM); float weights apply
    the QAT STE round-trip (f32 values — that is training semantics, not a
    serving decode)."""
    from repro.core.array import PositArray
    if isinstance(w, PositArray):
        return w, None
    if w.dtype in (jnp.int8, jnp.int16):
        return w, policy.weights
    if policy is not None and policy.weights is not None:
        return posit_cast_ste(w, policy.weights), None
    return w, None


def _router_logits(xt, router, policy: PositPolicy):
    """Router projection at storage width: posit router tables route
    through kops.pw_matmul (in-kernel decode on the Pallas path) — this was
    the last remaining per-step f32 decode of a posit weight in the block."""
    from repro.core.array import PositArray
    x32 = xt.astype(jnp.float32)
    if isinstance(router, PositArray):
        from repro.kernels import ops as kops
        return kops.pw_matmul(x32, router)
    if router.dtype in (jnp.int8, jnp.int16):
        from repro.kernels import ops as kops
        return kops.pw_matmul(x32, router, policy.weights)
    if policy is not None and policy.weights is not None:
        router = posit_cast_ste(router, policy.weights)
    return jnp.einsum("gtd,de->gte", x32, router)


def _route(xt, p: Params, *, n_experts: int, top_k: int, cap: int,
           policy: PositPolicy):
    """Shared routing math: (probs, gate_idx, onehot, pos, keep, comb_w).

    Identical for the grouped and one-hot paths (and replicated across
    expert-parallel shards, so drop decisions agree everywhere): top-k,
    per-group arrival-order capacity position, and combine weights
    renormalized over the kept experts only — normalizing before the drop
    left overflow victims with a stale under-weighted mix (the pinned
    forced-drop regression in tests/test_moe_grouped.py).
    """
    G, gs, _ = xt.shape
    logits = _router_logits(xt, p["router"], policy)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)          # [G,Tg,k]

    onehot = jax.nn.one_hot(gate_idx, n_experts, dtype=jnp.int32)
    if cap >= gs:
        # top-k expert ids are distinct per token, so one expert sees at
        # most gs arrivals per group: cap >= gs means no pair can overflow
        # (the serving setting).  Skip the O(T*k*E) arrival-order cumsum
        # on the decode hot path — XLA cannot prove keep is all-true on
        # its own.  pos stays None; the one-hot oracle recomputes it
        # lazily (it needs slot indices either way).
        pos = None
        keep = jnp.ones(gate_vals.shape, bool)
    else:
        pos = _arrival_positions(onehot)
        keep = pos < cap

    kept = gate_vals * keep
    comb_w = kept / jnp.maximum(kept.sum(axis=-1, keepdims=True), 1e-9)
    return probs, gate_idx, onehot, pos, keep, comb_w


def _arrival_positions(onehot):
    """Per-(token, k) arrival position within its expert's dispatch group
    ([G, Tg, k, E] int one-hot -> [G, Tg, k])."""
    G, gs, top_k, E = onehot.shape
    flat = onehot.reshape(G, gs * top_k, E)
    pos = jnp.cumsum(flat, axis=1) - 1
    return (pos * flat).sum(axis=-1).reshape(G, gs, top_k)


def _ep_ctx(n_experts: int):
    """Expert-parallel view under a tensor_parallel context: (local expert
    count, this shard's first global expert id), or None outside TP."""
    from repro.distributed.collectives import tp_ctx
    ctx = tp_ctx()
    if ctx is None:
        return None
    return n_experts // ctx.size, jax.lax.axis_index(ctx.axis) * (
        n_experts // ctx.size)


def _dispatch_grouped(xt, p: Params, *, n_experts: int, top_k: int, act: str,
                      policy: PositPolicy, gate_idx, comb_w):
    """Sort-based dispatch: argsort (token, k) pairs by expert, grouped
    GEMMs over per-expert segments, weighted scatter-add back to tokens."""
    from repro.kernels import ops as kops
    G, gs, d = xt.shape
    T = G * gs
    S = T * top_k
    x_flat = xt.reshape(T, d).astype(jnp.float32)

    ep = _ep_ctx(n_experts)
    eidx = gate_idx.reshape(S)
    w_flat = comb_w.reshape(S)
    if ep is None:
        E_loc, key = n_experts, eidx
    else:
        E_loc, off = ep
        local = (eidx >= off) & (eidx < off + E_loc)
        # non-local pairs sort past every local segment (sentinel id E_loc);
        # their rows fall outside group_offsets[-1] and come back as zeros
        key = jnp.where(local, eidx - off, E_loc)
        w_flat = w_flat * local

    order = jnp.argsort(key)          # stable: ties keep arrival order
    tok = order // top_k
    x_sorted = jnp.take(x_flat, tok, axis=0)
    counts = jnp.bincount(key, length=E_loc + 1)[:E_loc]
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts).astype(jnp.int32)])

    w_up, cfg_up = _grouped_weight(p["w_up"], policy)
    w_down, cfg_down = _grouped_weight(p["w_down"], policy)
    up = kops.grouped_matmul(x_sorted, w_up, offsets, cfg=cfg_up)
    if act in ("geglu", "swiglu"):
        w_gate, cfg_gate = _grouped_weight(p["w_gate"], policy)
        gate = kops.grouped_matmul(x_sorted, w_gate, offsets, cfg=cfg_gate)
        h = (jax.nn.gelu(gate) if act == "geglu"
             else jax.nn.silu(gate)) * up
    else:
        h = jax.nn.gelu(up)
    ye = kops.grouped_matmul(h, w_down, offsets, cfg=cfg_down)   # [S, d]

    wsort = jnp.take(w_flat, order)
    out = jnp.zeros((T, d), jnp.float32).at[tok].add(ye * wsort[:, None])
    return out.reshape(G, gs, d)


def _dispatch_oneshot(xt, p: Params, *, n_experts: int, top_k: int, act: str,
                      policy: PositPolicy, cap: int, gate_idx, pos, keep,
                      comb_w):
    """GShard one-hot capacity dispatch — the jnp oracle (and FORCE_DENSE
    benchmark baseline).  Decodes the full expert tensors (counted in
    DENSE_MOE_FALLBACKS when they are posit) and pays the O(G*Tg*E*C)
    dispatch/combine einsums the grouped path removes."""
    G, gs, d = xt.shape
    if pos is None:                       # no-overflow routing skipped it
        pos = _arrival_positions(
            jax.nn.one_hot(gate_idx, n_experts, dtype=jnp.int32))
    ep = _ep_ctx(n_experts)
    if ep is None:
        E_loc = n_experts
        gidx = gate_idx
        width = E_loc
    else:
        E_loc, off = ep
        local = (gate_idx >= off) & (gate_idx < off + E_loc)
        gidx = jnp.where(local, gate_idx - off, E_loc)
        comb_w = comb_w * local
        keep = keep & local
        width = E_loc + 1                 # sentinel column, sliced off below

    onehot = jax.nn.one_hot(gidx, width, dtype=xt.dtype)[..., :E_loc]
    slot_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                             dtype=xt.dtype)[..., :cap]            # [G,Tg,k,C]
    disp = jnp.einsum("gtke,gtkc->gtec", onehot, slot_oh)
    comb = jnp.einsum("gtke,gtkc,gtk->gtec", onehot.astype(jnp.float32),
                      slot_oh.astype(jnp.float32), comb_w).astype(xt.dtype)

    xe = jnp.einsum("gtec,gtd->gecd", disp, xt)                    # [G,E,C,d]

    w_up = _maybe_decode(p["w_up"], policy, count="expert-decode")
    w_down = _maybe_decode(p["w_down"], policy, count="expert-decode")
    w_gate = _maybe_decode(p["w_gate"], policy, count="expert-decode") \
        if "w_gate" in p else None

    up = jnp.einsum("gecd,edf->gecf", xe, w_up,
                    preferred_element_type=jnp.float32).astype(xt.dtype)
    if act == "geglu":
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", xe, w_gate,
                                   preferred_element_type=jnp.float32)
                        .astype(xt.dtype)) * up
    elif act == "swiglu":
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, w_gate,
                                   preferred_element_type=jnp.float32)
                        .astype(xt.dtype)) * up
    else:
        h = jax.nn.gelu(up)
    ye = jnp.einsum("gecf,efd->gecd", h, w_down,
                    preferred_element_type=jnp.float32).astype(xt.dtype)
    return jnp.einsum("gtec,gecd->gtd", comb, ye)


def moe_block(x, p: Params, *, n_experts: int, top_k: int, act: str,
              policy: PositPolicy, capacity_factor: float | None = 1.25,
              group_size: int = 128):
    """x [B, S, d] -> (out [B, S, d], aux_loss scalar).

    capacity_factor None disables overflow dropping entirely (cap covers
    every (token, k) pair).  Serving steps use this: capacity drops are a
    training-efficiency mechanism, and a per-group cap couples unrelated
    sequences through the decode batch — a token's output would depend on
    which other requests share its step (and bit-parity across data-shard
    layouts would be impossible).

    Dispatch: the Pallas path (use_pallas() — TPU, or the interpret-mode
    tier-1 drive) takes sort-based routing + the grouped posit GEMM for
    serving AND training (the grouped custom_vjp supplies the dX/dW
    kernels, and the training step runs under shard_map where partitioning
    is manual, so the old GSPMD carve-out is gone); the jnp backend keeps
    the GShard one-hot implementation (which is also the oracle).
    REPRO_FORCE_GATHER / ops.FORCE_REFERENCE / FORCE_DENSE pin the one-hot
    path everywhere (benchmark baseline); FORCE_GROUPED pins the grouped
    routing regardless of backend.  With capacity drops (training) the
    grouped dispatch is output-identical to one-hot: comb_w zeroes dropped
    (token, k) pairs before either path combines.
    """
    from repro.kernels import ops as kops
    from repro.distributed.collectives import block_grad_sync
    # f-operator under expert-parallel TP: shard-local expert paths give a
    # partial d(x) per member (identity fwd; see collectives).  Router
    # weight grads stay partial-per-shard though, so the training step
    # rejects MoE with ntp > 1 — this keeps d(x) correct for serving-style
    # grad probes and future EP training.
    x = block_grad_sync(x)
    B, S, d = x.shape
    T = B * S
    gs = min(group_size, T)
    G = T // gs
    # require T % gs == 0 (shapes here are powers of two; enforced by configs)
    xt = x.reshape(G, gs, d)
    if capacity_factor is None:
        # every pair fits (top-k ids are distinct, so an expert receives
        # at most gs arrivals per group): no drops
        cap = gs
    else:
        cap = max(1, int(capacity_factor * gs * top_k / n_experts))

    probs, gate_idx, onehot, pos, keep, comb_w = _route(
        xt, p, n_experts=n_experts, top_k=top_k, cap=cap, policy=policy)

    # load-balancing aux loss (Switch): E * sum_e f_e * P_e — computed from
    # the global routing, so it is replicated under expert-parallel TP
    f = onehot.astype(jnp.float32).sum(axis=(0, 1, 2)) / (T * top_k)
    pm = probs.mean(axis=(0, 1))
    aux = n_experts * jnp.sum(f * pm)

    # Grouped dispatch is the hot path for serving AND training on the
    # Pallas backend.  Both sharded steps (serving engine, train step) run
    # under shard_map where partitioning is manual and shard-local, so
    # pallas_call's lack of GSPMD rules no longer forces a training
    # carve-out — the grouped custom_vjp's dX/dW kernels carry the
    # backward.  With capacity drops the result is identical to one-hot
    # (comb_w is already zero for dropped pairs).
    # FORCE_DENSE / REPRO_FORCE_GATHER / ops.FORCE_REFERENCE always win
    # (the documented pin-the-oracle-everywhere contract), even over a
    # stale FORCE_GROUPED left set by an earlier in-process experiment
    grouped = ((FORCE_GROUPED or kops.use_pallas())
               and not kops.force_reference() and not FORCE_DENSE)
    if grouped:
        out = _dispatch_grouped(xt, p, n_experts=n_experts, top_k=top_k,
                                act=act, policy=policy, gate_idx=gate_idx,
                                comb_w=comb_w)
    else:
        # counted even for float weights: a zero-delta assertion on this
        # counter is the "the kernel path actually engaged" check for
        # training steps (posit materializations add "expert-decode" too)
        DENSE_MOE_FALLBACKS[
            "forced" if kops.use_pallas() else "jnp-reference"] += 1
        out = _dispatch_oneshot(xt, p, n_experts=n_experts, top_k=top_k,
                                act=act, policy=policy, cap=cap,
                                gate_idx=gate_idx, pos=pos, keep=keep,
                                comb_w=comb_w)
    # under expert-parallel TP each shard holds its experts' partial mix;
    # the block's one psum assembles the full output (identity otherwise)
    from repro.distributed.collectives import block_psum
    return block_psum(out).reshape(B, S, d).astype(x.dtype), aux
