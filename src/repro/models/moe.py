"""Mixture-of-Experts block: top-k routing with *grouped* capacity-based
einsum dispatch (GShard style — all matmul traffic, shards cleanly with the
expert dimension on the 'model' mesh axis and groups on the data axes).

Tokens are split into groups of `group_size`; each group gets a per-expert
capacity C = ceil(group_size * top_k * capacity_factor / E).  The dispatch
one-hot is [G, Tg, E, C] — its footprint scales as T_local * Tg * k * f per
device (bounded by the group size knob), unlike a global-capacity dispatch
whose [T, E, C] explodes at 1M-token batches.  Overflow tokens within a
group drop (standard GShard behaviour, tracked by the aux loss).

Used by olmoe-1b-7b (64e top-8) and qwen3-moe-235b-a22b (128e top-8).
Expert tables are the biggest posit-storage win (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.blocks import _dense_init
from repro.quant.policy import PositPolicy, posit_cast_ste

Params = dict[str, Any]


def init_moe(key, d_model: int, d_ff: int, n_experts: int, act: str) -> Params:
    ks = jax.random.split(key, 4)
    glu = act in ("geglu", "swiglu")
    p = {
        "router": _dense_init(ks[0], (d_model, n_experts)),
        "w_up": _dense_init(ks[1], (n_experts, d_model, d_ff)),
        "w_down": _dense_init(ks[2], (n_experts, d_ff, d_model), d_ff ** -0.5),
    }
    if glu:
        p["w_gate"] = _dense_init(ks[3], (n_experts, d_model, d_ff))
    return p


def _maybe_decode(w, policy: PositPolicy):
    from repro.core.array import PositArray
    if isinstance(w, PositArray):
        return w.to_f32()
    if w.dtype in (jnp.int8, jnp.int16):
        from repro.core.decode import decode_to_f32
        return decode_to_f32(w, policy.weights)
    if policy is not None and policy.weights is not None:
        return posit_cast_ste(w, policy.weights)
    return w


def moe_block(x, p: Params, *, n_experts: int, top_k: int, act: str,
              policy: PositPolicy, capacity_factor: float = 1.25,
              group_size: int = 128):
    """x [B, S, d] -> (out [B, S, d], aux_loss scalar)."""
    B, S, d = x.shape
    T = B * S
    gs = min(group_size, T)
    G = T // gs
    # require T % gs == 0 (shapes here are powers of two; enforced by configs)
    xt = x.reshape(G, gs, d)

    router = _maybe_decode(p["router"], policy)
    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)

    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)          # [G,Tg,k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)

    cap = max(1, int(capacity_factor * gs * top_k / n_experts))

    onehot = jax.nn.one_hot(gate_idx, n_experts, dtype=jnp.int32)  # [G,Tg,k,E]
    flat = onehot.reshape(G, gs * top_k, n_experts)
    pos = jnp.cumsum(flat, axis=1) - 1                             # arrival order
    pos = (pos * flat).sum(axis=-1).reshape(G, gs, top_k)
    keep = pos < cap

    slot_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                             dtype=x.dtype)[..., :cap]             # [G,Tg,k,C]
    disp = jnp.einsum("gtke,gtkc->gtec", onehot.astype(x.dtype), slot_oh)
    comb = jnp.einsum("gtke,gtkc,gtk->gtec", onehot.astype(jnp.float32),
                      slot_oh.astype(jnp.float32), gate_vals).astype(x.dtype)

    xe = jnp.einsum("gtec,gtd->gecd", disp, xt)                    # [G,E,C,d]

    w_up = _maybe_decode(p["w_up"], policy)
    w_down = _maybe_decode(p["w_down"], policy)
    w_gate = _maybe_decode(p["w_gate"], policy) if "w_gate" in p else None

    up = jnp.einsum("gecd,edf->gecf", xe, w_up,
                    preferred_element_type=jnp.float32).astype(x.dtype)
    if act == "geglu":
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", xe, w_gate,
                                   preferred_element_type=jnp.float32)
                        .astype(x.dtype)) * up
    elif act == "swiglu":
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, w_gate,
                                   preferred_element_type=jnp.float32)
                        .astype(x.dtype)) * up
    else:
        h = jax.nn.gelu(up)
    ye = jnp.einsum("gecf,efd->gecd", h, w_down,
                    preferred_element_type=jnp.float32).astype(x.dtype)

    out = jnp.einsum("gtec,gecd->gtd", comb, ye).reshape(B, S, d)

    # load-balancing aux loss (Switch): E * sum_e f_e * P_e
    f = onehot.astype(jnp.float32).sum(axis=(0, 1, 2)) / (T * top_k)
    pm = probs.mean(axis=(0, 1))
    aux = n_experts * jnp.sum(f * pm)
    return out, aux
