"""RWKV-6 "Finch" block (arXiv:2404.05892) — attention-free token mixing
with data-dependent per-channel decay.

TPU adaptation: the recurrence
    S_t = diag(w_t) S_{t-1} + k_t^T v_t ,   y_t = r_t S_{t-1} + (r_t.(u o k_t)) v_t
is evaluated in *chunks* (linear-attention chunked form).  Within a chunk the
pairwise decay factors  D[t,s,d] = exp(L_{t-1,d} - L_{s,d})  (L = cumulative
log-decay <= 0, differences only for s < t so every exponent is <= 0 —
numerically safe) are materialized at (C, C, dk) with a small C; across
chunks a (dk, dv) state is carried through lax.scan.  This trades the
sequential T-step scan for T/C steps of MXU-friendly batched einsums and is
the standard TPU-native form of gated linear recurrences.

Decode (serving) uses the O(1) single-step recurrence — this is why rwkv6
runs the long_500k shape that quadratic-attention archs skip.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.blocks import _dense_init, init_linear, linear, rms_norm
from repro.quant.policy import PositPolicy

Params = dict[str, Any]

CHUNK = 16
DECAY_LORA = 64


def init_rwkv6(key, d_model: int, head_dim: int = 64) -> Params:
    n_heads = d_model // head_dim
    ks = jax.random.split(key, 10)
    return {
        "mix": jnp.full((5, d_model), 0.5, jnp.float32),     # r,k,v,w,g lerp
        "wr": init_linear(ks[0], d_model, d_model),
        "wk": init_linear(ks[1], d_model, d_model),
        "wv": init_linear(ks[2], d_model, d_model),
        "wg": init_linear(ks[3], d_model, d_model),
        "w0": jnp.full((d_model,), -6.0, jnp.float32),       # base decay
        "w_lora_a": _dense_init(ks[4], (d_model, DECAY_LORA)),
        "w_lora_b": jnp.zeros((DECAY_LORA, d_model), jnp.float32),
        "u": jnp.zeros((n_heads, head_dim), jnp.float32),    # bonus
        "wo": init_linear(ks[5], d_model, d_model),
        "ln_x": {"scale": jnp.ones((d_model,), jnp.float32)},
    }


def _token_shift(x):
    """x[t] -> x[t-1] (zero for t=0)."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def _wkv_chunk(S, inputs, *, head_dim):
    """One chunk of the WKV recurrence.  S [B,H,dk,dv];
    r,k,v [B,H,C,dh]; logw [B,H,C,dk] (<= 0); u [H,dk]."""
    r, k, v, logw, u = inputs
    L = jnp.cumsum(logw, axis=2)                       # L_t, inclusive
    Lprev = L - logw                                   # L_{t-1} (zero at t=0)

    # inter-chunk: y_t += (r_t o exp(L_{t-1})) S_in
    y = jnp.einsum("bhtd,bhdv->bhtv", r * jnp.exp(Lprev), S)

    # intra-chunk: D[t,s,d] = exp(L_{t-1,d} - L_{s,d}) for s < t
    diff = Lprev[:, :, :, None, :] - L[:, :, None, :, :]
    C = r.shape[2]
    mask = (jnp.arange(C)[:, None] > jnp.arange(C)[None, :])[None, None, :, :, None]
    D = jnp.where(mask, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
    scores = jnp.einsum("bhtd,bhtsd,bhsd->bhts", r, D, k)
    y = y + jnp.einsum("bhts,bhsv->bhtv", scores, v)

    # bonus (current token): (r_t . (u o k_t)) v_t
    su = jnp.einsum("bhtd,hd,bhtd->bht", r, u, k)
    y = y + su[..., None] * v

    # state update: S_out = diag(exp(L_C)) S + sum_s (k_s o exp(L_C - L_s))^T v_s
    Lc = L[:, :, -1:, :]                               # [B,H,1,dk]
    S_new = jnp.exp(Lc[:, :, 0, :, None]) * S + jnp.einsum(
        "bhsd,bhsv->bhdv", k * jnp.exp(Lc - L), v)
    return S_new, y


def rwkv6_time_mix(x, p: Params, *, head_dim: int, policy: PositPolicy,
                   state=None, chunk: int = CHUNK):
    """x [B,S,d] -> (y [B,S,d], new_state).  state: [B,H,dk,dv] + shift [B,d]."""
    B, S, d = x.shape
    H = d // head_dim

    if state is None:
        x_prev = _token_shift(x)
        S0 = jnp.zeros((B, H, head_dim, head_dim), x.dtype)
    else:
        S0, last_x = state
        x_prev = jnp.concatenate([last_x[:, None], x[:, :-1]], axis=1)

    mix = p["mix"]
    xr, xk, xv, xw, xg = (x + (x_prev - x) * mix[i] for i in range(5))

    r = linear(xr, p["wr"], policy).reshape(B, S, H, head_dim).transpose(0, 2, 1, 3)
    k = linear(xk, p["wk"], policy).reshape(B, S, H, head_dim).transpose(0, 2, 1, 3)
    v = linear(xv, p["wv"], policy).reshape(B, S, H, head_dim).transpose(0, 2, 1, 3)
    g = linear(xg, p["wg"], policy)

    # data-dependent decay (the Finch contribution): w = exp(-exp(w0 + lora))
    ww = p["w0"] + jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]
    logw = -jnp.exp(jnp.clip(ww, -20.0, 10.0).astype(jnp.float32))
    logw = logw.reshape(B, S, H, head_dim).transpose(0, 2, 1, 3)

    # pad to chunk multiple
    pad = (-S) % chunk
    if pad:
        zf = lambda t: jnp.pad(t, ((0, 0), (0, 0), (0, pad), (0, 0)))
        r_, k_, v_ = zf(r), zf(k), zf(v)
        logw_ = jnp.pad(logw, ((0, 0), (0, 0), (0, pad), (0, 0)))
    else:
        r_, k_, v_, logw_ = r, k, v, logw
    nC = (S + pad) // chunk

    def body(Scur, idx):
        sl = lambda t: jax.lax.dynamic_slice_in_dim(t, idx * chunk, chunk, 2)
        S_new, y = _wkv_chunk(
            Scur, (sl(r_).astype(jnp.float32), sl(k_).astype(jnp.float32),
                   sl(v_).astype(jnp.float32), sl(logw_), p["u"]),
            head_dim=head_dim)
        return S_new, y

    S_fin, ys = jax.lax.scan(jax.checkpoint(body), S0.astype(jnp.float32),
                             jnp.arange(nC))
    y = ys.transpose(1, 2, 0, 3, 4).reshape(B, H, nC * chunk, head_dim)[:, :, :S]
    y = y.transpose(0, 2, 1, 3).reshape(B, S, d).astype(x.dtype)

    # per-head group norm + silu(g) gate, output projection
    y = y.reshape(B, S, H, head_dim)
    mu = y.mean(axis=-1, keepdims=True)
    var = ((y - mu) ** 2).mean(axis=-1, keepdims=True)
    y = ((y - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(B, S, d)
    y = y * p["ln_x"]["scale"]
    y = y * jax.nn.silu(g)
    out = linear(y, p["wo"], policy)
    new_state = (S_fin.astype(x.dtype), x[:, -1])
    return out, new_state


def rwkv6_time_mix_serving(x, p: Params, *, head_dim: int,
                           policy: PositPolicy, state, num_new=None):
    """Stateful serving-path time mix: same projections as rwkv6_time_mix,
    but the WKV core runs through the kernels.ops recurrent-scan dispatch
    (Pallas fused kernel on TPU, counted jnp oracle elsewhere) with the
    state posit-round-tripped after every token under policy.kv_cache.

    state = (S0 [B,H,dh,dh], last_x [B,d]): f32 arrays (the dense engine's
    cache tuples) or PositArray pool slots (the paged engine's state pool) —
    S0 is returned in the same representation; last_x comes back as raw f32
    *values* of this chunk's last valid token (callers re-encode for the
    pool via backends.store_state).  num_new [B] masks ragged chunks; every
    cross-token value is used round-tripped (blocks.rt_values), so chunked
    prefill + single-token decode reproduce the whole-sequence scan
    bit-for-bit at any chunking.
    """
    from repro.kernels import ops as kops
    from repro.models.blocks import rt_values, select_last
    from repro.serving.backends import state_f32
    B, S, d = x.shape
    H = d // head_dim
    pcfg = policy.kv_cache
    S0, last_x = state
    x_prev = rt_values(
        jnp.concatenate([state_f32(last_x)[:, None].astype(x.dtype),
                         x[:, :-1]], axis=1), pcfg).astype(x.dtype)

    mix = p["mix"]
    xr, xk, xv, xw, xg = (x + (x_prev - x) * mix[i] for i in range(5))

    r = linear(xr, p["wr"], policy).reshape(B, S, H, head_dim).transpose(0, 2, 1, 3)
    k = linear(xk, p["wk"], policy).reshape(B, S, H, head_dim).transpose(0, 2, 1, 3)
    v = linear(xv, p["wv"], policy).reshape(B, S, H, head_dim).transpose(0, 2, 1, 3)
    g = linear(xg, p["wg"], policy)

    ww = p["w0"] + jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]
    logw = -jnp.exp(jnp.clip(ww, -20.0, 10.0).astype(jnp.float32))
    logw = logw.reshape(B, S, H, head_dim).transpose(0, 2, 1, 3)

    y, S_fin = kops.wkv_scan(r.astype(jnp.float32), k.astype(jnp.float32),
                             v.astype(jnp.float32), logw, p["u"], S0,
                             num_new=num_new, cfg_state=pcfg)
    y = y.transpose(0, 2, 1, 3).reshape(B, S, d).astype(x.dtype)

    y = y.reshape(B, S, H, head_dim)
    mu = y.mean(axis=-1, keepdims=True)
    var = ((y - mu) ** 2).mean(axis=-1, keepdims=True)
    y = ((y - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(B, S, d)
    y = y * p["ln_x"]["scale"]
    y = y * jax.nn.silu(g)
    out = linear(y, p["wo"], policy)
    new_last = select_last(x, num_new).astype(jnp.float32)
    return out, (S_fin, new_last)


def init_rwkv6_channel_mix(key, d_model: int, d_ff: int) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "mix": jnp.full((2, d_model), 0.5, jnp.float32),
        "wk": init_linear(ks[0], d_model, d_ff),
        "wr": init_linear(ks[1], d_model, d_model),
        "wv": init_linear(ks[2], d_ff, d_model),
    }


def rwkv6_channel_mix(x, p: Params, *, policy: PositPolicy, last_x=None):
    B, S, d = x.shape
    if last_x is None:
        x_prev = _token_shift(x)
    else:
        x_prev = jnp.concatenate([last_x[:, None], x[:, :-1]], axis=1)
    xk = x + (x_prev - x) * p["mix"][0]
    xr = x + (x_prev - x) * p["mix"][1]
    k = jnp.square(jax.nn.relu(linear(xk, p["wk"], policy)))
    return jax.nn.sigmoid(linear(xr, p["wr"], policy)) * linear(
        k, p["wv"], policy), x[:, -1]


def rwkv6_channel_mix_serving(x, p: Params, *, policy: PositPolicy, last_x,
                              num_new=None):
    """Stateful serving-path channel mix (chunk-invariant token shift; no
    recurrence, so no kernel dispatch).  last_x: f32 or PositArray pool
    slot; the new shift comes back as raw f32 values (see
    rwkv6_time_mix_serving for the state contract)."""
    from repro.models.blocks import rt_values, select_last
    from repro.serving.backends import state_f32
    pcfg = policy.kv_cache
    x_prev = rt_values(
        jnp.concatenate([state_f32(last_x)[:, None].astype(x.dtype),
                         x[:, :-1]], axis=1), pcfg).astype(x.dtype)
    xk = x + (x_prev - x) * p["mix"][0]
    xr = x + (x_prev - x) * p["mix"][1]
    k = jnp.square(jax.nn.relu(linear(xk, p["wk"], policy)))
    out = jax.nn.sigmoid(linear(xr, p["wr"], policy)) * linear(
        k, p["wv"], policy)
    return out, select_last(x, num_new).astype(jnp.float32)
