"""Model assembly: decoder LMs, encoder-only stacks, MoE, SSM and hybrid
patterns — one config-driven implementation covering all ten assigned
architectures (DESIGN.md §4).

Layers are grouped by the repeating `block_pattern` and scanned
(jax.lax.scan over stacked parameters) so even the 94-layer MoE lowers to a
compact HLO; each scanned step is rematerialized (configurable policy).
Posit enters through cfg.policy (see quant/policy.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import blocks as B
from repro.models import griffin as GR
from repro.models import moe as MOE
from repro.models import rwkv6 as RW
from repro.quant.policy import NONE, PositPolicy

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    # capacity/dispatch group: routing drops overflow per `group_size`
    # tokens on both MoE paths (models/moe.py — sort-based grouped GEMM on
    # the Pallas path, GShard one-hot as the jnp oracle)
    group_size: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    act: str = "swiglu"
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"
    encoder_only: bool = False
    block_pattern: tuple[str, ...] = ("attn",)
    window: int | None = None         # for "attn_local"
    moe: MoEConfig | None = None
    tie_embeddings: bool = True
    embed_scale: bool = False         # gemma: x *= sqrt(d_model)
    input_mode: str = "tokens"        # tokens | embeddings | tokens+image
    dtype: str = "float32"
    policy: PositPolicy = NONE
    remat: bool = True
    scan_layers: bool = True          # False: unrolled (cost-probe mode)
    rwkv_head_dim: int = 64

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def pattern_reps(self) -> int:
        return self.n_layers // len(self.block_pattern)

    @property
    def pattern_rem(self) -> int:
        return self.n_layers % len(self.block_pattern)

    def param_count(self) -> int:
        """Analytic parameter count (for roofline MODEL_FLOPS)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        per_layer = {}
        glu = 3 if self.act in ("geglu", "swiglu") else 2
        attn = d * self.hd * (self.n_heads + 2 * self.n_kv) + self.n_heads * self.hd * d
        if self.moe:
            mlp = d * self.moe.n_experts + self.moe.n_experts * glu * d * ff
        else:
            mlp = glu * d * ff
        per_layer["attn"] = attn + mlp
        per_layer["attn_local"] = attn + mlp
        per_layer["rwkv6"] = 6 * d * d + 2 * d * ff + d * RW.DECAY_LORA * 2
        per_layer["rglru"] = 5 * d * d + mlp
        total = 0
        for i in range(self.n_layers):
            total += per_layer[self.block_pattern[i % len(self.block_pattern)]]
        total += v * d * (1 if self.tie_embeddings else 2)
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if not self.moe:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        glu = 3 if self.act in ("geglu", "swiglu") else 2
        dense = self.param_count()
        moe_all = self.n_layers * self.moe.n_experts * glu * d * ff
        moe_active = self.n_layers * self.moe.top_k * glu * d * ff
        return dense - moe_all + moe_active


# --------------------------------------------------------------------------
# per-block init/apply
# --------------------------------------------------------------------------
def _init_block(key, kind: str, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4)
    norm_init = (B.init_rmsnorm if cfg.norm == "rmsnorm"
                 else B.init_layernorm)
    if kind in ("attn", "attn_local"):
        p = {"ln1": norm_init(cfg.d_model), "ln2": norm_init(cfg.d_model),
             "attn": B.init_attention(ks[0], cfg.d_model, cfg.n_heads,
                                      cfg.n_kv, cfg.hd, cfg.qkv_bias)}
        if cfg.moe:
            p["moe"] = MOE.init_moe(ks[1], cfg.d_model, cfg.d_ff,
                                    cfg.moe.n_experts, cfg.act)
        else:
            p["mlp"] = B.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act)
        return p
    if kind == "rwkv6":
        return {"ln1": norm_init(cfg.d_model), "ln2": norm_init(cfg.d_model),
                "tmix": RW.init_rwkv6(ks[0], cfg.d_model, cfg.rwkv_head_dim),
                "cmix": RW.init_rwkv6_channel_mix(ks[1], cfg.d_model, cfg.d_ff)}
    if kind == "rglru":
        p = {"ln1": norm_init(cfg.d_model), "ln2": norm_init(cfg.d_model),
             "rec": GR.init_rglru_block(ks[0], cfg.d_model)}
        if cfg.moe:
            p["moe"] = MOE.init_moe(ks[1], cfg.d_model, cfg.d_ff,
                                    cfg.moe.n_experts, cfg.act)
        else:
            p["mlp"] = B.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act)
        return p
    raise ValueError(kind)


def _norm(x, p, cfg: ModelConfig):
    from repro.distributed.sharding import shard_activation
    h = (B.rms_norm(x, p) if cfg.norm == "rmsnorm"
         else B.layer_norm(x, p))
    # Megatron-SP: blocks consume sequence-gathered activations (no-op
    # outside a mesh context / under fsdp) — §Perf iteration A4
    return shard_activation(h, "block_in")


def _apply_block(x, p: Params, kind: str, cfg: ModelConfig, positions,
                 cache, aux):
    pol = cfg.policy
    if cfg.moe:
        # serving never drops: a per-group capacity would couple a token's
        # output to the other requests sharing its batch (and break
        # bit-parity across data-shard layouts); None also routes the
        # Pallas path into the grouped GEMM (models/moe.py)
        moe_cf = None if cache is not None else cfg.moe.capacity_factor
    if kind in ("attn", "attn_local"):
        h, new_cache = B.attention_block(
            _norm(x, p["ln1"], cfg), p["attn"], n_heads=cfg.n_heads,
            n_kv=cfg.n_kv, head_dim=cfg.hd, positions=positions, policy=pol,
            causal=not cfg.encoder_only,
            window=cfg.window if kind == "attn_local" else None,
            rope_theta=cfg.rope_theta, kv_cache=cache)
        x = x + h.astype(x.dtype)
        if cfg.moe:
            h, a = MOE.moe_block(_norm(x, p["ln2"], cfg), p["moe"],
                                 n_experts=cfg.moe.n_experts,
                                 top_k=cfg.moe.top_k, act=cfg.act, policy=pol,
                                 capacity_factor=moe_cf,
                                 group_size=cfg.moe.group_size)
            aux = aux + a
        else:
            h = B.mlp_block(_norm(x, p["ln2"], cfg), p["mlp"], act=cfg.act,
                            policy=pol)
        return x + h.astype(x.dtype), new_cache, aux
    if kind == "rwkv6":
        if cache is None:                        # training / no-cache path
            h, new_t = RW.rwkv6_time_mix(_norm(x, p["ln1"], cfg), p["tmix"],
                                         head_dim=cfg.rwkv_head_dim,
                                         policy=pol, state=None)
            x = x + h.astype(x.dtype)
            h, new_c = RW.rwkv6_channel_mix(_norm(x, p["ln2"], cfg),
                                            p["cmix"], policy=pol,
                                            last_x=None)
            return x + h.astype(x.dtype), (new_t, new_c), aux
        # serving: dense cache tuples or a posit state-pool dict — both run
        # the stateful chunk-invariant path (serving/backends.py)
        from repro.serving import backends as SB
        if isinstance(cache, dict):
            sl, nn = cache["seq_lens"], cache["num_new"]
            S0 = SB.zero_fresh(cache["wkv"], sl)
            tsh = SB.zero_fresh(cache["tshift"], sl)
            csh = SB.zero_fresh(cache["cshift"], sl)
        else:
            (S0, tsh), csh = cache
            nn = None
        h, (S_fin, t_last) = RW.rwkv6_time_mix_serving(
            _norm(x, p["ln1"], cfg), p["tmix"], head_dim=cfg.rwkv_head_dim,
            policy=pol, state=(S0, tsh), num_new=nn)
        x = x + h.astype(x.dtype)
        h, c_last = RW.rwkv6_channel_mix_serving(
            _norm(x, p["ln2"], cfg), p["cmix"], policy=pol, last_x=csh,
            num_new=nn)
        x = x + h.astype(x.dtype)
        if isinstance(cache, dict):
            new_cache = {"wkv": S_fin,
                         "tshift": SB.store_state(cache["tshift"], t_last,
                                                  nn),
                         "cshift": SB.store_state(cache["cshift"], c_last,
                                                  nn),
                         "seq_lens": sl, "num_new": nn}
        else:
            new_cache = ((S_fin, t_last), c_last)
        return x, new_cache, aux
    if kind == "rglru":
        if cache is None:                        # training / no-cache path
            h, new_state = GR.rglru_block(_norm(x, p["ln1"], cfg), p["rec"],
                                          policy=pol, state=None)
        else:
            from repro.serving import backends as SB
            if isinstance(cache, dict):
                sl, nn = cache["seq_lens"], cache["num_new"]
                h0 = SB.zero_fresh(cache["h"], sl)
                cv = SB.zero_fresh(cache["conv"], sl)
            else:
                h0, cv = cache
                nn = None
            h, (h_fin, conv_last) = GR.rglru_block_serving(
                _norm(x, p["ln1"], cfg), p["rec"], policy=pol,
                state=(h0, cv), num_new=nn)
            if isinstance(cache, dict):
                new_state = {"h": h_fin,
                             "conv": SB.store_state(cache["conv"], conv_last,
                                                    nn),
                             "seq_lens": sl, "num_new": nn}
            else:
                new_state = (h_fin, conv_last)
        x = x + h.astype(x.dtype)
        if cfg.moe:
            h, a = MOE.moe_block(_norm(x, p["ln2"], cfg), p["moe"],
                                 n_experts=cfg.moe.n_experts,
                                 top_k=cfg.moe.top_k, act=cfg.act, policy=pol,
                                 capacity_factor=moe_cf,
                                 group_size=cfg.moe.group_size)
            aux = aux + a
        else:
            h = B.mlp_block(_norm(x, p["ln2"], cfg), p["mlp"], act=cfg.act,
                            policy=pol)
        return x + h.astype(x.dtype), new_state, aux
    raise ValueError(kind)


# --------------------------------------------------------------------------
# cache pytrees
# --------------------------------------------------------------------------
def init_layer_cache(kind: str, cfg: ModelConfig, batch: int, max_len: int,
                     dtype=jnp.float32):
    from repro.serving.kv_cache import init_cache
    if kind == "attn":
        return init_cache(batch, cfg.n_kv, max_len, cfg.hd,
                          cfg.policy.kv_cache, dtype)
    if kind == "attn_local":
        # full-length buffer; a window-sized ring buffer is a §Perf memory
        # optimization applied in the hillclimb (EXPERIMENTS.md)
        return init_cache(batch, cfg.n_kv, max_len, cfg.hd,
                          cfg.policy.kv_cache, dtype)
    if kind == "rwkv6":
        H = cfg.d_model // cfg.rwkv_head_dim
        t = (jnp.zeros((batch, H, cfg.rwkv_head_dim, cfg.rwkv_head_dim), dtype),
             jnp.zeros((batch, cfg.d_model), dtype))
        c = jnp.zeros((batch, cfg.d_model), dtype)
        return (t, c)
    if kind == "rglru":
        return (jnp.zeros((batch, cfg.d_model), jnp.float32),
                jnp.zeros((batch, GR.CONV_WIDTH - 1, cfg.d_model), dtype))
    raise ValueError(kind)


def init_caches(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.float32):
    """Stacked caches: {kind_position: stacked over reps} + remainder list."""
    P = len(cfg.block_pattern)
    reps = cfg.pattern_reps

    def stack(kind):
        one = init_layer_cache(kind, cfg, batch, max_len, dtype)
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (reps,) + x.shape), one)

    scanned = tuple(stack(k) for k in cfg.block_pattern) if reps else ()
    rem = tuple(init_layer_cache(cfg.block_pattern[i], cfg, batch, max_len,
                                 dtype)
                for i in range(cfg.pattern_rem))
    return {"scanned": scanned, "rem": rem}


# ---- paged caches (continuous-batching serving; serving/backends.py) ------
def init_paged_pages(cfg: ModelConfig, num_pages: int, page_size: int,
                     dtype=jnp.float32, max_seqs: int = 0):
    """Per-layer serving pools in the same {scanned, rem} structure as
    init_caches.  Each pattern position gets its backend's pool: paged posit
    KV for attn/attn_local, a fixed-size posit state pool (sized max_seqs)
    for rwkv6/rglru — hybrid patterns mix both side by side."""
    from repro.serving.backends import backend_for
    reps = cfg.pattern_reps

    def one(kind):
        return backend_for(kind, cfg).init_layer(cfg, num_pages, page_size,
                                                 max_seqs, dtype)

    def stack(kind):
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (reps,) + x.shape), one(kind))

    scanned = tuple(stack(k) for k in cfg.block_pattern) if reps else ()
    rem = tuple(one(cfg.block_pattern[i]) for i in range(cfg.pattern_rem))
    return {"scanned": scanned, "rem": rem}


def assemble_paged_caches(pages, page_table, seq_lens, num_new):
    """Pools tree + this step's scheduler inputs -> forward()-ready caches.

    The scheduler fields are identical for every layer; scanned groups get
    them broadcast over the stacked reps axis so lax.scan can slice them.
    KV pools additionally take the page table; state pools are slot-indexed
    and just carry seq_lens/num_new."""
    from repro.serving.paged_kv import assemble_layer_cache

    def one(p, stacked: bool):
        if "k_pages" not in p:                    # state-pool layer
            if stacked:
                reps = next(iter(p.values())).shape[0]
                return {**p,
                        "seq_lens": jnp.broadcast_to(
                            seq_lens, (reps,) + seq_lens.shape),
                        "num_new": jnp.broadcast_to(
                            num_new, (reps,) + num_new.shape)}
            return {**p, "seq_lens": seq_lens, "num_new": num_new}
        if stacked:
            reps = p["k_pages"].shape[0]
            return assemble_layer_cache(
                p,
                jnp.broadcast_to(page_table, (reps,) + page_table.shape),
                jnp.broadcast_to(seq_lens, (reps,) + seq_lens.shape),
                jnp.broadcast_to(num_new, (reps,) + num_new.shape))
        return assemble_layer_cache(p, page_table, seq_lens, num_new)

    return {"scanned": tuple(one(p, True) for p in pages["scanned"]),
            "rem": tuple(one(p, False) for p in pages["rem"])}


def copy_paged_pages(pages, src, dst):
    """Copy page `src` onto page `dst` in every KV layer's pools (the device
    half of the prefix cache's copy-on-write: the host rewrites one table
    entry, this duplicates the page contents it pointed at).  src/dst are
    (traced) scalars — shard-local ids when the pools are shard_mapped.
    State-pool layers have no pages and pass through untouched (the prefix
    cache is KV-only)."""
    from repro.serving.paged_kv import copy_layer_pages
    return {"scanned": tuple(copy_layer_pages(p, src, dst, stacked=True)
                             if "k_pages" in p else p
                             for p in pages["scanned"]),
            "rem": tuple(copy_layer_pages(p, src, dst)
                         if "k_pages" in p else p
                         for p in pages["rem"])}


def poison_paged_pages(pages, pg):
    """Overwrite page `pg` with the posit NaR pattern (NaN for float
    pools) in every KV layer — the device half of the chaos harness's
    bit-flipped-page injection (serving/faults.py).  State-pool layers
    pass through untouched, like copy_paged_pages."""
    from repro.serving.paged_kv import poison_layer_pages
    return {"scanned": tuple(poison_layer_pages(p, pg, stacked=True)
                             if "k_pages" in p else p
                             for p in pages["scanned"]),
            "rem": tuple(poison_layer_pages(p, pg)
                         if "k_pages" in p else p
                         for p in pages["rem"])}


def extract_paged_pages(caches):
    """Inverse of assemble_paged_caches: keep only the device-resident
    pools (the scheduler recomputes the rest every step)."""
    from repro.serving.paged_kv import extract_layer_pages

    def one(c):
        if "k_pages" in c:
            return extract_layer_pages(c)
        return {k: v for k, v in c.items()
                if k not in ("seq_lens", "num_new")}

    return {"scanned": tuple(one(c) for c in caches["scanned"]),
            "rem": tuple(one(c) for c in caches["rem"])}


# --------------------------------------------------------------------------
# model init / forward
# --------------------------------------------------------------------------
def init_params(key, cfg: ModelConfig) -> Params:
    P = len(cfg.block_pattern)
    reps, rem = cfg.pattern_reps, cfg.pattern_rem
    keys = jax.random.split(key, reps * P + rem + 2)

    def stacked(pos):
        kind = cfg.block_pattern[pos]
        per_rep = [
            _init_block(keys[r * P + pos], kind, cfg) for r in range(reps)
        ]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_rep)

    params: Params = {
        "embed": B.init_embedding(keys[-1], cfg.vocab, cfg.d_model),
        "ln_f": (B.init_rmsnorm(cfg.d_model) if cfg.norm == "rmsnorm"
                 else B.init_layernorm(cfg.d_model)),
        "scanned": tuple(stacked(i) for i in range(P)) if reps else (),
        "rem": tuple(
            _init_block(keys[reps * P + i], cfg.block_pattern[i], cfg)
            for i in range(rem)),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = B.init_linear(keys[-2], cfg.d_model, cfg.vocab)
    return params


def forward(params: Params, cfg: ModelConfig, *, tokens=None,
            inputs_embeds=None, positions=None, caches=None,
            return_hidden: bool = False):
    """Returns (logits [B,S,vocab], aux_loss, new_caches).

    tokens [B,S] int32 and/or inputs_embeds [B,Se,d] depending on
    cfg.input_mode.  positions [B,S] absolute positions (default arange,
    offset by cache length when serving).
    return_hidden: skip the unembedding and return the final normalized
    hidden states instead of logits (the chunked-loss training path —
    §Perf iteration A3 — computes the LM head per sequence chunk).
    """
    pol = cfg.policy
    if cfg.input_mode == "embeddings":
        x = inputs_embeds
    elif cfg.input_mode == "tokens+image" and inputs_embeds is not None:
        t = B.embed(tokens, params["embed"], pol)
        x = jnp.concatenate([inputs_embeds.astype(t.dtype), t], axis=1)
    else:
        x = B.embed(tokens, params["embed"], pol)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x = x.astype(jnp.dtype(cfg.dtype))
    from repro.distributed.sharding import shard_activation
    x = shard_activation(x, "act")

    Bsz, S, _ = x.shape
    if positions is None:
        off = 0
        if caches is not None:
            off = _cache_length(caches, cfg)
        positions = off + jnp.broadcast_to(jnp.arange(S), (Bsz, S))

    aux = jnp.zeros((), jnp.float32)
    P = len(cfg.block_pattern)
    reps = cfg.pattern_reps

    serving = caches is not None
    scanned_caches = caches["scanned"] if serving else tuple(None for _ in range(P))
    rem_caches = caches["rem"] if serving else tuple(
        None for _ in range(cfg.pattern_rem))

    def superblock(carry, inputs):
        x, aux = carry
        layer_params = inputs[0]
        layer_caches = inputs[1]
        new_caches = []
        for pos in range(P):
            kind = cfg.block_pattern[pos]
            cache = layer_caches[pos] if serving else None
            x, nc, aux = _apply_block(x, layer_params[pos], kind, cfg,
                                      positions, cache, aux)
            new_caches.append(nc)
        return (x, aux), tuple(new_caches)

    new_scanned = ()
    if reps:
        fn = jax.checkpoint(superblock,
                            policy=jax.checkpoint_policies.nothing_saveable) \
            if cfg.remat else superblock
        xs_caches = (scanned_caches if serving
                     else tuple(jnp.zeros((reps,)) for _ in range(P)))
        if cfg.scan_layers:
            (x, aux), new_scanned = jax.lax.scan(
                fn, (x, aux), (params["scanned"], xs_caches))
        else:
            # unrolled python loop: identical math, per-layer ops visible to
            # cost_analysis (the dry-run's trip-count-correct probe mode)
            carry = (x, aux)
            ys = []
            for r in range(reps):
                sl = jax.tree_util.tree_map(lambda t: t[r],
                                            (params["scanned"], xs_caches))
                carry, nc = fn(carry, sl)
                ys.append(nc)
            x, aux = carry
            if serving:
                new_scanned = jax.tree_util.tree_map(
                    lambda *z: jnp.stack(z), *ys)

    new_rem = []
    for i in range(cfg.pattern_rem):
        kind = cfg.block_pattern[i]
        x, nc, aux = _apply_block(x, params["rem"][i], kind, cfg, positions,
                                  rem_caches[i] if serving else None, aux)
        new_rem.append(nc)

    x = _norm(x, params["ln_f"], cfg)
    new_caches = ({"scanned": new_scanned, "rem": tuple(new_rem)}
                  if serving else None)
    if return_hidden:
        return x, aux, new_caches
    if cfg.tie_embeddings:
        logits = B.unembed(x, params["embed"], pol)
    else:
        logits = B.linear(x, params["unembed"], pol).astype(jnp.float32)
    logits = shard_activation(logits, "logits")
    return logits, aux, new_caches


def _cache_length(caches, cfg: ModelConfig):
    """Current sequence offset from the first attention cache (if any).

    Dense caches: scalar length.  Paged caches: per-sequence seq_lens,
    returned [B, 1] so `off + arange(S)` broadcasts to ragged positions."""
    for group in (caches["scanned"], caches["rem"]):
        for c in group:
            if isinstance(c, dict) and "seq_lens" in c:
                sl = c["seq_lens"]
                sl = sl[0] if sl.ndim == 2 else sl    # unstack scanned reps
                return sl[:, None]
            if isinstance(c, dict) and "length" in c:
                ln = c["length"]
                return ln[0] if ln.ndim else ln
    return 0
