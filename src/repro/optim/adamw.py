"""AdamW with global-norm clipping and optional low-precision moments.

Pure-JAX (no optax offline).  Moments can be stored bf16 — at 235B params
the optimizer state drops from 8 bytes/param to 4, the difference between
fitting and not fitting v5e HBM at 256 chips (DESIGN.md §5).  Update math
is always f32.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    lr_min_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"     # "bfloat16" for >=100B models


def lr_at(step, cfg: OptConfig):
    warm = cfg.lr_peak * (step + 1) / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr_min_ratio + (1 - cfg.lr_min_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr_peak * cos)


def init_state(params, cfg: OptConfig) -> dict[str, Any]:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        # lifetime count of optimizer updates skipped by the non-finite
        # (NaR) gradient guard; lives in the optimizer state so checkpoint
        # resume preserves it bit-identically
        "nar_skips": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def apply_updates(params, grads, state, cfg: OptConfig, grad_norm=None):
    """Returns (new_params, new_state, metrics).

    grad_norm: precomputed global gradient norm.  The shard_map training
    step passes the mesh-correct norm (model-sharded leaves psum their
    squared sums; a local global_norm would double-count replicated leaves
    or miss TP shards); single-device callers leave it None.

    NaR containment: a non-finite global norm (a NaN/Inf — what a posit
    NaR decodes to — anywhere in the gradient tree propagates into the
    squared-sum) skips the whole update — params, moments, step, and LR
    schedule are carried forward unchanged — and increments
    state["nar_skips"].  The guard is a per-leaf where-select, so the
    happy path is bit-identical to unguarded AdamW and the skip count
    rides the checkpointed optimizer state (resume preserves it).
    """
    gn = global_norm(grads) if grad_norm is None else grad_norm
    ok = jnp.isfinite(gn)
    step = state["step"] + ok.astype(jnp.int32)
    # a NaN gn would make `scale` NaN and poison newp even under the
    # where-select's untaken branch bookkeeping; pin it finite when skipping
    scale = jnp.where(ok, jnp.minimum(1.0, cfg.clip_norm / (gn + 1e-9)), 0.0)
    lr = lr_at(step, cfg)
    mdt = jnp.dtype(cfg.moment_dtype)

    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = jnp.where(ok, g.astype(jnp.float32) * scale, 0.0)
        m32 = m.astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + jnp.square(g) * (1 - cfg.b2)
        mh = m32 / bc1
        vh = v32 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (delta + decay)
        return (jnp.where(ok, newp.astype(p.dtype), p),
                jnp.where(ok, m32.astype(mdt), m),
                jnp.where(ok, v32.astype(mdt), v))

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    skips = (state.get("nar_skips", jnp.zeros((), jnp.int32))
             + (1 - ok.astype(jnp.int32)))
    new_state = {"step": step, "m": new_m, "v": new_v, "nar_skips": skips}
    return new_p, new_state, {"grad_norm": gn, "lr": lr,
                              "nar_skips": skips}
