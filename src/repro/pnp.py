"""`repro.pnp` — numpy-style namespace over first-class posit arrays.

The public, cfg-threading-free API of the reproduction:

    import repro.pnp as pnp
    from repro.core import P16_2

    a = pnp.asarray([1.25, -0.375], P16_2)     # PFCVT: f32 -> posit
    b = pnp.ones((2,), P16_2)
    c = a + b                                  # PADD, format from the array
    d = pnp.fma(a, b, c)                       # PFMADD, one rounding
    m = pnp.matmul(A, B)                       # quire-semantics GEMM
    f = c.to_f32()                             # PFCVT.S back to float

Every function accepts `PositArray` operands and dispatches through
`repro.kernels.ops`, so `use_pallas()` routing (TPU Pallas kernels vs the
pure-jnp reference path) is invisible here.  Python scalars and float
arrays mix in as *values* (correctly rounded into the posit operand's
format); combining two different posit formats raises
`PositConfigMismatchError` — cast explicitly with `.astype()`.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.array import (PositArray, PositConfigMismatchError, is_posit,
                              result_cfg)
from repro.core.types import (P8_0, P8_2, P16_1, P16_2, P32_2, STANDARD,
                              PositConfig)
from repro.quant.policy import posit_cast_ste as ste  # noqa: F401  (jax.grad boundary)

__all__ = [
    "PositArray", "PositConfig", "PositConfigMismatchError", "is_posit",
    "P8_0", "P8_2", "P16_1", "P16_2", "P32_2", "STANDARD",
    "asarray", "frombits", "zeros", "ones", "full", "zeros_like",
    "ones_like", "full_like", "add", "subtract", "multiply", "divide",
    "fma", "reciprocal", "negative", "absolute", "abs", "sign", "where",
    "matmul", "dot", "equal", "not_equal", "less", "less_equal", "greater",
    "greater_equal", "pack", "unpack", "lanes", "ste",
    "to_float32", "to_bfloat16", "astype",
]


# --------------------------------------------------------------------------
# construction
# --------------------------------------------------------------------------
def asarray(x, cfg: PositConfig | None = None) -> PositArray:
    """Values -> PositArray (correctly-rounded encode; PFCVT direction).

    A PositArray input passes through unchanged (cfg, if given, must match —
    use `.astype()` for format conversion).  Int *arrays* are rejected as
    ambiguous; wrap payload bits with `frombits`.
    """
    if isinstance(x, PositArray):
        if cfg is not None and cfg != x.cfg:
            raise PositConfigMismatchError(
                f"asarray cannot silently convert {x.cfg} -> {cfg}; use "
                f".astype()")
        return x
    if cfg is None:
        raise TypeError("asarray needs a cfg when given plain values")
    v = jnp.asarray(x)
    if jnp.issubdtype(v.dtype, jnp.integer) and v.ndim > 0:
        raise TypeError("int arrays are ambiguous (values vs payload bits): "
                        "use pnp.frombits(bits, cfg) for payloads or cast to "
                        "float for values")
    from repro.kernels import ops as kops
    return PositArray(kops.encode(v.astype(jnp.float32), cfg), cfg)


def frombits(bits, cfg: PositConfig) -> PositArray:
    """Wrap existing posit payload ints (no conversion of the bits)."""
    import jax as _jax
    b = jnp.asarray(bits)
    if not jnp.issubdtype(b.dtype, jnp.integer):
        raise TypeError(f"frombits takes payload ints, got {b.dtype}; "
                        f"encode values with pnp.asarray(x, cfg)")
    if not isinstance(b, _jax.core.Tracer) and b.size:
        lo, hi = int(b.min()), int(b.max())
        if lo < -cfg.sign_bit or hi > cfg.mask:
            raise ValueError(
                f"payload {lo}..{hi} outside the {cfg.n}-bit pattern range "
                f"[-{cfg.sign_bit}, {cfg.mask}] — narrowing would wrap")
    return PositArray(b.astype(jnp.dtype(cfg.storage_dtype_name)), cfg)


def zeros(shape, cfg: PositConfig) -> PositArray:
    return PositArray(jnp.zeros(shape, jnp.dtype(cfg.storage_dtype_name)),
                      cfg)


def ones(shape, cfg: PositConfig) -> PositArray:
    one = jnp.asarray(cfg.one_bits, jnp.dtype(cfg.storage_dtype_name))
    return PositArray(jnp.full(shape, one), cfg)


def full(shape, value, cfg: PositConfig) -> PositArray:
    from repro.kernels import ops as kops
    bits = kops.encode(jnp.full(shape, value, jnp.float32), cfg)
    return PositArray(bits, cfg)


def zeros_like(a: PositArray) -> PositArray:
    return zeros(a.shape, a.cfg)


def ones_like(a: PositArray) -> PositArray:
    return ones(a.shape, a.cfg)


def full_like(a: PositArray, value) -> PositArray:
    return full(a.shape, value, a.cfg)


# --------------------------------------------------------------------------
# conversions (PFCVT both directions + format re-round)
# --------------------------------------------------------------------------
def to_float32(a: PositArray) -> jnp.ndarray:
    return a.to_f32()


def to_bfloat16(a: PositArray) -> jnp.ndarray:
    return a.to_bf16()


def astype(a: PositArray, cfg: PositConfig) -> PositArray:
    return a.astype(cfg)


# --------------------------------------------------------------------------
# arithmetic (PADD/PSUB/PMUL/PDIV/PFMADD + inversion, §VI)
# --------------------------------------------------------------------------
def _pa(x, cfg: PositConfig) -> PositArray:
    return x if isinstance(x, PositArray) else asarray(x, cfg)


def add(a, b, cfg: PositConfig | None = None) -> PositArray:
    cfg = result_cfg(a, b, cfg=cfg)
    return _pa(a, cfg) + _pa(b, cfg)


def subtract(a, b, cfg: PositConfig | None = None) -> PositArray:
    cfg = result_cfg(a, b, cfg=cfg)
    return _pa(a, cfg) - _pa(b, cfg)


def multiply(a, b, cfg: PositConfig | None = None) -> PositArray:
    cfg = result_cfg(a, b, cfg=cfg)
    return _pa(a, cfg) * _pa(b, cfg)


def divide(a, b, cfg: PositConfig | None = None, *,
           mode: str = "poly_corrected", nr_rounds: int = 1) -> PositArray:
    """PDIV; mode in {"exact", "poly", "poly_corrected", "pacogen"}."""
    cfg = result_cfg(a, b, cfg=cfg)
    from repro.kernels import ops as kops
    a, b = _pa(a, cfg), _pa(b, cfg)
    return PositArray(kops.divide(a.bits, b.bits, cfg=cfg, mode=mode,
                                  nr_rounds=nr_rounds), cfg)


def fma(a, b, c, cfg: PositConfig | None = None) -> PositArray:
    """round(a*b + c) with a single rounding (PFMADD)."""
    cfg = result_cfg(a, b, c, cfg=cfg)
    from repro.kernels import ops as kops
    a, b, c = _pa(a, cfg), _pa(b, cfg), _pa(c, cfg)
    return PositArray(kops.elementwise("fma", a.bits, b.bits, c.bits,
                                       cfg=cfg), cfg)


def reciprocal(a: PositArray, *, mode: str = "poly_corrected") -> PositArray:
    """1/a (the FPPU inversion op)."""
    return divide(ones_like(a), a, mode=mode)


def negative(a: PositArray) -> PositArray:
    return -a


def absolute(a: PositArray) -> PositArray:
    return a.__abs__()


abs = absolute  # noqa: A001  (numpy-style name)


def sign(a: PositArray) -> PositArray:
    """-1 / 0 / +1 / NaR, as posits of a's format."""
    cfg = a.cfg
    u = jnp.asarray(a.bits).astype(jnp.int32) & cfg.mask
    one = cfg.one_bits
    neg = (u >> (cfg.n - 1)) & 1
    out = jnp.where(u == 0, 0, jnp.where(neg == 1, (-one) & cfg.mask, one))
    out = jnp.where(u == cfg.nar, cfg.nar, out)
    from repro.core.encode import to_storage
    return PositArray(to_storage(out, cfg), cfg)


def where(mask, a, b, cfg: PositConfig | None = None) -> PositArray:
    """Elementwise select; both branches must share one posit format."""
    cfg = result_cfg(a, b, cfg=cfg)
    a, b = _pa(a, cfg), _pa(b, cfg)
    return PositArray(jnp.where(mask, a.bits, b.bits), cfg)


# --------------------------------------------------------------------------
# linear algebra (quire semantics: one rounding per reduction)
# --------------------------------------------------------------------------
def matmul(a: PositArray, b: PositArray, *, out_posit: bool = True):
    """[m,k] @ [k,n] with quire (single-rounding) accumulation.

    out_posit=False returns the raw f32 accumulator (the pw-GEMM serving
    path).
    """
    cfg = result_cfg(a, b)
    from repro.kernels import ops as kops
    out = kops.gemm(_pa(a, cfg).bits, _pa(b, cfg).bits, cfg_a=cfg, cfg_b=cfg,
                    cfg_out=cfg if out_posit else None, out_posit=out_posit)
    return PositArray(out, cfg) if out_posit else out


def dot(a: PositArray, b: PositArray, *, out_posit: bool = True):
    """Fused dot product over the last axis (quire semantics)."""
    cfg = result_cfg(a, b)
    from repro.core.quire import quire_dot
    out = quire_dot(_pa(a, cfg).bits, _pa(b, cfg).bits, cfg,
                    out_posit=out_posit)
    return PositArray(out, cfg) if out_posit else out


# --------------------------------------------------------------------------
# comparisons (free: patterns compare as 2's-complement ints, §VIII)
# --------------------------------------------------------------------------
def equal(a, b):
    return _cmp(a, b, "__eq__")


def not_equal(a, b):
    return _cmp(a, b, "__ne__")


def less(a, b):
    return _cmp(a, b, "__lt__")


def less_equal(a, b):
    return _cmp(a, b, "__le__")


def greater(a, b):
    return _cmp(a, b, "__gt__")


def greater_equal(a, b):
    return _cmp(a, b, "__ge__")


def _cmp(a, b, dunder):
    cfg = result_cfg(a, b)
    return getattr(_pa(a, cfg), dunder)(_pa(b, cfg))


# --------------------------------------------------------------------------
# SIMD packed-word views (paper §VIII-A, C4)
# --------------------------------------------------------------------------
def lanes(a_or_cfg) -> int:
    """SIMD lanes per 32-bit word: 4 for posit8, 2 for posit16."""
    from repro.core.packing import lanes as _lanes
    cfg = a_or_cfg.cfg if isinstance(a_or_cfg, PositArray) else a_or_cfg
    return _lanes(cfg)


def pack(a: PositArray) -> jnp.ndarray:
    """[..., L*k] PositArray -> [..., k] int32 packed words (lane 0 in the
    LSBs, the paper's register convention)."""
    from repro.core.packing import pack_words
    return pack_words(a.bits, a.cfg)


def unpack(words, cfg: PositConfig) -> PositArray:
    """[..., k] int32 packed words -> [..., k*L] PositArray."""
    from repro.core.packing import unpack_words
    return PositArray(unpack_words(words, cfg), cfg)
