"""Posit dtype policy — the paper's formats as first-class tensor formats.

The FPPU gives a core "real number processing capabilities" through an
integer register file (§VI-VII); the LM-framework analogue is a policy that
decides which tensors live as posit payload ints:

  * weights:      linear/embedding tables stored posit8/16; decoded on use
                  (forward), straight-through estimator for gradients (QAT),
                  or plain post-training quantization for serving.
  * kv_cache:     serving KV stored posit; decoded inside the attention
                  kernel (kernels/flash_attention.py).
  * grads:        wire format of the cross-pod gradient collective
                  (distributed/collectives.py).

`PositPolicy(None, ...)` fields disable posit for that tensor class, so the
same model code runs pure-f32/bf16 (the paper's binary32 baseline).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.array import PositArray
from repro.core.convert import f32_to_posit
from repro.core.decode import decode_to_f32
from repro.core.types import PositConfig


@dataclasses.dataclass(frozen=True)
class PositPolicy:
    weights: PositConfig | None = None     # linear/embedding storage format
    kv_cache: PositConfig | None = None    # serving KV-cache format
    grads: PositConfig | None = None       # gradient-collective wire format
    activations: PositConfig | None = None # inter-block activation format

    @property
    def enabled(self) -> bool:
        return any((self.weights, self.kv_cache, self.grads, self.activations))


NONE = PositPolicy()


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def posit_cast_ste(w: jnp.ndarray, cfg: PositConfig) -> jnp.ndarray:
    """f32 -> posit -> f32 round-trip with straight-through gradient.

    Forward sees exactly the values the posit weights will hold (quantization
    -aware); backward passes gradients unchanged (the standard STE used for
    low-bit formats).
    """
    orig = w.dtype
    return decode_to_f32(f32_to_posit(w.astype(jnp.float32), cfg),
                         cfg).astype(orig)


def _ste_fwd(w, cfg):
    return posit_cast_ste(w, cfg), None


def _ste_bwd(cfg, res, g):
    return (g,)


posit_cast_ste.defvjp(_ste_fwd, _ste_bwd)


def quantize_tree(params, cfg: PositConfig, predicate=None):
    """Post-training quantization: f32 param pytree -> PositArray leaves.

    predicate(path_str, leaf) -> bool selects which leaves quantize
    (default: every float array with >= 2 dims — matrices/tables, not
    norm scales or biases, matching the paper's DNN experiments which keep
    normalization in high precision).  Scan-stacked trees
    (models/transformer.py) carry a leading reps dim on every leaf, so a
    norm scale arrives as a 2-D [reps, d] array — the default predicate
    therefore also excludes by name (scale/bias/b/lam), not just by rank.
    Quantized leaves come back as `PositArray` (format bound to the
    payload), so downstream code needs no `cfg` threading.
    """
    flat = jax.tree_util.tree_flatten_with_path(params)
    leaves, treedef = flat

    _KEEP_F32 = ("scale", "bias", "b", "lam")

    def default_pred(path, x):
        leaf_name = path.rstrip("]'").rsplit("'", 1)[-1]
        return (hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating)
                and x.ndim >= 2 and leaf_name not in _KEEP_F32)

    pred = predicate or default_pred
    out = []
    for path, leaf in leaves:
        p = jax.tree_util.keystr(path)
        out.append(PositArray(f32_to_posit(leaf.astype(jnp.float32), cfg), cfg)
                   if pred(p, leaf) else leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def dequantize_tree(params, cfg: PositConfig | None = None):
    """Inverse of quantize_tree: PositArray leaves -> f32.

    `cfg` is only consulted for legacy trees holding raw storage-int leaves
    (the pre-PositArray convention, kept as a deprecated shim).
    """
    def deq(x):
        if isinstance(x, PositArray):
            return x.to_f32()
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.integer):
            if cfg is None:
                raise TypeError("raw int leaf in dequantize_tree without a "
                                "cfg; quantize with quantize_tree to get "
                                "PositArray leaves")
            return decode_to_f32(x, cfg)
        return x
    return jax.tree_util.tree_map(
        deq, params, is_leaf=lambda x: isinstance(x, PositArray))
