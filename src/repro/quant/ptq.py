"""Post-training quantization to posit storage (serving deployment).

Quantizes exactly the leaves the runtime knows how to decode (linear weight
matrices, embedding/expert tables); keeps norms, biases, convs, LoRA and
router weights in f32 (matching the paper's DNN experiments, which keep
normalization wide).  The predicate mirrors distributed/sharding rules.
"""
from __future__ import annotations

import re

import jax
import jax.numpy as jnp

from repro.core.convert import f32_to_posit
from repro.core.types import PositConfig

_QUANT_PATTERNS = [
    r"embed/table$",
    r"unembed/w$",
    r"moe/w_(up|gate|down)$",
    r"(wq|wk|wv|wg|wo|wr|w_up|w_gate|w_down|w_x|w_gate_branch|"
    r"w_input_gate|w_rec_gate|w_out)/w$",
]
_QUANT_RE = [re.compile(p) for p in _QUANT_PATTERNS]


def _path_str(path) -> str:
    parts = []
    for k in path:
        parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
    return "/".join(parts)


def is_quantizable(path_str: str) -> bool:
    return any(p.search(path_str) for p in _QUANT_RE)


def quantize_for_serving(params, cfg: PositConfig):
    """f32 param pytree -> posit storage ints on the quantizable leaves."""
    def q(path, leaf):
        if (is_quantizable(_path_str(path))
                and jnp.issubdtype(leaf.dtype, jnp.floating)):
            return f32_to_posit(leaf.astype(jnp.float32), cfg)
        return leaf
    return jax.tree_util.tree_map_with_path(q, params)


def serving_param_specs(param_shapes, cfg: PositConfig):
    """ShapeDtypeStruct tree -> same tree with posit int dtypes on
    quantizable leaves (for AOT lowering without materializing weights)."""
    dt = jnp.dtype(f"int{cfg.storage_bits}")

    def q(path, leaf):
        if (is_quantizable(_path_str(path))
                and jnp.issubdtype(leaf.dtype, jnp.floating)):
            return jax.ShapeDtypeStruct(leaf.shape, dt)
        return leaf
    return jax.tree_util.tree_map_with_path(q, param_shapes)
