"""Post-training quantization to posit storage (serving deployment).

Quantizes exactly the leaves the runtime knows how to decode (linear weight
matrices, embedding/expert tables); keeps norms, biases, convs, LoRA and
router weights in f32 (matching the paper's DNN experiments, which keep
normalization wide).  The predicate mirrors distributed/sharding rules.
"""
from __future__ import annotations

import re

import jax
import jax.numpy as jnp

from repro.core.array import PositArray
from repro.core.convert import f32_to_posit
from repro.core.types import PositConfig

_QUANT_PATTERNS = [
    r"embed/table$",
    r"unembed/w$",
    r"moe/w_(up|gate|down)$",
    r"(wq|wk|wv|wg|wo|wr|w_up|w_gate|w_down|w_x|w_gate_branch|"
    r"w_input_gate|w_rec_gate|w_out)/w$",
]
_QUANT_RE = [re.compile(p) for p in _QUANT_PATTERNS]


def _path_str(path) -> str:
    parts = []
    for k in path:
        parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
    return "/".join(parts)


def is_quantizable(path_str: str) -> bool:
    return any(p.search(path_str) for p in _QUANT_RE)


def quantize_for_serving(params, cfg: PositConfig):
    """f32 param pytree -> PositArray on the quantizable leaves.

    The format rides with each quantized leaf, so the serving stack
    (models/blocks.py `linear`, `embed`, `unembed`) consumes the weights
    with no cfg threading.
    """
    def q(path, leaf):
        if (is_quantizable(_path_str(path))
                and jnp.issubdtype(leaf.dtype, jnp.floating)):
            return PositArray(f32_to_posit(leaf.astype(jnp.float32), cfg),
                              cfg)
        return leaf
    return jax.tree_util.tree_map_with_path(q, params)


def serving_param_specs(param_shapes, cfg: PositConfig):
    """ShapeDtypeStruct tree -> same tree with PositArray-wrapped posit int
    specs on quantizable leaves (for AOT lowering without materializing
    weights — PositArray is a pytree, so abstract leaves pass through)."""
    dt = jnp.dtype(cfg.storage_dtype_name)

    def q(path, leaf):
        if (is_quantizable(_path_str(path))
                and jnp.issubdtype(leaf.dtype, jnp.floating)):
            return PositArray(jax.ShapeDtypeStruct(leaf.shape, dt), cfg)
        return leaf
    return jax.tree_util.tree_map_with_path(q, param_shapes)
