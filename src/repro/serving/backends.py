"""Pluggable per-layer sequence-cache backends for the serving engine.

What a layer caches per sequence used to be a hardwired attention-KV
assumption; this module makes it a per-layer-kind backend choice:

  * `PagedKVBackend`   — the existing block-paged posit KV pool
                         (attn / attn_local layers; serving/paged_kv.py).
  * `StatePoolBackend` — a fixed-size posit state pool: one quantized state
                         slot per serving slot.  RWKV6 caches the wkv
                         channel-state matrix plus the time/channel-mix
                         token shifts; rGLRU caches the recurrent hidden
                         vector plus the causal-conv tail.  O(1) bytes per
                         sequence vs the KV pool's O(context) — no page
                         tables, no allocation pressure, trivial continuous
                         batching.
  * `HybridLayout`     — the per-config composition: Griffin/RecurrentGemma
                         patterns mix windowed KV pages and state slots
                         side by side; pure-attention and pure-recurrent
                         stacks are the degenerate cases.

Pool state leaves are `PositArray` under a posit KV policy (`cfg.policy
.kv_cache`) and f32 otherwise.  Assembled state caches carry the step's
`seq_lens`/`num_new` scheduler fields exactly like assembled KV caches, so
`transformer._cache_length` and the engine's step plumbing are uniform.

Lifecycle notes:
  * alloc/free is implicit — a state slot belongs to whichever request owns
    the serving slot; `zero_fresh` re-initializes it on the first prefill
    chunk (seq_lens == 0), so freeing is just dropping the slot.
  * preempt-snapshot/resume for state layers is resume-via-re-prefill: the
    engine already requeues a preempted request with its prompt + generated
    tokens, and re-prefilling regenerates the state bit-exactly (the
    per-token posit round-trip makes the scan chunk-invariant).
  * the prefix cache is KV-only by design and the engine disables it for
    patterns with state layers: a recurrent layer must see every token, so
    skipping cached prefix tokens would skip state updates.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.array import PositArray

# ndim of each unstacked state-pool leaf ([max_seqs, ...]); the slot axis of
# a (possibly rep-stacked) leaf sits at `leaf.ndim - base` (0 unstacked, 1
# scan-stacked) — sharding.paged_pool_pspecs uses this to put the data axis
# on the slot dim
_STATE_BASE_NDIM = {"wkv": 4, "tshift": 2, "cshift": 2, "h": 2, "conv": 3}


# --------------------------------------------------------------------------
# state representation helpers (shared by models/* serving paths)
# --------------------------------------------------------------------------
def state_f32(s):
    """Decoded f32 view of a carried state leaf (PositArray or float)."""
    if isinstance(s, PositArray):
        from repro.core.decode import decode_to_f32
        return decode_to_f32(s.bits, s.cfg)
    return jnp.asarray(s, jnp.float32)


def zero_fresh(buf, seq_lens):
    """Zero the slots that start a fresh sequence this step (seq_lens == 0:
    first prefill chunk, or a re-admitted slot after preemption/retirement).
    Posit zero is the all-zeros bit pattern, so zeroing bits == encoding
    0.0; stale slots keep their state untouched."""
    live = (seq_lens > 0).reshape((-1,) + (1,) * (buf.ndim - 1))
    if isinstance(buf, PositArray):
        return PositArray(jnp.where(live, buf.bits, 0), buf.cfg)
    return jnp.where(live, buf, jnp.zeros((), buf.dtype))


def store_state(old, new_f32, num_new):
    """Write `new_f32` back into the pool representation of `old`, only for
    slots that advanced this step (num_new > 0) — inactive slots keep their
    bits exactly (no decode/encode round-trip drift on idle state)."""
    if num_new is None:
        live = None
    else:
        live = (num_new > 0).reshape((-1,) + (1,) * (old.ndim - 1))
    if isinstance(old, PositArray):
        from repro.core.convert import f32_to_posit
        bits = f32_to_posit(new_f32, old.cfg)
        if live is not None:
            bits = jnp.where(live, bits, old.bits)
        return PositArray(bits, old.cfg)
    new = new_f32.astype(old.dtype)
    return new if live is None else jnp.where(live, new, old)


def _state_zeros(shape, pcfg, dtype):
    if pcfg is not None:
        return PositArray(
            jnp.zeros(shape, jnp.dtype(pcfg.storage_dtype_name)), pcfg)
    return jnp.zeros(shape, dtype)


# --------------------------------------------------------------------------
# memory descriptors
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class LayerCacheDesc:
    """What one layer costs per sequence — the exact per-layer accounting
    used by launch/dryrun.py and the serving benchmarks."""
    kind: str                  # block kind: attn / attn_local / rwkv6 / rglru
    backend: str               # "paged_kv" | "state_pool"
    bytes_per_token: int       # KV bytes per cached token (0 for state)
    state_bytes_per_seq: int   # fixed per-seq state bytes (0 for KV)
    window: int | None         # attn_local sliding window, if any

    def bytes_per_seq(self, context: int, page_size: int) -> int:
        """Cache bytes one sequence holds at `context` tokens.  Windowed KV
        counts only live pages (sliding-window reclamation frees expired
        ones): a window of W tokens spans at most ceil(W/page)+1 pages."""
        if self.backend == "state_pool":
            return self.state_bytes_per_seq
        live = context
        if self.window is not None:
            live = min(context, self.window + page_size)
        n_pages = -(-live // page_size) if live else 0
        return n_pages * page_size * self.bytes_per_token


def _elem_bytes(cfg, dtype) -> int:
    pcfg = cfg.policy.kv_cache
    if pcfg is not None:
        return pcfg.storage_bits // 8
    return jnp.dtype(dtype).itemsize


# --------------------------------------------------------------------------
# backends
# --------------------------------------------------------------------------
class PagedKVBackend:
    """The block-paged posit KV pool (serving/paged_kv.py) behind the
    backend protocol."""
    backend = "paged_kv"
    needs_pages = True
    supports_prefix_cache = True

    def __init__(self, kind: str):
        self.kind = kind

    def init_layer(self, cfg, num_pages, page_size, max_seqs, dtype):
        from repro.serving.paged_kv import init_layer_pages
        return init_layer_pages(num_pages, cfg.n_kv, page_size, cfg.hd,
                                cfg.policy.kv_cache, dtype)

    def assemble(self, pool, page_table, seq_lens, num_new):
        from repro.serving.paged_kv import assemble_layer_cache
        return assemble_layer_cache(pool, page_table, seq_lens, num_new)

    def extract(self, cache):
        from repro.serving.paged_kv import extract_layer_pages
        return extract_layer_pages(cache)

    def copy_page(self, pool, src, dst, stacked=False):
        from repro.serving.paged_kv import copy_layer_pages
        return copy_layer_pages(pool, src, dst, stacked=stacked)

    def desc(self, cfg, page_size, dtype=jnp.float32) -> LayerCacheDesc:
        w = _elem_bytes(cfg, dtype)
        return LayerCacheDesc(
            kind=self.kind, backend=self.backend,
            bytes_per_token=2 * cfg.n_kv * cfg.hd * w,
            state_bytes_per_seq=0,
            window=cfg.window if self.kind == "attn_local" else None)


class StatePoolBackend:
    """Fixed-size per-slot recurrent state, posit-quantized when the KV
    policy is set.  No pages, no growth: `init_layer` sizes the pool at
    max_seqs and the engine's slot index doubles as the state index."""
    backend = "state_pool"
    needs_pages = False
    supports_prefix_cache = False

    def __init__(self, kind: str):
        if kind not in ("rwkv6", "rglru"):
            raise ValueError(f"no state-pool layout for block kind {kind!r}")
        self.kind = kind

    def _shapes(self, cfg, max_seqs):
        d = cfg.d_model
        if self.kind == "rwkv6":
            dh = cfg.rwkv_head_dim
            H = d // dh
            return {"wkv": (max_seqs, H, dh, dh), "tshift": (max_seqs, d),
                    "cshift": (max_seqs, d)}
        from repro.models.griffin import CONV_WIDTH
        return {"h": (max_seqs, d), "conv": (max_seqs, CONV_WIDTH - 1, d)}

    def init_layer(self, cfg, num_pages, page_size, max_seqs, dtype):
        if max_seqs < 1:
            raise ValueError(
                f"state-pool layer ({self.kind}) needs max_seqs >= 1")
        pcfg = cfg.policy.kv_cache
        return {k: _state_zeros(shape, pcfg, dtype)
                for k, shape in self._shapes(cfg, max_seqs).items()}

    def assemble(self, pool, page_table, seq_lens, num_new):
        # page_table is ignored: state is slot-indexed, not paged
        return {**pool, "seq_lens": seq_lens, "num_new": num_new}

    def extract(self, cache):
        return {k: v for k, v in cache.items()
                if k not in ("seq_lens", "num_new")}

    def copy_page(self, pool, src, dst, stacked=False):
        # prefix-cache COW is KV-only; state pools have no pages to copy
        return pool

    def desc(self, cfg, page_size, dtype=jnp.float32) -> LayerCacheDesc:
        w = _elem_bytes(cfg, dtype)
        elems = sum(int(jnp.prod(jnp.asarray(shape[1:])))
                    for shape in self._shapes(cfg, 1).values())
        return LayerCacheDesc(kind=self.kind, backend=self.backend,
                              bytes_per_token=0,
                              state_bytes_per_seq=elems * w, window=None)


def backend_for(kind: str, cfg) -> PagedKVBackend | StatePoolBackend:
    if kind in ("attn", "attn_local"):
        return PagedKVBackend(kind)
    return StatePoolBackend(kind)


class HybridLayout:
    """Per-pattern-position backend composition for one model config."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.backends = tuple(backend_for(k, cfg)
                              for k in cfg.block_pattern)

    @property
    def needs_pages(self) -> bool:
        return any(b.needs_pages for b in self.backends)

    @property
    def has_state(self) -> bool:
        return any(not b.needs_pages for b in self.backends)

    @property
    def supports_prefix_cache(self) -> bool:
        return all(b.supports_prefix_cache for b in self.backends)

    def descs(self, page_size, dtype=jnp.float32) -> list[LayerCacheDesc]:
        """One descriptor per physical layer, remainder included (the
        pattern cycles: layer i uses block_pattern[i % P])."""
        P = len(self.cfg.block_pattern)
        return [self.backends[i % P].desc(self.cfg, page_size, dtype)
                for i in range(self.cfg.n_layers)]

    def cache_bytes_per_seq(self, context: int, page_size: int,
                            dtype=jnp.float32) -> int:
        return sum(d.bytes_per_seq(context, page_size)
                   for d in self.descs(page_size, dtype))


def layout_for(cfg) -> HybridLayout:
    return HybridLayout(cfg)
