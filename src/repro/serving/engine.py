"""Serving engines: synchronized-batch (dense cache) and continuous-batching
(paged posit KV cache).

`generate` is the original synchronized engine — one batch, everyone
prefills together, everyone decodes until the longest request finishes.  It
remains the oracle the paged engine is tested against (identical batches
must produce bit-identical logits) and the baseline
benchmarks/serving_decode.py measures against.

`PagedServingEngine` is the production shape: a host-side scheduler admits
requests into sequence slots mid-flight, chunk-prefills their prompts,
decodes all active slots in one fused step over the paged pool
(serving/paged_kv.py), retires finished sequences and hands their pages to
waiting requests immediately.  On the Pallas path both step shapes are
fully fused attention: decode through paged_flash_decode, prefill chunks
(any Sq, softcap, window) through paged_flash_prefill — the gather_kv dense
materialization never runs on TPU (paged_kv.GATHER_FALLBACKS counts any
regression), so time-to-first-token streams KV at posit width end to end.  Out-of-pages triggers preemption (youngest
sequence requeued, pages freed), so the engine degrades gracefully instead
of OOMing.  Every device step runs through exactly two jitted callables
(one prefill-chunk shape, one decode shape) built once per model config and
shared across engines — zero retrace at steady state.  Two scheduling
policies keep mixed-length traffic fast: the page-table width is bucketed
to powers of two over the *participating* slots only (a short prompt's
prefill chunks never pay a 4k-token neighbor's width; bounded extra traces,
one per bucket), and admissions are batched so one prefill stall amortizes
over several waiting prompts instead of interrupting decode per freed slot.

Sampling happens on device inside the jitted step (greedy argmax or
jax.random temperature sampling): a step's device->host traffic is the
[max_seqs] int32 sampled tokens, never the [max_seqs, vocab] logits.  With
a `mesh`, the step becomes one shard_map over ("data", "model"): sequence
slots/pages data-parallel, weights Megatron tensor-parallel (see
_sharded_paged_step) — the host scheduler is a pure page/slot bookkeeper
and is identical in both modes.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
from collections import deque

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.transformer import (ModelConfig, assemble_paged_caches,
                                      copy_paged_pages, extract_paged_pages,
                                      forward, init_caches, init_paged_pages)
from repro.serving.backends import layout_for
from repro.serving.paged_kv import (GATHER_FALLBACKS, PagePool,
                                    reclaimable_pages)
from repro.serving.prefix_cache import RadixIndex

# python-body executions of the traced step fns — i.e. trace counts.  Tests
# assert the steady state adds zero entries here (the retrace regression).
STEP_TRACES: collections.Counter = collections.Counter()


def prefill_step(params, cfg: ModelConfig, tokens, caches):
    logits, _, caches = forward(params, cfg, tokens=tokens, caches=caches)
    return logits[:, -1], caches


def decode_step(params, cfg: ModelConfig, token, caches):
    """token [B, 1] -> (next-token logits [B, vocab], new caches)."""
    logits, _, caches = forward(params, cfg, tokens=token, caches=caches)
    return logits[:, -1], caches


def sample(logits, key, temperature: float = 0.0):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature, axis=-1)


@functools.lru_cache(maxsize=64)
def _dense_steps(cfg: ModelConfig):
    """Jitted prefill/decode steps, built once per model config.

    generate() used to rebuild `jax.jit(lambda ...)` wrappers per call,
    which made every call (and every distinct max_new via the fresh cache
    shape) retrace.  The lru_cache keys the jitted objects on the hashable
    ModelConfig, so steady-state serving reuses one trace per shape.

    The cache argument is donated: without it every dense step held the
    previous *and* the next KV cache live in HBM (2x the cache footprint,
    while the paged step already donated its pool); with donation XLA
    aliases the output cache onto the input buffers, asserted by
    tests/test_serving_paged.py::test_dense_steps_donate_cache_buffers."""
    def pf(p, t, c):
        STEP_TRACES[("dense_prefill", cfg.name)] += 1
        return prefill_step(p, cfg, t, c)

    def dc(p, t, c):
        STEP_TRACES[("dense_decode", cfg.name)] += 1
        return decode_step(p, cfg, t, c)

    return (jax.jit(pf, donate_argnums=(2,)),
            jax.jit(dc, donate_argnums=(2,)))


def generate(params, cfg: ModelConfig, prompts: jnp.ndarray, max_new: int,
             max_len: int | None = None, temperature: float = 0.0,
             seed: int = 0):
    """prompts [B, S] int32 -> generated [B, max_new] int32 (batched)."""
    B, S = prompts.shape
    max_len = max_len or (S + max_new)
    caches = init_caches(cfg, B, max_len, dtype=jnp.dtype(cfg.dtype))

    pf, dc = _dense_steps(cfg)

    logits, caches = pf(params, prompts, caches)
    key = jax.random.PRNGKey(seed)
    out = []
    tok = sample(logits, key, temperature)[:, None].astype(jnp.int32)
    out.append(tok)
    for i in range(max_new - 1):
        key, sub = jax.random.split(key)
        logits, caches = dc(params, tok, caches)
        tok = sample(logits, sub, temperature)[:, None].astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


# ==========================================================================
# continuous batching over the paged pool
# ==========================================================================
def _sample_on_device(last, *, greedy: bool, temperature, seed, step_idx,
                      slot_offset, tp_axis: str | None = None,
                      vocab_sharded: bool = False):
    """Sample next tokens [B] int32 from last-position logits, inside the
    jitted step — the host never sees a [B, vocab] array (the old engine
    pulled the full logits to numpy every decode step, a blocking
    device->host sync on the hottest loop; serving.engine._sample_host
    survives only as the tests' parity oracle).

    Keyed fold_in(fold_in(PRNGKey(seed), step), global_slot): slot_offset
    is this shard's first global slot id, so the data-sharded step draws
    the same per-slot streams as the single-device one.  Vocab-sharded
    logits (TP unembed) reduce via sharded_argmax (O(B) ints cross the
    mesh) for greedy; temperature gathers the vocab shards first.
    """
    if greedy:
        if vocab_sharded:
            from repro.distributed.collectives import sharded_argmax
            return sharded_argmax(last, tp_axis)
        return jnp.argmax(last, axis=-1).astype(jnp.int32)
    if vocab_sharded:
        from repro.distributed.collectives import gather_vocab_shards
        last = gather_vocab_shards(last, tp_axis)
    B = last.shape[0]
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step_idx)
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        key, slot_offset + jnp.arange(B))
    logits = last / jnp.maximum(temperature, 1e-6)
    return jax.vmap(jax.random.categorical)(keys, logits).astype(jnp.int32)


def _step_body(cfg: ModelConfig, greedy: bool, p, tokens, pages, pt, sl, nn,
               temp, seed, step_idx, *, slot_offset=0, tp_size: int = 1,
               vocab_sharded: bool = False, compress=None):
    """The paged serving step, shared verbatim by the single-device and the
    mesh-sharded builders (under shard_map the tensor_parallel context and
    the shard's slot_offset are the only differences — keeping one body
    means a sampling or last-position fix cannot diverge between them)."""
    from repro.distributed.collectives import tensor_parallel

    with tensor_parallel("model", tp_size, vocab_sharded, compress):
        caches = assemble_paged_caches(pages, pt, sl, nn)
        logits, _, new_caches = forward(p, cfg, tokens=tokens, caches=caches)
    # last *valid* position per slot (ragged prefill chunks)
    idx = jnp.clip(nn - 1, 0, tokens.shape[1] - 1)
    last = jnp.take_along_axis(logits, idx[:, None, None], axis=1)[:, 0]
    toks = _sample_on_device(last, greedy=greedy, temperature=temp,
                             seed=seed, step_idx=step_idx,
                             slot_offset=slot_offset,
                             tp_axis="model" if tp_size > 1 else None,
                             vocab_sharded=vocab_sharded)
    return toks, extract_paged_pages(new_caches)


@functools.lru_cache(maxsize=64)
def _paged_step(cfg: ModelConfig, greedy: bool = True):
    """The fused paged serving step, jitted once per (model config, sampling
    mode) and shared by every engine instance (a per-engine jit would
    recompile identical shapes for each engine — e.g. one per benchmark
    repetition).  Returns ([max_seqs] int32 sampled tokens, new pages) —
    token ids are the only device->host traffic a step produces."""
    def step(p, tokens, pages, pt, sl, nn, temp, seed, step_idx):
        STEP_TRACES[("paged_step", cfg.name, tokens.shape[1],
                     pt.shape[1])] += 1
        return _step_body(cfg, greedy, p, tokens, pages, pt, sl, nn, temp,
                          seed, step_idx)

    return jax.jit(step, donate_argnums=(2,))


@functools.lru_cache(maxsize=16)
def _sharded_paged_step(cfg: ModelConfig, mesh, greedy: bool = True,
                        compress=None):
    """The mesh-sharded paged serving step: one shard_map over the
    ("data", "model") mesh, jitted once per (config, mesh, sampling mode).

    data axis:  sequence slots — tokens/page_table/seq_lens/num_new rows
        and a private page sub-pool per shard (the host scheduler allocates
        shard-locally, so table entries are local page ids everywhere).
    model axis: Megatron TP — column/row-parallel weights per
        distributed.sharding.serving_param_pspecs, kv-head-sharded pages,
        one psum per block (posit-compressed via `compress`, off by default
        to keep single-device bit-parity), vocab-parallel embed/unembed
        when the vocab divides.

    Sampling runs on device inside the shard_map (a host round-trip per
    token would serialize the mesh): the step returns only the [max_seqs]
    int32 token ids, data-sharded like the slots.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import (paged_pool_pspecs,
                                            serving_param_pspecs)

    ndata, ntp = mesh.shape["data"], mesh.shape["model"]
    vocab_sharded = ntp > 1 and cfg.vocab % ntp == 0

    def body(p, tokens, pages, pt, sl, nn, temp, seed, step_idx):
        STEP_TRACES[("sharded_paged_step", cfg.name, ndata, ntp,
                     tokens.shape[1], pt.shape[1])] += 1
        return _step_body(
            cfg, greedy, p, tokens, pages, pt, sl, nn, temp, seed, step_idx,
            slot_offset=jax.lax.axis_index("data") * tokens.shape[0],
            tp_size=ntp, vocab_sharded=vocab_sharded, compress=compress)

    def step(p, tokens, pages, pt, sl, nn, temp, seed, step_idx):
        data_rows = P("data", None)
        return shard_map(
            body, mesh=mesh,
            in_specs=(serving_param_pspecs(p, mesh), data_rows,
                      paged_pool_pspecs(pages, mesh), data_rows,
                      P("data"), P("data"), P(), P(), P()),
            out_specs=(P("data"), paged_pool_pspecs(pages, mesh)),
            check_rep=False,
        )(p, tokens, pages, pt, sl, nn, temp, seed, step_idx)

    return jax.jit(step, donate_argnums=(2,))


@functools.lru_cache(maxsize=64)
def _paged_copy(cfg: ModelConfig):
    """Jitted whole-tree page copy (the device half of copy-on-write),
    once per model config like the step fns.  Donates the pools so the
    copy aliases in place instead of doubling the pool's HBM."""
    def cp(pages, src, dst):
        return copy_paged_pages(pages, src, dst)

    return jax.jit(cp, donate_argnums=(0,))


@functools.lru_cache(maxsize=16)
def _sharded_paged_copy(cfg: ModelConfig, mesh):
    """shard_map page copy: src/dst are [ndata] *shard-local* page ids
    (copy-on-write never crosses sub-pools — dedup is shard-local so DP
    stays bit-parity with the single-device engine).  Shards with nothing
    to copy get (0, 0): the garbage page copied onto itself, a no-op."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import paged_pool_pspecs

    def step(pages, src, dst):
        def body(pages, src, dst):
            return copy_paged_pages(pages, src[0], dst[0])

        specs = paged_pool_pspecs(pages, mesh)
        return shard_map(body, mesh=mesh,
                         in_specs=(specs, P("data"), P("data")),
                         out_specs=specs, check_rep=False)(pages, src, dst)

    return jax.jit(step, donate_argnums=(0,))


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [S] int32
    max_new: int
    # tokens generated before a preemption: the resumed request re-prefills
    # prompt+prior and only owes max_new - len(prior) more tokens, but the
    # caller still receives all of them
    prior: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((0,), np.int32))


@dataclasses.dataclass
class _Slot:
    req: Request
    admit_order: int
    pages: list                  # page ids owned, in position order
    prefill_pos: int = 0         # prompt tokens already written
    generated: list = dataclasses.field(default_factory=list)
    next_token: int = -1         # token to feed at the next decode step
    # prefix-cache bookkeeping: deepest radix node whose page this slot
    # holds (parent for the next registration), and the token count whose
    # pages are already registered/matched in the index
    node: object = None
    reg_pos: int = 0

    @property
    def phase(self) -> str:
        return "prefill" if self.prefill_pos < len(self.req.prompt) \
            else "decode"

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.req.max_new


class PagedServingEngine:
    """Continuous-batching serving over pluggable per-layer sequence caches.

    params/cfg as for generate().  Each layer kind maps to a
    serving/backends.py cache backend: attention layers live in the paged
    (optionally posit) KV pool; recurrent layers (rwkv6/rglru) live in a
    fixed-size posit *state pool* — one quantized state slot per sequence
    slot, O(1) in context length.  Hybrid patterns (recurrentgemma) mix
    both.  The host scheduler below is backend-agnostic: slots/admission/
    preemption are identical, paging simply no-ops for state layers (a
    state slot is owned by whichever request holds the sequence slot and is
    zeroed on first prefill chunk, so preempt/resume is resume-via-
    re-prefill with no extra bookkeeping).  The prefix cache is KV-only and
    auto-disables for patterns with recurrent layers — a state slot is not
    content-addressable by token prefix the way an immutable KV page is.
    For all-attn_local patterns (no prefix cache), fully expired
    sliding-window pages are freed eagerly after every step, so a long
    windowed decode holds O(window) pages, not O(context).

    max_seqs:     sequence slots (the fused step's batch dimension)
    page_size:    tokens per KV page
    table_width:  max pages per sequence (caps sequence length)
    num_pages:    total pool size; default fits max_seqs full-length
        sequences (+1 garbage page per data shard)
    prefill_chunk: prompt tokens written per prefill step (fixed shape)
    admit_threshold: batch admissions — hold freed slots until this many
        are free (or nothing is decoding / a prefill phase is already
        running) so one prefill stall amortizes over several prompts;
        default max_seqs // 2, 0 = admit eagerly
    prefix_cache: content-addressed prefix caching over the page pool
        (serving/prefix_cache.py), on by default.  Full pages of admitted
        prompts (and of generated continuations) register in a per-shard
        radix index keyed by a chained hash of the token chunks (keyed per
        model/KV-format/page-size); a later request's admission looks up
        its longest cached prefix, shares those pages (ref-counted) and
        starts chunked prefill at the first uncached token — warm
        time-to-first-token skips the shared prefix entirely, bit-identical
        to a cold prefill because the pages hold exactly the bits a cold
        run would recompute.  Writes into a shared page copy-on-write
        first; idle cached pages LRU-evict under pool pressure *before*
        any live sequence is preempted.  prefill_chunk is aligned down to
        a page_size multiple so the cached-page skip never splits a page.
    mesh:         a ("data", "model") jax Mesh (launch.mesh) — the fused
        step becomes one shard_map over it: sequence slots, page tables and
        a private page sub-pool per data shard; Megatron-TP weights and
        kv-head-sharded pages over the model axis (MoE blocks shard their
        *experts* over it instead — expert-parallel grouped GEMM with the
        router replicated, see models/moe.py; requires n_experts % ntp ==
        0); sampling stays on device (the step moves O(max_seqs) ints,
        never logits).  None (default): the single-device step, unchanged.
    tp_compress:  optional PositConfig — posit-compress the gather half of
        the per-block TP psums (distributed.collectives).  Profitable on
        slow inter-chip links; costs the wire quantization, so exact
        single-device parity holds only when off.
    """

    def __init__(self, params, cfg: ModelConfig, *, max_seqs: int = 8,
                 page_size: int = 64, table_width: int = 16,
                 num_pages: int | None = None, prefill_chunk: int = 128,
                 temperature: float = 0.0, seed: int = 0,
                 bucket_pages: bool = True,
                 admit_threshold: int | None = None,
                 prefix_cache: bool = True,
                 mesh=None, tp_compress=None):
        self.params, self.cfg = params, cfg
        self.max_seqs, self.page = max_seqs, page_size
        self.width = table_width
        self.layout = layout_for(cfg)
        self._needs_pages = self.layout.needs_pages
        self._recurrent = self.layout.has_state
        # chunk boundaries align to page_size multiples: warm prefill
        # resumes at a cached-page boundary, so a chunk that straddled a
        # page would re-prefill part of a cached page (or leave one
        # part-written).  Rounds down, floor one page.
        self.chunk = max(page_size, (prefill_chunk // page_size) * page_size)
        self.temperature = temperature
        self.bucket_pages = bucket_pages
        self.admit_threshold = (max_seqs // 2 if admit_threshold is None
                                else admit_threshold)
        self.mesh = mesh
        if mesh is not None:
            ndata, ntp = mesh.shape["data"], mesh.shape["model"]
            if max_seqs % ndata != 0:
                raise ValueError(f"max_seqs={max_seqs} must divide over the "
                                 f"data axis ({ndata})")
            if self._recurrent and ntp > 1:
                # sharding.py lays state pools out head-sharded on the
                # model axis, but the serving step's TP contexts only wrap
                # the attention/MLP projections — recurrent serving shards
                # data-parallel only (strategy_for makes the same call for
                # training).  Reject rather than silently mis-shard.
                raise ValueError(
                    "recurrent/hybrid patterns serve data-parallel only; "
                    f"use a mesh with model axis 1 (got {ntp})")
            dims = [(cfg.n_heads, "n_heads"), (cfg.n_kv, "n_kv")]
            if cfg.moe is None:
                dims.append((cfg.d_ff, "d_ff"))
            else:
                # MoE blocks shard the *expert* dim over the model axis
                # (expert-parallel grouped GEMM, one psum per block); each
                # expert's d_ff stays whole on its shard
                dims.append((cfg.moe.n_experts, "moe.n_experts"))
            for dim, nm in dims:
                if dim % ntp != 0:
                    raise ValueError(f"cfg.{nm}={dim} must divide the model "
                                     f"axis ({ntp}) for TP serving")
            self.n_shards = ndata
        else:
            self.n_shards = 1
        self.slots_per_shard = max_seqs // self.n_shards
        if num_pages is None:
            if self._needs_pages:
                num_pages = self.n_shards * (self.slots_per_shard
                                             * table_width + 1)
            else:
                # pure-recurrent: no KV layer reads the pool; keep the
                # garbage page plus one allocatable page per shard so the
                # page bookkeeping stays well-formed at negligible cost
                num_pages = 2 * self.n_shards
        if num_pages % self.n_shards != 0:
            raise ValueError(f"num_pages={num_pages} must divide over the "
                             f"data axis ({self.n_shards})")
        self.num_pages = num_pages
        self.pages_per_shard = num_pages // self.n_shards
        self.pages = init_paged_pages(cfg, num_pages, page_size,
                                      dtype=jnp.dtype(cfg.dtype),
                                      max_seqs=max_seqs)
        if mesh is not None:
            from repro.distributed.sharding import (paged_pool_pspecs,
                                                    serving_param_pspecs,
                                                    to_shardings)
            self.pages = jax.device_put(
                self.pages,
                to_shardings(paged_pool_pspecs(self.pages, mesh), mesh))
            # place the weights per the TP specs once, up front: params
            # committed to one device would otherwise be resharded onto the
            # mesh by GSPMD at *every* step call — O(param bytes) per decode
            # step on the loop this engine keeps at O(max_seqs) ints
            self.params = jax.device_put(
                self.params,
                to_shardings(serving_param_pspecs(self.params, mesh), mesh))
        # host scheduler state; local page 0 of every shard is its reserved
        # garbage page, and the table holds *shard-local* page ids (the
        # device step only ever sees its own sub-pool)
        self._pools = [PagePool(self.pages_per_shard)
                       for _ in range(self.n_shards)]
        # one radix index per data shard: page ids are shard-local and
        # pages cannot migrate between sub-pools, so dedup staying
        # shard-local is what keeps DP bit-parity with one device
        self._prefix = None
        self._copy_fn = None
        if prefix_cache and not self.layout.supports_prefix_cache:
            # state slots are mutable accumulators, not content-addressed
            # immutable pages — prefix caching cleanly no-ops for any
            # pattern with recurrent layers
            prefix_cache = False
        if prefix_cache:
            key = (f"{cfg.name}|kv={cfg.policy.kv_cache}|page={page_size}"
                   f"|n_kv={cfg.n_kv}|hd={cfg.hd}")
            self._prefix = [RadixIndex(key, page_size)
                            for _ in range(self.n_shards)]
            self._copy_fn = (_paged_copy(cfg) if mesh is None
                             else _sharded_paged_copy(cfg, mesh))
        self.table = np.zeros((max_seqs, table_width), np.int32)
        self.seq_lens = np.zeros((max_seqs,), np.int32)
        self.slots: list[_Slot | None] = [None] * max_seqs
        self.waiting: deque[Request] = deque()
        self._admitted = 0
        self._next_rid = 0
        self._rng = np.random.default_rng(seed)
        self._seed = int(seed) % (2 ** 31 - 1)
        self._step_idx = 0
        self.finished: dict[int, np.ndarray] = {}
        self.counters = collections.Counter()
        self._gather_base = self._moe_base = self._rec_base = 0
        # eager sliding-window page reclamation: sound only when *every*
        # attention layer is windowed (a full-attn layer still reads old
        # pages) and the prefix cache is off (a cached page must stay
        # resident for future prefix hits, not be recycled)
        attn_kinds = [k for k in cfg.block_pattern
                      if k in ("attn", "attn_local")]
        self._reclaim_window = (
            cfg.window
            if (attn_kinds and all(k == "attn_local" for k in attn_kinds)
                and cfg.window and self._prefix is None)
            else None)
        self.reset_stats()

        greedy = temperature <= 0.0
        if mesh is None:
            self._step_fn = _paged_step(cfg, greedy)
        else:
            self._step_fn = _sharded_paged_step(cfg, mesh, greedy,
                                                tp_compress)

    # ---- host-side paging ------------------------------------------------
    def _shard(self, i: int) -> int:
        """Data shard owning sequence slot i (0 when unsharded)."""
        return i // self.slots_per_shard

    @property
    def free_pages(self) -> list[int]:
        """All free (shard-local) page ids, across shards.  Idle *cached*
        prefix pages are not free — they are resident until evicted (see
        cached_pages)."""
        return [p for pool in self._pools for p in pool.free_list]

    @property
    def cached_pages(self) -> int:
        """Pages pinned by the prefix index across shards (some may also
        be live-referenced by sequences)."""
        return sum(pool.n_cached for pool in self._pools)

    def _evict_one(self, shard: int) -> bool:
        """LRU-evict one idle cached prefix page from `shard`'s index back
        to the free stack.  Runs *before* preemption ever does: a cached
        page nobody references must die before live work is rolled back."""
        if self._prefix is None:
            return False
        pool = self._pools[shard]
        pg = self._prefix[shard].evict_lru(pool.is_idle)
        if pg is None:
            return False
        pool.uncache(pg)
        self.counters["evicted_pages"] += 1
        return True

    def _alloc_page(self, i: int) -> int:
        """One fresh page for slot i's shard: the free stack, else LRU
        eviction of idle cached prefix pages, else preemption of a live
        sequence (strictly in that order)."""
        pool = self._pools[self._shard(i)]
        while True:
            pg = pool.try_alloc()
            if pg is not None:
                return pg
            if self._evict_one(self._shard(i)):
                continue
            if not self._preempt(exclude=i):
                raise RuntimeError(
                    "KV pool exhausted and nothing left to evict or "
                    "preempt; grow num_pages or lower max_seqs")

    def _ensure_pages(self, i: int, upto: int):
        """Slot i needs capacity for `upto` tokens; allocate from its
        shard's sub-pool (evicting idle cached pages, then preempting
        within the shard, if it runs dry)."""
        slot = self.slots[i]
        if not self._needs_pages:
            return                   # state-pool-only layout: no KV pages
        need = -(-upto // self.page)
        if need > self.width:
            raise ValueError(f"request {slot.req.rid}: {upto} tokens exceed "
                             f"table_width*page_size = {self.width * self.page}")
        while len(slot.pages) < need:
            pg = self._alloc_page(i)
            self.table[i, len(slot.pages)] = pg
            slot.pages.append(pg)

    def _free_slot(self, i: int):
        slot = self.slots[i]
        pool = self._pools[self._shard(i)]
        for pg in slot.pages:
            if pg:                   # 0 = reclaimed-window placeholder
                pool.decref(pg)      # cached prefix pages stay resident
        self.table[i, :] = 0
        self.seq_lens[i] = 0
        self.slots[i] = None

    def _maybe_cow(self, i: int):
        """Copy-on-write: the next step writes slot i's KV starting at
        seq_lens[i]; when that lands *mid-page* in a page the prefix index
        or another sequence shares, copy the page device-side and point
        slot i's table entry at the private copy first.  (Writes starting
        at a page boundary always land in a freshly allocated page, so
        only the first page of the write range can ever be shared.)"""
        slot = self.slots[i]
        if self._prefix is None or slot is None:
            return
        p0 = int(self.seq_lens[i])
        j = p0 // self.page
        if p0 % self.page == 0 or j >= len(slot.pages):
            return
        pg = slot.pages[j]
        pool = self._pools[self._shard(i)]
        if pool.ref_count(pg) <= 1 and not pool.is_cached(pg):
            return                   # private page: write in place
        new = self._alloc_page(i)
        self._device_copy(self._shard(i), pg, new)
        pool.decref(pg)
        slot.pages[j] = new
        self.table[i, j] = new
        self.counters["cow_copies"] += 1

    def _device_copy(self, shard: int, src: int, dst: int):
        """Device page copy (bit-exact for posit pages: raw bits move)."""
        if self.mesh is None:
            self.pages = self._copy_fn(self.pages, jnp.int32(src),
                                       jnp.int32(dst))
        else:
            s = np.zeros((self.n_shards,), np.int32)
            d = np.zeros((self.n_shards,), np.int32)
            s[shard], d[shard] = src, dst      # others: garbage no-op copy
            self.pages = self._copy_fn(self.pages, jnp.asarray(s),
                                       jnp.asarray(d))

    def _attach_prefix(self, i: int):
        """Longest-cached-prefix attach at admission: share the matched
        pages (ref-counted) and start chunked prefill at the first
        uncached token.  At least one prompt token is always re-fed so the
        step produces first-token logits — a fully cached page-aligned
        prompt keeps all its pages and re-feeds only the final token
        (whose mid-page write then triggers copy-on-write)."""
        slot = self.slots[i]
        if self._prefix is None:
            return
        shard = self._shard(i)
        idx, pool = self._prefix[shard], self._pools[shard]
        pages, node = idx.lookup(slot.req.prompt, self._step_idx)
        L = len(slot.req.prompt)
        cached = min(len(pages) * self.page, L - 1)
        if not pages or cached <= 0:
            self.counters["prefix_misses"] += 1
            return
        for j, pg in enumerate(pages):
            pool.incref(pg)
            self.table[i, j] = pg
        slot.pages = list(pages)
        slot.node = node
        slot.reg_pos = len(pages) * self.page
        slot.prefill_pos = cached
        self.seq_lens[i] = cached
        self.counters["prefix_hits"] += 1
        self.counters["prefix_hit_tokens"] += cached

    def _register(self, i: int):
        """Register slot i's newly filled pages in its shard's radix index
        (each page's content address covers the whole token prefix it
        completes).  An identical page already cached gets *adopted*: the
        slot's table entry swaps to the existing page and its own copy
        frees — safe because both hold bit-identical KV."""
        slot = self.slots[i]
        if self._prefix is None or slot is None:
            return
        written = int(self.seq_lens[i])
        if slot.reg_pos + self.page > written:
            return
        shard = self._shard(i)
        idx, pool = self._prefix[shard], self._pools[shard]
        if slot.node is None:
            slot.node = idx.root
        stream = np.concatenate([slot.req.prompt,
                                 np.asarray(slot.generated, np.int32)])
        while slot.reg_pos + self.page <= written:
            j = slot.reg_pos // self.page
            chunk = stream[slot.reg_pos:slot.reg_pos + self.page]
            node, existing = idx.insert(slot.node, chunk, slot.pages[j],
                                        self._step_idx)
            if existing is not None and existing != slot.pages[j]:
                pool.incref(existing)
                pool.decref(slot.pages[j])     # private copy -> freed
                slot.pages[j] = existing
                self.table[i, j] = existing
                self.counters["deduped_pages"] += 1
            elif existing is None:
                pool.cache(slot.pages[j])
            slot.node = node
            slot.reg_pos += self.page

    def _preempt(self, exclude: int) -> bool:
        """Evict the youngest other sequence *in the same shard* (pages
        cannot migrate between sub-pools): free its pages and requeue it
        (prompt + generated so far) at the front of the wait queue."""
        shard = self._shard(exclude)
        victims = [(s.admit_order, i) for i, s in enumerate(self.slots)
                   if s is not None and i != exclude
                   and self._shard(i) == shard]
        if not victims:
            return False
        _, i = max(victims)
        slot = self.slots[i]
        req = slot.req
        # restart from the full prompt + whatever was already generated
        gen = np.asarray(slot.generated, np.int32)
        new_prompt = np.concatenate([req.prompt, gen])
        remaining = req.max_new - len(slot.generated)
        self.waiting.appendleft(Request(req.rid, new_prompt, remaining,
                                        prior=np.concatenate([req.prior,
                                                              gen])))
        self._free_slot(i)
        self.counters["preempted"] += 1
        return True

    def _admit(self):
        if not self.waiting:
            return
        # admission batching: a mid-flight admission stalls every decoding
        # slot for the new prompt's chunk steps, so hold freed slots until
        # several can prefill together.  Admit immediately when a prefill
        # phase is already running (joining it is ~free), when nothing is
        # decoding (nothing to stall), or when enough slots accumulated.
        phases = [s.phase for s in self.slots if s is not None]
        n_free = self.max_seqs - len(phases)
        if ("decode" in phases and "prefill" not in phases
                and n_free < max(1, self.admit_threshold)):
            return
        while self.waiting:
            req = self.waiting[0]
            # pick the free slot whose shard caches the longest prefix of
            # this prompt (ties -> lowest slot, the pre-prefix-cache
            # behavior); a slot only qualifies when the pages the prompt
            # still needs fit its shard's free + evictable headroom
            best = None
            for i in range(self.max_seqs):
                if self.slots[i] is not None:
                    continue
                pool = self._pools[self._shard(i)]
                hit = (self._prefix[self._shard(i)].probe(req.prompt)
                       if self._prefix is not None else 0)
                n_match = hit // self.page
                need = -(-(len(req.prompt) + 1) // self.page) - n_match
                avail = pool.n_free + max(0, pool.n_evictable - n_match)
                if self._needs_pages and need > avail:
                    continue
                cached = min(hit, len(req.prompt) - 1)
                if best is None or (cached, -i) > best[0]:
                    best = ((cached, -i), i)
            if best is None:
                if self.active == 0:
                    raise RuntimeError(
                        f"request {req.rid} does not fit the idle pool "
                        f"({len(self.free_pages)} free pages across "
                        f"{self.n_shards} shard(s)); grow num_pages")
                return
            i = best[1]
            self.waiting.popleft()
            self.slots[i] = _Slot(req=req, admit_order=self._admitted,
                                  pages=[])
            self._admitted += 1
            self.counters["admitted"] += 1
            if self._recurrent:
                # the sequence slot *is* the state-pool slot; its state
                # leaves are zeroed device-side on the first prefill chunk
                # (seq_lens == 0 -> backends.zero_fresh)
                self.counters["state_slot_allocs"] += 1
            self._attach_prefix(i)

    # ---- public API ------------------------------------------------------
    def submit(self, prompt, max_new: int, rid: int | None = None) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) == 0:
            # an empty prompt would enter decode with the -1 sentinel as a
            # real token (wrapping to the last vocab row); reject instead
            raise ValueError("prompt must contain at least one token")
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        if self._needs_pages and len(prompt) + max_new > self.width * self.page:
            # page-table capacity only binds layouts with KV layers; pure
            # state-pool sequences are O(1) in length
            raise ValueError(f"prompt+max_new = {len(prompt) + max_new} "
                             f"exceeds per-sequence capacity "
                             f"{self.width * self.page}")
        if rid is None:
            rid = self._next_rid
        elif (rid in self.finished
              or any(r.rid == rid for r in self.waiting)
              or any(s is not None and s.req.rid == rid
                     for s in self.slots)):
            # a colliding rid would silently overwrite the other request's
            # results in `finished`
            raise ValueError(f"request id {rid} is already in use")
        self._next_rid = max(self._next_rid, rid + 1)
        if self._prefix is not None:
            # submit-time longest-cached-prefix probe (read-only: the
            # authoritative, LRU-touching lookup happens at admission,
            # when the slot — hence the shard — is known)
            self.counters["prefix_probe_tokens"] += max(
                idx.probe(prompt) for idx in self._prefix)
        self.waiting.append(Request(rid, prompt, max_new))
        return rid

    @property
    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    # ---- observability ---------------------------------------------------
    def stats(self) -> dict:
        """Scheduler + prefix-cache counters (the serving bench prints
        this).  Fallback counters are process-global; they are reported as
        deltas since engine construction or the last reset_stats()."""
        from repro.kernels.ops import RECURRENT_FALLBACKS
        from repro.models.moe import DENSE_MOE_FALLBACKS
        d = {k: 0 for k in ("admitted", "finished", "preempted",
                            "prefill_steps", "decode_steps",
                            "prefix_hits", "prefix_misses",
                            "prefix_hit_tokens", "prefix_probe_tokens",
                            "evicted_pages", "cow_copies",
                            "deduped_pages", "state_slot_allocs",
                            "expired_page_frees")}
        d.update(self.counters)
        d["gather_fallbacks"] = (sum(GATHER_FALLBACKS.values())
                                 - self._gather_base)
        d["dense_moe_fallbacks"] = (sum(DENSE_MOE_FALLBACKS.values())
                                    - self._moe_base)
        d["recurrent_fallbacks"] = (sum(RECURRENT_FALLBACKS.values())
                                    - self._rec_base)
        d["free_pages"] = sum(p.n_free for p in self._pools)
        d["cached_pages"] = self.cached_pages
        return d

    def reset_stats(self):
        """Zero the counters and re-baseline the global fallback counters
        (the tests' reset hook; several drains can share one engine)."""
        from repro.kernels.ops import RECURRENT_FALLBACKS
        from repro.models.moe import DENSE_MOE_FALLBACKS
        self.counters.clear()
        self._gather_base = sum(GATHER_FALLBACKS.values())
        self._moe_base = sum(DENSE_MOE_FALLBACKS.values())
        self._rec_base = sum(RECURRENT_FALLBACKS.values())

    def _sample_host(self, logits_row: np.ndarray) -> int:
        """Host-side sampling oracle.  The engine samples on device inside
        the jitted step (_sample_on_device) — this survives only so tests
        can check greedy parity against independently computed logits."""
        if self.temperature <= 0.0:
            return int(np.argmax(logits_row))
        z = logits_row.astype(np.float64) / self.temperature
        z = z - z.max()
        p = np.exp(z)
        p /= p.sum()
        return int(self._rng.choice(len(p), p=p))

    def _table_view(self, participants):
        """Power-of-two bucketed page-table slice sized to the sequences
        that actually compute this step (each bucket compiles once).

        Prefill steps pass only the prefilling slots: a short prompt then
        pays its own width even while a 4k-token sequence sits in a decode
        slot (that slot's num_new is 0 — its outputs are ignored and its
        writes dropped, so truncating its pages out of the view is safe)."""
        if not self.bucket_pages:
            return self.table
        used = max([len(self.slots[i].pages) for i in participants
                    if self.slots[i] is not None], default=1)
        w = 1
        while w < max(used, 1):
            w *= 2
        w = min(max(w, 1), self.width)
        return self.table[:, :w]

    def _run_step(self, tokens: np.ndarray, num_new: np.ndarray,
                  participants) -> np.ndarray:
        """Run the fused step; returns the sampled token per slot
        ([max_seqs] int32 — the step's only device->host transfer)."""
        pt = jnp.asarray(self._table_view(participants))
        sl = jnp.asarray(self.seq_lens)
        nn = jnp.asarray(num_new)
        toks, self.pages = self._step_fn(
            self.params, jnp.asarray(tokens), self.pages, pt, sl, nn,
            jnp.float32(self.temperature), jnp.int32(self._seed),
            jnp.int32(self._step_idx))
        self._step_idx += 1
        self.seq_lens += num_new
        self._reclaim_expired()
        return np.asarray(toks)

    def _reclaim_expired(self):
        """Free KV pages every token of which has slid out of the attention
        window (all-attn_local patterns, prefix cache off — see __init__).
        Freed table entries point at the garbage page; the window mask
        already excludes those positions on every attention path (Pallas
        decode/prefill kernels and the jnp fallback), so recycled pages can
        hold another sequence's KV without being read.  slot.pages keeps a
        0 placeholder so later positions stay index-aligned."""
        if self._reclaim_window is None:
            return
        for i, slot in enumerate(self.slots):
            if slot is None:
                continue
            n = reclaimable_pages(int(self.seq_lens[i]),
                                  self._reclaim_window, self.page)
            pool = self._pools[self._shard(i)]
            for j in range(min(n, len(slot.pages))):
                pg = slot.pages[j]
                if pg:
                    pool.decref(pg)
                    slot.pages[j] = 0
                    self.table[i, j] = 0
                    self.counters["expired_page_frees"] += 1

    def step(self) -> list[tuple[int, int]]:
        """One scheduler iteration; returns (rid, token) pairs emitted."""
        # retire finished sequences, then fill freed slots from the queue
        for i, slot in enumerate(self.slots):
            if slot is not None and slot.done:
                self.finished[slot.req.rid] = np.concatenate(
                    [slot.req.prior, np.asarray(slot.generated, np.int32)])
                self._free_slot(i)
                self.counters["finished"] += 1
        self._admit()

        prefilling = [i for i, s in enumerate(self.slots)
                      if s is not None and s.phase == "prefill"]
        emitted: list[tuple[int, int]] = []
        if prefilling:
            # page in first: allocation may preempt a slot (even one in
            # `prefilling`), so the batch is built only from survivors.
            # _maybe_cow runs after paging: a warm slot resuming mid-page
            # (fully cached page-aligned prompt) must write into a private
            # copy, never the shared page.
            for i in prefilling:
                s = self.slots[i]
                if s is None:
                    continue
                part_len = min(self.chunk,
                               len(s.req.prompt) - s.prefill_pos)
                self._ensure_pages(i, int(self.seq_lens[i]) + part_len)
                self._maybe_cow(i)
            alive = [i for i in prefilling if self.slots[i] is not None]
            if not alive:
                return emitted
            tokens = np.zeros((self.max_seqs, self.chunk), np.int32)
            num_new = np.zeros((self.max_seqs,), np.int32)
            for i in alive:
                s = self.slots[i]
                part = s.req.prompt[s.prefill_pos:s.prefill_pos + self.chunk]
                tokens[i, :len(part)] = part
                num_new[i] = len(part)
            toks = self._run_step(tokens, num_new, alive)
            for i in alive:
                s = self.slots[i]
                s.prefill_pos += int(num_new[i])
                if s.phase == "decode":
                    tok = int(toks[i])
                    s.generated.append(tok)
                    s.next_token = tok
                    emitted.append((s.req.rid, tok))
                self._register(i)
            self.counters["prefill_steps"] += 1
            return emitted

        decoding = [i for i, s in enumerate(self.slots)
                    if s is not None and s.phase == "decode" and not s.done]
        if not decoding:
            return emitted
        for i in decoding:
            if self.slots[i] is not None:
                self._ensure_pages(i, int(self.seq_lens[i]) + 1)
                self._maybe_cow(i)
        decoding = [i for i in decoding if self.slots[i] is not None]
        if not decoding:
            return emitted
        tokens = np.zeros((self.max_seqs, 1), np.int32)
        num_new = np.zeros((self.max_seqs,), np.int32)
        for i in decoding:
            tokens[i, 0] = self.slots[i].next_token
            num_new[i] = 1
        toks = self._run_step(tokens, num_new, decoding)
        for i in decoding:
            s = self.slots[i]
            tok = int(toks[i])
            s.generated.append(tok)
            s.next_token = tok
            emitted.append((s.req.rid, tok))
            self._register(i)
        self.counters["decode_steps"] += 1
        return emitted

    def run(self, requests=None, max_steps: int | None = None
            ) -> dict[int, np.ndarray]:
        """Drain: submit `requests` (iterable of (prompt, max_new)) and step
        until everything finished.  Returns {rid: generated tokens}."""
        if requests is not None:
            for prompt, max_new in requests:
                self.submit(prompt, max_new)
        steps = 0
        while self.waiting or self.active:
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return dict(self.finished)
