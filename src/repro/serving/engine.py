"""Serving engines: synchronized-batch (dense cache) and continuous-batching
(paged posit KV cache).

`generate` is the original synchronized engine — one batch, everyone
prefills together, everyone decodes until the longest request finishes.  It
remains the oracle the paged engine is tested against (identical batches
must produce bit-identical logits) and the baseline
benchmarks/serving_decode.py measures against.

`PagedServingEngine` is the production shape: a host-side scheduler admits
requests into sequence slots mid-flight, chunk-prefills their prompts,
decodes all active slots in one fused step over the paged pool
(serving/paged_kv.py), retires finished sequences and hands their pages to
waiting requests immediately.  On the Pallas path both step shapes are
fully fused attention: decode through paged_flash_decode, prefill chunks
(any Sq, softcap, window) through paged_flash_prefill — the gather_kv dense
materialization never runs on TPU (paged_kv.GATHER_FALLBACKS counts any
regression), so time-to-first-token streams KV at posit width end to end.  Out-of-pages triggers preemption (youngest
sequence requeued, pages freed), so the engine degrades gracefully instead
of OOMing.  Every device step runs through exactly two jitted callables
(one prefill-chunk shape, one decode shape) built once per model config and
shared across engines — zero retrace at steady state.  Two scheduling
policies keep mixed-length traffic fast: the page-table width is bucketed
to powers of two over the *participating* slots only (a short prompt's
prefill chunks never pay a 4k-token neighbor's width; bounded extra traces,
one per bucket), and admissions are batched so one prefill stall amortizes
over several waiting prompts instead of interrupting decode per freed slot.

Sampling happens on device inside the jitted step (greedy argmax or
jax.random temperature sampling): a step's device->host traffic is the
[max_seqs] int32 sampled tokens, never the [max_seqs, vocab] logits.  With
a `mesh`, the step becomes one shard_map over ("data", "model"): sequence
slots/pages data-parallel, weights Megatron tensor-parallel (see
_sharded_paged_step) — the host scheduler is a pure page/slot bookkeeper
and is identical in both modes.

Robustness contract (the chaos-hardened layer; serving/faults.py injects,
tests/test_chaos_serving.py asserts): every submitted request resolves to
exactly one structured outcome —

    completed     all requested tokens generated
    rejected      admission backpressure (bounded queue / pool capacity)
    expired       per-request deadline or step-TTL hit; partial tokens kept
    failed_nar    NaR/non-finite detected in the request's output logits
    failed_fault  its device step failed twice; the slot is quarantined

— and a drain never raises, no matter how oversubscribed the pool is or
what faults the step path throws.  NaR detection runs on device inside the
jitted step: a per-slot O(1) finiteness reduction over the last-position
logits (posit NaR decodes to NaN in the f32 logit domain, so one check
covers NaR-poisoned KV pages, activations and genuine numerical blowup)
whose [max_seqs] bool rides back with the sampled tokens — no extra host
sync on the happy path.  Outcomes and per-request partial tokens live in
`engine.outcomes`; `stats()` carries the full outcome/fault counter set.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
from collections import deque

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.transformer import (ModelConfig, assemble_paged_caches,
                                      copy_paged_pages, extract_paged_pages,
                                      forward, init_caches, init_paged_pages,
                                      poison_paged_pages)
from repro.serving.backends import layout_for
from repro.serving.faults import InjectedFault, as_injector
from repro.serving.paged_kv import (GARBAGE_PAGE, GATHER_FALLBACKS, PagePool,
                                    PoolExhausted, reclaimable_pages)
from repro.serving.prefix_cache import RadixIndex

# python-body executions of the traced step fns — i.e. trace counts.  Tests
# assert the steady state adds zero entries here (the retrace regression).
STEP_TRACES: collections.Counter = collections.Counter()


def prefill_step(params, cfg: ModelConfig, tokens, caches):
    logits, _, caches = forward(params, cfg, tokens=tokens, caches=caches)
    return logits[:, -1], caches


def decode_step(params, cfg: ModelConfig, token, caches):
    """token [B, 1] -> (next-token logits [B, vocab], new caches)."""
    logits, _, caches = forward(params, cfg, tokens=token, caches=caches)
    return logits[:, -1], caches


def sample(logits, key, temperature: float = 0.0):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature, axis=-1)


@functools.lru_cache(maxsize=64)
def _dense_steps(cfg: ModelConfig):
    """Jitted prefill/decode steps, built once per model config.

    generate() used to rebuild `jax.jit(lambda ...)` wrappers per call,
    which made every call (and every distinct max_new via the fresh cache
    shape) retrace.  The lru_cache keys the jitted objects on the hashable
    ModelConfig, so steady-state serving reuses one trace per shape.

    The cache argument is donated: without it every dense step held the
    previous *and* the next KV cache live in HBM (2x the cache footprint,
    while the paged step already donated its pool); with donation XLA
    aliases the output cache onto the input buffers, asserted by
    tests/test_serving_paged.py::test_dense_steps_donate_cache_buffers."""
    def pf(p, t, c):
        STEP_TRACES[("dense_prefill", cfg.name)] += 1
        return prefill_step(p, cfg, t, c)

    def dc(p, t, c):
        STEP_TRACES[("dense_decode", cfg.name)] += 1
        return decode_step(p, cfg, t, c)

    return (jax.jit(pf, donate_argnums=(2,)),
            jax.jit(dc, donate_argnums=(2,)))


def generate(params, cfg: ModelConfig, prompts: jnp.ndarray, max_new: int,
             max_len: int | None = None, temperature: float = 0.0,
             seed: int = 0):
    """prompts [B, S] int32 -> generated [B, max_new] int32 (batched)."""
    B, S = prompts.shape
    max_len = max_len or (S + max_new)
    caches = init_caches(cfg, B, max_len, dtype=jnp.dtype(cfg.dtype))

    pf, dc = _dense_steps(cfg)

    logits, caches = pf(params, prompts, caches)
    key = jax.random.PRNGKey(seed)
    out = []
    tok = sample(logits, key, temperature)[:, None].astype(jnp.int32)
    out.append(tok)
    for i in range(max_new - 1):
        key, sub = jax.random.split(key)
        logits, caches = dc(params, tok, caches)
        tok = sample(logits, sub, temperature)[:, None].astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


# ==========================================================================
# continuous batching over the paged pool
# ==========================================================================
def _sample_on_device(last, *, greedy: bool, temperature, seed, step_idx,
                      slot_offset, tp_axis: str | None = None,
                      vocab_sharded: bool = False):
    """Sample next tokens [B] int32 from last-position logits, inside the
    jitted step — the host never sees a [B, vocab] array (the old engine
    pulled the full logits to numpy every decode step, a blocking
    device->host sync on the hottest loop; serving.engine._sample_host
    survives only as the tests' parity oracle).

    Keyed fold_in(fold_in(PRNGKey(seed), step), global_slot): slot_offset
    is this shard's first global slot id, so the data-sharded step draws
    the same per-slot streams as the single-device one.  Vocab-sharded
    logits (TP unembed) reduce via sharded_argmax (O(B) ints cross the
    mesh) for greedy; temperature gathers the vocab shards first.
    """
    if greedy:
        if vocab_sharded:
            from repro.distributed.collectives import sharded_argmax
            return sharded_argmax(last, tp_axis)
        return jnp.argmax(last, axis=-1).astype(jnp.int32)
    if vocab_sharded:
        from repro.distributed.collectives import gather_vocab_shards
        last = gather_vocab_shards(last, tp_axis)
    B = last.shape[0]
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step_idx)
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        key, slot_offset + jnp.arange(B))
    logits = last / jnp.maximum(temperature, 1e-6)
    return jax.vmap(jax.random.categorical)(keys, logits).astype(jnp.int32)


def _step_body(cfg: ModelConfig, greedy: bool, p, tokens, pages, pt, sl, nn,
               temp, seed, step_idx, poison, *, slot_offset=0,
               tp_size: int = 1, vocab_sharded: bool = False, compress=None):
    """The paged serving step, shared verbatim by the single-device and the
    mesh-sharded builders (under shard_map the tensor_parallel context and
    the shard's slot_offset are the only differences — keeping one body
    means a sampling or last-position fix cannot diverge between them).

    poison [B] bool: chaos-injected NaR-poisoned activations — the flagged
    slots' last-position logits are overwritten with NaN *on device*, which
    is exactly what a NaR reaching the unembed decodes to.  Returns a third
    output, nar [B] bool: the per-slot NaR detector — one finiteness
    reduction over each slot's own logits row (posit NaR -> NaN in the f32
    logit domain, eq. (4) pattern check landed after decode), so a poisoned
    KV page, a poisoned activation or a real numerical blowup all trip it,
    and only for the slot that produced it.  The flags ride back with the
    sampled tokens; the happy path pays no extra host sync."""
    from repro.distributed.collectives import tensor_parallel

    with tensor_parallel("model", tp_size, vocab_sharded, compress):
        caches = assemble_paged_caches(pages, pt, sl, nn)
        logits, _, new_caches = forward(p, cfg, tokens=tokens, caches=caches)
    # last *valid* position per slot (ragged prefill chunks)
    idx = jnp.clip(nn - 1, 0, tokens.shape[1] - 1)
    last = jnp.take_along_axis(logits, idx[:, None, None], axis=1)[:, 0]
    last = jnp.where(poison[:, None], jnp.float32(jnp.nan), last)
    nar = jnp.any(~jnp.isfinite(last), axis=-1)
    if tp_size > 1 and vocab_sharded:
        # each model member sees only its vocab shard of `last`; a NaR in
        # any shard must flag the slot on every member (O(B) ints)
        nar = jax.lax.psum(nar.astype(jnp.int32), "model") > 0
    toks = _sample_on_device(last, greedy=greedy, temperature=temp,
                             seed=seed, step_idx=step_idx,
                             slot_offset=slot_offset,
                             tp_axis="model" if tp_size > 1 else None,
                             vocab_sharded=vocab_sharded)
    # a NaR'd row samples garbage (argmax over NaNs) — the host discards
    # the token for flagged slots and fails the request instead
    return toks, nar, extract_paged_pages(new_caches)


@functools.lru_cache(maxsize=64)
def _paged_step(cfg: ModelConfig, greedy: bool = True):
    """The fused paged serving step, jitted once per (model config, sampling
    mode) and shared by every engine instance (a per-engine jit would
    recompile identical shapes for each engine — e.g. one per benchmark
    repetition).  Returns ([max_seqs] int32 sampled tokens, [max_seqs]
    bool NaR flags, new pages) — the token ids and per-slot flags are the
    only device->host traffic a step produces, still O(max_seqs)."""
    def step(p, tokens, pages, pt, sl, nn, temp, seed, step_idx, poison):
        STEP_TRACES[("paged_step", cfg.name, tokens.shape[1],
                     pt.shape[1])] += 1
        return _step_body(cfg, greedy, p, tokens, pages, pt, sl, nn, temp,
                          seed, step_idx, poison)

    return jax.jit(step, donate_argnums=(2,))


@functools.lru_cache(maxsize=16)
def _sharded_paged_step(cfg: ModelConfig, mesh, greedy: bool = True,
                        compress=None):
    """The mesh-sharded paged serving step: one shard_map over the
    ("data", "model") mesh, jitted once per (config, mesh, sampling mode).

    data axis:  sequence slots — tokens/page_table/seq_lens/num_new rows
        and a private page sub-pool per shard (the host scheduler allocates
        shard-locally, so table entries are local page ids everywhere).
    model axis: Megatron TP — column/row-parallel weights per
        distributed.sharding.serving_param_pspecs, kv-head-sharded pages,
        one psum per block (posit-compressed via `compress`, off by default
        to keep single-device bit-parity), vocab-parallel embed/unembed
        when the vocab divides.

    Sampling runs on device inside the shard_map (a host round-trip per
    token would serialize the mesh): the step returns only the [max_seqs]
    int32 token ids, data-sharded like the slots.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import (paged_pool_pspecs,
                                            serving_param_pspecs)

    ndata, ntp = mesh.shape["data"], mesh.shape["model"]
    vocab_sharded = ntp > 1 and cfg.vocab % ntp == 0

    def body(p, tokens, pages, pt, sl, nn, temp, seed, step_idx, poison):
        STEP_TRACES[("sharded_paged_step", cfg.name, ndata, ntp,
                     tokens.shape[1], pt.shape[1])] += 1
        return _step_body(
            cfg, greedy, p, tokens, pages, pt, sl, nn, temp, seed, step_idx,
            poison,
            slot_offset=jax.lax.axis_index("data") * tokens.shape[0],
            tp_size=ntp, vocab_sharded=vocab_sharded, compress=compress)

    def step(p, tokens, pages, pt, sl, nn, temp, seed, step_idx, poison):
        data_rows = P("data", None)
        return shard_map(
            body, mesh=mesh,
            in_specs=(serving_param_pspecs(p, mesh), data_rows,
                      paged_pool_pspecs(pages, mesh), data_rows,
                      P("data"), P("data"), P(), P(), P(), P("data")),
            out_specs=(P("data"), P("data"),
                       paged_pool_pspecs(pages, mesh)),
            check_rep=False,
        )(p, tokens, pages, pt, sl, nn, temp, seed, step_idx, poison)

    return jax.jit(step, donate_argnums=(2,))


@functools.lru_cache(maxsize=64)
def _paged_copy(cfg: ModelConfig):
    """Jitted whole-tree page copy (the device half of copy-on-write),
    once per model config like the step fns.  Donates the pools so the
    copy aliases in place instead of doubling the pool's HBM."""
    def cp(pages, src, dst):
        return copy_paged_pages(pages, src, dst)

    return jax.jit(cp, donate_argnums=(0,))


@functools.lru_cache(maxsize=16)
def _sharded_paged_copy(cfg: ModelConfig, mesh):
    """shard_map page copy: src/dst are [ndata] *shard-local* page ids
    (copy-on-write never crosses sub-pools — dedup is shard-local so DP
    stays bit-parity with the single-device engine).  Shards with nothing
    to copy get (0, 0): the garbage page copied onto itself, a no-op."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import paged_pool_pspecs

    def step(pages, src, dst):
        def body(pages, src, dst):
            return copy_paged_pages(pages, src[0], dst[0])

        specs = paged_pool_pspecs(pages, mesh)
        return shard_map(body, mesh=mesh,
                         in_specs=(specs, P("data"), P("data")),
                         out_specs=specs, check_rep=False)(pages, src, dst)

    return jax.jit(step, donate_argnums=(0,))


@functools.lru_cache(maxsize=64)
def _paged_poison(cfg: ModelConfig):
    """Jitted whole-tree NaR page poison (the chaos harness's bit-flipped
    page), once per model config; donates the pools like the copy fn."""
    def po(pages, pg):
        return poison_paged_pages(pages, pg)

    return jax.jit(po, donate_argnums=(0,))


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [S] int32
    max_new: int
    # tokens generated before a preemption: the resumed request re-prefills
    # prompt+prior and only owes max_new - len(prior) more tokens, but the
    # caller still receives all of them
    prior: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((0,), np.int32))
    # graceful-degradation fields: a step-based TTL and/or an absolute
    # wall-clock deadline; both survive preemption (the re-queued Request
    # keeps the original submission's clock)
    ttl_steps: int | None = None       # device steps from submission
    deadline_t: float | None = None    # absolute time.time() cutoff
    submit_step: int = 0               # engine._step_idx at submission


@dataclasses.dataclass
class RequestOutcome:
    """How one request resolved — the structured result every submission
    gets exactly one of (never an unhandled exception):

      completed     tokens == everything asked for
      rejected      backpressure: bounded queue or pool capacity; tokens
                    hold whatever was generated before the reject (empty
                    for submit-time rejections); retry_after_steps is the
                    backoff hint for queue-full rejections
      expired       deadline/TTL hit; tokens are the partial prefix
      failed_nar    NaR detected in this request's logits; tokens are the
                    clean prefix generated before the poison
      failed_fault  device step failed twice; slot quarantined
    """
    rid: int
    status: str
    tokens: np.ndarray
    detail: str = ""
    retry_after_steps: int | None = None
    step: int = 0                 # engine._step_idx at resolution
    time_s: float = 0.0           # wall clock at resolution


OUTCOMES = ("completed", "rejected", "expired", "failed_nar", "failed_fault")


@dataclasses.dataclass
class _Slot:
    req: Request
    admit_order: int
    pages: list                  # page ids owned, in position order
    prefill_pos: int = 0         # prompt tokens already written
    generated: list = dataclasses.field(default_factory=list)
    next_token: int = -1         # token to feed at the next decode step
    # prefix-cache bookkeeping: deepest radix node whose page this slot
    # holds (parent for the next registration), and the token count whose
    # pages are already registered/matched in the index
    node: object = None
    reg_pos: int = 0

    @property
    def phase(self) -> str:
        return "prefill" if self.prefill_pos < len(self.req.prompt) \
            else "decode"

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.req.max_new


class PagedServingEngine:
    """Continuous-batching serving over pluggable per-layer sequence caches.

    params/cfg as for generate().  Each layer kind maps to a
    serving/backends.py cache backend: attention layers live in the paged
    (optionally posit) KV pool; recurrent layers (rwkv6/rglru) live in a
    fixed-size posit *state pool* — one quantized state slot per sequence
    slot, O(1) in context length.  Hybrid patterns (recurrentgemma) mix
    both.  The host scheduler below is backend-agnostic: slots/admission/
    preemption are identical, paging simply no-ops for state layers (a
    state slot is owned by whichever request holds the sequence slot and is
    zeroed on first prefill chunk, so preempt/resume is resume-via-
    re-prefill with no extra bookkeeping).  The prefix cache is KV-only and
    auto-disables for patterns with recurrent layers — a state slot is not
    content-addressable by token prefix the way an immutable KV page is.
    For all-attn_local patterns (no prefix cache), fully expired
    sliding-window pages are freed eagerly after every step, so a long
    windowed decode holds O(window) pages, not O(context).

    max_seqs:     sequence slots (the fused step's batch dimension)
    page_size:    tokens per KV page
    table_width:  max pages per sequence (caps sequence length)
    num_pages:    total pool size; default fits max_seqs full-length
        sequences (+1 garbage page per data shard)
    prefill_chunk: prompt tokens written per prefill step (fixed shape)
    admit_threshold: batch admissions — hold freed slots until this many
        are free (or nothing is decoding / a prefill phase is already
        running) so one prefill stall amortizes over several prompts;
        default max_seqs // 2, 0 = admit eagerly
    prefix_cache: content-addressed prefix caching over the page pool
        (serving/prefix_cache.py), on by default.  Full pages of admitted
        prompts (and of generated continuations) register in a per-shard
        radix index keyed by a chained hash of the token chunks (keyed per
        model/KV-format/page-size); a later request's admission looks up
        its longest cached prefix, shares those pages (ref-counted) and
        starts chunked prefill at the first uncached token — warm
        time-to-first-token skips the shared prefix entirely, bit-identical
        to a cold prefill because the pages hold exactly the bits a cold
        run would recompute.  Writes into a shared page copy-on-write
        first; idle cached pages LRU-evict under pool pressure *before*
        any live sequence is preempted.  prefill_chunk is aligned down to
        a page_size multiple so the cached-page skip never splits a page.
    mesh:         a ("data", "model") jax Mesh (launch.mesh) — the fused
        step becomes one shard_map over it: sequence slots, page tables and
        a private page sub-pool per data shard; Megatron-TP weights and
        kv-head-sharded pages over the model axis (MoE blocks shard their
        *experts* over it instead — expert-parallel grouped GEMM with the
        router replicated, see models/moe.py; requires n_experts % ntp ==
        0); sampling stays on device (the step moves O(max_seqs) ints,
        never logits).  None (default): the single-device step, unchanged.
    tp_compress:  optional PositConfig — posit-compress the gather half of
        the per-block TP psums (distributed.collectives).  Profitable on
        slow inter-chip links; costs the wire quantization, so exact
        single-device parity holds only when off.
    max_waiting:  bounded admission queue (backpressure).  A submit that
        finds the queue full resolves immediately as `rejected` with a
        retry_after_steps hint instead of growing the queue without bound.
        None (default): unbounded, the pre-robustness behavior.
    default_ttl_steps / default_deadline_s: per-request defaults for
        submit()'s ttl_steps/deadline_s (None = no deadline).  An expired
        request is cancelled at the next scheduler iteration: its pages and
        state slot return to the pool and it resolves as `expired` with the
        partial tokens generated so far.
    chaos:        a serving.faults.ChaosConfig/ChaosInjector — seeded fault
        injection on the step path (simulated device failures, NaR-poisoned
        activations, bit-flipped KV pages, stragglers).  Page poison
        requires mesh=None (the injector targets shard-local page ids).
        None (default): no injection; the detection/containment paths stay
        active for real faults either way.
    """

    def __init__(self, params, cfg: ModelConfig, *, max_seqs: int = 8,
                 page_size: int = 64, table_width: int = 16,
                 num_pages: int | None = None, prefill_chunk: int = 128,
                 temperature: float = 0.0, seed: int = 0,
                 bucket_pages: bool = True,
                 admit_threshold: int | None = None,
                 prefix_cache: bool = True,
                 mesh=None, tp_compress=None,
                 max_waiting: int | None = None,
                 default_ttl_steps: int | None = None,
                 default_deadline_s: float | None = None,
                 chaos=None):
        self.params, self.cfg = params, cfg
        self.max_seqs, self.page = max_seqs, page_size
        self.width = table_width
        self.layout = layout_for(cfg)
        self._needs_pages = self.layout.needs_pages
        self._recurrent = self.layout.has_state
        # chunk boundaries align to page_size multiples: warm prefill
        # resumes at a cached-page boundary, so a chunk that straddled a
        # page would re-prefill part of a cached page (or leave one
        # part-written).  Rounds down, floor one page.
        self.chunk = max(page_size, (prefill_chunk // page_size) * page_size)
        self.temperature = temperature
        self.bucket_pages = bucket_pages
        self.admit_threshold = (max_seqs // 2 if admit_threshold is None
                                else admit_threshold)
        self.mesh = mesh
        if mesh is not None:
            ndata, ntp = mesh.shape["data"], mesh.shape["model"]
            if max_seqs % ndata != 0:
                raise ValueError(f"max_seqs={max_seqs} must divide over the "
                                 f"data axis ({ndata})")
            if self._recurrent and ntp > 1:
                # sharding.py lays state pools out head-sharded on the
                # model axis, but the serving step's TP contexts only wrap
                # the attention/MLP projections — recurrent serving shards
                # data-parallel only (strategy_for makes the same call for
                # training).  Reject rather than silently mis-shard.
                raise ValueError(
                    "recurrent/hybrid patterns serve data-parallel only; "
                    f"use a mesh with model axis 1 (got {ntp})")
            dims = [(cfg.n_heads, "n_heads"), (cfg.n_kv, "n_kv")]
            if cfg.moe is None:
                dims.append((cfg.d_ff, "d_ff"))
            else:
                # MoE blocks shard the *expert* dim over the model axis
                # (expert-parallel grouped GEMM, one psum per block); each
                # expert's d_ff stays whole on its shard
                dims.append((cfg.moe.n_experts, "moe.n_experts"))
            for dim, nm in dims:
                if dim % ntp != 0:
                    raise ValueError(f"cfg.{nm}={dim} must divide the model "
                                     f"axis ({ntp}) for TP serving")
            self.n_shards = ndata
        else:
            self.n_shards = 1
        self.slots_per_shard = max_seqs // self.n_shards
        if num_pages is None:
            if self._needs_pages:
                num_pages = self.n_shards * (self.slots_per_shard
                                             * table_width + 1)
            else:
                # pure-recurrent: no KV layer reads the pool; keep the
                # garbage page plus one allocatable page per shard so the
                # page bookkeeping stays well-formed at negligible cost
                num_pages = 2 * self.n_shards
        if num_pages % self.n_shards != 0:
            raise ValueError(f"num_pages={num_pages} must divide over the "
                             f"data axis ({self.n_shards})")
        self.num_pages = num_pages
        self.pages_per_shard = num_pages // self.n_shards
        self.pages = init_paged_pages(cfg, num_pages, page_size,
                                      dtype=jnp.dtype(cfg.dtype),
                                      max_seqs=max_seqs)
        if mesh is not None:
            from repro.distributed.sharding import (paged_pool_pspecs,
                                                    serving_param_pspecs,
                                                    to_shardings)
            self.pages = jax.device_put(
                self.pages,
                to_shardings(paged_pool_pspecs(self.pages, mesh), mesh))
            # place the weights per the TP specs once, up front: params
            # committed to one device would otherwise be resharded onto the
            # mesh by GSPMD at *every* step call — O(param bytes) per decode
            # step on the loop this engine keeps at O(max_seqs) ints
            self.params = jax.device_put(
                self.params,
                to_shardings(serving_param_pspecs(self.params, mesh), mesh))
        # host scheduler state; local page 0 of every shard is its reserved
        # garbage page, and the table holds *shard-local* page ids (the
        # device step only ever sees its own sub-pool)
        self._pools = [PagePool(self.pages_per_shard)
                       for _ in range(self.n_shards)]
        # one radix index per data shard: page ids are shard-local and
        # pages cannot migrate between sub-pools, so dedup staying
        # shard-local is what keeps DP bit-parity with one device
        self._prefix = None
        # page copy fn: COW for the prefix cache, and NaR-page scrubbing
        # when a failed request's pages return to the pool
        self._copy_fn = None
        if self._needs_pages:
            self._copy_fn = (_paged_copy(cfg) if mesh is None
                             else _sharded_paged_copy(cfg, mesh))
        if prefix_cache and not self.layout.supports_prefix_cache:
            # state slots are mutable accumulators, not content-addressed
            # immutable pages — prefix caching cleanly no-ops for any
            # pattern with recurrent layers
            prefix_cache = False
        if prefix_cache:
            key = (f"{cfg.name}|kv={cfg.policy.kv_cache}|page={page_size}"
                   f"|n_kv={cfg.n_kv}|hd={cfg.hd}")
            self._prefix = [RadixIndex(key, page_size)
                            for _ in range(self.n_shards)]
        self.table = np.zeros((max_seqs, table_width), np.int32)
        self.seq_lens = np.zeros((max_seqs,), np.int32)
        self.slots: list[_Slot | None] = [None] * max_seqs
        self.waiting: deque[Request] = deque()
        self._admitted = 0
        self._next_rid = 0
        self._rng = np.random.default_rng(seed)
        self._seed = int(seed) % (2 ** 31 - 1)
        self._step_idx = 0
        self.finished: dict[int, np.ndarray] = {}
        self.outcomes: dict[int, RequestOutcome] = {}
        self.max_waiting = max_waiting
        self.default_ttl_steps = default_ttl_steps
        self.default_deadline_s = default_deadline_s
        self._quarantined: set[int] = set()
        self._chaos = as_injector(chaos)
        self._poison_fn = None
        if self._chaos is not None and self._chaos.cfg.p_page_poison > 0:
            if mesh is not None:
                raise ValueError("page-poison injection targets shard-local "
                                 "page ids; run chaos page poison with "
                                 "mesh=None")
            if self._needs_pages:
                self._poison_fn = _paged_poison(cfg)
        self.counters = collections.Counter()
        self._gather_base = self._moe_base = self._rec_base = 0
        # eager sliding-window page reclamation: sound only when *every*
        # attention layer is windowed (a full-attn layer still reads old
        # pages) and the prefix cache is off (a cached page must stay
        # resident for future prefix hits, not be recycled)
        attn_kinds = [k for k in cfg.block_pattern
                      if k in ("attn", "attn_local")]
        self._reclaim_window = (
            cfg.window
            if (attn_kinds and all(k == "attn_local" for k in attn_kinds)
                and cfg.window and self._prefix is None)
            else None)
        self.reset_stats()

        greedy = temperature <= 0.0
        if mesh is None:
            self._step_fn = _paged_step(cfg, greedy)
        else:
            self._step_fn = _sharded_paged_step(cfg, mesh, greedy,
                                                tp_compress)

    # ---- host-side paging ------------------------------------------------
    def _shard(self, i: int) -> int:
        """Data shard owning sequence slot i (0 when unsharded)."""
        return i // self.slots_per_shard

    @property
    def free_pages(self) -> list[int]:
        """All free (shard-local) page ids, across shards.  Idle *cached*
        prefix pages are not free — they are resident until evicted (see
        cached_pages)."""
        return [p for pool in self._pools for p in pool.free_list]

    @property
    def cached_pages(self) -> int:
        """Pages pinned by the prefix index across shards (some may also
        be live-referenced by sequences)."""
        return sum(pool.n_cached for pool in self._pools)

    def _evict_one(self, shard: int) -> bool:
        """LRU-evict one idle cached prefix page from `shard`'s index back
        to the free stack.  Runs *before* preemption ever does: a cached
        page nobody references must die before live work is rolled back."""
        if self._prefix is None:
            return False
        pool = self._pools[shard]
        pg = self._prefix[shard].evict_lru(pool.is_idle)
        if pg is None:
            return False
        pool.uncache(pg)
        self.counters["evicted_pages"] += 1
        return True

    def _alloc_page(self, i: int) -> int:
        """One fresh page for slot i's shard: the free stack, else LRU
        eviction of idle cached prefix pages, else preemption of a live
        sequence (strictly in that order).  Raises PoolExhausted when all
        three run dry — slot i alone exceeds its shard's pool — which the
        scheduler converts into a structured `rejected` outcome for slot
        i's request (never an unhandled exception out of a drain)."""
        pool = self._pools[self._shard(i)]
        while True:
            pg = pool.try_alloc()
            if pg is not None:
                return pg
            if self._evict_one(self._shard(i)):
                continue
            if not self._preempt(exclude=i):
                raise PoolExhausted(
                    "KV pool exhausted and nothing left to evict or "
                    "preempt; grow num_pages or lower max_seqs")

    def _ensure_pages(self, i: int, upto: int):
        """Slot i needs capacity for `upto` tokens; allocate from its
        shard's sub-pool (evicting idle cached pages, then preempting
        within the shard, if it runs dry)."""
        slot = self.slots[i]
        if not self._needs_pages:
            return                   # state-pool-only layout: no KV pages
        need = -(-upto // self.page)
        if need > self.width:
            raise ValueError(f"request {slot.req.rid}: {upto} tokens exceed "
                             f"table_width*page_size = {self.width * self.page}")
        while len(slot.pages) < need:
            pg = self._alloc_page(i)
            self.table[i, len(slot.pages)] = pg
            slot.pages.append(pg)

    def _free_slot(self, i: int):
        slot = self.slots[i]
        pool = self._pools[self._shard(i)]
        for pg in slot.pages:
            if pg:                   # 0 = reclaimed-window placeholder
                pool.decref(pg)      # cached prefix pages stay resident
        self.table[i, :] = 0
        self.seq_lens[i] = 0
        self.slots[i] = None

    def _maybe_cow(self, i: int):
        """Copy-on-write: the next step writes slot i's KV starting at
        seq_lens[i]; when that lands *mid-page* in a page the prefix index
        or another sequence shares, copy the page device-side and point
        slot i's table entry at the private copy first.  (Writes starting
        at a page boundary always land in a freshly allocated page, so
        only the first page of the write range can ever be shared.)"""
        slot = self.slots[i]
        if self._prefix is None or slot is None:
            return
        p0 = int(self.seq_lens[i])
        j = p0 // self.page
        if p0 % self.page == 0 or j >= len(slot.pages):
            return
        pg = slot.pages[j]
        pool = self._pools[self._shard(i)]
        if pool.ref_count(pg) <= 1 and not pool.is_cached(pg):
            return                   # private page: write in place
        new = self._alloc_page(i)
        self._device_copy(self._shard(i), pg, new)
        pool.decref(pg)
        slot.pages[j] = new
        self.table[i, j] = new
        self.counters["cow_copies"] += 1

    def _device_copy(self, shard: int, src: int, dst: int):
        """Device page copy (bit-exact for posit pages: raw bits move)."""
        if self.mesh is None:
            self.pages = self._copy_fn(self.pages, jnp.int32(src),
                                       jnp.int32(dst))
        else:
            s = np.zeros((self.n_shards,), np.int32)
            d = np.zeros((self.n_shards,), np.int32)
            s[shard], d[shard] = src, dst      # others: garbage no-op copy
            self.pages = self._copy_fn(self.pages, jnp.asarray(s),
                                       jnp.asarray(d))

    def _attach_prefix(self, i: int):
        """Longest-cached-prefix attach at admission: share the matched
        pages (ref-counted) and start chunked prefill at the first
        uncached token.  At least one prompt token is always re-fed so the
        step produces first-token logits — a fully cached page-aligned
        prompt keeps all its pages and re-feeds only the final token
        (whose mid-page write then triggers copy-on-write)."""
        slot = self.slots[i]
        if self._prefix is None:
            return
        shard = self._shard(i)
        idx, pool = self._prefix[shard], self._pools[shard]
        pages, node = idx.lookup(slot.req.prompt, self._step_idx)
        L = len(slot.req.prompt)
        cached = min(len(pages) * self.page, L - 1)
        if not pages or cached <= 0:
            self.counters["prefix_misses"] += 1
            return
        for j, pg in enumerate(pages):
            pool.incref(pg)
            self.table[i, j] = pg
        slot.pages = list(pages)
        slot.node = node
        slot.reg_pos = len(pages) * self.page
        slot.prefill_pos = cached
        self.seq_lens[i] = cached
        self.counters["prefix_hits"] += 1
        self.counters["prefix_hit_tokens"] += cached

    def _register(self, i: int):
        """Register slot i's newly filled pages in its shard's radix index
        (each page's content address covers the whole token prefix it
        completes).  An identical page already cached gets *adopted*: the
        slot's table entry swaps to the existing page and its own copy
        frees — safe because both hold bit-identical KV."""
        slot = self.slots[i]
        if self._prefix is None or slot is None:
            return
        written = int(self.seq_lens[i])
        if slot.reg_pos + self.page > written:
            return
        shard = self._shard(i)
        idx, pool = self._prefix[shard], self._pools[shard]
        if slot.node is None:
            slot.node = idx.root
        stream = np.concatenate([slot.req.prompt,
                                 np.asarray(slot.generated, np.int32)])
        while slot.reg_pos + self.page <= written:
            j = slot.reg_pos // self.page
            chunk = stream[slot.reg_pos:slot.reg_pos + self.page]
            node, existing = idx.insert(slot.node, chunk, slot.pages[j],
                                        self._step_idx)
            if existing is not None and existing != slot.pages[j]:
                pool.incref(existing)
                pool.decref(slot.pages[j])     # private copy -> freed
                slot.pages[j] = existing
                self.table[i, j] = existing
                self.counters["deduped_pages"] += 1
            elif existing is None:
                pool.cache(slot.pages[j])
            slot.node = node
            slot.reg_pos += self.page

    def _preempt(self, exclude: int) -> bool:
        """Evict the youngest other sequence *in the same shard* (pages
        cannot migrate between sub-pools): free its pages and requeue it
        (prompt + generated so far) at the front of the wait queue."""
        shard = self._shard(exclude)
        victims = [(s.admit_order, i) for i, s in enumerate(self.slots)
                   if s is not None and i != exclude
                   and self._shard(i) == shard]
        if not victims:
            return False
        _, i = max(victims)
        slot = self.slots[i]
        req = slot.req
        # restart from the full prompt + whatever was already generated
        gen = np.asarray(slot.generated, np.int32)
        new_prompt = np.concatenate([req.prompt, gen])
        remaining = req.max_new - len(slot.generated)
        self.waiting.appendleft(Request(req.rid, new_prompt, remaining,
                                        prior=np.concatenate([req.prior,
                                                              gen]),
                                        ttl_steps=req.ttl_steps,
                                        deadline_t=req.deadline_t,
                                        submit_step=req.submit_step))
        self._free_slot(i)
        self.counters["preempted"] += 1
        return True

    # ---- structured outcomes / graceful degradation ----------------------
    def _resolve(self, req: Request, status: str, detail: str = "",
                 retry_after: int | None = None, generated=None):
        """Record request `req`'s terminal outcome (exactly one per rid).
        `generated` is the token list/array produced since the last
        (re-)admission; the caller's view is always prior + generated."""
        gen = np.asarray([] if generated is None else generated, np.int32)
        toks = np.concatenate([req.prior, gen]) if len(req.prior) else gen
        self.outcomes[req.rid] = RequestOutcome(
            rid=req.rid, status=status, tokens=toks, detail=detail,
            retry_after_steps=retry_after, step=self._step_idx,
            time_s=time.time())
        self.counters[status] += 1
        if status == "completed":
            self.finished[req.rid] = toks
            self.counters["finished"] += 1      # legacy alias

    def _fail_slot(self, i: int, status: str, detail: str):
        """Resolve slot i's request as `status` (partial tokens kept) and
        hand every resource it held back to the pool.  NaR-failed slots
        scrub their private pages first — see _scrub_slot_pages."""
        slot = self.slots[i]
        if status == "failed_nar":
            self._scrub_slot_pages(i)
        self._resolve(slot.req, status, detail=detail,
                      generated=slot.generated)
        self._free_slot(i)

    def _scrub_slot_pages(self, i: int):
        """Overwrite a NaR'd sequence's *private* pages with the garbage
        page's (finite) bits before they return to the free list.

        Recycled pages are never *read as valid* — the attention masks
        exclude their positions — but masked positions still multiply into
        the value aggregation as exp(-inf) = 0 times v, and 0 * NaN is
        NaN: finite stale garbage in a recycled page is harmless, NaR/NaN
        bits would poison the page's next owner.  Shared/cached pages were
        written by healthy requests (a failed slot never registers pages,
        and mid-page writes COW first), so private pages are exactly the
        set the NaR'd request may have contaminated."""
        if not self._needs_pages or self._copy_fn is None:
            return
        shard = self._shard(i)
        pool = self._pools[shard]
        for pg in self.slots[i].pages:
            if pg and pool.ref_count(pg) == 1 and not pool.is_cached(pg):
                self._device_copy(shard, GARBAGE_PAGE, pg)
                self.counters["scrubbed_pages"] += 1

    def _quarantine(self, participants):
        """A step failed twice: fail its surviving participants loudly and
        quarantine their slots (a quarantined slot is never re-admitted —
        the model of a sick device lane).  The engine keeps serving on the
        remaining slots; with none left, waiting requests reject at
        admission instead of hanging."""
        for i in list(participants):
            if self.slots[i] is None:
                continue
            self._fail_slot(i, "failed_fault",
                            "device step failed twice; slot quarantined")
            self._quarantined.add(i)
            self.counters["slots_quarantined"] += 1

    def _expired(self, req: Request, now: float) -> bool:
        if (req.ttl_steps is not None
                and self._step_idx - req.submit_step >= req.ttl_steps):
            return True
        return req.deadline_t is not None and now >= req.deadline_t

    def _expire_deadlines(self):
        """Cancel active and waiting requests whose TTL/deadline passed:
        pages and state slots return to the pool immediately, the request
        resolves as `expired` with its partial tokens."""
        now = time.time()
        for i, slot in enumerate(self.slots):
            if slot is not None and self._expired(slot.req, now):
                self._fail_slot(i, "expired", "deadline/TTL exceeded")
        kept = deque()
        for req in self.waiting:
            if self._expired(req, now):
                self._resolve(req, "expired",
                              "deadline/TTL exceeded while queued")
            else:
                kept.append(req)
        self.waiting = kept

    def _maybe_poison_page(self):
        """Chaos page-poison injection: flip one live page to NaR before
        the step.  The victim is the lowest active slot's first fully
        written, *unshared and uncached* page (containment must hold: a
        shared page would legitimately fail every reader); no candidate —
        no injection."""
        if self._chaos is None or self._poison_fn is None:
            return
        victim = None
        for i, slot in enumerate(self.slots):
            if slot is None:
                continue
            pool = self._pools[self._shard(i)]
            full = int(self.seq_lens[i]) // self.page
            for j in range(min(full, len(slot.pages))):
                pg = slot.pages[j]
                if pg and pool.ref_count(pg) == 1 and not pool.is_cached(pg):
                    victim = pg
                    break
            if victim is not None:
                break
        # candidate first, injector second: a step with nothing safely
        # poisonable must not consume the injection budget
        if victim is None or not self._chaos.page_poison(self._step_idx):
            return
        self.pages = self._poison_fn(self.pages, jnp.int32(victim))
        self.counters["injected_page_poisons"] += 1

    def _retry_after_hint(self) -> int:
        """Backoff hint for queue-full rejections: device steps until the
        fastest active request can retire its slot (>= 1)."""
        remaining = [s.req.max_new - len(s.generated)
                     for s in self.slots if s is not None]
        return max(1, min(remaining, default=1))

    def _admit(self):
        if not self.waiting:
            return
        # admission batching: a mid-flight admission stalls every decoding
        # slot for the new prompt's chunk steps, so hold freed slots until
        # several can prefill together.  Admit immediately when a prefill
        # phase is already running (joining it is ~free), when nothing is
        # decoding (nothing to stall), or when enough slots accumulated.
        phases = [s.phase for s in self.slots if s is not None]
        n_free = self.max_seqs - len(phases) - len(self._quarantined)
        if ("decode" in phases and "prefill" not in phases
                and n_free < max(1, self.admit_threshold)):
            return
        while self.waiting:
            req = self.waiting[0]
            # pick the free slot whose shard caches the longest prefix of
            # this prompt (ties -> lowest slot, the pre-prefix-cache
            # behavior); a slot only qualifies when the pages the prompt
            # still needs fit its shard's free + evictable headroom
            best = None
            for i in range(self.max_seqs):
                if self.slots[i] is not None or i in self._quarantined:
                    continue
                pool = self._pools[self._shard(i)]
                hit = (self._prefix[self._shard(i)].probe(req.prompt)
                       if self._prefix is not None else 0)
                n_match = hit // self.page
                need = -(-(len(req.prompt) + 1) // self.page) - n_match
                avail = pool.n_free + max(0, pool.n_evictable - n_match)
                if self._needs_pages and need > avail:
                    continue
                cached = min(hit, len(req.prompt) - 1)
                if best is None or (cached, -i) > best[0]:
                    best = ((cached, -i), i)
            if best is None:
                if self.active == 0:
                    # nothing running and still no slot fits: this request
                    # can never be placed (pool too small for it alone, or
                    # every slot quarantined).  Structured rejection, not a
                    # crash — the drain keeps going.
                    self.waiting.popleft()
                    self._resolve(
                        req, "rejected",
                        detail=f"does not fit the idle pool "
                               f"({len(self.free_pages)} free pages across "
                               f"{self.n_shards} shard(s), "
                               f"{len(self._quarantined)} slot(s) "
                               f"quarantined)")
                    continue
                return
            i = best[1]
            self.waiting.popleft()
            self.slots[i] = _Slot(req=req, admit_order=self._admitted,
                                  pages=[])
            self._admitted += 1
            self.counters["admitted"] += 1
            if self._recurrent:
                # the sequence slot *is* the state-pool slot; its state
                # leaves are zeroed device-side on the first prefill chunk
                # (seq_lens == 0 -> backends.zero_fresh)
                self.counters["state_slot_allocs"] += 1
            self._attach_prefix(i)

    # ---- public API ------------------------------------------------------
    def submit(self, prompt, max_new: int, rid: int | None = None, *,
               ttl_steps: int | None = None,
               deadline_s: float | None = None) -> int:
        """Queue a request.  Malformed input (empty prompt, max_new < 1,
        rid collision) still raises ValueError — those are caller bugs.
        Load conditions never raise: a full wait queue or an over-capacity
        request resolves to a structured `rejected` outcome instead.

        `ttl_steps` / `deadline_s` bound the request's lifetime (device
        steps from now / wall-clock seconds from now); either hitting its
        limit cancels the request (`expired`), returning its pages and
        state slots to the pool.  Defaults come from the engine ctor."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) == 0:
            # an empty prompt would enter decode with the -1 sentinel as a
            # real token (wrapping to the last vocab row); reject instead
            raise ValueError("prompt must contain at least one token")
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        if rid is None:
            rid = self._next_rid
        elif (rid in self.finished or rid in self.outcomes
              or any(r.rid == rid for r in self.waiting)
              or any(s is not None and s.req.rid == rid
                     for s in self.slots)):
            # a colliding rid would silently overwrite the other request's
            # results in `finished`
            raise ValueError(f"request id {rid} is already in use")
        self._next_rid = max(self._next_rid, rid + 1)
        self.counters["submitted"] += 1
        if ttl_steps is None:
            ttl_steps = self.default_ttl_steps
        if deadline_s is None:
            deadline_s = self.default_deadline_s
        req = Request(rid, prompt, max_new, ttl_steps=ttl_steps,
                      deadline_t=(None if deadline_s is None
                                  else time.time() + deadline_s),
                      submit_step=self._step_idx)
        if self._needs_pages and len(prompt) + max_new > self.width * self.page:
            # page-table capacity only binds layouts with KV layers; pure
            # state-pool sequences are O(1) in length.  No amount of
            # waiting makes this fit -> immediate structured rejection.
            self._resolve(req, "rejected",
                          detail=f"prompt+max_new = {len(prompt) + max_new} "
                                 f"exceeds per-sequence capacity "
                                 f"{self.width * self.page}")
            return rid
        if (self.max_waiting is not None
                and len(self.waiting) >= self.max_waiting):
            # bounded admission queue: shed load *now* with a backoff hint
            # instead of growing the queue without bound
            self._resolve(req, "rejected",
                          detail=f"wait queue full "
                                 f"({len(self.waiting)} waiting)",
                          retry_after=self._retry_after_hint())
            return rid
        if self._prefix is not None:
            # submit-time longest-cached-prefix probe (read-only: the
            # authoritative, LRU-touching lookup happens at admission,
            # when the slot — hence the shard — is known)
            self.counters["prefix_probe_tokens"] += max(
                idx.probe(prompt) for idx in self._prefix)
        self.waiting.append(req)
        return rid

    @property
    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    # ---- observability ---------------------------------------------------
    def stats(self) -> dict:
        """Scheduler + prefix-cache counters (the serving bench prints
        this).  Fallback counters are process-global; they are reported as
        deltas since engine construction or the last reset_stats()."""
        from repro.kernels.ops import RECURRENT_FALLBACKS
        from repro.models.moe import DENSE_MOE_FALLBACKS
        d = {k: 0 for k in ("admitted", "finished", "preempted",
                            "prefill_steps", "decode_steps",
                            "prefix_hits", "prefix_misses",
                            "prefix_hit_tokens", "prefix_probe_tokens",
                            "evicted_pages", "cow_copies",
                            "deduped_pages", "state_slot_allocs",
                            "expired_page_frees",
                            # robustness: outcome taxonomy (sums to
                            # `submitted`) + fault/degradation telemetry
                            "submitted", *OUTCOMES,
                            "step_retries", "slots_quarantined",
                            "scrubbed_pages",
                            "straggler_steps", "injected_step_faults",
                            "injected_nar_poisons",
                            "injected_page_poisons")}
        d.update(self.counters)
        d["gather_fallbacks"] = (sum(GATHER_FALLBACKS.values())
                                 - self._gather_base)
        d["dense_moe_fallbacks"] = (sum(DENSE_MOE_FALLBACKS.values())
                                    - self._moe_base)
        d["recurrent_fallbacks"] = (sum(RECURRENT_FALLBACKS.values())
                                    - self._rec_base)
        d["free_pages"] = sum(p.n_free for p in self._pools)
        d["cached_pages"] = self.cached_pages
        if self._step_lat_s:
            lat = np.percentile(np.asarray(self._step_lat_s), [50, 99])
            d["step_latency_p50_ms"] = float(lat[0]) * 1e3
            d["step_latency_p99_ms"] = float(lat[1]) * 1e3
        else:
            d["step_latency_p50_ms"] = d["step_latency_p99_ms"] = 0.0
        return d

    def reset_stats(self):
        """Zero the counters and re-baseline the global fallback counters
        (the tests' reset hook; several drains can share one engine)."""
        from repro.kernels.ops import RECURRENT_FALLBACKS
        from repro.models.moe import DENSE_MOE_FALLBACKS
        self.counters.clear()
        self._step_lat_s: collections.deque = collections.deque(maxlen=4096)
        self._gather_base = sum(GATHER_FALLBACKS.values())
        self._moe_base = sum(DENSE_MOE_FALLBACKS.values())
        self._rec_base = sum(RECURRENT_FALLBACKS.values())

    def _sample_host(self, logits_row: np.ndarray) -> int:
        """Host-side sampling oracle.  The engine samples on device inside
        the jitted step (_sample_on_device) — this survives only so tests
        can check greedy parity against independently computed logits."""
        if self.temperature <= 0.0:
            return int(np.argmax(logits_row))
        z = logits_row.astype(np.float64) / self.temperature
        z = z - z.max()
        p = np.exp(z)
        p /= p.sum()
        return int(self._rng.choice(len(p), p=p))

    def _table_view(self, participants):
        """Power-of-two bucketed page-table slice sized to the sequences
        that actually compute this step (each bucket compiles once).

        Prefill steps pass only the prefilling slots: a short prompt then
        pays its own width even while a 4k-token sequence sits in a decode
        slot (that slot's num_new is 0 — its outputs are ignored and its
        writes dropped, so truncating its pages out of the view is safe)."""
        if not self.bucket_pages:
            return self.table
        used = max([len(self.slots[i].pages) for i in participants
                    if self.slots[i] is not None], default=1)
        w = 1
        while w < max(used, 1):
            w *= 2
        w = min(max(w, 1), self.width)
        return self.table[:, :w]

    def _run_step(self, tokens: np.ndarray, num_new: np.ndarray,
                  participants):
        """Run the fused step; returns (tokens, nar) — the sampled token
        and the on-device NaR-detector flag per slot ([max_seqs] int32 /
        bool, fetched in one transfer, so the happy path costs no extra
        host sync).  A step that fails (InjectedFault before the device
        call) is retried once against unchanged state; a repeat failure
        quarantines the participants and returns (None, None)."""
        t_step0 = time.perf_counter()
        poisoned: list[int] = []
        if self._chaos is not None:
            poisoned = self._chaos.poison_slots(self._step_idx, participants)
        poison = np.zeros((self.max_seqs,), bool)
        poison[poisoned] = True
        pt = jnp.asarray(self._table_view(participants))
        sl = jnp.asarray(self.seq_lens)
        nn = jnp.asarray(num_new)
        for attempt in (0, 1):
            try:
                if self._chaos is not None:
                    nap = self._chaos.straggle(self._step_idx, attempt)
                    if nap > 0.0:
                        self.counters["straggler_steps"] += 1
                        time.sleep(nap)
                    if self._chaos.step_fault(self._step_idx, attempt):
                        self.counters["injected_step_faults"] += 1
                        raise InjectedFault(
                            f"injected device failure at step "
                            f"{self._step_idx} attempt {attempt}")
                toks, bad, self.pages = self._step_fn(
                    self.params, jnp.asarray(tokens), self.pages, pt, sl, nn,
                    jnp.float32(self.temperature), jnp.int32(self._seed),
                    jnp.int32(self._step_idx), jnp.asarray(poison))
                break
            except InjectedFault:
                if attempt == 0:
                    self.counters["step_retries"] += 1
                    continue
                self._quarantine(participants)
                return None, None
        self.counters["injected_nar_poisons"] += len(poisoned)
        self._step_idx += 1
        self.seq_lens += num_new
        self._reclaim_expired()
        toks, bad = jax.device_get((toks, bad))
        # end-to-end wall time of the fused step (injected straggler sleeps
        # included — that skew is exactly what the p99 is for)
        self._step_lat_s.append(time.perf_counter() - t_step0)
        return np.asarray(toks), np.asarray(bad)

    def _reclaim_expired(self):
        """Free KV pages every token of which has slid out of the attention
        window (all-attn_local patterns, prefix cache off — see __init__).
        Freed table entries point at the garbage page; the window mask
        already excludes those positions on every attention path (Pallas
        decode/prefill kernels and the jnp fallback), so recycled pages can
        hold another sequence's KV without being read.  slot.pages keeps a
        0 placeholder so later positions stay index-aligned."""
        if self._reclaim_window is None:
            return
        for i, slot in enumerate(self.slots):
            if slot is None:
                continue
            n = reclaimable_pages(int(self.seq_lens[i]),
                                  self._reclaim_window, self.page)
            pool = self._pools[self._shard(i)]
            for j in range(min(n, len(slot.pages))):
                pg = slot.pages[j]
                if pg:
                    pool.decref(pg)
                    slot.pages[j] = 0
                    self.table[i, j] = 0
                    self.counters["expired_page_frees"] += 1

    def _page_in(self, i: int) -> bool:
        """Allocate slot i's pages for its next write and run COW; a dry
        pool (slot i alone exceeds its shard) resolves the request as
        `rejected` instead of raising.  Returns False if the slot died."""
        try:
            self._ensure_pages(i, int(self.seq_lens[i])
                               + (min(self.chunk, len(self.slots[i].req.prompt)
                                      - self.slots[i].prefill_pos)
                                  if self.slots[i].phase == "prefill" else 1))
            self._maybe_cow(i)
            return True
        except PoolExhausted as e:
            self._fail_slot(i, "rejected", detail=str(e))
            return False

    def step(self) -> list[tuple[int, int]]:
        """One scheduler iteration; returns (rid, token) pairs emitted."""
        # retire finished sequences (before expiry: a request that is done
        # resolves `completed` even if its deadline passed this instant),
        # then cancel expired work, then fill freed slots from the queue
        for i, slot in enumerate(self.slots):
            if slot is not None and slot.done:
                self._resolve(slot.req, "completed",
                              generated=slot.generated)
                self._free_slot(i)
        self._expire_deadlines()
        self._admit()
        self._maybe_poison_page()

        prefilling = [i for i, s in enumerate(self.slots)
                      if s is not None and s.phase == "prefill"]
        emitted: list[tuple[int, int]] = []
        if prefilling:
            # page in first: allocation may preempt a slot (even one in
            # `prefilling`), so the batch is built only from survivors.
            # _maybe_cow runs after paging: a warm slot resuming mid-page
            # (fully cached page-aligned prompt) must write into a private
            # copy, never the shared page.
            for i in prefilling:
                if self.slots[i] is None:
                    continue
                self._page_in(i)
            alive = [i for i in prefilling if self.slots[i] is not None]
            if not alive:
                return emitted
            tokens = np.zeros((self.max_seqs, self.chunk), np.int32)
            num_new = np.zeros((self.max_seqs,), np.int32)
            for i in alive:
                s = self.slots[i]
                part = s.req.prompt[s.prefill_pos:s.prefill_pos + self.chunk]
                tokens[i, :len(part)] = part
                num_new[i] = len(part)
            toks, bad = self._run_step(tokens, num_new, alive)
            if toks is None:
                return emitted           # step failed twice: slots resolved
            for i in alive:
                s = self.slots[i]
                s.prefill_pos += int(num_new[i])
                if bad[i]:
                    # NaR reached this slot's logits: fail it before any
                    # token is emitted or any page registers in the prefix
                    # index (poisoned KV must never be shared)
                    self._fail_slot(i, "failed_nar",
                                    "NaR detected in output logits")
                    continue
                if s.phase == "decode":
                    tok = int(toks[i])
                    s.generated.append(tok)
                    s.next_token = tok
                    emitted.append((s.req.rid, tok))
                self._register(i)
            self.counters["prefill_steps"] += 1
            return emitted

        decoding = [i for i, s in enumerate(self.slots)
                    if s is not None and s.phase == "decode" and not s.done]
        if not decoding:
            return emitted
        for i in decoding:
            if self.slots[i] is not None:
                self._page_in(i)
        decoding = [i for i in decoding if self.slots[i] is not None]
        if not decoding:
            return emitted
        tokens = np.zeros((self.max_seqs, 1), np.int32)
        num_new = np.zeros((self.max_seqs,), np.int32)
        for i in decoding:
            tokens[i, 0] = self.slots[i].next_token
            num_new[i] = 1
        toks, bad = self._run_step(tokens, num_new, decoding)
        if toks is None:
            return emitted
        for i in decoding:
            s = self.slots[i]
            if bad[i]:
                self._fail_slot(i, "failed_nar",
                                "NaR detected in output logits")
                continue
            tok = int(toks[i])
            s.generated.append(tok)
            s.next_token = tok
            emitted.append((s.req.rid, tok))
            self._register(i)
        self.counters["decode_steps"] += 1
        return emitted

    def run(self, requests=None, max_steps: int | None = None
            ) -> dict[int, np.ndarray]:
        """Drain: submit `requests` (iterable of (prompt, max_new)) and step
        until everything finished.  Returns {rid: generated tokens}."""
        if requests is not None:
            for prompt, max_new in requests:
                self.submit(prompt, max_new)
        steps = 0
        while self.waiting or self.active:
            self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return dict(self.finished)
