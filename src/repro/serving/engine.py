"""Batched serving engine: prefill + decode with (optionally posit) KV cache.

Greedy/temperature sampling over a synchronized batch — the serve_step the
dry-run lowers for decode_32k / long_500k is `decode_step` below.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.transformer import ModelConfig, forward, init_caches


def prefill_step(params, cfg: ModelConfig, tokens, caches):
    logits, _, caches = forward(params, cfg, tokens=tokens, caches=caches)
    return logits[:, -1], caches


def decode_step(params, cfg: ModelConfig, token, caches):
    """token [B, 1] -> (next-token logits [B, vocab], new caches)."""
    logits, _, caches = forward(params, cfg, tokens=token, caches=caches)
    return logits[:, -1], caches


def sample(logits, key, temperature: float = 0.0):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature, axis=-1)


def generate(params, cfg: ModelConfig, prompts: jnp.ndarray, max_new: int,
             max_len: int | None = None, temperature: float = 0.0,
             seed: int = 0):
    """prompts [B, S] int32 -> generated [B, max_new] int32 (batched)."""
    B, S = prompts.shape
    max_len = max_len or (S + max_new)
    caches = init_caches(cfg, B, max_len, dtype=jnp.dtype(cfg.dtype))

    pf = jax.jit(lambda p, t, c: prefill_step(p, cfg, t, c))
    dc = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c))

    logits, caches = pf(params, prompts, caches)
    key = jax.random.PRNGKey(seed)
    out = []
    tok = sample(logits, key, temperature)[:, None].astype(jnp.int32)
    out.append(tok)
    for i in range(max_new - 1):
        key, sub = jax.random.split(key)
        logits, caches = dc(params, tok, caches)
        tok = sample(logits, sub, temperature)[:, None].astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
