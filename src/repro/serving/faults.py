"""Deterministic fault injection for the serving engine (chaos harness).

The paper's case for posits is *well-defined behavior*: one NaR pattern
(1000...0) instead of the IEEE NaN/Inf zoo.  That guarantee is only worth
anything if the system above the datapath treats NaR as a first-class
signal — detects it, contains it to the request that produced it, and
degrades gracefully instead of crashing or emitting garbage tokens.  This
module is the harness that *proves* that: seeded injectors hooked into
`PagedServingEngine`'s step path simulate the faults a fleet actually sees,
and the chaos tests (tests/test_chaos_serving.py) assert the engine's
contract under them:

  * every submitted request resolves to exactly one structured outcome
    (``completed | rejected | expired | failed_nar | failed_fault``) —
    an oversubscribed drain under injected faults never raises;
  * surviving requests' greedy tokens are bit-identical to a fault-free
    run (faults are contained to the request they hit);
  * the engine's outcome counters exactly account for every submission.

Fault kinds (all decisions are pure functions of (seed, step, ...) — two
runs with the same ChaosConfig inject the identical fault schedule):

  step fault     — a simulated device failure: the step raises
                   InjectedFault *before* the device call, so no state is
                   consumed.  The engine retries once; a repeat failure
                   fails the step's participants (``failed_fault``) and
                   quarantines their slots.
  NaR poison     — a NaR-poisoned activation: the jitted step overwrites
                   one participating slot's last-position logits with NaN
                   (what a NaR reaching the unembed would decode to) on
                   device, exercising the engine's per-slot NaR detector.
  page poison    — a bit-flipped posit KV page: a live, private,
                   fully-written page is overwritten with the NaR pattern
                   (NaN for float pools).  The owning slot's next attention
                   read propagates NaN to its logits only — pages are
                   per-sequence — so the NaR detector fails that request
                   and nothing else.
  straggler      — a slow step: the scheduler sleeps before dispatch,
                   which is what makes request deadlines/TTLs bind.

The injector never touches engine internals; the engine asks it questions
at fixed points and applies the answers through its normal fault paths, so
the same paths cover *real* faults (a genuinely non-finite logit fails the
request the same way an injected one does).
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np


class InjectedFault(RuntimeError):
    """A simulated device-step failure (raised before the device call, so
    the step can be retried against unchanged state)."""


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Seeded fault schedule.  Probabilities are per decision point; the
    draw for each decision is keyed by (seed, step, salt), never by call
    order, so the schedule is reproducible across runs and unaffected by
    how many questions the engine asks."""
    seed: int = 0
    p_step_fault: float = 0.0    # per step *attempt*: simulated device fail
    p_nar_poison: float = 0.0    # per participating slot: NaN'd logits
    p_page_poison: float = 0.0   # per step: one private KV page -> NaR
    p_straggle: float = 0.0      # per step attempt: sleep before dispatch
    straggle_s: float = 0.002    # straggler sleep duration (seconds)
    max_injections: int | None = None   # total budget across kinds


# stable salts so adding a new fault kind never perturbs existing draws
_SALT = {"step_fault": 1, "nar_poison": 2, "page_poison": 3, "straggle": 4}


class ChaosInjector:
    """Deterministic injector over a ChaosConfig.

    ``injected`` counts what was actually injected, by kind — the engine
    mirrors these into its stats() so a drain's fault schedule is visible
    next to the outcomes it caused."""

    def __init__(self, cfg: ChaosConfig):
        self.cfg = cfg
        self.injected: collections.Counter = collections.Counter()

    # ---- seeded decisions ------------------------------------------------
    def _rng(self, *key: int) -> np.random.Generator:
        return np.random.default_rng((self.cfg.seed,) + tuple(
            int(k) & 0x7FFFFFFF for k in key))

    def _budget_left(self) -> bool:
        return (self.cfg.max_injections is None
                or sum(self.injected.values()) < self.cfg.max_injections)

    def _hit(self, p: float, *key: int) -> bool:
        if p <= 0.0 or not self._budget_left():
            return False
        return bool(self._rng(*key).random() < p)

    # ---- questions the engine asks ---------------------------------------
    def step_fault(self, step_idx: int, attempt: int) -> bool:
        """Should this (step, attempt) fail before the device call?"""
        if self._hit(self.cfg.p_step_fault, _SALT["step_fault"], step_idx,
                     attempt):
            self.injected["step_faults"] += 1
            return True
        return False

    def poison_slots(self, step_idx: int, participants) -> list[int]:
        """Which participating slots get NaN'd logits this step (drawn
        independently per slot, keyed by global slot id)?"""
        out = []
        for i in participants:
            if self._hit(self.cfg.p_nar_poison, _SALT["nar_poison"],
                         step_idx, i):
                self.injected["nar_poisons"] += 1
                out.append(i)
        return out

    def page_poison(self, step_idx: int) -> bool:
        """Should one live private page be NaR-flipped before this step?
        (The engine picks the victim page — lowest active slot with a
        fully-written, unshared, uncached page — so containment is
        checkable.)"""
        if self._hit(self.cfg.p_page_poison, _SALT["page_poison"], step_idx):
            self.injected["page_poisons"] += 1
            return True
        return False

    def straggle(self, step_idx: int, attempt: int) -> float:
        """Seconds to sleep before dispatching this attempt (0 = healthy)."""
        if self._hit(self.cfg.p_straggle, _SALT["straggle"], step_idx,
                     attempt):
            self.injected["stragglers"] += 1
            return self.cfg.straggle_s
        return 0.0


def as_injector(chaos) -> ChaosInjector | None:
    """Engine-ctor convenience: None | ChaosConfig | ChaosInjector."""
    if chaos is None or isinstance(chaos, ChaosInjector):
        return chaos
    if isinstance(chaos, ChaosConfig):
        return ChaosInjector(chaos)
    raise TypeError(f"chaos must be ChaosConfig/ChaosInjector, got "
                    f"{type(chaos).__name__}")
