"""KV cache with optional posit storage (the serving-side posit win).

Decode is HBM-bound on KV reads; posit16 halves and posit8 quarters those
bytes vs f32 (paper C4 applied to serving).  The cache stores posit payload
ints; decode happens at attention time (fused into the Pallas kernel on TPU,
explicit decode on the jnp path — either way HBM sees only narrow ints).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.core.convert import f32_to_posit
from repro.core.decode import decode_to_f32
from repro.core.types import PositConfig


def init_cache(batch: int, n_kv: int, max_len: int, head_dim: int,
               cfg: PositConfig | None, dtype=jnp.float32):
    if cfg is not None:
        buf_dtype = jnp.dtype(f"int{cfg.storage_bits}")
    else:
        buf_dtype = dtype
    shape = (batch, n_kv, max_len, head_dim)
    return {
        "k": jnp.zeros(shape, buf_dtype),
        "v": jnp.zeros(shape, buf_dtype),
        "length": jnp.zeros((), jnp.int32),
    }


def append_kv(cache, k, v, cfg: PositConfig | None):
    """k, v: [B, n_kv, S, head_dim] float.  Writes at cache['length'].

    Decode-sized appends (S_new << S_max) use a masked elementwise write
    instead of dynamic_update_slice: a DUS at a *traced* index on a sharded
    sequence dim makes GSPMD gather the whole buffer (involuntary
    rematerialization); where()+iota stays fully sharded.  Prefill-sized
    appends start at 0 with a static extent, where DUS is sharding-safe.
    """
    if cfg is not None:
        k = f32_to_posit(k.astype(jnp.float32), cfg)
        v = f32_to_posit(v.astype(jnp.float32), cfg)
    else:
        k = k.astype(cache["k"].dtype)
        v = v.astype(cache["v"].dtype)
    start = cache["length"]
    s_new, s_max = k.shape[2], cache["k"].shape[2]

    if s_new * 4 >= s_max:
        # prefill: static start (the cache is empty; length is 0 by
        # construction of the serving engine)
        new_k = lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0))
        new_v = lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0))
    else:
        pos = jnp.arange(s_max)
        mask = (pos >= start) & (pos < start + s_new)
        if s_new == 1:
            # single-token decode: broadcast + where, purely elementwise
            def write(buf, new):
                return jnp.where(mask[None, None, :, None],
                                 jnp.broadcast_to(new[:, :, 0:1], buf.shape),
                                 buf)
        else:
            idx = jnp.clip(pos - start, 0, s_new - 1)
            def write(buf, new):
                cand = jnp.take(new, idx, axis=2)
                return jnp.where(mask[None, None, :, None], cand, buf)
        new_k = write(cache["k"], k)
        new_v = write(cache["v"], v)
    return {"k": new_k, "v": new_v, "length": start + s_new}


def materialize_kv(cache, cfg: PositConfig | None, dtype=jnp.float32):
    """Full-buffer k, v as float (positions >= length are masked by the
    attention's kv_len argument)."""
    k, v = cache["k"], cache["v"]
    if cfg is not None:
        k = decode_to_f32(k, cfg).astype(dtype)
        v = decode_to_f32(v, cfg).astype(dtype)
    return k, v
