"""KV cache with optional posit storage (the serving-side posit win).

Decode is HBM-bound on KV reads; posit16 halves and posit8 quarters those
bytes vs f32 (paper C4 applied to serving).  Posit caches hold `PositArray`
buffers — the format is bound to the pages at `init_cache` time (like the
FPPU register file) and every later call infers it from the cache itself;
decode happens at attention time (fused into the Pallas kernel on TPU,
explicit decode on the jnp path — either way HBM sees only narrow ints).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.array import PositArray, PositConfigMismatchError
from repro.core.convert import f32_to_posit
from repro.core.decode import decode_to_f32
from repro.core.types import PositConfig


def init_cache(batch: int, n_kv: int, max_len: int, head_dim: int,
               cfg: PositConfig | None, dtype=jnp.float32):
    """Empty cache.  cfg set -> PositArray pages; None -> float pages."""
    shape = (batch, n_kv, max_len, head_dim)
    if cfg is not None:
        dt = jnp.dtype(cfg.storage_dtype_name)
        k = PositArray(jnp.zeros(shape, dt), cfg)
        v = PositArray(jnp.zeros(shape, dt), cfg)
    else:
        k = jnp.zeros(shape, dtype)
        v = jnp.zeros(shape, dtype)
    return {"k": k, "v": v, "length": jnp.zeros((), jnp.int32)}


def _cache_cfg(cache, cfg: PositConfig | None) -> PositConfig | None:
    """The cache's bound format; a legacy explicit cfg must agree."""
    buf = cache["k"]
    if isinstance(buf, PositArray):
        if cfg is not None and cfg != buf.cfg:
            raise PositConfigMismatchError(
                f"explicit cfg {cfg} contradicts cache format {buf.cfg}")
        return buf.cfg
    if cfg is None and jnp.issubdtype(buf.dtype, jnp.integer):
        # an int-buffer cache without a format would silently truncate the
        # appended floats; refuse instead of corrupting
        raise TypeError("raw int KV buffers need an explicit cfg (deprecated"
                        " shim) — or build the cache with init_cache(...,"
                        " cfg) to get PositArray pages")
    return cfg  # legacy raw-int cache (deprecated shim) or float cache


def append_kv(cache, k, v, cfg: PositConfig | None = None):
    """k, v: [B, n_kv, S, head_dim] float.  Writes at cache['length'].

    The storage format comes from the cache buffers themselves; the `cfg`
    argument remains only as a deprecated shim for legacy raw-int caches.

    Every append is a masked elementwise write (where()+iota), never a
    dynamic_update_slice: a DUS at a *traced* index on a sharded sequence
    dim makes GSPMD gather the whole buffer (involuntary rematerialization),
    and the traced `length` start means no append has a static index.  (An
    earlier prefill fast path did DUS at a hard-coded start 0, which
    silently clobbered tokens 0..length on chunked prefill into a part-full
    cache.)  Tokens past s_max are dropped — one capacity contract for
    every append size.
    """
    cfg = _cache_cfg(cache, cfg)
    posit_pages = isinstance(cache["k"], PositArray)
    kbuf = cache["k"].bits if posit_pages else cache["k"]
    vbuf = cache["v"].bits if posit_pages else cache["v"]
    if cfg is not None:
        k = f32_to_posit(k.astype(jnp.float32), cfg)
        v = f32_to_posit(v.astype(jnp.float32), cfg)
    else:
        k = k.astype(kbuf.dtype)
        v = v.astype(vbuf.dtype)
    start = cache["length"]
    s_new, s_max = k.shape[2], kbuf.shape[2]

    pos = jnp.arange(s_max)
    mask = (pos >= start) & (pos < start + s_new)
    if s_new == 1:
        # single-token decode: broadcast + where, purely elementwise
        def write(buf, new):
            return jnp.where(mask[None, None, :, None],
                             jnp.broadcast_to(new[:, :, 0:1], buf.shape),
                             buf)
    else:
        idx = jnp.clip(pos - start, 0, s_new - 1)
        def write(buf, new):
            cand = jnp.take(new, idx, axis=2)
            return jnp.where(mask[None, None, :, None], cand, buf)
    new_k = write(kbuf, k)
    new_v = write(vbuf, v)
    if posit_pages:
        new_k = PositArray(new_k, cfg)
        new_v = PositArray(new_v, cfg)
    return {"k": new_k, "v": new_v, "length": start + s_new}


def materialize_kv(cache, cfg: PositConfig | None = None, dtype=jnp.float32):
    """Full-buffer k, v as float (positions >= length are masked by the
    attention's kv_len argument).  Format comes from the cache; `cfg` is the
    deprecated legacy-shim override."""
    cfg = _cache_cfg(cache, cfg)
    k, v = cache["k"], cache["v"]
    if isinstance(k, PositArray):
        return k.to_f32().astype(dtype), v.to_f32().astype(dtype)
    if cfg is not None:
        k = decode_to_f32(k, cfg).astype(dtype)
        v = decode_to_f32(v, cfg).astype(dtype)
    return k, v
