"""Block-paged posit KV cache — the serving-side memory system.

The dense cache (`serving.kv_cache`) allocates `(B, n_kv, max_len, D)` per
sequence slot: every request pays for the longest request's worth of HBM,
and a finished sequence's buffer cannot be handed to a waiting one.  This
module replaces that with a vLLM-style paged pool:

  * one global page pool per attention layer — `k_pages`/`v_pages` of shape
    `[num_pages, n_kv, page_size, head_dim]`, `PositArray` pages when the
    serving policy stores posit KV (paper C4/C6: posit8/16 quarters/halves
    the bytes decode streams from HBM) or float pages otherwise;
  * a per-sequence `page_table [max_seqs, table_width]` of page indices and
    `seq_lens [max_seqs]` — sequences own only the pages they filled, so
    finished sequences return capacity immediately (continuous batching);
  * page 0 is reserved as the garbage page: unallocated table entries point
    at it (reads beyond a sequence's length land there and are masked) —
    it is never allocated to a sequence.  Masked *writes* are dropped
    outright via a truly out-of-bounds scatter index (see paged_append_kv),
    so no page, including page 0, is ever written by an inactive slot.

The scheduler fields (`page_table`, `seq_lens`, `num_new`) are *inputs* of
every serving step — the host-side scheduler (serving.engine) computes them
between steps and the jitted step assembles them into the per-layer cache
dicts.  Only the page pools live on device across steps (donated through
the jit), so a step moves O(max_seqs * table_width) scheduler ints and
nothing else.

Layer cache dict layout (travels through models.transformer like the dense
dict; distinguished by the "page_table" key):

    {"k_pages", "v_pages", "page_table", "seq_lens", "num_new"}
"""
from __future__ import annotations

import collections

import jax
import jax.numpy as jnp

from repro.core.array import PositArray
from repro.core.convert import f32_to_posit
from repro.core.types import PositConfig

GARBAGE_PAGE = 0   # page index reserved for masked/invalid writes


class PoolExhausted(RuntimeError):
    """A page allocation found nothing free, nothing evictable and nothing
    preemptible.  The engine converts this into a structured ``rejected``
    outcome for the request that needed the page — it must never escape a
    drain as an unhandled exception (tests/test_chaos_serving.py)."""


def reclaimable_pages(seq_len: int, window: int, page_size: int) -> int:
    """How many leading pages of a sequence have slid *entirely* out of a
    `window`-token attention window at length `seq_len` (post-append).

    The newest query position is seq_len - 1 and attends kpos in
    (seq_len - 1 - window, seq_len); page j (tokens [j*page, (j+1)*page))
    is fully expired when (j+1)*page <= seq_len - window.  seq_len only
    grows, so expiry is monotone: the engine frees expired pages eagerly
    (sliding-window page reclamation) and both attention kernels' window
    masks already hide whatever a freed page's id gets recycled into —
    a long windowed decode holds O(window) live pages, not O(context)."""
    return max(0, (seq_len - window) // page_size)

# trace-time executions of the gather_kv dense-materialization fallback in
# paged_attention, keyed by the reason it was taken.  On the Pallas path
# (use_pallas(), i.e. TPU or the interpret-mode tier-1 drive) this must stay
# empty — every Sq, window and softcap routes through the fused kernels —
# so tests assert no new entries appear while an engine runs; gather_kv
# itself survives as the CPU/interpret reference oracle.  Forcing the
# fallback (the benchmark baseline leg) goes through REPRO_FORCE_GATHER=1 /
# kernels.ops.FORCE_REFERENCE, which every fused dispatch site consults —
# including blockwise_attention's, so the forced leg is the *whole* jnp
# reference, never gather + a fused kernel.
GATHER_FALLBACKS: collections.Counter = collections.Counter()


class PagePool:
    """Host-side page allocator for one (shard-local) sub-pool, with
    refcounts and prefix-cache pinning.

    States a page id can be in (page 0, the reserved garbage page, is in
    none of them — it is never allocated, cached, or freed):

      free    — on the free stack, contents dead;
      live    — refcount >= 1: referenced by that many sequences' page
                tables (>1 means a prefix page shared across sequences);
      cached  — pinned by the prefix index (serving.prefix_cache).  A page
                can be live *and* cached; a cached page whose refcount
                drops to 0 stays resident as an evictable prefix page
                instead of returning to the free stack.

    Invariants (enforced loudly; tests/test_prefix_cache.py drives them
    with hypothesis): refcounts never go negative, a page is never freed
    twice, the garbage page is never handed out, and
    free + live + idle-cached == num_pages - 1 at all times."""

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError("need at least the garbage page + one page")
        self.num_pages = num_pages
        self._free = list(range(num_pages - 1, 0, -1))   # pop() -> page 1 first
        self._ref: dict[int, int] = {}
        self._cached: set[int] = set()

    # ---- introspection ---------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def free_list(self) -> list[int]:
        return list(self._free)

    @property
    def n_cached(self) -> int:
        return len(self._cached)

    @property
    def n_evictable(self) -> int:
        """Cached pages no live sequence references (LRU-eviction fodder)."""
        return sum(1 for p in self._cached if p not in self._ref)

    def ref_count(self, page: int) -> int:
        return self._ref.get(page, 0)

    def is_cached(self, page: int) -> bool:
        return page in self._cached

    def is_idle(self, page: int) -> bool:
        return page not in self._ref

    # ---- allocation ------------------------------------------------------
    def _check(self, page: int):
        if not 0 < page < self.num_pages:
            raise ValueError(f"page {page} out of range (garbage page 0 "
                             f"never participates)")

    def try_alloc(self) -> int | None:
        """Pop a free page with refcount 1, or None when the stack is dry
        (the engine then evicts cached pages / preempts)."""
        if not self._free:
            return None
        page = self._free.pop()
        if page in self._ref or page in self._cached:
            raise AssertionError(f"page {page} on the free stack while "
                                 f"live/cached")
        self._ref[page] = 1
        return page

    def incref(self, page: int):
        """One more sequence references `page` (prefix-cache hit; also
        revives an idle cached page)."""
        self._check(page)
        if page not in self._ref and page not in self._cached:
            raise ValueError(f"incref of free page {page}")
        self._ref[page] = self._ref.get(page, 0) + 1

    def decref(self, page: int):
        """One fewer reference; at 0 the page frees unless the prefix
        index still pins it (then it stays resident, evictable)."""
        self._check(page)
        if self._ref.get(page, 0) <= 0:
            raise ValueError(f"decref of page {page} with no references "
                             f"(double free?)")
        self._ref[page] -= 1
        if self._ref[page] == 0:
            del self._ref[page]
            if page not in self._cached:
                self._free.append(page)

    # ---- prefix-cache pinning --------------------------------------------
    def cache(self, page: int):
        """Pin `page` as prefix-cache resident (it must be live — pages
        are registered while their owner still holds them)."""
        self._check(page)
        if page not in self._ref and page not in self._cached:
            raise ValueError(f"cache of free page {page}")
        self._cached.add(page)

    def uncache(self, page: int) -> bool:
        """Unpin `page` (eviction); frees it if no sequence holds it.
        Returns True when the page returned to the free stack."""
        self._check(page)
        if page not in self._cached:
            raise ValueError(f"uncache of page {page} that is not cached")
        self._cached.remove(page)
        if page not in self._ref:
            self._free.append(page)
            return True
        return False


def copy_layer_pages(pages: dict, src, dst, stacked: bool = False) -> dict:
    """Copy page `src` onto page `dst` in one layer's pools (the device
    half of copy-on-write; posit pages copy as raw bits, so the copy is
    bit-identical by construction).  src/dst may be traced scalars;
    stacked=True for scan-stacked pools ([reps, num_pages, ...])."""
    def cp(buf):
        if stacked:
            return buf.at[:, dst].set(buf[:, src])
        return buf.at[dst].set(buf[src])

    kp, vp = pages["k_pages"], pages["v_pages"]
    if isinstance(kp, PositArray):
        return {"k_pages": PositArray(cp(kp.bits), kp.cfg),
                "v_pages": PositArray(cp(vp.bits), vp.cfg)}
    return {"k_pages": cp(kp), "v_pages": cp(vp)}


def poison_layer_pages(pages: dict, pg, stacked: bool = False) -> dict:
    """Overwrite page `pg` of one layer's pools with the posit NaR pattern
    (1000...0 per element; NaN for float pools) — the chaos harness's
    bit-flipped-page injection.  A poisoned page decodes to NaN, so the
    owning sequence's next attention read propagates NaN into *its* logits
    (and only its — pages are per-sequence unless prefix-shared, and the
    injector targets unshared pages), tripping the engine's NaR detector."""
    def po(buf, fill):
        if stacked:
            return buf.at[:, pg].set(fill)
        return buf.at[pg].set(fill)

    kp, vp = pages["k_pages"], pages["v_pages"]
    if isinstance(kp, PositArray):
        # NaR as a signed storage value: the bit pattern 1000...0 is
        # -2^(n-1) in two's complement (int8/int16-safe, unlike 2^(n-1))
        nar = -(1 << (kp.cfg.n - 1))
        return {"k_pages": PositArray(po(kp.bits, nar), kp.cfg),
                "v_pages": PositArray(po(vp.bits, nar), vp.cfg)}
    return {"k_pages": po(kp, jnp.nan), "v_pages": po(vp, jnp.nan)}


def init_layer_pages(num_pages: int, n_kv: int, page_size: int, head_dim: int,
                     cfg: PositConfig | None, dtype=jnp.float32):
    """One attention layer's page pools: {"k_pages", "v_pages"}."""
    shape = (num_pages, n_kv, page_size, head_dim)
    if cfg is not None:
        dt = jnp.dtype(cfg.storage_dtype_name)
        return {"k_pages": PositArray(jnp.zeros(shape, dt), cfg),
                "v_pages": PositArray(jnp.zeros(shape, dt), cfg)}
    return {"k_pages": jnp.zeros(shape, dtype),
            "v_pages": jnp.zeros(shape, dtype)}


def assemble_layer_cache(pages: dict, page_table, seq_lens, num_new) -> dict:
    """Pages (device state) + scheduler inputs -> the per-layer cache dict."""
    return {"k_pages": pages["k_pages"], "v_pages": pages["v_pages"],
            "page_table": page_table, "seq_lens": seq_lens,
            "num_new": num_new}


def extract_layer_pages(cache: dict) -> dict:
    return {"k_pages": cache["k_pages"], "v_pages": cache["v_pages"]}


def is_paged(cache) -> bool:
    return isinstance(cache, dict) and "page_table" in cache


def page_size_of(cache) -> int:
    return cache["k_pages"].shape[2]


def paged_append_kv(cache: dict, k, v) -> dict:
    """Scatter `num_new` new tokens per sequence into the page pool.

    k, v: [B, n_kv, S, D] float.  Token j of sequence i lands at logical
    position `seq_lens[i] + j` -> (page_table[i, pos // page], pos % page);
    positions with j >= num_new[i] (inactive slots, ragged prefill tails)
    are dropped via out-of-bounds scatter indices.  Distinct live (i, j)
    always hit distinct (page, offset) slots, so the scatter is
    collision-free by construction.
    """
    kp, vp = cache["k_pages"], cache["v_pages"]
    posit_pages = isinstance(kp, PositArray)
    pcfg = kp.cfg if posit_pages else None
    kbuf = kp.bits if posit_pages else kp
    vbuf = vp.bits if posit_pages else vp
    if pcfg is not None:
        k = f32_to_posit(k.astype(jnp.float32), pcfg)
        v = f32_to_posit(v.astype(jnp.float32), pcfg)
    else:
        k = k.astype(kbuf.dtype)
        v = v.astype(vbuf.dtype)

    table, seq_lens, num_new = (cache["page_table"], cache["seq_lens"],
                                cache["num_new"])
    B, n_kv, S, D = k.shape
    page = kbuf.shape[2]
    width = table.shape[1]

    pos = seq_lens[:, None] + jnp.arange(S)[None, :]            # [B, S]
    valid = jnp.arange(S)[None, :] < num_new[:, None]           # [B, S]
    slot = pos // page                                          # [B, S]
    in_table = slot < width
    page_idx = jnp.take_along_axis(table, jnp.clip(slot, 0, width - 1),
                                   axis=1)
    # invalid writes -> index num_pages, truly out of bounds, so the scatter
    # drops them.  (-1 would NOT work: jnp .at[] wraps negative indices
    # numpy-style and the write would land in the pool's last page.)
    page_idx = jnp.where(valid & in_table, page_idx, kbuf.shape[0])
    off = pos % page

    flat_pg = page_idx.reshape(-1)
    flat_off = off.reshape(-1)
    kv_vals = k.transpose(0, 2, 1, 3).reshape(B * S, n_kv, D)
    vv_vals = v.transpose(0, 2, 1, 3).reshape(B * S, n_kv, D)
    new_k = kbuf.at[flat_pg, :, flat_off, :].set(kv_vals, mode="drop")
    new_v = vbuf.at[flat_pg, :, flat_off, :].set(vv_vals, mode="drop")
    if posit_pages:
        new_k = PositArray(new_k, pcfg)
        new_v = PositArray(new_v, pcfg)
    return {"k_pages": new_k, "v_pages": new_v, "page_table": table,
            "seq_lens": seq_lens + num_new, "num_new": num_new}


def gather_kv(cache: dict):
    """Dense view of the paged cache: [B, n_kv, table_width * page, D].

    Page p of sequence i occupies positions [p*page, (p+1)*page) in order,
    so the gathered view is position-identical to a dense cache of
    max_len == table_width * page — the basis of the paged-vs-dense
    bit-exactness guarantee (and of the jnp attention path; the Pallas
    kernel gathers page-by-page in VMEM instead, see
    kernels.flash_attention.paged_flash_decode).
    """
    kp, vp = cache["k_pages"], cache["v_pages"]
    posit_pages = isinstance(kp, PositArray)
    kbuf = kp.bits if posit_pages else kp
    vbuf = vp.bits if posit_pages else vp
    table = cache["page_table"]
    B, W = table.shape
    _, n_kv, page, D = kbuf.shape

    def dense(buf):
        g = buf[table]                                  # [B, W, n_kv, page, D]
        g = g.transpose(0, 2, 1, 3, 4).reshape(B, n_kv, W * page, D)
        return g

    k, v = dense(kbuf), dense(vbuf)
    if posit_pages:
        return PositArray(k, kp.cfg), PositArray(v, vp.cfg)
    return k, v


def paged_attention(q, cache: dict, *, n_kv: int, causal: bool = True,
                    q_offset=None, window: int | None = None,
                    softcap: float | None = None,
                    interpret: bool | None = None):
    """Attention over a paged cache.  q: [B, H, Sq, D] float.

    On the Pallas path (TPU, or CPU interpret mode) **every** shape is
    fused: decode steps (Sq == 1, no softcap) take paged_flash_decode, and
    everything else — prefill chunks of any Sq, softcapped archs, windowed
    prefill — takes paged_flash_prefill.  Both scalar-prefetch the page
    table and decode posit pages in VMEM right before the MXU, so the TPU
    hot path performs no dense KV materialization for any Sq, with or
    without window/softcap.

    The gather_kv + models.blocks.blockwise_attention path (bit-identical
    to the dense engine by construction) survives only as the CPU/interpret
    reference oracle; taking it is counted in GATHER_FALLBACKS so tests can
    assert the steady-state TPU path never lands there.
    """
    from repro.kernels import ops as kops

    B, H, Sq, D = q.shape
    if q_offset is None:
        # the cache is post-append: queries start where this step's tokens
        # were written.  (None must not reach blockwise_attention — it would
        # become a NaN position and mask every key.)
        q_offset = cache["seq_lens"] - cache["num_new"]
    kp = cache["k_pages"]
    posit_pages = isinstance(kp, PositArray)
    if kops.use_pallas() and not kops.force_reference():
        if Sq == 1 and softcap is None:
            from repro.kernels.flash_attention import paged_flash_decode
            kbuf = kp.bits if posit_pages else kp
            vbuf = cache["v_pages"].bits if posit_pages else cache["v_pages"]
            out = paged_flash_decode(
                q[:, :, 0, :], kbuf, vbuf, cache["page_table"],
                cache["seq_lens"],
                cfg_kv=kp.cfg if posit_pages else None, window=window,
                interpret=(kops.pallas_interpret() if interpret is None
                           else interpret))
            return out[:, :, None, :].astype(q.dtype)
        q_off = jnp.broadcast_to(
            jnp.asarray(q_offset, jnp.int32).reshape(-1), (B,))
        out = kops.paged_prefill_attention(
            q, kp, cache["v_pages"], cache["page_table"],
            cache["seq_lens"], q_off, causal=causal, window=window,
            softcap=softcap, interpret=interpret)
        return out.astype(q.dtype)

    GATHER_FALLBACKS["forced" if kops.use_pallas() else "jnp-reference"] += 1
    from repro.models.blocks import blockwise_attention
    k, v = gather_kv(cache)
    return blockwise_attention(q, k, v, n_kv=n_kv, causal=causal,
                               q_offset=q_offset, window=window,
                               softcap=softcap, kv_len=cache["seq_lens"])
