"""Prefix cache: a content-addressed radix index over posit KV pages.

At production traffic most requests share a system prompt or few-shot
template, yet every admission used to re-prefill it from scratch.  KV at a
position depends only on the token stream up to that position (and the
absolute positions themselves), so a *full* page — the KV for tokens
[j*page_size, (j+1)*page_size) of some prefix — can be shared verbatim by
every sequence whose first (j+1)*page_size tokens match.  Because the paged
pool stores posit8/16 pages (paper C4/C6), the same HBM holds 2-4x more
cached prefix tokens than an f32 serving stack — this module is what turns
that density into time-to-first-token.

Design (host-side; the device never sees any of this — shared pages are
just page-table entries appearing in several sequences' rows):

  * **Content addressing.**  Each full page is keyed by a chained digest:
    ``digest_j = blake2b(digest_{j-1} + tokens_j.tobytes())`` with the root
    digest seeded from a per-(model, KV format, page size) key, so caches
    of different models/formats can never alias.  The chain makes the key
    cover the *whole* prefix, not just the local chunk — two prompts that
    share page 3's tokens but differ in page 0 hash to different keys.

  * **Radix index.**  Digests are arranged in a trie whose path from the
    root spells the prefix page by page: ``lookup(prompt)`` walks full-page
    chunks and returns the longest cached prefix's pages, ``insert``
    registers a freshly filled page under its parent (deduping against an
    existing identical page — the caller adopts the existing page id and
    frees its own copy, since the contents are bit-identical by
    construction).  One index per data shard: page ids are shard-local and
    pages cannot migrate between sub-pools, which also keeps the
    data-parallel engine's behavior bitwise independent per shard.

  * **Sharing & eviction.**  Live refcounts stay in paged_kv.PagePool; the
    index *pins* registered pages so a retiring sequence's prefix pages
    stay resident (ref 0, pinned) instead of returning to the free list.
    Under pool pressure the engine LRU-evicts pinned ref-0 *leaf* pages
    (children always die before parents, so an interior page is never
    orphaned) before it ever preempts a live sequence.  Copy-on-write is
    the engine's job: a write landing mid-page in a shared page first
    copies the page device-side and rewrites the owner's table entry.

The scheduler fields this module keeps per node are O(1); the whole index
is O(cached pages) host memory and never enters a jitted computation.
"""
from __future__ import annotations

import hashlib

import numpy as np

__all__ = ["RadixIndex", "chunk_digest", "root_digest"]


def root_digest(key: str) -> bytes:
    """Root of the digest chain: the model/format/page-size cache key."""
    return hashlib.blake2b(key.encode(), digest_size=16).digest()


def chunk_digest(parent: bytes, tokens: np.ndarray) -> bytes:
    """Chained content address of one full page of tokens."""
    tokens = np.ascontiguousarray(np.asarray(tokens, np.int32))
    return hashlib.blake2b(parent + tokens.tobytes(),
                           digest_size=16).digest()


class _Node:
    """One cached full page.  The path root -> node spells a prefix."""
    __slots__ = ("digest", "tokens", "page", "parent", "children",
                 "last_used")

    def __init__(self, digest: bytes, tokens: np.ndarray, page: int,
                 parent: "_Node | None", last_used: int):
        self.digest = digest
        self.tokens = tokens
        self.page = page
        self.parent = parent
        self.children: dict[bytes, _Node] = {}
        self.last_used = last_used


class RadixIndex:
    """Trie of content-addressed cached pages for one page sub-pool.

    All methods are host-side bookkeeping; refcount/pinning side effects
    are the caller's (the engine pairs every lookup with PagePool.incref
    and every insert with PagePool.cache)."""

    def __init__(self, key: str, page_size: int):
        self.page = page_size
        self.root = _Node(root_digest(key), np.zeros((0,), np.int32), -1,
                          None, 0)
        self.by_page: dict[int, _Node] = {}

    def __len__(self) -> int:
        return len(self.by_page)

    def _child(self, node: _Node, chunk: np.ndarray) -> "_Node | None":
        d = chunk_digest(node.digest, chunk)
        c = node.children.get(d)
        if c is not None and not np.array_equal(c.tokens, chunk):
            return None          # 128-bit collision guard: treat as a miss
        return c

    def lookup(self, tokens: np.ndarray, clock: int):
        """Longest cached prefix of `tokens`, full pages only.

        Returns (pages, deepest_node); touches every matched node's LRU
        stamp.  The caller must incref each returned page before anything
        can evict it."""
        tokens = np.asarray(tokens, np.int32)
        node, pages = self.root, []
        for lo in range(0, len(tokens) - self.page + 1, self.page):
            c = self._child(node, tokens[lo:lo + self.page])
            if c is None:
                break
            c.last_used = clock
            pages.append(c.page)
            node = c
        return pages, node

    def probe(self, tokens: np.ndarray) -> int:
        """Read-only longest-cached-prefix length in tokens (no LRU
        touch) — the submit()-time lookup feeding scheduling stats."""
        tokens = np.asarray(tokens, np.int32)
        node, n = self.root, 0
        for lo in range(0, len(tokens) - self.page + 1, self.page):
            c = self._child(node, tokens[lo:lo + self.page])
            if c is None:
                break
            n += self.page
            node = c
        return n

    def insert(self, parent: _Node, chunk: np.ndarray, page: int,
               clock: int):
        """Register `page` as holding `chunk`'s KV under `parent`.

        Returns (node, existing_page): existing_page is not None when an
        identical page was already cached — the caller should adopt it
        (swap its table entry, incref the existing page, decref its own
        copy) because the two pages are bit-identical."""
        chunk = np.asarray(chunk, np.int32).copy()
        if len(chunk) != self.page:
            raise ValueError(f"can only register full pages "
                             f"({len(chunk)} != {self.page})")
        d = chunk_digest(parent.digest, chunk)
        c = parent.children.get(d)
        if c is not None and np.array_equal(c.tokens, chunk):
            c.last_used = clock
            return c, c.page
        node = _Node(d, chunk, page, parent, clock)
        parent.children[d] = node
        self.by_page[page] = node
        return node, None

    def evict_lru(self, is_idle) -> int | None:
        """Drop the least-recently-used evictable page and return its id
        (None if nothing is evictable).  Evictable: a *leaf* (interior
        pages outlive their children, so a cached chain never dangles)
        whose page `is_idle` (refcount 0) says no live sequence shares."""
        victim = None
        for n in self.by_page.values():
            if n.children or not is_idle(n.page):
                continue
            if victim is None or n.last_used < victim.last_used:
                victim = n
        if victim is None:
            return None
        del victim.parent.children[victim.digest]
        del self.by_page[victim.page]
        return victim.page

    def drop_page(self, page: int):
        """Unregister `page` (and its now-unreachable descendants) — used
        when the engine must invalidate rather than evict in LRU order."""
        node = self.by_page.get(page)
        if node is None:
            return []
        stack, dropped = [node], []
        del node.parent.children[node.digest]
        while stack:
            n = stack.pop()
            dropped.append(n.page)
            del self.by_page[n.page]
            stack.extend(n.children.values())
        return dropped
