"""Elastic-exact data-parallel training: the worker loop behind
launch/supervisor.py.

The correctness problem with elastic resume is not resuming — it is that
a shrunk world must keep producing the *same parameters*.  Gradients of a
mean loss are a sum over per-example gradients, and floating-point
addition is not associative: summing 4 per-host partials gives different
bits than summing 3, so a 4→3 worker shrink that naively all-reduces
partial gradients silently forks the training trajectory and "bit-
identical resume" becomes unverifiable.

This loop makes the update bitwise invariant to how rows are grouped onto
workers:

  * each worker computes PER-ROW gradients for its balanced slice of the
    global batch (data.pipeline.host_row_bounds — the slices tile the
    global batch for any worker count), via lax.map over [1, S]
    microbatches, padded to the global ceil(B/H) row budget;
  * the padded per-row stacks are exchanged with one
    multihost_utils.process_allgather (ordered by process index), so
    every worker holds every row's gradient in canonical global row
    order;
  * the reduction is a sequential fori_loop over global rows, with
    padding rows skipped by a where-select (which leaves the accumulator
    bit-untouched — adding a zero would already flip -0.0 to +0.0).

The per-row gradient values themselves do not depend on which worker
computed them (same jitted row function, same shapes), and the ordered
sum does not depend on the grouping — so 4 workers, 3 workers, and a
single process all produce bit-identical parameters from the same seed,
which is exactly what tests/test_supervisor.py pins end-to-end through a
SIGKILL + shrunk restart.

Cost: per-row gradients forgo batched matmul efficiency — this is the
deliberate price of regroup-invariance, paid at microbatch granularity
(production systems pick a fixed microgroup size that divides every
allowed world size; row granularity is the always-valid special case and
keeps this CPU-scale rig simple).  Everything outside the row loop
(optimizer, norm, schedule) is replicated deterministic compute.
"""
from __future__ import annotations

import os
import signal
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import store
from repro.checkpoint.async_store import AsyncCheckpointStore
from repro.data.pipeline import DataConfig, host_batch_at, host_row_bounds
from repro.distributed.fault_tolerance import Heartbeat, RestartPolicy
from repro.models.transformer import ModelConfig, init_params
from repro.optim import adamw
from repro.training.train_step import lm_loss


def max_host_rows(global_batch: int, num_hosts: int) -> int:
    """Padded per-host row budget: ceil(B / H), uniform across hosts so
    the all-gathered stacks have one static shape per world size."""
    return -(-global_batch // num_hosts)


def valid_row_mask(global_batch: int, num_hosts: int) -> np.ndarray:
    """[num_hosts * maxR] bool: which entries of the flattened gathered
    stack are real rows (in canonical global row order) vs padding."""
    max_r = max_host_rows(global_batch, num_hosts)
    mask = np.zeros((num_hosts, max_r), bool)
    for h in range(num_hosts):
        lo, hi = host_row_bounds(global_batch, h, num_hosts)
        mask[h, :hi - lo] = True
    return mask.reshape(-1)


def make_row_grad_fn(cfg: ModelConfig):
    """jit: (params, rows [R, S+1]) -> (losses [R], grads stacked [R, ...]).
    One value_and_grad per [1, S] microbatch under lax.map — the
    per-iteration computation (and therefore each row's gradient bits) is
    independent of R, i.e. of the worker count."""

    def one(params, row):
        (loss, _), g = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, {"tokens": row[None]}),
            has_aux=True)(params)
        return loss, g

    return jax.jit(lambda params, rows:
                   jax.lax.map(lambda r: one(params, r), rows))


def make_ordered_update_fn(cfg: ModelConfig, opt_cfg: adamw.OptConfig):
    """jit: ordered masked reduction over the gathered per-row gradient
    stacks + the AdamW update.  The fori_loop walks global row order
    0..N-1 sequentially; invalid (padding) entries leave the accumulator
    bit-untouched via where-select, so the result depends only on the
    valid rows' values and order — never on the host grouping."""

    def update(params, opt_state, losses, grads, valid, global_batch):
        def body(i, acc):
            g_acc, l_acc = acc
            take = valid[i]
            g_acc = jax.tree_util.tree_map(
                lambda a, s: jnp.where(take, a + s[i].astype(jnp.float32), a),
                g_acc, grads)
            return g_acc, jnp.where(take, l_acc + losses[i], l_acc)

        zeros = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape[1:], jnp.float32), grads)
        g_sum, l_sum = jax.lax.fori_loop(
            0, valid.shape[0], body, (zeros, jnp.zeros((), jnp.float32)))
        inv = 1.0 / global_batch
        g_mean = jax.tree_util.tree_map(lambda g: g * inv, g_sum)
        params, opt_state, m = adamw.apply_updates(params, g_mean,
                                                   opt_state, opt_cfg)
        return params, opt_state, dict(m, loss=l_sum * inv)

    return jax.jit(update, static_argnames=("global_batch",))


def _gather_rows(losses, grads, num_hosts: int):
    """All hosts' padded per-row stacks, flattened to canonical global row
    order ([H*maxR, ...]).  Ordered by process index — process_allgather
    stacks host h's rows at slot h, matching host_row_bounds."""
    if num_hosts == 1:
        return losses, grads
    from jax.experimental import multihost_utils
    losses, grads = multihost_utils.process_allgather((losses, grads))
    flat = lambda x: jnp.reshape(jnp.asarray(x), (-1,) + x.shape[2:])
    return flat(losses), jax.tree_util.tree_map(flat, grads)


def elastic_train_loop(cfg: ModelConfig, opt_cfg: adamw.OptConfig,
                       data_cfg: DataConfig, num_steps: int, *,
                       ckpt_dir: str | None = None,
                       policy: RestartPolicy = RestartPolicy(),
                       host_id: int = 0, num_hosts: int = 1,
                       heartbeat: Heartbeat | None = None,
                       async_ckpt: bool = False, seed: int = 0,
                       log_every: int = 10, verbose: bool = True,
                       chaos_kill_at: int | None = None,
                       chaos_straggle_at: int | None = None,
                       chaos_straggle_s: float = 30.0,
                       ckpt_stalls_out: list | None = None):
    """Runs (or resumes) one worker of an elastic data-parallel group.

    Every host executes the same loop on its derived host_batch_at slice;
    host 0 is the checkpoint writer (all hosts hold bit-identical state,
    so one writer suffices and restore is symmetric).  num_hosts == 1 is
    the uninterrupted-reference special case: no collectives at all, same
    math.  Chaos hooks (the supervisor's generation-0 fault injection):
    chaos_kill_at SIGKILLs this process at the top of that step;
    chaos_straggle_at sleeps chaos_straggle_s before computing it.

    Returns (params, opt_state, history) like training.trainer.train_loop.
    """
    B = data_cfg.global_batch
    max_r = max_host_rows(B, num_hosts)
    lo, hi = host_row_bounds(B, host_id, num_hosts)

    params = init_params(jax.random.PRNGKey(seed), cfg)
    opt_state = adamw.init_state(params, opt_cfg)
    start_step = 0
    if ckpt_dir:
        step, restored = store.restore_latest(
            ckpt_dir, {"params": params, "opt": opt_state})
        if step is not None:
            params, opt_state = restored["params"], restored["opt"]
            start_step = step
            if verbose:
                print(f"[elastic h{host_id}] resumed from step {step} "
                      f"({num_hosts} hosts)", flush=True)

    row_grads = make_row_grad_fn(cfg)
    update = make_ordered_update_fn(cfg, opt_cfg)
    valid = jnp.asarray(valid_row_mask(B, num_hosts))

    writer = (host_id == 0 and ckpt_dir is not None)
    astore = (AsyncCheckpointStore(ckpt_dir, keep=policy.keep)
              if writer and async_ckpt else None)

    def _save(step, tree):
        if astore is not None:
            return astore.save(step, tree)
        t0 = time.perf_counter()
        store.save(ckpt_dir, step, tree, keep=policy.keep)
        return time.perf_counter() - t0

    history, step_s = [], []
    # caller-visible per-checkpoint stall seconds (the elastic bench reads
    # these to compare sync vs async checkpointing)
    ckpt_stalls = ckpt_stalls_out if ckpt_stalls_out is not None else []
    try:
        for step in range(start_step, num_steps):
            if heartbeat is not None:
                heartbeat.beat(step, "step")
            if chaos_kill_at is not None and step == chaos_kill_at:
                os.kill(os.getpid(), signal.SIGKILL)   # node death, induced
            if chaos_straggle_at is not None and step == chaos_straggle_at:
                time.sleep(chaos_straggle_s)
            t0 = time.perf_counter()
            rows = host_batch_at(step, data_cfg, host_id,
                                 num_hosts)["tokens"]
            pad = max_r - rows.shape[0]
            if pad:
                rows = jnp.concatenate(
                    [rows, jnp.zeros((pad, rows.shape[1]), rows.dtype)])
            losses, grads = row_grads(params, rows)
            if heartbeat is not None:
                heartbeat.beat(step, "sync")
            losses, grads = _gather_rows(losses, grads, num_hosts)
            params, opt_state, metrics = update(params, opt_state, losses,
                                                grads, valid,
                                                global_batch=B)
            jax.block_until_ready(params)
            step_s.append(time.perf_counter() - t0)
            if step % log_every == 0 or step == num_steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                m["step_s"] = step_s[-1]
                history.append(m)
                if verbose and host_id == 0:
                    print(f"[elastic h0/{num_hosts}] step {step:5d} "
                          f"loss {m['loss']:.4f} gnorm {m['grad_norm']:.3f} "
                          f"{m['step_s'] * 1e3:.0f} ms", flush=True)
            if writer and (step + 1) % policy.ckpt_every == 0:
                ckpt_stalls.append(
                    _save(step + 1, {"params": params, "opt": opt_state}))
        if writer:
            ckpt_stalls.append(
                _save(num_steps, {"params": params, "opt": opt_state}))
        if astore is not None:
            astore.wait()
    finally:
        if astore is not None:
            astore.close()
    if num_hosts > 1:
        from jax.experimental import multihost_utils
        multihost_utils.sync_global_devices("elastic_loop_done")
    if heartbeat is not None:
        heartbeat.done(num_steps)
    if verbose and host_id == 0 and step_s:
        lat = np.asarray(step_s) * 1e3
        print(f"[elastic h0/{num_hosts}] done: {len(step_s)} steps, "
              f"step_ms p50={np.percentile(lat, 50):.0f} "
              f"p99={np.percentile(lat, 99):.0f}, "
              f"ckpt stalls {[round(s * 1e3, 1) for s in ckpt_stalls]} ms",
              flush=True)
    return params, opt_state, history
