"""Loss and train step — the function the dry-run lowers for train_4k.

Next-token cross-entropy (encoder-only archs train on masked-frame
classification over the same label layout — synthetic targets), MoE aux
loss folded in, AdamW update, metrics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.transformer import ModelConfig, forward
from repro.optim import adamw

AUX_WEIGHT = 0.01


def _token_nll(logits, labels):
    """-log p(label) without materializing log_softmax (shard-friendly:
    logsumexp and an iota-compare masked reduce both respect a vocab-sharded
    last dim; no gather collectives)."""
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    onehot_sum = jnp.sum(
        jnp.where(jax.lax.broadcasted_iota(jnp.int32, lg.shape, lg.ndim - 1)
                  == labels[..., None], lg, 0.0), axis=-1)
    return lse - onehot_sum


LM_HEAD_CHUNK = 512


def _chunked_lm_head_nll(hidden, labels, params, cfg: ModelConfig):
    """Mean NLL with the LM head evaluated per sequence chunk (remat'd):
    the (B, S, vocab) logits tensor never exists at full length — §Perf
    iteration A3 (chunked cross-entropy)."""
    from repro.models import blocks as B
    Bsz, S, _ = hidden.shape
    c = min(LM_HEAD_CHUNK, S)
    pad = (-S) % c
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
    n = (S + pad) // c
    hc = hidden.reshape(Bsz, n, c, -1).transpose(1, 0, 2, 3)
    lc = labels.reshape(Bsz, n, c).transpose(1, 0, 2)
    valid = (jnp.arange(S + pad) < S).reshape(n, c)

    def chunk_nll(args):
        h, lab, v = args
        if cfg.tie_embeddings:
            logits = B.unembed(h, params["embed"], cfg.policy)
        else:
            logits = B.linear(h, params["unembed"], cfg.policy)
        nll = _token_nll(logits, lab)
        return jnp.sum(nll * v[None, :])

    sums = jax.lax.map(
        jax.checkpoint(chunk_nll,
                       policy=jax.checkpoint_policies.nothing_saveable),
        (hc, lc, valid))
    return sums.sum() / (Bsz * S)


def lm_loss(params, cfg: ModelConfig, batch):
    tokens = batch.get("tokens")
    if cfg.encoder_only:
        # masked-frame objective stand-in: embeddings in, per-frame classes out
        embeds = batch["embeds"]
        labels = batch["labels"]
        hidden, aux, _ = forward(params, cfg, inputs_embeds=embeds,
                                 return_hidden=True)
        nll = _chunked_lm_head_nll(hidden, labels, params, cfg)
        return nll + AUX_WEIGHT * aux, {}
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    kwargs = {}
    if cfg.input_mode == "tokens+image":
        kwargs["inputs_embeds"] = batch["image_embeds"]
    hidden, aux, _ = forward(params, cfg, tokens=inputs,
                             return_hidden=True, **kwargs)
    # VLM: image positions prepended — score only the token tail
    hidden = hidden[:, -inputs.shape[1]:]
    nll = _chunked_lm_head_nll(hidden, labels, params, cfg)
    return nll + AUX_WEIGHT * aux, {"nll": nll}


def train_step(params, opt_state, batch, cfg: ModelConfig,
               opt_cfg: adamw.OptConfig, accum_steps: int = 1):
    """One optimization step.  Pure; jit/pjit-able.

    accum_steps > 1: gradient accumulation over microbatches (sequential
    lax.scan) — activation memory scales 1/accum_steps at identical math,
    the standard fit lever for >=100B models on 16 GB chips (§Perf A2).
    """
    if accum_steps == 1:
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, batch), has_aux=True)(params)
    else:
        micro = jax.tree_util.tree_map(
            lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps,
                                *x.shape[1:]), batch)

        def acc(carry, mb):
            g_acc, l_acc = carry
            (l, _), g = jax.value_and_grad(
                lambda p: lm_loss(p, cfg, mb), has_aux=True)(params)
            g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
            return (g_acc, l_acc + l), None

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum), _ = jax.lax.scan(
            acc, (zeros, jnp.zeros((), jnp.float32)), micro)
        inv = 1.0 / accum_steps
        grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
        loss = loss_sum * inv
        metrics = {}
    params, opt_state, opt_metrics = adamw.apply_updates(
        params, grads, opt_state, opt_cfg)
    metrics = dict(metrics, loss=loss, **opt_metrics)
    return params, opt_state, metrics
