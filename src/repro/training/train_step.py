"""Loss and train step — the function the dry-run lowers for train_4k.

Next-token cross-entropy (encoder-only archs train on masked-frame
classification over the same label layout — synthetic targets), MoE aux
loss folded in, AdamW update, metrics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.transformer import ModelConfig, forward
from repro.optim import adamw

AUX_WEIGHT = 0.01


def _token_nll(logits, labels):
    """-log p(label) without materializing log_softmax (shard-friendly:
    logsumexp and an iota-compare masked reduce both respect a vocab-sharded
    last dim; no gather collectives)."""
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    onehot_sum = jnp.sum(
        jnp.where(jax.lax.broadcasted_iota(jnp.int32, lg.shape, lg.ndim - 1)
                  == labels[..., None], lg, 0.0), axis=-1)
    return lse - onehot_sum


LM_HEAD_CHUNK = 512


def _chunked_lm_head_nll(hidden, labels, params, cfg: ModelConfig):
    """Mean NLL with the LM head evaluated per sequence chunk (remat'd):
    the (B, S, vocab) logits tensor never exists at full length — §Perf
    iteration A3 (chunked cross-entropy)."""
    from repro.models import blocks as B
    Bsz, S, _ = hidden.shape
    c = min(LM_HEAD_CHUNK, S)
    pad = (-S) % c
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
    n = (S + pad) // c
    hc = hidden.reshape(Bsz, n, c, -1).transpose(1, 0, 2, 3)
    lc = labels.reshape(Bsz, n, c).transpose(1, 0, 2)
    valid = (jnp.arange(S + pad) < S).reshape(n, c)

    def chunk_nll(args):
        h, lab, v = args
        if cfg.tie_embeddings:
            logits = B.unembed(h, params["embed"], cfg.policy)
        else:
            logits = B.linear(h, params["unembed"], cfg.policy)
        nll = _token_nll(logits, lab)
        return jnp.sum(nll * v[None, :])

    sums = jax.lax.map(
        jax.checkpoint(chunk_nll,
                       policy=jax.checkpoint_policies.nothing_saveable),
        (hc, lc, valid))
    return sums.sum() / (Bsz * S)


def lm_loss(params, cfg: ModelConfig, batch):
    tokens = batch.get("tokens")
    if cfg.encoder_only:
        # masked-frame objective stand-in: embeddings in, per-frame classes out
        embeds = batch["embeds"]
        labels = batch["labels"]
        hidden, aux, _ = forward(params, cfg, inputs_embeds=embeds,
                                 return_hidden=True)
        nll = _chunked_lm_head_nll(hidden, labels, params, cfg)
        return nll + AUX_WEIGHT * aux, {}
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    kwargs = {}
    if cfg.input_mode == "tokens+image":
        kwargs["inputs_embeds"] = batch["image_embeds"]
    hidden, aux, _ = forward(params, cfg, tokens=inputs,
                             return_hidden=True, **kwargs)
    # VLM: image positions prepended — score only the token tail
    hidden = hidden[:, -inputs.shape[1]:]
    nll = _chunked_lm_head_nll(hidden, labels, params, cfg)
    return nll + AUX_WEIGHT * aux, {"nll": nll}


def _compute_grads(params, batch, cfg: ModelConfig, accum_steps: int):
    """(loss, metrics, grads) for one (micro-accumulated) batch.

    accum_steps > 1: gradient accumulation over microbatches (sequential
    lax.scan) — activation memory scales 1/accum_steps at identical math,
    the standard fit lever for >=100B models on 16 GB chips (§Perf A2).
    """
    if accum_steps == 1:
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, batch), has_aux=True)(params)
        return loss, metrics, grads
    micro = jax.tree_util.tree_map(
        lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps,
                            *x.shape[1:]), batch)

    def acc(carry, mb):
        g_acc, l_acc = carry
        (l, _), g = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, mb), has_aux=True)(params)
        g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
        return (g_acc, l_acc + l), None

    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (grads, loss_sum), _ = jax.lax.scan(
        acc, (zeros, jnp.zeros((), jnp.float32)), micro)
    inv = 1.0 / accum_steps
    grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
    return loss_sum * inv, {}, grads


def _poison_grads(grads, poison):
    """Chaos hook: where `poison` (traced bool scalar) is set, replace
    every gradient leaf with NaN — what a posit NaR entering the gradient
    stream decodes to — so adamw's non-finite guard trips.  A per-leaf
    where-select, so poison=False keeps the gradients bit-identical."""
    return jax.tree_util.tree_map(
        lambda g: jnp.where(poison, jnp.asarray(jnp.nan, g.dtype), g), grads)


def train_step(params, opt_state, batch, cfg: ModelConfig,
               opt_cfg: adamw.OptConfig, accum_steps: int = 1,
               poison=None):
    """One optimization step.  Pure; jit/pjit-able."""
    loss, metrics, grads = _compute_grads(params, batch, cfg, accum_steps)
    if poison is not None:
        grads = _poison_grads(grads, poison)
    params, opt_state, opt_metrics = adamw.apply_updates(
        params, grads, opt_state, opt_cfg)
    metrics = dict(metrics, loss=loss, **opt_metrics)
    return params, opt_state, metrics


def _mesh_grad_norm(grads, pspecs):
    """Global gradient norm on the ("data","model") mesh, computed inside
    the shard_map body *after* the data-axis sync.

    'model'-sharded leaves hold disjoint slices per member — their squared
    sums psum over the TP axis; replicated leaves (norms, embeddings,
    biases) carry identical full gradients on every member thanks to the
    blocks' f-operator, so a local sum is already global.  A plain
    adamw.global_norm inside the body would miss the TP shards; outside it
    would need fully-gathered grads."""
    sq_local = jnp.zeros((), jnp.float32)
    sq_model = jnp.zeros((), jnp.float32)
    leaves = jax.tree_util.tree_leaves(grads)
    specs = jax.tree_util.tree_leaves(
        pspecs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    assert len(leaves) == len(specs), (len(leaves), len(specs))
    for g, s in zip(leaves, specs):
        sq = jnp.sum(jnp.square(g.astype(jnp.float32)))
        if any(ax is not None and "model" in jax.tree_util.tree_leaves([ax])
               for ax in tuple(s)):
            sq_model = sq_model + sq
        else:
            sq_local = sq_local + sq
    return jnp.sqrt(sq_local + jax.lax.psum(sq_model, "model"))


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.OptConfig, mesh=None, *,
                    accum_steps: int = 1, donate: bool = True,
                    chaos_nar: bool = False):
    """Build the jitted train step: `step(params, opt_state, batch)` —
    or, with chaos_nar=True, `step(params, opt_state, batch, poison)`
    where `poison` is a bool scalar that NaNs the gradient tree on device
    (the trainer's fault-injection hook; the default build carries no
    poison plumbing at all, so the production step is untouched).

    mesh None — the single-device path: plain jit with params/opt-state
    donated (the two largest buffers alias in place; at 235B+f32 moments a
    non-donated step would hold 3x the resident state during the update).

    mesh — one shard_map over the ("data","model") mesh, the training twin
    of serving's _sharded_paged_step.  Inside the body partitioning is
    manual, so the Pallas kernels (flash fwd/bwd, grouped MoE, posit GEMM
    — none of which carry GSPMD rules) run on shard-local tiles:

      data axis:  pure DP — batch rows shard, grads mean via
          distributed.collectives.compressed_grad_sync (posit wire format
          per cfg.policy.grads; exact f32 psum when unset or ndata == 1).
      model axis: Megatron TP per sharding.train_param_pspecs (column/row-
          parallel weights, replicated embed/unembed — no vocab
          parallelism, so the loss needs no vocab collectives).  The
          blocks' forward psum (block_psum) and backward f-operator
          (block_grad_sync) are the only TP collectives per layer.

    TP training (ntp > 1) is attention/MLP stacks only: MoE router
    gradients and recurrent scan states are partial-per-shard and would
    silently diverge — those archs raise and should train DP/FSDP.
    """
    if mesh is None:
        if chaos_nar:
            def step(params, opt_state, batch, poison):
                return train_step(params, opt_state, batch, cfg, opt_cfg,
                                  accum_steps, poison=poison)
        else:
            def step(params, opt_state, batch):
                return train_step(params, opt_state, batch, cfg, opt_cfg,
                                  accum_steps)
        return jax.jit(step, donate_argnums=(0, 1) if donate else ())

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.distributed.collectives import (compressed_grad_sync,
                                               tensor_parallel)
    from repro.distributed import sharding

    ndata, ntp = mesh.shape["data"], mesh.shape["model"]
    if ntp > 1:
        bad = [k for k in cfg.block_pattern if k not in ("attn", "attn_local")]
        if bad or cfg.moe is not None:
            raise NotImplementedError(
                f"TP training (model axis = {ntp}) supports attention/MLP "
                f"stacks only; {cfg.name} has moe={cfg.moe is not None}, "
                f"blocks={bad}.  Use a (ndev, 1) data-parallel mesh.")
    wire = cfg.policy.grads if cfg.policy is not None else None

    def body(pspecs, params, opt_state, batch, poison=None):
        with tensor_parallel("model", ntp):
            loss, metrics, grads = _compute_grads(params, batch, cfg,
                                                  accum_steps)
        if ndata > 1:
            inv = 1.0 / ndata
            grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
            grads = compressed_grad_sync(grads, "data", wire)
            loss = jax.lax.pmean(loss, "data")
            metrics = {k: jax.lax.pmean(v, "data") for k, v in metrics.items()}
        if poison is not None:
            # chaos: the NaN reaches the guard through the grad norm,
            # exactly like a real NaR-poisoned gradient would
            grads = _poison_grads(grads, poison)
        gn = _mesh_grad_norm(grads, pspecs)
        params, opt_state, opt_metrics = adamw.apply_updates(
            params, grads, opt_state, opt_cfg, grad_norm=gn)
        return params, opt_state, dict(metrics, loss=loss, **opt_metrics)

    def _specs(params, opt_state, batch):
        pspecs = sharding.train_param_pspecs(params, mesh)
        ospecs = sharding.opt_state_pspecs(opt_state, pspecs, mesh)
        bspecs = jax.tree_util.tree_map(
            lambda x: P("data") if getattr(x, "ndim", 0) else P(), batch)
        return pspecs, ospecs, bspecs

    def _backfill(opt_state):
        # pre-nar_skips checkpoints: backfill the guard counter so the
        # output opt_state tree (which always carries it) matches out_specs
        opt_state = dict(opt_state)
        opt_state.setdefault("nar_skips", jnp.zeros((), jnp.int32))
        return opt_state

    if chaos_nar:
        def step(params, opt_state, batch, poison):
            opt_state = _backfill(opt_state)
            pspecs, ospecs, bspecs = _specs(params, opt_state, batch)
            return shard_map(
                functools.partial(body, pspecs), mesh=mesh,
                in_specs=(pspecs, ospecs, bspecs, P()),
                out_specs=(pspecs, ospecs, P()),
                check_rep=False,
            )(params, opt_state, batch, poison)
    else:
        def step(params, opt_state, batch):
            opt_state = _backfill(opt_state)
            pspecs, ospecs, bspecs = _specs(params, opt_state, batch)
            return shard_map(
                functools.partial(body, pspecs), mesh=mesh,
                in_specs=(pspecs, ospecs, bspecs),
                out_specs=(pspecs, ospecs, P()),
                check_rep=False,
            )(params, opt_state, batch)

    return jax.jit(step, donate_argnums=(0, 1) if donate else ())
