"""Training loop with checkpoint/restart fault tolerance.

Single-host CPU runs drive the examples and tests; launch/train.py wraps the
same loop in a mesh with sharded params.  The step itself comes from
training.train_step.make_train_step — plain donated jit on one device, a
shard_map over the ("data","model") mesh otherwise, so the Pallas training
kernels engage identically in both.

Each log interval also records the kernel-dispatch health counters —
deltas of BWD_FALLBACKS (kernels.ops), DENSE_MOE_FALLBACKS (models.moe)
and GATHER_FALLBACKS (serving.paged_kv) since the previous log line — and
steps/sec.  On the Pallas path all three deltas staying zero is the "the
training step is actually running on the kernels" invariant the tier-1
suite asserts; a nonzero delta in a log line is the first sign a config
silently fell back to the jnp oracles.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.checkpoint import store
from repro.data.pipeline import DataConfig, global_batch_at
from repro.distributed.fault_tolerance import RestartPolicy, StepWatchdog
from repro.models.transformer import ModelConfig, init_params
from repro.optim import adamw
from repro.training.train_step import make_train_step


def _fallback_counters():
    """Snapshot of every kernel-fallback counter, one flat dict."""
    from repro.kernels import ops as kops
    from repro.models import moe
    from repro.serving import paged_kv
    out = {}
    for name, ctr in (("bwd", kops.BWD_FALLBACKS),
                      ("moe", moe.DENSE_MOE_FALLBACKS),
                      ("gather", paged_kv.GATHER_FALLBACKS)):
        for k, v in ctr.items():
            out[f"{name}:{k}"] = int(v)
    return out


def _counter_delta(before, after):
    return {k: v - before.get(k, 0) for k, v in after.items()
            if v - before.get(k, 0)}


def train_loop(cfg: ModelConfig, opt_cfg: adamw.OptConfig,
               data_cfg: DataConfig, num_steps: int,
               ckpt_dir: str | None = None,
               policy: RestartPolicy = RestartPolicy(),
               log_every: int = 10, seed: int = 0, verbose: bool = True,
               mesh=None, accum_steps: int = 1,
               chaos_nar_steps=None, async_ckpt: bool = False):
    """Runs (or resumes) training; returns the metrics history.

    mesh: a ("data","model") jax Mesh routes every step through the
    shard_map training path (params/opt-state/batch device_put to their
    PartitionSpecs up front so the donated jit re-uses the buffers in
    place); None keeps the single-device donated jit.

    chaos_nar_steps: fault injection — a collection of step indices whose
    gradient tree is NaN'd on device before the optimizer, exercising the
    non-finite (NaR) guard in adamw.apply_updates: the update is skipped,
    opt_state["nar_skips"] increments (checkpointed, so resume keeps the
    count), and the log line reports it.  None builds the production step
    with no poison plumbing at all.

    async_ckpt: checkpoint through AsyncCheckpointStore — the loop stalls
    only for the device->host snapshot; write+fsync+publish happen on a
    background thread behind a bounded queue, with a wait() barrier before
    returning so no enqueued checkpoint is lost on normal exit.
    """
    params = init_params(jax.random.PRNGKey(seed), cfg)
    opt_state = adamw.init_state(params, opt_cfg)
    start_step = 0

    if ckpt_dir:
        step, restored = store.restore_latest(
            ckpt_dir, {"params": params, "opt": opt_state})
        if step is None and "nar_skips" in opt_state:
            # pre-nar_skips checkpoint: its opt tree has one leaf fewer;
            # retry against the legacy layout and backfill the counter
            legacy = {k: v for k, v in opt_state.items()
                      if k != "nar_skips"}
            step, restored = store.restore_latest(
                ckpt_dir, {"params": params, "opt": legacy})
            if step is not None:
                restored["opt"]["nar_skips"] = jnp.zeros((), jnp.int32)
        if step is not None:
            params, opt_state = restored["params"], restored["opt"]
            start_step = step
            if verbose:
                print(f"[trainer] resumed from step {step}")

    chaos_set = (None if chaos_nar_steps is None
                 else frozenset(int(s) for s in chaos_nar_steps))
    step_fn = make_train_step(cfg, opt_cfg, mesh, accum_steps=accum_steps,
                              chaos_nar=chaos_set is not None)
    if mesh is not None:
        from repro.distributed import sharding
        pspecs = sharding.train_param_pspecs(params, mesh)
        params = jax.device_put(params, sharding.to_shardings(pspecs, mesh))
        opt_state = jax.device_put(
            opt_state, sharding.to_shardings(
                sharding.opt_state_pspecs(opt_state, pspecs, mesh), mesh))

    astore = None
    if ckpt_dir and async_ckpt:
        from repro.checkpoint.async_store import AsyncCheckpointStore
        astore = AsyncCheckpointStore(ckpt_dir, keep=policy.keep)

    def _save(at_step):
        tree = {"params": params, "opt": opt_state}
        if astore is not None:
            astore.save(at_step, tree)
        else:
            store.save(ckpt_dir, at_step, tree, keep=policy.keep)

    history = []
    t0 = time.time()
    t_log, s_log = t0, start_step
    ctr_log = _fallback_counters()
    for step in range(start_step, num_steps):
        batch = global_batch_at(step, data_cfg)
        with StepWatchdog(policy.step_timeout_s):
            if chaos_set is None:
                params, opt_state, metrics = step_fn(params, opt_state,
                                                     batch)
            else:
                params, opt_state, metrics = step_fn(
                    params, opt_state, batch,
                    jnp.asarray(step in chaos_set))
        if step % log_every == 0 or step == num_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            now = time.time()
            m["wall_s"] = now - t0
            # block on the metrics (already floats above) so steps/sec
            # measures completed device work, not dispatch latency
            m["steps_per_s"] = (step + 1 - s_log) / max(now - t_log, 1e-9)
            ctr = _fallback_counters()
            m["fallbacks"] = _counter_delta(ctr_log, ctr)
            t_log, s_log, ctr_log = now, step + 1, ctr
            history.append(m)
            if verbose:
                fb = f" fallbacks {m['fallbacks']}" if m["fallbacks"] else ""
                nar = (f" nar_skips {int(m['nar_skips'])}"
                       if m.get("nar_skips") else "")
                print(f"[trainer] step {step:5d} loss {m['loss']:.4f} "
                      f"gnorm {m['grad_norm']:.3f} lr {m['lr']:.2e} "
                      f"{m['steps_per_s']:.2f} steps/s{nar}{fb}")
        if ckpt_dir and (step + 1) % policy.ckpt_every == 0:
            _save(step + 1)
    if ckpt_dir:
        _save(num_steps)
    if astore is not None:
        try:
            astore.wait()
        finally:
            astore.close()
    return params, opt_state, history
