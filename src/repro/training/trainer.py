"""Training loop with checkpoint/restart fault tolerance.

Single-host CPU runs drive the examples and tests; launch/train.py wraps the
same loop in a mesh with sharded params (the pjit path the dry-run proves).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.checkpoint import store
from repro.data.pipeline import DataConfig, global_batch_at
from repro.distributed.fault_tolerance import RestartPolicy, StepWatchdog
from repro.models.transformer import ModelConfig, init_params
from repro.optim import adamw
from repro.training.train_step import train_step


def train_loop(cfg: ModelConfig, opt_cfg: adamw.OptConfig,
               data_cfg: DataConfig, num_steps: int,
               ckpt_dir: str | None = None,
               policy: RestartPolicy = RestartPolicy(),
               log_every: int = 10, seed: int = 0, verbose: bool = True):
    """Runs (or resumes) training; returns the metrics history."""
    params = init_params(jax.random.PRNGKey(seed), cfg)
    opt_state = adamw.init_state(params, opt_cfg)
    start_step = 0

    if ckpt_dir:
        step, restored = store.restore_latest(
            ckpt_dir, {"params": params, "opt": opt_state})
        if step is not None:
            params, opt_state = restored["params"], restored["opt"]
            start_step = step
            if verbose:
                print(f"[trainer] resumed from step {step}")

    step_fn = jax.jit(
        lambda p, o, b: train_step(p, o, b, cfg, opt_cfg))

    history = []
    t0 = time.time()
    for step in range(start_step, num_steps):
        batch = global_batch_at(step, data_cfg)
        with StepWatchdog(policy.step_timeout_s):
            params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % log_every == 0 or step == num_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["wall_s"] = time.time() - t0
            history.append(m)
            if verbose:
                print(f"[trainer] step {step:5d} loss {m['loss']:.4f} "
                      f"gnorm {m['grad_norm']:.3f} lr {m['lr']:.2e}")
        if ckpt_dir and (step + 1) % policy.ckpt_every == 0:
            store.save(ckpt_dir, step + 1,
                       {"params": params, "opt": opt_state},
                       keep=policy.keep)
    if ckpt_dir:
        store.save(ckpt_dir, num_steps, {"params": params, "opt": opt_state},
                   keep=policy.keep)
    return params, opt_state, history
