import os
import sys

# tests run single-device (the 512-device flag is dryrun.py-only by design)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
