"""Chaos drains: the serving engine's graceful-degradation contract under
seeded fault injection (serving/faults.py).

The contract (engine docstring, ISSUE 9):
  * an oversubscribed drain with injected device failures, NaR-poisoned
    activations, bit-flipped posit KV pages, stragglers and expiring
    deadlines never raises — every submission resolves to exactly one of
    completed | rejected | expired | failed_nar | failed_fault;
  * faults are contained: every surviving request's greedy tokens are
    bit-identical to a fault-free run, and a failed request's partial
    tokens are a clean prefix of its fault-free tokens;
  * stats() outcome counters exactly account for all submissions.
"""
from __future__ import annotations

import numpy as np
import jax
import pytest

from repro.core.types import P8_2, P16_2
from repro.models.transformer import ModelConfig
from repro.quant.policy import PositPolicy
from repro.serving.engine import OUTCOMES, PagedServingEngine
from repro.serving.faults import ChaosConfig, ChaosInjector

MAX_DRAIN_STEPS = 2000


def _cfg(pcfg):
    return ModelConfig(name="tst", n_layers=2, d_model=32, n_heads=4,
                       n_kv=2, d_ff=64, vocab=50,
                       policy=PositPolicy(kv_cache=pcfg))


def _params(cfg):
    from repro.models.transformer import init_params
    return init_params(jax.random.PRNGKey(0), cfg)


def _requests(cfg, n, max_new=6, seed=7):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, cfg.vocab, int(rng.integers(3, 14))), max_new)
            for _ in range(n)]


def _drain(eng):
    """Step until quiescent; the step budget turns a hang into a failure."""
    for _ in range(MAX_DRAIN_STEPS):
        if not (eng.waiting or eng.active):
            return
        eng.step()
    raise AssertionError("drain did not terminate")


def _reference(cfg, params, reqs):
    """Fault-free tokens per rid from a generously provisioned engine."""
    eng = PagedServingEngine(params, cfg, max_seqs=4, page_size=4,
                             table_width=8, prefill_chunk=8)
    return eng.run(list(reqs))


def _check_accounting(eng, n_submitted):
    s = eng.stats()
    assert s["submitted"] == n_submitted
    assert sum(s[k] for k in OUTCOMES) == n_submitted, s
    assert set(eng.outcomes) == set(range(n_submitted))
    for rid, o in eng.outcomes.items():
        assert o.status in OUTCOMES, o
    return s


@pytest.mark.parametrize("pcfg", [None, P16_2, P8_2],
                         ids=["float", "p16", "p8"])
def test_oversubscribed_chaos_drain_contract(pcfg):
    """2x-oversubscribed drain under every fault kind at once: never
    raises, counters account for everything, survivors bit-identical."""
    cfg = _cfg(pcfg)
    params = _params(cfg)
    reqs = _requests(cfg, 8)
    ref = _reference(cfg, params, reqs)
    assert len(ref) == len(reqs)          # the oracle run completes fully

    chaos = ChaosConfig(seed=5, p_step_fault=0.05, p_nar_poison=0.08,
                        p_page_poison=0.10, p_straggle=0.2,
                        straggle_s=0.0)
    eng = PagedServingEngine(params, cfg, max_seqs=2, page_size=4,
                             table_width=8, prefill_chunk=8,
                             chaos=chaos)
    # oversubscribed: 8 requests over 2 slots; a couple with a TTL tight
    # enough to expire under stragglers/retries
    for j, (prompt, max_new) in enumerate(reqs):
        eng.submit(prompt, max_new,
                   ttl_steps=12 if j in (5, 6) else None)
    _drain(eng)
    s = _check_accounting(eng, len(reqs))

    # the schedule must have actually injected something, else the test
    # silently degrades to the fault-free case
    injected = (s["injected_step_faults"] + s["injected_nar_poisons"]
                + s["injected_page_poisons"])
    assert injected > 0, s

    for rid, o in eng.outcomes.items():
        if o.status == "completed":
            np.testing.assert_array_equal(o.tokens, ref[rid])
        else:
            # containment: whatever was generated before the fault is a
            # clean prefix of the fault-free greedy stream
            assert len(o.tokens) < len(ref[rid]) or o.status != "completed"
            np.testing.assert_array_equal(
                np.asarray(o.tokens), ref[rid][:len(o.tokens)])


def test_nar_poison_fails_only_poisoned_request():
    """One injected NaR-poisoned activation: exactly one failed_nar, every
    other request completes bit-identically."""
    cfg = _cfg(None)
    params = _params(cfg)
    reqs = _requests(cfg, 4)
    ref = _reference(cfg, params, reqs)

    chaos = ChaosConfig(seed=1, p_nar_poison=1.0, max_injections=1)
    eng = PagedServingEngine(params, cfg, max_seqs=2, page_size=4,
                             table_width=8, prefill_chunk=8, chaos=chaos)
    eng.run(list(reqs))
    s = _check_accounting(eng, len(reqs))
    assert s["failed_nar"] == 1
    assert s["completed"] == len(reqs) - 1
    assert s["injected_nar_poisons"] == 1
    for rid, o in eng.outcomes.items():
        if o.status == "completed":
            np.testing.assert_array_equal(o.tokens, ref[rid])
        else:
            assert "NaR" in o.detail
            np.testing.assert_array_equal(
                np.asarray(o.tokens), ref[rid][:len(o.tokens)])


@pytest.mark.parametrize("pcfg", [None, P16_2, P8_2],
                         ids=["float", "p16", "p8"])
def test_page_poison_contained_to_victim(pcfg):
    """One bit-flipped (NaR'd) private KV page: the owning request trips
    the on-device NaR detector; nobody else is touched, and the freed
    poisoned page can be recycled without poisoning its next owner (the
    attention masks are where-selects, not additive biases)."""
    cfg = _cfg(pcfg)
    params = _params(cfg)
    reqs = _requests(cfg, 6, max_new=8)
    ref = _reference(cfg, params, reqs)

    chaos = ChaosConfig(seed=3, p_page_poison=1.0, max_injections=1)
    # prefix cache off: cached pages are shared by design and the injector
    # only targets private pages, so a cache-on run may find no victim
    eng = PagedServingEngine(params, cfg, max_seqs=2, page_size=4,
                             table_width=8, prefill_chunk=8, chaos=chaos,
                             prefix_cache=False)
    eng.run(list(reqs))
    s = _check_accounting(eng, len(reqs))
    assert s["injected_page_poisons"] == 1
    assert s["failed_nar"] == 1, s
    assert s["completed"] == len(reqs) - 1
    for rid, o in eng.outcomes.items():
        if o.status == "completed":
            np.testing.assert_array_equal(o.tokens, ref[rid])


def test_step_fault_retries_then_quarantines():
    """p_step_fault=1 with a budget of 2: the first step fails, the retry
    fails, participants fail loudly and their slots quarantine; with the
    budget spent the drain then completes the rest on clean steps --
    unless every slot is quarantined, in which case the queue rejects
    instead of hanging.  Either way: structured outcomes, no exception."""
    cfg = _cfg(None)
    params = _params(cfg)
    reqs = _requests(cfg, 6)
    chaos = ChaosConfig(seed=2, p_step_fault=1.0, max_injections=2)
    eng = PagedServingEngine(params, cfg, max_seqs=2, page_size=4,
                             table_width=8, prefill_chunk=8, chaos=chaos)
    eng.run(list(reqs))
    s = _check_accounting(eng, len(reqs))
    assert s["injected_step_faults"] == 2
    assert s["step_retries"] == 1
    assert s["failed_fault"] == 2          # both step-0 participants
    assert s["slots_quarantined"] == 2
    # every slot was quarantined (max_seqs=2): the rest must have been
    # rejected at admission rather than left hanging
    assert s["rejected"] == len(reqs) - 2
    for o in eng.outcomes.values():
        if o.status == "failed_fault":
            assert "quarantined" in o.detail


def test_bounded_queue_rejects_with_retry_after():
    """max_waiting bounds admission: overflow submissions resolve as
    rejected (with a retry-after hint) instead of queueing forever."""
    cfg = _cfg(None)
    params = _params(cfg)
    reqs = _requests(cfg, 6)
    eng = PagedServingEngine(params, cfg, max_seqs=2, page_size=4,
                             table_width=8, prefill_chunk=8, max_waiting=2)
    for prompt, max_new in reqs:
        eng.submit(prompt, max_new)
    assert len(eng.waiting) == 2
    _drain(eng)
    s = _check_accounting(eng, len(reqs))
    assert s["rejected"] == 4
    assert s["completed"] == 2
    for o in eng.outcomes.values():
        if o.status == "rejected":
            assert o.retry_after_steps is not None
            assert o.retry_after_steps >= 1
            assert len(o.tokens) == 0


def test_ttl_expiry_returns_resources():
    """A TTL tighter than the work: requests expire with partial tokens
    and every page goes back to the pool (no leak), after which the
    engine still serves new work."""
    cfg = _cfg(None)
    params = _params(cfg)
    eng = PagedServingEngine(params, cfg, max_seqs=2, page_size=4,
                             table_width=8, prefill_chunk=8)
    free0 = len(eng.free_pages)
    # max_new chosen to fit the per-sequence capacity (else the submit
    # resolves `rejected` before the TTL can ever bind)
    prompts = _requests(cfg, 2, max_new=18)
    for prompt, max_new in prompts:
        eng.submit(prompt, max_new, ttl_steps=6)
    _drain(eng)
    s = _check_accounting(eng, 2)
    assert s["expired"] == 2
    for o in eng.outcomes.values():
        assert len(o.tokens) < 18
    # pages returned (cached prefix pages stay resident by design)
    assert len(eng.free_pages) + eng.cached_pages == free0
    # the engine is still healthy: fresh work completes
    rid = eng.submit(prompts[0][0], 3)
    _drain(eng)
    assert eng.outcomes[rid].status == "completed"


def test_over_capacity_submit_rejects_structurally():
    """prompt+max_new beyond the per-sequence page capacity used to raise
    ValueError; it now resolves as a structured rejection (malformed
    input -- empty prompt, bad rid -- still raises)."""
    cfg = _cfg(None)
    params = _params(cfg)
    eng = PagedServingEngine(params, cfg, max_seqs=2, page_size=4,
                             table_width=4)
    rid = eng.submit(np.arange(10) % cfg.vocab, 1000)
    assert eng.outcomes[rid].status == "rejected"
    assert "capacity" in eng.outcomes[rid].detail
    with pytest.raises(ValueError):
        eng.submit(np.zeros((0,), np.int32), 4)
    with pytest.raises(ValueError):
        eng.submit(np.arange(4) % cfg.vocab, 0)


def test_chaos_schedule_deterministic():
    """Two injectors over the same config answer identically regardless of
    call order/count; a different seed answers differently somewhere."""
    cfg = ChaosConfig(seed=9, p_step_fault=0.3, p_nar_poison=0.3,
                      p_page_poison=0.3, p_straggle=0.3)
    a, b = ChaosInjector(cfg), ChaosInjector(cfg)
    # b asks extra questions first: per-decision keying must not care
    for t in range(50):
        b.page_poison(t)
    sched_a = [(a.step_fault(t, 0), sorted(a.poison_slots(t, range(4))))
               for t in range(40)]
    sched_b = [(b.step_fault(t, 0), sorted(b.poison_slots(t, range(4))))
               for t in range(40)]
    assert sched_a == sched_b
    c = ChaosInjector(ChaosConfig(seed=10, p_step_fault=0.3,
                                  p_nar_poison=0.3, p_page_poison=0.3,
                                  p_straggle=0.3))
    sched_c = [(c.step_fault(t, 0), sorted(c.poison_slots(t, range(4))))
               for t in range(40)]
    assert sched_c != sched_a
