"""Regression: poly-divide kernel vs ref bit-exactness (ROADMAP latent bug).

The f32 evaluation of Algorithm 1 + Newton-Raphson inside approx_quotient
was FP-contraction sensitive: XLA fused `2 - x*y` into an FMA in some
compilation contexts (jit/Pallas) but not others (eager), flipping the
quotient estimate by +/-1 on rounding-boundary operands, so
posit_elementwise.divide(mode="poly") disagreed with divide_ref on ~1e-4
of posit16es1 operand pairs.  The fix evaluates the pipeline in int32
fixed point (core.recip.recip_poly_fx / nr_round_fx): integer ops leave
the compiler no contraction freedom.

The pinned operand pairs below were enumerated by the *old* implementation
via experiments/characterize_divide.py (389/4194304 random pairs and
3213/16777216 exhaustive te=0 mantissa pairs diverged); they are frozen
here as 16-bit patterns, independent of any rng stream.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.types import P16_1
from repro.kernels import posit_elementwise as KE
from repro.kernels import ref as R

# (a, b) posit16es1 bit patterns on which the old f32 poly path produced
# kernel != ref (from experiments/divide_characterization.json).
DIVERGING_PAIRS = [
    (20160, 22786), (27802, 50443), (55268, 55871), (61078, 7244),
    (47904, 49907), (11459, 9696), (16708, 51996), (1020, 38806),
    (17296, 42019), (12369, 12890), (14617, 15308), (4899, 4993),
    (58374, 58230), (37817, 37185), (61675, 56834), (56193, 32982),
    (57123, 16926), (54931, 7474), (15612, 23742), (9649, 54402),
    (14443, 13207), (18850, 52390), (39362, 27059), (16837, 47888),
    (20933, 43862), (59012, 9002), (16621, 44998), (23605, 43141),
    (58582, 50352), (52711, 32649), (11740, 57163), (26976, 41943),
    (41781, 27363), (56639, 49963), (24715, 26859), (16726, 43535),
    (14794, 11134), (14545, 53011), (47228, 40161), (16222, 1099),
    (14836, 12728), (10674, 56174), (54928, 37635), (46062, 16636),
    (48902, 40709), (13769, 41899), (38734, 11591), (42653, 40597),
    # exhaustively-enumerated te=0 mantissa-space pairs
    (16386, 17892), (16386, 19462), (16387, 17252), (16390, 17455),
    (16400, 18544), (16401, 19345), (16402, 16850), (16402, 19847),
    (16417, 16601), (16417, 17355), (16419, 18687), (16421, 18127),
    (16432, 20006), (16436, 16926), (16436, 19949), (16437, 16936),
]


def _pairs():
    a = np.asarray([p[0] for p in DIVERGING_PAIRS], np.uint16)
    b = np.asarray([p[1] for p in DIVERGING_PAIRS], np.uint16)
    return jnp.asarray(a.astype(np.int16)), jnp.asarray(b.astype(np.int16))


@pytest.mark.parametrize("mode", ["poly", "poly_corrected", "pacogen",
                                  "exact"])
def test_divide_kernel_matches_ref_on_characterized_pairs(mode):
    a, b = _pairs()
    got = KE.divide(a, b, cfg=P16_1, mode=mode, block_rows=8, interpret=True)
    want = R.divide_ref(a, b, cfg=P16_1, mode=mode)
    assert (got == want).all(), np.nonzero(np.asarray(got != want))


def test_divide_ref_is_jit_invariant_on_characterized_pairs():
    """The root cause was context-dependent compilation; the ref itself must
    now produce identical bits eagerly and under jit."""
    a, b = _pairs()
    eager = R.divide_ref(a, b, cfg=P16_1, mode="poly")
    jitted = jax.jit(lambda x, y: R.divide_ref(x, y, cfg=P16_1,
                                               mode="poly"))(a, b)
    assert (eager == jitted).all()


def test_divide_kernel_matches_ref_random_sweep_local_rng():
    """Fresh random sweep with a *local* rng (operand sets independent of
    suite composition, per the ROADMAP note on the shared session stream)."""
    lrng = np.random.default_rng(20260729)
    a = jnp.asarray(lrng.integers(0, 1 << 16, size=(1 << 15,))
                    .astype(np.uint16).astype(np.int16))
    b = jnp.asarray(lrng.integers(0, 1 << 16, size=(1 << 15,))
                    .astype(np.uint16).astype(np.int16))
    for mode in ("poly", "pacogen"):
        got = KE.divide(a, b, cfg=P16_1, mode=mode, block_rows=8,
                        interpret=True)
        want = R.divide_ref(a, b, cfg=P16_1, mode=mode)
        assert (got == want).all(), mode
