"""Elastic-exactness invariants + async checkpointing + the fixed
StepWatchdog/GC satellites.

The load-bearing property: a training run is bitwise invariant to the
worker count — derived balanced batch slices (data.pipeline), per-row
gradients reduced in canonical global row order (training.elastic) — so
the supervisor's shrink-on-failure resume reproduces an uninterrupted
run exactly.  These tests pin each layer in-process (subprocess
end-to-end lives in tests/test_supervisor.py).
"""
from __future__ import annotations

import os
import threading
import time

import jax
import numpy as np
import pytest

from repro.checkpoint import store
from repro.checkpoint.async_store import AsyncCheckpointStore
from repro.data.pipeline import (DataConfig, global_batch_at, host_batch_at,
                                 host_row_bounds)
from repro.distributed.fault_tolerance import (Heartbeat, StepWatchdog,
                                               read_heartbeat)
from repro.models.transformer import ModelConfig, init_params
from repro.optim import adamw
from repro.optim.adamw import OptConfig
from repro.training import elastic

TINY = ModelConfig("tiny", n_layers=2, d_model=64, n_heads=4, n_kv=2,
                   d_ff=128, vocab=128)
DATA = DataConfig(vocab=128, seq_len=16, global_batch=5)   # 5: won't divide


# ---------------------------------------------------------------------------
# elastic batch determinism
# ---------------------------------------------------------------------------

def test_host_slices_tile_global_batch_any_world_size():
    for step in (0, 7):
        full = np.asarray(global_batch_at(step, DATA)["tokens"])
        for nh in (1, 2, 3, 4, 5):
            parts = [np.asarray(host_batch_at(step, DATA, h, nh)["tokens"])
                     for h in range(nh)]
            assert sum(p.shape[0] for p in parts) == DATA.global_batch
            np.testing.assert_array_equal(np.concatenate(parts), full)


def test_host_batch_sequence_survives_shrink_and_regrow():
    """A 4->3->4 worker run consumes the bit-identical global batch
    sequence: reassembling the per-host slices at each step matches the
    fixed global sequence regardless of the world-size schedule."""
    world = {0: 4, 1: 4, 2: 3, 3: 3, 4: 4}          # shrink at 2, regrow at 4
    for step, nh in world.items():
        full = np.asarray(global_batch_at(step, DATA)["tokens"])
        got = np.concatenate(
            [np.asarray(host_batch_at(step, DATA, h, nh)["tokens"])
             for h in range(nh)])
        np.testing.assert_array_equal(got, full)


def test_host_row_bounds_validation():
    with pytest.raises(ValueError):
        host_row_bounds(8, 0, 0)
    with pytest.raises(ValueError):
        host_row_bounds(8, 3, 3)


def test_param_pspecs_refit_on_shrunk_mesh_falls_back():
    """Re-fitting shardings on a shrunk mesh whose axis no longer divides
    the params must degrade to replication, not raise."""
    from repro.distributed import sharding

    class FakeMesh:
        shape = {"data": 3, "model": 3}             # 3 divides nothing below

    from jax.sharding import PartitionSpec
    params = init_params(jax.random.PRNGKey(0), TINY)
    specs = sharding.param_pspecs(params, FakeMesh(), multi_pod=False,
                                  strategy="fsdp")
    leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda s: isinstance(s, PartitionSpec))
    assert leaves, "no specs produced"
    for spec in leaves:
        assert isinstance(spec, PartitionSpec)
        for axis in spec:
            assert axis is None, f"non-dividing mesh kept sharding {spec}"


# ---------------------------------------------------------------------------
# regroup-invariant gradients: H workers == 1 worker, bit for bit
# ---------------------------------------------------------------------------

def _simulated_group_step(params, opt_state, row_grads, update, step, nh):
    """One update as an nh-worker group would compute it: per-host padded
    row grads, allgather simulated by concatenation in host order."""
    max_r = elastic.max_host_rows(DATA.global_batch, nh)
    per_host = []
    for h in range(nh):
        rows = host_batch_at(step, DATA, h, nh)["tokens"]
        pad = max_r - rows.shape[0]
        if pad:
            rows = np.concatenate(
                [rows, np.zeros((pad, rows.shape[1]), rows.dtype)])
        per_host.append(row_grads(params, rows))
    losses = np.concatenate([np.asarray(l) for l, _ in per_host])
    grads = jax.tree_util.tree_map(
        lambda *xs: np.concatenate([np.asarray(x) for x in xs]),
        *[g for _, g in per_host])
    valid = np.asarray(elastic.valid_row_mask(DATA.global_batch, nh))
    return update(params, opt_state, losses, grads, valid,
                  global_batch=DATA.global_batch)


@pytest.mark.parametrize("nh", [2, 3, 5])
def test_elastic_update_bitwise_invariant_to_world_size(nh):
    opt_cfg = OptConfig(lr_peak=3e-4, warmup_steps=2, total_steps=4)
    row_grads = elastic.make_row_grad_fn(TINY)
    update = elastic.make_ordered_update_fn(TINY, opt_cfg)

    p_ref = init_params(jax.random.PRNGKey(0), TINY)
    s_ref = adamw.init_state(p_ref, opt_cfg)
    p_h, s_h = p_ref, s_ref
    for step in range(2):
        p_ref, s_ref, _ = _simulated_group_step(p_ref, s_ref, row_grads,
                                                update, step, 1)
        p_h, s_h, _ = _simulated_group_step(p_h, s_h, row_grads,
                                            update, step, nh)
    for a, b in zip(jax.tree_util.tree_leaves(p_ref),
                    jax.tree_util.tree_leaves(p_h)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_loop_resume_matches_uninterrupted(tmp_path):
    """Kill-free sanity of the loop's own resume: 4 steps straight vs
    2 steps, 'restart' (fresh call restores from ckpt), 2 more."""
    opt_cfg = OptConfig(lr_peak=3e-4, warmup_steps=2, total_steps=4)
    from repro.distributed.fault_tolerance import RestartPolicy
    p_ref, _, _ = elastic.elastic_train_loop(TINY, opt_cfg, DATA, 4,
                                             verbose=False)
    ck = str(tmp_path / "ck")
    pol = RestartPolicy(ckpt_every=2)
    elastic.elastic_train_loop(TINY, opt_cfg, DATA, 2, ckpt_dir=ck,
                               policy=pol, verbose=False)
    p_res, _, _ = elastic.elastic_train_loop(TINY, opt_cfg, DATA, 4,
                                             ckpt_dir=ck, policy=pol,
                                             verbose=False)
    for a, b in zip(jax.tree_util.tree_leaves(p_ref),
                    jax.tree_util.tree_leaves(p_res)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# StepWatchdog hygiene (satellite 1)
# ---------------------------------------------------------------------------

def test_step_watchdog_restores_previous_handler_and_timer():
    import signal as sig
    fired = []
    prev = sig.signal(sig.SIGALRM, lambda *a: fired.append("outer"))
    try:
        sig.setitimer(sig.ITIMER_REAL, 5.0)         # enclosing timer
        with StepWatchdog(1.0):
            pass
        assert sig.getsignal(sig.SIGALRM) is not None
        handler = sig.getsignal(sig.SIGALRM)
        assert handler not in (sig.SIG_DFL, sig.SIG_IGN)
        assert "outer" in repr(handler) or callable(handler)
        left, _ = sig.setitimer(sig.ITIMER_REAL, 0.0)
        # the enclosing timer was re-armed with (about) its remaining time
        assert 0.0 < left <= 5.0
    finally:
        sig.setitimer(sig.ITIMER_REAL, 0.0)
        sig.signal(sig.SIGALRM, prev)


def test_step_watchdog_fires_and_then_restores():
    import signal as sig
    prev = sig.getsignal(sig.SIGALRM)
    with pytest.raises(TimeoutError):
        with StepWatchdog(0.05):
            time.sleep(2.0)
    assert sig.getsignal(sig.SIGALRM) == prev
    assert sig.setitimer(sig.ITIMER_REAL, 0.0)[0] == 0.0   # no timer leaked


def test_step_watchdog_rejects_non_main_thread():
    err = []

    def arm():
        try:
            with StepWatchdog(1.0):
                pass
        except RuntimeError as e:
            err.append(str(e))

    t = threading.Thread(target=arm)
    t.start()
    t.join()
    assert err and "main thread" in err[0]


def test_step_watchdog_disabled_is_free_anywhere():
    t = threading.Thread(target=lambda: StepWatchdog(None).__enter__())
    t.start()
    t.join()


# ---------------------------------------------------------------------------
# checkpoint GC by *valid* steps (satellite 2)
# ---------------------------------------------------------------------------

def _tree(v):
    return {"w": np.full((4,), v, np.float32)}


def test_gc_ignores_partial_dirs_and_keeps_newest_valid(tmp_path):
    ck = str(tmp_path)
    for s in (2, 4, 6):
        store.save(ck, s, _tree(s), keep=10)
    # newer junk above the newest valid step: a manifest-less partial dir
    # and an in-flight .tmp dir
    os.makedirs(os.path.join(ck, "step_00000008"))
    os.makedirs(os.path.join(ck, "step_00000010.tmp"))
    store._gc(ck, keep=2)
    kept = sorted(os.listdir(ck))
    assert "step_00000002" not in kept          # pruned: beyond keep=2
    assert "step_00000004" in kept and "step_00000006" in kept
    assert "step_00000008" in kept              # partial: untouched
    assert "step_00000010.tmp" in kept          # in-flight: untouched
    # newest *valid* step still restores
    step, tree = store.restore_latest(ck, _tree(0))
    assert step == 6 and tree["w"][0] == 6.0


def test_gc_partial_dirs_do_not_consume_keep_slots(tmp_path):
    ck = str(tmp_path)
    store.save(ck, 2, _tree(2), keep=10)
    for s in (4, 6, 8):
        os.makedirs(os.path.join(ck, f"step_{s:08d}"))   # manifest-less
    store._gc(ck, keep=1)
    # the single valid step survives even though 3 newer partials exist
    step, _ = store.restore_latest(ck, _tree(0))
    assert step == 2


def test_gc_keep_nonpositive_is_noop(tmp_path):
    ck = str(tmp_path)
    for s in (2, 4):
        store.save(ck, s, _tree(s), keep=0)
    assert {"step_00000002", "step_00000004"} <= set(os.listdir(ck))


# ---------------------------------------------------------------------------
# async checkpoint store (tentpole, checkpoint side)
# ---------------------------------------------------------------------------

def test_async_store_equivalent_to_sync(tmp_path):
    sync_dir, async_dir = str(tmp_path / "s"), str(tmp_path / "a")
    trees = {s: _tree(s) for s in (2, 4, 6)}
    for s, t in trees.items():
        store.save(sync_dir, s, t, keep=3)
    with AsyncCheckpointStore(async_dir, keep=3) as a:
        for s, t in trees.items():
            a.save(s, t)
        a.wait()
        assert a.published == [2, 4, 6]
    for d in (sync_dir, async_dir):
        step, tree = store.restore_latest(d, _tree(0))
        assert step == 6
        np.testing.assert_array_equal(tree["w"], trees[6]["w"])


def test_async_store_snapshot_is_a_copy(tmp_path):
    """Mutating the source tree after save() must not corrupt the write
    (donated device buffers are reused by the very next step)."""
    a = AsyncCheckpointStore(str(tmp_path), keep=3)
    src = _tree(1.0)
    a.save(2, src)
    src["w"][:] = -99.0          # "the next train step reused the buffer"
    a.wait()
    a.close()
    _, tree = store.restore_latest(str(tmp_path), _tree(0))
    np.testing.assert_array_equal(tree["w"], np.full((4,), 1.0, np.float32))


def test_async_store_bounded_queue_blocks_instead_of_dropping(tmp_path):
    orig_save = store.save

    def slow_save(*a, **kw):
        time.sleep(0.3)
        return orig_save(*a, **kw)

    store.save = slow_save
    try:
        a = AsyncCheckpointStore(str(tmp_path), keep=10, max_inflight=1)
        a.save(1, _tree(1))      # writer picks this up
        t0 = time.perf_counter()
        a.save(2, _tree(2))      # fills the queue slot
        a.save(3, _tree(3))      # must BLOCK until 2 drains
        blocked = time.perf_counter() - t0
        a.wait()
        a.close()
    finally:
        store.save = orig_save
    assert blocked > 0.15, f"save() returned in {blocked:.3f}s — dropped?"
    assert sorted(a.published) == [1, 2, 3]      # nothing dropped
    step, _ = store.restore_latest(str(tmp_path), _tree(0))
    assert step == 3


def test_async_store_surfaces_writer_errors(tmp_path):
    target = str(tmp_path / "not_a_dir")
    with open(target, "w") as f:
        f.write("occupied")     # makedirs inside store.save will explode
    a = AsyncCheckpointStore(target, keep=3)
    a.save(2, _tree(2))
    with pytest.raises(RuntimeError, match="async checkpoint write failed"):
        a.wait()
    a.close()                   # writer thread survived the error


def test_crash_mid_async_write_restores_last_valid(tmp_path):
    """A process that dies mid-async-write leaves a .tmp dir (the writer
    never got to the atomic rename); restore falls back to the last
    published step."""
    ck = str(tmp_path)
    with AsyncCheckpointStore(ck, keep=3) as a:
        a.save(2, _tree(2))
        a.wait()
    # simulate the torn in-flight write of step 4
    torn = os.path.join(ck, "step_00000004.tmp")
    os.makedirs(torn)
    with open(os.path.join(torn, "leaf_00000.npy"), "wb") as f:
        f.write(b"\x93NUMPY partial garbage")
    step, tree = store.restore_latest(ck, _tree(0))
    assert step == 2
    np.testing.assert_array_equal(tree["w"], _tree(2)["w"])


def test_trainer_async_ckpt_parity(tmp_path):
    """train_loop(async_ckpt=True) publishes the same checkpoints as the
    sync path (and the barrier makes the final one durable)."""
    from repro.distributed.fault_tolerance import RestartPolicy
    from repro.training.trainer import train_loop
    opt_cfg = OptConfig(lr_peak=3e-4, warmup_steps=2, total_steps=4)
    pol = RestartPolicy(ckpt_every=2)
    outs = {}
    for mode, use_async in (("sync", False), ("async", True)):
        ck = str(tmp_path / mode)
        p, o, _ = train_loop(TINY, opt_cfg, DATA, 4, ckpt_dir=ck,
                             policy=pol, verbose=False,
                             async_ckpt=use_async)
        step, tree = store.restore_latest(ck, {"params": p, "opt": o})
        assert step == 4
        outs[mode] = tree["params"]
    for a, b in zip(jax.tree_util.tree_leaves(outs["sync"]),
                    jax.tree_util.tree_leaves(outs["async"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# heartbeats
# ---------------------------------------------------------------------------

def test_heartbeat_roundtrip_and_phases(tmp_path):
    path = str(tmp_path / "hb.json")
    assert read_heartbeat(path) is None
    hb = Heartbeat(path, host_id=3)
    hb.beat(7)
    rec = read_heartbeat(path)
    assert rec["host_id"] == 3 and rec["step"] == 7
    assert rec["phase"] == "step" and rec["t"] <= time.time()
    hb.beat(7, "sync")
    assert read_heartbeat(path)["phase"] == "sync"
    hb.done(8)
    assert read_heartbeat(path)["phase"] == "done"
    with pytest.raises(ValueError):
        hb.beat(9, "nonsense")
