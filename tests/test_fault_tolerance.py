"""Fault tolerance end-to-end: the tests distributed/fault_tolerance.py's
docstring promises.

  * a trainer subprocess SIGKILL'd mid-flight resumes from the newest
    valid checkpoint and reproduces the uninterrupted run bit-for-bit;
  * restore falls back past a deliberately corrupted/partial step dir;
  * the manifest catches corruption *anywhere* in a leaf, not just the
    first 4 KiB (regression for the old prefix-only hash);
  * the non-finite (NaR) gradient guard skips the update, counts the skip
    in the checkpointed opt_state, and resume preserves both.
"""
from __future__ import annotations

import glob
import os
import signal
import subprocess
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import store
from repro.data.pipeline import DataConfig, global_batch_at
from repro.distributed.fault_tolerance import RestartPolicy
from repro.models.transformer import ModelConfig, init_params
from repro.optim.adamw import OptConfig, apply_updates, init_state
from repro.training.train_step import make_train_step
from repro.training.trainer import train_loop

TINY = ModelConfig("tiny", n_layers=2, d_model=64, n_heads=4, n_kv=2,
                   d_ff=128, vocab=128)
OPT = OptConfig(lr_peak=1e-3, warmup_steps=5, total_steps=40)
DATA = DataConfig(vocab=128, seq_len=64, global_batch=8)


# --------------------------------------------------------------------------
# checkpoint store: full-content digests
# --------------------------------------------------------------------------
def test_corrupted_tail_detected(tmp_path):
    """Flip one byte deep in a leaf (far past the first 4 KiB): the old
    prefix hash validated this silently; the per-leaf sha256 must not."""
    td = str(tmp_path)
    tree = {"big": np.arange(65536, dtype=np.float32),   # 256 KiB leaf
            "small": np.ones((3,), np.float32)}
    store.save(td, 1, tree)
    leaf = sorted(glob.glob(os.path.join(td, "step_*", "leaf_*.npy")))[0]
    with open(leaf, "r+b") as f:
        f.seek(200_000)                       # way past header + 4 KiB
        b = f.read(1)
        f.seek(200_000)
        f.write(bytes([b[0] ^ 0xFF]))
    step, restored = store.restore_latest(td, tree)
    assert step is None and restored is None  # only (corrupt) step rejected


def test_corrupted_tail_falls_back_to_older_step(tmp_path):
    td = str(tmp_path)
    tree = {"big": np.arange(65536, dtype=np.float32)}
    store.save(td, 1, tree, keep=5)
    store.save(td, 2, {"big": np.arange(65536, dtype=np.float32) + 1},
               keep=5)
    leaf = os.path.join(td, "step_00000002", "leaf_00000.npy")
    with open(leaf, "r+b") as f:
        f.seek(100_000)
        f.write(b"\x55")
    step, restored = store.restore_latest(td, tree)
    assert step == 1
    np.testing.assert_array_equal(restored["big"], tree["big"])


def test_partial_step_dir_skipped(tmp_path):
    """A step dir missing its manifest (writer died between leaves and
    manifest would have stayed .tmp, but cover hand-mangled dirs too)."""
    td = str(tmp_path)
    tree = {"a": np.arange(10, dtype=np.float32)}
    store.save(td, 1, tree, keep=5)
    broken = os.path.join(td, "step_00000002")
    os.makedirs(broken)
    np.save(os.path.join(broken, "leaf_00000.npy"), np.zeros(10))
    step, restored = store.restore_latest(td, tree)
    assert step == 1
    np.testing.assert_array_equal(restored["a"], tree["a"])


# --------------------------------------------------------------------------
# subprocess kill mid-flight
# --------------------------------------------------------------------------
_CHILD = """
import sys
from repro.distributed.fault_tolerance import RestartPolicy
from repro.data.pipeline import DataConfig
from repro.models.transformer import ModelConfig
from repro.optim.adamw import OptConfig
from repro.training.trainer import train_loop

cfg = ModelConfig("tiny", n_layers=2, d_model=64, n_heads=4, n_kv=2,
                  d_ff=128, vocab=128)
opt = OptConfig(lr_peak=1e-3, warmup_steps=5, total_steps=40)
data = DataConfig(vocab=128, seq_len=64, global_batch=8)
train_loop(cfg, opt, data, 10, ckpt_dir=sys.argv[1],
           policy=RestartPolicy(ckpt_every=5), verbose=False)
"""


def test_subprocess_kill_resumes_bit_identical(tmp_path):
    """SIGKILL a trainer child once its first checkpoint lands; resuming
    to 12 steps must equal an uninterrupted 12-step run bit-for-bit."""
    td = str(tmp_path / "ckpt")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.Popen([sys.executable, "-c", _CHILD, td], env=env,
                            stdout=subprocess.PIPE, stderr=subprocess.PIPE)
    try:
        deadline = time.time() + 300
        while time.time() < deadline:
            # wait for a *published* step — the glob must not match an
            # in-flight step_*.tmp, or the SIGKILL below can land mid-write
            # and leave no durable checkpoint at all
            published = [d for d in glob.glob(os.path.join(td, "step_*"))
                         if not d.endswith(".tmp")]
            if published or proc.poll() is not None:
                break
            time.sleep(0.05)
        else:
            raise AssertionError("child produced no checkpoint in time")
        if proc.poll() is None:
            proc.send_signal(signal.SIGKILL)   # mid-flight, not graceful
            proc.wait(timeout=60)
    finally:
        if proc.poll() is None:
            proc.kill()
    valid = [d for d in glob.glob(os.path.join(td, "step_*"))
             if not d.endswith(".tmp")]
    assert valid, "no published checkpoint survived the kill"

    p_full, _, _ = train_loop(TINY, OPT, DATA, 12, verbose=False)
    p_res, _, _ = train_loop(TINY, OPT, DATA, 12, ckpt_dir=td,
                             verbose=False)
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# NaR / non-finite gradient guard
# --------------------------------------------------------------------------
def test_nar_guard_skips_update_and_counts():
    """A poisoned (all-NaN) gradient step is a bit-exact no-op on params,
    moments and the LR schedule; only nar_skips moves."""
    params = init_params(jax.random.PRNGKey(0), TINY)
    opt = init_state(params, OPT)
    batch = global_batch_at(0, DATA)
    step = make_train_step(TINY, OPT, donate=False, chaos_nar=True)

    p1, o1, m1 = step(params, opt, batch, jnp.asarray(True))
    assert int(o1["nar_skips"]) == 1
    assert int(o1["step"]) == 0                      # schedule untouched
    assert not np.isfinite(float(m1["grad_norm"]))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for k in ("m", "v"):
        for a, b in zip(jax.tree.leaves(opt[k]), jax.tree.leaves(o1[k])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # happy path through the guarded step == the production step, bitwise
    prod = make_train_step(TINY, OPT, donate=False)
    p2, o2, _ = step(params, opt, batch, jnp.asarray(False))
    p3, o3, _ = prod(params, opt, batch)
    for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(p3)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(o2["nar_skips"]) == 0 and int(o2["step"]) == 1
    for a, b in zip(jax.tree.leaves(o2["m"]), jax.tree.leaves(o3["m"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_nar_guard_real_nan_gradient():
    """The guard keys off the gradient norm, so a genuine NaN (not just
    the chaos hook) in any single leaf skips the update too."""
    params = init_params(jax.random.PRNGKey(0), TINY)
    opt = init_state(params, OPT)
    grads = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    leaves, tdef = jax.tree_util.tree_flatten(grads)
    leaves[3] = leaves[3].at[(0,) * leaves[3].ndim].set(jnp.inf)
    grads = jax.tree_util.tree_unflatten(tdef, leaves)
    p1, o1, m1 = apply_updates(params, grads, opt, OPT)
    assert int(o1["nar_skips"]) == 1
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_trainer_chaos_nar_loss_parity_resume(tmp_path):
    """train_loop with an injected NaR-grad step: the skip is counted, the
    run checkpoints, and a resumed run reproduces params *and* the skip
    counter bit-identically (acceptance: loss-parity resume intact)."""
    td = str(tmp_path)
    p1, o1, hist = train_loop(TINY, OPT, DATA, 10, ckpt_dir=td,
                              policy=RestartPolicy(ckpt_every=5),
                              verbose=False, log_every=1,
                              chaos_nar_steps={3})
    assert int(o1["nar_skips"]) == 1
    by_step = {h["step"]: h for h in hist}
    assert by_step[3]["nar_skips"] == 1.0
    assert not np.isfinite(by_step[3]["grad_norm"])
    assert by_step[2]["nar_skips"] == 0.0

    # resume from the final checkpoint: nothing to redo, state preserved
    p2, o2, _ = train_loop(TINY, OPT, DATA, 10, ckpt_dir=td, verbose=False)
    assert int(o2["nar_skips"]) == 1
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # and the poisoned step really was a no-op: replaying steps 0..9
    # without chaos from scratch diverges (the skipped update is missing
    # from the chaos run), while replaying with the same chaos matches
    p3, _, _ = train_loop(TINY, OPT, DATA, 10, verbose=False,
                          chaos_nar_steps={3})
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p3)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    p4, _, _ = train_loop(TINY, OPT, DATA, 10, verbose=False)
    assert any(not np.array_equal(a, b) for a, b in
               zip(jax.tree.leaves(p1), jax.tree.leaves(p4)))


def test_old_format_checkpoint_without_nar_skips_resumes(tmp_path):
    """A pre-nar_skips opt_state restores and trains (the step backfills
    the counter) — forward compatibility for existing checkpoints."""
    td = str(tmp_path)
    params = init_params(jax.random.PRNGKey(0), TINY)
    opt = init_state(params, OPT)
    legacy = {k: v for k, v in opt.items() if k != "nar_skips"}
    legacy["step"] = jnp.asarray(4, jnp.int32)   # sentinel: proves resume
    store.save(td, 4, {"params": params, "opt": legacy})
    p, o, hist = train_loop(TINY, OPT, DATA, 6, ckpt_dir=td, verbose=False)
    assert hist[-1]["step"] == 5
    assert int(o["step"]) == 6       # resumed at 4, two clean updates
    assert int(o["nar_skips"]) == 0  # backfilled counter present
