"""Pallas kernel sweeps (interpret=True) vs the ref.py pure-jnp oracles.

Integer-domain kernels must be bit-exact; f32-accumulating kernels compare
with accumulation-order tolerance.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.convert import f32_to_posit
from repro.core.types import P8_0, P8_2, P16_1, P16_2
from repro.kernels import flash_attention as KF
from repro.kernels import posit_codec as KC
from repro.kernels import posit_elementwise as KE
from repro.kernels import posit_gemm as KG
from repro.kernels import ref as R

CFGS = [(P8_2, jnp.int8), (P16_2, jnp.int16), (P8_0, jnp.int8),
        (P16_1, jnp.int16)]


def _rand_posit(rng, shape, cfg, dt):
    x = rng.integers(-(1 << (cfg.n - 1)) + 1, 1 << (cfg.n - 1), shape)
    return jnp.asarray(x, dt)


@pytest.mark.slow
@pytest.mark.parametrize("cfg,dt", CFGS[:2], ids=lambda c: str(c))
@pytest.mark.parametrize("shape", [(32, 48, 56), (96, 160, 200), (8, 512, 128)])
def test_gemm_vs_ref(rng, cfg, dt, shape):
    m, k, n = shape
    a = _rand_posit(rng, (m, k), cfg, dt)
    b = _rand_posit(rng, (k, n), cfg, dt)
    got = KG.posit_gemm(a, b, cfg_a=cfg, cfg_b=cfg, bm=32, bn=64, bk=64,
                        interpret=True)
    want = R.posit_gemm_ref(a, b, cfg_a=cfg, cfg_b=cfg)
    # random posit<.,2> operands span ~useed^(n-2) of dynamic range, so the
    # k-tiled accumulation order shifts cancellation-heavy entries: compare
    # against the magnitude scale of the accumulator, not elementwise rtol
    scale = float(jnp.abs(want).max())
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-6 * scale)
    # posit-rounded output: the single final rounding must match exactly
    gotp = KG.posit_gemm(a, b, cfg_a=cfg, cfg_b=cfg, cfg_out=cfg,
                         out_posit=True, bm=32, bn=64, bk=64, interpret=True)
    wantp = R.posit_gemm_ref(a, b, cfg_a=cfg, cfg_b=cfg, cfg_out=cfg,
                             out_posit=True)
    mism = int((gotp != wantp).sum())
    # f32 accumulation order may flip the last posit ulp on a tiny fraction
    assert mism <= gotp.size * 0.002, mism


@pytest.mark.parametrize("cfg,dt", CFGS[:2], ids=lambda c: str(c))
def test_pw_gemm_float_activation(rng, cfg, dt):
    x = jnp.asarray(rng.normal(size=(64, 96)), jnp.float32)
    w = f32_to_posit(jnp.asarray(rng.normal(size=(96, 128)), jnp.float32), cfg)
    got = KG.pw_gemm(x, w, cfg, bm=32, bn=64, bk=32, interpret=True)
    want = R.posit_gemm_ref(x, w, cfg_a=None, cfg_b=cfg)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-5)


@pytest.mark.slow
@pytest.mark.parametrize("cfg,dt", CFGS, ids=lambda c: str(c))
@pytest.mark.parametrize("op", ["add", "sub", "mul", "fma"])
def test_elementwise_bit_exact(rng, cfg, dt, op):
    shape = (37, 211)
    n_in = 3 if op == "fma" else 2
    args = tuple(_rand_posit(rng, shape, cfg, dt) for _ in range(n_in))
    got = KE.elementwise(op, *args, cfg=cfg, block_rows=8, interpret=True)
    want = R.elementwise_ref(op, *args, cfg=cfg)
    assert (got == want).all()


@pytest.mark.parametrize("op", ["add", "mul"])
def test_elementwise_smoke(op):
    """Fast default-suite check of the elementwise kernel path (the full
    op x format sweep is @slow).  Local rng: the session fixture's stream
    feeds order-sensitive sampled tests downstream (see ROADMAP latent
    divide divergence) and must not shift."""
    lrng = np.random.default_rng(99)
    cfg, dt = CFGS[0]
    args = tuple(_rand_posit(lrng, (8, 64), cfg, dt) for _ in range(2))
    got = KE.elementwise(op, *args, cfg=cfg, block_rows=8, interpret=True)
    want = R.elementwise_ref(op, *args, cfg=cfg)
    assert (got == want).all()


@pytest.mark.parametrize("cfg,dt", CFGS, ids=lambda c: str(c))
@pytest.mark.parametrize("mode", ["exact", "poly", "poly_corrected", "pacogen"])
def test_divide_kernel_bit_exact_vs_ref(cfg, dt, mode):
    # local deterministic rng: operands must not depend on suite composition
    # (the session stream shifts with -m selection; see ROADMAP's latent
    # poly/p16es1 kernel-vs-ref divergence)
    lrng = np.random.default_rng(7 * cfg.n + cfg.es)
    a = _rand_posit(lrng, (23, 129), cfg, dt)
    b = _rand_posit(lrng, (23, 129), cfg, dt)
    got = KE.divide(a, b, cfg=cfg, mode=mode, block_rows=8, interpret=True)
    want = R.divide_ref(a, b, cfg=cfg, mode=mode)
    assert (got == want).all()


@pytest.mark.parametrize("cfg,dt", CFGS, ids=lambda c: str(c))
def test_codec_roundtrip(rng, cfg, dt):
    v = jnp.asarray(rng.normal(size=(33, 77)), jnp.float32)
    p = KC.encode_block(v, cfg, block_rows=8, interpret=True)
    assert (p == R.encode_ref(v, cfg)).all()
    d = KC.decode_block(p, cfg, block_rows=8, interpret=True)
    assert (d == R.decode_ref(p, cfg)).all()
    # re-encode is idempotent
    assert (KC.encode_block(d, cfg, block_rows=8, interpret=True) == p).all()


@pytest.mark.parametrize("cfg_kv", [None, P16_2, P8_2],
                         ids=["f32kv", "p16kv", "p8kv"])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_vs_ref(rng, cfg_kv, causal):
    BH, SQ, SKV, D = 4, 48, 160, 64
    q = jnp.asarray(rng.normal(size=(BH, SQ, D)), jnp.float32)
    kf = jnp.asarray(rng.normal(size=(BH, SKV, D)), jnp.float32)
    vf = jnp.asarray(rng.normal(size=(BH, SKV, D)), jnp.float32)
    if cfg_kv is not None:
        kf = f32_to_posit(kf, cfg_kv)
        vf = f32_to_posit(vf, cfg_kv)
    got = KF.flash_attention(q, kf, vf, cfg_kv=cfg_kv, causal=causal,
                             bq=16, bk=64, interpret=True)
    want = R.flash_attention_ref(q, kf, vf, cfg_kv=cfg_kv, causal=causal)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


def test_flash_attention_decode_shape(rng):
    """Sq=1 decode against a long KV context (the serve_step hot path)."""
    BH, SKV, D = 8, 333, 128
    q = jnp.asarray(rng.normal(size=(BH, 1, D)), jnp.float32)
    k = f32_to_posit(jnp.asarray(rng.normal(size=(BH, SKV, D)), jnp.float32), P16_2)
    v = f32_to_posit(jnp.asarray(rng.normal(size=(BH, SKV, D)), jnp.float32), P16_2)
    got = KF.flash_attention(q, k, v, cfg_kv=P16_2, causal=True, bq=8, bk=128,
                             interpret=True)
    want = R.flash_attention_ref(q, k, v, cfg_kv=P16_2, causal=True)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


def test_kernel_dispatch_ref_path(rng):
    """kernels.ops must route to ref on CPU (use_pallas False by default)."""
    from repro.kernels import ops as kops
    assert not kops.use_pallas()
    x = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
    w = f32_to_posit(jnp.asarray(rng.normal(size=(8, 16)), jnp.float32), P16_2)
    out = kops.pw_matmul(x, w, P16_2)
    assert out.shape == (4, 16)
