"""Per-arch smoke tests (reduced same-family configs) + model invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.core.types import P8_2, P16_2
from repro.models.transformer import (ModelConfig, forward, init_caches,
                                      init_params)
from repro.quant.policy import PositPolicy


def _inputs(cfg, B=2, S=16):
    if cfg.input_mode == "embeddings":
        return dict(inputs_embeds=jnp.ones((B, S, cfg.d_model), jnp.float32))
    if cfg.input_mode == "tokens+image":
        return dict(tokens=jnp.zeros((B, S), jnp.int32),
                    inputs_embeds=jnp.ones((B, 4, cfg.d_model), jnp.float32))
    return dict(tokens=jnp.zeros((B, S), jnp.int32))


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_arch_smoke_forward(arch):
    cfg = configs.get_smoke(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    logits, aux, _ = jax.jit(lambda p, kw: forward(p, cfg, **kw),
                             static_argnames=())(params, _inputs(cfg))
    B = 2
    S_out = 16 + (4 if cfg.input_mode == "tokens+image" else 0)
    assert logits.shape == (B, S_out, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_arch_smoke_train_step(arch):
    from repro.optim.adamw import OptConfig, init_state
    from repro.training.train_step import train_step
    cfg = configs.get_smoke(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = init_state(params, OptConfig())
    B, S = 2, 16
    if cfg.encoder_only:
        batch = {"embeds": jnp.ones((B, S, cfg.d_model), jnp.float32),
                 "labels": jnp.zeros((B, S), jnp.int32)}
    else:
        batch = {"tokens": jnp.ones((B, S + 1), jnp.int32)}
        if cfg.input_mode == "tokens+image":
            batch["image_embeds"] = jnp.ones((B, 4, cfg.d_model), jnp.float32)
    params2, opt2, metrics = jax.jit(
        lambda p, o, b: train_step(p, o, b, cfg, OptConfig()))(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    moved = any(not np.array_equal(a, b) for a, b in
                zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


def test_serving_matches_full_forward():
    cfg = ModelConfig("eq", n_layers=3, d_model=48, n_heads=4, n_kv=2,
                      d_ff=96, vocab=64)
    params = init_params(jax.random.PRNGKey(2), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 24), 0, 64)
    full, _, _ = forward(params, cfg, tokens=toks)
    caches = init_caches(cfg, 2, 32)
    lg, _, caches = forward(params, cfg, tokens=toks[:, :16], caches=caches)
    errs = [float(jnp.abs(lg[:, -1] - full[:, 15]).max())]
    for i in range(16, 24):
        lg, _, caches = forward(params, cfg, tokens=toks[:, i:i + 1],
                                caches=caches)
        errs.append(float(jnp.abs(lg[:, 0] - full[:, i]).max()))
    assert max(errs) < 1e-4


def test_hybrid_serving_matches_full_forward():
    """recurrentgemma-style hybrid: rglru + local attention caches."""
    cfg = ModelConfig("rg-eq", n_layers=5, d_model=32, n_heads=2, n_kv=1,
                      d_ff=64, vocab=64, head_dim=16, act="geglu",
                      block_pattern=("rglru", "rglru", "attn_local"),
                      window=8)
    params = init_params(jax.random.PRNGKey(4), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 20), 0, 64)
    full, _, _ = forward(params, cfg, tokens=toks)
    caches = init_caches(cfg, 2, 24)
    lg, _, caches = forward(params, cfg, tokens=toks[:, :12], caches=caches)
    errs = [float(jnp.abs(lg[:, -1] - full[:, 11]).max())]
    for i in range(12, 20):
        lg, _, caches = forward(params, cfg, tokens=toks[:, i:i + 1],
                                caches=caches)
        errs.append(float(jnp.abs(lg[:, 0] - full[:, i]).max()))
    assert max(errs) < 1e-4


def test_rwkv_serving_matches_full_forward():
    cfg = ModelConfig("rwkv-eq", n_layers=2, d_model=32, n_heads=2, n_kv=2,
                      d_ff=64, vocab=64, block_pattern=("rwkv6",),
                      rwkv_head_dim=16)
    params = init_params(jax.random.PRNGKey(6), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(7), (1, 16), 0, 64)
    full, _, _ = forward(params, cfg, tokens=toks)
    caches = init_caches(cfg, 1, 16)
    lg, _, caches = forward(params, cfg, tokens=toks[:, :8], caches=caches)
    errs = [float(jnp.abs(lg[:, -1] - full[:, 7]).max())]
    for i in range(8, 16):
        lg, _, caches = forward(params, cfg, tokens=toks[:, i:i + 1],
                                caches=caches)
        errs.append(float(jnp.abs(lg[:, 0] - full[:, i]).max()))
    assert max(errs) < 1e-3


def test_posit_policy_close_to_f32():
    """posit16 weight QAT forward stays close to the f32 forward (the
    paper's 'p16 ~ binary32' claim at the LM scale of a smoke config)."""
    base = ModelConfig("pol", n_layers=2, d_model=64, n_heads=4, n_kv=2,
                       d_ff=128, vocab=128)
    params = init_params(jax.random.PRNGKey(8), base)
    toks = jnp.ones((2, 16), jnp.int32)
    ref, _, _ = forward(params, base, tokens=toks)
    import dataclasses
    for cfg_fmt, tol in ((P16_2, 0.02), (P8_2, 0.6)):
        qcfg = dataclasses.replace(base, policy=PositPolicy(weights=cfg_fmt))
        got, _, _ = forward(params, qcfg, tokens=toks)
        rel = float(jnp.abs(got - ref).max() / (jnp.abs(ref).max() + 1e-9))
        assert rel < tol, (str(cfg_fmt), rel)


def test_ste_gradient_passthrough():
    from repro.quant.policy import posit_cast_ste
    w = jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)), jnp.float32)
    g = jax.grad(lambda x: (posit_cast_ste(x, P16_2) ** 2).sum())(w)
    # STE: d/dw (q(w)^2) = 2*q(w) (gradient flows through cast unchanged)
    np.testing.assert_allclose(g, 2 * posit_cast_ste(w, P16_2), rtol=1e-6)
