"""Grouped posit MoE: sort-based routing + the grouped GEMM kernel vs the
GShard one-hot oracle.

Covers: kernel-vs-reference parity at ragged/empty group sizes (float, p8,
p16), the zero-rows-outside-groups contract, grouped moe_block vs oracle
parity on the olmoe and qwen3 smoke shapes, the forced-drop combine-weight
renormalization (pinned against an independent numpy oracle), custom_vjp
gradients (kernel forward, segment-sum reference backward), the
no-dense-decode guarantee across a full engine drain (DENSE_MOE_FALLBACKS),
serving's batch-independence (no capacity coupling between requests), and
expert-parallel TP serving on a forced multi-device host.

Everything kernel-shaped runs in interpret mode, so regressions fail in
tier-1 before the nightly TPU lane sees them.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.core.convert import f32_to_posit
from repro.core.types import P8_2, P16_2
from repro.kernels import ops as kops
from repro.kernels.grouped_gemm import posit_grouped_gemm
from repro.kernels.ref import grouped_matmul_ref
from repro.models import moe as MOE
from repro.models.transformer import ModelConfig, init_params
from repro.quant.policy import NONE, PositPolicy, quantize_tree

# multi-k-tile kernels split the contraction into per-tile partial sums, so
# parity with the single-dot reference is f32-accumulation-order loose
TOL = dict(rtol=2e-4, atol=2e-5)


def _pallas_interpret_env(monkeypatch):
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    monkeypatch.delenv("REPRO_FORCE_GATHER", raising=False)


# --------------------------------------------------------------------------
# the kernel itself: ragged groups, empty groups, rows outside every group
# --------------------------------------------------------------------------
@pytest.mark.parametrize("pcfg", [None, P16_2, P8_2],
                         ids=["float", "p16", "p8"])
@pytest.mark.parametrize("sizes,tail", [
    ([0, 7, 0, 13, 4], 0),          # empty groups between ragged ones
    ([5, 0, 0, 0, 19], 0),          # leading singleton + empty run
    ([10, 3, 9, 6, 2], 3),          # offsets[-1] < S: unowned tail rows
    ([0, 0, 0], 16),                # every group empty
], ids=["ragged", "sparse", "tail", "all-empty"])
def test_grouped_gemm_matches_ref(pcfg, sizes, tail):
    rng = np.random.default_rng(0)
    E = len(sizes)
    S = int(sum(sizes)) + tail
    K, N = 32, 48
    off = jnp.asarray(np.concatenate([[0], np.cumsum(sizes)]), jnp.int32)
    x = jnp.asarray(rng.normal(size=(S, K)), jnp.float32)
    wd = jnp.asarray(rng.normal(size=(E, K, N)), jnp.float32)
    w = f32_to_posit(wd, pcfg) if pcfg is not None else wd
    got = posit_grouped_gemm(x, w, off, cfg_b=pcfg, bm=8, bn=128, bk=16,
                             interpret=True)
    ref = grouped_matmul_ref(x, w, off, cfg_b=pcfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), **TOL)
    if tail:
        # rows past offsets[-1] belong to no group: exact zeros, not the
        # unwritten-buffer garbage of the untouched output tiles
        assert np.array_equal(np.asarray(got[-tail:]), np.zeros((tail, N)))


def test_grouped_gemm_tile_straddling_groups():
    """Group boundaries strictly inside an m-tile: the tile is visited once
    per group and the visits' row sets must compose, not clobber."""
    rng = np.random.default_rng(1)
    sizes = [3, 2, 3, 5, 3]                      # every boundary mid-tile
    E, S, K, N = len(sizes), sum(sizes), 16, 24
    off = jnp.asarray(np.concatenate([[0], np.cumsum(sizes)]), jnp.int32)
    x = jnp.asarray(rng.normal(size=(S, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(E, K, N)), jnp.float32)
    got = posit_grouped_gemm(x, w, off, cfg_b=None, bm=8, bn=128, bk=16,
                             interpret=True)
    ref = grouped_matmul_ref(x, w, off, cfg_b=None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), **TOL)


def test_grouped_matmul_dispatch_requires_cfg_for_raw_ints():
    x = jnp.zeros((4, 8), jnp.float32)
    w = jnp.zeros((2, 8, 8), jnp.int16)
    off = jnp.asarray([0, 2, 4], jnp.int32)
    with pytest.raises(TypeError, match="format"):
        kops.grouped_matmul(x, w, off)


# --------------------------------------------------------------------------
# moe_block: grouped path vs the GShard one-hot oracle
# --------------------------------------------------------------------------
def _smoke_moe_shapes():
    out = []
    for arch in ("olmoe-1b-7b", "qwen3-moe-235b-a22b"):
        c = configs.get_smoke(arch)
        out.append((arch, c.d_model, c.d_ff, c.moe.n_experts, c.moe.top_k,
                    c.act))
    return out


@pytest.mark.parametrize("pcfg", [None, P16_2, P8_2],
                         ids=["float", "p16", "p8"])
@pytest.mark.parametrize("arch,d,ff,E,k,act", _smoke_moe_shapes(),
                         ids=["olmoe", "qwen3"])
def test_moe_grouped_matches_oneshot_oracle(monkeypatch, arch, d, ff, E, k,
                                            act, pcfg):
    p = MOE.init_moe(jax.random.PRNGKey(0), d, ff, E, act)
    if pcfg is not None:
        p = quantize_tree(p, pcfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, d))
    kw = dict(n_experts=E, top_k=k, act=act, policy=NONE,
              capacity_factor=2.0, group_size=8)
    ref, aux_ref = MOE.moe_block(x, p, **kw)

    _pallas_interpret_env(monkeypatch)
    # capacity is set (training-shaped call), which keeps the one-hot path
    # even on the Pallas backend — pin the grouped dispatch explicitly
    monkeypatch.setattr(MOE, "FORCE_GROUPED", True)
    before = dict(MOE.DENSE_MOE_FALLBACKS)
    got, aux = MOE.moe_block(x, p, **kw)
    assert dict(MOE.DENSE_MOE_FALLBACKS) == before, \
        "grouped path materialized full expert tensors"
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), **TOL)
    np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-6)


def test_moe_grouped_no_capacity_matches_oracle(monkeypatch):
    """capacity_factor=None (the serving setting): no pair ever drops and
    both paths agree."""
    d, ff, E, k = 32, 48, 8, 2
    p = MOE.init_moe(jax.random.PRNGKey(2), d, ff, E, "swiglu")
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 16, d))
    kw = dict(n_experts=E, top_k=k, act="swiglu", policy=NONE,
              capacity_factor=None, group_size=16)
    ref, _ = MOE.moe_block(x, p, **kw)
    _pallas_interpret_env(monkeypatch)
    got, _ = MOE.moe_block(x, p, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), **TOL)


# --------------------------------------------------------------------------
# forced drops: combine weights renormalize over the *kept* experts
# --------------------------------------------------------------------------
def _numpy_moe_oracle(x, p, *, n_experts, top_k, act, cap, group_size):
    """Independent numpy reimplementation of routing + dispatch with the
    kept-only renormalization — the pinned semantics both paths must hit."""
    assert act == "gelu"
    B, S, d = x.shape
    T = B * S
    xt = np.asarray(x, np.float64).reshape(T, d)
    logits = xt @ np.asarray(p["router"], np.float64)
    z = np.exp(logits - logits.max(-1, keepdims=True))
    probs = z / z.sum(-1, keepdims=True)
    order = np.argsort(-probs, axis=-1, kind="stable")[:, :top_k]
    gate = np.take_along_axis(probs, order, axis=-1)
    # arrival-order capacity per dispatch group
    fill = {}
    keep = np.zeros_like(gate, bool)
    for t in range(T):
        g = t // group_size
        for j in range(top_k):
            e = int(order[t, j])
            c = fill.get((g, e), 0)
            if c < cap:
                keep[t, j] = True
            fill[(g, e)] = c + 1
    kept = gate * keep
    w = kept / np.maximum(kept.sum(-1, keepdims=True), 1e-9)
    wu = np.asarray(p["w_up"], np.float64)
    wd = np.asarray(p["w_down"], np.float64)

    def expert_out(rows, e):
        # borrow jax's own gelu for the activation (reimplementing erf
        # would test library plumbing, not the routing semantics)
        h = np.asarray(jax.nn.gelu(jnp.asarray(rows @ wu[e])), np.float64)
        return h @ wd[e]

    out = np.zeros((T, d))
    for t in range(T):
        for j in range(top_k):
            if keep[t, j]:
                out[t] += w[t, j] * expert_out(xt[t][None, :],
                                               int(order[t, j]))[0]
    return out.reshape(B, S, d), keep


@pytest.mark.parametrize("grouped", [False, True], ids=["oneshot", "grouped"])
def test_forced_drop_renormalizes_over_kept_experts(monkeypatch, grouped):
    """cap=1 forces overflow: a token whose sibling expert dropped must put
    its full weight on the kept expert (renormalized over kept), not keep
    the stale pre-drop mix."""
    E, k, d, ff, B, S = 4, 2, 16, 24, 1, 8
    p = MOE.init_moe(jax.random.PRNGKey(4), d, ff, E, "gelu")
    x = jax.random.normal(jax.random.PRNGKey(5), (B, S, d))
    # cap = max(1, int(cf * gs * k / E)) = 1 with cf=0.25, gs=8, k=2, E=4
    want, keep = _numpy_moe_oracle(x, p, n_experts=E, top_k=k, act="gelu",
                                   cap=1, group_size=8)
    n_kept = keep.sum(-1)
    assert (n_kept == 1).any(), "seed produced no partial drop; test vacuous"
    if grouped:
        _pallas_interpret_env(monkeypatch)
        monkeypatch.setattr(MOE, "FORCE_GROUPED", True)
    got, _ = MOE.moe_block(x, p, n_experts=E, top_k=k, act="gelu",
                           policy=NONE, capacity_factor=0.25, group_size=8)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-3, atol=1e-4)


# --------------------------------------------------------------------------
# custom_vjp: kernel forward, jnp segment-sum reference backward
# --------------------------------------------------------------------------
def test_grouped_matmul_grads_match_dense_reference(monkeypatch):
    rng = np.random.default_rng(6)
    sizes = [5, 0, 9, 2]
    E, S, K, N = len(sizes), sum(sizes), 16, 24
    off = jnp.asarray(np.concatenate([[0], np.cumsum(sizes)]), jnp.int32)
    x = jnp.asarray(rng.normal(size=(S, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(E, K, N)), jnp.float32)
    gid = np.repeat(np.arange(E), sizes)

    def dense_loss(x, w):
        out = jnp.einsum("sk,skn->sn", x, w[jnp.asarray(gid)])
        return (out * jnp.sin(out)).sum()

    def grouped_loss(x, w):
        out = kops.grouped_matmul(x, w, off)
        return (out * jnp.sin(out)).sum()

    ref = jax.grad(dense_loss, argnums=(0, 1))(x, w)
    _pallas_interpret_env(monkeypatch)
    got = jax.grad(grouped_loss, argnums=(0, 1))(x, w)
    for name, a, b in zip("xw", got, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-5, err_msg=f"d{name} diverged")


def test_moe_block_grads_grouped_matches_oracle(monkeypatch):
    """End-to-end moe_block gradients (routing + custom_vjp + scatter
    combine + STE posit weights) agree between the two dispatch paths."""
    E, k, d, ff = 8, 2, 32, 48
    p = MOE.init_moe(jax.random.PRNGKey(7), d, ff, E, "swiglu")
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 8, d))
    pol = PositPolicy(weights=P16_2)

    def loss(p, x):
        out, aux = MOE.moe_block(x, p, n_experts=E, top_k=k, act="swiglu",
                                 policy=pol, capacity_factor=2.0,
                                 group_size=8)
        return (out * out).sum() + aux

    ref = jax.grad(loss)(p, x)
    _pallas_interpret_env(monkeypatch)
    monkeypatch.setattr(MOE, "FORCE_GROUPED", True)
    got = jax.grad(loss)(p, x)
    for kk in ref:
        np.testing.assert_allclose(np.asarray(got[kk]), np.asarray(ref[kk]),
                                   rtol=5e-4, atol=5e-5, err_msg=kk)


# --------------------------------------------------------------------------
# the acceptance row: engine drain with zero full-expert decodes
# --------------------------------------------------------------------------
def _olmoe_cfg(name):
    base = configs.get_smoke("olmoe-1b-7b")
    return ModelConfig(**{**base.__dict__, "name": name,
                          "policy": PositPolicy(kv_cache=P16_2)})


def test_engine_drain_grouped_no_dense_decode_and_bit_parity(monkeypatch):
    """A full continuous-batching drain of olmoe-1b-7b-smoke with PTQ posit
    weights through the interpret-mode kernels: the grouped path never
    materializes the [E, d, ff] expert tensors (DENSE_MOE_FALLBACKS stays
    untouched — the ISSUE-5 acceptance row) and greedy tokens match the jnp
    oracle engine."""
    from repro.serving import engine as E
    from repro.serving import paged_kv

    cfg = _olmoe_cfg("olmoe-drain-ref")
    params = quantize_tree(init_params(jax.random.PRNGKey(0), cfg), P16_2)
    rng = np.random.default_rng(3)
    reqs = [(rng.integers(0, cfg.vocab, int(rng.integers(3, 12))
                          ).astype(np.int32), 5) for _ in range(4)]

    eng = E.PagedServingEngine(params, cfg, max_seqs=4, page_size=4,
                               table_width=8, prefill_chunk=8)
    ref = eng.run([(p.copy(), n) for p, n in reqs])
    # the oracle engine *did* decode the full expert tensors (it is the
    # counted dense path) — the counter moved
    assert MOE.DENSE_MOE_FALLBACKS["expert-decode"] > 0

    _pallas_interpret_env(monkeypatch)
    before = dict(MOE.DENSE_MOE_FALLBACKS)
    before_g = dict(paged_kv.GATHER_FALLBACKS)
    eng2 = E.PagedServingEngine(params, _olmoe_cfg("olmoe-drain-grouped"),
                                max_seqs=4, page_size=4, table_width=8,
                                prefill_chunk=8)
    res = eng2.run([(p.copy(), n) for p, n in reqs])
    assert dict(MOE.DENSE_MOE_FALLBACKS) == before, \
        "Pallas-path serving decoded the full expert tensors"
    assert dict(paged_kv.GATHER_FALLBACKS) == before_g
    for r in ref:
        assert np.array_equal(ref[r], res[r]), (r, ref[r], res[r])


def test_serving_moe_output_independent_of_batch_composition():
    """Serving disables capacity dropping, so a request's tokens cannot
    depend on which other requests share its decode batch."""
    from repro.serving import engine as E

    cfg = _olmoe_cfg("olmoe-batchindep")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab, 7).astype(np.int32)
    others = [(rng.integers(0, cfg.vocab, int(rng.integers(3, 10))
                            ).astype(np.int32), 5) for _ in range(3)]

    solo = E.PagedServingEngine(params, cfg, max_seqs=4, page_size=4,
                                table_width=8, prefill_chunk=8)
    res_solo = solo.run([(prompt.copy(), 5)])
    crowd = E.PagedServingEngine(params, cfg, max_seqs=4, page_size=4,
                                 table_width=8, prefill_chunk=8)
    res_crowd = crowd.run([(prompt.copy(), 5)] + others)
    assert np.array_equal(res_solo[0], res_crowd[0]), \
        "MoE serving output depends on batch composition"


# --------------------------------------------------------------------------
# expert-parallel TP serving (the lifted engine ValueError)
# --------------------------------------------------------------------------
def test_sharded_engine_validates_expert_divisibility():
    """The old blanket `TP over MoE blocks is not supported` is gone; the
    guard is now n_experts % ntp (each expert's d_ff stays whole on its
    shard, so d_ff is deliberately not checked for MoE archs)."""
    from repro.serving import engine as E

    class _FakeMesh:
        shape = {"data": 1, "model": 3}

    base = configs.get_smoke("olmoe-1b-7b")       # 8 experts
    # heads/kv divide the 3-wide model axis, experts (8) do not
    cfg = ModelConfig(**{**base.__dict__, "n_heads": 3, "n_kv": 3,
                         "d_model": 48})
    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    with pytest.raises(ValueError, match="n_experts"):
        E.PagedServingEngine(params, cfg, max_seqs=3, mesh=_FakeMesh())


_TP_SUBPROCESS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np, jax
    from repro import configs
    from repro.core.types import P16_2
    from repro.models.transformer import ModelConfig, init_params
    from repro.quant.policy import PositPolicy
    from repro.serving import engine as E
    from repro.launch.mesh import make_serving_mesh

    base = configs.get_smoke("olmoe-1b-7b")
    cfg = ModelConfig(**{**base.__dict__,
                         "policy": PositPolicy(kv_cache=P16_2)})
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    reqs = [(rng.integers(0, cfg.vocab, int(rng.integers(3, 14))
                          ).astype(np.int32), 6) for _ in range(8)]

    ref = E.PagedServingEngine(params, cfg, max_seqs=4, page_size=4,
                               table_width=8, prefill_chunk=8)
    res_ref = ref.run([(p.copy(), n) for p, n in reqs])

    # DP, DPxEP, pure EP: experts split over the model axis, one psum per
    # block — greedy tokens bit-identical to the single-device engine
    for shape in [(4, 1), (2, 2), (1, 4)]:
        mesh = make_serving_mesh(*shape)
        eng = E.PagedServingEngine(params, cfg, max_seqs=4, page_size=4,
                                   table_width=8, prefill_chunk=8,
                                   mesh=mesh)
        res = eng.run([(p.copy(), n) for p, n in reqs])
        assert sorted(res) == sorted(res_ref), shape
        for r in res_ref:
            assert np.array_equal(res[r], res_ref[r]), (shape, r)
    print("MOE-TP-OK")
""")


@pytest.mark.parametrize("path", ["oneshot", "grouped"])
def test_moe_tp_serving_bit_exact_vs_single_device(path):
    """Both EP dispatch branches: the jnp one-hot oracle (default CPU) and
    the sentinel-sort grouped path (interpret-mode kernels) must match the
    single-device engine bit for bit on every mesh layout."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env.pop("XLA_FLAGS", None)
    if path == "grouped":
        env["REPRO_USE_PALLAS"] = "1"
        env["REPRO_PALLAS_INTERPRET"] = "1"
    else:
        env.pop("REPRO_USE_PALLAS", None)
        env.pop("REPRO_PALLAS_INTERPRET", None)
    env.pop("REPRO_FORCE_GATHER", None)
    out = subprocess.run([sys.executable, "-c", _TP_SUBPROCESS], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "MOE-TP-OK" in out.stdout


# --------------------------------------------------------------------------
# router projection at storage width (no per-step router decode)
# --------------------------------------------------------------------------
def test_posit_router_routes_through_pw_matmul():
    from repro.core.decode import decode_to_f32

    rng = np.random.default_rng(10)
    d, E = 32, 8
    router = f32_to_posit(jnp.asarray(rng.normal(size=(d, E)), jnp.float32),
                          P16_2)
    from repro.core.array import PositArray
    xt = jnp.asarray(rng.normal(size=(2, 8, d)), jnp.float32)
    got = MOE._router_logits(xt, PositArray(router, P16_2), NONE)
    want = jnp.einsum("gtd,de->gte", xt, decode_to_f32(router, P16_2),
                      preferred_element_type=jnp.float32)
    assert got.shape == (2, 8, E)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
