"""Block-level correctness: MoE dispatch, chunked WKV, RG-LRU scan."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.models import griffin as GR
from repro.models import moe as MOE
from repro.models import rwkv6 as RW
from repro.quant.policy import NONE


def test_moe_matches_dense_when_topk_equals_experts():
    """top_k == E with ample capacity => exact softmax-weighted expert sum."""
    E, d, ff, B, S = 4, 16, 32, 2, 8
    key = jax.random.PRNGKey(0)
    p = MOE.init_moe(key, d, ff, E, "swiglu")
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d))
    out, aux = MOE.moe_block(x, p, n_experts=E, top_k=E, act="swiglu",
                             policy=NONE, capacity_factor=float(E),
                             group_size=B * S)
    # dense reference: every expert on every token, softmax-weighted
    logits = jnp.einsum("bsd,de->bse", x, p["router"])
    w = jax.nn.softmax(logits, -1)
    up = jnp.einsum("bsd,edf->bsef", x, p["w_up"])
    gate = jnp.einsum("bsd,edf->bsef", x, p["w_gate"])
    h = jax.nn.silu(gate.transpose(0, 1, 3, 2)).transpose(0, 1, 3, 2) * up
    h = jax.nn.silu(gate) * up
    ye = jnp.einsum("bsef,efd->bsed", h, p["w_down"])
    want = jnp.einsum("bse,bsed->bsd", w, ye)
    np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-5)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    """tiny capacity must drop tokens (outputs partially zeroed), not crash."""
    E, d, ff, B, S = 8, 16, 32, 2, 16
    p = MOE.init_moe(jax.random.PRNGKey(0), d, ff, E, "gelu")
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d))
    out, _ = MOE.moe_block(x, p, n_experts=E, top_k=2, act="gelu",
                           policy=NONE, capacity_factor=0.1, group_size=8)
    assert bool(jnp.isfinite(out).all())


def _wkv_sequential(r, k, v, logw, u):
    """Step-by-step WKV6 reference. r,k,v,logw [B,H,T,dh]; u [H,dh]."""
    B, H, T, dh = r.shape
    S = jnp.zeros((B, H, dh, dh))
    ys = []
    for t in range(T):
        rt, kt, vt = r[:, :, t], k[:, :, t], v[:, :, t]
        y = jnp.einsum("bhd,bhdv->bhv", rt, S)
        y += jnp.einsum("bhd,hd,bhd->bh", rt, u, kt)[..., None] * vt
        S = jnp.exp(logw[:, :, t])[..., None] * S + jnp.einsum(
            "bhd,bhv->bhdv", kt, vt)
        ys.append(y)
    return jnp.stack(ys, axis=2), S


def test_wkv_chunked_matches_sequential():
    B, H, T, dh = 2, 3, 40, 8
    rng = np.random.default_rng(0)
    r, k, v = (jnp.asarray(rng.normal(size=(B, H, T, dh)), jnp.float32)
               for _ in range(3))
    logw = -jnp.asarray(rng.uniform(0.01, 2.0, (B, H, T, dh)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, dh)), jnp.float32)

    want_y, want_S = _wkv_sequential(r, k, v, logw, u)

    S0 = jnp.zeros((B, H, dh, dh))
    C = 8
    ys = []
    S = S0
    for c in range(T // C):
        sl = slice(c * C, (c + 1) * C)
        S, y = RW._wkv_chunk(S, (r[:, :, sl], k[:, :, sl], v[:, :, sl],
                                 logw[:, :, sl], u), head_dim=dh)
        ys.append(y)
    got_y = jnp.concatenate(ys, axis=2)
    np.testing.assert_allclose(got_y, want_y, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(S, want_S, rtol=2e-4, atol=2e-5)


def test_rglru_scan_matches_sequential():
    B, T, d = 2, 24, 16
    rng = np.random.default_rng(1)
    p = GR.init_rglru_block(jax.random.PRNGKey(0), d)
    x = jnp.asarray(rng.normal(size=(B, T, d)), jnp.float32)
    got, h_last = GR.rglru(x, x, p)

    # sequential reference
    import jax.nn as jnn
    r = jnn.sigmoid(x @ p["w_rec_gate"]["w"])
    i = jnn.sigmoid(x @ p["w_input_gate"]["w"])
    log_a = GR.LRU_C * r * jnn.log_sigmoid(p["lam"])
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1 - jnp.exp(2 * log_a), 1e-12)) * (i * x)
    h = jnp.zeros((B, d))
    hs = []
    for t in range(T):
        h = a[:, t] * h + b[:, t]
        hs.append(h)
    want = jnp.stack(hs, axis=1)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(h_last, want[:, -1], rtol=2e-5, atol=2e-6)


def test_rglru_decode_step_matches_scan():
    B, T, d = 1, 10, 8
    p = GR.init_rglru_block(jax.random.PRNGKey(2), d)
    x = jax.random.normal(jax.random.PRNGKey(3), (B, T, d))
    full, _ = GR.rglru(x, x, p)
    h = jnp.zeros((B, d))
    outs = []
    for t in range(T):
        step, h = GR.rglru(x[:, t:t + 1], x[:, t:t + 1], p, h0=h)
        outs.append(step[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(got, full, rtol=1e-4, atol=1e-5)


# --------------------------------------------------------------------------
# step-vs-scan bit-parity through the serving recurrent-scan dispatchers:
# a single-token stateful step applied T times must reproduce the full-
# sequence scan *bit-for-bit* (not approximately) in every state format —
# that identity is what makes the paged engine's chunked prefill and
# per-token decode agree with the dense oracle exactly.
# --------------------------------------------------------------------------
def _state_cfgs():
    from repro.core.types import P8_2, P16_2
    return [("float", None), ("p8", P8_2), ("p16", P16_2)]


def _bits(x):
    return np.asarray(x).view(np.uint32)


def test_wkv_step_matches_scan_bitwise():
    from repro.kernels import ops as kops
    B, H, T, dh = 2, 2, 12, 8
    rng = np.random.default_rng(7)
    r, k, v = (jnp.asarray(rng.normal(size=(B, H, T, dh)), jnp.float32)
               for _ in range(3))
    logw = -jnp.asarray(rng.uniform(0.01, 2.0, (B, H, T, dh)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, dh)), jnp.float32)
    for name, pcfg in _state_cfgs():
        S0 = jnp.zeros((B, H, dh, dh), jnp.float32)
        y_full, S_full = kops.wkv_scan(r, k, v, logw, u, S0, cfg_state=pcfg)
        S = S0
        ys = []
        for t in range(T):
            sl = slice(t, t + 1)
            y, S = kops.wkv_scan(r[:, :, sl], k[:, :, sl], v[:, :, sl],
                                 logw[:, :, sl], u, S, cfg_state=pcfg)
            ys.append(y)
        y_step = jnp.concatenate(ys, axis=2)
        np.testing.assert_array_equal(_bits(y_step), _bits(y_full),
                                      err_msg=name)
        np.testing.assert_array_equal(_bits(S), _bits(S_full),
                                      err_msg=name)


def test_wkv_step_posit_pool_state_matches_dense_state():
    """Threading the state as PositArray pool bits (the engine's state
    pool) must equal threading it as round-tripped raw f32 (the dense
    cache tuple) — encode∘decode is the identity on canonical bits."""
    from repro.core.array import PositArray
    from repro.core.convert import f32_to_posit
    from repro.core.types import P16_2
    from repro.kernels import ops as kops
    B, H, T, dh = 1, 2, 6, 8
    rng = np.random.default_rng(8)
    r, k, v = (jnp.asarray(rng.normal(size=(B, H, T, dh)), jnp.float32)
               for _ in range(3))
    logw = -jnp.asarray(rng.uniform(0.01, 2.0, (B, H, T, dh)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, dh)), jnp.float32)
    Sf = jnp.zeros((B, H, dh, dh), jnp.float32)
    Sp = PositArray(f32_to_posit(Sf, P16_2), P16_2)
    for t in range(T):
        sl = slice(t, t + 1)
        args = (r[:, :, sl], k[:, :, sl], v[:, :, sl], logw[:, :, sl], u)
        yf, Sf = kops.wkv_scan(*args, Sf, cfg_state=P16_2)
        yp, Sp = kops.wkv_scan(*args, Sp, cfg_state=P16_2)
        assert isinstance(Sp, PositArray)
        np.testing.assert_array_equal(_bits(yf), _bits(yp))
        np.testing.assert_array_equal(np.asarray(Sp.to_f32()),
                                      np.asarray(Sf))


def test_rglru_step_matches_scan_bitwise():
    from repro.kernels import ops as kops
    B, T, d = 3, 15, 16
    rng = np.random.default_rng(9)
    a = jnp.asarray(rng.uniform(0.5, 0.999, (B, T, d)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(B, T, d)), jnp.float32)
    for name, pcfg in _state_cfgs():
        h0 = jnp.zeros((B, d), jnp.float32)
        y_full, h_full = kops.rglru_scan(a, b, h0, cfg_state=pcfg)
        h = h0
        ys = []
        for t in range(T):
            y, h = kops.rglru_scan(a[:, t:t + 1], b[:, t:t + 1], h,
                                   cfg_state=pcfg)
            ys.append(y)
        y_step = jnp.concatenate(ys, axis=1)
        np.testing.assert_array_equal(_bits(y_step), _bits(y_full),
                                      err_msg=name)
        np.testing.assert_array_equal(_bits(h), _bits(h_full), err_msg=name)
