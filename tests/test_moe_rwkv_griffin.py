"""Block-level correctness: MoE dispatch, chunked WKV, RG-LRU scan."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.models import griffin as GR
from repro.models import moe as MOE
from repro.models import rwkv6 as RW
from repro.quant.policy import NONE


def test_moe_matches_dense_when_topk_equals_experts():
    """top_k == E with ample capacity => exact softmax-weighted expert sum."""
    E, d, ff, B, S = 4, 16, 32, 2, 8
    key = jax.random.PRNGKey(0)
    p = MOE.init_moe(key, d, ff, E, "swiglu")
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d))
    out, aux = MOE.moe_block(x, p, n_experts=E, top_k=E, act="swiglu",
                             policy=NONE, capacity_factor=float(E),
                             group_size=B * S)
    # dense reference: every expert on every token, softmax-weighted
    logits = jnp.einsum("bsd,de->bse", x, p["router"])
    w = jax.nn.softmax(logits, -1)
    up = jnp.einsum("bsd,edf->bsef", x, p["w_up"])
    gate = jnp.einsum("bsd,edf->bsef", x, p["w_gate"])
    h = jax.nn.silu(gate.transpose(0, 1, 3, 2)).transpose(0, 1, 3, 2) * up
    h = jax.nn.silu(gate) * up
    ye = jnp.einsum("bsef,efd->bsed", h, p["w_down"])
    want = jnp.einsum("bse,bsed->bsd", w, ye)
    np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-5)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    """tiny capacity must drop tokens (outputs partially zeroed), not crash."""
    E, d, ff, B, S = 8, 16, 32, 2, 16
    p = MOE.init_moe(jax.random.PRNGKey(0), d, ff, E, "gelu")
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d))
    out, _ = MOE.moe_block(x, p, n_experts=E, top_k=2, act="gelu",
                           policy=NONE, capacity_factor=0.1, group_size=8)
    assert bool(jnp.isfinite(out).all())


def _wkv_sequential(r, k, v, logw, u):
    """Step-by-step WKV6 reference. r,k,v,logw [B,H,T,dh]; u [H,dh]."""
    B, H, T, dh = r.shape
    S = jnp.zeros((B, H, dh, dh))
    ys = []
    for t in range(T):
        rt, kt, vt = r[:, :, t], k[:, :, t], v[:, :, t]
        y = jnp.einsum("bhd,bhdv->bhv", rt, S)
        y += jnp.einsum("bhd,hd,bhd->bh", rt, u, kt)[..., None] * vt
        S = jnp.exp(logw[:, :, t])[..., None] * S + jnp.einsum(
            "bhd,bhv->bhdv", kt, vt)
        ys.append(y)
    return jnp.stack(ys, axis=2), S


def test_wkv_chunked_matches_sequential():
    B, H, T, dh = 2, 3, 40, 8
    rng = np.random.default_rng(0)
    r, k, v = (jnp.asarray(rng.normal(size=(B, H, T, dh)), jnp.float32)
               for _ in range(3))
    logw = -jnp.asarray(rng.uniform(0.01, 2.0, (B, H, T, dh)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, dh)), jnp.float32)

    want_y, want_S = _wkv_sequential(r, k, v, logw, u)

    S0 = jnp.zeros((B, H, dh, dh))
    C = 8
    ys = []
    S = S0
    for c in range(T // C):
        sl = slice(c * C, (c + 1) * C)
        S, y = RW._wkv_chunk(S, (r[:, :, sl], k[:, :, sl], v[:, :, sl],
                                 logw[:, :, sl], u), head_dim=dh)
        ys.append(y)
    got_y = jnp.concatenate(ys, axis=2)
    np.testing.assert_allclose(got_y, want_y, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(S, want_S, rtol=2e-4, atol=2e-5)


def test_rglru_scan_matches_sequential():
    B, T, d = 2, 24, 16
    rng = np.random.default_rng(1)
    p = GR.init_rglru_block(jax.random.PRNGKey(0), d)
    x = jnp.asarray(rng.normal(size=(B, T, d)), jnp.float32)
    got, h_last = GR.rglru(x, x, p)

    # sequential reference
    import jax.nn as jnn
    r = jnn.sigmoid(x @ p["w_rec_gate"]["w"])
    i = jnn.sigmoid(x @ p["w_input_gate"]["w"])
    log_a = GR.LRU_C * r * jnn.log_sigmoid(p["lam"])
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1 - jnp.exp(2 * log_a), 1e-12)) * (i * x)
    h = jnp.zeros((B, d))
    hs = []
    for t in range(T):
        h = a[:, t] * h + b[:, t]
        hs.append(h)
    want = jnp.stack(hs, axis=1)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(h_last, want[:, -1], rtol=2e-5, atol=2e-6)


def test_rglru_decode_step_matches_scan():
    B, T, d = 1, 10, 8
    p = GR.init_rglru_block(jax.random.PRNGKey(2), d)
    x = jax.random.normal(jax.random.PRNGKey(3), (B, T, d))
    full, _ = GR.rglru(x, x, p)
    h = jnp.zeros((B, d))
    outs = []
    for t in range(T):
        step, h = GR.rglru(x[:, t:t + 1], x[:, t:t + 1], p, h0=h)
        outs.append(step[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(got, full, rtol=1e-4, atol=1e-5)
