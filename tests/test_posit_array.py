"""First-class PositArray + repro.pnp: equivalence with the functional ops,
mixed-format safety, pytree transparency, and old-shim parity."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.pnp as pnp
from repro.core import (P8_2, P16_2, PositArray, PositConfigMismatchError,
                        padd, pdiv, pfma, pmul, pneg, pabs, precip, psub,
                        quire_dot, quire_matmul)
from repro.core.types import PositConfig


@pytest.fixture()
def rng():
    """Module-local, function-scoped rng: keeps this file's draws out of the
    session-scoped stream other test files consume (their sampled-input
    tests are order-sensitive via the shared fixture)."""
    return np.random.default_rng(1234)


def _all_p8_pairs():
    bits = np.arange(256)
    A, B = np.meshgrid(bits, bits)
    return (jnp.asarray(A.ravel(), jnp.int8), jnp.asarray(B.ravel(), jnp.int8))


# --------------------------------------------------------------------------
# operator overloading is bit-identical to the functional intrinsics
# --------------------------------------------------------------------------
def test_operators_bit_identical_exhaustive_p8():
    cfg = P8_2
    ab, bb = _all_p8_pairs()
    a, b = pnp.frombits(ab, cfg), pnp.frombits(bb, cfg)
    m = cfg.mask

    def raw(x):
        return np.asarray(x.bits).astype(np.int64) & m

    def ref(x):
        return np.asarray(x).astype(np.int64) & m

    assert (raw(a + b) == ref(padd(ab, bb, cfg))).all()
    assert (raw(a - b) == ref(psub(ab, bb, cfg))).all()
    assert (raw(a * b) == ref(pmul(ab, bb, cfg))).all()
    assert (raw(a / b) == ref(pdiv(ab, bb, cfg))).all()
    assert (raw(-a) == ref(pneg(ab, cfg))).all()
    assert (raw(abs(a)) == ref(pabs(ab, cfg))).all()
    assert (raw(pnp.fma(a, b, a)) == ref(pfma(ab, bb, ab, cfg))).all()
    assert (raw(pnp.reciprocal(a)) == ref(precip(ab, cfg))).all()


def test_matmul_bit_identical_to_quire(rng):
    for cfg, dt in ((P8_2, jnp.int8), (P16_2, jnp.int16)):
        ab = jnp.asarray(rng.integers(-(1 << (cfg.n - 1)) + 1,
                                      1 << (cfg.n - 1), (16, 24)), dt)
        bb = jnp.asarray(rng.integers(-(1 << (cfg.n - 1)) + 1,
                                      1 << (cfg.n - 1), (24, 8)), dt)
        a, b = pnp.frombits(ab, cfg), pnp.frombits(bb, cfg)
        got = np.asarray((a @ b).bits)
        want = np.asarray(quire_matmul(ab, bb, cfg))
        assert (got == want).all()
        gd = np.asarray(pnp.dot(a[0], b[:, 0]).bits)
        wd = np.asarray(quire_dot(ab[0], bb[:, 0], cfg))
        assert (gd == wd).all()


def test_comparisons_and_scalar_mixing(rng):
    cfg = P16_2
    a = pnp.asarray(rng.normal(size=(64,)).astype(np.float32), cfg)
    b = pnp.asarray(rng.normal(size=(64,)).astype(np.float32), cfg)
    lt = np.asarray(a < b)
    assert (np.asarray(a >= b) == ~lt).all()
    assert (np.asarray(pnp.equal(a, a))).all()
    # scalars are values, correctly rounded into a's format
    two_a = 2.0 * a
    want = pmul(pnp.asarray(2.0, cfg).bits, a.bits, cfg)
    assert (np.asarray(two_a.bits) == np.asarray(want)).all()
    # 1 - a == psub(one, a)
    one = pnp.ones_like(a)
    assert (np.asarray((1 - a).bits)
            == np.asarray((one - a).bits)).all()


# --------------------------------------------------------------------------
# mixed-format safety: loud errors, no silent reinterpretation
# --------------------------------------------------------------------------
def test_config_mismatch_raises():
    a = pnp.asarray(1.5, P16_2)
    b = pnp.asarray(1.5, P8_2)
    for fn in (lambda: a + b, lambda: a * b, lambda: a / b,
               lambda: a < b, lambda: pnp.fma(a, b, a),
               lambda: pnp.where(True, a, b)):
        with pytest.raises(PositConfigMismatchError):
            fn()
    with pytest.raises(PositConfigMismatchError):
        pnp.asarray(a, P8_2)
    # but the explicit cast works and is exact (widening)
    assert float(b.astype(P16_2).to_f32()) == float(b.to_f32())


def test_int_arrays_rejected_as_ambiguous():
    a = pnp.asarray(1.5, P16_2)
    with pytest.raises(TypeError):
        a + np.arange(3)
    with pytest.raises(TypeError):
        pnp.asarray(np.arange(3), P16_2)
    # payload ints go through the explicit constructor
    assert pnp.frombits(np.arange(3, dtype=np.int16), P16_2).shape == (3,)
    # ...which refuses float "bits" and out-of-range payloads
    with pytest.raises(TypeError):
        pnp.frombits(np.array([1.5, 2.0], np.float32), P16_2)
    with pytest.raises(ValueError):
        pnp.frombits(np.arange(300), P8_2)     # would wrap in int8


def test_scalar_broadcast_through_dispatch(rng):
    """Scalar / broadcast operands must be expanded at the dispatch layer
    (the Pallas path tiles inputs independently and cannot broadcast)."""
    from repro.kernels import ops as kops
    cfg = P16_2
    a = pnp.asarray(rng.normal(size=(8, 64)).astype(np.float32), cfg)
    two = pnp.asarray(2.0, cfg)
    out = kops.elementwise("mul", a, two)
    assert out.shape == (8, 64)
    np.testing.assert_array_equal(np.asarray(out.bits),
                                  np.asarray((a * 2.0).bits))
    rev = two - a                               # scalar on the left
    assert rev.shape == (8, 64)
    row = pnp.asarray(rng.normal(size=(64,)).astype(np.float32), cfg)
    got = kops.divide(a, row)                   # (8,64) / (64,) broadcast
    assert got.shape == (8, 64)
    # gemm with cfg-less raw ints (old silent-garbage path) now refuses
    with pytest.raises(TypeError):
        kops.gemm(a.bits[:4, :4], a.bits[:4, :4])


# --------------------------------------------------------------------------
# pytree transparency: jit / vmap / grad(STE)
# --------------------------------------------------------------------------
def test_pytree_roundtrip_and_jit_vmap(rng):
    cfg = P16_2
    a = pnp.asarray(rng.normal(size=(8, 16)).astype(np.float32), cfg)
    leaves, treedef = jax.tree_util.tree_flatten(a)
    assert len(leaves) == 1 and leaves[0].dtype == jnp.int16
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(back, PositArray) and back.cfg == cfg

    b = pnp.asarray(rng.normal(size=(8, 16)).astype(np.float32), cfg)
    eager = a + b
    jitted = jax.jit(lambda x, y: x + y)(a, b)
    assert isinstance(jitted, PositArray) and jitted.cfg == cfg
    assert (np.asarray(jitted.bits) == np.asarray(eager.bits)).all()

    vm = jax.vmap(lambda x, y: x * y)(a, b)
    assert (np.asarray(vm.bits) == np.asarray((a * b).bits)).all()

    # PositArray nested inside dict pytrees (the params/caches convention)
    tree = {"w": a, "scale": jnp.ones(())}
    out = jax.jit(lambda t: t["w"] + t["w"])(tree)
    assert isinstance(out, PositArray)


def test_grad_via_ste_cast(rng):
    w = jnp.asarray(rng.normal(size=(4, 4)), jnp.float32)
    g = jax.grad(lambda x: (pnp.ste(x, P16_2) ** 2).sum())(w)
    np.testing.assert_allclose(np.asarray(g), 2 * np.asarray(pnp.ste(w, P16_2)),
                               rtol=1e-6)


# --------------------------------------------------------------------------
# namespace coverage: constructors, where/sign, packing
# --------------------------------------------------------------------------
def test_constructors_and_where_sign(rng):
    cfg = P8_2
    z = pnp.zeros((3, 4), cfg)
    assert (np.asarray(z.bits) == 0).all() and z.dtype == jnp.int8
    o = pnp.ones((3, 4), cfg)
    assert (np.asarray(o.to_f32()) == 1.0).all()
    f = pnp.full((5,), -2.5, cfg)
    assert np.allclose(np.asarray(f.to_f32()), -2.5)

    a = pnp.asarray(rng.normal(size=(32,)).astype(np.float32), cfg)
    w = pnp.where(a < 0.0, pnp.zeros_like(a), a)
    assert (np.asarray(w.to_f32()) >= 0).all()

    s = pnp.sign(a)
    vf = np.asarray(a.to_f32())
    np.testing.assert_array_equal(np.asarray(s.to_f32()), np.sign(vf))


def test_pack_unpack_roundtrip(rng):
    for cfg, dt in ((P8_2, np.int8), (P16_2, np.int16)):
        a = pnp.frombits(
            jnp.asarray(rng.integers(-(1 << (cfg.n - 1)), 1 << (cfg.n - 1),
                                     (4, 32)), jnp.dtype(dt.__name__)), cfg)
        w = pnp.pack(a)
        assert w.dtype == jnp.int32
        assert w.shape[-1] == 32 // pnp.lanes(cfg)
        back = pnp.unpack(w, cfg)
        assert (np.asarray(back.bits) == np.asarray(a.bits)).all()


# --------------------------------------------------------------------------
# deprecated shims: old functional signatures == new API
# --------------------------------------------------------------------------
def test_old_shims_match_new_api(rng):
    from repro.kernels import ops as kops
    cfg = P16_2
    xb = jnp.asarray(rng.integers(-(1 << 15) + 1, 1 << 15, (6, 8)), jnp.int16)
    yb = jnp.asarray(rng.integers(-(1 << 15) + 1, 1 << 15, (6, 8)), jnp.int16)
    x, y = pnp.frombits(xb, cfg), pnp.frombits(yb, cfg)

    # raw-bits + explicit cfg (old) vs PositArray (new)
    old = kops.elementwise("add", xb, yb, cfg=cfg)
    new = kops.elementwise("add", x, y)
    assert isinstance(new, PositArray)
    assert (np.asarray(old) == np.asarray(new.bits)).all()

    old = kops.divide(xb, yb, cfg=cfg)
    new = kops.divide(x, y)
    assert (np.asarray(old) == np.asarray(new.bits)).all()

    act = jnp.asarray(rng.normal(size=(4, 6)), jnp.float32)
    wb = jnp.asarray(rng.integers(-(1 << 15) + 1, 1 << 15, (6, 8)), jnp.int16)
    old = kops.pw_matmul(act, wb, cfg)
    new = kops.pw_matmul(act, pnp.frombits(wb, cfg))
    np.testing.assert_array_equal(np.asarray(old), np.asarray(new))

    mb = jnp.asarray(rng.integers(-(1 << 15) + 1, 1 << 15, (8, 5)), jnp.int16)
    m = pnp.frombits(mb, cfg)
    old = kops.gemm(xb, mb, cfg_a=cfg, cfg_b=cfg, cfg_out=cfg, out_posit=True)
    new = kops.gemm(x, m, out_posit=True)
    assert isinstance(new, PositArray)
    assert (np.asarray(old) == np.asarray(new.bits)).all()

    # explicit cfg contradicting the array's bound format is an error
    with pytest.raises(ValueError):
        kops.elementwise("add", x, y, cfg=P8_2)


def test_kv_cache_positarray_pages(rng):
    from repro.serving.kv_cache import append_kv, init_cache, materialize_kv
    cfg = P16_2
    cache = init_cache(2, 2, 16, 8, cfg)
    assert isinstance(cache["k"], PositArray)
    k = jnp.asarray(rng.normal(size=(2, 2, 4, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 2, 4, 8)), jnp.float32)
    cache = append_kv(cache, k, v)          # no cfg threading
    assert isinstance(cache["k"], PositArray) and int(cache["length"]) == 4
    kf, vf = materialize_kv(cache)
    np.testing.assert_allclose(np.asarray(kf[:, :, :4]), np.asarray(k),
                               rtol=0.01, atol=0.01)
    # legacy float cache still works
    fcache = init_cache(2, 2, 16, 8, None)
    fcache = append_kv(fcache, k, v)
    kf2, _ = materialize_kv(fcache)
    np.testing.assert_array_equal(np.asarray(kf2[:, :, :4]), np.asarray(k))
    # explicit cfg contradicting the page format is an error
    with pytest.raises(ValueError):
        append_kv(cache, k, v, P8_2)


def test_numpy_left_operand_and_foreign_eq(rng):
    cfg = P16_2
    a = pnp.asarray(rng.normal(size=(4,)).astype(np.float32), cfg)
    f = np.ones((4,), np.float32)
    # numpy on the left must defer to our reflected ops (__array_ufunc__=None)
    out = f + a
    assert isinstance(out, PositArray)
    want = pnp.asarray(f, cfg) + a
    assert (np.asarray(out.bits) == np.asarray(want.bits)).all()
    out = f * a
    assert isinstance(out, PositArray)
    # foreign types fall back to identity comparison instead of raising
    assert (a == None) is False          # noqa: E711
    assert (a != "x") is True
    # ...but ambiguous int arrays stay loud even under == (no silent False)
    with pytest.raises(TypeError):
        a == a.bits                      # noqa: B015
    # but mismatched posit formats still raise, even under ==
    with pytest.raises(PositConfigMismatchError):
        a == pnp.asarray(1.0, P8_2)      # noqa: B015


def test_single_posit_kv_operand_rejected(rng):
    from repro.kernels import ops as kops
    from repro.models.blocks import blockwise_attention
    q = jnp.asarray(rng.normal(size=(2, 8, 16)), jnp.float32)
    kf = jnp.asarray(rng.normal(size=(2, 8, 16)), jnp.float32)
    vp = pnp.asarray(rng.normal(size=(2, 8, 16)).astype(np.float32), P16_2)
    with pytest.raises(TypeError):
        kops.attention(q, kf, vp)
    qb = jnp.asarray(rng.normal(size=(1, 2, 4, 8)), jnp.float32)
    kb = jnp.asarray(rng.normal(size=(1, 2, 4, 8)), jnp.float32)
    vb = pnp.asarray(rng.normal(size=(1, 2, 4, 8)).astype(np.float32), P16_2)
    with pytest.raises(TypeError):
        blockwise_attention(qb, kb, vb, n_kv=2, causal=True)


def test_float_payload_and_mixed_gemm_guards(rng):
    from repro.kernels import ops as kops
    cfg = P16_2
    a = pnp.asarray(rng.normal(size=(4,)).astype(np.float32), cfg)
    f = jnp.asarray(rng.normal(size=(4,)), jnp.float32)
    # raw float companions would be consumed as bit patterns: refuse
    with pytest.raises(TypeError):
        kops.elementwise("add", a, f)
    with pytest.raises(TypeError):
        kops.divide(a, f)
    # mixed-format gemm with posit output needs an explicit cfg_out
    e8 = pnp.asarray(np.eye(4, dtype=np.float32), P8_2)
    e16 = pnp.asarray(np.eye(4, dtype=np.float32), P16_2)
    with pytest.raises(PositConfigMismatchError):
        kops.gemm(e8, e16, out_posit=True)
    out = kops.gemm(e8, e16, cfg_out=P16_2, out_posit=True)
    assert isinstance(out, PositArray) and out.cfg == P16_2
    # posit q is rejected at the boundary with a clear message
    with pytest.raises(TypeError, match="q must be a float array"):
        kops.attention(e16[None], f[None, :, None], f[None, :, None])
    # int raw companions remain valid shims (same-format payload bits)
    got = kops.elementwise("add", a, a.bits)
    assert (np.asarray(got.bits) == np.asarray((a + a).bits)).all()
    # python scalars (values) would be consumed as bit patterns: refuse
    with pytest.raises(TypeError):
        kops.elementwise("add", a, 1.5)
    with pytest.raises(TypeError):
        kops.elementwise("add", a, 7)
    # gemm: cfg-less int companions of a posit operand are value-corruption
    w16 = pnp.asarray(rng.normal(size=(4, 3)).astype(np.float32), cfg)
    with pytest.raises(TypeError):
        kops.gemm(a.reshape(1, 4).bits, w16)
    # ...but float activations x posit weights (the pw path) stay legal
    acts = jnp.asarray(rng.normal(size=(2, 4)), jnp.float32)
    assert kops.gemm(acts, w16).shape == (2, 3)


def test_legacy_raw_int_cache_shim(rng):
    from repro.serving.kv_cache import append_kv, materialize_kv
    cfg = P16_2
    # pre-PositArray convention: raw int buffers + threaded cfg
    legacy = {"k": jnp.zeros((1, 1, 8, 4), jnp.int16),
              "v": jnp.zeros((1, 1, 8, 4), jnp.int16),
              "length": jnp.zeros((), jnp.int32)}
    k = jnp.asarray(rng.normal(size=(1, 1, 2, 4)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 1, 2, 4)), jnp.float32)
    out = append_kv(legacy, k, v, cfg)
    kf, _ = materialize_kv(out, cfg)
    np.testing.assert_allclose(np.asarray(kf[:, :, :2]), np.asarray(k),
                               rtol=0.01, atol=0.01)
    # int buffers with no format must refuse, not truncate silently
    with pytest.raises(TypeError):
        append_kv(legacy, k, v)


def test_quantize_trees_produce_posit_arrays(rng):
    from repro.quant.policy import dequantize_tree, quantize_tree
    params = {"w": jnp.asarray(rng.normal(size=(8, 8)), jnp.float32),
              "scale": jnp.ones((8,), jnp.float32)}
    q = quantize_tree(params, P16_2)
    assert isinstance(q["w"], PositArray) and q["w"].cfg == P16_2
    assert q["scale"].dtype == jnp.float32      # 1-D leaves stay float
    d = dequantize_tree(q)                      # no cfg needed
    np.testing.assert_allclose(np.asarray(d["w"]), np.asarray(params["w"]),
                               rtol=2e-3, atol=2e-3)
