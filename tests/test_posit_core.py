"""Bit-exactness of the posit core: golden model vs f64 semantics, and the
JAX integer datapath vs the golden model (the paper's §VII validation flow).

posit8: exhaustive over all operand pairs (65 536 per op per ES).
posit16: 200k sampled pairs per op per ES.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import golden as G
from repro.core import ops as O
from repro.core.convert import f32_to_posit, posit_to_f32
from repro.core.types import PositConfig, table2_grid

P8S = [PositConfig(8, es) for es in range(5)]
P16S = [PositConfig(16, es) for es in range(4)]


def _pairs(cfg, n=200_000, seed=0):
    if cfg.n <= 8:
        bits = np.arange(1 << cfg.n)
        A, B = np.meshgrid(bits, bits)
        return A.ravel(), B.ravel()
    rng = np.random.default_rng(seed)
    return (rng.integers(0, 1 << cfg.n, n), rng.integers(0, 1 << cfg.n, n))


# ---------------- golden vs float64 semantics ----------------
@pytest.mark.parametrize("cfg", P8S, ids=str)
def test_golden_roundtrip_exhaustive(cfg):
    bits = np.arange(1 << cfg.n)
    v = G.decode_to_float64(bits, cfg)
    back = G.encode_from_float64(v, cfg)
    ok = (back == bits) | (~np.isfinite(v) & (back == cfg.nar))
    assert ok.all()


@pytest.mark.parametrize("cfg", P8S, ids=str)
def test_golden_ops_vs_f64_exhaustive(cfg):
    A, B = _pairs(cfg)
    va, vb = G.decode_to_float64(A, cfg), G.decode_to_float64(B, cfg)
    assert (G.padd(A, B, cfg) == G.encode_from_float64(va + vb, cfg)).all()
    assert (G.pmul(A, B, cfg) == G.encode_from_float64(va * vb, cfg)).all()
    q = np.divide(va, vb, out=np.full_like(va, np.nan), where=vb != 0)
    want = np.where(vb == 0, cfg.nar, G.encode_from_float64(q, cfg))
    assert (G.pdiv(A, B, cfg) == want).all()


# ---------------- JAX datapath vs golden ----------------
@pytest.mark.parametrize("cfg", P8S + P16S, ids=str)
def test_jax_ops_bit_exact(cfg):
    A, B = _pairs(cfg)
    Aj = jnp.asarray(A, jnp.int32)
    Bj = jnp.asarray(B, jnp.int32)
    m = cfg.mask
    assert (np.asarray(O.padd(Aj, Bj, cfg)).astype(np.int64) & m
            == G.padd(A, B, cfg)).all()
    assert (np.asarray(O.pmul(Aj, Bj, cfg)).astype(np.int64) & m
            == G.pmul(A, B, cfg)).all()
    assert (np.asarray(O.psub(Aj, Bj, cfg)).astype(np.int64) & m
            == G.psub(A, B, cfg)).all()
    wantd = G.pdiv(A, B, cfg)
    for mode in ("exact", "poly_corrected"):
        got = np.asarray(O.pdiv(Aj, Bj, cfg, mode=mode)).astype(np.int64) & m
        assert (got == wantd).all(), mode


@pytest.mark.parametrize("cfg", [PositConfig(8, 2), PositConfig(16, 2)],
                         ids=str)
def test_jax_fma_bit_exact(cfg):
    rng = np.random.default_rng(1)
    n = 50_000
    A, B, C = (rng.integers(0, 1 << cfg.n, n) for _ in range(3))
    got = np.asarray(O.pfma(jnp.asarray(A, jnp.int32), jnp.asarray(B, jnp.int32),
                            jnp.asarray(C, jnp.int32), cfg)).astype(np.int64) & cfg.mask
    assert (got == G.pfma(A, B, C, cfg)).all()


@pytest.mark.parametrize("cfg", P8S + P16S, ids=str)
def test_conversions_exact(cfg):
    bits = np.arange(1 << cfg.n) if cfg.n <= 8 else \
        np.random.default_rng(2).integers(0, 1 << cfg.n, 100_000)
    v64 = G.decode_to_float64(bits, cfg)
    # decode f32 == golden f64 (exact for n<=16)
    vj = np.asarray(posit_to_f32(jnp.asarray(bits, jnp.int32), cfg), np.float64)
    ok = (vj == v64) | (np.isnan(vj) & np.isnan(v64))
    assert ok.all()
    # f32 encode == golden encode
    vv = v64.astype(np.float32)
    pj = np.asarray(f32_to_posit(jnp.asarray(vv), cfg)).astype(np.int64) & cfg.mask
    assert (pj == G.encode_from_float64(vv.astype(np.float64), cfg)).all()


def test_table2_wrong_rates_match_paper_scale():
    """The approximate (paper) division pipeline should sit at/below the
    paper's proposed wrong-%s (Table II): p8 <= ~8%, p16es2 <= ~1%."""
    from repro.core.types import P8_0, P16_2
    for cfg, bound in ((P8_0, 8.0), (P16_2, 1.0)):
        A, B = _pairs(cfg, n=100_000)
        want = G.pdiv(A, B, cfg)
        got = np.asarray(O.pdiv(jnp.asarray(A, jnp.int32),
                                jnp.asarray(B, jnp.int32), cfg,
                                mode="poly", nr_rounds=1)).astype(np.int64) & cfg.mask
        assert 100.0 * (got != want).mean() <= bound


def test_quire_dot_exact():
    cfg = PositConfig(16, 2)
    rng = np.random.default_rng(3)
    x = rng.integers(0, 1 << 16, 128)
    y = rng.integers(0, 1 << 16, 128)
    x = np.where(x == cfg.nar, 0, x)
    y = np.where(y == cfg.nar, 0, y)
    import math
    vx, vy = G.decode_to_float64(x, cfg), G.decode_to_float64(y, cfg)
    exact = math.fsum(float(a) * float(b) for a, b in zip(vx, vy))
    assert G.quire_dot(x, y, cfg) == int(
        G.encode_from_float64(np.array(exact), cfg))


def test_packing_roundtrip_and_simd_map():
    from repro.core.packing import lanes, pack_words, packed_map, unpack_words
    from repro.core.types import P8_2, P16_2
    rng = np.random.default_rng(4)
    for cfg, dt in ((P8_2, jnp.int8), (P16_2, jnp.int16)):
        x = jnp.asarray(rng.integers(-(1 << (cfg.n - 1)), 1 << (cfg.n - 1),
                                     (8, 32)), dt)
        y = jnp.asarray(rng.integers(-(1 << (cfg.n - 1)), 1 << (cfg.n - 1),
                                     (8, 32)), dt)
        w = pack_words(x, cfg)
        assert w.shape[-1] == 32 // lanes(cfg)
        assert (unpack_words(w, cfg) == x).all()
        pm = unpack_words(packed_map(O.padd, pack_words(x, cfg),
                                     pack_words(y, cfg), cfg), cfg)
        assert (np.asarray(pm).astype(np.int64) & cfg.mask
                == np.asarray(O.padd(x, y, cfg)).astype(np.int64) & cfg.mask).all()
