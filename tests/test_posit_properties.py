"""Hypothesis property-based tests for posit arithmetic invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

# property sweeps run hundreds of eager-dispatch examples per test: nightly
pytestmark = pytest.mark.slow

from repro.core import golden as G
from repro.core import ops as O
from repro.core.types import PositConfig

CFGS = [PositConfig(8, 0), PositConfig(8, 2), PositConfig(16, 1),
        PositConfig(16, 2)]

cfg_st = st.sampled_from(CFGS)


def bits_st(cfg):
    return st.integers(0, (1 << cfg.n) - 1)


@given(cfg=cfg_st, data=st.data())
@settings(max_examples=300, deadline=None)
def test_commutativity(cfg, data):
    a = data.draw(bits_st(cfg))
    b = data.draw(bits_st(cfg))
    aj, bj = jnp.int32(a), jnp.int32(b)
    assert int(O.padd(aj, bj, cfg)) == int(O.padd(bj, aj, cfg))
    assert int(O.pmul(aj, bj, cfg)) == int(O.pmul(bj, aj, cfg))


@given(cfg=cfg_st, data=st.data())
@settings(max_examples=300, deadline=None)
def test_negation_symmetry(cfg, data):
    """round(-a + -b) == -round(a + b): RNE is sign-symmetric."""
    a = data.draw(bits_st(cfg))
    b = data.draw(bits_st(cfg))
    if a == cfg.nar or b == cfg.nar:
        return
    aj, bj = jnp.int32(a), jnp.int32(b)
    s = O.padd(aj, bj, cfg)
    sn = O.padd(O.pneg(aj, cfg).astype(jnp.int32),
                O.pneg(bj, cfg).astype(jnp.int32), cfg)
    assert int(O.pneg(s.astype(jnp.int32), cfg)) & cfg.mask == int(sn) & cfg.mask


@given(cfg=cfg_st, data=st.data())
@settings(max_examples=300, deadline=None)
def test_identities(cfg, data):
    a = data.draw(bits_st(cfg))
    if a == cfg.nar:
        return
    aj = jnp.int32(a)
    one = jnp.int32(1 << (cfg.n - 2))
    zero = jnp.int32(0)
    assert int(O.pmul(aj, one, cfg)) & cfg.mask == a          # x*1 == x
    assert int(O.padd(aj, zero, cfg)) & cfg.mask == a         # x+0 == x
    assert int(O.pdiv(aj, one, cfg, mode="exact")) & cfg.mask == a
    # x - x == 0
    assert int(O.psub(aj, aj, cfg)) & cfg.mask == 0
    # x / x == 1 for nonzero
    if a != 0:
        assert int(O.pdiv(aj, aj, cfg, mode="poly_corrected")) & cfg.mask == int(one)


@given(cfg=cfg_st, data=st.data())
@settings(max_examples=300, deadline=None)
def test_nar_propagation(cfg, data):
    a = data.draw(bits_st(cfg))
    nar = jnp.int32(cfg.nar)
    aj = jnp.int32(a)
    for op in (O.padd, O.pmul, O.psub):
        assert int(op(aj, nar, cfg)) & cfg.mask == cfg.nar
    assert int(O.pdiv(aj, nar, cfg)) & cfg.mask == cfg.nar
    assert int(O.pdiv(aj, jnp.int32(0), cfg)) & cfg.mask == cfg.nar  # x/0


@given(cfg=cfg_st, data=st.data())
@settings(max_examples=300, deadline=None)
def test_pattern_monotonicity(cfg, data):
    """Posit patterns compare as 2's-complement ints (paper §VIII)."""
    a = data.draw(bits_st(cfg))
    b = data.draw(bits_st(cfg))
    if cfg.nar in (a, b):
        return
    va, vb = (float(G.decode_to_float64(np.array([x]), cfg)[0]) for x in (a, b))
    got = bool(O.plt(jnp.int32(a), jnp.int32(b), cfg))
    assert got == (va < vb)


@given(cfg=cfg_st, v=st.floats(-1e6, 1e6, allow_nan=False))
@settings(max_examples=300, deadline=None)
def test_encode_is_nearest(cfg, v):
    """f32->posit must return one of the two bracketing posits, preferring
    the closer (exact RNE checked against the golden f64 encode)."""
    from repro.core.convert import f32_to_posit
    got = int(np.asarray(f32_to_posit(jnp.float32(v), cfg))) & cfg.mask
    want = int(G.encode_from_float64(np.array(np.float32(v), np.float64), cfg))
    assert got == want


@given(cfg=cfg_st, data=st.data())
@settings(max_examples=200, deadline=None)
def test_double_encode_idempotent(cfg, data):
    a = data.draw(bits_st(cfg))
    if a == cfg.nar:
        return
    from repro.core.convert import f32_to_posit, posit_to_f32
    v = posit_to_f32(jnp.int32(a), cfg)
    assert int(np.asarray(f32_to_posit(v, cfg))) & cfg.mask == a


@given(data=st.data())
@settings(max_examples=100, deadline=None)
def test_add_magnitude_bounds(data):
    """|round(a+b)| lies within the posit range and saturates, never wraps."""
    cfg = PositConfig(8, 0)
    a = data.draw(bits_st(cfg))
    b = data.draw(bits_st(cfg))
    if cfg.nar in (a, b):
        return
    out = int(O.padd(jnp.int32(a), jnp.int32(b), cfg)) & cfg.mask
    va, vb = (G.decode_to_float64(np.array([x]), cfg)[0] for x in (a, b))
    vo = G.decode_to_float64(np.array([out]), cfg)[0]
    assert not np.isnan(vo)
    hi = G.decode_to_float64(np.array([cfg.maxpos_bits]), cfg)[0]
    assert abs(vo) <= hi
