"""Fused paged/contiguous flash *prefill* kernel: parity vs the gather_kv +
blockwise_attention oracle, chunk-boundary causality, in-kernel
window/softcap masking, the no-dense-materialization guarantee on the
Pallas path (gather-fallback counter), the transpose_b pw_gemm unembedding
path, and the custom_vjp (kernel forward / reference backward) gradients.

Everything runs the real kernel code in interpret mode, so regressions fail
in tier-1 before the nightly TPU lane ever sees them.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.convert import f32_to_posit
from repro.core.types import P8_2, P16_2
from repro.kernels.flash_attention import (flash_prefill_contiguous,
                                           paged_flash_prefill)
from repro.models.blocks import blockwise_attention
from repro.serving.paged_kv import gather_kv

TOL = dict(rtol=2e-6, atol=2e-6)


def _sequential_table(B, W):
    pt = np.zeros((B, W), np.int32)
    pt[:] = 1 + np.arange(B * W).reshape(B, W)
    return jnp.asarray(pt)


def _pool(rng, B, n_kv, page, W, D, pcfg):
    kd = jnp.asarray(rng.normal(size=(1 + B * W, n_kv, page, D)), jnp.float32)
    vd = jnp.asarray(rng.normal(size=(1 + B * W, n_kv, page, D)), jnp.float32)
    if pcfg is not None:
        return f32_to_posit(kd, pcfg), f32_to_posit(vd, pcfg)
    return kd, vd


def _oracle(q, kp, vp, pt, pcfg, *, seq_lens, q_off, causal=True,
            window=None, softcap=None):
    """The dense-materialization reference the kernel replaced: gather_kv
    into the position-identical dense view, then the jnp blockwise scan."""
    if pcfg is not None:
        from repro.core.array import PositArray
        cache = {"k_pages": PositArray(kp, pcfg),
                 "v_pages": PositArray(vp, pcfg), "page_table": pt}
    else:
        cache = {"k_pages": kp, "v_pages": vp, "page_table": pt}
    k, v = gather_kv(cache)
    return blockwise_attention(q, k, v, n_kv=kp.shape[1], causal=causal,
                               q_offset=q_off, window=window,
                               softcap=softcap, kv_len=seq_lens)


@pytest.mark.parametrize("pcfg", [None, P16_2, P8_2],
                         ids=["float", "p16", "p8"])
@pytest.mark.parametrize("window,softcap",
                         [(None, None), (5, None), (None, 8.0), (7, 12.0)],
                         ids=["plain", "window", "softcap", "both"])
def test_paged_prefill_matches_gathered_blockwise_oracle(pcfg, window,
                                                         softcap):
    """Sq > 1 chunks over the paged pool (interpret mode) vs the gather_kv
    + blockwise oracle at ragged lengths — the masks that used to force the
    dense fallback (softcap, window) are now in-kernel."""
    rng = np.random.default_rng(7)
    B, n_kv, G, D, page, W, Sq = 3, 2, 2, 16, 8, 4, 6
    H = n_kv * G
    seq_lens = jnp.asarray([7, 20, 32], jnp.int32)     # post-append
    q_off = seq_lens - Sq
    pt = _sequential_table(B, W)
    kb, vb = _pool(rng, B, n_kv, page, W, D, pcfg)
    q = jnp.asarray(rng.normal(size=(B, H, Sq, D)), jnp.float32)

    out = paged_flash_prefill(q, kb, vb, pt, seq_lens, q_off, cfg_kv=pcfg,
                              window=window, softcap=softcap, bq=4,
                              interpret=True)
    ref = _oracle(q, kb, vb, pt, pcfg, seq_lens=seq_lens, q_off=q_off,
                  window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


@pytest.mark.parametrize("pcfg", [None, P16_2], ids=["float", "p16"])
def test_paged_prefill_noncausal_encoder_chunk(pcfg):
    rng = np.random.default_rng(8)
    B, n_kv, G, D, page, W, Sq = 2, 2, 2, 16, 8, 4, 8
    H = n_kv * G
    seq_lens = jnp.asarray([8, 26], jnp.int32)
    q_off = jnp.zeros((B,), jnp.int32)
    pt = _sequential_table(B, W)
    kb, vb = _pool(rng, B, n_kv, page, W, D, pcfg)
    q = jnp.asarray(rng.normal(size=(B, H, Sq, D)), jnp.float32)
    out = paged_flash_prefill(q, kb, vb, pt, seq_lens, q_off, cfg_kv=pcfg,
                              causal=False, bq=4, interpret=True)
    ref = _oracle(q, kb, vb, pt, pcfg, seq_lens=seq_lens, q_off=q_off,
                  causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


@pytest.mark.parametrize("pcfg", [None, P16_2], ids=["float", "p16"])
def test_prefill_chunk_boundary_causality(pcfg):
    """Prefilling a prompt in one 1 x N chunk and in two N/2 chunks must
    produce identical rows: each chunk's queries see exactly the KV written
    so far (seq_lens advances between chunks), never the later half."""
    rng = np.random.default_rng(9)
    B, n_kv, G, D, page, W, N = 2, 2, 2, 16, 8, 4, 8
    H = n_kv * G
    L0 = jnp.asarray([5, 11], jnp.int32)               # tokens before chunk
    pt = _sequential_table(B, W)
    kb, vb = _pool(rng, B, n_kv, page, W, D, pcfg)
    q = jnp.asarray(rng.normal(size=(B, H, N, D)), jnp.float32)

    whole = paged_flash_prefill(q, kb, vb, pt, L0 + N, L0, cfg_kv=pcfg,
                                bq=4, interpret=True)
    h = N // 2
    first = paged_flash_prefill(q[:, :, :h], kb, vb, pt, L0 + h, L0,
                                cfg_kv=pcfg, bq=4, interpret=True)
    second = paged_flash_prefill(q[:, :, h:], kb, vb, pt, L0 + N, L0 + h,
                                 cfg_kv=pcfg, bq=4, interpret=True)
    split = jnp.concatenate([first, second], axis=2)
    assert jnp.array_equal(whole, split), \
        "1xN vs 2xN/2 prefill chunks disagree at the chunk boundary"


@pytest.mark.parametrize("pcfg", [None, P16_2], ids=["float", "p16"])
@pytest.mark.parametrize("window,softcap", [(None, None), (6, 9.0)],
                         ids=["plain", "masked"])
def test_contiguous_prefill_matches_blockwise(pcfg, window, softcap):
    """The contiguous-KV entry (dense cache / training layout) vs the jnp
    scan it dispatches around."""
    rng = np.random.default_rng(10)
    B, n_kv, G, D, Skv, Sq = 2, 2, 2, 16, 24, 6
    H = n_kv * G
    kv_len = jnp.asarray([13, 24], jnp.int32)
    q_off = kv_len - Sq
    kd = jnp.asarray(rng.normal(size=(B, n_kv, Skv, D)), jnp.float32)
    vd = jnp.asarray(rng.normal(size=(B, n_kv, Skv, D)), jnp.float32)
    kb = f32_to_posit(kd, pcfg) if pcfg is not None else kd
    vb = f32_to_posit(vd, pcfg) if pcfg is not None else vd
    q = jnp.asarray(rng.normal(size=(B, H, Sq, D)), jnp.float32)

    out = flash_prefill_contiguous(q, kb, vb, kv_len, q_off, cfg_kv=pcfg,
                                   window=window, softcap=softcap, bq=4,
                                   bk=8, interpret=True)
    ref = blockwise_attention(q, kb, vb, n_kv=n_kv, causal=True,
                              q_offset=q_off, window=window,
                              softcap=softcap, kv_len=kv_len, cfg_kv=pcfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


# --------------------------------------------------------------------------
# the no-dense-materialization guarantee on the Pallas path
# --------------------------------------------------------------------------
def _pallas_interpret_env(monkeypatch):
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    monkeypatch.delenv("REPRO_FORCE_GATHER", raising=False)


def test_paged_attention_fuses_all_shapes_on_pallas_path(monkeypatch):
    """Sq > 1, softcapped Sq == 1, and windowed chunks must all take the
    fused kernels when use_pallas(): the gather_kv fallback counter stays
    untouched and outputs match the CPU oracle route."""
    from repro.serving import paged_kv

    rng = np.random.default_rng(11)
    B, n_kv, G, D, page, W = 2, 2, 2, 16, 4, 4
    H = n_kv * G
    pt = _sequential_table(B, W)
    kp, vp = _pool(rng, B, n_kv, page, W, D, P16_2)   # raw bits
    from repro.core.array import PositArray
    cases = [
        dict(Sq=5, softcap=None, window=None),
        dict(Sq=5, softcap=7.0, window=None),
        dict(Sq=1, softcap=7.0, window=None),    # softcapped decode
        dict(Sq=5, softcap=None, window=3),
    ]
    for case in cases:
        Sq = case["Sq"]
        seq_lens = jnp.asarray([6, 15], jnp.int32)
        cache = {"k_pages": PositArray(kp, P16_2),
                 "v_pages": PositArray(vp, P16_2),
                 "page_table": pt, "seq_lens": seq_lens,
                 "num_new": jnp.full((B,), Sq, jnp.int32)}
        q = jnp.asarray(rng.normal(size=(B, H, Sq, D)), jnp.float32)

        ref = paged_kv.paged_attention(q, cache, n_kv=n_kv,
                                       softcap=case["softcap"],
                                       window=case["window"])

        _pallas_interpret_env(monkeypatch)
        before = dict(paged_kv.GATHER_FALLBACKS)
        out = paged_kv.paged_attention(q, cache, n_kv=n_kv,
                                       softcap=case["softcap"],
                                       window=case["window"])
        monkeypatch.delenv("REPRO_USE_PALLAS")
        monkeypatch.delenv("REPRO_PALLAS_INTERPRET")
        assert dict(paged_kv.GATHER_FALLBACKS) == before, \
            f"fused path fell back to gather_kv for {case}"
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def test_engine_drain_on_pallas_path_no_gather_and_bit_parity(monkeypatch):
    """A full continuous-batching drain (chunked prefill + decode + posit16
    unembedding) through the interpret-mode kernels: steady-state prefill
    never calls gather_kv, and greedy tokens are identical to the jnp
    reference engine."""
    from repro.models.transformer import ModelConfig, init_params
    from repro.quant.policy import PositPolicy
    from repro.serving import engine as E
    from repro.serving import paged_kv

    def _cfg(name):
        # distinct names: the per-config jitted steps must not be shared
        # between the reference and kernel runs
        return ModelConfig(name=name, n_layers=2, d_model=32, n_heads=4,
                           n_kv=2, d_ff=64, vocab=50,
                           policy=PositPolicy(kv_cache=P16_2))

    cfg = _cfg("prefill-ref")
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (3, 10),
                                            0, cfg.vocab))
    reqs = [(prompts[i], 5) for i in range(3)]

    eng = E.PagedServingEngine(params, cfg, max_seqs=3, page_size=4,
                               table_width=8, prefill_chunk=8)
    ref = eng.run(list(reqs))

    _pallas_interpret_env(monkeypatch)
    before = dict(paged_kv.GATHER_FALLBACKS)
    eng2 = E.PagedServingEngine(params, _cfg("prefill-fused"), max_seqs=3,
                                page_size=4, table_width=8, prefill_chunk=8)
    res = eng2.run(list(reqs))
    assert dict(paged_kv.GATHER_FALLBACKS) == before, \
        "TPU-path serving performed a dense KV materialization"
    for i in range(3):
        assert np.array_equal(ref[i], res[i]), (i, ref[i], res[i])


def test_forced_gather_fallback_is_counted(monkeypatch):
    """The REPRO_FORCE_GATHER escape hatch (the benchmark baseline) must
    land on the counted gather path even under use_pallas()."""
    from repro.core.array import PositArray
    from repro.serving import paged_kv

    rng = np.random.default_rng(12)
    B, n_kv, G, D, page, W, Sq = 2, 2, 2, 16, 4, 4, 5
    kp, vp = _pool(rng, B, n_kv, page, W, D, P16_2)
    cache = {"k_pages": PositArray(kp, P16_2),
             "v_pages": PositArray(vp, P16_2),
             "page_table": _sequential_table(B, W),
             "seq_lens": jnp.asarray([6, 15], jnp.int32),
             "num_new": jnp.full((B,), Sq, jnp.int32)}
    q = jnp.asarray(rng.normal(size=(B, n_kv * G, Sq, D)), jnp.float32)

    _pallas_interpret_env(monkeypatch)
    monkeypatch.setenv("REPRO_FORCE_GATHER", "1")
    before = paged_kv.GATHER_FALLBACKS["forced"]
    paged_kv.paged_attention(q, cache, n_kv=n_kv)
    assert paged_kv.GATHER_FALLBACKS["forced"] == before + 1


# --------------------------------------------------------------------------
# unembedding through pw_gemm (transpose_b)
# --------------------------------------------------------------------------
def test_pw_gemm_transpose_b_matches_ref_and_pretransposed():
    from repro.kernels import posit_gemm as KG
    from repro.kernels import ref as KR

    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)
    w = f32_to_posit(jnp.asarray(rng.normal(size=(48, 32))), P16_2)  # [n, k]

    got = KG.pw_gemm(x, w, P16_2, bm=8, bn=128, bk=32, transpose_b=True,
                     interpret=True)
    ref = KR.posit_gemm_ref(x, w, cfg_a=None, cfg_b=P16_2, transpose_b=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), **TOL)
    plain = KG.pw_gemm(x, jnp.transpose(w), P16_2, bm=8, bn=128, bk=32,
                       interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(plain), **TOL)


def test_unembed_posit_table_bit_identical_to_dense_einsum():
    """The pw_gemm unembedding (jnp ref path here) must reproduce the old
    decode-whole-table einsum bit for bit — same dot_general contraction,
    no full-table f32 materialization on the kernel path."""
    import repro.pnp as pnp
    from repro.core.decode import decode_to_f32
    from repro.models.blocks import unembed
    from repro.quant.policy import NONE

    rng = np.random.default_rng(14)
    V, d = 40, 32
    table = pnp.asarray(rng.normal(size=(V, d)).astype(np.float32), P16_2)
    h = jnp.asarray(rng.normal(size=(2, 3, d)), jnp.float32)
    got = unembed(h, {"table": table}, NONE)
    want = jnp.einsum("...d,vd->...v", h,
                      decode_to_f32(table.bits, P16_2),
                      preferred_element_type=jnp.float32)
    assert got.shape == (2, 3, V)
    assert jnp.array_equal(got, want), "unembed logits changed bit pattern"


# --------------------------------------------------------------------------
# training: kernel forward, reference backward
# --------------------------------------------------------------------------
def test_fused_prefill_grads_match_reference(monkeypatch):
    """blockwise_attention's Pallas dispatch must stay differentiable: the
    custom_vjp backward is the jnp scan's VJP, so grads agree with the pure
    reference to f32 accumulation noise."""
    rng = np.random.default_rng(15)
    B, KV, G, Sq, Skv, D = 2, 2, 2, 8, 16, 16
    H = KV * G
    q = jnp.asarray(rng.normal(size=(B, H, Sq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, KV, Skv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, KV, Skv, D)), jnp.float32)

    def loss(q, k, v):
        out = blockwise_attention(q, k, v, n_kv=KV, causal=True,
                                  q_offset=Skv - Sq)
        return (out * out).sum()

    ref = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    _pallas_interpret_env(monkeypatch)
    got = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    for name, a, b in zip("qkv", got, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-5, err_msg=f"d{name} diverged")
