"""Prefix cache subsystem: content-addressed radix index, PagePool
refcount/pinning invariants (hypothesis-driven), warm-vs-cold bit-exactness
across float/p8/p16 pages, copy-on-write, dedup, LRU eviction ordered
before preemption, and DP-sharded warm/cold parity in a subprocess."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import pytest

from repro.core.types import P8_2, P16_2
from repro.models.transformer import ModelConfig, init_params
from repro.quant.policy import PositPolicy
from repro.serving import engine as E
from repro.serving.paged_kv import PagePool
from repro.serving.prefix_cache import RadixIndex, chunk_digest, root_digest


def _cfg(pcfg, **kw):
    return ModelConfig(name="tst-px", n_layers=2, d_model=32, n_heads=4,
                       n_kv=2, d_ff=64, vocab=50,
                       policy=PositPolicy(kv_cache=pcfg), **kw)


def _engine(params, cfg, **kw):
    kw.setdefault("max_seqs", 4)
    kw.setdefault("page_size", 4)
    kw.setdefault("table_width", 8)
    kw.setdefault("prefill_chunk", 8)
    return E.PagedServingEngine(params, cfg, **kw)


# ==========================================================================
# radix index
# ==========================================================================
def test_radix_index_lookup_insert_roundtrip():
    idx = RadixIndex("model-a|p16|page=4", page_size=4)
    toks = np.arange(13, dtype=np.int32)
    n1, _ = idx.insert(idx.root, toks[:4], page=7, clock=1)
    n2, _ = idx.insert(n1, toks[4:8], page=9, clock=1)
    assert idx.probe(toks) == 8            # 3rd page partial: not cached
    pages, node = idx.lookup(toks, clock=2)
    assert pages == [7, 9] and node is n2
    # divergent second page: only the first matches
    other = toks.copy()
    other[5] = 49
    pages, node = idx.lookup(other, clock=3)
    assert pages == [7] and node is n1
    # shorter than a page: nothing to match
    assert idx.probe(toks[:3]) == 0


def test_radix_index_insert_dedups_identical_chunk():
    idx = RadixIndex("k", page_size=4)
    chunk = np.asarray([1, 2, 3, 4], np.int32)
    n1, existing = idx.insert(idx.root, chunk, page=3, clock=0)
    assert existing is None
    n2, existing = idx.insert(idx.root, chunk, page=5, clock=1)
    assert n2 is n1 and existing == 3      # caller adopts page 3, frees 5
    assert len(idx) == 1


def test_radix_index_keyed_per_model_and_format():
    """The digest chain is rooted in the model/format/page key: identical
    token chunks under different keys can never alias."""
    a = root_digest("gemma|p16|page=64")
    b = root_digest("gemma|p8|page=64")
    chunk = np.arange(64, dtype=np.int32)
    assert a != b
    assert chunk_digest(a, chunk) != chunk_digest(b, chunk)
    # chained: same chunk under different parents differs too
    assert (chunk_digest(chunk_digest(a, chunk), chunk)
            != chunk_digest(a, chunk))


def test_radix_index_evicts_lru_leaves_first():
    idx = RadixIndex("k", page_size=2)
    t = np.asarray([1, 2, 3, 4, 5, 6], np.int32)
    n1, _ = idx.insert(idx.root, t[:2], page=1, clock=1)
    n2, _ = idx.insert(n1, t[2:4], page=2, clock=5)
    idx.insert(n2, t[4:6], page=3, clock=3)
    # page 1 is oldest but interior: the LRU *leaf* (page 3) dies first,
    # then page 2, then page 1 — a cached chain never dangles
    assert idx.evict_lru(lambda p: True) == 3
    assert idx.evict_lru(lambda p: True) == 2
    assert idx.evict_lru(lambda p: True) == 1
    assert idx.evict_lru(lambda p: True) is None


def test_radix_index_eviction_respects_live_refs():
    idx = RadixIndex("k", page_size=2)
    n1, _ = idx.insert(idx.root, np.asarray([1, 2], np.int32), 1, clock=0)
    idx.insert(n1, np.asarray([3, 4], np.int32), 2, clock=1)
    # leaf page 2 is live -> nothing evictable (parent is interior)
    assert idx.evict_lru(lambda p: p != 2) is None
    assert idx.evict_lru(lambda p: True) == 2


# ==========================================================================
# PagePool allocator invariants (satellite: hypothesis property tests)
# ==========================================================================
def _check_invariants(pool: PagePool):
    free = pool.free_list
    live = set(pool._ref)
    cached = set(pool._cached)
    assert 0 not in free and 0 not in live and 0 not in cached, \
        "the reserved garbage page entered circulation"
    assert len(set(free)) == len(free), "free stack holds a duplicate"
    assert not (set(free) & (live | cached)), "free page is live/cached"
    assert all(v >= 1 for v in pool._ref.values()), "non-positive refcount"
    assert len(free) + len(live | cached) == pool.num_pages - 1, \
        "pages leaked or double-counted"


def _drive(pool: PagePool, ops):
    """Interpret a random op stream against the pool, asserting invariants
    after every op.  Invalid transitions must raise ValueError (double
    free, negative refcount, garbage-page ops) and change nothing."""
    held = []                  # pages with refs we hold
    cached = []
    for opcode, arg in ops:
        try:
            if opcode == 0:
                pg = pool.try_alloc()
                if pg is not None:
                    assert pg != 0
                    held.append(pg)
            elif opcode == 1 and held:
                pool.incref(held[arg % len(held)])
                held.append(held[arg % len(held)])
            elif opcode == 2 and held:
                pg = held.pop(arg % len(held))
                pool.decref(pg)
            elif opcode == 3 and held:
                pg = held[arg % len(held)]
                pool.cache(pg)
                if pg not in cached:
                    cached.append(pg)
            elif opcode == 4 and cached:
                pool.uncache(cached.pop(arg % len(cached)))
            elif opcode == 5:
                # invalid: decref a page we hold no reference to
                free = pool.free_list
                if free:
                    with pytest.raises(ValueError):
                        pool.decref(free[arg % len(free)])
            elif opcode == 6:
                for bad in (pool.incref, pool.decref, pool.cache,
                            pool.uncache):
                    with pytest.raises(ValueError):
                        bad(0)             # the garbage page never moves
        finally:
            _check_invariants(pool)
    return held, cached


def test_page_pool_random_walk_deterministic():
    """No-hypothesis fallback: a long seeded op stream (CI also runs the
    hypothesis version below)."""
    rng = np.random.default_rng(0)
    pool = PagePool(17)
    ops = [(int(rng.integers(0, 7)), int(rng.integers(0, 1 << 30)))
           for _ in range(2000)]
    held, cached = _drive(pool, ops)
    # drain: refs then pins; everything must return to the free stack
    for pg in held:
        pool.decref(pg)
    for pg in list(pool._cached):
        pool.uncache(pg)
    _check_invariants(pool)
    assert pool.n_free == pool.num_pages - 1


def test_page_pool_alloc_free_roundtrip_preserves_count():
    pool = PagePool(9)
    n0 = pool.n_free
    pages = [pool.try_alloc() for _ in range(n0)]
    assert pool.try_alloc() is None and pool.n_free == 0
    for pg in pages:
        pool.decref(pg)
    assert pool.n_free == n0
    assert sorted(pool.free_list) == sorted(pages)


def test_page_pool_cached_page_survives_decref_until_uncache():
    pool = PagePool(5)
    pg = pool.try_alloc()
    pool.cache(pg)
    pool.decref(pg)
    assert pool.n_free == 3 and pool.n_evictable == 1
    assert pool.is_idle(pg) and pool.is_cached(pg)
    pool.incref(pg)                        # prefix hit revives it
    assert pool.ref_count(pg) == 1 and pool.n_evictable == 0
    pool.decref(pg)
    assert pool.uncache(pg) is True        # eviction frees it
    assert pool.n_free == 4
    with pytest.raises(ValueError):
        pool.decref(pg)                    # double free


try:
    import hypothesis
    from hypothesis import given, settings, strategies as st

    @given(ops=st.lists(st.tuples(st.integers(0, 6),
                                  st.integers(0, 1 << 30)),
                        max_size=300))
    @settings(max_examples=100, deadline=None)
    def test_page_pool_invariants_hypothesis(ops):
        _drive(PagePool(11), ops)
except ImportError:                         # pragma: no cover
    pass                                    # deterministic walk still runs


# ==========================================================================
# warm vs cold engine bit-exactness
# ==========================================================================
def _shared_prefix_reqs(vocab, n_req=4, prefix_len=8, suffix_len=4,
                        max_new=6, seed=0):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab, prefix_len).astype(np.int32)
    return [(np.concatenate([prefix,
                             rng.integers(0, vocab,
                                          suffix_len).astype(np.int32)]),
             max_new) for _ in range(n_req)]


@pytest.mark.parametrize("pcfg", [None, P16_2, P8_2],
                         ids=["float", "p16", "p8"])
def test_warm_vs_cold_bit_identical(pcfg):
    """Greedy tokens from cache-hit (warm) prefill must equal the cold
    engine's bit for bit: shared pages hold exactly the bits a cold
    prefill would recompute, and prefill restarts at the first uncached
    token with q_offset handled in-kernel."""
    cfg = _cfg(pcfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    reqs = _shared_prefix_reqs(cfg.vocab)
    cold = _engine(params, cfg, prefix_cache=False)
    res_cold = cold.run([(p.copy(), n) for p, n in reqs])
    assert cold.stats()["prefix_hit_tokens"] == 0

    eng = _engine(params, cfg)
    res1 = eng.run([(p.copy(), n) for p, n in reqs])
    for r in res_cold:
        assert np.array_equal(res1[r], res_cold[r]), ("first drain", r)

    res2 = eng.run([(p.copy(), n) for p, n in reqs])     # warm
    st = eng.stats()
    assert st["prefix_hits"] >= len(reqs), st
    assert st["prefix_hit_tokens"] > 0
    for k in range(len(reqs)):
        assert np.array_equal(res2[k + len(reqs)], res_cold[k]), \
            ("warm drain", k)


def test_disjoint_prompts_no_false_sharing():
    """Requests sharing no page-aligned prefix must never hit the cache
    (the digest chain covers the whole prefix, so equal later chunks with
    different openings cannot alias)."""
    cfg = _cfg(P16_2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = _engine(params, cfg)
    # same tail chunk, different first token: chained digests diverge
    base = np.arange(12, dtype=np.int32) % cfg.vocab
    other = base.copy()
    other[0] = (base[0] + 1) % cfg.vocab
    eng.run([(base, 4)])
    eng.run([(other, 4)])
    st = eng.stats()
    assert st["prefix_hits"] == 0 and st["prefix_hit_tokens"] == 0
    assert st["deduped_pages"] == 0


def test_fully_cached_aligned_prompt_cow():
    """A page-aligned fully cached prompt keeps every shared page and
    re-feeds only the final token; its mid-page write must copy-on-write,
    leaving the shared page intact for a third identical request."""
    cfg = _cfg(P16_2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = np.arange(8, dtype=np.int32)       # exactly 2 pages of 4
    cold = _engine(params, cfg, prefix_cache=False)
    ref = cold.run([(prompt.copy(), 5)])[0]

    eng = _engine(params, cfg)
    r0 = eng.run([(prompt.copy(), 5)])[0]
    r1 = eng.run([(prompt.copy(), 5)])[1]
    r2 = eng.run([(prompt.copy(), 5)])[2]
    st = eng.stats()
    assert st["cow_copies"] >= 2, st
    assert st["prefix_hit_tokens"] >= 2 * (len(prompt) - 1)
    for r in (r0, r1, r2):
        assert np.array_equal(r, ref)


def test_concurrent_identical_prompts_dedup_to_shared_pages():
    """Two identical prompts admitted cold in the same batch prefill
    privately but converge on one copy at registration (adoption frees
    the duplicate — contents are bit-identical by construction)."""
    cfg = _cfg(P16_2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = np.arange(12, dtype=np.int32)
    cold = _engine(params, cfg, prefix_cache=False)
    ref = cold.run([(prompt.copy(), 4), (prompt.copy(), 4)])

    eng = _engine(params, cfg)
    res = eng.run([(prompt.copy(), 4), (prompt.copy(), 4)])
    st = eng.stats()
    assert st["deduped_pages"] >= 2, st
    for r in ref:
        assert np.array_equal(res[r], ref[r]), r
    # pages either free or cached afterwards; dedup means strictly fewer
    # resident pages than two private copies would hold
    assert len(eng.free_pages) + eng.cached_pages == eng.num_pages - 1


def test_eviction_frees_pages_before_preemption():
    """Satellite regression: when idle cached prefix pages can cover a
    new allocation, they are LRU-evicted and NO live sequence is
    preempted (the old engine's only pressure valve)."""
    cfg = _cfg(P16_2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    pa = rng.integers(0, cfg.vocab, 8).astype(np.int32)    # 2 full pages
    pc = rng.integers(0, cfg.vocab, 8).astype(np.int32)
    pb = rng.integers(0, cfg.vocab, 12).astype(np.int32)
    cold = _engine(params, cfg, prefix_cache=False, max_seqs=2,
                   num_pages=11, admit_threshold=0)
    ref = cold.run([(pa.copy(), 4), (pc.copy(), 16), (pb.copy(), 4)])
    assert cold.counters["preempted"] == 0   # workload fits without cache

    # 10 usable pages: A (2 cached after retiring) + C live (6 at peak) +
    # B (4) only fit if A's cached pages are evicted, not by preempting C
    eng = _engine(params, cfg, max_seqs=2, num_pages=11, admit_threshold=0)
    res = eng.run([(pa.copy(), 4), (pc.copy(), 16), (pb.copy(), 4)])
    st = eng.stats()
    assert st["preempted"] == 0, st
    assert st["evicted_pages"] >= 1, st
    for r in ref:
        assert np.array_equal(res[r], ref[r]), r


def test_preempted_request_resumes_through_cache():
    """Preemption still works under the cache and the resumed request's
    outputs stay bit-identical to the dense oracle (its cached prompt
    pages may or may not survive eviction in between)."""
    cfg = _cfg(P16_2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(2), (3, 10), 0,
                                 cfg.vocab)
    dense = np.asarray(E.generate(params, cfg, prompts, 12, max_len=32))
    eng = _engine(params, cfg, max_seqs=3, num_pages=10, prefill_chunk=16)
    res = eng.run([(np.asarray(prompts[i]), 12) for i in range(3)])
    assert eng.counters["preempted"] >= 1
    for i in range(3):
        assert np.array_equal(res[i], dense[i]), i


# ==========================================================================
# knobs, alignment, observability
# ==========================================================================
def test_prefill_chunk_aligns_to_page_size():
    cfg = _cfg(P16_2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    assert _engine(params, cfg, page_size=4, prefill_chunk=6).chunk == 4
    assert _engine(params, cfg, page_size=4, prefill_chunk=9).chunk == 8
    assert _engine(params, cfg, page_size=4, prefill_chunk=2).chunk == 4
    assert _engine(params, cfg, page_size=4, prefill_chunk=8).chunk == 8


def test_misaligned_chunk_request_still_matches_cold():
    """A prefill_chunk that is not a page multiple is aligned down, and
    warm runs over multi-chunk prompts stay bit-identical."""
    cfg = _cfg(P16_2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    reqs = _shared_prefix_reqs(cfg.vocab, prefix_len=12, suffix_len=5,
                               seed=4)
    cold = _engine(params, cfg, prefix_cache=False, prefill_chunk=7,
                   table_width=8)
    ref = cold.run([(p.copy(), n) for p, n in reqs])
    eng = _engine(params, cfg, prefill_chunk=7, table_width=8)
    eng.run([(p.copy(), n) for p, n in reqs])
    res = eng.run([(p.copy(), n) for p, n in reqs])
    assert eng.stats()["prefix_hit_tokens"] > 0
    for k in range(len(reqs)):
        assert np.array_equal(res[k + len(reqs)], ref[k]), k


def test_stats_surface_and_reset():
    cfg = _cfg(P16_2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = _engine(params, cfg)
    eng.run([(np.arange(6, dtype=np.int32), 3)])
    st = eng.stats()
    for key in ("admitted", "finished", "preempted", "prefix_hits",
                "prefix_misses", "prefix_hit_tokens", "evicted_pages",
                "cow_copies", "deduped_pages", "gather_fallbacks",
                "dense_moe_fallbacks", "free_pages", "cached_pages"):
        assert key in st, key
    assert st["admitted"] == 1 and st["finished"] == 1
    eng.reset_stats()
    st = eng.stats()
    assert st["admitted"] == 0 and st["gather_fallbacks"] == 0


def test_prefix_cache_off_keeps_legacy_behavior():
    cfg = _cfg(P16_2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = _engine(params, cfg, prefix_cache=False)
    prompt = np.arange(8, dtype=np.int32)
    eng.run([(prompt.copy(), 4)])
    eng.run([(prompt.copy(), 4)])
    st = eng.stats()
    assert st["prefix_hits"] == 0 and st["cached_pages"] == 0
    assert st["cow_copies"] == 0 and st["deduped_pages"] == 0
    assert len(eng.free_pages) == eng.num_pages - 1


# ==========================================================================
# the acceptance row: 4-device DP warm/cold parity, subprocess
# ==========================================================================
_SUBPROCESS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np, jax
    from repro.core.types import P16_2
    from repro.models.transformer import ModelConfig, init_params
    from repro.quant.policy import PositPolicy
    from repro.serving import engine as E
    from repro.launch.mesh import make_serving_mesh

    cfg = ModelConfig(name="tst-px4", n_layers=2, d_model=32, n_heads=4,
                      n_kv=2, d_ff=64, vocab=50,
                      policy=PositPolicy(kv_cache=P16_2))
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(11)
    prefix = rng.integers(0, cfg.vocab, 8).astype(np.int32)
    reqs = [(np.concatenate([prefix,
                             rng.integers(0, cfg.vocab, 4).astype(np.int32)]),
             6) for _ in range(8)]

    ref = E.PagedServingEngine(params, cfg, max_seqs=8, page_size=4,
                               table_width=8, prefill_chunk=8,
                               prefix_cache=False)
    res_ref = ref.run([(p.copy(), n) for p, n in reqs])

    mesh = make_serving_mesh(4, 1)
    eng = E.PagedServingEngine(params, cfg, max_seqs=8, page_size=4,
                               table_width=8, prefill_chunk=8, mesh=mesh)
    cold = eng.run([(p.copy(), n) for p, n in reqs])
    for r in res_ref:
        assert np.array_equal(cold[r], res_ref[r]), ("cold", r)

    warm = eng.run([(p.copy(), n) for p, n in reqs])
    st = eng.stats()
    assert st["prefix_hit_tokens"] > 0, st
    for k in range(len(reqs)):
        assert np.array_equal(warm[k + len(reqs)], res_ref[k]), ("warm", k)

    # shard-local dedup: every table entry stays inside its shard's
    # sub-pool, so DP admission/paging is bitwise shard-independent
    for i, slot in enumerate(eng.slots):
        assert slot is None
    print("PREFIX-DP-OK")
""")


def test_prefix_cache_dp_sharded_warm_cold_bit_exact_4dev():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "PREFIX-DP-OK" in out.stdout
