"""Recurrent & hybrid serving through the pluggable cache backends.

The paged engine serves RWKV6 (pure state-pool), and a Griffin-style
hybrid (rglru state slots + windowed paged KV), with greedy tokens
bit-identical to the dense generate() oracle across float/p8/p16 state
formats — on the counted jnp oracle path, on the Pallas kernel path
(interpret mode, zero recurrent fallbacks asserted), and on a 4-device
data-parallel mesh (subprocess).  The sliding-window reclamation test pins
that a long windowed decode holds O(window) pages, not O(context).
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke
from repro.core.types import P8_2, P16_2
from repro.models.transformer import init_params
from repro.quant.policy import PositPolicy
from repro.serving.engine import PagedServingEngine, generate

FORMATS = [("float", None), ("p8", P8_2), ("p16", P16_2)]


def _cfg(arch: str, pcfg, tag: str):
    cfg = get_smoke(arch)
    name = f"{cfg.name}-{tag}"
    if pcfg is None:
        return dataclasses.replace(cfg, name=name)
    return dataclasses.replace(cfg, name=name,
                               policy=PositPolicy(kv_cache=pcfg))


def _drain_vs_dense(cfg, *, max_new=5, n_req=3, seed=0, **eng_kwargs):
    """Engine drain vs per-request dense generate(); asserts bit-identical
    greedy tokens.  Returns the engine (for stats assertions)."""
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(1, cfg.vocab, size=int(L)).astype(np.int32)
               for L in rng.integers(4, 18, size=n_req)]
    kw = dict(max_seqs=4, page_size=8, table_width=8, prefill_chunk=8)
    kw.update(eng_kwargs)
    eng = PagedServingEngine(params, cfg, **kw)
    rids = [eng.submit(p, max_new) for p in prompts]
    out = eng.run()
    for rid, p in zip(rids, prompts):
        dense = np.asarray(
            generate(params, cfg, jnp.asarray(p)[None], max_new))[0]
        np.testing.assert_array_equal(out[rid], dense)
    return eng


@pytest.mark.parametrize("fmt,pcfg", FORMATS)
def test_rwkv6_engine_matches_dense(fmt, pcfg):
    cfg = _cfg("rwkv6-3b", pcfg, fmt)
    eng = _drain_vs_dense(cfg)
    st = eng.stats()
    assert st["state_slot_allocs"] == 3
    # pure-recurrent layout: the prefix cache must have auto-disabled
    # (state slots are not content-addressable) and no KV paging ran
    assert eng._prefix is None
    assert st["prefix_hits"] == st["prefix_misses"] == 0


@pytest.mark.parametrize("fmt,pcfg", FORMATS)
def test_griffin_hybrid_engine_matches_dense(fmt, pcfg):
    cfg = _cfg("recurrentgemma-9b", pcfg, fmt)
    eng = _drain_vs_dense(cfg, seed=1)
    st = eng.stats()
    assert st["state_slot_allocs"] == 3
    assert eng._prefix is None          # hybrid contains state layers


def test_kernel_path_bit_parity_zero_fallbacks(monkeypatch):
    """The Pallas fused recurrent-scan route (interpret mode): engine and
    dense drains are bit-identical and never fall back to the jnp oracle.
    Distinct cfg names from the oracle-path tests: the jitted steps cache
    per config, and the two environments trace different kernels."""
    from repro.kernels.ops import RECURRENT_FALLBACKS
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    monkeypatch.delenv("REPRO_FORCE_GATHER", raising=False)
    for arch in ("rwkv6-3b", "recurrentgemma-9b"):
        cfg = _cfg(arch, P16_2, "kernel-p16")
        before = dict(RECURRENT_FALLBACKS)
        eng = _drain_vs_dense(cfg, n_req=2)
        assert dict(RECURRENT_FALLBACKS) == before, arch
        assert eng.stats()["recurrent_fallbacks"] == 0


def test_windowed_decode_holds_o_window_pages():
    """Sliding-window reclamation: a 126-token decode against window=32,
    page=8 completes inside a 7-usable-page pool (O(window), not the 16
    pages O(context) would need), frees expired pages, never preempts, and
    stays bit-identical to dense."""
    cfg = dataclasses.replace(get_smoke("recurrentgemma-9b"),
                              name="rg-smoke-reclaim")
    params = init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(2)
    prompt = rng.integers(1, cfg.vocab, size=6).astype(np.int32)
    max_new = 120
    eng = PagedServingEngine(params, cfg, max_seqs=2, page_size=8,
                             table_width=32, num_pages=8, prefill_chunk=8,
                             prefix_cache=False)
    assert eng._reclaim_window == cfg.window
    rid = eng.submit(prompt, max_new)
    out = eng.run()
    st = eng.stats()
    assert st["expired_page_frees"] > 0
    assert st["preempted"] == 0
    dense = np.asarray(
        generate(params, cfg, jnp.asarray(prompt)[None], max_new))[0]
    np.testing.assert_array_equal(out[rid], dense)
    # every slot freed at retirement despite the zero placeholders
    assert st["free_pages"] == eng.pages_per_shard - 1


def test_reclamation_gated_off_with_prefix_cache_or_full_attn():
    """Reclamation requires *every* attention layer windowed and the
    prefix cache off — a full-attn layer still reads expired pages and a
    cached page must stay resident for future prefix hits."""
    cfg = dataclasses.replace(get_smoke("recurrentgemma-9b"),
                              name="rg-smoke-noreclaim")
    params = init_params(jax.random.PRNGKey(1), cfg)
    # hybrid contains state layers -> prefix_cache auto-disables, so the
    # prefix gate is exercised on a pure-attn_local config instead
    attn_cfg = dataclasses.replace(cfg, block_pattern=("attn_local",),
                                   name="attn-local-prefix")
    attn_params = init_params(jax.random.PRNGKey(1), attn_cfg)
    eng = PagedServingEngine(attn_params, attn_cfg, max_seqs=2, page_size=8,
                             table_width=8, prefill_chunk=8,
                             prefix_cache=True)
    assert eng._prefix is not None and eng._reclaim_window is None
    full = dataclasses.replace(cfg, block_pattern=("rglru", "rglru", "attn"),
                               name="rg-smoke-fullattn")
    eng2 = PagedServingEngine(init_params(jax.random.PRNGKey(1), full), full,
                              max_seqs=2, page_size=8, table_width=8,
                              prefill_chunk=8, prefix_cache=False)
    assert eng2._reclaim_window is None


def test_pure_recurrent_ignores_page_capacity():
    """State-pool sequences are O(1): a request far beyond
    table_width*page_size must be accepted and served."""
    cfg = _cfg("rwkv6-3b", None, "longreq")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    prompt = rng.integers(1, cfg.vocab, size=40).astype(np.int32)
    eng = PagedServingEngine(params, cfg, max_seqs=2, page_size=8,
                             table_width=2, prefill_chunk=8)
    rid = eng.submit(prompt, 4)     # 44 tokens >> 2*8 page capacity
    out = eng.run()
    dense = np.asarray(generate(params, cfg, jnp.asarray(prompt)[None], 4))[0]
    np.testing.assert_array_equal(out[rid], dense)


# ---- the acceptance row: 4-device DP mesh, subprocess --------------------
_SUBPROCESS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses
    import numpy as np, jax, jax.numpy as jnp
    from repro.configs import get_smoke
    from repro.core.types import P16_2
    from repro.models.transformer import init_params
    from repro.quant.policy import PositPolicy
    from repro.serving.engine import PagedServingEngine, generate
    from repro.launch.mesh import make_serving_mesh

    mesh = make_serving_mesh(4, 1)
    for arch in ("rwkv6-3b", "recurrentgemma-9b"):
        cfg = dataclasses.replace(get_smoke(arch),
                                  policy=PositPolicy(kv_cache=P16_2),
                                  name=f"{arch}-dp4-p16")
        params = init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(0)
        prompts = [rng.integers(1, cfg.vocab, size=L).astype(np.int32)
                   for L in (5, 9, 13, 7)]
        eng = PagedServingEngine(params, cfg, max_seqs=4, page_size=8,
                                 table_width=8, prefill_chunk=8, mesh=mesh)
        rids = [eng.submit(p, 5) for p in prompts]
        out = eng.run()
        for rid, p in zip(rids, prompts):
            dense = np.asarray(
                generate(params, cfg, jnp.asarray(p)[None], 5))[0]
            assert np.array_equal(out[rid], dense), (arch, rid)

    # TP over recurrent layers is rejected, not silently mis-sharded
    cfg = dataclasses.replace(get_smoke("rwkv6-3b"), name="rwkv6-tp-reject")
    params = init_params(jax.random.PRNGKey(0), cfg)
    try:
        PagedServingEngine(params, cfg, max_seqs=4, page_size=8,
                           table_width=8, mesh=make_serving_mesh(2, 2))
    except ValueError as e:
        assert "data-parallel only" in str(e)
    else:
        raise AssertionError("ntp=2 accepted for a recurrent pattern")
    print("RECURRENT-DP4-OK")
""")


def test_recurrent_dp4_bit_parity_subprocess():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "RECURRENT-DP4-OK" in out.stdout
