"""Paged KV cache + continuous-batching engine: correctness vs the dense
oracle (bit-exact logits), eviction/slot-reuse, paged flash-decode parity,
chunked prefill, and the zero-retrace guarantees."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.types import P8_2, P16_2
from repro.models.transformer import (ModelConfig, assemble_paged_caches,
                                      extract_paged_pages, forward,
                                      init_caches, init_params,
                                      init_paged_pages)
from repro.quant.policy import PositPolicy
from repro.serving import engine as E
from repro.serving.kv_cache import append_kv, init_cache, materialize_kv
from repro.serving.paged_kv import gather_kv, paged_append_kv


def _cfg(pcfg, **kw):
    return ModelConfig(name="tst", n_layers=2, d_model=32, n_heads=4,
                       n_kv=2, d_ff=64, vocab=50,
                       policy=PositPolicy(kv_cache=pcfg), **kw)


def _sequential_table(B, W):
    pt = np.zeros((B, W), np.int32)
    pt[:] = 1 + np.arange(B * W).reshape(B, W)
    return jnp.asarray(pt)


@pytest.mark.parametrize("pcfg", [None, P16_2, P8_2],
                         ids=["float", "p16", "p8"])
def test_paged_vs_dense_logits_bit_exact(pcfg):
    """Same batch through the dense cache and the paged pool: prefill and
    decode logits must agree bit for bit (same ops, same element order —
    the gathered page view is position-identical to the dense buffer)."""
    cfg = _cfg(pcfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S, page, W = 2, 6, 4, 8
    max_len = page * W
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)

    dense = init_caches(cfg, B, max_len)
    ld, _, dense = forward(params, cfg, tokens=toks, caches=dense)

    pages = init_paged_pages(cfg, num_pages=1 + B * W, page_size=page)
    pt = _sequential_table(B, W)
    caches = assemble_paged_caches(pages, pt, jnp.zeros((B,), jnp.int32),
                                   jnp.full((B,), S, jnp.int32))
    lp, _, caches = forward(params, cfg, tokens=toks, caches=caches)
    pages = extract_paged_pages(caches)
    assert jnp.array_equal(ld, lp), "prefill logits diverge"

    tok = jnp.argmax(ld[:, -1], -1)[:, None].astype(jnp.int32)
    ld2, _, dense = forward(params, cfg, tokens=tok, caches=dense)
    caches = assemble_paged_caches(pages, pt, jnp.full((B,), S, jnp.int32),
                                   jnp.ones((B,), jnp.int32))
    lp2, _, _ = forward(params, cfg, tokens=tok, caches=caches)
    assert jnp.array_equal(ld2, lp2), "decode logits diverge"


@pytest.mark.parametrize("pcfg", [None, P16_2, P8_2],
                         ids=["float", "p16", "p8"])
def test_paged_flash_decode_vs_materialized_dense_attention(pcfg):
    """The Pallas paged-gather decode kernel (interpret mode) vs the
    materialize_kv + dense flash-attention oracle at mixed lengths."""
    from repro.core.convert import f32_to_posit
    from repro.kernels.flash_attention import paged_flash_decode
    from repro.kernels.ref import flash_attention_ref

    rng = np.random.default_rng(3)
    B, n_kv, G, D, page, W = 3, 2, 2, 16, 8, 4
    H = n_kv * G
    seq_lens = np.asarray([5, 17, 32], np.int32)
    pt = np.asarray(_sequential_table(B, W))
    kd = rng.normal(size=(1 + B * W, n_kv, page, D)).astype(np.float32)
    vd = rng.normal(size=(1 + B * W, n_kv, page, D)).astype(np.float32)
    q = jnp.asarray(rng.normal(size=(B, H, D)).astype(np.float32))
    if pcfg is not None:
        kp = f32_to_posit(jnp.asarray(kd), pcfg)
        vp = f32_to_posit(jnp.asarray(vd), pcfg)
    else:
        kp, vp = jnp.asarray(kd), jnp.asarray(vd)

    out = paged_flash_decode(q, kp, vp, jnp.asarray(pt),
                             jnp.asarray(seq_lens), cfg_kv=pcfg,
                             interpret=True)
    for i in range(B):
        # materialize this sequence's pages densely, run the ref oracle
        kk = np.concatenate([np.asarray(kp)[pt[i, j]] for j in range(W)],
                            axis=1)[:, :seq_lens[i]]
        vv = np.concatenate([np.asarray(vp)[pt[i, j]] for j in range(W)],
                            axis=1)[:, :seq_lens[i]]
        qq = np.asarray(q[i]).reshape(n_kv, G, D)
        for h in range(n_kv):
            ref = flash_attention_ref(jnp.asarray(qq[h][None]),
                                      jnp.asarray(kk[h][None]),
                                      jnp.asarray(vv[h][None]),
                                      cfg_kv=pcfg, causal=False)
            got = np.asarray(out[i]).reshape(n_kv, G, D)[h]
            np.testing.assert_allclose(got, np.asarray(ref[0]), rtol=2e-6,
                                       atol=2e-6)


@pytest.mark.parametrize("pcfg", [None, P16_2], ids=["float", "p16"])
@pytest.mark.parametrize("window", [4, 16])
def test_paged_flash_decode_window_matches_gathered_reference(pcfg, window):
    """Windowed (local-attention) decode used to fall off the paged kernel
    onto the dense gather_kv path; the kernel now masks the window itself
    and must match the gathered blockwise reference at mixed lengths."""
    from repro.core.convert import f32_to_posit
    from repro.kernels.flash_attention import paged_flash_decode
    from repro.models.blocks import blockwise_attention

    rng = np.random.default_rng(11)
    B, n_kv, G, D, page, W = 3, 2, 2, 16, 8, 4
    H = n_kv * G
    seq_lens = jnp.asarray([3, 17, 32], jnp.int32)
    pt = _sequential_table(B, W)
    kd = jnp.asarray(rng.normal(size=(1 + B * W, n_kv, page, D)), jnp.float32)
    vd = jnp.asarray(rng.normal(size=(1 + B * W, n_kv, page, D)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, H, D)), jnp.float32)
    kp = f32_to_posit(kd, pcfg) if pcfg is not None else kd
    vp = f32_to_posit(vd, pcfg) if pcfg is not None else vd

    out = paged_flash_decode(q, kp, vp, pt, seq_lens, cfg_kv=pcfg,
                             window=window, interpret=True)

    if pcfg is not None:
        from repro.core.array import PositArray
        cache = {"k_pages": PositArray(kp, pcfg),
                 "v_pages": PositArray(vp, pcfg), "page_table": pt}
    else:
        cache = {"k_pages": kp, "v_pages": vp, "page_table": pt}
    k, v = gather_kv(cache)
    ref = blockwise_attention(q[:, :, None, :], k, v, n_kv=n_kv, causal=True,
                              q_offset=seq_lens - 1, window=window,
                              kv_len=seq_lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref[:, :, 0, :]),
                               rtol=2e-6, atol=2e-6)


def test_dense_steps_donate_cache_buffers():
    """_dense_steps used to jit without donate_argnums, holding two full KV
    caches live per step; the decode step must now alias the new cache onto
    the donated input buffers (and invalidate the donated array)."""
    params, cfg, prompts = _engine_model()
    pf, dc = E._dense_steps(cfg)
    caches = init_caches(cfg, 4, 16, dtype=jnp.dtype(cfg.dtype))
    logits, caches = pf(params, prompts, caches)

    def kbuf(c):
        k = c["scanned"][0]["k"]
        return k.bits if hasattr(k, "bits") else k

    kbuf(caches).block_until_ready()
    ptr = kbuf(caches).unsafe_buffer_pointer()
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    donated = caches
    logits, caches = dc(params, tok, caches)
    kbuf(caches).block_until_ready()
    assert kbuf(caches).unsafe_buffer_pointer() == ptr, \
        "decode step did not reuse the donated KV buffer"
    with pytest.raises(RuntimeError):
        np.asarray(kbuf(donated))            # donated input is dead


def test_paged_append_drops_masked_writes_out_of_bounds():
    """Masked scatter rows must vanish, not wrap into the last page (the
    -1-index clobber this PR fixed)."""
    cfg = _cfg(P16_2)
    pages = init_paged_pages(cfg, num_pages=4, page_size=4)
    layer = pages["scanned"][0]     # stacked [reps=2, ...]
    one = jax.tree_util.tree_map(lambda x: x[0], layer)
    B, W = 2, 1
    pt = jnp.asarray([[3], [2]], jnp.int32)   # last page owned by seq 0
    cache = {"k_pages": one["k_pages"], "v_pages": one["v_pages"],
             "page_table": pt, "seq_lens": jnp.zeros((B,), jnp.int32),
             "num_new": jnp.asarray([2, 0], jnp.int32)}   # seq 1 inactive
    k = jnp.ones((B, cfg.n_kv, 2, cfg.hd), jnp.float32)
    new = paged_append_kv(cache, k, 2.0 * k)
    # seq 1 wrote nothing anywhere: pages 1, 2 and the garbage page stay 0
    bits = new["k_pages"].bits
    assert (bits[2] == 0).all() and (bits[1] == 0).all()
    assert (bits[3][:, :2] != 0).any()        # seq 0's write landed
    assert int(new["seq_lens"][1]) == 0


@pytest.mark.parametrize("pcfg", [None, P16_2], ids=["float", "p16"])
def test_dense_chunked_prefill_no_clobber(pcfg):
    """append_kv's prefill-sized fast path used to write at static offset
    0, clobbering earlier tokens when a chunked prefill hit a part-full
    cache (appends are one masked-write path now)."""
    rng = np.random.default_rng(0)
    cache = init_cache(2, 2, 16, 8, pcfg)
    k = jnp.asarray(rng.normal(size=(2, 2, 12, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 2, 12, 8)), jnp.float32)
    whole = append_kv(cache, k, v)
    # prefill-sized chunks (6*4 >= 16) into a part-full cache
    chunked = append_kv(cache, k[:, :, :6], v[:, :, :6])
    chunked = append_kv(chunked, k[:, :, 6:], v[:, :, 6:])
    k1, v1 = materialize_kv(whole)
    k2, v2 = materialize_kv(chunked)
    assert int(chunked["length"]) == 12
    assert jnp.array_equal(k1, k2) and jnp.array_equal(v1, v2)


def _engine_model():
    cfg = _cfg(P16_2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 6), 0, cfg.vocab)
    return params, cfg, prompts


def test_engine_matches_dense_generate():
    params, cfg, prompts = _engine_model()
    max_new = 8
    dense = np.asarray(E.generate(params, cfg, prompts, max_new, max_len=32))
    eng = E.PagedServingEngine(params, cfg, max_seqs=4, page_size=4,
                               table_width=8, prefill_chunk=8)
    res = eng.run([(np.asarray(prompts[i]), max_new) for i in range(4)])
    for i in range(4):
        assert np.array_equal(res[i], dense[i]), i


def test_engine_slot_reuse_more_requests_than_slots():
    params, cfg, prompts = _engine_model()
    max_new = 8
    dense = np.asarray(E.generate(params, cfg, prompts, max_new, max_len=32))
    eng = E.PagedServingEngine(params, cfg, max_seqs=2, page_size=4,
                               table_width=8, prefill_chunk=8)
    res = eng.run([(np.asarray(prompts[i % 4]), max_new) for i in range(6)])
    assert sorted(res) == list(range(6))
    assert eng.counters["finished"] == 6 and eng.active == 0
    # every page is either back on the free stack or resident in the
    # prefix cache (idle, evictable) — none leaked, none doubly owned
    assert len(eng.free_pages) + eng.cached_pages == eng.num_pages - 1
    assert eng.stats()["prefix_hits"] >= 2   # repeated prompts hit warm
    for i in range(6):
        assert np.array_equal(res[i], dense[i % 4]), i


def test_engine_eviction_preserves_outputs():
    """A pool too small for the full workload forces preemption; evicted
    requests must resume (prompt + generated so far) and still produce the
    dense engine's exact tokens."""
    params, cfg, _ = _engine_model()
    prompts = jax.random.randint(jax.random.PRNGKey(2), (3, 10), 0,
                                 cfg.vocab)
    dense = np.asarray(E.generate(params, cfg, prompts, 12, max_len=32))
    eng = E.PagedServingEngine(params, cfg, max_seqs=3, page_size=4,
                               table_width=8, num_pages=10, prefill_chunk=16)
    res = eng.run([(np.asarray(prompts[i]), 12) for i in range(3)])
    assert eng.counters["preempted"] >= 1, \
        "workload did not exercise preemption"
    for i in range(3):
        assert np.array_equal(res[i], dense[i]), i


def test_generate_zero_retrace_across_calls():
    """generate() used to rebuild its jit wrappers per call; the hoisted
    steps must not retrace for repeated calls (same shapes, different
    max_new)."""
    params, cfg, prompts = _engine_model()
    E.generate(params, cfg, prompts, 3, max_len=24)
    before = dict(E.STEP_TRACES)
    E.generate(params, cfg, prompts, 6, max_len=24)    # longer decode loop
    E.generate(params, cfg, prompts, 4, max_len=24)
    after = dict(E.STEP_TRACES)
    assert after == before, (before, after)


def test_paged_engine_zero_retrace_steady_state():
    params, cfg, prompts = _engine_model()
    eng = E.PagedServingEngine(params, cfg, max_seqs=4, page_size=4,
                               table_width=8, prefill_chunk=8)
    eng.run([(np.asarray(prompts[i]), 4) for i in range(4)])
    before = dict(E.STEP_TRACES)
    # same engine, new traffic: no new traces at all (finished accumulates
    # across runs, so the second drain reports rids 0..7)
    eng2_res = eng.run([(np.asarray(prompts[i]), 4) for i in range(4)])
    assert sorted(eng2_res) == list(range(8))
    # a fresh engine shares the per-config jitted step: still no retrace
    eng3 = E.PagedServingEngine(params, cfg, max_seqs=4, page_size=4,
                                table_width=8, prefill_chunk=8)
    eng3.run([(np.asarray(prompts[i]), 4) for i in range(4)])
    after = dict(E.STEP_TRACES)
    assert after == before, (before, after)
