"""Mesh-sharded paged serving: TP/DP spec rules, the on-device sampling
contract (a decode step moves O(max_seqs) ints host<->device, never
logits), and sharded-vs-single-device bit-exactness on a forced 8-device
CPU host (subprocess — the parent process stays single-device)."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.types import P16_2
from repro.distributed import sharding as sh
from repro.models.transformer import ModelConfig, init_params
from repro.quant.policy import PositPolicy
from repro.serving import engine as E


def _cfg(**kw):
    return ModelConfig(name="tst-sh", n_layers=2, d_model=32, n_heads=4,
                       n_kv=2, d_ff=64, vocab=50,
                       policy=PositPolicy(kv_cache=P16_2), **kw)


class MockMesh:
    shape = {"data": 4, "model": 2}
    size = 8


# ---- spec rules (no devices needed) --------------------------------------
def test_serving_param_pspecs_megatron_layout():
    cfg = _cfg()
    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    specs = sh.serving_param_pspecs(shapes, MockMesh())
    flat = {sh._path_str(p): s for (p, _), (_, s) in zip(
        jax.tree_util.tree_flatten_with_path(shapes)[0],
        jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, P))[0])}
    wq = next(v for k, v in flat.items() if k.endswith("attn/wq/w"))
    wo = next(v for k, v in flat.items() if k.endswith("attn/wo/w"))
    wd = next(v for k, v in flat.items() if k.endswith("mlp/w_down/w"))
    table = next(v for k, v in flat.items() if k.endswith("embed/table"))
    assert wq[-1] == "model" and wo[-2] == "model"       # column / row
    assert wd[-2] == "model"
    assert table[-2] == "model"                          # vocab 50 % 2 == 0
    # serving never FSDPs: nothing may shard over 'data'
    for k, s in flat.items():
        assert "data" not in str(s), (k, s)


def test_serving_param_pspecs_drops_indivisible_vocab():
    cfg = _cfg()

    class M4:
        shape = {"data": 2, "model": 4}

    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    specs = sh.serving_param_pspecs(shapes, M4())
    flat = {sh._path_str(p): s for (p, _), (_, s) in zip(
        jax.tree_util.tree_flatten_with_path(shapes)[0],
        jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, P))[0])}
    table = next(v for k, v in flat.items() if k.endswith("embed/table"))
    assert "model" not in str(table)                     # 50 % 4 != 0


def test_paged_pool_pspecs_pages_over_data_kv_over_model():
    from repro.models.transformer import init_paged_pages
    cfg = _cfg()
    pages = jax.eval_shape(
        lambda: init_paged_pages(cfg, num_pages=8, page_size=4))
    specs = sh.paged_pool_pspecs(pages, MockMesh())
    scanned = specs["scanned"][0]["k_pages"]             # [reps, np, kv, p, d]
    assert scanned == P(None, "data", "model", None, None)


# ---- engine validation ---------------------------------------------------
class _FakeMesh:
    def __init__(self, d, m):
        self.shape = {"data": d, "model": m}


def test_sharded_engine_rejects_indivisible_shapes():
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="max_seqs"):
        E.PagedServingEngine(params, cfg, max_seqs=3, mesh=_FakeMesh(2, 1))
    with pytest.raises(ValueError, match="n_kv"):
        E.PagedServingEngine(params, cfg, max_seqs=8, mesh=_FakeMesh(1, 4))


# ---- on-device sampling contract -----------------------------------------
def test_decode_step_transfers_only_token_ids():
    """The jitted step's outputs are the [max_seqs] int32 sampled tokens,
    the [max_seqs] bool NaR flags, and the (donated, device-resident) page
    pools — no [max_seqs, vocab] logits leaf exists for the host to pull
    (the ISSUE-3 acceptance row; still O(max_seqs) after the ISSUE-9
    on-device NaR detector rode its flags onto the same transfer)."""
    from repro.models.transformer import init_paged_pages
    cfg = _cfg()
    max_seqs, page, W = 4, 4, 8
    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    pages = jax.eval_shape(
        lambda: init_paged_pages(cfg, num_pages=1 + max_seqs * W,
                                 page_size=page))
    step = E._paged_step(cfg, True)
    out = jax.eval_shape(
        step, params,
        jax.ShapeDtypeStruct((max_seqs, 1), jnp.int32), pages,
        jax.ShapeDtypeStruct((max_seqs, W), jnp.int32),
        jax.ShapeDtypeStruct((max_seqs,), jnp.int32),
        jax.ShapeDtypeStruct((max_seqs,), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((max_seqs,), jnp.bool_))
    toks, bad, new_pages = out
    assert toks.shape == (max_seqs,) and toks.dtype == jnp.int32
    assert bad.shape == (max_seqs,) and bad.dtype == jnp.bool_
    for leaf in jax.tree_util.tree_leaves(new_pages):
        assert leaf.ndim >= 4, leaf.shape     # page pools only, no logits


def test_engine_never_samples_on_host(monkeypatch):
    """Greedy decode must not touch the host oracle at all."""
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 6), 0, cfg.vocab)
    eng = E.PagedServingEngine(params, cfg, max_seqs=4, page_size=4,
                               table_width=8, prefill_chunk=8)

    def boom(row):
        raise AssertionError("host sampling reached on the decode path")

    monkeypatch.setattr(eng, "_sample_host", boom)
    res = eng.run([(np.asarray(prompts[i]), 4) for i in range(4)])
    assert sorted(res) == list(range(4))


def test_device_sampling_matches_host_oracle():
    """Greedy tokens from the on-device step equal _sample_host applied to
    independently computed logits (the oracle role the host sampler keeps)."""
    from repro.models.transformer import forward, init_caches
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, cfg.vocab)
    eng = E.PagedServingEngine(params, cfg, max_seqs=2, page_size=4,
                               table_width=8, prefill_chunk=8)
    res = eng.run([(np.asarray(prompts[i]), 1) for i in range(2)])
    caches = init_caches(cfg, 2, 16)
    logits, _, _ = forward(params, cfg, tokens=prompts, caches=caches)
    for i in range(2):
        assert int(res[i][0]) == eng._sample_host(np.asarray(logits[i, -1]))


# ---- 1x1 mesh: the sharded step itself, runnable on one device -----------
def test_sharded_engine_1x1_mesh_matches_unsharded():
    from repro.launch.mesh import make_serving_mesh
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    reqs = [(rng.integers(0, cfg.vocab, int(rng.integers(3, 12))
                          ).astype(np.int32), 6) for _ in range(6)]
    ref = E.PagedServingEngine(params, cfg, max_seqs=4, page_size=4,
                               table_width=8, prefill_chunk=8)
    res_ref = ref.run([(p.copy(), n) for p, n in reqs])
    eng = E.PagedServingEngine(params, cfg, max_seqs=4, page_size=4,
                               table_width=8, prefill_chunk=8,
                               mesh=make_serving_mesh(1, 1))
    res = eng.run([(p.copy(), n) for p, n in reqs])
    for r in res_ref:
        assert np.array_equal(res[r], res_ref[r]), r
    assert any(k[0] == "sharded_paged_step" for k in E.STEP_TRACES)


# ---- the acceptance row: forced 8-device host, subprocess ----------------
_SUBPROCESS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from repro.core.types import P16_2
    from repro.models.transformer import ModelConfig, init_params
    from repro.quant.policy import PositPolicy
    from repro.serving import engine as E
    from repro.launch.mesh import make_serving_mesh

    cfg = ModelConfig(name="tst-sh8", n_layers=2, d_model=32, n_heads=4,
                      n_kv=2, d_ff=64, vocab=50,
                      policy=PositPolicy(kv_cache=P16_2))
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    reqs = [(rng.integers(0, cfg.vocab, int(rng.integers(3, 14))
                          ).astype(np.int32), 8) for _ in range(12)]

    ref = E.PagedServingEngine(params, cfg, max_seqs=8, page_size=4,
                               table_width=8, prefill_chunk=8)
    res_ref = ref.run([(p.copy(), n) for p, n in reqs])

    # pure DP (8, 1): structurally bit-exact (row-independent math per slot)
    # and DP x TP (4, 2): Megatron psums + vocab-parallel embed/unembed
    for shape in [(8, 1), (4, 2)]:
        mesh = make_serving_mesh(*shape)
        eng = E.PagedServingEngine(params, cfg, max_seqs=8, page_size=4,
                                   table_width=8, prefill_chunk=8, mesh=mesh)
        res = eng.run([(p.copy(), n) for p, n in reqs])
        assert sorted(res) == sorted(res_ref), (shape, sorted(res))
        for r in res_ref:
            assert np.array_equal(res[r], res_ref[r]), (shape, r)

        # a decode step returns [max_seqs] int32 token ids, [max_seqs]
        # bool NaR flags and page pools only — no logits-shaped leaf ever
        # crosses to the host
        toks, bad, pages = jax.eval_shape(
            eng._step_fn, params,
            jax.ShapeDtypeStruct((8, 1), jnp.int32), eng.pages,
            jax.ShapeDtypeStruct((8, 8), jnp.int32),
            jax.ShapeDtypeStruct((8,), jnp.int32),
            jax.ShapeDtypeStruct((8,), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32),
            jax.ShapeDtypeStruct((8,), jnp.bool_))
        assert toks.shape == (8,) and toks.dtype == jnp.int32
        assert bad.shape == (8,) and bad.dtype == jnp.bool_
        for leaf in jax.tree_util.tree_leaves(pages):
            assert leaf.ndim >= 4, leaf.shape

    # zero steady-state retrace: a fresh engine on the same mesh reuses the
    # shared jitted step for the whole drain
    before = dict(E.STEP_TRACES)
    mesh = make_serving_mesh(8, 1)
    eng2 = E.PagedServingEngine(params, cfg, max_seqs=8, page_size=4,
                                table_width=8, prefill_chunk=8, mesh=mesh)
    eng2.run([(p.copy(), n) for p, n in reqs])
    assert dict(E.STEP_TRACES) == before, (before, dict(E.STEP_TRACES))
    assert any(k[0] == "sharded_paged_step" for k in E.STEP_TRACES)
    print("SHARDED-OK")
""")


def test_sharded_vs_single_device_bit_exact_8dev():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "SHARDED-OK" in out.stdout
