"""Sharding rules: divisibility guards, strategy selection, spec shapes.

These run on 1 device against a *mock* mesh-shape object — the real
512-device lowering is exercised by launch/dryrun.py (see EXPERIMENTS.md).
"""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.distributed import sharding as sh
from repro.models.transformer import init_params


class MockMesh:
    shape = {"pod": 2, "data": 16, "model": 16}
    size = 512


MESH = MockMesh()


def test_strategy_selection():
    get = configs.get_config
    assert sh.strategy_for(get("qwen1.5-110b"), MESH) == "tp2d"
    assert sh.strategy_for(get("qwen3-moe-235b-a22b"), MESH) == "tp2d"
    for small in ("smollm-360m", "gemma-2b", "rwkv6-3b", "internlm2-20b",
                  "hubert-xlarge", "olmoe-1b-7b", "recurrentgemma-9b"):
        assert sh.strategy_for(get(small), MESH) == "fsdp", small


def _leaf_specs(arch, strategy, multi_pod=False):
    cfg = configs.get_smoke(arch)
    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    return shapes, sh.param_pspecs(shapes, MESH, multi_pod, strategy)


def test_specs_rank_matches_and_divisible():
    for arch in configs.ARCHS:
        for strategy in ("tp2d", "fsdp"):
            shapes, specs = _leaf_specs(arch, strategy)
            for (path, leaf), (_, spec) in zip(
                    jax.tree_util.tree_flatten_with_path(shapes)[0],
                    jax.tree_util.tree_flatten_with_path(
                        specs, is_leaf=lambda x: isinstance(x, P))[0]):
                assert len(spec) <= leaf.ndim, (arch, path, spec, leaf.shape)
                for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
                    if ax is None:
                        continue
                    axes = ax if isinstance(ax, tuple) else (ax,)
                    n = 1
                    for a in axes:
                        n *= MESH.shape[a]
                    assert dim % n == 0, (arch, path, spec, leaf.shape)


def test_full_config_tp2d_rules_hit_big_weights():
    """For the TP archs, the big weight matrices must actually shard."""
    cfg = configs.get_config("qwen1.5-110b")
    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    specs = sh.param_pspecs(shapes, MESH, False, "tp2d")
    flat = {sh._path_str(p): (l, s) for (p, l), (_, s) in zip(
        jax.tree_util.tree_flatten_with_path(shapes)[0],
        jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, P))[0])}
    wq = next(v for k, v in flat.items() if k.endswith("attn/wq/w"))
    assert "model" in str(wq[1])
    table = next(v for k, v in flat.items() if k.endswith("embed/table"))
    assert str(table[1]) != "PartitionSpec()"


def test_batch_pspecs_fallbacks():
    batch = {"tokens": jax.ShapeDtypeStruct((256, 4097), jnp.int32)}
    spec = sh.batch_pspecs(batch, MESH, False, strategy="fsdp")["tokens"]
    assert spec[0] == ("data", "model")
    batch = {"tokens": jax.ShapeDtypeStruct((128, 10), jnp.int32)}
    spec = sh.batch_pspecs(batch, MESH, False, strategy="fsdp")["tokens"]
    assert spec[0] in ("data", ("data",))   # 128 % 256 != 0 -> next candidate
    batch = {"tokens": jax.ShapeDtypeStruct((1, 524288), jnp.int32)}
    spec = sh.batch_pspecs(batch, MESH, False, shard_seq=True,
                           strategy="fsdp")["tokens"]
    assert spec == P(None, "data")      # B=1: sequence parallelism


def test_shard_activation_is_identity_without_context():
    x = jnp.ones((4, 8))
    assert sh.shard_activation(x, "act") is x
