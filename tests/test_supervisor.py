"""Elastic process-group supervisor (launch/supervisor.py).

Two layers:

  * toy-worker tests drive supervise() with tiny non-jax python workers
    (seconds each): outcome taxonomy, shrink-on-crash, straggler culprit
    selection by (step, phase), collateral rc=75 no-shrink, startup
    timeout, min_workers floor, restart exhaustion;
  * full-stack tests (slow, nightly elastic lane) run real
    jax.distributed training groups and pin the ISSUE acceptance row:
    SIGKILL 1 of 4 workers mid-run -> the supervisor restarts with 3 and
    the final params are bit-identical to an uninterrupted same-seed run;
    an induced straggler (sleep > --step-timeout) takes the same path.
"""
from __future__ import annotations

import os
import sys
import textwrap

import numpy as np
import pytest

from repro.distributed.fault_tolerance import RestartPolicy
from repro.launch.supervisor import COLLATERAL_RC, supervise

# ---------------------------------------------------------------------------
# toy workers: behaviour scripted per (gen, rank), heartbeats hand-written
# ---------------------------------------------------------------------------

_TOY = textwrap.dedent("""
    import json, os, sys, time
    hb_path, host_id, gen, mode = (sys.argv[1], int(sys.argv[2]),
                                   int(sys.argv[3]), sys.argv[4])
    def beat(step, phase="step"):
        tmp = hb_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"host_id": host_id, "step": step, "phase": phase,
                       "t": time.time()}, f)
        os.replace(tmp, hb_path)
    if mode == "no_beat":
        time.sleep(60)
    for step in range(4):
        beat(step)
        if mode == "crash" and step == 2:
            os.kill(os.getpid(), 9)
        if mode == "stall_step" and step == 2:
            time.sleep(60)
        if mode == "stall_sync" and step == 2:
            beat(step, "sync")
            time.sleep(60)
        if mode == "exit_err" and step == 2:
            sys.exit(7)
        if mode == "exit_collateral" and step == 2:
            sys.exit(75)
        time.sleep(0.05)
    beat(4, "done")
""")


def _toy_cmd(modes_by_gen_rank):
    """make_cmd for supervise(): modes_by_gen_rank[(gen, rank)] -> mode
    string, default 'ok'."""
    def make_cmd(gen, rank, num_hosts, port, hb_path):
        mode = modes_by_gen_rank.get((gen, rank), "ok")
        return [sys.executable, "-c", _TOY, hb_path, str(rank), str(gen),
                mode]
    return make_cmd


_FAST = dict(backoff_s=0.05, backoff_max_s=0.1, startup_timeout_s=10.0)


def test_supervisor_completed(tmp_path):
    out = supervise(_toy_cmd({}), 2, RestartPolicy(**_FAST),
                    str(tmp_path), verbose=False)
    assert out.status == "completed" and out.ok
    assert out.restarts == 0 and out.final_workers == 2
    assert [g.failure for g in out.generations] == [None]
    assert out.generations[0].last_step == 4


def test_supervisor_shrinks_on_crash(tmp_path):
    out = supervise(_toy_cmd({(0, 1): "crash"}), 3, RestartPolicy(**_FAST),
                    str(tmp_path), verbose=False)
    assert out.status == "completed"
    assert out.restarts == 1 and out.final_workers == 2
    g0, g1 = out.generations
    assert g0.failure == "crash" and g0.culprits == (1,)
    assert g1.workers == 2 and g1.failure is None


def test_supervisor_straggler_culprit_by_phase(tmp_path):
    # rank 0 stuck at (2, step); rank 1 reached (2, sync) and is "blocked
    # on the exchange": both heartbeats go stale, but only rank 0 — the
    # earliest (step, phase) — is the straggler to remove
    out = supervise(
        _toy_cmd({(0, 0): "stall_step", (0, 1): "stall_sync"}), 2,
        RestartPolicy(step_timeout_s=1.0, **_FAST),
        str(tmp_path), verbose=False)
    assert out.status == "completed"
    g0 = out.generations[0]
    assert g0.failure == "straggler" and g0.culprits == (0,)
    assert out.final_workers == 1


def test_supervisor_collateral_does_not_shrink(tmp_path):
    # gen 0: both workers exit COLLATERAL_RC (coordinator hiccup) — the
    # restart keeps the group at full size
    out = supervise(
        _toy_cmd({(0, 0): "exit_collateral", (0, 1): "exit_collateral"}),
        2, RestartPolicy(**_FAST), str(tmp_path), verbose=False)
    assert out.status == "completed"
    assert out.restarts == 1 and out.final_workers == 2
    assert out.generations[0].failure == "collateral"
    assert out.generations[0].culprits == ()


def test_supervisor_error_restarts_same_size_until_exhausted(tmp_path):
    # a deterministic worker bug (rc=7) restarts without shrinking and is
    # bounded by max_restarts
    out = supervise(
        _toy_cmd({(g, 0): "exit_err" for g in range(5)}), 2,
        RestartPolicy(max_restarts=2, **_FAST), str(tmp_path),
        verbose=False)
    assert out.status == "exhausted_restarts"
    assert out.restarts == 3 and out.final_workers == 2
    assert all(g.failure == "error" for g in out.generations)


def test_supervisor_min_workers_floor(tmp_path):
    out = supervise(
        _toy_cmd({(g, r): "crash" for g in range(4) for r in range(3)}), 2,
        RestartPolicy(min_workers=2, **_FAST), str(tmp_path),
        verbose=False)
    assert out.status == "failed"
    assert "min_workers" in out.error


def test_supervisor_startup_timeout(tmp_path):
    out = supervise(
        _toy_cmd({(0, 1): "no_beat"}), 2,
        RestartPolicy(**dict(_FAST, startup_timeout_s=1.0)),
        str(tmp_path), verbose=False)
    assert out.status == "completed"
    g0 = out.generations[0]
    assert g0.failure == "startup_timeout" and g0.culprits == (1,)
    assert out.final_workers == 1


# ---------------------------------------------------------------------------
# full stack: real jax.distributed training groups (nightly elastic lane)
# ---------------------------------------------------------------------------

STEPS, BATCH, SEQ = 6, 4, 32


def _reference_params(steps=STEPS):
    """Uninterrupted single-process run of the same seed/config."""
    from repro.data.pipeline import DataConfig
    from repro.models.transformer import ModelConfig
    from repro.optim.adamw import OptConfig
    from repro.training.elastic import elastic_train_loop
    cfg = ModelConfig("tiny", n_layers=2, d_model=64, n_heads=4, n_kv=2,
                      d_ff=128, vocab=128)
    opt_cfg = OptConfig(lr_peak=3e-4, warmup_steps=min(100, steps // 10 + 1),
                        total_steps=steps)
    data_cfg = DataConfig(vocab=128, seq_len=SEQ, global_batch=BATCH, seed=0)
    params, opt, _ = elastic_train_loop(cfg, opt_cfg, data_cfg, steps,
                                        verbose=False)
    return params, opt


def _final_params(ckpt_dir, example):
    from repro.checkpoint import store
    step, restored = store.restore_latest(ckpt_dir, example)
    assert step == STEPS, f"final checkpoint at step {step}, want {STEPS}"
    return restored["params"]


def _assert_bit_identical(ref, got):
    import jax
    for a, b in zip(jax.tree_util.tree_leaves(ref),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_supervised_kill_resumes_bit_identical(tmp_path):
    """The acceptance row: SIGKILL 1 of 4 workers mid-run — the
    supervisor restarts with 3 survivors and the final params match an
    uninterrupted same-seed run bit-for-bit."""
    from repro.launch.supervisor import supervise_training
    ck = str(tmp_path / "ck")
    out = supervise_training(
        "tiny", STEPS, ck, str(tmp_path / "run"), workers=4,
        policy=RestartPolicy(ckpt_every=2, step_timeout_s=180,
                             backoff_s=0.1),
        global_batch=BATCH, seq_len=SEQ, seed=0,
        chaos_kill="2:3", verbose=False)
    assert out.status == "completed", (out.status, out.error)
    assert out.restarts == 1 and out.final_workers == 3
    assert out.generations[0].failure == "crash"

    ref_params, ref_opt = _reference_params()
    got = _final_params(ck, {"params": ref_params, "opt": ref_opt})
    _assert_bit_identical(ref_params, got)


@pytest.mark.slow
def test_supervised_straggler_resumes_bit_identical(tmp_path):
    """An induced straggler (sleep > step-timeout) takes the same
    kill-group/shrink/resume path as a crash."""
    from repro.launch.supervisor import supervise_training
    ck = str(tmp_path / "ck")
    out = supervise_training(
        "tiny", STEPS, ck, str(tmp_path / "run"), workers=3,
        policy=RestartPolicy(ckpt_every=2, step_timeout_s=20,
                             backoff_s=0.1),
        global_batch=BATCH, seq_len=SEQ, seed=0,
        chaos_straggle="1:3:600", verbose=False)
    assert out.status == "completed", (out.status, out.error)
    assert out.restarts == 1 and out.final_workers == 2
    assert out.generations[0].failure == "straggler"

    ref_params, ref_opt = _reference_params()
    got = _final_params(ck, {"params": ref_params, "opt": ref_opt})
    _assert_bit_identical(ref_params, got)


@pytest.mark.slow
def test_supervised_async_ckpt_group(tmp_path):
    """--async-ckpt through the whole supervised path still yields the
    bit-identical final checkpoint."""
    from repro.launch.supervisor import supervise_training
    ck = str(tmp_path / "ck")
    out = supervise_training(
        "tiny", STEPS, ck, str(tmp_path / "run"), workers=2,
        policy=RestartPolicy(ckpt_every=2, step_timeout_s=180,
                             backoff_s=0.1),
        global_batch=BATCH, seq_len=SEQ, seed=0, async_ckpt=True,
        verbose=False)
    assert out.status == "completed", (out.status, out.error)
    ref_params, ref_opt = _reference_params()
    got = _final_params(ck, {"params": ref_params, "opt": ref_opt})
    _assert_bit_identical(ref_params, got)
