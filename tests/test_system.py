"""End-to-end behaviour: train a tiny LM, quantize, serve — the full stack."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core.types import P16_2
from repro.data.pipeline import DataConfig
from repro.models.transformer import ModelConfig
from repro.optim.adamw import OptConfig
from repro.quant.policy import PositPolicy
from repro.quant.ptq import quantize_for_serving
from repro.serving.engine import generate
from repro.training.trainer import train_loop


def test_train_quantize_serve_end_to_end(tmp_path):
    cfg = ModelConfig("e2e", n_layers=2, d_model=64, n_heads=4, n_kv=2,
                      d_ff=128, vocab=128,
                      policy=PositPolicy(weights=P16_2))   # QAT train
    ocfg = OptConfig(lr_peak=3e-3, warmup_steps=10, total_steps=80)
    dcfg = DataConfig(vocab=128, seq_len=48, global_batch=16)
    params, _, hist = train_loop(cfg, ocfg, dcfg, 60, ckpt_dir=str(tmp_path),
                                 verbose=False)
    assert hist[-1]["loss"] < hist[0]["loss"]

    # PTQ to posit16 storage and serve with posit KV
    import dataclasses
    scfg = dataclasses.replace(
        cfg, policy=PositPolicy(weights=P16_2, kv_cache=P16_2))
    qparams = quantize_for_serving(params, P16_2)
    int_leaves = [x for x in jax.tree_util.tree_leaves(qparams)
                  if x.dtype == jnp.int16]
    assert int_leaves, "PTQ produced no posit weights"

    prompts = jnp.ones((2, 8), jnp.int32)
    out = generate(qparams, scfg, prompts, max_new=6, max_len=16)
    assert out.shape == (2, 6)
    assert bool((out >= 0).all()) and bool((out < 128).all())

    # posit-served logits stay close to float-served logits
    fout = generate(params, cfg, prompts, max_new=6, max_len=16)
    # greedy tokens may diverge after a few steps; at least the first token
    # should match (p16 ~ f32 claim)
    assert int(out[0, 0]) == int(fout[0, 0])
