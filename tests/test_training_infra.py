"""Trainer, checkpointing, fault tolerance, data pipeline, collectives."""
import glob
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import store
from repro.data.pipeline import DataConfig, global_batch_at, host_batch_at
from repro.distributed.fault_tolerance import RestartPolicy
from repro.models.transformer import ModelConfig
from repro.optim.adamw import OptConfig
from repro.training.trainer import train_loop

TINY = ModelConfig("tiny", n_layers=2, d_model=64, n_heads=4, n_kv=2,
                   d_ff=128, vocab=128)
OPT = OptConfig(lr_peak=1e-3, warmup_steps=5, total_steps=40)
DATA = DataConfig(vocab=128, seq_len=64, global_batch=8)


def test_loss_decreases():
    ocfg = OptConfig(lr_peak=3e-3, warmup_steps=20, total_steps=200)
    _, _, hist = train_loop(TINY, ocfg,
                            DataConfig(vocab=128, seq_len=64, global_batch=16),
                            120, verbose=False)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.5


def test_restart_bit_identical(tmp_path):
    td = str(tmp_path)
    p1, _, _ = train_loop(TINY, OPT, DATA, 12, ckpt_dir=td,
                          policy=RestartPolicy(ckpt_every=5), verbose=False)
    # second run resumes from the final checkpoint: params unchanged
    p2, _, _ = train_loop(TINY, OPT, DATA, 12, ckpt_dir=td, verbose=False)
    assert all(np.array_equal(a, b) for a, b in
               zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))


def test_crash_resume_equals_uninterrupted(tmp_path):
    """Simulated crash at step 10: resume must reproduce the 12-step run."""
    td = str(tmp_path)
    p_full, _, _ = train_loop(TINY, OPT, DATA, 12, verbose=False)
    train_loop(TINY, OPT, DATA, 10, ckpt_dir=td,
               policy=RestartPolicy(ckpt_every=5), verbose=False)
    # drop the step-12... keep only step 10, resume to 12
    p_res, _, _ = train_loop(TINY, OPT, DATA, 12, ckpt_dir=td, verbose=False)
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)):
        np.testing.assert_array_equal(a, b)


def test_corrupted_checkpoint_fallback(tmp_path):
    from repro.models.transformer import init_params
    from repro.optim.adamw import init_state
    td = str(tmp_path)
    train_loop(TINY, OPT, DATA, 12, ckpt_dir=td,
               policy=RestartPolicy(ckpt_every=5), verbose=False)
    latest = sorted(glob.glob(os.path.join(td, "step_*")))[-1]
    os.remove(glob.glob(os.path.join(latest, "leaf_00000.npy"))[0])
    params = init_params(jax.random.PRNGKey(0), TINY)
    example = {"params": params, "opt": init_state(params, OPT)}
    step, tree = store.restore_latest(td, example)
    # restore_latest must skip the corrupted dir and return an older step
    assert step is not None and step < 12
    assert tree is not None


def test_checkpoint_atomicity(tmp_path):
    """A .tmp dir (simulated crash mid-write) is ignored by restore."""
    td = str(tmp_path)
    tree = {"a": np.arange(4), "b": np.ones((2, 2))}
    store.save(td, 1, tree)
    os.makedirs(os.path.join(td, "step_00000002.tmp"))
    step, restored = store.restore_latest(td, tree)
    assert step == 1
    np.testing.assert_array_equal(restored["a"], tree["a"])


def test_data_pipeline_deterministic_and_seekable():
    cfg = DataConfig(vocab=100, seq_len=32, global_batch=8, seed=7)
    b1 = global_batch_at(5, cfg)
    b2 = global_batch_at(5, cfg)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = global_batch_at(6, cfg)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # elastic: per-host slices tile the global batch regardless of host count
    for nh in (1, 2, 4):
        parts = [host_batch_at(5, cfg, h, nh)["tokens"] for h in range(nh)]
        np.testing.assert_array_equal(np.concatenate(parts), b1["tokens"])


def test_step_watchdog():
    from repro.distributed.fault_tolerance import StepWatchdog
    import time
    with pytest.raises(TimeoutError):
        with StepWatchdog(0.1):
            time.sleep(0.5)
    with StepWatchdog(5.0):
        pass  # disarms cleanly


def test_compressed_psum_multidevice_subprocess():
    """Run the posit-compressed all-reduce on 8 emulated devices and compare
    against the exact f32 psum (error bounded by one posit16 rounding)."""
    import subprocess
    import sys
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.core.types import P16_2
from repro.distributed.collectives import compressed_psum

mesh = jax.make_mesh((8,), ("data",))
x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 64)), jnp.float32)

def f(xs):
    return compressed_psum(xs, "data", P16_2)

got = shard_map(f, mesh=mesh, in_specs=P("data", None),
                out_specs=P("data", None), check_rep=False)(x)
want = x.sum(axis=0, keepdims=True).repeat(8, 0)
rel = np.abs(np.asarray(got) - np.asarray(want)) / (np.abs(np.asarray(want)) + 1e-9)
assert rel.max() < 2e-3, rel.max()   # p16: ~2^-13 relative rounding + margin
print("OK", rel.max())
"""
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
