"""Training on the Pallas kernels: backward-kernel grad parity, the
donated train step, and the shard_map training acceptance row.

Tier-1 scope (interpret mode on CPU):
  * flash prefill dQ/dK/dV vs the jnp reference VJP across mask configs
    (causal / sliding window / softcap) and KV formats (float, p8, p16)
  * grouped-GEMM dX/dW vs the einsum oracle on ragged / empty /
    tile-straddling groups
  * posit_gemm custom_vjp (plain, transpose_b, posit operand)
  * zero-BWD_FALLBACKS invariant of the kernel-path train step + buffer
    donation aliasing
  * the ISSUE-8 acceptance row: a forced 4-device host runs the shard_map
    train step with zero BWD_FALLBACKS and zero DENSE_MOE_FALLBACKS (DP
    MoE), and (2,2) DP x TP matches the single-device step (subprocess,
    like test_serving_sharded).
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.convert import f32_to_posit
from repro.core.decode import decode_to_f32
from repro.core.types import P8_2, P16_2
from repro.kernels import ops as kops
from repro.models import blocks
from repro.models import moe as MOE
from repro.models.transformer import ModelConfig, init_params
from repro.optim.adamw import OptConfig, init_state
from repro.quant.policy import PositPolicy
from repro.training.train_step import make_train_step


def _pallas_interpret_env(monkeypatch):
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    monkeypatch.delenv("REPRO_FORCE_GATHER", raising=False)
    monkeypatch.delenv("REPRO_FORCE_BWD_REFERENCE", raising=False)


# --------------------------------------------------------------------------
# flash prefill backward vs the jnp reference VJP
# --------------------------------------------------------------------------
def _flash_grads(posit_cfg, causal, window, softcap):
    """(kernel_grads, reference_grads, grad_names) through _fused_prefill —
    the same custom_vjp training differentiates."""
    rng = np.random.default_rng(3)
    B, H, NKV, Sq, Skv, D = 2, 4, 2, 40, 72, 16
    q = jnp.asarray(rng.standard_normal((B, H, Sq, D)) * 0.5, jnp.float32)
    kf = jnp.asarray(rng.standard_normal((B, NKV, Skv, D)) * 0.5, jnp.float32)
    vf = jnp.asarray(rng.standard_normal((B, NKV, Skv, D)) * 0.5, jnp.float32)
    g = jnp.asarray(rng.standard_normal((B, H, Sq, D)), jnp.float32)
    kv_len = jnp.asarray([Skv, Skv - 9], jnp.int32)   # ragged valid lengths
    q_off = kv_len - Sq
    if posit_cfg is not None:
        k, v = f32_to_posit(kf, posit_cfg), f32_to_posit(vf, posit_cfg)
        argnums = (0,)          # posit KV: quantized, not differentiable
        names = ["dq"]
    else:
        k, v = kf, vf
        argnums = (0, 1, 2)
        names = ["dq", "dk", "dv"]

    static = (posit_cfg, NKV, causal, window, softcap)

    def loss(q, k, v):
        out = blocks._fused_prefill(static, q, k, v, kv_len, q_off)
        return (out * g).sum()

    kops.BWD_FALLBACKS.clear()
    got = jax.grad(loss, argnums=argnums)(q, k, v)
    assert not dict(kops.BWD_FALLBACKS), dict(kops.BWD_FALLBACKS)

    kops.FORCE_BWD_REFERENCE = True
    try:
        ref = jax.grad(loss, argnums=argnums)(q, k, v)
    finally:
        kops.FORCE_BWD_REFERENCE = False
    assert kops.BWD_FALLBACKS["flash:forced"] > 0
    kops.BWD_FALLBACKS.clear()
    return got, ref, names


@pytest.mark.parametrize("causal,window,softcap", [
    (True, None, None),
    (True, 48, None),
    (False, None, 8.0),
    (True, 32, 10.0),
], ids=["causal", "window", "softcap", "all"])
def test_flash_bwd_float_matches_reference(monkeypatch, causal, window,
                                           softcap):
    _pallas_interpret_env(monkeypatch)
    got, ref, names = _flash_grads(None, causal, window, softcap)
    for n, a, b in zip(names, got, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                                   atol=2e-5, err_msg=n)


@pytest.mark.parametrize("pcfg", [P16_2, P8_2], ids=["p16", "p8"])
def test_flash_bwd_posit_kv_matches_reference(monkeypatch, pcfg):
    """Posit KV: dq only (the cache is quantized storage); the kernel
    decodes k/v tiles in VMEM exactly like the reference decodes chunks."""
    _pallas_interpret_env(monkeypatch)
    got, ref, names = _flash_grads(pcfg, True, 24, 6.0)
    for n, a, b in zip(names, got, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                                   atol=2e-5, err_msg=n)


# --------------------------------------------------------------------------
# grouped-GEMM backward vs the einsum oracle
# --------------------------------------------------------------------------
@pytest.mark.parametrize("sizes,tail", [
    ([30, 0, 50, 16], 0),            # ragged + one empty group
    ([5, 0, 0, 0, 19], 4),           # empty run + unowned tail rows
    ([130, 7, 120, 3], 0),           # groups straddling the 128-row m-tile
], ids=["ragged", "sparse-tail", "straddle"])
def test_grouped_bwd_matches_einsum_oracle(monkeypatch, sizes, tail):
    rng = np.random.default_rng(4)
    E, K, N = len(sizes), 32, 40
    S = int(sum(sizes)) + tail
    off = jnp.asarray(np.concatenate([[0], np.cumsum(sizes)]), jnp.int32)
    x = jnp.asarray(rng.standard_normal((S, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((E, K, N)), jnp.float32)
    g = jnp.asarray(rng.standard_normal((S, N)), jnp.float32)

    def loss(x, w):
        return (kops.grouped_matmul(x, w, off) * g).sum()

    _pallas_interpret_env(monkeypatch)
    kops.BWD_FALLBACKS.clear()
    dx, dw = jax.grad(loss, argnums=(0, 1))(x, w)
    assert not dict(kops.BWD_FALLBACKS), dict(kops.BWD_FALLBACKS)

    gid = np.repeat(np.arange(E), sizes)
    live = np.asarray(g)[:len(gid)]
    dx_ref = np.zeros((S, K), np.float32)
    dx_ref[:len(gid)] = np.einsum("sn,skn->sk", live, np.asarray(w)[gid])
    oh = np.eye(E, dtype=np.float32)[gid]
    dw_ref = np.einsum("se,sk,sn->ekn", oh, np.asarray(x)[:len(gid)], live)
    np.testing.assert_allclose(np.asarray(dx), dx_ref, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(dw), dw_ref, rtol=2e-4, atol=2e-5)


def test_grouped_bwd_posit_weights_dx_only(monkeypatch):
    """Posit expert weights: dx streams the storage tiles via transpose_b;
    no dw (quantized storage is not a differentiable leaf)."""
    rng = np.random.default_rng(5)
    sizes = [30, 0, 50, 16]
    E, K, N = len(sizes), 32, 40
    S = int(sum(sizes))
    off = jnp.asarray(np.concatenate([[0], np.cumsum(sizes)]), jnp.int32)
    x = jnp.asarray(rng.standard_normal((S, K)), jnp.float32)
    w = f32_to_posit(
        jnp.asarray(rng.standard_normal((E, K, N)), jnp.float32), P16_2)
    g = jnp.asarray(rng.standard_normal((S, N)), jnp.float32)

    def loss(x):
        return (kops.grouped_matmul(x, w, off, cfg=P16_2) * g).sum()

    _pallas_interpret_env(monkeypatch)
    kops.BWD_FALLBACKS.clear()
    dx = jax.grad(loss)(x)
    assert not dict(kops.BWD_FALLBACKS), dict(kops.BWD_FALLBACKS)
    gid = np.repeat(np.arange(E), sizes)
    wf = np.asarray(decode_to_f32(w, P16_2))
    dx_ref = np.einsum("sn,skn->sk", np.asarray(g), wf[gid])
    np.testing.assert_allclose(np.asarray(dx), dx_ref, rtol=2e-4, atol=2e-5)


# --------------------------------------------------------------------------
# posit_gemm custom_vjp (the linear/unembed training path)
# --------------------------------------------------------------------------
def test_gemm_vjp_matches_math(monkeypatch):
    rng = np.random.default_rng(6)
    m, k, n = 48, 64, 80
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    bt = jnp.asarray(rng.standard_normal((n, k)), jnp.float32)
    g = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)

    _pallas_interpret_env(monkeypatch)
    kops.BWD_FALLBACKS.clear()
    da, db = jax.grad(lambda a, b: (kops.gemm(a, b) * g).sum(),
                      argnums=(0, 1))(a, b)
    np.testing.assert_allclose(np.asarray(da), np.asarray(g @ b.T),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(db), np.asarray(a.T @ g),
                               rtol=2e-4, atol=2e-5)
    # transpose_b (the tied-unembedding layout [vocab, d])
    da, dbt = jax.grad(
        lambda a, bt: (kops.gemm(a, bt, transpose_b=True) * g).sum(),
        argnums=(0, 1))(a, bt)
    np.testing.assert_allclose(np.asarray(da), np.asarray(g @ bt),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(dbt), np.asarray(g.T @ a),
                               rtol=2e-4, atol=2e-5)
    # posit B operand: dA only, contracted against in-kernel decoded tiles
    bb = f32_to_posit(b, P16_2)
    da = jax.grad(lambda a: (kops.gemm(a, bb, cfg_b=P16_2) * g).sum())(a)
    bf = np.asarray(decode_to_f32(bb, P16_2))
    np.testing.assert_allclose(np.asarray(da), np.asarray(g) @ bf.T,
                               rtol=2e-4, atol=2e-5)
    assert not dict(kops.BWD_FALLBACKS), dict(kops.BWD_FALLBACKS)


def test_forced_reference_bwd_counts(monkeypatch):
    """REPRO_FORCE_BWD_REFERENCE pins the jnp backwards (the bench oracle
    leg) and every op counts itself in BWD_FALLBACKS."""
    rng = np.random.default_rng(7)
    _pallas_interpret_env(monkeypatch)
    monkeypatch.setenv("REPRO_FORCE_BWD_REFERENCE", "1")
    a = jnp.asarray(rng.standard_normal((16, 24)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((24, 8)), jnp.float32)
    kops.BWD_FALLBACKS.clear()
    jax.grad(lambda a: kops.gemm(a, b).sum())(a)
    assert kops.BWD_FALLBACKS["gemm:forced"] > 0
    kops.BWD_FALLBACKS.clear()


# --------------------------------------------------------------------------
# the kernel-path train step: zero fallbacks + donation aliasing
# --------------------------------------------------------------------------
def test_train_step_kernel_path_zero_fallbacks(monkeypatch):
    _pallas_interpret_env(monkeypatch)
    cfg = ModelConfig("tk-zero-fb", n_layers=2, d_model=64, n_heads=4,
                      n_kv=2, d_ff=128, vocab=256,
                      policy=PositPolicy(weights=P16_2))
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = OptConfig(lr_peak=1e-3, warmup_steps=2, total_steps=8)
    opt = init_state(params, opt_cfg)
    step = make_train_step(cfg, opt_cfg, donate=False)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 33),
                                          0, cfg.vocab)}
    kops.BWD_FALLBACKS.clear()
    moe_before = dict(MOE.DENSE_MOE_FALLBACKS)
    p2, o2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert not dict(kops.BWD_FALLBACKS), dict(kops.BWD_FALLBACKS)
    assert dict(MOE.DENSE_MOE_FALLBACKS) == moe_before
    # params actually moved
    d0 = np.abs(np.asarray(p2["embed"]["table"])
                - np.asarray(params["embed"]["table"])).max()
    assert d0 > 0


def test_train_step_donates_params_and_opt_state():
    """donate_argnums=(0, 1): the step aliases the param/moment buffers in
    place — the old leaves are deleted and (same shape/dtype/layout) the
    new params reuse the donated memory."""
    cfg = ModelConfig("tk-donate", n_layers=1, d_model=32, n_heads=2,
                      n_kv=1, d_ff=64, vocab=128, policy=PositPolicy())
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = OptConfig(lr_peak=1e-3, warmup_steps=2, total_steps=8)
    opt = init_state(params, opt_cfg)
    params = jax.device_put(params)
    opt = jax.device_put(opt)
    table = params["embed"]["table"]
    moment = opt["m"]["embed"]["table"]
    ptr_t = table.unsafe_buffer_pointer()
    ptr_m = moment.unsafe_buffer_pointer()

    step = make_train_step(cfg, opt_cfg)     # donate=True default
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 17),
                                          0, cfg.vocab)}
    p2, o2, _ = step(params, opt, batch)
    jax.block_until_ready((p2, o2))

    # donated inputs are dead buffers now
    with pytest.raises(RuntimeError):
        np.asarray(table)
    with pytest.raises(RuntimeError):
        np.asarray(moment)
    # and the outputs re-use the donated memory (same device pointers)
    out_ptrs = {l.unsafe_buffer_pointer()
                for l in jax.tree_util.tree_leaves((p2, o2))}
    assert ptr_t in out_ptrs
    assert ptr_m in out_ptrs


def test_trainer_history_logs_fallbacks_and_throughput(tmp_path):
    from repro.data.pipeline import DataConfig
    from repro.training.trainer import train_loop
    cfg = ModelConfig("tk-trainer-log", n_layers=1, d_model=32, n_heads=2,
                      n_kv=1, d_ff=64, vocab=128, policy=PositPolicy())
    opt_cfg = OptConfig(lr_peak=1e-3, warmup_steps=1, total_steps=3)
    data = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=2)
    _, _, hist = train_loop(cfg, opt_cfg, data, 3, log_every=1,
                            verbose=False)
    assert len(hist) == 3
    for row in hist:
        assert row["steps_per_s"] > 0
        assert isinstance(row["fallbacks"], dict)


def test_tp_training_rejects_moe():
    """TP training is attention/MLP stacks only (router grads are partial
    per shard); the builder must refuse rather than silently diverge."""
    from repro.models.transformer import MoEConfig

    class _FakeMesh:
        shape = {"data": 2, "model": 2}

    cfg = ModelConfig("tk-tp-moe", n_layers=2, d_model=64, n_heads=4,
                      n_kv=2, d_ff=128, vocab=256,
                      moe=MoEConfig(n_experts=4, top_k=2),
                      policy=PositPolicy())
    with pytest.raises(NotImplementedError):
        make_train_step(cfg, OptConfig(), _FakeMesh())


# --------------------------------------------------------------------------
# the acceptance row: shard_map training on a forced 4-device host
# --------------------------------------------------------------------------
_SUBPROCESS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ["REPRO_USE_PALLAS"] = "1"
    os.environ["REPRO_PALLAS_INTERPRET"] = "1"
    import numpy as np, jax, jax.numpy as jnp
    from repro.core.types import P16_2
    from repro.models.transformer import ModelConfig, MoEConfig, init_params
    from repro.optim.adamw import OptConfig, init_state
    from repro.quant.policy import PositPolicy
    from repro.training.train_step import make_train_step
    from repro.launch.mesh import make_serving_mesh
    from repro.distributed import sharding
    from repro.kernels import ops as kops
    from repro.models import moe as MOE

    def shard(params, opt, mesh):
        pspecs = sharding.train_param_pspecs(params, mesh)
        sp = jax.device_put(params, sharding.to_shardings(pspecs, mesh))
        so = jax.device_put(opt, sharding.to_shardings(
            sharding.opt_state_pspecs(opt, pspecs, mesh), mesh))
        return sp, so

    opt_cfg = OptConfig(lr_peak=1e-3, warmup_steps=2, total_steps=8)

    # ---- (4, 1) data-parallel MoE: the zero-fallback acceptance row ----
    cfg = ModelConfig("tk-sh4-moe", n_layers=2, d_model=64, n_heads=4,
                      n_kv=2, d_ff=128, vocab=256,
                      moe=MoEConfig(n_experts=4, top_k=2),
                      policy=PositPolicy(weights=P16_2))
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = init_state(params, opt_cfg)
    mesh = make_serving_mesh(4, 1)
    sp, so = shard(params, opt, mesh)
    step = make_train_step(cfg, opt_cfg, mesh, donate=False)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 33),
                                          0, cfg.vocab)}
    kops.BWD_FALLBACKS.clear()
    moe_before = dict(MOE.DENSE_MOE_FALLBACKS)
    p2, o2, m = step(sp, so, batch)
    assert np.isfinite(float(m["loss"])), m
    assert not dict(kops.BWD_FALLBACKS), dict(kops.BWD_FALLBACKS)
    assert dict(MOE.DENSE_MOE_FALLBACKS) == moe_before, (
        moe_before, dict(MOE.DENSE_MOE_FALLBACKS))

    # ---- (2, 2) DP x Megatron-TP attention stack vs single device ----
    cfg2 = ModelConfig("tk-sh4-tp", n_layers=2, d_model=64, n_heads=4,
                       n_kv=2, d_ff=128, vocab=256,
                       policy=PositPolicy(weights=P16_2))
    params2 = init_params(jax.random.PRNGKey(0), cfg2)
    opt2 = init_state(params2, opt_cfg)
    mesh2 = make_serving_mesh(2, 2)
    sp2, so2 = shard(params2, opt2, mesh2)
    step2 = make_train_step(cfg2, opt_cfg, mesh2, donate=False)
    kops.BWD_FALLBACKS.clear()
    pa, oa, ma = step2(sp2, so2, batch)
    assert not dict(kops.BWD_FALLBACKS), dict(kops.BWD_FALLBACKS)

    step1 = make_train_step(cfg2, opt_cfg, donate=False)
    pb, ob, mb = step1(params2, opt2, batch)
    np.testing.assert_allclose(float(ma["loss"]), float(mb["loss"]),
                               rtol=2e-4)
    np.testing.assert_allclose(float(ma["grad_norm"]),
                               float(mb["grad_norm"]), rtol=2e-3)
    for (ka, a), (kb, b) in zip(jax.tree_util.tree_leaves_with_path(pa),
                                jax.tree_util.tree_leaves_with_path(pb)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=5e-3, err_msg=str(ka))
    print("TRAIN-SHARDED-OK")
""")


def test_shard_map_train_step_4dev_zero_fallbacks():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "TRAIN-SHARDED-OK" in out.stdout
